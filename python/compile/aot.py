"""AOT: lower the L2 model to HLO *text* artifacts for the rust runtime.

Two gotchas drive this file's shape (see /opt/xla-example/README.md and
DESIGN.md §3):

1. HLO text (NOT ``.serialize()``) is the interchange format: jax >= 0.5
   emits HloModuleProto with 64-bit instruction ids which xla_extension
   0.5.1 (what the published ``xla`` 0.1.6 crate links) rejects
   (``proto.id() <= INT_MAX``). The text parser reassigns ids.

2. ``as_hlo_text()`` ELIDES large constants (``constant({...})``), so
   weights must NOT be baked into the HLO via closure capture — they are
   passed as runtime parameters and exported to ``weights.bin`` (raw f32
   little-endian, concatenated in jax tree-flatten order) with the order
   recorded in ``meta.txt``. The rust runtime reconstructs the argument
   list from that manifest.

Outputs (under --out-dir, default ../artifacts):
  prefill.hlo.txt       (params..., tokens[B,P]) -> (logits, k_cache, v_cache)
  decode.hlo.txt        (params..., token, pos, k_cache, v_cache) -> (logits, k', v')
  attn_kernel.hlo.txt   standalone Pallas decode-attention (microbench)
  weights.bin           concatenated f32 LE leaves
  meta.txt / meta.json  config + weight manifest (txt for rust, json for humans)
  golden_*.bin          test vectors: rust integration tests compare against
                        python-computed logits for seeded inputs
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile.model import DEFAULT_CONFIG, decode_step, init_params, prefill
from compile.kernels.attention import decode_attention_batched


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit(fn, example_args, path):
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    # Guard against silent constant elision: any '{...}' in the text means a
    # large constant got baked in and its values were dropped.
    assert "constant({...})" not in text.replace(" ", ""), (
        f"{path}: large constant elided — weights leaked into the graph"
    )
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path} ({len(text) / 1e6:.2f} MB)")
    return text


def flat_leaves(params):
    """Leaves with dotted names, in the exact order jax.jit flattens them."""
    paths = jax.tree_util.tree_flatten_with_path(params)[0]
    out = []
    for kp, leaf in paths:
        name = ".".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        out.append((name, np.asarray(leaf, np.float32)))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--seed", type=int, default=42)
    args = ap.parse_args()
    out = args.out_dir
    os.makedirs(out, exist_ok=True)

    cfg = DEFAULT_CONFIG
    params = init_params(cfg, args.seed)
    b, p, s = cfg.batch, cfg.prefill_len, cfg.max_seq
    l, h, dh = cfg.n_layers, cfg.n_heads, cfg.head_dim
    cache = jax.ShapeDtypeStruct((l, b, h, s, dh), jnp.float32)
    pshape = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)

    def prefill_fn(params, tokens):
        return prefill(params, cfg, tokens)

    def decode_fn(params, token, pos, k_cache, v_cache):
        return decode_step(params, cfg, token, pos, k_cache, v_cache)

    emit(prefill_fn,
         (pshape, jax.ShapeDtypeStruct((b, p), jnp.int32)),
         os.path.join(out, "prefill.hlo.txt"))

    emit(decode_fn,
         (pshape, jax.ShapeDtypeStruct((b,), jnp.int32),
          jax.ShapeDtypeStruct((), jnp.int32), cache, cache),
         os.path.join(out, "decode.hlo.txt"))

    emit(functools.partial(decode_attention_batched, block_s=cfg.kv_block),
         (jax.ShapeDtypeStruct((b, h, 1, dh), jnp.float32),
          jax.ShapeDtypeStruct((b, h, s, dh), jnp.float32),
          jax.ShapeDtypeStruct((b, h, s, dh), jnp.float32),
          jax.ShapeDtypeStruct((b, s), jnp.float32)),
         os.path.join(out, "attn_kernel.hlo.txt"))

    # --- weights in tree-flatten order (== jit parameter order) ---
    leaves = flat_leaves(params)
    with open(os.path.join(out, "weights.bin"), "wb") as f:
        for _, arr in leaves:
            f.write(arr.tobytes())
    total = sum(a.size for _, a in leaves)
    print(f"wrote weights.bin ({total} f32, {total * 4 / 1e6:.1f} MB, "
          f"{len(leaves)} leaves)")

    # --- golden vectors for the rust integration tests ---
    rng = np.random.RandomState(7)
    tokens = rng.randint(1, cfg.vocab, size=(b, p)).astype(np.int32)
    g_logits, kc, vc = jax.jit(prefill_fn)(params, tokens)
    nxt = jnp.argmax(g_logits, -1).astype(jnp.int32)
    d_logits, _, _ = jax.jit(decode_fn)(params, nxt, jnp.int32(p), kc, vc)
    np.asarray(tokens).tofile(os.path.join(out, "golden_tokens.bin"))
    np.asarray(g_logits, np.float32).tofile(
        os.path.join(out, "golden_prefill_logits.bin"))
    np.asarray(nxt, np.int32).tofile(os.path.join(out, "golden_next_token.bin"))
    np.asarray(d_logits, np.float32).tofile(
        os.path.join(out, "golden_decode_logits.bin"))
    print("wrote golden vectors")

    # --- manifests ---
    meta = {
        "vocab": cfg.vocab, "d_model": cfg.d_model, "n_heads": cfg.n_heads,
        "n_layers": cfg.n_layers, "d_ff": cfg.d_ff, "max_seq": cfg.max_seq,
        "prefill_len": cfg.prefill_len, "batch": cfg.batch,
        "kv_block": cfg.kv_block, "head_dim": cfg.head_dim, "seed": args.seed,
        "n_weights": len(leaves),
        "weights": [{"name": n, "numel": int(a.size),
                     "shape": list(a.shape)} for n, a in leaves],
    }
    with open(os.path.join(out, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2)
    with open(os.path.join(out, "meta.txt"), "w") as f:
        for k in ("vocab", "d_model", "n_heads", "n_layers", "d_ff",
                  "max_seq", "prefill_len", "batch", "kv_block", "head_dim",
                  "seed", "n_weights"):
            f.write(f"{k}={meta[k]}\n")
        for n, a in leaves:
            shape = ",".join(str(d) for d in a.shape)
            f.write(f"weight {n} {a.size} {shape}\n")
    print("wrote meta.txt / meta.json")


if __name__ == "__main__":
    main()
