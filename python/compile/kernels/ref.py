"""Pure-jnp correctness oracle for the Pallas decode-attention kernel."""

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k, v, bias):
    """Reference decode attention.

    q: (H, 1, D), k/v: (H, S, D), bias: (S,) additive -> (H, 1, D)
    """
    scale = 1.0 / (k.shape[-1] ** 0.5)
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("hqd,hkd->hqk", qf, kf) * scale + bias[None, None, :]
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("hqk,hkd->hqd", p, vf)
    return out.astype(q.dtype)


def decode_attention_ref_batched(q, k, v, bias):
    """q (B,H,1,D), k/v (B,H,S,D), bias (B,S) -> (B,H,1,D)."""
    return jax.vmap(decode_attention_ref)(q, k, v, bias)
