"""L1: Pallas blocked decode-attention kernel.

This is the compute hot-spot of the serving path: one query token attends
over a blocked KV cache. The KV blocking granularity (``block_s``) is the
SAME granularity at which the rust KV-cache manager offloads blocks to the
remote pool, so the HBM<->VMEM schedule expressed by the BlockSpec grid
mirrors HyperOffload's Remote<->Device block schedule (DESIGN.md §4).

Hardware adaptation (paper targets Ascend NPU tiles): we tile KV into
``(block_s, head_dim)`` VMEM-resident blocks via BlockSpec and run an
online-softmax (flash) accumulation across the sequential grid — the TPU
analogue of the paper's per-tile DMA prefetch pipeline. ``interpret=True``
is mandatory: real-TPU lowering emits a Mosaic custom-call the CPU PJRT
plugin cannot execute (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_attn_kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, m_ref, l_ref, *, scale):
    """One (head, kv-block) grid step of online-softmax decode attention.

    Shapes inside the kernel (leading head dim blocked to 1):
      q_ref:    (1, 1, D)   query for this head
      k_ref:    (1, B, D)   one KV block
      v_ref:    (1, B, D)
      bias_ref: (B,)        additive mask (0 or -inf) for this block
      o_ref:    (1, 1, D)   output accumulator (revisited across blocks)
      m_ref:    (1, 1)      running max     (revisited)
      l_ref:    (1, 1)      running denom   (revisited)
    """
    blk = pl.program_id(1)

    q = q_ref[0].astype(jnp.float32)          # (1, D)
    k = k_ref[0].astype(jnp.float32)          # (B, D)
    v = v_ref[0].astype(jnp.float32)          # (B, D)
    bias = bias_ref[...].astype(jnp.float32)  # (B,)

    # scores for this block: (1, B)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale + bias[None, :]

    @pl.when(blk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        o_ref[...] = jnp.zeros_like(o_ref)

    m_prev = m_ref[...]                        # (1, 1)
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)  # (1, 1)
    m_new = jnp.maximum(m_prev, m_cur)

    p = jnp.exp(s - m_new)                     # (1, B)
    alpha = jnp.exp(m_prev - m_new)            # (1, 1)

    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc = o_ref[0].astype(jnp.float32) * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    m_ref[...] = m_new
    l_ref[...] = l_new
    o_ref[0] = acc.astype(o_ref.dtype)

    # Final block: normalise.
    @pl.when(blk == pl.num_programs(1) - 1)
    def _finalize():
        o_ref[0] = (o_ref[0].astype(jnp.float32) / l_ref[...]).astype(o_ref.dtype)


def decode_attention(q, k, v, bias, *, block_s=32):
    """Blocked decode attention for a single sequence.

    Args:
      q:    (H, 1, D) query for the current token.
      k:    (H, S, D) key cache (padded to max seq S).
      v:    (H, S, D) value cache.
      bias: (S,) additive mask, 0 for valid positions, -inf for padding.
      block_s: KV block size; must divide S. This is the offload granule.

    Returns:
      (H, 1, D) attention output.
    """
    h, s, d = k.shape
    assert s % block_s == 0, f"S={s} not divisible by block_s={block_s}"
    nblk = s // block_s
    scale = 1.0 / (d ** 0.5)

    grid = (h, nblk)
    out, _, _ = pl.pallas_call(
        functools.partial(_decode_attn_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda hh, bb: (hh, 0, 0)),
            pl.BlockSpec((1, block_s, d), lambda hh, bb: (hh, bb, 0)),
            pl.BlockSpec((1, block_s, d), lambda hh, bb: (hh, bb, 0)),
            pl.BlockSpec((block_s,), lambda hh, bb: (bb,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, d), lambda hh, bb: (hh, 0, 0)),
            pl.BlockSpec((1, 1), lambda hh, bb: (hh, 0)),
            pl.BlockSpec((1, 1), lambda hh, bb: (hh, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, 1, d), q.dtype),
            jax.ShapeDtypeStruct((h, 1), jnp.float32),
            jax.ShapeDtypeStruct((h, 1), jnp.float32),
        ],
        interpret=True,
    )(q, k, v, bias)
    return out


def decode_attention_batched(q, k, v, bias, *, block_s=32):
    """vmap over batch: q (B,H,1,D), k/v (B,H,S,D), bias (B,S) -> (B,H,1,D)."""
    return jax.vmap(
        functools.partial(decode_attention, block_s=block_s)
    )(q, k, v, bias)
