"""L2: tiny transformer (prefill + decode step) in JAX, calling the L1 kernel.

The model is the real-compute substrate for the end-to-end serving example:
rust loads the lowered HLO and drives batched autoregressive decoding while
the HyperOffload coordinator manages KV-block residency. Weights are seeded
and baked into the HLO as constants so the artifact is self-contained (the
rust side passes only tokens / position / caches).

Architecture: pre-RMSNorm decoder, MHA with the Pallas blocked decode
attention kernel on the decode path, SiLU MLP. All shapes static for AOT.
"""

import dataclasses

import jax
import jax.numpy as jnp

from compile.kernels.attention import decode_attention_batched


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab: int = 512
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 4
    d_ff: int = 512
    max_seq: int = 128       # S: KV cache capacity (padded)
    prefill_len: int = 32    # P: static prompt length
    batch: int = 4           # B: static batch for the AOT executable
    kv_block: int = 32       # Pallas KV block == offload granule

    @property
    def head_dim(self):
        return self.d_model // self.n_heads


DEFAULT_CONFIG = ModelConfig()


def init_params(cfg: ModelConfig, seed: int = 42):
    """Seeded parameter pytree (dict of arrays)."""
    keys = jax.random.split(jax.random.PRNGKey(seed), 2 + cfg.n_layers)
    scale = 0.02
    params = {
        "embed": jax.random.normal(keys[0], (cfg.vocab, cfg.d_model)) * scale,
        "unembed": jax.random.normal(keys[1], (cfg.d_model, cfg.vocab)) * scale,
        "final_norm": jnp.ones((cfg.d_model,)),
        "layers": [],
    }
    for i in range(cfg.n_layers):
        lk = jax.random.split(keys[2 + i], 7)
        d, f = cfg.d_model, cfg.d_ff
        params["layers"].append({
            "attn_norm": jnp.ones((d,)),
            "wq": jax.random.normal(lk[0], (d, d)) * scale,
            "wk": jax.random.normal(lk[1], (d, d)) * scale,
            "wv": jax.random.normal(lk[2], (d, d)) * scale,
            "wo": jax.random.normal(lk[3], (d, d)) * scale,
            "mlp_norm": jnp.ones((d,)),
            "w_up": jax.random.normal(lk[4], (d, f)) * scale,
            "w_gate": jax.random.normal(lk[5], (d, f)) * scale,
            "w_down": jax.random.normal(lk[6], (f, d)) * scale,
        })
    return params


def _rms_norm(x, w, eps=1e-6):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def _split_heads(x, cfg):
    # (B, T, d) -> (B, H, T, Dh)
    b, t, _ = x.shape
    return x.reshape(b, t, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)


def _merge_heads(x):
    # (B, H, T, Dh) -> (B, T, d)
    b, h, t, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, h * dh)


def _mlp(x, lp):
    return (jax.nn.silu(x @ lp["w_gate"]) * (x @ lp["w_up"])) @ lp["w_down"]


def prefill(params, cfg: ModelConfig, tokens):
    """Process a padded prompt, build KV caches.

    tokens: (B, P) int32 (pad id 0; full P positions are attended causally —
    the serving layer pads prompts and tracks true lengths itself).
    Returns (logits[B, V] for the last position, k_cache, v_cache) where
    caches are (L, B, H, S, Dh), positions >= P zero-filled.
    """
    b, p = tokens.shape
    s = cfg.max_seq
    x = params["embed"][tokens]  # (B, P, d)

    causal = jnp.tril(jnp.ones((p, p), jnp.float32))
    mask = jnp.where(causal == 1.0, 0.0, -1e30)

    k_cache = jnp.zeros((cfg.n_layers, b, cfg.n_heads, s, cfg.head_dim), jnp.float32)
    v_cache = jnp.zeros_like(k_cache)

    scale = 1.0 / (cfg.head_dim ** 0.5)
    for li, lp in enumerate(params["layers"]):
        h = _rms_norm(x, lp["attn_norm"])
        q = _split_heads(h @ lp["wq"], cfg)   # (B, H, P, Dh)
        k = _split_heads(h @ lp["wk"], cfg)
        v = _split_heads(h @ lp["wv"], cfg)

        k_cache = jax.lax.dynamic_update_slice(k_cache, k[None], (li, 0, 0, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, v[None], (li, 0, 0, 0, 0))

        sc = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale + mask[None, None]
        pr = jax.nn.softmax(sc, axis=-1)
        att = jnp.einsum("bhqk,bhkd->bhqd", pr, v)
        x = x + _merge_heads(att) @ lp["wo"]
        x = x + _mlp(_rms_norm(x, lp["mlp_norm"]), lp)

    x = _rms_norm(x, params["final_norm"])
    logits = x[:, -1, :] @ params["unembed"]  # (B, V)
    return logits, k_cache, v_cache


def decode_step(params, cfg: ModelConfig, token, pos, k_cache, v_cache):
    """One autoregressive decode step over blocked KV caches.

    token: (B,) int32 current tokens; pos: () int32 write position (same for
    the whole batch — the serving layer aligns batches); caches (L,B,H,S,Dh).
    Returns (logits[B, V], k_cache', v_cache').
    """
    b = token.shape[0]
    s = cfg.max_seq
    x = params["embed"][token][:, None, :]  # (B, 1, d)

    # Valid keys are 0..pos inclusive (the new token's k/v is written at pos).
    bias = jnp.where(jnp.arange(s) <= pos, 0.0, -1e30).astype(jnp.float32)
    bias_b = jnp.broadcast_to(bias, (b, s))

    for li, lp in enumerate(params["layers"]):
        h = _rms_norm(x, lp["attn_norm"])
        q = _split_heads(h @ lp["wq"], cfg)   # (B, H, 1, Dh)
        k = _split_heads(h @ lp["wk"], cfg)   # (B, H, 1, Dh)
        v = _split_heads(h @ lp["wv"], cfg)

        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k[None], (li, 0, 0, pos, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v[None], (li, 0, 0, pos, 0))

        # L1 Pallas kernel over the blocked KV cache.
        att = decode_attention_batched(
            q, k_cache[li], v_cache[li], bias_b, block_s=cfg.kv_block)
        x = x + _merge_heads(att) @ lp["wo"]
        x = x + _mlp(_rms_norm(x, lp["mlp_norm"]), lp)

    x = _rms_norm(x, params["final_norm"])
    logits = x[:, 0, :] @ params["unembed"]  # (B, V)
    return logits, k_cache, v_cache


def decode_step_ref(params, cfg: ModelConfig, token, pos, k_cache, v_cache):
    """decode_step with the pure-jnp attention oracle (for pytest)."""
    from compile.kernels.ref import decode_attention_ref_batched

    b = token.shape[0]
    s = cfg.max_seq
    x = params["embed"][token][:, None, :]
    bias = jnp.where(jnp.arange(s) <= pos, 0.0, -1e30).astype(jnp.float32)
    bias_b = jnp.broadcast_to(bias, (b, s))
    for li, lp in enumerate(params["layers"]):
        h = _rms_norm(x, lp["attn_norm"])
        q = _split_heads(h @ lp["wq"], cfg)
        k = _split_heads(h @ lp["wk"], cfg)
        v = _split_heads(h @ lp["wv"], cfg)
        k_cache = jax.lax.dynamic_update_slice(k_cache, k[None], (li, 0, 0, pos, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, v[None], (li, 0, 0, pos, 0))
        att = decode_attention_ref_batched(q, k_cache[li], v_cache[li], bias_b)
        x = x + _merge_heads(att) @ lp["wo"]
        x = x + _mlp(_rms_norm(x, lp["mlp_norm"]), lp)
    x = _rms_norm(x, params["final_norm"])
    return x[:, 0, :] @ params["unembed"], k_cache, v_cache


def make_jit_fns(cfg: ModelConfig = DEFAULT_CONFIG, seed: int = 42):
    """Return (prefill_fn, decode_fn, params) with params baked by closure.

    Closing over params bakes the weights into the lowered HLO as constants:
    the artifact is self-contained and rust never handles weight tensors.
    """
    params = init_params(cfg, seed)

    def prefill_fn(tokens):
        return prefill(params, cfg, tokens)

    def decode_fn(token, pos, k_cache, v_cache):
        return decode_step(params, cfg, token, pos, k_cache, v_cache)

    return prefill_fn, decode_fn, params
