"""Kernel vs ref allclose — the CORE correctness signal for L1.

Hypothesis sweeps shapes/dtypes per the project test policy.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import decode_attention, decode_attention_batched
from compile.kernels.ref import decode_attention_ref, decode_attention_ref_batched


def _mk(h, s, d, seed, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (h, 1, d), dtype)
    k = jax.random.normal(ks[1], (h, s, d), dtype)
    v = jax.random.normal(ks[2], (h, s, d), dtype)
    return q, k, v


def _bias(s, length):
    return jnp.where(jnp.arange(s) < length, 0.0, -1e30).astype(jnp.float32)


class TestDecodeAttentionBasic:
    def test_matches_ref_full_length(self):
        q, k, v = _mk(4, 128, 32, 0)
        bias = _bias(128, 128)
        out = decode_attention(q, k, v, bias)
        ref = decode_attention_ref(q, k, v, bias)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)

    def test_matches_ref_partial_length(self):
        q, k, v = _mk(4, 128, 32, 1)
        bias = _bias(128, 77)
        np.testing.assert_allclose(
            decode_attention(q, k, v, bias),
            decode_attention_ref(q, k, v, bias),
            rtol=2e-5, atol=2e-5)

    def test_single_valid_position(self):
        """length=1: attention must return exactly v[:, 0]."""
        q, k, v = _mk(2, 64, 16, 2)
        bias = _bias(64, 1)
        out = decode_attention(q, k, v, bias)
        np.testing.assert_allclose(out[:, 0], v[:, 0], rtol=1e-5, atol=1e-5)

    def test_length_at_block_boundary(self):
        q, k, v = _mk(2, 128, 32, 3)
        for length in (32, 64, 96):
            bias = _bias(128, length)
            np.testing.assert_allclose(
                decode_attention(q, k, v, bias),
                decode_attention_ref(q, k, v, bias),
                rtol=2e-5, atol=2e-5)

    def test_block_size_invariance(self):
        """Result must not depend on the offload granule."""
        q, k, v = _mk(4, 128, 32, 4)
        bias = _bias(128, 100)
        outs = [decode_attention(q, k, v, bias, block_s=bs) for bs in (16, 32, 64, 128)]
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], rtol=2e-5, atol=2e-5)

    def test_rejects_indivisible_block(self):
        q, k, v = _mk(2, 100, 16, 5)
        with pytest.raises(AssertionError):
            decode_attention(q, k, v, _bias(100, 50), block_s=32)

    def test_softmax_scale_invariance_shift(self):
        """Adding a constant to all scores must not change the output."""
        q, k, v = _mk(2, 64, 16, 6)
        bias0 = _bias(64, 64)
        out0 = decode_attention(q, k, v, bias0)
        out1 = decode_attention(q, k, v, bias0 + 3.0)
        np.testing.assert_allclose(out0, out1, rtol=1e-4, atol=1e-4)

    def test_batched_matches_per_sequence(self):
        b, h, s, d = 3, 4, 64, 16
        ks = jax.random.split(jax.random.PRNGKey(7), 3)
        q = jax.random.normal(ks[0], (b, h, 1, d))
        k = jax.random.normal(ks[1], (b, h, s, d))
        v = jax.random.normal(ks[2], (b, h, s, d))
        bias = jnp.stack([_bias(s, 10 * (i + 1)) for i in range(b)])
        out = decode_attention_batched(q, k, v, bias)
        for i in range(b):
            np.testing.assert_allclose(
                out[i], decode_attention(q[i], k[i], v[i], bias[i]),
                rtol=2e-5, atol=2e-5)


class TestDecodeAttentionHypothesis:
    @settings(max_examples=20, deadline=None)
    @given(
        h=st.sampled_from([1, 2, 4, 8]),
        nblk=st.integers(1, 4),
        d=st.sampled_from([8, 16, 32, 64]),
        block_s=st.sampled_from([16, 32]),
        length_frac=st.floats(0.05, 1.0),
        seed=st.integers(0, 2**16),
    )
    def test_shapes_sweep(self, h, nblk, d, block_s, length_frac, seed):
        s = nblk * block_s
        length = max(1, int(s * length_frac))
        q, k, v = _mk(h, s, d, seed)
        bias = _bias(s, length)
        out = decode_attention(q, k, v, bias, block_s=block_s)
        ref = decode_attention_ref(q, k, v, bias)
        np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)

    @settings(max_examples=8, deadline=None)
    @given(
        dtype=st.sampled_from(["float32", "bfloat16"]),
        seed=st.integers(0, 2**16),
    )
    def test_dtype_sweep(self, dtype, seed):
        dt = jnp.dtype(dtype)
        q, k, v = _mk(2, 64, 16, seed, dt)
        bias = _bias(64, 50)
        out = decode_attention(q, k, v, bias)
        ref = decode_attention_ref(q, k, v, bias)
        tol = 5e-2 if dtype == "bfloat16" else 3e-5
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            rtol=tol, atol=tol)
        assert out.dtype == dt

    @settings(max_examples=10, deadline=None)
    @given(
        scale=st.floats(0.01, 30.0),
        seed=st.integers(0, 2**16),
    )
    def test_magnitude_sweep_numerical_stability(self, scale, seed):
        """Online softmax must stay stable across score magnitudes."""
        q, k, v = _mk(2, 64, 16, seed)
        q = q * scale
        bias = _bias(64, 64)
        out = decode_attention(q, k, v, bias)
        ref = decode_attention_ref(q, k, v, bias)
        assert np.isfinite(np.asarray(out)).all()
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)
