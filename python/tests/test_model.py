"""L2 model tests: shapes, decode-vs-ref, prefill/decode cache consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    DEFAULT_CONFIG, ModelConfig, decode_step, decode_step_ref,
    init_params, prefill,
)

CFG = ModelConfig(vocab=64, d_model=32, n_heads=2, n_layers=2, d_ff=64,
                  max_seq=64, prefill_len=8, batch=2, kv_block=16)


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, seed=0)


class TestShapes:
    def test_prefill_shapes(self, params):
        tokens = jnp.zeros((CFG.batch, CFG.prefill_len), jnp.int32)
        logits, kc, vc = prefill(params, CFG, tokens)
        assert logits.shape == (CFG.batch, CFG.vocab)
        assert kc.shape == (CFG.n_layers, CFG.batch, CFG.n_heads, CFG.max_seq, CFG.head_dim)
        assert vc.shape == kc.shape

    def test_decode_shapes(self, params):
        cache = jnp.zeros((CFG.n_layers, CFG.batch, CFG.n_heads, CFG.max_seq, CFG.head_dim))
        tok = jnp.zeros((CFG.batch,), jnp.int32)
        logits, kc, vc = decode_step(params, CFG, tok, jnp.int32(0), cache, cache)
        assert logits.shape == (CFG.batch, CFG.vocab)
        assert kc.shape == cache.shape


class TestCorrectness:
    def test_decode_matches_ref(self, params):
        """Pallas decode path == pure-jnp oracle path end-to-end."""
        cache = jax.random.normal(
            jax.random.PRNGKey(1),
            (CFG.n_layers, CFG.batch, CFG.n_heads, CFG.max_seq, CFG.head_dim)) * 0.1
        tok = jnp.array([3, 7], jnp.int32)
        pos = jnp.int32(10)
        lo, ko, vo = decode_step(params, CFG, tok, pos, cache, cache)
        lr, kr, vr = decode_step_ref(params, CFG, tok, pos, cache, cache)
        np.testing.assert_allclose(lo, lr, rtol=5e-5, atol=5e-5)
        np.testing.assert_allclose(ko, kr, rtol=5e-5, atol=5e-5)
        np.testing.assert_allclose(vo, vr, rtol=5e-5, atol=5e-5)

    def test_decode_writes_cache_at_pos(self, params):
        cache = jnp.zeros((CFG.n_layers, CFG.batch, CFG.n_heads, CFG.max_seq, CFG.head_dim))
        tok = jnp.array([5, 9], jnp.int32)
        pos = 7
        _, kc, vc = decode_step(params, CFG, tok, jnp.int32(pos), cache, cache)
        # Written exactly at pos, zero elsewhere.
        assert float(jnp.abs(kc[:, :, :, pos]).sum()) > 0
        mask = jnp.ones(CFG.max_seq, bool).at[pos].set(False)
        assert float(jnp.abs(kc[:, :, :, mask]).sum()) == 0.0

    def test_prefill_then_decode_consistent_with_full_prefill(self, params):
        """Decoding token t after prefill(0..t-1) must match prefilling 0..t
        (greedy continuation consistency)."""
        p = CFG.prefill_len
        tokens = jax.random.randint(jax.random.PRNGKey(2), (CFG.batch, p), 0, CFG.vocab)
        logits_a, kc, vc = prefill(params, CFG, tokens)
        nxt = jnp.argmax(logits_a, -1).astype(jnp.int32)
        logits_b, _, _ = decode_step(params, CFG, nxt, jnp.int32(p), kc, vc)

        # Full prefill over p+1 tokens (config with longer prefill_len).
        tokens2 = jnp.concatenate([tokens, nxt[:, None]], axis=1)
        logits_c, _, _ = prefill(params, CFG, tokens2)
        np.testing.assert_allclose(logits_b, logits_c, rtol=1e-4, atol=1e-4)

    def test_decode_deterministic(self, params):
        cache = jnp.zeros((CFG.n_layers, CFG.batch, CFG.n_heads, CFG.max_seq, CFG.head_dim))
        tok = jnp.array([1, 2], jnp.int32)
        l1, _, _ = decode_step(params, CFG, tok, jnp.int32(0), cache, cache)
        l2, _, _ = decode_step(params, CFG, tok, jnp.int32(0), cache, cache)
        np.testing.assert_array_equal(l1, l2)

    def test_params_seeded_reproducible(self):
        p1 = init_params(CFG, seed=123)
        p2 = init_params(CFG, seed=123)
        np.testing.assert_array_equal(p1["embed"], p2["embed"])
        p3 = init_params(CFG, seed=124)
        assert not np.array_equal(p1["embed"], p3["embed"])


class TestAotLowering:
    def test_decode_lowers_to_hlo_text(self):
        """The exact artifact path: jit -> stablehlo -> XlaComputation -> text."""
        from compile.aot import to_hlo_text
        from compile.model import make_jit_fns

        cfg = CFG
        _, decode_fn, _ = make_jit_fns(cfg, seed=0)
        cache = jax.ShapeDtypeStruct(
            (cfg.n_layers, cfg.batch, cfg.n_heads, cfg.max_seq, cfg.head_dim), jnp.float32)
        lowered = jax.jit(decode_fn).lower(
            jax.ShapeDtypeStruct((cfg.batch,), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32), cache, cache)
        text = to_hlo_text(lowered)
        assert "HloModule" in text
        assert "ROOT" in text
