//! Discrete-event execution simulator.
//!
//! Models the SuperNode device as in-order streams — compute, DMA-in
//! (R2D), DMA-out (D2R), network, a host stream for CPU control work, and
//! a cold-DMA stream for non-device tier moves (`Promote`) — executing a
//! graph in a given total order (list scheduling): an op starts when its
//! stream is free AND all dependency predecessors have finished. Produces
//! the timeline quantities the paper's figures report: makespan, exposed
//! vs overlapped communication, peak device residency — and, when the
//! `HwConfig` carries a `TierTopology`, per-tier residency peaks for the
//! cold levels below the pool.

use std::collections::HashMap;

use crate::graph::{Graph, OpId, OpKind, Tier};

use super::hw::HwConfig;

/// Execution stream an op occupies.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Stream {
    Compute,
    DmaIn,
    DmaOut,
    Net,
    Host,
    /// Moves between non-device tiers (promotion/demotion). A separate
    /// engine from the device DMA pair: the pool↔DRAM↔SSD fabric does not
    /// contend with the device links.
    ColdDma,
}

pub fn stream_of(kind: &OpKind) -> Stream {
    match kind {
        OpKind::Compute { .. } => Stream::Compute,
        OpKind::Prefetch { .. } => Stream::DmaIn,
        OpKind::Store { .. } => Stream::DmaOut,
        OpKind::Detach { .. } => Stream::Host, // bookkeeping, ~free
        OpKind::Promote { .. } => Stream::ColdDma,
        OpKind::Collective { .. } => Stream::Net,
        OpKind::HostWork { .. } => Stream::Host,
    }
}

/// Duration of `kind` on `hw` in microseconds. Transfers cost the fabric
/// edge(s) between their explicit tiers; without a `TierTopology` this is
/// exactly the legacy pool-link formula.
pub fn duration_us(kind: &OpKind, g: &Graph, hw: &HwConfig) -> f64 {
    match kind {
        OpKind::Compute { flops, bytes_accessed } => hw.compute_us(*flops, *bytes_accessed),
        OpKind::Prefetch { tensor, src } => hw.fetch_us(*src, g.tensor(*tensor).bytes),
        OpKind::Store { tensor, dst } => hw.evict_us(*dst, g.tensor(*tensor).bytes),
        OpKind::Detach { .. } => 0.0,
        OpKind::Promote { tensor, src, dst } => hw.promote_us(*src, *dst, g.tensor(*tensor).bytes),
        OpKind::Collective { bytes } => hw.net_us(*bytes),
        OpKind::HostWork { us } => *us,
    }
}

/// Per-op interval in the simulated timeline.
#[derive(Debug, Clone, Copy)]
pub struct Interval {
    pub op: OpId,
    pub start_us: f64,
    pub finish_us: f64,
    pub stream: Stream,
}

/// Simulation output: everything the paper's tables/figures need.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub makespan_us: f64,
    /// Busy time of the compute stream.
    pub compute_busy_us: f64,
    /// Portion of `compute_busy_us` spent replaying recompute clones
    /// (ops carrying [`Op::recompute`](crate::graph::Op::recompute)) — the
    /// compute the recompute-vs-offload pass trades against transfers.
    pub recompute_us: f64,
    /// Compute-stream stall time attributable to waiting on DMA transfers
    /// ("exposed communication" in Fig. 6).
    pub exposed_comm_us: f64,
    /// DMA busy time that ran under compute ("overlapped communication").
    pub overlapped_comm_us: f64,
    /// Total DMA (prefetch+store) busy time.
    pub dma_busy_us: f64,
    /// Total bytes moved across the device boundary (Prefetch + Store) —
    /// the fabric-traffic quantity `ElideRedundantTransfers` minimises.
    pub dma_bytes: u64,
    /// Peak device-memory residency (bytes).
    pub peak_device_bytes: u64,
    /// (time_us, resident_bytes) residency curve, one point per change.
    pub residency: Vec<(f64, u64)>,
    /// Peak residency per *non-device* tier, in topology (hot → cold)
    /// order. Empty when the `HwConfig` carries no `TierTopology` — the
    /// legacy two-home accounting is unchanged.
    pub tier_peaks: Vec<(Tier, u64)>,
    /// Bytes moved between non-device tiers (`Promote` traffic). Not part
    /// of `dma_bytes`, which counts device-boundary transfers only.
    pub cold_dma_bytes: u64,
    pub intervals: Vec<Interval>,
}

impl SimResult {
    /// Fraction of DMA time hidden under compute.
    pub fn overlap_efficiency(&self) -> f64 {
        if self.dma_busy_us <= 0.0 {
            1.0
        } else {
            (self.overlapped_comm_us / self.dma_busy_us).clamp(0.0, 1.0)
        }
    }

    /// Integral of the device residency curve (byte·us): the quantity the
    /// too-early-prefetch pattern of Fig. 4(b) inflates even when the peak
    /// is unchanged.
    pub fn residency_byte_time(&self) -> f64 {
        let mut acc = 0.0;
        for w in self.residency.windows(2) {
            acc += w[0].1 as f64 * (w[1].0 - w[0].0);
        }
        acc
    }
}

/// Simulate `graph` executed in `order` on `hw`.
///
/// `order` must be a valid topological order (checked in debug builds).
pub fn simulate(graph: &Graph, order: &[OpId], hw: &HwConfig) -> SimResult {
    debug_assert!(graph.is_valid_order(order), "simulate: invalid execution order");

    let n = graph.ops.len();
    let mut finish = vec![0.0f64; n];
    let mut start = vec![0.0f64; n];
    let mut stream_free: HashMap<Stream, f64> = HashMap::new();
    let mut intervals = Vec::with_capacity(n);

    // --- residency bookkeeping -------------------------------------------
    // A tensor occupies device memory from `alloc_time` until its free
    // event. Graph-input tensors homed on device are resident from t=0.
    // Compute outputs alloc at op start. Prefetch allocs at transfer start.
    // Store frees at completion; Detach frees immediately; device-home
    // tensors with no cache ops free after their last consumer (static
    // memory planning, §3.2 "predictable memory management").
    let mut mem_events: Vec<(f64, i64)> = Vec::new(); // (time, +bytes/-bytes)
    let mut last_use: HashMap<usize, OpId> = HashMap::new();
    let mut pos = vec![usize::MAX; n];
    for (i, &o) in order.iter().enumerate() {
        pos[o] = i;
    }
    for t in &graph.tensors {
        let mut consumers: Vec<OpId> = graph.consumers_of(t.id).to_vec();
        consumers.retain(|&c| pos[c] != usize::MAX);
        if let Some(&last) = consumers.iter().max_by_key(|&&c| pos[c]) {
            last_use.insert(t.id, last);
        }
    }
    // Last Store/Detach position per tensor: a cache op frees the device
    // copy, but if the tensor is prefetched back and consumed *after* its
    // last Store, the static planner frees it after that last consumer.
    let mut last_cache_free_pos: HashMap<usize, usize> = HashMap::new();
    for op in &graph.ops {
        if let OpKind::Store { tensor, .. } | OpKind::Detach { tensor } = op.kind {
            if pos[op.id] != usize::MAX {
                let e = last_cache_free_pos.entry(tensor).or_insert(0);
                *e = (*e).max(pos[op.id]);
            }
        }
    }
    // Device-home graph inputs (no producer): resident from t=0. Chunk
    // views are excluded — their storage is the parent's bytes, already
    // counted through the parent; a chunk's own Store/Prefetch events are
    // the *partial* release/restore of that storage.
    for t in &graph.tensors {
        if t.home == Tier::Device && graph.producer_of(t.id).is_none() && t.alias_of.is_none() {
            mem_events.push((0.0, t.bytes as i64));
        }
    }

    // --- per-tier (non-device) residency, only under a TierTopology -----
    // Copy semantics on the cold side mirror the pool: a Store materialises
    // a copy at its destination tier, a Prefetch reads without consuming
    // it, and a Promote *moves* the copy (destination reserved at start,
    // source released at completion). Non-device-home graph inputs are
    // resident in their home tier from t=0.
    let topo = hw.tiers.as_ref();
    let mut tier_events: Vec<Vec<(f64, i64)>> = match topo {
        Some(t) => vec![Vec::new(); t.tiers.len()],
        None => Vec::new(),
    };
    if let Some(t) = topo {
        for tn in &graph.tensors {
            if tn.home != Tier::Device
                && tn.alias_of.is_none()
                && graph.producer_of(tn.id).is_none()
            {
                if let Some(i) = t.index_of(tn.home) {
                    tier_events[i].push((0.0, tn.bytes as i64));
                }
            }
        }
    }

    // --- list scheduling ---------------------------------------------------
    let mut dma_bytes = 0u64;
    let mut cold_dma_bytes = 0u64;
    for &op_id in order {
        let op = graph.op(op_id);
        let stream = stream_of(&op.kind);
        let dur = duration_us(&op.kind, graph, hw);
        let dep_ready = graph
            .preds(op_id)
            .iter()
            .map(|&p| finish[p])
            .fold(0.0f64, f64::max);
        let s = dep_ready.max(*stream_free.get(&stream).unwrap_or(&0.0));
        let f = s + dur;
        start[op_id] = s;
        finish[op_id] = f;
        stream_free.insert(stream, f);
        intervals.push(Interval { op: op_id, start_us: s, finish_us: f, stream });

        match op.kind {
            OpKind::Compute { .. } => {
                for &t in &op.outputs {
                    if graph.tensor(t).home == Tier::Device {
                        mem_events.push((s, graph.tensor(t).bytes as i64));
                    }
                }
            }
            OpKind::Prefetch { tensor, .. } => {
                // Destination reserved at transfer start. The source-tier
                // copy persists (pool copy semantics).
                mem_events.push((s, graph.tensor(tensor).bytes as i64));
                dma_bytes += graph.tensor(tensor).bytes;
            }
            OpKind::Store { tensor, dst } => {
                // Device copy released once the transfer completes; the
                // destination tier gains a copy at the same instant.
                mem_events.push((f, -(graph.tensor(tensor).bytes as i64)));
                dma_bytes += graph.tensor(tensor).bytes;
                if let Some(t) = topo {
                    if let Some(i) = t.index_of(dst) {
                        tier_events[i].push((f, graph.tensor(tensor).bytes as i64));
                    }
                }
            }
            OpKind::Detach { tensor } => {
                mem_events.push((f, -(graph.tensor(tensor).bytes as i64)));
            }
            OpKind::Promote { tensor, src, dst } => {
                // A move, not a copy: destination reserved up front, source
                // released when the transfer lands. No device-side event.
                cold_dma_bytes += graph.tensor(tensor).bytes;
                if let Some(t) = topo {
                    if let Some(i) = t.index_of(dst) {
                        tier_events[i].push((s, graph.tensor(tensor).bytes as i64));
                    }
                    if let Some(i) = t.index_of(src) {
                        tier_events[i].push((f, -(graph.tensor(tensor).bytes as i64)));
                    }
                }
            }
            _ => {}
        }
    }

    // Refcount frees: after the last consumer, unless a later cache op
    // owns the free. Remote-home tensors are freed too once prefetched in
    // (their device copy exists only between Prefetch and last use).
    // Device-home chunk views get NO refcount free of their own: the
    // parent's lifetime owns the allocation, and the chunk's Store/Prefetch
    // pair nets to zero inside it (partial-tensor residency).
    for t in &graph.tensors {
        if t.alias_of.is_some() && t.home == Tier::Device {
            continue;
        }
        let Some(&last) = last_use.get(&t.id) else { continue };
        let has_device_copy = t.home == Tier::Device
            || graph.ops.iter().any(
                |o| matches!(o.kind, OpKind::Prefetch { tensor, .. } if tensor == t.id),
            );
        if !has_device_copy {
            continue;
        }
        if let Some(&cp) = last_cache_free_pos.get(&t.id) {
            if cp >= pos[last] {
                continue; // the trailing Store/Detach performs the free
            }
        }
        mem_events.push((finish[last], -(t.bytes as i64)));
        // Tensors never consumed (graph outputs) stay resident to the end.
    }

    // --- aggregate ----------------------------------------------------------
    let makespan = finish.iter().copied().fold(0.0f64, f64::max);
    let compute_busy: f64 = intervals
        .iter()
        .filter(|iv| iv.stream == Stream::Compute)
        .map(|iv| iv.finish_us - iv.start_us)
        .sum();
    let recompute_busy: f64 = intervals
        .iter()
        .filter(|iv| iv.stream == Stream::Compute && graph.op(iv.op).recompute)
        .map(|iv| iv.finish_us - iv.start_us)
        .sum();
    let dma_busy: f64 = intervals
        .iter()
        .filter(|iv| matches!(iv.stream, Stream::DmaIn | Stream::DmaOut))
        .map(|iv| iv.finish_us - iv.start_us)
        .sum();

    // Exposed communication: for each compute op, the gap behind the
    // previous compute op that is closed by a DMA dependency finishing.
    let mut exposed = 0.0f64;
    let mut prev_compute_finish = 0.0f64;
    for &op_id in order {
        let op = graph.op(op_id);
        if stream_of(&op.kind) != Stream::Compute {
            continue;
        }
        let gap_start = prev_compute_finish;
        let s = start[op_id];
        if s > gap_start {
            // Which dependency pushed us here?
            let dma_ready = graph
                .preds(op_id)
                .iter()
                .filter(|&&p| matches!(stream_of(&graph.op(p).kind), Stream::DmaIn | Stream::DmaOut))
                .map(|&p| finish[p])
                .fold(0.0f64, f64::max);
            exposed += (dma_ready.min(s) - gap_start).max(0.0);
        }
        prev_compute_finish = finish[op_id];
    }
    let overlapped = (dma_busy - exposed).max(0.0);

    // Residency curve. At equal timestamps frees apply before allocs
    // (static memory planning reuses the slot within the same instant).
    mem_events.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    let mut cur: i64 = 0;
    let mut peak: i64 = 0;
    let mut residency = Vec::with_capacity(mem_events.len());
    for (t, d) in mem_events {
        cur += d;
        peak = peak.max(cur);
        residency.push((t, cur.max(0) as u64));
    }

    // Per-tier peaks (non-device levels), same free-before-alloc tie rule.
    let mut tier_peaks = Vec::new();
    if let Some(t) = topo {
        for (i, tier) in t.tiers.iter().enumerate().skip(1) {
            let mut ev = std::mem::take(&mut tier_events[i]);
            ev.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
            let mut cur: i64 = 0;
            let mut peak: i64 = 0;
            for (_, d) in ev {
                cur += d;
                peak = peak.max(cur);
            }
            tier_peaks.push((*tier, peak.max(0) as u64));
        }
    }

    SimResult {
        makespan_us: makespan,
        compute_busy_us: compute_busy,
        recompute_us: recompute_busy,
        exposed_comm_us: exposed,
        overlapped_comm_us: overlapped,
        dma_busy_us: dma_busy,
        dma_bytes,
        peak_device_bytes: peak.max(0) as u64,
        residency,
        tier_peaks,
        cold_dma_bytes,
        intervals,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    // 1 TFLOP/s -> 1e6 flops = 1 us; 1 GB/s -> 1 KB = 1 us.
    fn hw() -> HwConfig {
        HwConfig::test_default()
    }

    #[test]
    fn serial_chain_sums_durations() {
        let g = GraphBuilder::linear_chain(4, 1e6, 0);
        let order = g.topo_order().unwrap();
        let r = simulate(&g, &order, &hw());
        assert!((r.makespan_us - 4.0).abs() < 1e-9);
        assert!((r.compute_busy_us - 4.0).abs() < 1e-9);
        assert_eq!(r.exposed_comm_us, 0.0);
    }

    #[test]
    fn prefetch_overlaps_with_compute() {
        // c0 (5us) ; prefetch w (3us, independent) ; c1 consumes w.
        let mut b = GraphBuilder::new();
        let w = b.tensor("w", 3000, crate::graph::Tier::Remote); // 3 us at 1 GB/s
        let a0 = b.tensor("a0", 0, crate::graph::Tier::Device);
        let a1 = b.tensor("a1", 0, crate::graph::Tier::Device);
        let pf = b.prefetch("pf.w", w);
        b.compute("c0", 5e6, 0, vec![], vec![a0]);
        let c1 = b.compute("c1", 1e6, 0, vec![a0, w], vec![a1]);
        b.dep(c1, pf);
        let g = b.build();
        // Order: pf first -> fully overlapped with c0.
        let order = vec![0, 1, 2];
        let r = simulate(&g, &order, &hw());
        assert!((r.makespan_us - 6.0).abs() < 1e-9, "makespan {}", r.makespan_us);
        assert_eq!(r.exposed_comm_us, 0.0);
        assert!((r.overlapped_comm_us - 3.0).abs() < 1e-9);
    }

    #[test]
    fn late_prefetch_exposes_latency() {
        // Same graph but prefetch issued after c0 -> c1 stalls 3us.
        let mut b = GraphBuilder::new();
        let w = b.tensor("w", 3000, crate::graph::Tier::Remote);
        let a0 = b.tensor("a0", 0, crate::graph::Tier::Device);
        let a1 = b.tensor("a1", 0, crate::graph::Tier::Device);
        b.compute("c0", 5e6, 0, vec![], vec![a0]);
        let pf = b.prefetch("pf.w", w);
        let c1 = b.compute("c1", 1e6, 0, vec![a0, w], vec![a1]);
        b.dep(pf, 0); // runtime-style: issue only when c0 done
        b.dep(c1, pf);
        let g = b.build();
        let order = vec![0, 1, 2];
        let r = simulate(&g, &order, &hw());
        assert!((r.makespan_us - 9.0).abs() < 1e-9, "makespan {}", r.makespan_us);
        assert!((r.exposed_comm_us - 3.0).abs() < 1e-9, "exposed {}", r.exposed_comm_us);
    }

    #[test]
    fn peak_memory_tracks_alloc_and_free() {
        // Two 1KB activations, freed after last use; peak = 2KB while both live.
        let g = GraphBuilder::linear_chain(3, 1e6, 1024);
        let order = g.topo_order().unwrap();
        let r = simulate(&g, &order, &hw());
        // act0 freed when op1 finishes; act1 while op2 runs; act2 never freed.
        assert_eq!(r.peak_device_bytes, 2048);
    }

    #[test]
    fn store_reduces_residency() {
        let mut b = GraphBuilder::new();
        let a = b.tensor("a", 4096, crate::graph::Tier::Device);
        let o = b.tensor("o", 0, crate::graph::Tier::Device);
        let c0 = b.compute("produce", 1e6, 0, vec![], vec![a]);
        let st = b.store("st.a", a);
        b.dep(st, c0);
        let c1 = b.compute("rest", 10e6, 0, vec![], vec![o]);
        b.dep(c1, c0);
        let g = b.build();
        let order = g.topo_order().unwrap();
        let r = simulate(&g, &order, &hw());
        // a allocated then stored out; final residency 0 (o is 0 bytes).
        let final_res = r.residency.last().unwrap().1;
        assert_eq!(final_res, 0);
        assert_eq!(r.peak_device_bytes, 4096);
        // The store's 4096 bytes are the only fabric traffic.
        assert_eq!(r.dma_bytes, 4096);
    }

    #[test]
    fn detach_is_instantaneous() {
        let mut b = GraphBuilder::new();
        let a = b.tensor("a", 4096, crate::graph::Tier::Device);
        let c0 = b.compute("produce", 1e6, 0, vec![], vec![a]);
        let dt = b.detach("dt.a", a);
        b.dep(dt, c0);
        let g = b.build();
        let order = g.topo_order().unwrap();
        let r = simulate(&g, &order, &hw());
        assert!((r.makespan_us - 1.0).abs() < 1e-9);
        assert_eq!(r.residency.last().unwrap().1, 0);
    }

    #[test]
    fn chunked_round_trip_accounts_partial_residency() {
        // A 4 KB device tensor whose round trip is expressed as two 2 KB
        // chunk views: residency must step down per chunk store, step back
        // up per chunk prefetch, and never exceed the unsplit peak.
        let mut g = Graph::new();
        let t = g.add_tensor("t", 4096, crate::graph::Tier::Device);
        let o = g.add_tensor("o", 0, crate::graph::Tier::Device);
        let p = g.add_op(
            "produce",
            OpKind::Compute { flops: 1e6, bytes_accessed: 0 },
            vec![],
            vec![t],
        );
        let mut pfs = Vec::new();
        for j in 0..2u32 {
            let tc = g.add_chunk_tensor(t, format!("t.chunk{j}"), 2048);
            let st = g.add_op(format!("st{j}"), OpKind::store(tc), vec![tc], vec![]);
            g.add_control_dep(st, p);
            let pf = g.add_op(format!("pf{j}"), OpKind::prefetch(tc), vec![tc], vec![]);
            g.add_control_dep(pf, st);
            pfs.push(pf);
        }
        let c = g.add_op(
            "consume",
            OpKind::Compute { flops: 1e6, bytes_accessed: 0 },
            vec![t],
            vec![o],
        );
        for pf in pfs {
            g.add_control_dep(c, pf);
        }
        let order = g.topo_order().unwrap();
        let r = simulate(&g, &order, &hw());
        // Peak is the full tensor (both chunks resident around the compute).
        assert_eq!(r.peak_device_bytes, 4096);
        // Mid-window the residency dips to a partial value: some sample
        // strictly between 0 and the full size must exist.
        assert!(
            r.residency.iter().any(|&(_, b)| b > 0 && b < 4096),
            "no partial-residency sample: {:?}",
            r.residency
        );
        // Conservation: final residency returns to zero (t freed after its
        // last consumer, chunk events net out inside the bracket).
        assert_eq!(r.residency.last().unwrap().1, 0);
        // Four chunk transfers moved exactly the tensor's bytes twice.
        assert_eq!(r.dma_bytes, 2 * 4096);
    }

    #[test]
    fn two_tier_topology_is_bit_identical_to_legacy() {
        use super::super::hw::TierTopology;
        // Same graph, same order: the mirrored two-tier topology must
        // reproduce the legacy cost model bit for bit.
        let (g, ws) = GraphBuilder::chain_with_remote_weights(4, 5e6, 64, 2000);
        let mut b = GraphBuilder { graph: g };
        for (i, &w) in ws.iter().enumerate() {
            let pf = b.prefetch(&format!("pf.{i}"), w);
            b.dep(i, pf);
        }
        let st = b.store("st.final", 3); // one store for DMA-out coverage
        b.dep(st, 3);
        let g = b.build();
        let order = g.topo_order().unwrap();
        let legacy = simulate(&g, &order, &hw());
        let tiered = simulate(&g, &order, &hw().with_tiers(TierTopology::two_tier(&hw())));
        assert_eq!(legacy.makespan_us, tiered.makespan_us);
        assert_eq!(legacy.exposed_comm_us, tiered.exposed_comm_us);
        assert_eq!(legacy.dma_busy_us, tiered.dma_busy_us);
        assert_eq!(legacy.peak_device_bytes, tiered.peak_device_bytes);
        assert_eq!(legacy.residency, tiered.residency);
        // The only divergence is the new additive accounting.
        assert!(legacy.tier_peaks.is_empty());
        assert_eq!(tiered.tier_peaks.len(), 1); // pool level tracked
    }

    #[test]
    fn tiered_round_trip_costs_cold_edges_and_moves_the_copy() {
        use super::super::hw::TierTopology;
        let base = hw();
        let hw3 = hw().with_tiers(TierTopology::three_tier(&base));
        // produce -> store(Dram) -> promote(Dram->Remote) -> prefetch -> consume
        let mut b = GraphBuilder::new();
        let a = b.tensor("a", 4096, Tier::Device);
        let o = b.tensor("o", 0, Tier::Device);
        let p = b.compute("produce", 1e6, 0, vec![], vec![a]);
        let st = b.store_to("st.a", a, Tier::Dram);
        b.dep(st, p);
        let pm = b.promote("pm.a", a, Tier::Dram, Tier::Remote);
        b.dep(pm, st);
        let pf = b.prefetch("pf.a", a);
        b.dep(pf, pm);
        let c = b.compute("consume", 1e6, 0, vec![a], vec![o]);
        b.dep(c, pf);
        let g = b.build();
        let order = g.topo_order().unwrap();
        let r = simulate(&g, &order, &hw3);
        // Store to Dram pays both hops: 2us latency + 4096B at 0.5 GB/s.
        let st_iv = r.intervals.iter().find(|iv| iv.stream == Stream::DmaOut).unwrap();
        let expect_st = 2.0 + 4096.0 / 0.5e9 * 1e6;
        assert!(
            (st_iv.finish_us - st_iv.start_us - expect_st).abs() < 1e-9,
            "store dur {}",
            st_iv.finish_us - st_iv.start_us
        );
        // Promote rides its own stream and moves the copy Dram -> pool.
        let pm_iv = r.intervals.iter().find(|iv| iv.stream == Stream::ColdDma).unwrap();
        assert!((pm_iv.finish_us - pm_iv.start_us - expect_st).abs() < 1e-9);
        assert_eq!(r.cold_dma_bytes, 4096);
        let peaks: std::collections::HashMap<Tier, u64> = r.tier_peaks.iter().copied().collect();
        assert_eq!(peaks[&Tier::Dram], 4096);
        assert_eq!(peaks[&Tier::Remote], 4096);
        // Prefetch from the pool costs the hot edge only (1 GB/s, no lat).
        let pf_iv = r.intervals.iter().find(|iv| iv.stream == Stream::DmaIn).unwrap();
        assert!((pf_iv.finish_us - pf_iv.start_us - 4096.0 / 1e9 * 1e6).abs() < 1e-9);
        // Device residency is untouched by the cold-side moves.
        assert_eq!(r.peak_device_bytes, 4096);
        assert_eq!(r.residency.last().unwrap().1, 0);
    }

    #[test]
    fn order_changes_outcome_but_not_validity() {
        // Exactly Fig. 4: same graph, different order, different exposure.
        let (g, ws) = GraphBuilder::chain_with_remote_weights(4, 5e6, 0, 2000);
        let mut b = GraphBuilder { graph: g };
        let mut pf_ops = Vec::new();
        for (i, &w) in ws.iter().enumerate() {
            let pf = b.prefetch(&format!("pf.{i}"), w);
            b.dep(i, pf); // consumer op i depends on its prefetch
            pf_ops.push(pf);
        }
        let g = b.build();
        // "All prefetches first" order vs "each prefetch just before use".
        let early: Vec<OpId> = pf_ops.iter().copied().chain(0..4).collect();
        let mut late: Vec<OpId> = Vec::new();
        for i in 0..4 {
            late.push(pf_ops[i]);
            late.push(i);
        }
        assert!(g.is_valid_order(&early));
        assert!(g.is_valid_order(&late));
        let r_early = simulate(&g, &early, &hw());
        let r_late = simulate(&g, &late, &hw());
        // Early: everything prefetched up front -> higher residency.
        assert!(r_early.peak_device_bytes >= r_late.peak_device_bytes);
    }
}
