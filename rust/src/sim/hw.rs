//! SuperNode hardware model (DESIGN.md §2 substitution table).
//!
//! The Ascend 910C SuperNode testbed is parameterised as capacities,
//! bandwidths and latencies; the paper's bandwidth sweeps (Fig. 6) become
//! sweeps over `d2r_gbps`/`r2d_gbps`. Values default to the paper's
//! measured point (33.6 GB/s D2H) and public Ascend 910C specs.
//!
//! # The tier stack
//!
//! The base config models the paper's two homes — device HBM and the
//! shared pool. [`TierTopology`] generalises that to an ordered chain of
//! tiers with per-edge bandwidth/latency and per-tier capacity:
//!
//! ```text
//!   Device (HBM) ── d2r/r2d ── Remote (pool) ── dram link ── Dram
//!                                                   └── cxl link ── Cxl ── ssd link ── Ssd
//! ```
//!
//! A transfer between tiers `i..j` pays the sum of per-hop latencies and
//! the bandwidth of the narrowest edge on the path. With `tiers: None`
//! (or a topology whose device↔pool edge mirrors `d2r_gbps`/`r2d_gbps`/
//! `link_latency_us`), the hot-edge cost expression is *bit-identical* to
//! the legacy `d2r_us`/`r2d_us` formulas — the two-tier configuration is
//! a degenerate case, not a fork.

use crate::graph::Tier;

/// Hardware/platform parameters for the discrete-event simulator.
#[derive(Debug, Clone)]
pub struct HwConfig {
    /// Effective dense-compute throughput per device (TFLOP/s).
    pub compute_tflops: f64,
    /// Device HBM bandwidth (GB/s) — the memory-bound roofline axis.
    pub hbm_gbps: f64,
    /// Device → Remote-pool DMA bandwidth (GB/s). The paper's "D2H".
    pub d2r_gbps: f64,
    /// Remote-pool → Device DMA bandwidth (GB/s).
    pub r2d_gbps: f64,
    /// One-way link latency per transfer (us).
    pub link_latency_us: f64,
    /// Inter-device collective bandwidth (GB/s) for TP/PP/EP traffic.
    pub net_gbps: f64,
    /// CPU control-path overhead per *runtime-issued* memory operation
    /// (us): inspect state, issue DMA, synchronise (§3.1). Compile-time
    /// scheduled cache operators do NOT pay this.
    pub host_overhead_us: f64,
    /// Device HBM capacity (bytes).
    pub device_capacity: u64,
    /// Shared remote pool capacity (bytes).
    pub remote_capacity: u64,
    /// Optional N-level tier stack below the device. `None` means the
    /// legacy two-home model (device + pool) with exactly the costs above.
    pub tiers: Option<TierTopology>,
    /// Optional device↔device fabric edge for harvested peer-HBM homes
    /// ([`Tier::Peer`]). `None` means no peer tier exists: peer transfers
    /// conservatively fall back to the pool-link cost, and nothing in the
    /// two-home or N-tier cost model changes — the peer-disabled fixpoint.
    pub peer: Option<PeerLink>,
}

/// The device↔device edge peer-HBM harvesting rides: a sibling replica's
/// spare HBM reached over the SuperNode's direct device fabric, bypassing
/// the pool hop. Typically higher bandwidth and lower latency than the
/// device↔pool link — that gap is the whole point of borrowing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PeerLink {
    /// Device↔device bandwidth (GB/s), symmetric.
    pub gbps: f64,
    /// One-way latency per transfer (us).
    pub latency_us: f64,
}

pub const GB: u64 = 1024 * 1024 * 1024;
pub const MB: u64 = 1024 * 1024;

impl HwConfig {
    /// Paper's measured platform point: Ascend-910C-like device with
    /// 33.6 GB/s measured D2H bandwidth (§7.2.1). The dual-die 910C
    /// carries more HBM than the 64 GB 910B; we model ~96 GB usable for
    /// training. Inference benches override capacity to the 64 GB the
    /// paper's Table 3 arithmetic implies.
    pub fn ascend910c_like() -> Self {
        Self {
            compute_tflops: 320.0,
            hbm_gbps: 1600.0,
            d2r_gbps: 33.6,
            r2d_gbps: 33.6,
            link_latency_us: 10.0,
            net_gbps: 56.0,
            host_overhead_us: 150.0,
            device_capacity: 96 * GB,
            remote_capacity: 1024 * GB,
            tiers: None,
            peer: None,
        }
    }

    /// Deterministic unit-test fixture with round numbers: 1 TFLOP/s
    /// compute (1e6 flops = 1 us), 1 GB/s symmetric pool links (1 KB =
    /// 1 us), zero link latency and host overhead, effectively unlimited
    /// HBM bandwidth, 1 GiB device / 1 TiB pool capacity. The single
    /// source of the hand-rolled `hw()` fixtures that used to be copied
    /// across the pass, sim, and baseline test modules.
    pub fn test_default() -> Self {
        Self {
            compute_tflops: 1.0,
            hbm_gbps: 1e9,
            d2r_gbps: 1.0,
            r2d_gbps: 1.0,
            link_latency_us: 0.0,
            net_gbps: 1.0,
            host_overhead_us: 0.0,
            device_capacity: 1 << 30,
            remote_capacity: 1 << 40,
            tiers: None,
            peer: None,
        }
    }

    /// Same platform with a different symmetric pool bandwidth (Fig. 6 sweep).
    pub fn with_pool_bandwidth(mut self, gbps: f64) -> Self {
        self.d2r_gbps = gbps;
        self.r2d_gbps = gbps;
        self
    }

    pub fn with_device_capacity(mut self, bytes: u64) -> Self {
        self.device_capacity = bytes;
        self
    }

    /// Same platform with a different CPU control-path overhead (us) per
    /// runtime-issued memory operation.
    pub fn with_host_overhead(mut self, us: f64) -> Self {
        self.host_overhead_us = us;
        self
    }

    /// Duration of a compute op under the roofline model (us).
    pub fn compute_us(&self, flops: f64, bytes_accessed: u64) -> f64 {
        let t_flops = flops / (self.compute_tflops * 1e12) * 1e6;
        let t_mem = bytes_accessed as f64 / (self.hbm_gbps * 1e9) * 1e6;
        t_flops.max(t_mem)
    }

    /// Duration of a Device→Remote transfer (us).
    pub fn d2r_us(&self, bytes: u64) -> f64 {
        self.d2r_us_slowed(bytes, 1.0)
    }

    /// Duration of a Remote→Device transfer (us).
    pub fn r2d_us(&self, bytes: u64) -> f64 {
        self.r2d_us_slowed(bytes, 1.0)
    }

    /// D2R transfer with a fabric-contention slowdown factor (≥ 1.0)
    /// applied to the bandwidth term only: link latency is per-hop and
    /// does not stretch when siblings share the fabric.
    pub fn d2r_us_slowed(&self, bytes: u64, slowdown: f64) -> f64 {
        self.link_latency_us + slowdown * (bytes as f64 / (self.d2r_gbps * 1e9) * 1e6)
    }

    /// R2D transfer with a fabric-contention slowdown factor (≥ 1.0).
    pub fn r2d_us_slowed(&self, bytes: u64, slowdown: f64) -> f64 {
        self.link_latency_us + slowdown * (bytes as f64 / (self.r2d_gbps * 1e9) * 1e6)
    }

    /// Duration of a collective of `bytes` (us) — flat ring model.
    pub fn net_us(&self, bytes: u64) -> f64 {
        self.link_latency_us + bytes as f64 / (self.net_gbps * 1e9) * 1e6
    }

    /// Install an N-level tier stack. See [`TierTopology`].
    pub fn with_tiers(mut self, tiers: TierTopology) -> Self {
        self.tiers = Some(tiers);
        self
    }

    /// Install a device↔device peer edge for harvested peer-HBM homes.
    pub fn with_peer_link(mut self, gbps: f64, latency_us: f64) -> Self {
        self.peer = Some(PeerLink { gbps, latency_us });
        self
    }

    /// Duration of a transfer over the peer edge (us), with a contention
    /// slowdown on the bandwidth term only. Without a configured
    /// [`PeerLink`] this conservatively degrades to the pool-link cost
    /// (`up` selects the r2d vs d2r expression), so a `Tier::Peer` op in
    /// a peer-less config never costs *less* than the pool round trip.
    fn peer_us_slowed(&self, bytes: u64, slowdown: f64, up: bool) -> f64 {
        match &self.peer {
            Some(link) => link.latency_us + slowdown * (bytes as f64 / (link.gbps * 1e9) * 1e6),
            None if up => self.r2d_us_slowed(bytes, slowdown),
            None => self.d2r_us_slowed(bytes, slowdown),
        }
    }

    /// Duration of a `src`-tier → Device transfer (a tiered `Prefetch`).
    /// Falls back to the legacy [`r2d_us`](Self::r2d_us) expression —
    /// bit-for-bit — when no topology is installed or `src` is one of the
    /// hot legacy tiers the topology resolves to the pool edge.
    pub fn fetch_us(&self, src: Tier, bytes: u64) -> f64 {
        self.fetch_us_slowed(src, bytes, 1.0)
    }

    /// [`fetch_us`](Self::fetch_us) with a fabric-contention slowdown
    /// applied to the bandwidth term only (per-hop latency never
    /// stretches).
    pub fn fetch_us_slowed(&self, src: Tier, bytes: u64, slowdown: f64) -> f64 {
        if src.is_peer() {
            return self.peer_us_slowed(bytes, slowdown, true);
        }
        if let Some(topo) = &self.tiers {
            if let Some(i) = topo.index_of(src) {
                if i > 0 {
                    return topo.path_us(i, 0, bytes, slowdown);
                }
            }
        }
        self.r2d_us_slowed(bytes, slowdown)
    }

    /// Duration of a Device → `dst`-tier transfer (a tiered `Store`).
    pub fn evict_us(&self, dst: Tier, bytes: u64) -> f64 {
        self.evict_us_slowed(dst, bytes, 1.0)
    }

    /// [`evict_us`](Self::evict_us) with a fabric-contention slowdown.
    pub fn evict_us_slowed(&self, dst: Tier, bytes: u64, slowdown: f64) -> f64 {
        if dst.is_peer() {
            return self.peer_us_slowed(bytes, slowdown, false);
        }
        if let Some(topo) = &self.tiers {
            if let Some(i) = topo.index_of(dst) {
                if i > 0 {
                    return topo.path_us(0, i, bytes, slowdown);
                }
            }
        }
        self.d2r_us_slowed(bytes, slowdown)
    }

    /// Duration of a non-device `src` → `dst` move (a `Promote`, in either
    /// direction). Without a topology — a graph that should not contain
    /// promotes — this degrades to the pool-link cost as a conservative
    /// stand-in rather than panicking.
    pub fn promote_us(&self, src: Tier, dst: Tier, bytes: u64) -> f64 {
        if src.is_peer() || dst.is_peer() {
            // Revocation demotions (Peer → pool) and lease installs
            // (pool → Peer) are bottlenecked on the peer edge.
            return self.peer_us_slowed(bytes, 1.0, src.is_peer());
        }
        if let Some(topo) = &self.tiers {
            if let (Some(i), Some(j)) = (topo.index_of(src), topo.index_of(dst)) {
                if i != j {
                    return topo.path_us(i, j, bytes, 1.0);
                }
                return 0.0;
            }
        }
        self.r2d_us(bytes)
    }

    /// Capacity of `tier` in the installed topology; `Device`/`Remote`
    /// fall back to the flat config fields when no topology is present.
    pub fn tier_capacity(&self, tier: Tier) -> Option<u64> {
        if let Some(topo) = &self.tiers {
            if let Some(i) = topo.index_of(tier) {
                return Some(topo.capacities[i]);
            }
        }
        match tier {
            Tier::Device => Some(self.device_capacity),
            Tier::Remote | Tier::Host => Some(self.remote_capacity),
            _ => None,
        }
    }
}

/// One edge of the tier chain, connecting two adjacent tiers.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TierLink {
    /// Bandwidth in the hotter → colder direction (GB/s): stores, demotions.
    pub down_gbps: f64,
    /// Bandwidth in the colder → hotter direction (GB/s): prefetches,
    /// promotions.
    pub up_gbps: f64,
    /// One-way latency per hop (us).
    pub latency_us: f64,
}

/// An ordered memory-tier chain: `tiers[0]` is always [`Tier::Device`],
/// `tiers[1]` the shared pool ([`Tier::Remote`]), then optional cold
/// levels in hot → cold order. `links[i]` connects `tiers[i]` and
/// `tiers[i+1]`; `capacities[i]` bounds `tiers[i]`.
///
/// A transfer between tiers pays the *sum* of per-hop latencies and the
/// bandwidth of the *narrowest* edge on the path (store-and-forward
/// through intermediate levels is modelled as pipelined). The legacy
/// [`Tier::Host`] staging tier resolves to the pool level so two-home
/// graphs cost identically under a mirrored topology.
#[derive(Debug, Clone, PartialEq)]
pub struct TierTopology {
    pub tiers: Vec<Tier>,
    pub links: Vec<TierLink>,
    pub capacities: Vec<u64>,
}

impl TierTopology {
    /// The degenerate two-tier stack mirroring `hw`'s flat fields exactly:
    /// one device↔pool edge at `d2r_gbps`/`r2d_gbps`/`link_latency_us`.
    /// Costs through this topology are bit-identical to the legacy
    /// `d2r_us`/`r2d_us` path (pinned by the differential proptest).
    pub fn two_tier(hw: &HwConfig) -> Self {
        Self {
            tiers: vec![Tier::Device, Tier::Remote],
            links: vec![TierLink {
                down_gbps: hw.d2r_gbps,
                up_gbps: hw.r2d_gbps,
                latency_us: hw.link_latency_us,
            }],
            capacities: vec![hw.device_capacity, hw.remote_capacity],
        }
    }

    /// Append a cold tier below the current coldest level. Panics if
    /// `tier` is not a cold tier or already present.
    pub fn with_cold_tier(
        mut self,
        tier: Tier,
        down_gbps: f64,
        up_gbps: f64,
        latency_us: f64,
        capacity: u64,
    ) -> Self {
        assert!(tier.is_cold(), "only Dram/Cxl/Ssd can sit below the pool");
        assert!(!self.tiers.contains(&tier), "tier {tier:?} already in the topology");
        self.tiers.push(tier);
        self.links.push(TierLink { down_gbps, up_gbps, latency_us });
        self.capacities.push(capacity);
        self
    }

    /// Three-level stack: device, pool, and a cold DRAM level at roughly
    /// half the pool-link bandwidth and DDR-class capacity.
    pub fn three_tier(hw: &HwConfig) -> Self {
        Self::two_tier(hw).with_cold_tier(
            Tier::Dram,
            hw.d2r_gbps * 0.5,
            hw.r2d_gbps * 0.5,
            hw.link_latency_us + 2.0,
            2 * hw.remote_capacity,
        )
    }

    /// Five-level stack: device, pool, DRAM, CXL, SSD with
    /// order-of-magnitude bandwidth/latency spreads per level (the ITME
    /// pyramid).
    pub fn five_tier(hw: &HwConfig) -> Self {
        Self::three_tier(hw)
            .with_cold_tier(
                Tier::Cxl,
                hw.d2r_gbps * 0.25,
                hw.r2d_gbps * 0.25,
                5.0 * (hw.link_latency_us + 1.0),
                4 * hw.remote_capacity,
            )
            .with_cold_tier(
                Tier::Ssd,
                hw.d2r_gbps * 0.1,
                hw.r2d_gbps * 0.1,
                20.0 * (hw.link_latency_us + 1.0),
                16 * hw.remote_capacity,
            )
    }

    /// Position of `tier` in the chain. The legacy `Host` staging tier
    /// resolves to the pool level (index 1).
    pub fn index_of(&self, tier: Tier) -> Option<usize> {
        if tier == Tier::Host {
            return Some(1);
        }
        self.tiers.iter().position(|&t| t == tier)
    }

    /// Tiers strictly below the pool, hot → cold.
    pub fn cold_tiers(&self) -> &[Tier] {
        &self.tiers[2.min(self.tiers.len())..]
    }

    /// Transfer duration between tier indices `from` → `to` (us): sum of
    /// per-hop latencies plus the bytes over the narrowest edge on the
    /// path, with `slowdown` stretching the bandwidth term only. For a
    /// single device↔pool hop this reduces to exactly the legacy
    /// `d2r_us_slowed`/`r2d_us_slowed` expression.
    pub fn path_us(&self, from: usize, to: usize, bytes: u64, slowdown: f64) -> f64 {
        debug_assert!(from != to, "zero-length tier path");
        let (lo, hi, down) = if from < to { (from, to, true) } else { (to, from, false) };
        let mut latency = 0.0;
        let mut gbps = f64::INFINITY;
        for link in &self.links[lo..hi] {
            latency += link.latency_us;
            gbps = gbps.min(if down { link.down_gbps } else { link.up_gbps });
        }
        latency + slowdown * (bytes as f64 / (gbps * 1e9) * 1e6)
    }
}

/// The shared device↔pool interconnect of one SuperNode.
///
/// Each device owns a private link of `d2r_gbps`/`r2d_gbps`, but all links
/// funnel into one fabric with finite aggregate bandwidth. While `k`
/// devices transfer in the same window, each sees
/// `min(per_link, aggregate / k)` — below the per-link rate once the
/// fabric saturates. This is the §7 multi-NPU effect the cluster
/// simulation exercises: a transfer slows down *because* siblings are
/// transferring, not because its own link got slower.
#[derive(Debug, Clone)]
pub struct Fabric {
    /// Aggregate device↔pool bandwidth across all devices (GB/s).
    pub aggregate_gbps: f64,
}

impl Fabric {
    /// Default provisioning for a node built around `hw`: the fabric
    /// carries two full per-link rates, so one or two active devices run
    /// uncontended and a wider fan-in progressively saturates.
    pub fn for_hw(hw: &HwConfig) -> Self {
        Self { aggregate_gbps: 2.0 * hw.d2r_gbps.max(hw.r2d_gbps) }
    }

    /// An effectively infinite fabric (no contention, any k).
    pub fn uncontended() -> Self {
        Self { aggregate_gbps: f64::INFINITY }
    }

    /// Slowdown multiplier (≥ 1.0) for a link of `per_link_gbps` while
    /// `k` devices transfer concurrently. Exactly 1.0 when k ≤ 1 or the
    /// fabric has headroom — the single-device fixpoint is preserved
    /// bit-for-bit.
    pub fn slowdown(&self, per_link_gbps: f64, k: usize) -> f64 {
        if k <= 1 {
            return 1.0;
        }
        let share = self.aggregate_gbps / k as f64;
        if share >= per_link_gbps {
            1.0
        } else {
            per_link_gbps / share
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roofline_picks_max() {
        let hw = HwConfig::ascend910c_like();
        // 3.2e12 flops at 320 TFLOP/s = 10 ms = 1e4 us (compute bound).
        let t1 = hw.compute_us(3.2e12, 1);
        assert!((t1 - 1e4).abs() / 1e4 < 1e-6, "t1={t1}");
        // 16 GB at 1600 GB/s = 10 ms (memory bound).
        let t2 = hw.compute_us(1.0, 16_000_000_000);
        assert!((t2 - 1e4).abs() / 1e4 < 1e-6, "t2={t2}");
    }

    #[test]
    fn transfer_scales_with_bytes_plus_latency() {
        let hw = HwConfig::ascend910c_like().with_pool_bandwidth(33.6);
        let t = hw.d2r_us(33_600_000_000 / 1000); // 1/1000 s of traffic
        assert!((t - (10.0 + 1000.0)).abs() < 1.0);
    }

    #[test]
    fn bandwidth_sweep_changes_only_pool() {
        let a = HwConfig::ascend910c_like();
        let b = a.clone().with_pool_bandwidth(70.0);
        assert_eq!(a.hbm_gbps, b.hbm_gbps);
        assert!(b.d2r_us(GB) < a.d2r_us(GB));
    }

    #[test]
    fn fabric_slowdown_kicks_in_past_provisioning() {
        let hw = HwConfig::ascend910c_like();
        let f = Fabric::for_hw(&hw); // 2x the 33.6 GB/s link
        assert_eq!(f.slowdown(hw.d2r_gbps, 1), 1.0);
        assert_eq!(f.slowdown(hw.d2r_gbps, 2), 1.0);
        // 4 concurrent links share 67.2 GB/s -> 16.8 each: 2x slower.
        let s4 = f.slowdown(hw.d2r_gbps, 4);
        assert!((s4 - 2.0).abs() < 1e-9, "s4={s4}");
        assert!(f.slowdown(hw.d2r_gbps, 8) > s4);
        assert_eq!(Fabric::uncontended().slowdown(hw.d2r_gbps, 64), 1.0);
    }

    #[test]
    fn slowed_transfer_stretches_bandwidth_term_only() {
        let hw = HwConfig::ascend910c_like();
        let base = hw.d2r_us(GB);
        let slowed = hw.d2r_us_slowed(GB, 2.0);
        // Latency is unchanged; the bandwidth term doubles.
        let bw_term = base - hw.link_latency_us;
        assert!((slowed - (hw.link_latency_us + 2.0 * bw_term)).abs() < 1e-6);
        // Factor 1.0 is bit-identical to the plain path.
        assert_eq!(hw.d2r_us_slowed(GB, 1.0), base);
        assert_eq!(hw.r2d_us_slowed(GB, 1.0), hw.r2d_us(GB));
    }
}
