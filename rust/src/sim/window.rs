//! Windowed re-simulation: record one full simulation, then replay only
//! the schedule suffix a candidate rewrite can affect.
//!
//! The decision passes (`RecomputeVsOffload`, `SloThrottle`) speculate a
//! rewrite, re-simulate, and keep or roll back. At production graph scale
//! (20k+ ops) a full [`simulate`](super::simulate) per candidate is the
//! compile-latency bottleneck — yet a candidate only perturbs the schedule
//! from its earliest touched position onward. [`SimTrace`] records the
//! baseline walk (per-op start/finish times plus the per-position stream
//! occupancy — the complete entry state of every schedule suffix);
//! [`SimTrace::resume`] seeds a trial simulation with the recorded prefix
//! and walks only the suffix.
//!
//! `resume` is *exact*, not approximate: it reuses the recorded prefix
//! times verbatim and assembles memory events, refcount frees and
//! aggregate counters in the same sequence as `simulate`, so the result is
//! bit-identical to a full simulation of the trial graph/order (the P13
//! differential proptest in rust/tests/ pins this). The caller contract is
//! that the first `prefix_len` positions of the trial order correspond
//! 1:1 (possibly renumbered, e.g. after `Graph::remove_ops`) to the
//! recorded order, with identical op kinds, durations, and
//! prefix-internal dependencies.

use crate::graph::{Graph, OpId, OpKind, Tier};

use super::engine::{duration_us, simulate, stream_of, Interval, SimResult, Stream};
use super::hw::HwConfig;

fn stream_idx(s: Stream) -> usize {
    match s {
        Stream::Compute => 0,
        Stream::DmaIn => 1,
        Stream::DmaOut => 2,
        Stream::Net => 3,
        Stream::Host => 4,
        Stream::ColdDma => 5,
    }
}

/// Number of in-order streams ([`stream_idx`] codomain).
const N_STREAMS: usize = 6;

/// A recorded baseline simulation that trial schedules can resume from.
#[derive(Debug, Clone)]
pub struct SimTrace {
    /// The recorded execution order.
    order: Vec<OpId>,
    /// Per-op start time in the recorded walk.
    start: Vec<f64>,
    /// Per-op finish time in the recorded walk.
    finish: Vec<f64>,
    /// Stream occupancy *before* each position (`order.len() + 1`
    /// entries): the complete cross-window entry state of every suffix.
    stream_free: Vec<[f64; N_STREAMS]>,
    /// The baseline result (identical to `simulate(graph, order, hw)`).
    pub base: SimResult,
}

impl SimTrace {
    /// Simulate `graph` under `order` once, recording the per-position
    /// state needed to resume from any schedule position.
    pub fn record(graph: &Graph, order: &[OpId], hw: &HwConfig) -> Self {
        debug_assert!(graph.is_valid_order(order), "record: invalid execution order");
        let n = graph.ops.len();
        let mut start = vec![0.0f64; n];
        let mut finish = vec![0.0f64; n];
        let mut sf = [0.0f64; N_STREAMS];
        let mut snaps = Vec::with_capacity(order.len() + 1);
        for &op_id in order {
            snaps.push(sf);
            let op = graph.op(op_id);
            let stream = stream_of(&op.kind);
            let dur = duration_us(&op.kind, graph, hw);
            let dep_ready =
                graph.preds(op_id).iter().map(|&p| finish[p]).fold(0.0f64, f64::max);
            let s = dep_ready.max(sf[stream_idx(stream)]);
            let f = s + dur;
            start[op_id] = s;
            finish[op_id] = f;
            sf[stream_idx(stream)] = f;
        }
        snaps.push(sf);
        let base = simulate(graph, order, hw);
        debug_assert!(order
            .iter()
            .zip(base.intervals.iter())
            .all(|(&o, iv)| iv.op == o
                && iv.start_us.to_bits() == start[o].to_bits()
                && iv.finish_us.to_bits() == finish[o].to_bits()));
        SimTrace { order: order.to_vec(), start, finish, stream_free: snaps, base }
    }

    /// Position of the recorded order's `i`-th op (convenience for
    /// callers computing the resume point).
    pub fn order(&self) -> &[OpId] {
        &self.order
    }

    /// Re-simulate `order` over (a possibly rewritten) `graph`, reusing
    /// the recorded walk for the first `prefix_len` positions.
    ///
    /// `extra_deps` is a list of `(op, dep)` ordering edges assumed *in
    /// addition to* the graph's own — so callers can probe "what if `op`
    /// also waited on `dep`" without cloning and mutating the graph per
    /// probe. The result is bit-identical to
    /// `simulate(&graph_with_extra_deps, order, hw)`.
    ///
    /// Caller contract: for `i < prefix_len`, `order[i]` is the same op
    /// as the recorded `order[i]` (same kind, duration, and
    /// prefix-internal preds — op *ids* may differ after renumbering),
    /// and no graph rewrite or extra dep affects any prefix op.
    pub fn resume(
        &self,
        prefix_len: usize,
        graph: &Graph,
        order: &[OpId],
        hw: &HwConfig,
        extra_deps: &[(OpId, OpId)],
    ) -> SimResult {
        debug_assert!(prefix_len <= self.order.len() && prefix_len <= order.len());
        debug_assert!(graph.is_valid_order(order), "resume: invalid execution order");

        let n = graph.ops.len();
        let mut finish = vec![0.0f64; n];
        let mut start = vec![0.0f64; n];
        let mut intervals = Vec::with_capacity(n);

        let mut pos = vec![usize::MAX; n];
        for (i, &o) in order.iter().enumerate() {
            pos[o] = i;
        }
        debug_assert!(extra_deps.iter().all(|&(o, d)| pos[d] < pos[o]));

        // --- residency bookkeeping (mirrors `simulate`) ------------------
        let mut mem_events: Vec<(f64, i64)> = Vec::new();
        let mut last_use: Vec<Option<OpId>> = vec![None; graph.tensors.len()];
        for t in &graph.tensors {
            let mut consumers: Vec<OpId> = graph.consumers_of(t.id).to_vec();
            consumers.retain(|&c| pos[c] != usize::MAX);
            if let Some(&last) = consumers.iter().max_by_key(|&&c| pos[c]) {
                last_use[t.id] = Some(last);
            }
        }
        let mut last_cache_free_pos: Vec<Option<usize>> = vec![None; graph.tensors.len()];
        for op in &graph.ops {
            if let OpKind::Store { tensor, .. } | OpKind::Detach { tensor } = op.kind {
                if pos[op.id] != usize::MAX {
                    let e = last_cache_free_pos[tensor].get_or_insert(0);
                    *e = (*e).max(pos[op.id]);
                }
            }
        }
        for t in &graph.tensors {
            if t.home == Tier::Device && graph.producer_of(t.id).is_none() && t.alias_of.is_none()
            {
                mem_events.push((0.0, t.bytes as i64));
            }
        }

        // --- per-tier (non-device) residency (mirrors `simulate`) --------
        let topo = hw.tiers.as_ref();
        let mut tier_events: Vec<Vec<(f64, i64)>> = match topo {
            Some(t) => vec![Vec::new(); t.tiers.len()],
            None => Vec::new(),
        };
        if let Some(t) = topo {
            for tn in &graph.tensors {
                if tn.home != Tier::Device
                    && tn.alias_of.is_none()
                    && graph.producer_of(tn.id).is_none()
                {
                    if let Some(i) = t.index_of(tn.home) {
                        tier_events[i].push((0.0, tn.bytes as i64));
                    }
                }
            }
        }

        // --- prefix: recorded times, trial-graph events ------------------
        let mut dma_bytes = 0u64;
        let mut cold_dma_bytes = 0u64;
        let emit = |op_id: OpId,
                    s: f64,
                    f: f64,
                    mem_events: &mut Vec<(f64, i64)>,
                    tier_events: &mut Vec<Vec<(f64, i64)>>,
                    dma_bytes: &mut u64,
                    cold_dma_bytes: &mut u64| {
            let op = graph.op(op_id);
            match op.kind {
                OpKind::Compute { .. } => {
                    for &t in &op.outputs {
                        if graph.tensor(t).home == Tier::Device {
                            mem_events.push((s, graph.tensor(t).bytes as i64));
                        }
                    }
                }
                OpKind::Prefetch { tensor, .. } => {
                    mem_events.push((s, graph.tensor(tensor).bytes as i64));
                    *dma_bytes += graph.tensor(tensor).bytes;
                }
                OpKind::Store { tensor, dst } => {
                    mem_events.push((f, -(graph.tensor(tensor).bytes as i64)));
                    *dma_bytes += graph.tensor(tensor).bytes;
                    if let Some(t) = topo {
                        if let Some(i) = t.index_of(dst) {
                            tier_events[i].push((f, graph.tensor(tensor).bytes as i64));
                        }
                    }
                }
                OpKind::Detach { tensor } => {
                    mem_events.push((f, -(graph.tensor(tensor).bytes as i64)));
                }
                OpKind::Promote { tensor, src, dst } => {
                    *cold_dma_bytes += graph.tensor(tensor).bytes;
                    if let Some(t) = topo {
                        if let Some(i) = t.index_of(dst) {
                            tier_events[i].push((s, graph.tensor(tensor).bytes as i64));
                        }
                        if let Some(i) = t.index_of(src) {
                            tier_events[i].push((f, -(graph.tensor(tensor).bytes as i64)));
                        }
                    }
                }
                _ => {}
            }
        };
        for i in 0..prefix_len {
            let o = order[i];
            let b = self.order[i];
            let (s, f) = (self.start[b], self.finish[b]);
            start[o] = s;
            finish[o] = f;
            intervals.push(Interval {
                op: o,
                start_us: s,
                finish_us: f,
                stream: stream_of(&graph.op(o).kind),
            });
            emit(o, s, f, &mut mem_events, &mut tier_events, &mut dma_bytes, &mut cold_dma_bytes);
        }

        // --- suffix: list scheduling from the recorded entry state -------
        let mut sf = self.stream_free[prefix_len];
        for &op_id in &order[prefix_len..] {
            let op = graph.op(op_id);
            let stream = stream_of(&op.kind);
            let dur = duration_us(&op.kind, graph, hw);
            let mut dep_ready =
                graph.preds(op_id).iter().map(|&p| finish[p]).fold(0.0f64, f64::max);
            for &(o, d) in extra_deps {
                if o == op_id {
                    dep_ready = dep_ready.max(finish[d]);
                }
            }
            let s = dep_ready.max(sf[stream_idx(stream)]);
            let f = s + dur;
            start[op_id] = s;
            finish[op_id] = f;
            sf[stream_idx(stream)] = f;
            intervals.push(Interval { op: op_id, start_us: s, finish_us: f, stream });
            emit(
                op_id,
                s,
                f,
                &mut mem_events,
                &mut tier_events,
                &mut dma_bytes,
                &mut cold_dma_bytes,
            );
        }

        // --- refcount frees (mirrors `simulate`) -------------------------
        for t in &graph.tensors {
            if t.alias_of.is_some() && t.home == Tier::Device {
                continue;
            }
            let Some(last) = last_use[t.id] else { continue };
            let has_device_copy = t.home == Tier::Device
                || graph
                    .ops
                    .iter()
                    .any(|o| matches!(o.kind, OpKind::Prefetch { tensor, .. } if tensor == t.id));
            if !has_device_copy {
                continue;
            }
            if let Some(cp) = last_cache_free_pos[t.id] {
                if cp >= pos[last] {
                    continue;
                }
            }
            mem_events.push((finish[last], -(t.bytes as i64)));
        }

        // --- aggregates (mirrors `simulate`) -----------------------------
        let makespan = finish.iter().copied().fold(0.0f64, f64::max);
        let compute_busy: f64 = intervals
            .iter()
            .filter(|iv| iv.stream == Stream::Compute)
            .map(|iv| iv.finish_us - iv.start_us)
            .sum();
        let recompute_busy: f64 = intervals
            .iter()
            .filter(|iv| iv.stream == Stream::Compute && graph.op(iv.op).recompute)
            .map(|iv| iv.finish_us - iv.start_us)
            .sum();
        let dma_busy: f64 = intervals
            .iter()
            .filter(|iv| matches!(iv.stream, Stream::DmaIn | Stream::DmaOut))
            .map(|iv| iv.finish_us - iv.start_us)
            .sum();

        let mut exposed = 0.0f64;
        let mut prev_compute_finish = 0.0f64;
        for &op_id in order {
            let op = graph.op(op_id);
            if stream_of(&op.kind) != Stream::Compute {
                continue;
            }
            let gap_start = prev_compute_finish;
            let s = start[op_id];
            if s > gap_start {
                let mut dma_ready = graph
                    .preds(op_id)
                    .iter()
                    .filter(|&&p| {
                        matches!(stream_of(&graph.op(p).kind), Stream::DmaIn | Stream::DmaOut)
                    })
                    .map(|&p| finish[p])
                    .fold(0.0f64, f64::max);
                for &(o, d) in extra_deps {
                    if o == op_id
                        && matches!(stream_of(&graph.op(d).kind), Stream::DmaIn | Stream::DmaOut)
                    {
                        dma_ready = dma_ready.max(finish[d]);
                    }
                }
                exposed += (dma_ready.min(s) - gap_start).max(0.0);
            }
            prev_compute_finish = finish[op_id];
        }
        let overlapped = (dma_busy - exposed).max(0.0);

        mem_events.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let mut cur: i64 = 0;
        let mut peak: i64 = 0;
        let mut residency = Vec::with_capacity(mem_events.len());
        for (t, d) in mem_events {
            cur += d;
            peak = peak.max(cur);
            residency.push((t, cur.max(0) as u64));
        }

        // Per-tier peaks, same free-before-alloc tie rule as `simulate`.
        let mut tier_peaks = Vec::new();
        if let Some(t) = topo {
            for (i, tier) in t.tiers.iter().enumerate().skip(1) {
                let mut ev = std::mem::take(&mut tier_events[i]);
                ev.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
                let mut cur: i64 = 0;
                let mut peak: i64 = 0;
                for (_, d) in ev {
                    cur += d;
                    peak = peak.max(cur);
                }
                tier_peaks.push((*tier, peak.max(0) as u64));
            }
        }

        SimResult {
            makespan_us: makespan,
            compute_busy_us: compute_busy,
            recompute_us: recompute_busy,
            exposed_comm_us: exposed,
            overlapped_comm_us: overlapped,
            dma_busy_us: dma_busy,
            dma_bytes,
            peak_device_bytes: peak.max(0) as u64,
            residency,
            tier_peaks,
            cold_dma_bytes,
            intervals,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;

    fn hw() -> HwConfig {
        HwConfig::test_default()
    }

    fn assert_bit_identical(a: &SimResult, b: &SimResult) {
        assert_eq!(a.makespan_us.to_bits(), b.makespan_us.to_bits());
        assert_eq!(a.peak_device_bytes, b.peak_device_bytes);
        assert_eq!(a.dma_bytes, b.dma_bytes);
        assert_eq!(a.exposed_comm_us.to_bits(), b.exposed_comm_us.to_bits());
        assert_eq!(a.dma_busy_us.to_bits(), b.dma_busy_us.to_bits());
        assert_eq!(a.residency.len(), b.residency.len());
        for (x, y) in a.residency.iter().zip(b.residency.iter()) {
            assert_eq!(x.0.to_bits(), y.0.to_bits());
            assert_eq!(x.1, y.1);
        }
    }

    #[test]
    fn resume_from_zero_matches_full_simulation() {
        let (mut g, ws) = GraphBuilder::chain_with_remote_weights(6, 5e6, 0, 2000);
        for (i, &w) in ws.iter().enumerate() {
            let pf = g.add_op(format!("pf.{i}"), OpKind::prefetch(w), vec![w], vec![]);
            g.add_control_dep(i, pf);
        }
        let order = g.topo_order().unwrap();
        let trace = SimTrace::record(&g, &order, &hw());
        let full = simulate(&g, &order, &hw());
        assert_bit_identical(&trace.base, &full);
        for cut in [0, 1, order.len() / 2, order.len()] {
            let resumed = trace.resume(cut, &g, &order, &hw(), &[]);
            assert_bit_identical(&resumed, &full);
        }
    }

    #[test]
    fn resume_with_extra_dep_matches_mutated_graph() {
        let (mut g, ws) = GraphBuilder::chain_with_remote_weights(4, 5e6, 0, 2000);
        let mut pfs = Vec::new();
        for (i, &w) in ws.iter().enumerate() {
            let pf = g.add_op(format!("pf.{i}"), OpKind::prefetch(w), vec![w], vec![]);
            g.add_control_dep(i, pf);
            pfs.push(pf);
        }
        let order = g.topo_order().unwrap();
        let trace = SimTrace::record(&g, &order, &hw());
        // Probe "pf.3 also waits on compute op 1" without mutating g.
        let (pf3, anchor) = (pfs[3], 1usize);
        let mut pos = vec![0usize; g.ops.len()];
        for (i, &o) in order.iter().enumerate() {
            pos[o] = i;
        }
        // Move pf3 just after the anchor so the probed order stays valid.
        let mut cand: Vec<OpId> = order.clone();
        cand.retain(|&o| o != pf3);
        let a_idx = cand.iter().position(|&o| o == anchor).unwrap();
        cand.insert(a_idx + 1, pf3);
        let cut = pos[pf3].min(a_idx + 1);
        let probed = trace.resume(cut, &g, &cand, &hw(), &[(pf3, anchor)]);
        let mut gm = g.clone();
        gm.add_control_dep(pf3, anchor);
        assert!(gm.is_valid_order(&cand));
        let full = simulate(&gm, &cand, &hw());
        assert_bit_identical(&probed, &full);
    }
}
