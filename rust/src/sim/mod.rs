//! Discrete-event simulation of SuperNode execution (DESIGN.md §2).
//!
//! The simulator is the measurement substrate for every paper table/figure
//! that the real CPU-PJRT path cannot produce (bandwidth sweeps, 8-device
//! training steps, terabyte pools). Costs are analytic (roofline compute,
//! bandwidth+latency transfers); results are *shape-faithful*, not
//! absolute-number-faithful.

mod engine;
mod hw;
mod window;

pub use engine::{duration_us, simulate, stream_of, Interval, SimResult, Stream};
pub use hw::{Fabric, HwConfig, GB, MB};
pub use window::SimTrace;
