//! Discrete-event simulation of SuperNode execution (DESIGN.md §2).
//!
//! The simulator is the measurement substrate for every paper table/figure
//! that the real CPU-PJRT path cannot produce (bandwidth sweeps, 8-device
//! training steps, terabyte pools). Costs are analytic (roofline compute,
//! bandwidth+latency transfers); results are *shape-faithful*, not
//! absolute-number-faithful.
//!
//! ## The tier stack
//!
//! Hardware is described by [`HwConfig`]. Historically that meant exactly
//! two memory levels — HBM ("device") and the fabric-attached pool
//! ("remote") — and the flat `d2r_gbps`/`r2d_gbps`/`link_latency_us`
//! numbers still describe that edge. An optional [`TierTopology`]
//! (`HwConfig::with_tiers`) generalises the stack to an ordered list of
//! tiers — device, pool, then any of DRAM / CXL / SSD below it — with a
//! [`TierLink`] (bandwidth each way + latency) per adjacent pair and a
//! capacity per tier. Transfer costs between non-adjacent tiers are the
//! *path* cost: the sum of per-hop latencies plus one serialisation term
//! at the bottleneck hop's bandwidth (`TierTopology::path_us`).
//!
//! The simulator charges each cache op on the right edge: `Prefetch`
//! pulls from its source tier to device, `Store` pushes to its
//! destination tier, and `Promote` moves a cold copy between non-device
//! tiers on its own `Stream::ColdDma` engine without touching device
//! residency. With tiers configured, [`SimResult::tier_peaks`] reports
//! the peak resident bytes per non-device tier and
//! [`SimResult::cold_dma_bytes`] the bytes moved on the cold fabric.
//! `HwConfig { tiers: None, .. }` is the legacy two-level machine and is
//! bit-identical to the pre-tier simulator.

mod engine;
mod hw;
mod window;

pub use engine::{duration_us, simulate, stream_of, Interval, SimResult, Stream};
pub use hw::{Fabric, HwConfig, PeerLink, TierLink, TierTopology, GB, MB};
pub use window::SimTrace;
