//! L3 coordinator: ties the runtime (real PJRT execution), the KV-cache
//! manager, and the serving metrics together — the process a deployment
//! would actually run. The `hyperoffload` binary and `examples/serve_llm`
//! drive this.
//!
//! Real compute, modelled memory: token generation runs the AOT-compiled
//! transformer on the PJRT CPU client; KV residency/transfer timing is
//! accounted by the same hierarchical-memory model the benches use (the
//! CPU host has no NPU HBM to fragment — DESIGN.md §2 records the
//! substitution).

use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::kvcache::{KvCacheManager, KvPolicy, NsaConfig};
use crate::runtime::ModelRuntime;
use crate::serving::{stats, Stats};
use crate::sim::{HwConfig, GB};
use crate::util::rng::Rng;

/// Configuration for a real-execution serving run.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub artifacts_dir: std::path::PathBuf,
    /// Total requests to serve (waves of the artifact's static batch).
    pub n_requests: usize,
    /// Tokens to generate per request.
    pub gen_tokens: usize,
    /// KV residency policy (AllDevice baseline vs FullOffload).
    pub kv_policy: KvPolicy,
    pub seed: u64,
}

impl ServeConfig {
    pub fn new(artifacts_dir: impl Into<std::path::PathBuf>) -> Self {
        Self {
            artifacts_dir: artifacts_dir.into(),
            n_requests: 16,
            gen_tokens: 32,
            kv_policy: KvPolicy::FullOffload,
            seed: 7,
        }
    }
}

/// Outcome of a real serving run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub requests: usize,
    pub waves: usize,
    pub prefill_ms: Stats,
    pub decode_step_ms: Stats,
    pub tokens_generated: u64,
    pub wall_ms: f64,
    pub throughput_tok_s: f64,
    /// Modelled KV transfer volume (bytes) under the chosen policy.
    pub kv_transfer_bytes: u64,
    /// Modelled device-side KV footprint peak (bytes).
    pub kv_device_peak: u64,
    /// Sample of generated token ids (first sequence) for smoke checking.
    pub sample_tokens: Vec<i32>,
}

/// The coordinator: owns the compiled model and the KV manager.
pub struct Coordinator {
    pub model: ModelRuntime,
    pub kv: KvCacheManager,
    pub hw: HwConfig,
}

impl Coordinator {
    pub fn load(artifacts_dir: &Path, kv_policy: KvPolicy) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let model = ModelRuntime::load(&client, artifacts_dir)
            .with_context(|| format!("loading artifacts from {}", artifacts_dir.display()))?;
        let hw = HwConfig::ascend910c_like();
        let nsa = NsaConfig {
            block_tokens: model.spec.kv_block,
            num_selected: 2,
            sliding_tokens: model.spec.kv_block,
            ..Default::default()
        };
        let kv = KvCacheManager::new(
            kv_policy,
            nsa,
            model.spec.kv_bytes_per_token(),
            GB, // device KV budget for the toy model
        );
        Ok(Self { model, kv, hw })
    }

    /// Serve `cfg.n_requests` requests in waves of the static batch size,
    /// greedy decoding, measuring real execution latencies.
    pub fn serve(mut self, cfg: &ServeConfig) -> Result<ServeReport> {
        let spec = self.model.spec.clone();
        let b = spec.batch;
        let p = spec.prefill_len;
        let gen = cfg.gen_tokens.min(spec.max_seq - p - 1);
        let waves = cfg.n_requests.div_ceil(b);

        let mut rng = Rng::new(cfg.seed);
        let mut prefill_ms = Vec::new();
        let mut decode_ms = Vec::new();
        let mut kv_transfer = 0u64;
        let mut sample_tokens = Vec::new();
        let t0 = Instant::now();
        let mut total_tokens = 0u64;

        for wave in 0..waves {
            // Seeded prompts (vocab ids 1..V, 0 is pad).
            let tokens: Vec<i32> = (0..b * p)
                .map(|_| rng.gen_range(1, spec.vocab as u64) as i32)
                .collect();

            // Admit sequences to the KV manager.
            for s in 0..b {
                let seq = (wave * b + s) as u64;
                let admit = self.kv.admit(seq, p, &self.hw)?;
                kv_transfer += admit.d2r_bytes + admit.r2d_bytes;
            }

            // Real prefill.
            let tp = Instant::now();
            let (logits, mut kc, mut vc) = self.model.run_prefill(&tokens)?;
            prefill_ms.push(tp.elapsed().as_secs_f64() * 1e3);

            let mut next = self.model.argmax_tokens(&logits);
            // Greedy decode loop.
            for step in 0..gen {
                let pos = (p + step) as i32;
                let td = Instant::now();
                let (logits, kc2, vc2) = self.model.run_decode(&next, pos, &kc, &vc)?;
                decode_ms.push(td.elapsed().as_secs_f64() * 1e3);
                kc = kc2;
                vc = vc2;
                next = self.model.argmax_tokens(&logits);
                if wave == 0 {
                    sample_tokens.push(next[0]);
                }
                for s in 0..b {
                    let seq = (wave * b + s) as u64;
                    let c = self.kv.decode_step(seq, &self.hw)?;
                    kv_transfer += c.r2d_bytes + c.d2r_bytes;
                }
                total_tokens += b as u64;
            }

            for s in 0..b {
                self.kv.retire((wave * b + s) as u64)?;
            }
        }

        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        Ok(ServeReport {
            requests: waves * b,
            waves,
            prefill_ms: stats(&prefill_ms),
            decode_step_ms: stats(&decode_ms),
            tokens_generated: total_tokens,
            wall_ms,
            throughput_tok_s: total_tokens as f64 / (wall_ms / 1e3),
            kv_transfer_bytes: kv_transfer,
            kv_device_peak: self.kv.peak_device_kv,
            sample_tokens,
        })
    }
}
