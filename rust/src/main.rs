//! `hyperoffload` CLI — the L3 leader entrypoint.
//!
//! Subcommands (hand-rolled parsing; clap is not in the offline mirror):
//!   serve      real-execution serving demo over the AOT artifacts
//!   train-sim  baseline vs hierarchical training step for a preset
//!   graph-demo the compile pipeline on a synthetic graph, with timeline
//!   ha-sim     checkpoint vs pool recovery comparison
//!   info       artifact + platform info

use anyhow::{bail, Result};

use hyperoffload::graph::GraphBuilder;
use hyperoffload::ha;
use hyperoffload::passes::Compiler;
use hyperoffload::sim::{simulate, HwConfig, GB};
use hyperoffload::training::{baseline_step, hierarchical_step, ModelPreset, ParallelCfg};
use hyperoffload::util::table::{f, Table};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let flag = |name: &str| -> Option<String> {
        args.iter()
            .position(|a| a == name)
            .and_then(|i| args.get(i + 1))
            .cloned()
    };
    #[cfg(feature = "xla")]
    let has = |name: &str| args.iter().any(|a| a == name);

    match cmd {
        #[cfg(not(feature = "xla"))]
        "serve" | "info" => {
            bail!(
                "`{cmd}` needs real PJRT execution: rebuild with `--features xla` \
                 (requires the vendored xla crate, see Cargo.toml)"
            );
        }
        #[cfg(feature = "xla")]
        "serve" => {
            use hyperoffload::coordinator::{Coordinator, ServeConfig};
            use hyperoffload::kvcache::KvPolicy;
            let dir = flag("--artifacts").unwrap_or_else(|| "artifacts".into());
            let mut cfg = ServeConfig::new(std::path::PathBuf::from(&dir));
            if let Some(n) = flag("--requests") {
                cfg.n_requests = n.parse()?;
            }
            if let Some(g) = flag("--gen") {
                cfg.gen_tokens = g.parse()?;
            }
            if has("--no-offload") {
                cfg.kv_policy = KvPolicy::AllDevice;
            }
            let coord = Coordinator::load(&cfg.artifacts_dir, cfg.kv_policy)?;
            println!(
                "loaded model: {} layers, d={}, vocab={}, batch={}, max_seq={}",
                coord.model.spec.n_layers,
                coord.model.spec.d_model,
                coord.model.spec.vocab,
                coord.model.spec.batch,
                coord.model.spec.max_seq
            );
            let r = coord.serve(&cfg)?;
            let mut t = Table::new("real-execution serving (PJRT CPU)", &["metric", "value"]);
            t.row(&["requests".into(), r.requests.to_string()]);
            t.row(&["waves".into(), r.waves.to_string()]);
            t.row(&["prefill mean (ms)".into(), f(r.prefill_ms.mean, 2)]);
            t.row(&["decode step mean (ms)".into(), f(r.decode_step_ms.mean, 2)]);
            t.row(&["decode step p99 (ms)".into(), f(r.decode_step_ms.p99, 2)]);
            t.row(&["tokens generated".into(), r.tokens_generated.to_string()]);
            t.row(&["throughput (tok/s)".into(), f(r.throughput_tok_s, 1)]);
            t.row(&["KV transfer (modelled, MB)".into(), f(r.kv_transfer_bytes as f64 / 1e6, 1)]);
            t.row(&["KV device peak (modelled, MB)".into(), f(r.kv_device_peak as f64 / 1e6, 1)]);
            t.print();
            println!("sample tokens: {:?}", &r.sample_tokens[..r.sample_tokens.len().min(16)]);
        }
        "train-sim" => {
            let model = flag("--model").unwrap_or_else(|| "llama8b".into());
            let bw: f64 = flag("--bandwidth").map(|s| s.parse()).transpose()?.unwrap_or(33.6);
            let hw = HwConfig::ascend910c_like().with_pool_bandwidth(bw);
            let (preset, base_cfg, hier_cfg) = match model.as_str() {
                "llama8b" => (ModelPreset::llama8b(), ParallelCfg::llama_no2(), ParallelCfg::llama_hier()),
                "dsv3" => (ModelPreset::deepseek_v3_like(), ParallelCfg::dsv3_baseline(), ParallelCfg::dsv3_hier()),
                other => bail!("unknown model {other} (llama8b|dsv3)"),
            };
            let base = baseline_step(&preset, &base_cfg, &hw);
            let hier = hierarchical_step(&preset, &hier_cfg, &hw);
            let mut t = Table::new(
                format!("{} training step @ {bw} GB/s pool bandwidth", preset.name),
                &["config", "compute ms", "comm ms", "exposed d2h", "overlapped", "stalls", "total ms", "peak GB"],
            );
            for (name, b) in [("baseline", &base), ("hierarchical", &hier)] {
                t.row(&[
                    name.into(),
                    f(b.compute_ms + b.recompute_ms, 1),
                    f(b.comm_ms, 1),
                    f(b.exposed_d2h_ms, 1),
                    f(b.overlapped_d2h_ms, 1),
                    f(b.stall_ms, 1),
                    f(b.total_ms, 1),
                    f(b.peak_bytes / 1e9, 1),
                ]);
            }
            t.print();
        }
        "graph-demo" => {
            let hw = HwConfig::ascend910c_like();
            let (mut g, _) = GraphBuilder::chain_with_remote_weights(8, 50e12, 0, 4 * GB / 10);
            let report = Compiler::new(hw.clone()).verify(true).compile(&mut g)?;
            let sim = simulate(&g, &report.order, &hw);
            println!(
                "ops={} cache_ops={} moved={} makespan={:.1}ms exposed={:.2}ms overlap={:.0}%",
                g.ops.len(),
                g.cache_ops().len(),
                report.moved,
                sim.makespan_us / 1e3,
                sim.exposed_comm_us / 1e3,
                sim.overlap_efficiency() * 100.0
            );
            for p in &report.per_pass {
                println!(
                    "  pass {:<24} inserted={} rejected={} moved={}",
                    p.pass,
                    p.inserted.len(),
                    p.rejected,
                    p.moved
                );
            }
        }
        "ha-sim" => {
            let hw = HwConfig::ascend910c_like();
            let state = ha::StateFootprint { weights: 16 * GB, optimizer: 8 * GB };
            let r = ha::failure_campaign(state, &ha::CheckpointCfg::default(), &hw, 100, 13);
            let mut t = Table::new(
                "recovery comparison (100 injected failures)",
                &["path", "mean recovery (s)", "lost steps"],
            );
            t.row(&["checkpoint".into(), f(r.mean_ckpt_recovery_s, 1), r.total_lost_steps_ckpt.to_string()]);
            t.row(&["pool-resident".into(), f(r.mean_pool_recovery_s, 1), r.total_lost_steps_pool.to_string()]);
            t.print();
        }
        #[cfg(feature = "xla")]
        "info" => {
            let client = xla::PjRtClient::cpu()?;
            println!("PJRT platform: {} ({} devices)", client.platform_name(), client.device_count());
        }
        _ => {
            println!(
                "hyperoffload — graph-driven hierarchical memory management\n\
                 usage: hyperoffload <serve|train-sim|graph-demo|ha-sim|info> [flags]\n\
                 \n\
                 serve      --artifacts DIR --requests N --gen N [--no-offload]\n\
                 train-sim  --model llama8b|dsv3 --bandwidth GBPS\n\
                 graph-demo\n\
                 ha-sim\n\
                 info"
            );
        }
    }
    Ok(())
}
