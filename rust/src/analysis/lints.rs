//! The lint registry: named analyzer findings with configurable levels,
//! and the mapping from a finished [`AnalysisReport`] to the compiler's
//! [`Diagnostic`] stream.
//!
//! Levels follow the rustc model: every lint ships a default
//! ([`LintSpec::default`]), a session overrides per name
//! ([`LintConfig::set`], surfaced as `Compiler::lint`), `Deny` findings
//! become [`Severity::Error`] diagnostics (a compile failure), `Warn`
//! findings become warnings (fatal only under `Compiler::deny_warnings`
//! or `--cfg strict_verify`), and `Allow` findings are dropped.

use crate::passes::{Diagnostic, Severity};

use super::{AnalysisReport, Finding};

pub const RACE_STORE_CONSUMER: &str = "race::store_consumer";
pub const RACE_ACQUIRE_ACQUIRE: &str = "race::acquire_acquire";
pub const RESIDENCY_NO_ACQUIRE: &str = "residency::no_acquire";
pub const RESIDENCY_USE_AFTER_RELEASE: &str = "residency::use_after_release";
pub const RESIDENCY_DOUBLE_RELEASE: &str = "residency::double_release";
pub const RESIDENCY_RELEASE_NONRESIDENT: &str = "residency::release_nonresident";
pub const CHUNK_SIBLING_RELEASE: &str = "chunk::sibling_release";
pub const LEDGER_LEAK: &str = "ledger::leak";
pub const PEAK_UNBOUNDED: &str = "peak::unbounded";
pub const TIER_COLD_READ: &str = "tier::cold_read";
pub const PEER_REVOKED_READ: &str = "peer::revoked_read";

/// Diagnostic pass label every TransferSan finding is reported under.
pub const PASS: &str = "transfer-san";

/// How a lint's findings surface.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LintLevel {
    /// Dropped.
    Allow,
    /// [`Severity::Warning`] — fatal only under `deny_warnings`.
    Warn,
    /// [`Severity::Error`] — fails the compile.
    Deny,
}

/// One registry entry.
#[derive(Debug, Clone, Copy)]
pub struct LintSpec {
    pub name: &'static str,
    pub default: LintLevel,
    /// One-line meaning.
    pub summary: &'static str,
    /// What makes it fire (the proved condition).
    pub trigger: &'static str,
}

/// Every lint TransferSan can emit. `Compiler::lint` names must come from
/// this table; unknown names are ignored.
pub const LINTS: &[LintSpec] = &[
    LintSpec {
        name: RESIDENCY_NO_ACQUIRE,
        default: LintLevel::Deny,
        summary: "reader of a non-resident tensor without a forced acquire",
        trigger: "no Prefetch (and no initial/produced residency) is forced before the reader",
    },
    LintSpec {
        name: RESIDENCY_USE_AFTER_RELEASE,
        default: LintLevel::Deny,
        summary: "read after a forced release with no re-acquire",
        trigger: "a Store/Detach is forced before the reader and no Prefetch is forced between",
    },
    LintSpec {
        name: RACE_STORE_CONSUMER,
        default: LintLevel::Deny,
        summary: "release races a consumer",
        trigger: "a Store/Detach and a reader of the same tensor are unordered \
                  (some linearization runs the release first)",
    },
    LintSpec {
        name: RESIDENCY_DOUBLE_RELEASE,
        default: LintLevel::Deny,
        summary: "double free of a device region",
        trigger: "two releases of one tensor with no re-acquire forced between them",
    },
    LintSpec {
        name: RESIDENCY_RELEASE_NONRESIDENT,
        default: LintLevel::Deny,
        summary: "release of bytes that were never device-resident",
        trigger: "no acquire is forced before the Store/Detach of a remote-home tensor",
    },
    LintSpec {
        name: CHUNK_SIBLING_RELEASE,
        default: LintLevel::Deny,
        summary: "chunk release can starve a reader of the parent region",
        trigger: "a chunk view's Store/Detach can run before a parent-region reader \
                  with no chunk re-acquire forced between",
    },
    LintSpec {
        name: TIER_COLD_READ,
        default: LintLevel::Deny,
        summary: "transfer reads a tensor from a tier its copy provably is not at",
        trigger: "a Store/Promote parking the copy at another tier is forced before the \
                  Prefetch/Promote with no corrective move to the read tier forced between \
                  (only enforced when a cold DRAM/CXL/SSD tier is involved)",
    },
    LintSpec {
        name: PEER_REVOKED_READ,
        default: LintLevel::Deny,
        summary: "peer fetch of a copy provably moved off the lender",
        trigger: "a Store/Promote parking the copy at another tier is forced before a \
                  Prefetch/Promote reading `Tier::Peer` with no corrective move back to \
                  the peer forced between (the revocation-demotion race)",
    },
    LintSpec {
        name: RACE_ACQUIRE_ACQUIRE,
        default: LintLevel::Warn,
        summary: "acquire of possibly already-resident bytes",
        trigger: "no release is forced between the acquire and a prior residency source \
                  (initial residency, the producer, or an earlier Prefetch)",
    },
    LintSpec {
        name: LEDGER_LEAK,
        default: LintLevel::Warn,
        summary: "acquired bytes with no forced release or use",
        trigger: "neither a Store/Detach nor a reader is forced after the Prefetch",
    },
    LintSpec {
        name: PEAK_UNBOUNDED,
        default: LintLevel::Allow,
        summary: "static residency bound exceeds device capacity",
        trigger: "the antichain peak bound is larger than HwConfig::device_capacity \
                  (the pinned order may still fit; the guarantee is order-robust)",
    },
];

/// Per-session lint levels: registry defaults plus overrides.
#[derive(Debug, Clone, Default)]
pub struct LintConfig {
    overrides: Vec<(&'static str, LintLevel)>,
}

impl LintConfig {
    /// Override `name`'s level. Unknown names are ignored (returns
    /// `false`) so configs stay forward-compatible across lint additions.
    pub fn set(&mut self, name: &str, level: LintLevel) -> bool {
        let Some(spec) = LINTS.iter().find(|s| s.name == name) else {
            return false;
        };
        if let Some(e) = self.overrides.iter_mut().find(|(n, _)| *n == spec.name) {
            e.1 = level;
        } else {
            self.overrides.push((spec.name, level));
        }
        true
    }

    /// Effective level for `name` (override, else registry default, else
    /// `Allow` for unregistered names).
    pub fn level_of(&self, name: &str) -> LintLevel {
        self.overrides
            .iter()
            .find(|(n, _)| *n == name)
            .map(|&(_, l)| l)
            .or_else(|| LINTS.iter().find(|s| s.name == name).map(|s| s.default))
            .unwrap_or(LintLevel::Allow)
    }
}

/// Lower a report into the compiler's diagnostic stream under `cfg`'s
/// levels. Always ends with one `Info` line carrying the static peak
/// bound, so a clean run still leaves an audit trail in the compile
/// report.
pub fn to_diagnostics(report: &AnalysisReport, cfg: &LintConfig) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for f in &report.findings {
        let severity = match cfg.level_of(f.lint) {
            LintLevel::Allow => continue,
            LintLevel::Warn => Severity::Warning,
            LintLevel::Deny => Severity::Error,
        };
        let d = Diagnostic::new(severity, PASS, format!("{}: {}", f.lint, f.message));
        out.push(match f.op {
            Some(op) => d.with_op(op),
            None => d,
        });
    }
    out.push(Diagnostic::info(
        PASS,
        format!(
            "static peak residency bound {} bytes over {} chain(s); device capacity {} bytes",
            report.peak_bound_bytes, report.chains, report.device_capacity
        ),
    ));
    out
}

/// Convenience for tests and tools: the registry entry for `name`.
pub fn spec(name: &str) -> Option<&'static LintSpec> {
    LINTS.iter().find(|s| s.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_are_unique_and_namespaced() {
        for (i, a) in LINTS.iter().enumerate() {
            assert!(a.name.contains("::"), "lint '{}' not namespaced", a.name);
            for b in &LINTS[i + 1..] {
                assert_ne!(a.name, b.name, "duplicate lint name");
            }
        }
    }

    #[test]
    fn config_overrides_and_ignores_unknown() {
        let mut cfg = LintConfig::default();
        assert_eq!(cfg.level_of(RACE_STORE_CONSUMER), LintLevel::Deny);
        assert_eq!(cfg.level_of(LEDGER_LEAK), LintLevel::Warn);
        assert_eq!(cfg.level_of(PEAK_UNBOUNDED), LintLevel::Allow);

        assert!(cfg.set(RACE_STORE_CONSUMER, LintLevel::Allow));
        assert!(cfg.set(PEAK_UNBOUNDED, LintLevel::Deny));
        assert_eq!(cfg.level_of(RACE_STORE_CONSUMER), LintLevel::Allow);
        assert_eq!(cfg.level_of(PEAK_UNBOUNDED), LintLevel::Deny);

        assert!(!cfg.set("race::not_a_lint", LintLevel::Deny));
        assert_eq!(cfg.level_of("race::not_a_lint"), LintLevel::Allow);
    }

    #[test]
    fn deny_becomes_error_warn_becomes_warning_allow_drops() {
        let report = AnalysisReport {
            findings: vec![
                Finding { lint: RACE_STORE_CONSUMER, op: Some(3), message: "x".into() },
                Finding { lint: LEDGER_LEAK, op: None, message: "y".into() },
                Finding { lint: PEAK_UNBOUNDED, op: None, message: "z".into() },
            ],
            peak_bound_bytes: 7,
            chains: 2,
            device_capacity: 100,
        };
        let diags = to_diagnostics(&report, &LintConfig::default());
        assert_eq!(diags.len(), 3, "allow-level finding must drop; info bound must stay");
        assert_eq!(diags[0].severity, Severity::Error);
        assert_eq!(diags[0].op, Some(3));
        assert!(diags[0].message.starts_with("race::store_consumer:"));
        assert_eq!(diags[1].severity, Severity::Warning);
        assert_eq!(diags[2].severity, Severity::Info);
        assert!(diags[2].message.contains("7 bytes"));
        assert!(diags.iter().all(|d| d.pass == PASS));
    }
}
