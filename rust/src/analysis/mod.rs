//! TransferSan — an order-robust static analyzer for the cache-op IR.
//!
//! The verifier (`passes::verify_ir`) checks one *pinned* execution order.
//! That is not enough: the runtime dispatches any dependency-consistent
//! linearization, so a schedule that is residency-safe in the order the
//! decision passes validated can still read an offloaded tensor, double
//! free a pool region, or race a Store against a consumer in another
//! valid order. TransferSan proves those properties for **all**
//! linearizations at once, without simulating any of them.
//!
//! ## The abstract domain
//!
//! Per managed tensor, the analyzer reasons in a small residency lattice:
//!
//! ```text
//!              ⊤ (unknown)
//!            /   |        \
//!      Device   Pool   Partial{chunks}     Released
//!            \   |        /
//!              ⊥ (impossible)
//! ```
//!
//! * `Device` — bytes resident in HBM (initial residency, a producer's
//!   allocation, or a completed `Prefetch`).
//! * `Pool` — bytes live in the remote pool (`Store` completed, or a
//!   remote-home tensor before its first `Prefetch`).
//! * `Partial{chunks}` — chunk views ([`alias_of`]) of the storage moved
//!   independently; the parent region is split between tiers.
//! * `Released` — dropped (`Detach`, or double-released storage).
//!
//! A concrete linearization walks each tensor through these states. The
//! analyzer computes, per (tensor, op) pair, the **join over every
//! linearization** of the states the tensor may be in when the op runs —
//! but it never enumerates orders. The join is decidable from the
//! happens-before relation alone: a reader is safe iff an acquire
//! (`Prefetch`, initial residency, or the producer's allocation) is
//! *forced* before it and no release (`Store`/`Detach`) can interleave
//! without a re-acquire. Those "forced before / possibly between"
//! questions are bitset-reachability queries against the shared
//! [`Reach`](crate::graph::Reach) matrices (ancestors + descendants over
//! the cache-op columns), the same structure the verifier uses — so the
//! whole analysis is a few bit tests per (cache op, consumer) pair and
//! stays cheap at 20k ops.
//!
//! Two-sided queries decide the interleavings: with `anc` the ancestor
//! matrix and `desc` the descendant matrix, "some acquire is forced
//! between release `r` and reader `o`" is `row_anc(o) ∩ row_desc(r) ∩
//! acquires ≠ ∅`; if `r` and `o` are *unordered*, no op can be forced
//! between them at all, and placing them adjacently is always realizable
//! — which is why unordered (release, reader) pairs are races outright.
//!
//! ## The lint registry
//!
//! Findings are reported through a rustc-style lint table
//! ([`LINTS`]) with per-session levels ([`LintConfig`],
//! `Compiler::lint`). Deny lints are proofs of a realizable failure;
//! Warn lints flag wasted transfers or unbalanced pool ledgers.
//!
//! | lint | default | fires when |
//! |------|---------|------------|
//! | `residency::no_acquire` | Deny | a reader of a non-resident-home tensor has no acquire forced before it |
//! | `residency::use_after_release` | Deny | a release is forced before a reader with no re-acquire forced between |
//! | `race::store_consumer` | Deny | a release and a reader are unordered (adjacent placement realizable) |
//! | `residency::double_release` | Deny | two releases with no re-acquire forced between (or unordered) |
//! | `residency::release_nonresident` | Deny | a release with no acquire forced before it on a never-resident tensor |
//! | `chunk::sibling_release` | Deny | a chunk view's release can overtake a reader of the parent region |
//! | `race::acquire_acquire` | Warn | an acquire whose bytes may already be resident (no release forced since the prior source) |
//! | `ledger::leak` | Warn | an acquire with neither a release nor a reader forced after it |
//! | `peak::unbounded` | Allow | the static residency bound exceeds device capacity |
//!
//! ## The static peak bound
//!
//! [`analyze`] also reports an order-robust **upper bound** on peak device
//! residency ([`AnalysisReport::peak_bound_bytes`]): tensors are greedily
//! partitioned into chains such that within a chain, every alloc/free
//! event of one tensor is forced (happens-before) strictly before the
//! next tensor's first allocation — so no two tensors of a chain can ever
//! be resident simultaneously, in *any* linearization, and the bound is
//! the sum over chains of each chain's largest tensor. The simulator's
//! time-aware peak for any valid order is ≤ this bound (property P15);
//! the bound is deliberately loose (it ignores transfer timing) — it is
//! the capacity guarantee a scheduler may rely on before picking an
//! order.
//!
//! ## Writing a new lint
//!
//! A lint is (1) a registry entry and (2) a check in
//! [`sanitizer::analyze`] that pushes a [`Finding`] with the registered
//! name. For example, a lint flagging `Detach` of a tensor that was never
//! device-resident:
//!
//! ```text
//! // lints.rs — register it:
//! pub const DETACH_COLD: &str = "residency::detach_cold";
//! LintSpec { name: DETACH_COLD, default: LintLevel::Warn,
//!            summary: "Detach of a never-resident tensor",
//!            trigger: "no acquire is forced before the Detach" },
//!
//! // sanitizer.rs — inside the per-tensor loop:
//! for &r in releases {
//!     if matches!(g.op(r).kind, OpKind::Detach { .. })
//!         && !anc.row_intersects(r, &acquire_mask)
//!     {
//!         findings.push(Finding {
//!             lint: lints::DETACH_COLD,
//!             op: Some(r),
//!             message: format!("detach of cold '{}'", tensor.name),
//!         });
//!     }
//! }
//! ```
//!
//! Severity mapping, `allow`/`warn`/`deny` overrides and the
//! `deny_warnings` compile mode come for free from
//! [`to_diagnostics`] — the sanitizer never constructs
//! [`Diagnostic`](crate::passes::Diagnostic)s itself.
//!
//! Run the analyzer as a pipeline stage with `Compiler::sanitize(true)`
//! (or build with `--cfg strict_verify`, which forces it after every
//! pass and promotes warnings to failures).
//!
//! [`alias_of`]: crate::graph::TensorInfo::alias_of

pub mod lints;
pub mod sanitizer;

pub use lints::{to_diagnostics, LintConfig, LintLevel, LintSpec, LINTS};
pub use sanitizer::analyze;

use crate::graph::OpId;

/// One lint hit: a named, op-anchored fact the analyzer proved about the
/// graph. Severity is *not* part of a finding — the session's
/// [`LintConfig`] decides that at diagnostic time.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Registered lint name (`LINTS` entry), e.g. `race::store_consumer`.
    pub lint: &'static str,
    /// The op the finding anchors to (the reader for residency lints, the
    /// offending cache op otherwise). `None` for graph-wide findings.
    pub op: Option<OpId>,
    pub message: String,
}

/// Everything one [`analyze`] run proved.
#[derive(Debug, Clone)]
pub struct AnalysisReport {
    /// Lint hits, in tensor-id order.
    pub findings: Vec<Finding>,
    /// Order-robust upper bound on peak device residency (bytes): the
    /// simulator's peak under any valid linearization is at most this.
    pub peak_bound_bytes: u64,
    /// Number of antichain-free tensor chains backing the bound.
    pub chains: usize,
    /// Device capacity the bound was judged against (`HwConfig`).
    pub device_capacity: u64,
}
