//! The TransferSan core: per-tensor residency checks and the order-robust
//! peak bound, all answered from the shared [`Reach`] matrices.
//!
//! Notation used throughout: `x ⇝ y` means `x` happens-before `y` in
//! **every** valid linearization (a dependency path exists). `anc` is the
//! ancestor matrix (row(o) = tracked cache ops forced at-or-before `o`),
//! `desc` the descendant matrix (row(o) = tracked cache ops forced
//! at-or-after `o`). Two facts carry most of the analysis:
//!
//! 1. "some acquire is forced between `a` and `b`" ⇔
//!    `row_anc(b) ∩ row_desc(a) ∩ acquires ≠ ∅` — an op in both rows is
//!    after `a` and before `b` in every order.
//! 2. If `a` and `b` are *unordered*, nothing can be forced between them
//!    (it would transitively order them), and scheduling them adjacently
//!    in either direction is realizable — so unordered (release, reader)
//!    and (release, release) pairs are violations outright, no
//!    interleaving analysis needed.

use crate::graph::{Graph, OpId, OpKind, Reach, Tier, TrackedSet};
use crate::sim::HwConfig;

use super::lints;
use super::{AnalysisReport, Finding};

/// Chains scanned per tensor when building the peak bound. First-fit over
/// a bounded window keeps the partition O(tensors × cap) — beyond the cap
/// a tensor just opens a new chain (the bound gets looser, never wrong).
const CHAIN_SCAN_CAP: usize = 64;

/// Run every registered lint plus the static peak bound over `g`.
///
/// `order` must be a valid topological order (only used to orient the
/// sweeps — the result is order-robust, since reachability is a property
/// of the DAG, not of the chosen linearization). `anc` is the cache-op
/// ancestor matrix for exactly this graph (the compiler session shares
/// its cached copy); the descendant matrix is built here (it is not
/// incrementally patchable, and one reverse sweep is cheap).
pub fn analyze(g: &Graph, order: &[OpId], anc: &Reach, hw: &HwConfig) -> AnalysisReport {
    let desc = Reach::descendants(g, order, TrackedSet::CacheOps);

    // Cache ops per tensor, in op-id order.
    let nt = g.tensors.len();
    let mut acquires: Vec<Vec<OpId>> = vec![Vec::new(); nt];
    let mut releases: Vec<Vec<OpId>> = vec![Vec::new(); nt];
    for op in &g.ops {
        match op.kind {
            OpKind::Prefetch { tensor, .. } => acquires[tensor].push(op.id),
            OpKind::Store { tensor, .. } | OpKind::Detach { tensor } => {
                releases[tensor].push(op.id)
            }
            _ => {}
        }
    }

    let mut findings = Vec::new();
    for t in &g.tensors {
        let acq = &acquires[t.id];
        let rel = &releases[t.id];
        let readers: Vec<OpId> = g
            .consumers_of(t.id)
            .iter()
            .copied()
            .filter(|&c| !g.op(c).kind.is_cache_op())
            .collect();
        let managed = !acq.is_empty() || !rel.is_empty();
        // Unmanaged tensors are the static planner's business, same as the
        // verifier: a split rewrite may retire a tensor's transfers and
        // move its bytes through replacement chunk tensors, keeping the
        // original input edges only as logical-value bookkeeping.
        if !managed {
            continue;
        }
        let producer = g.producer_of(t.id);
        // Residency sources that need no acquire: device-home bytes are
        // resident from t=0 (graph inputs) or from the producer's
        // allocation — and every cache op and reader of a produced tensor
        // has a data edge from the producer, so production is always
        // forced first.
        let init = t.home == Tier::Device && producer.is_none();
        let produced_on_device = t.home == Tier::Device && producer.is_some();
        let mask_a = anc.mask(acq.iter().copied());
        let mask_r = anc.mask(rel.iter().copied());

        // -- residency::no_acquire ------------------------------------
        if !init && !produced_on_device {
            for &o in &readers {
                if !anc.row_intersects(o, &mask_a) {
                    findings.push(Finding {
                        lint: lints::RESIDENCY_NO_ACQUIRE,
                        op: Some(o),
                        message: format!(
                            "'{}' reads '{}' (home {:?}) with no prefetch forced before it",
                            g.op(o).name, t.name, t.home
                        ),
                    });
                }
            }
        }

        // -- residency::use_after_release / race::store_consumer ------
        for &r in rel {
            for &o in &readers {
                if anc.contains(o, r) {
                    // r ⇝ o: the reader needs a re-acquire forced between.
                    if !anc.rows_intersect(o, &desc, r, &mask_a) {
                        findings.push(Finding {
                            lint: lints::RESIDENCY_USE_AFTER_RELEASE,
                            op: Some(o),
                            message: format!(
                                "'{}' reads '{}' after '{}' released it, with no \
                                 re-acquire forced between",
                                g.op(o).name, t.name, g.op(r).name
                            ),
                        });
                    }
                } else if !desc.contains(o, r) {
                    // Unordered: r-then-o adjacent is realizable, and no
                    // acquire can be forced between unordered ops.
                    findings.push(Finding {
                        lint: lints::RACE_STORE_CONSUMER,
                        op: Some(o),
                        message: format!(
                            "release '{}' of '{}' is unordered against reader '{}'",
                            g.op(r).name, t.name, g.op(o).name
                        ),
                    });
                }
            }
        }

        // -- residency::double_release --------------------------------
        for (i, &r1) in rel.iter().enumerate() {
            for &r2 in &rel[i + 1..] {
                // Orient the pair if ordered; unordered pairs flag
                // unconditionally (the between-mask test is vacuously
                // false for them).
                let (first, second) = if anc.contains(r2, r1) {
                    (r1, r2)
                } else if anc.contains(r1, r2) {
                    (r2, r1)
                } else {
                    (r1, r2)
                };
                if !anc.rows_intersect(second, &desc, first, &mask_a) {
                    findings.push(Finding {
                        lint: lints::RESIDENCY_DOUBLE_RELEASE,
                        op: Some(second),
                        message: format!(
                            "'{}' and '{}' can both release '{}' with no re-acquire between",
                            g.op(first).name, g.op(second).name, t.name
                        ),
                    });
                }
            }
        }

        // -- residency::release_nonresident ---------------------------
        if !init && !produced_on_device {
            for &r in rel {
                if !anc.row_intersects(r, &mask_a) {
                    findings.push(Finding {
                        lint: lints::RESIDENCY_RELEASE_NONRESIDENT,
                        op: Some(r),
                        message: format!(
                            "'{}' releases '{}' (home {:?}), which has no acquire \
                             forced before it",
                            g.op(r).name, t.name, t.home
                        ),
                    });
                }
            }
        }

        // -- race::acquire_acquire ------------------------------------
        // An acquire is wasted (and the pool ledger double-counts) when
        // some linearization runs it while the bytes are already
        // device-resident: no release is forced between it and a prior
        // residency source.
        for &a2 in acq {
            if init && !anc.row_intersects(a2, &mask_r) {
                findings.push(Finding {
                    lint: lints::RACE_ACQUIRE_ACQUIRE,
                    op: Some(a2),
                    message: format!(
                        "'{}' re-loads initially-resident '{}' with no release forced first",
                        g.op(a2).name, t.name
                    ),
                });
            }
            if produced_on_device {
                let p = producer.expect("produced_on_device implies producer");
                if !anc.rows_intersect(a2, &desc, p, &mask_r) {
                    findings.push(Finding {
                        lint: lints::RACE_ACQUIRE_ACQUIRE,
                        op: Some(a2),
                        message: format!(
                            "'{}' re-loads '{}' with no release forced after its producer",
                            g.op(a2).name, t.name
                        ),
                    });
                }
            }
        }
        for (i, &x) in acq.iter().enumerate() {
            for &y in &acq[i + 1..] {
                let (a1, a2) = if anc.contains(y, x) {
                    (x, y)
                } else if anc.contains(x, y) {
                    (y, x)
                } else {
                    (x, y) // unordered: the between-test is vacuously false
                };
                if !anc.rows_intersect(a2, &desc, a1, &mask_r) {
                    findings.push(Finding {
                        lint: lints::RACE_ACQUIRE_ACQUIRE,
                        op: Some(a2),
                        message: format!(
                            "'{}' can re-load '{}' while '{}'s copy is still resident",
                            g.op(a2).name, t.name, g.op(a1).name
                        ),
                    });
                }
            }
        }

        // -- ledger::leak ---------------------------------------------
        for &a in acq {
            let released_after = desc.row_intersects(a, &mask_r);
            let read_after = readers.iter().any(|&o| anc.contains(o, a));
            if !released_after && !read_after {
                findings.push(Finding {
                    lint: lints::LEDGER_LEAK,
                    op: Some(a),
                    message: format!(
                        "'{}' loads '{}' but no release or reader is forced after it",
                        g.op(a).name, t.name
                    ),
                });
            }
        }

        // -- tier::cold_read ------------------------------------------
        // N-tier hierarchy: every transfer that reads the offloaded copy
        // (a Prefetch from `src`, a Promote out of `src`) must find it
        // there. A Store/Promote parks the copy at its destination tier;
        // a read from a different tier with no corrective move to the
        // read tier forced between is a cold read. Only enforced when a
        // cold (DRAM/CXL/SSD) tier is involved — the legacy Host/pool
        // conflation stays diagnostic-free.
        let movers: Vec<(OpId, Tier)> = g
            .ops
            .iter()
            .filter_map(|op| match op.kind {
                OpKind::Store { tensor, dst } if tensor == t.id => Some((op.id, dst)),
                OpKind::Promote { tensor, dst, .. } if tensor == t.id => Some((op.id, dst)),
                _ => None,
            })
            .collect();
        let tier_readers: Vec<(OpId, Tier)> = g
            .ops
            .iter()
            .filter_map(|op| match op.kind {
                OpKind::Prefetch { tensor, src } if tensor == t.id => Some((op.id, src)),
                OpKind::Promote { tensor, src, .. } if tensor == t.id => Some((op.id, src)),
                _ => None,
            })
            .collect();
        for &(a, src) in &tier_readers {
            let to_src =
                anc.mask(movers.iter().filter(|&&(m, d)| m != a && d == src).map(|&(m, _)| m));
            for &(m, d) in &movers {
                if m == a || d == src {
                    continue;
                }
                // The same structural proof backs two lints: the cold-tier
                // variant, and the peer variant — a fetch from borrowed
                // HBM after the copy provably moved off the lender (the
                // revocation-demotion race a stale lease would hit).
                let lint = if src.is_peer() || d.is_peer() {
                    lints::PEER_REVOKED_READ
                } else if d.is_cold() || src.is_cold() {
                    lints::TIER_COLD_READ
                } else {
                    continue;
                };
                if anc.contains(a, m) && !anc.rows_intersect(a, &desc, m, &to_src) {
                    findings.push(Finding {
                        lint,
                        op: Some(a),
                        message: format!(
                            "'{}' reads '{}' from tier {:?}, but '{}' parks the copy at \
                             {:?} with no move back forced between",
                            g.op(a).name,
                            t.name,
                            src,
                            g.op(m).name,
                            d
                        ),
                    });
                }
            }
            // Initial placement: the copy starts at the tensor's home
            // tier; reading another tier needs a mover to it first.
            let init_lint = if t.home.is_peer() || src.is_peer() {
                Some(lints::PEER_REVOKED_READ)
            } else if t.home.is_cold() || src.is_cold() {
                Some(lints::TIER_COLD_READ)
            } else {
                None
            };
            if t.home != Tier::Device
                && t.home != src
                && init_lint.is_some()
                && !anc.row_intersects(a, &to_src)
            {
                findings.push(Finding {
                    lint: init_lint.unwrap(),
                    op: Some(a),
                    message: format!(
                        "'{}' reads '{}' from tier {:?}, but the copy starts at its home \
                         tier {:?} and no move to {:?} is forced before it",
                        g.op(a).name,
                        t.name,
                        src,
                        t.home,
                        src
                    ),
                });
            }
        }

        // -- chunk::sibling_release -----------------------------------
        // A chunk view releases part of the parent's storage; readers of
        // the *whole* parent region need every chunk release ordered
        // after them or bridged by a chunk re-acquire. Sibling chunks are
        // disjoint byte ranges and need no cross-check.
        if let Some(parent) = t.alias_of {
            for &r in rel {
                for &o in g.consumers_of(parent) {
                    if g.op(o).kind.is_cache_op() {
                        continue;
                    }
                    let violation = if anc.contains(o, r) {
                        !anc.rows_intersect(o, &desc, r, &mask_a)
                    } else {
                        !desc.contains(o, r)
                    };
                    if violation {
                        findings.push(Finding {
                            lint: lints::CHUNK_SIBLING_RELEASE,
                            op: Some(o),
                            message: format!(
                                "chunk release '{}' of '{}' can run before '{}', which \
                                 reads the parent region '{}'",
                                g.op(r).name,
                                t.name,
                                g.op(o).name,
                                g.tensor(parent).name
                            ),
                        });
                    }
                }
            }
        }
    }

    // ---- static peak residency bound --------------------------------
    let (peak_bound_bytes, chains) = peak_bound(g, order, anc, &desc, &acquires, &releases);
    if hw.device_capacity > 0 && peak_bound_bytes > hw.device_capacity {
        findings.push(Finding {
            lint: lints::PEAK_UNBOUNDED,
            op: None,
            message: format!(
                "static residency bound {} bytes exceeds device capacity {} bytes",
                peak_bound_bytes, hw.device_capacity
            ),
        });
    }

    AnalysisReport { findings, peak_bound_bytes, chains, device_capacity: hw.device_capacity }
}

/// Greedy antichain/chain partition of device-resident tensors.
///
/// A tensor's device bytes only ever move at its *events*: the producer's
/// allocation (or t=0 for device-home inputs), each Prefetch's start,
/// each Store/Detach's finish, and the refcount free at its last
/// consumer (the simulator's accounting — cache ops count as consumers).
/// So if every event op of tensor `t1` is forced **strictly** before
/// tensor `t2`'s first-allocation op, then `t1`'s bytes are gone before
/// `t2`'s arrive, in every linearization — the two can share a chain and
/// only the larger counts toward the bound. Tensors the simulator never
/// frees (no consumers, no releases) terminate their chain.
///
/// Returns `(bound_bytes, chain_count)`.
fn peak_bound(
    g: &Graph,
    order: &[OpId],
    anc: &Reach,
    desc: &Reach,
    acquires: &[Vec<OpId>],
    releases: &[Vec<OpId>],
) -> (u64, usize) {
    let mut pos = vec![usize::MAX; g.ops.len()];
    for (i, &o) in order.iter().enumerate() {
        pos[o] = i;
    }

    struct Cand {
        bytes: u64,
        /// Op whose start is the tensor's first allocation; `None` means
        /// resident from t=0 (device-home input) or no single provable
        /// first acquire — such tensors always open their own chain.
        start: Option<OpId>,
        sort_pos: usize,
        ends: Vec<OpId>,
        has_free: bool,
    }

    let mut cands: Vec<Cand> = Vec::new();
    for t in &g.tensors {
        if t.bytes == 0 {
            continue;
        }
        // Device-home chunk views move bytes *within* the parent's
        // allocation; the parent is counted in full.
        if t.alias_of.is_some() && t.home == Tier::Device {
            continue;
        }
        let producer = g.producer_of(t.id);
        let start = if t.home == Tier::Device {
            producer
        } else {
            match acquires[t.id].as_slice() {
                [] => continue, // never device-resident
                [a] => Some(*a),
                _ => None, // several acquires: no single provable first
            }
        };
        // Every op that can carry one of t's alloc/free events.
        let mut ends: Vec<OpId> = g.consumers_of(t.id).to_vec();
        if let Some(p) = producer {
            if !ends.contains(&p) {
                ends.push(p);
            }
        }
        for &x in acquires[t.id].iter().chain(releases[t.id].iter()) {
            if !ends.contains(&x) {
                ends.push(x);
            }
        }
        let has_free = !g.consumers_of(t.id).is_empty();
        let sort_pos = start.map(|s| pos[s]).unwrap_or(0);
        cands.push(Cand { bytes: t.bytes, start, sort_pos, ends, has_free });
    }
    cands.sort_by_key(|c| (c.sort_pos, std::cmp::Reverse(c.bytes)));

    struct Chain {
        tail_ends: Vec<OpId>,
        can_extend: bool,
        max_bytes: u64,
    }
    let mut chains: Vec<Chain> = Vec::new();
    for c in cands {
        let slot = c.start.and_then(|s| {
            let preds = g.preds(s); // sorted, for the untracked-op fallback
            chains.iter().take(CHAIN_SCAN_CAP).position(|ch| {
                ch.can_extend
                    && ch.tail_ends.iter().all(|&x| {
                        x != s
                            && (desc.contains(x, s) // s tracked: x ⇝ s
                                || anc.contains(s, x) // x tracked: x ⇝ s
                                || preds.binary_search(&x).is_ok())
                    })
            })
        });
        match slot {
            Some(i) => {
                let ch = &mut chains[i];
                ch.tail_ends = c.ends;
                ch.can_extend = c.has_free;
                ch.max_bytes = ch.max_bytes.max(c.bytes);
            }
            None => chains.push(Chain {
                tail_ends: c.ends,
                can_extend: c.has_free,
                max_bytes: c.bytes,
            }),
        }
    }
    (chains.iter().map(|c| c.max_bytes).sum(), chains.len())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::sim::simulate;

    fn hw() -> HwConfig {
        HwConfig::test_default()
    }

    fn run(g: &Graph) -> AnalysisReport {
        let order = g.topo_order().unwrap();
        let anc = Reach::ancestors(g, &order, TrackedSet::CacheOps);
        analyze(g, &order, &anc, &hw())
    }

    fn names(r: &AnalysisReport) -> Vec<&'static str> {
        r.findings.iter().map(|f| f.lint).collect()
    }

    fn denies(r: &AnalysisReport) -> Vec<&'static str> {
        let cfg = super::super::LintConfig::default();
        r.findings
            .iter()
            .map(|f| f.lint)
            .filter(|l| cfg.level_of(l) == super::super::LintLevel::Deny)
            .collect()
    }

    /// p ── c1 ── st ── pf ── c2: the canonical offload round trip.
    fn round_trip() -> Graph {
        let mut b = GraphBuilder::new();
        let w = b.tensor("w", 8 << 20, Tier::Device);
        b.compute("p", 1e9, 0, vec![], vec![w]);
        let c1 = b.compute("c1", 1e9, 0, vec![w], vec![]);
        let st = b.store("st", w);
        b.dep(st, c1);
        let pf = b.prefetch("pf", w);
        b.dep(pf, st);
        let c2 = b.compute("c2", 1e9, 0, vec![w], vec![]);
        b.dep(c2, pf);
        b.build()
    }

    #[test]
    fn clean_round_trip_has_no_findings() {
        let r = run(&round_trip());
        assert!(r.findings.is_empty(), "spurious findings: {:?}", r.findings);
    }

    #[test]
    fn unordered_release_and_reader_is_a_race() {
        // Same shape, but c2 waits on nothing cache-side: the store and
        // the second reader are unordered.
        let mut b = GraphBuilder::new();
        let w = b.tensor("w", 8 << 20, Tier::Device);
        b.compute("p", 1e9, 0, vec![], vec![w]);
        let c1 = b.compute("c1", 1e9, 0, vec![w], vec![]);
        let st = b.store("st", w);
        b.dep(st, c1);
        b.compute("c2", 1e9, 0, vec![w], vec![]);
        let g = b.build();
        let r = run(&g);
        assert!(names(&r).contains(&lints::RACE_STORE_CONSUMER), "got {:?}", r.findings);
    }

    #[test]
    fn forced_read_after_release_without_reacquire() {
        let mut b = GraphBuilder::new();
        let w = b.tensor("w", 8 << 20, Tier::Device);
        b.compute("p", 1e9, 0, vec![], vec![w]);
        let st = b.store("st", w);
        let c2 = b.compute("c2", 1e9, 0, vec![w], vec![]);
        b.dep(c2, st); // reader ordered after the release, no prefetch back
        let g = b.build();
        let r = run(&g);
        assert!(names(&r).contains(&lints::RESIDENCY_USE_AFTER_RELEASE), "got {:?}", r.findings);
    }

    #[test]
    fn reader_of_remote_tensor_without_forced_prefetch() {
        // One prefetch, two readers, only one of them waiting on it: the
        // other can dispatch while the bytes are still in flight. (The
        // exact gap the reactive runtime had before it wired every
        // consumer to the load.)
        let mut b = GraphBuilder::new();
        let w = b.tensor("w", 8 << 20, Tier::Remote);
        let pf = b.prefetch("pf", w);
        let c1 = b.compute("c1", 1e9, 0, vec![w], vec![]);
        b.dep(c1, pf);
        let c2 = b.compute("c2", 1e9, 0, vec![w], vec![]);
        let g = b.build();
        let r = run(&g);
        assert_eq!(names(&r), vec![lints::RESIDENCY_NO_ACQUIRE]);
        assert_eq!(r.findings[0].op, Some(c2));
    }

    #[test]
    fn double_release_needs_reacquire_between() {
        // Ordered st1 ⇝ st2 with no prefetch between: double free.
        let mut b = GraphBuilder::new();
        let w = b.tensor("w", 8 << 20, Tier::Device);
        b.compute("p", 1e9, 0, vec![], vec![w]);
        let st1 = b.store("st1", w);
        let st2 = b.store("st2", w);
        b.dep(st2, st1);
        let g = b.build();
        let r = run(&g);
        assert!(names(&r).contains(&lints::RESIDENCY_DOUBLE_RELEASE), "got {:?}", r.findings);

        // With a round trip between them, both releases are justified.
        let mut b = GraphBuilder::new();
        let w = b.tensor("w", 8 << 20, Tier::Device);
        b.compute("p", 1e9, 0, vec![], vec![w]);
        let st1 = b.store("st1", w);
        let pf = b.prefetch("pf", w);
        b.dep(pf, st1);
        let st2 = b.store("st2", w);
        b.dep(st2, pf);
        let g = b.build();
        let r = run(&g);
        assert!(!names(&r).contains(&lints::RESIDENCY_DOUBLE_RELEASE), "got {:?}", r.findings);
    }

    #[test]
    fn release_of_never_resident_remote_tensor() {
        let mut b = GraphBuilder::new();
        let w = b.tensor("w", 8 << 20, Tier::Remote);
        b.store("st", w);
        let g = b.build();
        let r = run(&g);
        assert!(names(&r).contains(&lints::RESIDENCY_RELEASE_NONRESIDENT), "got {:?}", r.findings);
    }

    #[test]
    fn duplicate_unordered_prefetch_warns() {
        let mut b = GraphBuilder::new();
        let w = b.tensor("w", 8 << 20, Tier::Remote);
        let pf1 = b.prefetch("pf1", w);
        b.prefetch("pf2", w);
        let c = b.compute("c", 1e9, 0, vec![w], vec![]);
        b.dep(c, pf1);
        let g = b.build();
        let r = run(&g);
        assert!(names(&r).contains(&lints::RACE_ACQUIRE_ACQUIRE), "got {:?}", r.findings);
    }

    #[test]
    fn consumerless_prefetch_leaks() {
        let mut b = GraphBuilder::new();
        let w = b.tensor("w", 8 << 20, Tier::Remote);
        b.prefetch("pf", w);
        let g = b.build();
        let r = run(&g);
        assert_eq!(names(&r), vec![lints::LEDGER_LEAK]);
    }

    #[test]
    fn demoted_then_read_without_promotion_is_denied() {
        // The store parks w at DRAM; the prefetch reads from the pool with
        // no promotion between — the canonical N-tier bug.
        let mut b = GraphBuilder::new();
        let w = b.tensor("w", 8 << 20, Tier::Device);
        let p = b.compute("p", 1e9, 0, vec![], vec![w]);
        let st = b.store_to("st", w, Tier::Dram);
        b.dep(st, p);
        let pf = b.prefetch("pf", w);
        b.dep(pf, st);
        let c2 = b.compute("c2", 1e9, 0, vec![w], vec![]);
        b.dep(c2, pf);
        let g = b.build();
        let r = run(&g);
        assert!(names(&r).contains(&lints::TIER_COLD_READ), "got {:?}", r.findings);
        assert!(denies(&r).contains(&lints::TIER_COLD_READ));

        // A promotion back to the pool, dependency-ordered between the
        // demotion and the prefetch, clears it.
        let mut b = GraphBuilder::new();
        let w = b.tensor("w", 8 << 20, Tier::Device);
        let p = b.compute("p", 1e9, 0, vec![], vec![w]);
        let st = b.store_to("st", w, Tier::Dram);
        b.dep(st, p);
        let pm = b.promote("pm", w, Tier::Dram, Tier::Remote);
        b.dep(pm, st);
        let pf = b.prefetch("pf", w);
        b.dep(pf, pm);
        let c2 = b.compute("c2", 1e9, 0, vec![w], vec![]);
        b.dep(c2, pf);
        let g = b.build();
        let r = run(&g);
        assert!(!names(&r).contains(&lints::TIER_COLD_READ), "got {:?}", r.findings);
    }

    #[test]
    fn cold_home_tensor_read_from_wrong_tier_is_denied() {
        // An SSD-home input prefetched straight from the pool: the copy
        // was never moved up, so the read is cold.
        let mut b = GraphBuilder::new();
        let w = b.tensor("w", 8 << 20, Tier::Ssd);
        let pf = b.prefetch("pf", w); // legacy constructor: src = pool
        let c = b.compute("c", 1e9, 0, vec![w], vec![]);
        b.dep(c, pf);
        let g = b.build();
        let r = run(&g);
        assert!(names(&r).contains(&lints::TIER_COLD_READ), "got {:?}", r.findings);

        // Promoting SSD → pool before the prefetch clears it.
        let mut b = GraphBuilder::new();
        let w = b.tensor("w", 8 << 20, Tier::Ssd);
        let pm = b.promote("pm", w, Tier::Ssd, Tier::Remote);
        let pf = b.prefetch("pf", w);
        b.dep(pf, pm);
        let c = b.compute("c", 1e9, 0, vec![w], vec![]);
        b.dep(c, pf);
        let g = b.build();
        let r = run(&g);
        assert!(!names(&r).contains(&lints::TIER_COLD_READ), "got {:?}", r.findings);
    }

    #[test]
    fn revoked_peer_read_is_denied() {
        // Lease install parks w at peer 1; revocation demotes the copy to
        // the pool; a stale reader still fetches from the peer — the
        // revocation-demotion race, denied under its own lint name.
        let mut b = GraphBuilder::new();
        let w = b.tensor("w", 8 << 20, Tier::Device);
        let p = b.compute("p", 1e9, 0, vec![], vec![w]);
        let st = b.store_to("st", w, Tier::Peer(1));
        b.dep(st, p);
        let dm = b.promote("dm", w, Tier::Peer(1), Tier::Remote);
        b.dep(dm, st);
        let pf = b.prefetch_from("pf", w, Tier::Peer(1));
        b.dep(pf, dm);
        let c2 = b.compute("c2", 1e9, 0, vec![w], vec![]);
        b.dep(c2, pf);
        let g = b.build();
        let r = run(&g);
        assert!(names(&r).contains(&lints::PEER_REVOKED_READ), "got {:?}", r.findings);
        assert!(denies(&r).contains(&lints::PEER_REVOKED_READ));
        assert!(!names(&r).contains(&lints::TIER_COLD_READ), "peer race has its own lint");

        // Fetching from the pool — where the demotion parked the copy —
        // is the correct post-revocation read and stays clean.
        let mut b = GraphBuilder::new();
        let w = b.tensor("w", 8 << 20, Tier::Device);
        let p = b.compute("p", 1e9, 0, vec![], vec![w]);
        let st = b.store_to("st", w, Tier::Peer(1));
        b.dep(st, p);
        let dm = b.promote("dm", w, Tier::Peer(1), Tier::Remote);
        b.dep(dm, st);
        let pf = b.prefetch("pf", w);
        b.dep(pf, dm);
        let c2 = b.compute("c2", 1e9, 0, vec![w], vec![]);
        b.dep(c2, pf);
        let g = b.build();
        let r = run(&g);
        assert!(!names(&r).contains(&lints::PEER_REVOKED_READ), "got {:?}", r.findings);
    }

    #[test]
    fn chunk_release_racing_parent_reader() {
        // Parent produced on device; one chunk stored out with no ordering
        // against the parent-wide reader.
        let mut g = Graph::new();
        let w = g.add_tensor("w", 8 << 20, Tier::Device);
        let p = g.add_op("p", OpKind::Compute { flops: 1e9, bytes_accessed: 0 }, vec![], vec![w]);
        let c = g.add_op("c", OpKind::Compute { flops: 1e9, bytes_accessed: 0 }, vec![w], vec![]);
        let ck = g.add_chunk_tensor(w, "w.chunk0", 4 << 20);
        let st = g.add_op("store.w.chunk0", OpKind::store(ck), vec![ck], vec![]);
        g.add_control_dep(st, p);
        let r = run(&g);
        assert!(names(&r).contains(&lints::CHUNK_SIBLING_RELEASE), "got {:?}", r.findings);

        // Ordering the chunk store after the reader clears it.
        let mut g2 = g.clone();
        g2.add_control_dep(st, c);
        let r2 = run(&g2);
        assert!(!names(&r2).contains(&lints::CHUNK_SIBLING_RELEASE), "got {:?}", r2.findings);
    }

    #[test]
    fn peak_bound_chains_sequential_tensors_and_dominates_sim() {
        // w1's whole lifetime (pf1, c1) is forced before w2's prefetch, so
        // the two share a chain: bound = max bytes, not the sum.
        let mut b = GraphBuilder::new();
        let w1 = b.tensor("w1", 8 << 20, Tier::Remote);
        let w2 = b.tensor("w2", 4 << 20, Tier::Remote);
        let pf1 = b.prefetch("pf1", w1);
        let c1 = b.compute("c1", 1e9, 0, vec![w1], vec![]);
        b.dep(c1, pf1);
        let pf2 = b.prefetch("pf2", w2);
        b.dep(pf2, c1);
        let c2 = b.compute("c2", 1e9, 0, vec![w2], vec![]);
        b.dep(c2, pf2);
        let g = b.build();
        let r = run(&g);
        assert!(denies(&r).is_empty(), "got {:?}", r.findings);
        assert_eq!(r.peak_bound_bytes, 8 << 20, "sequential lifetimes must share a chain");
        assert_eq!(r.chains, 1);
        let order = g.topo_order().unwrap();
        let sim = simulate(&g, &order, &hw());
        assert!(sim.peak_device_bytes <= r.peak_bound_bytes);
    }

    #[test]
    fn peak_bound_keeps_parallel_tensors_apart() {
        // Two unordered prefetched weights can be resident together: the
        // bound must take the sum.
        let mut b = GraphBuilder::new();
        let w1 = b.tensor("w1", 8 << 20, Tier::Remote);
        let w2 = b.tensor("w2", 4 << 20, Tier::Remote);
        let pf1 = b.prefetch("pf1", w1);
        let pf2 = b.prefetch("pf2", w2);
        let c = b.compute("c", 1e9, 0, vec![w1, w2], vec![]);
        b.dep(c, pf1);
        b.dep(c, pf2);
        let g = b.build();
        let r = run(&g);
        assert_eq!(r.peak_bound_bytes, 12 << 20);
        assert_eq!(r.chains, 2);
    }

    #[test]
    fn capacity_overflow_reports_peak_unbounded() {
        let mut b = GraphBuilder::new();
        let w = b.tensor("w", 8 << 20, Tier::Remote);
        let pf = b.prefetch("pf", w);
        let c = b.compute("c", 1e9, 0, vec![w], vec![]);
        b.dep(c, pf);
        let g = b.build();
        let order = g.topo_order().unwrap();
        let anc = Reach::ancestors(&g, &order, TrackedSet::CacheOps);
        let mut small = hw();
        small.device_capacity = 1 << 20;
        let r = analyze(&g, &order, &anc, &small);
        assert_eq!(names(&r), vec![lints::PEAK_UNBOUNDED]);
        // Default lint level keeps it out of the diagnostic stream...
        let cfg = super::super::LintConfig::default();
        let diags = super::super::to_diagnostics(&r, &cfg);
        assert!(diags.iter().all(|d| d.severity == crate::passes::Severity::Info));
        // ...but a session can deny it.
        let mut strict = super::super::LintConfig::default();
        strict.set(lints::PEAK_UNBOUNDED, super::super::LintLevel::Deny);
        let diags = super::super::to_diagnostics(&r, &strict);
        assert!(diags.iter().any(|d| d.severity == crate::passes::Severity::Error));
    }

    #[test]
    fn compiled_pipeline_output_is_clean_under_random_orders() {
        // The default pipeline's output must be clean, and stay verifiable
        // under arbitrary valid linearizations — the analyzer's whole
        // claim. Also: the static bound dominates the simulated peak of
        // every sampled order.
        let mut g = GraphBuilder::fwd_bwd_chain(4, 8 << 20, 10e9, 24, 1e9);
        let report = crate::passes::Compiler::new(hw()).verify(true).compile(&mut g).unwrap();
        assert!(!report.inserted.is_empty());
        let r = run(&g);
        assert!(denies(&r).is_empty(), "pipeline output denied: {:?}", r.findings);
        for seed in 0..8 {
            let order = g.topo_order_seeded(seed).unwrap();
            let diags = crate::passes::verify_ir(&g, &order);
            assert!(
                diags.iter().all(|d| d.severity != crate::passes::Severity::Error),
                "seed {seed}: {diags:?}"
            );
            let sim = simulate(&g, &order, &hw());
            assert!(
                sim.peak_device_bytes <= r.peak_bound_bytes,
                "seed {seed}: sim peak {} > bound {}",
                sim.peak_device_bytes,
                r.peak_bound_bytes
            );
        }
    }
}
