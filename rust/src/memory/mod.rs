//! SuperNode hierarchical memory substrate (DESIGN.md §2): device HBM
//! allocator with fragmentation/compaction, remote shared pool, host tier,
//! and the unified transfer primitives of §6.

mod allocator;
mod tiers;

pub use allocator::{AllocId, DeviceAllocator};
pub use tiers::{HierarchicalMemory, PoolHandle, Region, RegionId, SharedAcquire, TransferKind};
