//! SuperNode hierarchical memory substrate (DESIGN.md §2): the device HBM
//! allocator with fragmentation/compaction at the top of the stack, then
//! one capacity ledger per tier below it — the remote shared pool
//! ([`PoolHandle`]) and, under a configured
//! [`TierTopology`](crate::sim::TierTopology), the cold DRAM/CXL/SSD
//! levels ([`TieredLedger`]) — plus the unified transfer primitives of §6.
//!
//! Reservation semantics are uniform down the stack: every tier's ledger
//! supports private bytes (`try_reserve`/`release`) and refcounted shared
//! entries (`shared_acquire`/`shared_release` — the prefix-cache dedup
//! ledger), and [`TieredLedger`] adds the demotion/promotion moves that
//! shift either flavour between adjacent tiers without ever dropping or
//! double-counting a byte.
//!
//! Orthogonal to the vertical chain sits the *harvested* middle tier:
//! [`LeaseLedger`] brokers spare HBM on idle sibling replicas
//! (`Tier::Peer`), faster than the pool but revocable. The lease
//! protocol is lender/borrower: an idle lender exposes capacity, a
//! loaded borrower homes KV blocks there, and a lender-side load spike
//! revokes the lease by *demoting* every borrowed byte into the pool —
//! reserve-destination-first, exactly once, so conservation holds
//! through revocation (never drop, never double-count).

mod allocator;
mod lease;
mod tiers;

pub use allocator::{AllocId, DeviceAllocator};
pub use lease::LeaseLedger;
pub use tiers::{
    HierarchicalMemory, PoolHandle, Region, RegionId, SharedAcquire, TieredLedger, TransferKind,
};
