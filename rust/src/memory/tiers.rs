//! Hierarchical memory: the tier stack of one SuperNode device slice,
//! with the unified transfer primitives of §6 (H2R/R2H/R2D/D2R/D2D).
//!
//! The stack is Device HBM at the top, the fabric-attached remote pool
//! below it, and — when a [`TierTopology`](crate::sim::TierTopology) is
//! configured — any of DRAM / CXL / SSD below the pool. Capacity is
//! accounted per tier: the pool's ledger is a [`PoolHandle`] (cloneable,
//! shared across slices), and [`TieredLedger`] generalises that to one
//! handle *per* non-device tier, preserving both reservation flavours at
//! every level — private bytes (`try_reserve`/`release`) and refcounted
//! shared entries (`shared_acquire`/`shared_release`, the dedup ledger of
//! the prefix cache). `TieredLedger::move_private` / `shared_move`
//! implement demotion and promotion: bytes leave one tier's ledger and
//! enter another's atomically, so `Σ per-tier used` is conserved by every
//! move (property P16 in `rust/tests/proptest_invariants.rs`).
//!
//! This is the state-tracking side (who holds which bytes, what a transfer
//! costs); the *timing* of transfers is simulated by [`crate::sim`] or the
//! serving engine. DMA engines are modelled as in-order queues per
//! direction.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use crate::graph::Tier;
use crate::sim::HwConfig;

use super::allocator::{AllocId, DeviceAllocator};

/// Capacity-accounted handle to one SuperNode remote pool.
///
/// The pool is the *shared* resource of the paper's architecture: every
/// device on the node reserves KV/optimizer bytes out of the same
/// terabyte-scale budget. A [`PoolHandle`] is cheaply cloneable; all clones
/// account against one ledger, so N engines holding clones of the same
/// handle contend for the same capacity (the cluster-serving setup), while
/// a freshly created handle models a private, uncontended pool (the
/// single-engine setup).
#[derive(Debug, Clone)]
pub struct PoolHandle {
    state: Arc<Mutex<PoolState>>,
}

#[derive(Debug)]
struct PoolState {
    capacity: u64,
    used: u64,
    peak: u64,
    /// Reservation granularity (bytes). Every reserve/release is rounded
    /// up to a multiple of this — the pool hands out fixed-size chunks,
    /// matching the paged layout of the KV tier (a KV block is one chunk).
    /// `1` = byte-granular (the legacy behaviour).
    chunk_bytes: u64,
    /// Shared (refcounted) reservations, keyed by content hash. The bytes
    /// of each entry are counted against `used` exactly once no matter how
    /// many holders attached — the dedup ledger of the prefix-cache tier.
    shared: HashMap<u64, SharedEntry>,
}

#[derive(Debug)]
struct SharedEntry {
    /// Quantized bytes this entry holds in the ledger.
    bytes: u64,
    refs: u64,
}

/// Outcome of [`PoolHandle::shared_acquire`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SharedAcquire {
    /// The key was already resident: its refcount grew, no new bytes were
    /// reserved (a dedup hit).
    Attached,
    /// The key was not resident: capacity was reserved for it and the
    /// refcount is now 1.
    Reserved,
    /// The key was not resident and the pool cannot hold its bytes.
    Exhausted,
}

impl PoolHandle {
    pub fn new(capacity: u64) -> Self {
        Self::new_chunked(capacity, 1)
    }

    /// A pool that reserves in `chunk_bytes`-sized units: requests are
    /// rounded up to whole chunks, so partial-chunk reservations cannot
    /// fragment the ledger. The serving cluster sizes chunks to the KV
    /// block, making every pool reservation block-granular end to end.
    pub fn new_chunked(capacity: u64, chunk_bytes: u64) -> Self {
        Self {
            state: Arc::new(Mutex::new(PoolState {
                capacity,
                used: 0,
                peak: 0,
                chunk_bytes: chunk_bytes.max(1),
                shared: HashMap::new(),
            })),
        }
    }

    /// A pool with effectively no capacity limit (legacy single-device
    /// behaviour where the remote tier was treated as inexhaustible).
    pub fn unbounded() -> Self {
        Self::new(u64::MAX)
    }

    /// Round `bytes` up to the pool's chunk granularity.
    fn quantize(chunk: u64, bytes: u64) -> u64 {
        if chunk <= 1 || bytes == 0 {
            bytes
        } else {
            bytes.div_ceil(chunk).saturating_mul(chunk)
        }
    }

    /// Reserve `bytes` from the pool (rounded up to whole chunks).
    /// Returns false (reserving nothing) if the remaining capacity cannot
    /// hold them.
    pub fn try_reserve(&self, bytes: u64) -> bool {
        let mut s = self.state.lock().unwrap();
        let bytes = Self::quantize(s.chunk_bytes, bytes);
        match s.used.checked_add(bytes) {
            Some(next) if next <= s.capacity => {
                s.used = next;
                s.peak = s.peak.max(next);
                true
            }
            _ => false,
        }
    }

    /// Return `bytes` to the pool (rounded up to whole chunks, symmetric
    /// with [`try_reserve`](Self::try_reserve)).
    pub fn release(&self, bytes: u64) {
        let mut s = self.state.lock().unwrap();
        let bytes = Self::quantize(s.chunk_bytes, bytes);
        s.used = s.used.saturating_sub(bytes);
    }

    /// Reservation granularity (bytes); 1 for byte-granular pools.
    pub fn chunk_bytes(&self) -> u64 {
        self.state.lock().unwrap().chunk_bytes
    }

    /// Chunks currently reserved (`used / chunk_bytes`, rounded up).
    pub fn chunks_used(&self) -> u64 {
        let s = self.state.lock().unwrap();
        s.used.div_ceil(s.chunk_bytes.max(1))
    }

    pub fn used(&self) -> u64 {
        self.state.lock().unwrap().used
    }

    pub fn capacity(&self) -> u64 {
        self.state.lock().unwrap().capacity
    }

    /// High-water mark of pool occupancy (bytes).
    pub fn peak(&self) -> u64 {
        self.state.lock().unwrap().peak
    }

    /// Occupancy in [0, 1]; 0 for an unbounded pool.
    pub fn pressure(&self) -> f64 {
        let s = self.state.lock().unwrap();
        if s.capacity == 0 || s.capacity == u64::MAX {
            0.0
        } else {
            s.used as f64 / s.capacity as f64
        }
    }

    /// Acquire a reference on the shared reservation `key`.
    ///
    /// If the key is already resident the refcount grows and no new bytes
    /// are reserved ([`SharedAcquire::Attached`] — the dedup hit). If not,
    /// `bytes` (chunk-quantized) are reserved under the key with refcount 1
    /// ([`SharedAcquire::Reserved`]), or [`SharedAcquire::Exhausted`] is
    /// returned untouched if the capacity cannot hold them.
    pub fn shared_acquire(&self, key: u64, bytes: u64) -> SharedAcquire {
        let mut s = self.state.lock().unwrap();
        if let Some(e) = s.shared.get_mut(&key) {
            e.refs += 1;
            return SharedAcquire::Attached;
        }
        let bytes = Self::quantize(s.chunk_bytes, bytes);
        match s.used.checked_add(bytes) {
            Some(next) if next <= s.capacity => {
                s.used = next;
                s.peak = s.peak.max(next);
                s.shared.insert(key, SharedEntry { bytes, refs: 1 });
                SharedAcquire::Reserved
            }
            _ => SharedAcquire::Exhausted,
        }
    }

    /// Drop one reference on shared reservation `key`. When the last
    /// reference goes, the entry's bytes return to the pool and `true` is
    /// returned. Unknown keys are ignored (returns `false`).
    pub fn shared_release(&self, key: u64) -> bool {
        let mut s = self.state.lock().unwrap();
        let Some(e) = s.shared.get_mut(&key) else { return false };
        e.refs -= 1;
        if e.refs == 0 {
            let bytes = e.bytes;
            s.shared.remove(&key);
            s.used = s.used.saturating_sub(bytes);
            true
        } else {
            false
        }
    }

    /// Current refcount of shared reservation `key` (0 if not resident).
    pub fn shared_refs(&self, key: u64) -> u64 {
        self.state.lock().unwrap().shared.get(&key).map_or(0, |e| e.refs)
    }

    /// Total bytes held by shared reservations (each counted once).
    pub fn shared_bytes(&self) -> u64 {
        self.state.lock().unwrap().shared.values().map(|e| e.bytes).sum()
    }

    /// Ledger bytes and refcount of shared reservation `key`, if resident.
    pub fn shared_entry(&self, key: u64) -> Option<(u64, u64)> {
        self.state.lock().unwrap().shared.get(&key).map(|e| (e.bytes, e.refs))
    }

    /// Install a shared reservation wholesale (bytes already quantized,
    /// refcount carried over) — the receiving half of a tier move. Fails
    /// without reserving anything if the key is already resident or the
    /// capacity cannot hold the bytes.
    fn shared_install(&self, key: u64, bytes: u64, refs: u64) -> bool {
        let mut s = self.state.lock().unwrap();
        if s.shared.contains_key(&key) {
            return false;
        }
        match s.used.checked_add(bytes) {
            Some(next) if next <= s.capacity => {
                s.used = next;
                s.peak = s.peak.max(next);
                s.shared.insert(key, SharedEntry { bytes, refs });
                true
            }
            _ => false,
        }
    }

    /// Remove a shared reservation wholesale, returning its
    /// `(bytes, refs)` — the sending half of a tier move. The entry's
    /// bytes return to this ledger regardless of the refcount.
    fn shared_remove(&self, key: u64) -> Option<(u64, u64)> {
        let mut s = self.state.lock().unwrap();
        let e = s.shared.remove(&key)?;
        s.used = s.used.saturating_sub(e.bytes);
        Some((e.bytes, e.refs))
    }
}

/// One capacity ledger per non-device tier of a
/// [`TierTopology`](crate::sim::TierTopology) — the pool's
/// [`PoolHandle`] semantics, generalised down the stack.
///
/// The first entry is always the pool tier ([`Tier::Remote`]); deeper
/// entries are the topology's cold tiers in order. Clones share every
/// ledger (the handles are themselves shared), so a node-wide
/// `TieredLedger` models all device slices drawing on one tier stack.
///
/// Demotion and promotion go through [`move_private`](Self::move_private)
/// / [`shared_move`](Self::shared_move): the destination tier is reserved
/// *first* and the source released only after, so a failed move changes
/// nothing and a successful one conserves `Σ used` across the stack.
#[derive(Debug, Clone)]
pub struct TieredLedger {
    tiers: Vec<(Tier, PoolHandle)>,
}

impl TieredLedger {
    /// The degenerate single-tier ledger: just the pool. Every tier-aware
    /// code path handed this behaves bit-identically to the pre-tier
    /// pool-only path (there is nowhere to demote to).
    pub fn single(pool: PoolHandle) -> Self {
        Self { tiers: vec![(Tier::Remote, pool)] }
    }

    /// Build the ledger stack below `topo`'s device tier, reusing `pool`
    /// as the pool tier's ledger (so existing clones of the handle keep
    /// accounting against the same capacity) and creating one
    /// `chunk_bytes`-granular handle per cold tier with the topology's
    /// capacity (0 = unbounded).
    pub fn from_topology(
        pool: PoolHandle,
        topo: &crate::sim::TierTopology,
        chunk_bytes: u64,
    ) -> Self {
        let mut tiers = vec![(Tier::Remote, pool)];
        for (i, &t) in topo.tiers.iter().enumerate().skip(2) {
            let cap = match topo.capacities.get(i) {
                Some(&c) if c > 0 => c,
                _ => u64::MAX,
            };
            tiers.push((t, PoolHandle::new_chunked(cap, chunk_bytes)));
        }
        Self { tiers }
    }

    /// The pool tier's handle (always present).
    pub fn pool(&self) -> &PoolHandle {
        &self.tiers[0].1
    }

    /// The ledger handle for `tier`, if that tier is in the stack.
    /// [`Tier::Host`] resolves to the pool tier, mirroring
    /// `TierTopology::index_of`.
    pub fn handle(&self, tier: Tier) -> Option<&PoolHandle> {
        let tier = if tier == Tier::Host { Tier::Remote } else { tier };
        self.tiers.iter().find(|(t, _)| *t == tier).map(|(_, h)| h)
    }

    /// Tiers in stack order (pool first, then cold tiers).
    pub fn tiers(&self) -> impl Iterator<Item = Tier> + '_ {
        self.tiers.iter().map(|(t, _)| *t)
    }

    /// The tier one level below `tier` in the stack, if any.
    pub fn below(&self, tier: Tier) -> Option<Tier> {
        let i = self.tiers.iter().position(|(t, _)| *t == tier)?;
        self.tiers.get(i + 1).map(|(t, _)| *t)
    }

    /// Σ used bytes across every tier in the stack.
    pub fn total_used(&self) -> u64 {
        self.tiers.iter().map(|(_, h)| h.used()).sum()
    }

    /// Move `bytes` of *private* reservation from `src` to `dst`.
    /// Reserves at the destination first; on any failure nothing changes.
    pub fn move_private(&self, src: Tier, dst: Tier, bytes: u64) -> bool {
        if src == dst {
            return true;
        }
        let (Some(s), Some(d)) = (self.handle(src), self.handle(dst)) else {
            return false;
        };
        if s.used() < bytes || !d.try_reserve(bytes) {
            return false;
        }
        s.release(bytes);
        true
    }

    /// Move the *shared* reservation `key` from `src` to `dst`, carrying
    /// its refcount. Installs at the destination first; on capacity
    /// failure the entry stays at the source untouched.
    pub fn shared_move(&self, key: u64, src: Tier, dst: Tier) -> bool {
        if src == dst {
            return true;
        }
        let (Some(s), Some(d)) = (self.handle(src), self.handle(dst)) else {
            return false;
        };
        let Some((bytes, refs)) = s.shared_entry(key) else { return false };
        if !d.shared_install(key, bytes, refs) {
            return false;
        }
        let removed = s.shared_remove(key);
        debug_assert!(removed.is_some(), "entry vanished mid-move");
        true
    }
}

/// A transfer primitive between tiers (§6 "Unified Memory Primitives").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransferKind {
    H2R,
    R2H,
    R2D,
    D2R,
    D2D,
    H2D,
    D2H,
}

impl TransferKind {
    pub fn between(src: Tier, dst: Tier) -> Result<Self> {
        use Tier::*;
        Ok(match (src, dst) {
            (Host, Remote) => TransferKind::H2R,
            (Remote, Host) => TransferKind::R2H,
            (Remote, Device) => TransferKind::R2D,
            (Device, Remote) => TransferKind::D2R,
            (Device, Device) => TransferKind::D2D,
            (Host, Device) => TransferKind::H2D,
            (Device, Host) => TransferKind::D2H,
            // Cold tiers (DRAM/CXL/SSD) ride the host-side links in this
            // coarse primitive taxonomy: a move between two non-device,
            // non-pool levels is host-lateral traffic (H2R class). The
            // per-edge timing of a configured TierTopology supersedes
            // these labels in `HierarchicalMemory::migrate`.
            (a, b) if a.is_cold() || b.is_cold() => {
                let fa = if a.is_cold() { Host } else { a };
                let fb = if b.is_cold() { Host } else { b };
                if fa == fb {
                    TransferKind::H2R
                } else {
                    return Self::between(fa, fb);
                }
            }
            // Borrowed peer HBM is device-class memory on a sibling: a
            // move touching a `Peer` home is device↔device traffic. The
            // peer-edge timing in `HwConfig::peer` supersedes this coarse
            // label wherever the simulator costs the op directly.
            (a, b) if a.is_peer() || b.is_peer() => {
                let fa = if a.is_peer() { Device } else { a };
                let fb = if b.is_peer() { Device } else { b };
                if fa == fb {
                    TransferKind::D2D
                } else {
                    return Self::between(fa, fb);
                }
            }
            (a, b) => bail!("unsupported transfer {a:?} -> {b:?}"),
        })
    }

    /// Transfer duration on `hw` (us). Host links share the pool link in
    /// this model; D2D rides HBM bandwidth.
    pub fn duration_us(self, bytes: u64, hw: &HwConfig) -> f64 {
        match self {
            TransferKind::R2D | TransferKind::H2D => hw.r2d_us(bytes),
            TransferKind::D2R | TransferKind::D2H => hw.d2r_us(bytes),
            TransferKind::H2R | TransferKind::R2H => {
                hw.link_latency_us + bytes as f64 / (hw.d2r_gbps * 1e9) * 1e6
            }
            TransferKind::D2D => bytes as f64 / (hw.hbm_gbps * 1e9) * 1e6,
        }
    }
}

/// A logical region registered in the hierarchy.
#[derive(Debug, Clone)]
pub struct Region {
    pub name: String,
    pub bytes: u64,
    pub tier: Tier,
    /// Device allocation backing it when tier == Device.
    pub alloc: Option<AllocId>,
}

/// The three-tier memory system of one SuperNode device slice.
///
/// The remote tier is accounted through a [`PoolHandle`]: pass a shared
/// handle via [`HierarchicalMemory::with_pool`] to model several device
/// slices drawing from one node-level pool.
#[derive(Debug)]
pub struct HierarchicalMemory {
    pub device: DeviceAllocator,
    pool: PoolHandle,
    /// Remote bytes reserved by *this* slice (the pool ledger aggregates
    /// all slices).
    remote_local: u64,
    pub host_used: u64,
    /// Bytes this slice holds in each cold tier (DRAM/CXL/SSD); capacity
    /// is checked against the hardware's `TierTopology` on registration.
    cold_used: HashMap<Tier, u64>,
    regions: HashMap<u64, Region>,
    next_region: u64,
    /// Cumulative microseconds of defrag stall charged (compaction moves
    /// bytes at HBM bandwidth).
    pub defrag_stall_us: f64,
}

/// Handle to a registered region.
pub type RegionId = u64;

impl HierarchicalMemory {
    pub fn new(hw: &HwConfig) -> Self {
        Self::with_pool(hw, PoolHandle::new(hw.remote_capacity))
    }

    /// Build a slice whose remote tier draws from `pool` (shared across
    /// slices when the handle is cloned).
    pub fn with_pool(hw: &HwConfig, pool: PoolHandle) -> Self {
        Self {
            device: DeviceAllocator::new(hw.device_capacity),
            pool,
            remote_local: 0,
            host_used: 0,
            cold_used: HashMap::new(),
            regions: HashMap::new(),
            next_region: 1,
            defrag_stall_us: 0.0,
        }
    }

    /// Remote-pool bytes reserved by this slice.
    pub fn remote_used(&self) -> u64 {
        self.remote_local
    }

    /// The (possibly shared) remote pool behind this slice.
    pub fn pool(&self) -> &PoolHandle {
        &self.pool
    }

    /// Register a region in `tier`, allocating device space if needed.
    /// Returns (region id, defrag stall charged in us).
    pub fn register(&mut self, name: &str, bytes: u64, tier: Tier, hw: &HwConfig) -> Result<(RegionId, f64)> {
        let mut stall = 0.0;
        let alloc = match tier {
            Tier::Device => {
                let (id, moved) = self.device.alloc(bytes)?;
                stall = Self::defrag_us(moved, hw);
                self.defrag_stall_us += stall;
                Some(id)
            }
            Tier::Remote => {
                if !self.pool.try_reserve(bytes) {
                    bail!("remote pool exhausted");
                }
                self.remote_local += bytes;
                None
            }
            Tier::Host => {
                self.host_used += bytes;
                None
            }
            t @ (Tier::Dram | Tier::Cxl | Tier::Ssd) => {
                self.reserve_cold(t, bytes, hw)?;
                None
            }
            // Borrowed peer HBM is brokered by the lease ledger, not
            // registered as a region: leases carry KV blocks, not
            // training regions.
            Tier::Peer(_) => bail!("peer tier is not a region home"),
        };
        let id = self.next_region;
        self.next_region += 1;
        self.regions.insert(id, Region { name: name.into(), bytes, tier, alloc });
        Ok((id, stall))
    }

    /// Move a region to another tier. Returns (transfer kind, duration us,
    /// defrag stall us).
    pub fn migrate(&mut self, id: RegionId, dst: Tier, hw: &HwConfig) -> Result<(TransferKind, f64, f64)> {
        let region = self.regions.get(&id).cloned();
        let Some(region) = region else { bail!("unknown region {id}") };
        if region.tier == dst {
            return Ok((TransferKind::between(region.tier, dst).unwrap_or(TransferKind::D2D), 0.0, 0.0));
        }
        let kind = TransferKind::between(region.tier, dst)?;
        // A configured TierTopology routes the timing over the actual
        // tier path (per-hop latencies, bottleneck bandwidth); the flat
        // per-kind costs are the legacy two-level fallback.
        let dur = if hw.tiers.is_some() {
            match (region.tier, dst) {
                (Tier::Device, d) => hw.evict_us(d, region.bytes),
                (s, Tier::Device) => hw.fetch_us(s, region.bytes),
                (s, d) => hw.promote_us(s, d, region.bytes),
            }
        } else {
            kind.duration_us(region.bytes, hw)
        };

        // Acquire the destination *first*: src != dst here, so the two
        // never compete for the same capacity, and a failed acquisition
        // (device OOM, shared pool exhausted by a sibling slice) leaves
        // the region intact at its source instead of half-migrated.
        let mut stall = 0.0;
        let alloc = match dst {
            Tier::Device => {
                let (a, moved) = self.device.alloc(region.bytes)?;
                stall = Self::defrag_us(moved, hw);
                self.defrag_stall_us += stall;
                Some(a)
            }
            Tier::Remote => {
                if !self.pool.try_reserve(region.bytes) {
                    bail!("remote pool exhausted");
                }
                self.remote_local += region.bytes;
                None
            }
            Tier::Host => {
                self.host_used += region.bytes;
                None
            }
            t @ (Tier::Dram | Tier::Cxl | Tier::Ssd) => {
                self.reserve_cold(t, region.bytes, hw)?;
                None
            }
            Tier::Peer(_) => bail!("peer tier is not a region home"),
        };
        // Release the source.
        match region.tier {
            Tier::Device => {
                if let Some(a) = region.alloc {
                    self.device.free(a)?;
                }
            }
            Tier::Remote => {
                self.pool.release(region.bytes);
                self.remote_local -= region.bytes;
            }
            Tier::Host => self.host_used -= region.bytes,
            t @ (Tier::Dram | Tier::Cxl | Tier::Ssd) => {
                if let Some(u) = self.cold_used.get_mut(&t) {
                    *u = u.saturating_sub(region.bytes);
                }
            }
            // Unreachable: `register`/`migrate` refuse Peer homes.
            Tier::Peer(_) => {}
        }
        let r = self.regions.get_mut(&id).unwrap();
        r.tier = dst;
        r.alloc = alloc;
        Ok((kind, dur, stall))
    }

    /// Drop a region entirely.
    pub fn release(&mut self, id: RegionId) -> Result<()> {
        let Some(region) = self.regions.remove(&id) else { bail!("unknown region {id}") };
        match region.tier {
            Tier::Device => {
                if let Some(a) = region.alloc {
                    self.device.free(a)?;
                }
            }
            Tier::Remote => {
                self.pool.release(region.bytes);
                self.remote_local -= region.bytes;
            }
            Tier::Host => self.host_used -= region.bytes,
            t @ (Tier::Dram | Tier::Cxl | Tier::Ssd) => {
                if let Some(u) = self.cold_used.get_mut(&t) {
                    *u = u.saturating_sub(region.bytes);
                }
            }
            // Unreachable: `register`/`migrate` refuse Peer homes.
            Tier::Peer(_) => {}
        }
        Ok(())
    }

    pub fn region(&self, id: RegionId) -> Option<&Region> {
        self.regions.get(&id)
    }

    pub fn device_used(&self) -> u64 {
        self.device.used()
    }

    /// Bytes this slice holds in cold tier `tier` (0 if none).
    pub fn cold_used(&self, tier: Tier) -> u64 {
        self.cold_used.get(&tier).copied().unwrap_or(0)
    }

    /// Account `bytes` into cold tier `t`, checking the topology's
    /// capacity. Rejects tiers absent from the hardware's tier stack.
    fn reserve_cold(&mut self, t: Tier, bytes: u64, hw: &HwConfig) -> Result<()> {
        let Some(cap) = hw.tier_capacity(t) else {
            bail!("tier {t:?} is not in the hardware topology");
        };
        let used = self.cold_used.get(&t).copied().unwrap_or(0);
        if cap > 0 && used.saturating_add(bytes) > cap {
            bail!("{t:?} tier exhausted: {bytes} B over {cap} B capacity");
        }
        *self.cold_used.entry(t).or_insert(0) += bytes;
        Ok(())
    }

    /// Compaction stall: moved bytes at HBM bandwidth (read+write).
    fn defrag_us(moved: u64, hw: &HwConfig) -> f64 {
        2.0 * moved as f64 / (hw.hbm_gbps * 1e9) * 1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::GB;

    fn hw() -> HwConfig {
        HwConfig {
            compute_tflops: 100.0,
            hbm_gbps: 1000.0,
            d2r_gbps: 33.6,
            r2d_gbps: 33.6,
            link_latency_us: 10.0,
            net_gbps: 56.0,
            host_overhead_us: 150.0,
            device_capacity: 4 * GB,
            remote_capacity: 64 * GB,
            tiers: None,
            peer: None,
        }
    }

    #[test]
    fn register_per_tier() {
        let hw = hw();
        let mut m = HierarchicalMemory::new(&hw);
        let (d, _) = m.register("w", GB, Tier::Device, &hw).unwrap();
        let (r, _) = m.register("kv", 2 * GB, Tier::Remote, &hw).unwrap();
        assert_eq!(m.device_used(), GB);
        assert_eq!(m.remote_used(), 2 * GB);
        assert_eq!(m.region(d).unwrap().tier, Tier::Device);
        assert_eq!(m.region(r).unwrap().tier, Tier::Remote);
    }

    #[test]
    fn migrate_d2r_frees_device() {
        let hw = hw();
        let mut m = HierarchicalMemory::new(&hw);
        let (id, _) = m.register("act", GB, Tier::Device, &hw).unwrap();
        let (kind, dur, _) = m.migrate(id, Tier::Remote, &hw).unwrap();
        assert_eq!(kind, TransferKind::D2R);
        assert!(dur > 0.0);
        assert_eq!(m.device_used(), 0);
        assert_eq!(m.remote_used(), GB);
    }

    #[test]
    fn migrate_r2d_uses_r2d_bandwidth() {
        let hw = hw();
        let mut m = HierarchicalMemory::new(&hw);
        let (id, _) = m.register("kv", GB, Tier::Remote, &hw).unwrap();
        let (kind, dur, _) = m.migrate(id, Tier::Device, &hw).unwrap();
        assert_eq!(kind, TransferKind::R2D);
        let expect = hw.r2d_us(GB);
        assert!((dur - expect).abs() < 1e-6);
    }

    #[test]
    fn same_tier_migrate_is_noop() {
        let hw = hw();
        let mut m = HierarchicalMemory::new(&hw);
        let (id, _) = m.register("x", GB, Tier::Remote, &hw).unwrap();
        let (_, dur, _) = m.migrate(id, Tier::Remote, &hw).unwrap();
        assert_eq!(dur, 0.0);
    }

    #[test]
    fn remote_pool_capacity_enforced() {
        let hw = hw();
        let mut m = HierarchicalMemory::new(&hw);
        assert!(m.register("big", 65 * GB, Tier::Remote, &hw).is_err());
    }

    #[test]
    fn device_oom_propagates() {
        let hw = hw();
        let mut m = HierarchicalMemory::new(&hw);
        assert!(m.register("big", 5 * GB, Tier::Device, &hw).is_err());
    }

    #[test]
    fn release_returns_space() {
        let hw = hw();
        let mut m = HierarchicalMemory::new(&hw);
        let (id, _) = m.register("x", GB, Tier::Device, &hw).unwrap();
        m.release(id).unwrap();
        assert_eq!(m.device_used(), 0);
        assert!(m.region(id).is_none());
    }

    #[test]
    fn shared_pool_contends_across_slices() {
        let hw = hw();
        let pool = PoolHandle::new(3 * GB);
        let mut a = HierarchicalMemory::with_pool(&hw, pool.clone());
        let mut b = HierarchicalMemory::with_pool(&hw, pool.clone());
        a.register("a", 2 * GB, Tier::Remote, &hw).unwrap();
        // b sees a's reservation: only 1 GB left.
        assert!(b.register("b", 2 * GB, Tier::Remote, &hw).is_err());
        let (id, _) = b.register("b", GB, Tier::Remote, &hw).unwrap();
        assert_eq!(pool.used(), 3 * GB);
        b.release(id).unwrap();
        assert_eq!(pool.used(), 2 * GB);
        assert_eq!(pool.peak(), 3 * GB);
        assert_eq!(a.remote_used(), 2 * GB);
        assert_eq!(b.remote_used(), 0);
    }

    #[test]
    fn failed_migrate_leaves_region_intact() {
        let hw = hw();
        let pool = PoolHandle::new(GB);
        let mut sibling = HierarchicalMemory::with_pool(&hw, pool.clone());
        let mut m = HierarchicalMemory::with_pool(&hw, pool.clone());
        sibling.register("hog", GB, Tier::Remote, &hw).unwrap();
        let (id, _) = m.register("act", GB, Tier::Device, &hw).unwrap();
        // Destination pool is full: migration must fail atomically.
        assert!(m.migrate(id, Tier::Remote, &hw).is_err());
        assert_eq!(m.region(id).unwrap().tier, Tier::Device);
        assert_eq!(m.device_used(), GB, "source must still be allocated");
        // And still releasable / migratable once the sibling frees up.
        m.release(id).unwrap();
        assert_eq!(m.device_used(), 0);
    }

    #[test]
    fn pool_handle_accounting() {
        let p = PoolHandle::new(100);
        assert!(p.try_reserve(60));
        assert!(!p.try_reserve(50));
        assert!(p.try_reserve(40));
        assert_eq!(p.used(), 100);
        assert!((p.pressure() - 1.0).abs() < 1e-12);
        p.release(30);
        assert_eq!(p.used(), 70);
        assert_eq!(p.peak(), 100);
        // Unbounded pool never rejects and reports zero pressure.
        let u = PoolHandle::unbounded();
        assert!(u.try_reserve(u64::MAX / 2));
        assert_eq!(u.pressure(), 0.0);
    }

    #[test]
    fn chunked_pool_quantizes_reservations() {
        // 4 chunks of 64 bytes; partial-chunk requests round up.
        let p = PoolHandle::new_chunked(256, 64);
        assert_eq!(p.chunk_bytes(), 64);
        assert!(p.try_reserve(1)); // -> one whole chunk
        assert_eq!(p.used(), 64);
        assert_eq!(p.chunks_used(), 1);
        assert!(p.try_reserve(65)); // -> two chunks
        assert_eq!(p.used(), 192);
        assert!(!p.try_reserve(128), "only one chunk left");
        assert!(p.try_reserve(64));
        assert_eq!(p.chunks_used(), 4);
        // Release is symmetric: the same request size frees the same chunks.
        p.release(65);
        assert_eq!(p.used(), 128);
        assert_eq!(p.chunks_used(), 2);
        // Chunk-multiple traffic is untouched by quantisation.
        let q = PoolHandle::new_chunked(256, 64);
        assert!(q.try_reserve(128));
        assert_eq!(q.used(), 128);
    }

    #[test]
    fn shared_reservations_dedup_bytes() {
        let p = PoolHandle::new_chunked(256, 64);
        // First holder reserves; bytes quantize up to one chunk.
        assert_eq!(p.shared_acquire(7, 33), SharedAcquire::Reserved);
        assert_eq!(p.used(), 64);
        assert_eq!(p.shared_refs(7), 1);
        // Second and third holders attach: no new bytes.
        assert_eq!(p.shared_acquire(7, 33), SharedAcquire::Attached);
        assert_eq!(p.shared_acquire(7, 33), SharedAcquire::Attached);
        assert_eq!(p.used(), 64);
        assert_eq!(p.shared_refs(7), 3);
        assert_eq!(p.shared_bytes(), 64);
        // Private traffic coexists with the shared ledger.
        assert!(p.try_reserve(128));
        assert_eq!(p.used(), 192);
        // Releases: bytes return only on the last one.
        assert!(!p.shared_release(7));
        assert!(!p.shared_release(7));
        assert_eq!(p.used(), 192);
        assert!(p.shared_release(7));
        assert_eq!(p.used(), 128);
        assert_eq!(p.shared_refs(7), 0);
        assert_eq!(p.shared_bytes(), 0);
        // Releasing an unknown key is a harmless no-op.
        assert!(!p.shared_release(7));
        assert_eq!(p.used(), 128);
    }

    #[test]
    fn shared_acquire_respects_capacity_but_attach_always_succeeds() {
        let p = PoolHandle::new_chunked(128, 64);
        assert_eq!(p.shared_acquire(1, 64), SharedAcquire::Reserved);
        assert!(p.try_reserve(64));
        // Pool full: a *new* key cannot reserve...
        assert_eq!(p.shared_acquire(2, 64), SharedAcquire::Exhausted);
        assert_eq!(p.used(), 128);
        // ...but attaching to a resident key still works (no new bytes).
        assert_eq!(p.shared_acquire(1, 64), SharedAcquire::Attached);
        assert_eq!(p.shared_refs(1), 2);
        assert_eq!(p.peak(), 128);
    }

    #[test]
    fn transfer_kind_matrix() {
        use Tier::*;
        assert_eq!(TransferKind::between(Host, Remote).unwrap(), TransferKind::H2R);
        assert_eq!(TransferKind::between(Remote, Host).unwrap(), TransferKind::R2H);
        assert_eq!(TransferKind::between(Device, Remote).unwrap(), TransferKind::D2R);
        assert_eq!(TransferKind::between(Remote, Device).unwrap(), TransferKind::R2D);
        // Cold tiers fold onto the host-class links.
        assert_eq!(TransferKind::between(Remote, Dram).unwrap(), TransferKind::R2H);
        assert_eq!(TransferKind::between(Ssd, Device).unwrap(), TransferKind::H2D);
        assert_eq!(TransferKind::between(Dram, Cxl).unwrap(), TransferKind::H2R);
    }

    #[test]
    fn tiered_ledger_moves_conserve_total_used() {
        use crate::sim::TierTopology;
        let hw = hw();
        let pool = PoolHandle::new(4 * GB);
        let ledger = TieredLedger::from_topology(pool.clone(), &TierTopology::five_tier(&hw), 1);
        let tiers: Vec<Tier> = ledger.tiers().collect();
        assert_eq!(tiers, vec![Tier::Remote, Tier::Dram, Tier::Cxl, Tier::Ssd]);
        assert_eq!(ledger.below(Tier::Remote), Some(Tier::Dram));
        assert_eq!(ledger.below(Tier::Ssd), None);

        assert!(ledger.pool().try_reserve(3 * GB));
        let before = ledger.total_used();
        // Demote 1 GB pool → DRAM, then DRAM → SSD: Σ used is invariant.
        assert!(ledger.move_private(Tier::Remote, Tier::Dram, GB));
        assert_eq!(pool.used(), 2 * GB);
        assert_eq!(ledger.handle(Tier::Dram).unwrap().used(), GB);
        assert!(ledger.move_private(Tier::Dram, Tier::Ssd, GB));
        assert_eq!(ledger.total_used(), before);
        // Promote back up.
        assert!(ledger.move_private(Tier::Ssd, Tier::Remote, GB));
        assert_eq!(pool.used(), 3 * GB);
        assert_eq!(ledger.total_used(), before);
        // A move bigger than the source's holdings changes nothing.
        assert!(!ledger.move_private(Tier::Remote, Tier::Dram, 100 * GB), "src underflow");
        assert_eq!(ledger.total_used(), before);
    }

    #[test]
    fn tiered_ledger_shared_move_carries_refs() {
        use crate::sim::TierTopology;
        let hw = hw();
        let block = 64u64;
        let pool = PoolHandle::new_chunked(4 * block, block);
        let ledger =
            TieredLedger::from_topology(pool.clone(), &TierTopology::three_tier(&hw), block);
        assert_eq!(pool.shared_acquire(7, block), SharedAcquire::Reserved);
        assert_eq!(pool.shared_acquire(7, block), SharedAcquire::Attached);
        // Demote the shared entry pool → DRAM: refcount and bytes move.
        assert!(ledger.shared_move(7, Tier::Remote, Tier::Dram));
        assert_eq!(pool.shared_refs(7), 0);
        assert_eq!(pool.used(), 0);
        let dram = ledger.handle(Tier::Dram).unwrap();
        assert_eq!(dram.shared_refs(7), 2);
        assert_eq!(dram.used(), block);
        // Release both holders on the new tier; bytes return there.
        assert!(!dram.shared_release(7));
        assert!(dram.shared_release(7));
        assert_eq!(dram.used(), 0);
        // Moving an absent key fails without touching either ledger.
        assert!(!ledger.shared_move(7, Tier::Remote, Tier::Dram));
        assert_eq!(ledger.total_used(), 0);
    }

    #[test]
    fn cold_tier_regions_register_and_migrate() {
        use crate::sim::TierTopology;
        let mut hw = hw();
        // Without a topology, cold tiers are rejected outright.
        let mut flat = HierarchicalMemory::new(&hw);
        assert!(flat.register("x", GB, Tier::Dram, &hw).is_err());

        hw.tiers = Some(TierTopology::five_tier(&hw));
        let mut m = HierarchicalMemory::new(&hw);
        let (id, _) = m.register("act", GB, Tier::Remote, &hw).unwrap();
        // Demote pool → SSD: bytes leave the pool ledger for the cold one.
        let (kind, dur, _) = m.migrate(id, Tier::Ssd, &hw).unwrap();
        assert_eq!(kind, TransferKind::R2H);
        let expect = hw.promote_us(Tier::Remote, Tier::Ssd, GB);
        assert!((dur - expect).abs() < 1e-6, "dur {dur} vs {expect}");
        assert_eq!(m.remote_used(), 0);
        assert_eq!(m.cold_used(Tier::Ssd), GB);
        // Fetch it all the way to device: full path timing.
        let (kind, dur, _) = m.migrate(id, Tier::Device, &hw).unwrap();
        assert_eq!(kind, TransferKind::H2D);
        assert!((dur - hw.fetch_us(Tier::Ssd, GB)).abs() < 1e-6);
        assert_eq!(m.cold_used(Tier::Ssd), 0);
        m.release(id).unwrap();
        assert_eq!(m.device_used(), 0);
    }
}
