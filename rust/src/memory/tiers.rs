//! Hierarchical memory: Device HBM + SuperNode remote pool + host DRAM,
//! with the unified transfer primitives of §6 (H2R/R2H/R2D/D2R/D2D).
//!
//! This is the state-tracking side (who holds which bytes, what a transfer
//! costs); the *timing* of transfers is simulated by [`crate::sim`] or the
//! serving engine. DMA engines are modelled as in-order queues per
//! direction.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::graph::Tier;
use crate::sim::HwConfig;

use super::allocator::{AllocId, DeviceAllocator};

/// A transfer primitive between tiers (§6 "Unified Memory Primitives").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransferKind {
    H2R,
    R2H,
    R2D,
    D2R,
    D2D,
    H2D,
    D2H,
}

impl TransferKind {
    pub fn between(src: Tier, dst: Tier) -> Result<Self> {
        use Tier::*;
        Ok(match (src, dst) {
            (Host, Remote) => TransferKind::H2R,
            (Remote, Host) => TransferKind::R2H,
            (Remote, Device) => TransferKind::R2D,
            (Device, Remote) => TransferKind::D2R,
            (Device, Device) => TransferKind::D2D,
            (Host, Device) => TransferKind::H2D,
            (Device, Host) => TransferKind::D2H,
            (a, b) => bail!("unsupported transfer {a:?} -> {b:?}"),
        })
    }

    /// Transfer duration on `hw` (us). Host links share the pool link in
    /// this model; D2D rides HBM bandwidth.
    pub fn duration_us(self, bytes: u64, hw: &HwConfig) -> f64 {
        match self {
            TransferKind::R2D | TransferKind::H2D => hw.r2d_us(bytes),
            TransferKind::D2R | TransferKind::D2H => hw.d2r_us(bytes),
            TransferKind::H2R | TransferKind::R2H => {
                hw.link_latency_us + bytes as f64 / (hw.d2r_gbps * 1e9) * 1e6
            }
            TransferKind::D2D => bytes as f64 / (hw.hbm_gbps * 1e9) * 1e6,
        }
    }
}

/// A logical region registered in the hierarchy.
#[derive(Debug, Clone)]
pub struct Region {
    pub name: String,
    pub bytes: u64,
    pub tier: Tier,
    /// Device allocation backing it when tier == Device.
    pub alloc: Option<AllocId>,
}

/// The three-tier memory system of one SuperNode device slice.
#[derive(Debug)]
pub struct HierarchicalMemory {
    pub device: DeviceAllocator,
    pub remote_capacity: u64,
    pub remote_used: u64,
    pub host_used: u64,
    regions: HashMap<u64, Region>,
    next_region: u64,
    /// Cumulative microseconds of defrag stall charged (compaction moves
    /// bytes at HBM bandwidth).
    pub defrag_stall_us: f64,
}

/// Handle to a registered region.
pub type RegionId = u64;

impl HierarchicalMemory {
    pub fn new(hw: &HwConfig) -> Self {
        Self {
            device: DeviceAllocator::new(hw.device_capacity),
            remote_capacity: hw.remote_capacity,
            remote_used: 0,
            host_used: 0,
            regions: HashMap::new(),
            next_region: 1,
        defrag_stall_us: 0.0,
        }
    }

    /// Register a region in `tier`, allocating device space if needed.
    /// Returns (region id, defrag stall charged in us).
    pub fn register(&mut self, name: &str, bytes: u64, tier: Tier, hw: &HwConfig) -> Result<(RegionId, f64)> {
        let mut stall = 0.0;
        let alloc = match tier {
            Tier::Device => {
                let (id, moved) = self.device.alloc(bytes)?;
                stall = Self::defrag_us(moved, hw);
                self.defrag_stall_us += stall;
                Some(id)
            }
            Tier::Remote => {
                if self.remote_used + bytes > self.remote_capacity {
                    bail!("remote pool exhausted");
                }
                self.remote_used += bytes;
                None
            }
            Tier::Host => {
                self.host_used += bytes;
                None
            }
        };
        let id = self.next_region;
        self.next_region += 1;
        self.regions.insert(id, Region { name: name.into(), bytes, tier, alloc });
        Ok((id, stall))
    }

    /// Move a region to another tier. Returns (transfer kind, duration us,
    /// defrag stall us).
    pub fn migrate(&mut self, id: RegionId, dst: Tier, hw: &HwConfig) -> Result<(TransferKind, f64, f64)> {
        let region = self.regions.get(&id).cloned();
        let Some(region) = region else { bail!("unknown region {id}") };
        if region.tier == dst {
            return Ok((TransferKind::between(region.tier, dst).unwrap_or(TransferKind::D2D), 0.0, 0.0));
        }
        let kind = TransferKind::between(region.tier, dst)?;
        let dur = kind.duration_us(region.bytes, hw);

        // Release source.
        match region.tier {
            Tier::Device => {
                if let Some(a) = region.alloc {
                    self.device.free(a)?;
                }
            }
            Tier::Remote => self.remote_used -= region.bytes,
            Tier::Host => self.host_used -= region.bytes,
        }
        // Acquire destination.
        let mut stall = 0.0;
        let alloc = match dst {
            Tier::Device => {
                let (a, moved) = self.device.alloc(region.bytes)?;
                stall = Self::defrag_us(moved, hw);
                self.defrag_stall_us += stall;
                Some(a)
            }
            Tier::Remote => {
                if self.remote_used + region.bytes > self.remote_capacity {
                    bail!("remote pool exhausted");
                }
                self.remote_used += region.bytes;
                None
            }
            Tier::Host => {
                self.host_used += region.bytes;
                None
            }
        };
        let r = self.regions.get_mut(&id).unwrap();
        r.tier = dst;
        r.alloc = alloc;
        Ok((kind, dur, stall))
    }

    /// Drop a region entirely.
    pub fn release(&mut self, id: RegionId) -> Result<()> {
        let Some(region) = self.regions.remove(&id) else { bail!("unknown region {id}") };
        match region.tier {
            Tier::Device => {
                if let Some(a) = region.alloc {
                    self.device.free(a)?;
                }
            }
            Tier::Remote => self.remote_used -= region.bytes,
            Tier::Host => self.host_used -= region.bytes,
        }
        Ok(())
    }

    pub fn region(&self, id: RegionId) -> Option<&Region> {
        self.regions.get(&id)
    }

    pub fn device_used(&self) -> u64 {
        self.device.used()
    }

    /// Compaction stall: moved bytes at HBM bandwidth (read+write).
    fn defrag_us(moved: u64, hw: &HwConfig) -> f64 {
        2.0 * moved as f64 / (hw.hbm_gbps * 1e9) * 1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::GB;

    fn hw() -> HwConfig {
        HwConfig {
            compute_tflops: 100.0,
            hbm_gbps: 1000.0,
            d2r_gbps: 33.6,
            r2d_gbps: 33.6,
            link_latency_us: 10.0,
            net_gbps: 56.0,
            host_overhead_us: 150.0,
            device_capacity: 4 * GB,
            remote_capacity: 64 * GB,
        }
    }

    #[test]
    fn register_per_tier() {
        let hw = hw();
        let mut m = HierarchicalMemory::new(&hw);
        let (d, _) = m.register("w", GB, Tier::Device, &hw).unwrap();
        let (r, _) = m.register("kv", 2 * GB, Tier::Remote, &hw).unwrap();
        assert_eq!(m.device_used(), GB);
        assert_eq!(m.remote_used, 2 * GB);
        assert_eq!(m.region(d).unwrap().tier, Tier::Device);
        assert_eq!(m.region(r).unwrap().tier, Tier::Remote);
    }

    #[test]
    fn migrate_d2r_frees_device() {
        let hw = hw();
        let mut m = HierarchicalMemory::new(&hw);
        let (id, _) = m.register("act", GB, Tier::Device, &hw).unwrap();
        let (kind, dur, _) = m.migrate(id, Tier::Remote, &hw).unwrap();
        assert_eq!(kind, TransferKind::D2R);
        assert!(dur > 0.0);
        assert_eq!(m.device_used(), 0);
        assert_eq!(m.remote_used, GB);
    }

    #[test]
    fn migrate_r2d_uses_r2d_bandwidth() {
        let hw = hw();
        let mut m = HierarchicalMemory::new(&hw);
        let (id, _) = m.register("kv", GB, Tier::Remote, &hw).unwrap();
        let (kind, dur, _) = m.migrate(id, Tier::Device, &hw).unwrap();
        assert_eq!(kind, TransferKind::R2D);
        let expect = hw.r2d_us(GB);
        assert!((dur - expect).abs() < 1e-6);
    }

    #[test]
    fn same_tier_migrate_is_noop() {
        let hw = hw();
        let mut m = HierarchicalMemory::new(&hw);
        let (id, _) = m.register("x", GB, Tier::Remote, &hw).unwrap();
        let (_, dur, _) = m.migrate(id, Tier::Remote, &hw).unwrap();
        assert_eq!(dur, 0.0);
    }

    #[test]
    fn remote_pool_capacity_enforced() {
        let hw = hw();
        let mut m = HierarchicalMemory::new(&hw);
        assert!(m.register("big", 65 * GB, Tier::Remote, &hw).is_err());
    }

    #[test]
    fn device_oom_propagates() {
        let hw = hw();
        let mut m = HierarchicalMemory::new(&hw);
        assert!(m.register("big", 5 * GB, Tier::Device, &hw).is_err());
    }

    #[test]
    fn release_returns_space() {
        let hw = hw();
        let mut m = HierarchicalMemory::new(&hw);
        let (id, _) = m.register("x", GB, Tier::Device, &hw).unwrap();
        m.release(id).unwrap();
        assert_eq!(m.device_used(), 0);
        assert!(m.region(id).is_none());
    }

    #[test]
    fn transfer_kind_matrix() {
        use Tier::*;
        assert_eq!(TransferKind::between(Host, Remote).unwrap(), TransferKind::H2R);
        assert_eq!(TransferKind::between(Remote, Host).unwrap(), TransferKind::R2H);
        assert_eq!(TransferKind::between(Device, Remote).unwrap(), TransferKind::D2R);
        assert_eq!(TransferKind::between(Remote, Device).unwrap(), TransferKind::R2D);
    }
}
