//! Device HBM allocator with real fragmentation behaviour.
//!
//! First-fit free-list allocator over a simulated address space. When an
//! allocation fails although enough *total* free bytes exist, the allocator
//! performs a compaction pass ("memory defragmentation" in §7.3.2) —
//! counting the event and the bytes moved, which the serving simulator
//! converts into stall time. Table 4's defrag-event column comes from here.

use anyhow::{bail, Result};

/// Identifier of a live allocation.
pub type AllocId = u64;

#[derive(Debug, Clone, Copy)]
struct Block {
    addr: u64,
    size: u64,
    id: AllocId,
}

/// First-fit allocator with compaction.
#[derive(Debug, Clone)]
pub struct DeviceAllocator {
    capacity: u64,
    live: Vec<Block>, // sorted by addr
    next_id: AllocId,
    /// Number of compaction passes triggered by fragmentation.
    pub defrag_events: u64,
    /// Total bytes moved across all compactions.
    pub defrag_bytes_moved: u64,
    /// High-water mark of used bytes.
    pub peak_used: u64,
    /// Allocation failures even after compaction (hard OOM).
    pub oom_events: u64,
}

impl DeviceAllocator {
    pub fn new(capacity: u64) -> Self {
        Self {
            capacity,
            live: Vec::new(),
            next_id: 1,
            defrag_events: 0,
            defrag_bytes_moved: 0,
            peak_used: 0,
            oom_events: 0,
        }
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn used(&self) -> u64 {
        self.live.iter().map(|b| b.size).sum()
    }

    pub fn free_total(&self) -> u64 {
        self.capacity - self.used()
    }

    /// Largest contiguous free extent.
    pub fn largest_free_extent(&self) -> u64 {
        let mut largest = 0u64;
        let mut cursor = 0u64;
        for b in &self.live {
            largest = largest.max(b.addr - cursor);
            cursor = b.addr + b.size;
        }
        largest.max(self.capacity - cursor)
    }

    /// External fragmentation in [0,1]: 1 - largest_extent / total_free.
    pub fn fragmentation(&self) -> f64 {
        let free = self.free_total();
        if free == 0 {
            return 0.0;
        }
        1.0 - self.largest_free_extent() as f64 / free as f64
    }

    fn find_first_fit(&self, size: u64) -> Option<u64> {
        let mut cursor = 0u64;
        for b in &self.live {
            if b.addr - cursor >= size {
                return Some(cursor);
            }
            cursor = b.addr + b.size;
        }
        if self.capacity - cursor >= size {
            Some(cursor)
        } else {
            None
        }
    }

    /// Allocate `size` bytes. Returns (id, bytes_moved_by_defrag): the
    /// caller charges compaction cost into its timeline.
    pub fn alloc(&mut self, size: u64) -> Result<(AllocId, u64)> {
        if size == 0 {
            bail!("zero-size allocation");
        }
        let mut moved = 0u64;
        let addr = match self.find_first_fit(size) {
            Some(a) => a,
            None => {
                if self.free_total() >= size {
                    // Fragmented: compact (slide all blocks down).
                    moved = self.compact();
                    self.find_first_fit(size)
                        .expect("post-compaction fit must succeed")
                } else {
                    self.oom_events += 1;
                    bail!(
                        "OOM: need {size}, free {} of {}",
                        self.free_total(),
                        self.capacity
                    );
                }
            }
        };
        let id = self.next_id;
        self.next_id += 1;
        let idx = self.live.partition_point(|b| b.addr < addr);
        self.live.insert(idx, Block { addr, size, id });
        self.peak_used = self.peak_used.max(self.used());
        Ok((id, moved))
    }

    /// Release allocation `id`.
    pub fn free(&mut self, id: AllocId) -> Result<()> {
        match self.live.iter().position(|b| b.id == id) {
            Some(i) => {
                self.live.remove(i);
                Ok(())
            }
            None => bail!("free of unknown allocation {id}"),
        }
    }

    /// Slide every live block to the lowest address (compaction). Returns
    /// bytes moved.
    pub fn compact(&mut self) -> u64 {
        self.defrag_events += 1;
        let mut cursor = 0u64;
        let mut moved = 0u64;
        for b in &mut self.live {
            if b.addr != cursor {
                moved += b.size;
                b.addr = cursor;
            }
            cursor += b.size;
        }
        self.defrag_bytes_moved += moved;
        moved
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_roundtrip() {
        let mut a = DeviceAllocator::new(1000);
        let (id, moved) = a.alloc(400).unwrap();
        assert_eq!(moved, 0);
        assert_eq!(a.used(), 400);
        a.free(id).unwrap();
        assert_eq!(a.used(), 0);
    }

    #[test]
    fn oom_when_truly_full() {
        let mut a = DeviceAllocator::new(100);
        a.alloc(80).unwrap();
        assert!(a.alloc(30).is_err());
        assert_eq!(a.oom_events, 1);
    }

    #[test]
    fn fragmentation_triggers_compaction() {
        let mut a = DeviceAllocator::new(100);
        let (i1, _) = a.alloc(30).unwrap();
        let (_i2, _) = a.alloc(30).unwrap();
        let (i3, _) = a.alloc(30).unwrap();
        // Free blocks 1 and 3: 40 total free but split 30+10... actually
        // free = holes at [0,30) and [60,90) + tail [90,100): largest 30.
        a.free(i1).unwrap();
        a.free(i3).unwrap();
        assert_eq!(a.free_total(), 70);
        assert!(a.largest_free_extent() < 70);
        // 50 doesn't fit contiguously -> compaction.
        let (_, moved) = a.alloc(50).unwrap();
        assert!(moved > 0);
        assert_eq!(a.defrag_events, 1);
        assert_eq!(a.used(), 80);
    }

    #[test]
    fn no_compaction_when_contiguous_fit_exists() {
        let mut a = DeviceAllocator::new(1000);
        let (i1, _) = a.alloc(100).unwrap();
        a.free(i1).unwrap();
        let (_, moved) = a.alloc(100).unwrap();
        assert_eq!(moved, 0);
        assert_eq!(a.defrag_events, 0);
    }

    #[test]
    fn peak_tracks_high_water() {
        let mut a = DeviceAllocator::new(1000);
        let (i1, _) = a.alloc(600).unwrap();
        a.free(i1).unwrap();
        a.alloc(100).unwrap();
        assert_eq!(a.peak_used, 600);
    }

    #[test]
    fn fragmentation_metric_bounds() {
        let mut a = DeviceAllocator::new(1000);
        assert_eq!(a.fragmentation(), 0.0);
        let (i1, _) = a.alloc(100).unwrap();
        a.alloc(100).unwrap();
        a.free(i1).unwrap();
        let f = a.fragmentation();
        assert!((0.0..=1.0).contains(&f));
        assert!(f > 0.0);
    }

    #[test]
    fn double_free_rejected() {
        let mut a = DeviceAllocator::new(100);
        let (id, _) = a.alloc(10).unwrap();
        a.free(id).unwrap();
        assert!(a.free(id).is_err());
    }

    #[test]
    fn many_allocs_stress_first_fit() {
        let mut a = DeviceAllocator::new(1 << 20);
        let mut ids = Vec::new();
        for i in 0..1000 {
            let (id, _) = a.alloc(64 + (i % 7) * 16).unwrap();
            ids.push(id);
        }
        for id in ids.iter().step_by(2) {
            a.free(*id).unwrap();
        }
        // Still allocatable; compaction may or may not fire.
        a.alloc(4096).unwrap();
        assert!(a.used() <= a.capacity());
    }
}
