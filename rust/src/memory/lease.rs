//! Peer-HBM lease broker: idle-replica HBM as a revocable middle tier.
//!
//! A SuperNode replica that is momentarily idle has the fastest spare
//! capacity in the cluster — its own HBM, reachable over the
//! device↔device fabric edge ([`crate::sim::PeerLink`]) without touching
//! the shared pool. The [`LeaseLedger`] brokers that capacity:
//!
//! - A **lender** registers spare HBM (`register_lender`) and opens or
//!   closes itself for new borrows as its own load moves (`set_open`).
//! - A **borrower** asks the ledger for a lender (`try_borrow`); on
//!   success its KV blocks are homed at [`Tier::Peer(lender)`]
//!   (`crate::graph::Tier::Peer`) instead of the pool, and every fetch of
//!   those blocks rides the faster peer edge.
//! - On a load spike the lender **revokes** (`begin_revoke`): the lease
//!   closes immediately and each borrowed block is *demoted to the pool*,
//!   never dropped — [`demote`](LeaseLedger::demote) reserves the pool
//!   destination **first** and only then retires the borrowed bytes, so a
//!   full pool leaves the block safely parked at the peer until a later
//!   retry. Conservation holds through revoke: every borrowed byte is
//!   either still lent out or has landed in the pool exactly once
//!   (property P18 in `rust/tests/proptest_invariants.rs`).
//!
//! The ledger tracks *bytes*, not blocks: block identity and re-homing
//! live in `kvcache::KvCacheManager`, which owns the `Tier::Peer` →
//! `Tier::Remote` rewrite on revocation. Like [`super::PoolHandle`] this
//! handle is cheaply cloneable and all clones share one ledger, so the
//! cluster's engines contend for the same spare HBM.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::tiers::PoolHandle;

/// Cluster-wide broker for harvested peer HBM. Cloneable; all clones
/// share state.
#[derive(Debug, Clone, Default)]
pub struct LeaseLedger {
    state: Arc<Mutex<LeaseState>>,
}

#[derive(Debug, Default)]
struct LeaseState {
    lenders: HashMap<u16, Lender>,
    /// Running peak of Σ lent across all lenders.
    borrowed_peak: u64,
    /// Revocation events (one per `begin_revoke` that found live leases).
    revocations: u64,
    /// Bytes demoted to the pool by revocations.
    revoked_bytes: u64,
}

#[derive(Debug, Default)]
struct Lender {
    /// Spare HBM this replica exposes (bytes).
    capacity: u64,
    /// Bytes currently borrowed out of it.
    lent: u64,
    /// Accepting new borrows? Closed lenders keep existing leases alive
    /// (until revoked) but match no new ones.
    open: bool,
}

impl LeaseLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Expose `capacity` bytes of spare HBM on `replica`. Lenders start
    /// open. Re-registering resizes the exposed capacity in place.
    pub fn register_lender(&self, replica: u16, capacity: u64) {
        let mut s = self.state.lock().unwrap();
        let l = s.lenders.entry(replica).or_default();
        l.capacity = capacity;
        l.open = true;
    }

    /// Open or close `replica` for *new* borrows. No-op for unregistered
    /// replicas. Closing does not touch existing leases.
    pub fn set_open(&self, replica: u16, open: bool) {
        let mut s = self.state.lock().unwrap();
        if let Some(l) = s.lenders.get_mut(&replica) {
            l.open = open;
        }
    }

    pub fn is_open(&self, replica: u16) -> bool {
        let s = self.state.lock().unwrap();
        s.lenders.get(&replica).is_some_and(|l| l.open)
    }

    /// Bytes currently borrowed out of `replica`'s HBM.
    pub fn lent(&self, replica: u16) -> u64 {
        let s = self.state.lock().unwrap();
        s.lenders.get(&replica).map_or(0, |l| l.lent)
    }

    /// Spare bytes still borrowable from `replica` (0 when closed).
    pub fn headroom(&self, replica: u16) -> u64 {
        let s = self.state.lock().unwrap();
        s.lenders
            .get(&replica)
            .filter(|l| l.open)
            .map_or(0, |l| l.capacity.saturating_sub(l.lent))
    }

    /// Σ bytes borrowed out across all lenders.
    pub fn total_lent(&self) -> u64 {
        let s = self.state.lock().unwrap();
        s.lenders.values().map(|l| l.lent).sum()
    }

    /// Pick a lender for `borrower` with room for `bytes` and record the
    /// borrow. Deterministic: among open lenders (≠ `borrower`) with
    /// enough headroom, the one with the most headroom wins, ties broken
    /// by lowest replica id. Returns the lender's id.
    pub fn try_borrow(&self, borrower: u16, bytes: u64) -> Option<u16> {
        let mut s = self.state.lock().unwrap();
        let pick = s
            .lenders
            .iter()
            .filter(|(r, l)| {
                **r != borrower && l.open && l.capacity.saturating_sub(l.lent) >= bytes
            })
            // max_by_key keeps the *last* maximum; order by (headroom,
            // Reverse(id)) so the lowest id wins ties deterministically.
            .max_by_key(|(r, l)| (l.capacity.saturating_sub(l.lent), std::cmp::Reverse(**r)))
            .map(|(r, _)| *r)?;
        let l = s.lenders.get_mut(&pick).unwrap();
        l.lent += bytes;
        let total: u64 = s.lenders.values().map(|l| l.lent).sum();
        s.borrowed_peak = s.borrowed_peak.max(total);
        Some(pick)
    }

    /// Record a borrow against a *specific* lender (growing an existing
    /// lease keeps blocks of one sequence on one peer). Fails if the
    /// lender is closed or lacks headroom.
    pub fn borrow_from(&self, lender: u16, bytes: u64) -> bool {
        let mut s = self.state.lock().unwrap();
        let Some(l) = s.lenders.get_mut(&lender) else { return false };
        if !l.open || l.capacity.saturating_sub(l.lent) < bytes {
            return false;
        }
        l.lent += bytes;
        let total: u64 = s.lenders.values().map(|l| l.lent).sum();
        s.borrowed_peak = s.borrowed_peak.max(total);
        true
    }

    /// Return `bytes` of `lender`'s HBM (borrower freed or migrated the
    /// blocks itself — a retire/preempt, not a revocation).
    pub fn release(&self, lender: u16, bytes: u64) {
        let mut s = self.state.lock().unwrap();
        if let Some(l) = s.lenders.get_mut(&lender) {
            debug_assert!(l.lent >= bytes, "lease release exceeds lent bytes");
            l.lent = l.lent.saturating_sub(bytes);
        }
    }

    /// Lender-side load spike: close `lender` to new borrows and return
    /// the bytes currently out on lease (what the borrowers must now
    /// demote). Counts as a revocation event iff any lease was live.
    pub fn begin_revoke(&self, lender: u16) -> u64 {
        let mut s = self.state.lock().unwrap();
        let Some(l) = s.lenders.get_mut(&lender) else { return 0 };
        l.open = false;
        let out = l.lent;
        if out > 0 {
            s.revocations += 1;
        }
        out
    }

    /// Demote `bytes` of a revoked lease into `pool`. The pool
    /// reservation is taken **first**; only on success does the lease
    /// retire the bytes — so a full pool fails the demotion cleanly (the
    /// copy stays at the peer, the borrower retries later) and a
    /// successful one moves every byte exactly once.
    pub fn demote(&self, lender: u16, bytes: u64, pool: &PoolHandle) -> bool {
        let mut s = self.state.lock().unwrap();
        let Some(l) = s.lenders.get_mut(&lender) else { return false };
        // Overdraw answers `false` rather than asserting: a revocation
        // sweep can race a borrower-side release, and the sweep retrying
        // against an already-empty lease must be a clean no-op.
        if l.lent < bytes || !pool.try_reserve(bytes) {
            return false;
        }
        l.lent -= bytes;
        s.revoked_bytes += bytes;
        true
    }

    /// Running peak of Σ lent across all lenders.
    pub fn borrowed_peak(&self) -> u64 {
        self.state.lock().unwrap().borrowed_peak
    }

    /// Revocation events so far.
    pub fn revocations(&self) -> u64 {
        self.state.lock().unwrap().revocations
    }

    /// Bytes demoted to the pool by revocations so far.
    pub fn revoked_bytes(&self) -> u64 {
        self.state.lock().unwrap().revoked_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn borrow_picks_max_headroom_lowest_id() {
        let lease = LeaseLedger::new();
        lease.register_lender(1, 100);
        lease.register_lender(2, 100);
        lease.register_lender(3, 50);
        // 1 and 2 tie on headroom; lowest id wins.
        assert_eq!(lease.try_borrow(0, 40), Some(1));
        // Now 2 has the most headroom.
        assert_eq!(lease.try_borrow(0, 40), Some(2));
        assert_eq!(lease.lent(1), 40);
        assert_eq!(lease.lent(2), 40);
        assert_eq!(lease.total_lent(), 80);
    }

    #[test]
    fn borrower_never_matches_itself() {
        let lease = LeaseLedger::new();
        lease.register_lender(7, 100);
        assert_eq!(lease.try_borrow(7, 10), None);
        assert_eq!(lease.try_borrow(3, 10), Some(7));
    }

    #[test]
    fn closed_lender_matches_nothing_but_keeps_leases() {
        let lease = LeaseLedger::new();
        lease.register_lender(1, 100);
        assert!(lease.borrow_from(1, 60));
        lease.set_open(1, false);
        assert!(!lease.borrow_from(1, 10));
        assert_eq!(lease.try_borrow(0, 10), None);
        assert_eq!(lease.lent(1), 60);
        assert_eq!(lease.headroom(1), 0);
    }

    #[test]
    fn revoke_demotes_into_pool_exactly_once() {
        let lease = LeaseLedger::new();
        let pool = PoolHandle::new(100);
        lease.register_lender(1, 100);
        assert!(lease.borrow_from(1, 80));
        let out = lease.begin_revoke(1);
        assert_eq!(out, 80);
        assert_eq!(lease.revocations(), 1);
        assert!(lease.demote(1, 80, &pool));
        assert_eq!(pool.used(), 80);
        assert_eq!(lease.lent(1), 0);
        assert_eq!(lease.revoked_bytes(), 80);
        // Nothing left to demote: a second attempt must not double-move.
        assert!(!lease.demote(1, 80, &pool));
        assert_eq!(pool.used(), 80);
    }

    #[test]
    fn demote_into_full_pool_leaves_lease_intact() {
        let lease = LeaseLedger::new();
        let pool = PoolHandle::new(50);
        lease.register_lender(1, 100);
        assert!(lease.borrow_from(1, 80));
        lease.begin_revoke(1);
        // Pool too small: demotion fails, bytes stay on lease.
        assert!(!lease.demote(1, 80, &pool));
        assert_eq!(pool.used(), 0);
        assert_eq!(lease.lent(1), 80);
        assert_eq!(lease.revoked_bytes(), 0);
    }

    #[test]
    fn empty_revoke_is_not_an_event() {
        let lease = LeaseLedger::new();
        lease.register_lender(1, 100);
        assert_eq!(lease.begin_revoke(1), 0);
        assert_eq!(lease.revocations(), 0);
    }

    #[test]
    fn borrowed_peak_tracks_cluster_total() {
        let lease = LeaseLedger::new();
        lease.register_lender(1, 100);
        lease.register_lender(2, 100);
        assert!(lease.borrow_from(1, 60));
        assert!(lease.borrow_from(2, 50));
        lease.release(1, 60);
        assert_eq!(lease.total_lent(), 50);
        assert_eq!(lease.borrowed_peak(), 110);
    }
}
