//! KV-cache substrate: paged blocks, residency policies (device vs remote
//! pool), NSA sparse-attention block selection, and per-step transfer/CPU
//! cost accounting. Consumed by [`crate::serving`] (Tables 3–6, §7.4).

mod manager;
pub mod nsa;

pub use manager::{KvCacheManager, KvPolicy, StepCost};
pub use nsa::NsaConfig;
