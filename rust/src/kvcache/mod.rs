//! KV-cache substrate: paged blocks, residency policies (device vs remote
//! pool), NSA sparse-attention block selection, per-step transfer/CPU cost
//! accounting — and cluster-wide prefix sharing with copy-on-write blocks.
//! Consumed by [`crate::serving`] (Tables 3–6, §7.4).
//!
//! # The block-sharing model
//!
//! Under [`KvPolicy::FullOffload`] every KV block's home is the SuperNode
//! remote pool, which makes the pool a natural *cluster-wide prefix
//! cache*: a prompt prefix prefilled by any device is pool-resident, so
//! any other device can attach to it instead of recomputing prefill.
//! Three pieces cooperate:
//!
//! * **[`PrefixIndex`]** — a radix tree over token-block *chain hashes*
//!   (`hash_i` commits to block `i`'s tokens and `hash_{i-1}`, so one hash
//!   identifies a whole prefix and the tree lives in a flat map with
//!   parent links). The handle is cloneable; `serving/cluster.rs` shares
//!   one across all replicas.
//! * **Refcounted residency** — the pool's shared ledger
//!   ([`crate::memory::PoolHandle::shared_acquire`]) counts each shared
//!   block's bytes *once* no matter how many sequences (or replicas) read
//!   it. The index holds one reference per resident entry; each live
//!   sequence holds one per block it acquired. Eviction
//!   ([`PrefixIndex::evict`]) only takes LRU *leaves* whose last reference
//!   is the index's own — a block a sequence is still reading, or an
//!   interior block of a longer resident prefix, cannot be evicted. With
//!   a [`crate::memory::TieredLedger`] carrying cold DRAM/CXL/SSD tiers,
//!   pressure is relieved demotion-first: the LRU unreferenced entry
//!   moves its reservation below the pool and *stays resident* (later
//!   hits fetch it over the cold path, reported per tier in
//!   `cold_fetch`); only when every cold tier is full does eviction run.
//! * **Copy-on-write** — [`KvCacheManager::fork`] makes a child sequence
//!   share every parent block for free; a shared tail that is *written*
//!   (the per-step persist in [`KvCacheManager::decode_step`]) first forks
//!   a private copy ([`KvCacheManager::cow_forks`] counts these).
//!
//! # Worked example
//!
//! Two requests share a 192-token system prompt (3 full 64-token blocks,
//! hashes `h1..h3`), each with its own 58-token suffix (1 partial block):
//!
//! ```text
//! admit_prefix(seq A, 250 tok, [h1,h2,h3]):   index: h1 -> h2 -> h3
//!   cold: 3 shared blocks reserved + 1 private   pool: 4 blocks
//!   prefill computes all 250 tokens              (A refs h1..h3)
//! admit_prefix(seq B, 250 tok, [h1,h2,h3]):
//!   hit_blocks = 3, deduped = 3 blocks           pool: 5 blocks (not 8)
//!   prefill computes only B's 58-token suffix;
//!   prefix_fetch_bytes = 3 blocks (pool -> device, compiled Prefetch)
//! retire(A); retire(B):
//!   private tails freed                          pool: 3 blocks
//!   h1..h3 stay cached (index refs) until evicted under pressure
//! ```
//!
//! The serving layer surfaces this as `ServingReport::prefix_hit_blocks`,
//! `prefill_flops_saved` and `pool_bytes_deduped`.

mod manager;
pub mod nsa;
pub mod prefix;

pub use manager::{KvCacheManager, KvError, KvPolicy, PrefixAdmit, StepCost};
pub use nsa::NsaConfig;
pub use prefix::{AcquireResult, PrefixIndex};
