//! Radix-tree prefix index over token-block hashes (the cluster-wide
//! prefix cache of the serving tier).
//!
//! Every *full* KV block of a prompt gets a chain hash: `hash_i` commits to
//! the block's tokens *and* `hash_{i-1}`, so a hash identifies an entire
//! prefix, not just one block — the chain hash *is* the radix path, which
//! lets the tree live in a flat map keyed by hash with parent links and
//! child counts instead of explicit edges.
//!
//! Refcounting is delegated to the pool's shared ledger
//! ([`PoolHandle::shared_acquire`]): the index holds exactly one reference
//! per resident node (taken when the node is inserted, dropped when it is
//! evicted), and every live sequence holds one reference per block it
//! acquired. A node is evictable only when it is a leaf (no children — so
//! resident prefixes stay chain-contiguous) *and* the index holds the last
//! reference (`shared_refs == 1` — no live sequence is reading it).
//! Eviction is LRU over evictable leaves.
//!
//! With a [`TieredLedger`] carrying cold tiers (DRAM/CXL/SSD below the
//! pool), pool pressure is relieved **demotion-first, eviction-second**:
//! the LRU unreferenced node — leaf or not, demotion keeps it resident —
//! moves its shared reservation one tier down
//! ([`TieredLedger::shared_move`]) and stays readable over the deeper
//! fabric path; only when no cold capacity remains does the LRU
//! unreferenced *leaf* actually evict. A later admission hitting a
//! demoted node attaches on the node's current tier and reports the bytes
//! in [`AcquireResult::cold_fetch`], so the serving engine lowers the
//! read as a cold-tier `Prefetch` instead of a pool fetch. Nodes a live
//! sequence still references never move, which keeps every holder's
//! recorded tier valid for the lifetime of its reference.
//!
//! The handle is cheaply cloneable; all clones share one tree, which is how
//! `serving/cluster.rs` makes the index cluster-wide: a prefix prefilled on
//! replica A is resident in the shared pool, so replica B's admission
//! attaches to it and fetches the blocks instead of recomputing prefill.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::graph::Tier;
use crate::memory::{PoolHandle, SharedAcquire, TieredLedger};

/// Cluster-wide prefix index handle. Clones share one tree.
#[derive(Debug, Clone, Default)]
pub struct PrefixIndex {
    state: Arc<Mutex<IndexState>>,
}

#[derive(Debug, Default)]
struct IndexState {
    nodes: HashMap<u64, Node>,
    /// Logical clock for LRU ordering (bumped on every acquire walk).
    clock: u64,
    hits: u64,
    misses: u64,
    evicted: u64,
    /// Nodes pushed below the pool instead of evicted (tiered ledgers).
    demoted: u64,
}

#[derive(Debug)]
struct Node {
    parent: Option<u64>,
    /// Resident children (edges out of this node). Non-zero blocks
    /// eviction, which keeps resident prefixes chain-contiguous.
    children: u32,
    bytes: u64,
    last_use: u64,
    /// Which tier's ledger holds this node's shared reservation. Freshly
    /// inserted nodes live at the pool; demotion moves them down.
    tier: Tier,
}

/// Outcome of one [`PrefixIndex::acquire`] walk.
#[derive(Debug, Clone, Default)]
pub struct AcquireResult {
    /// Chain hashes the caller now holds one pool reference each for, in
    /// chain order. Always a prefix of the requested chain; the first
    /// [`hit_blocks`](Self::hit_blocks) of them were already resident.
    pub acquired: Vec<u64>,
    /// Hashes *inserted* by this walk (the cold tail of `acquired`). The
    /// caller computes these blocks; pass them to [`PrefixIndex::abort`]
    /// if the admission is rolled back before they are produced.
    pub inserted: Vec<u64>,
    /// The tier each `acquired` entry's reservation lives at (parallel to
    /// [`acquired`](Self::acquired)). The caller must release each
    /// reference on that tier's ledger. All-`Remote` on untiered setups.
    pub tiers: Vec<Tier>,
    /// Leading blocks that were already resident (dedup hits).
    pub hit_blocks: usize,
    /// Pool bytes the hits deduplicated (attached without reserving).
    pub deduped_bytes: u64,
    /// Bytes of hit blocks resident *below* the pool, summed per cold
    /// tier — the device must fetch these over the deep fabric path.
    pub cold_fetch: Vec<(Tier, u64)>,
}

impl PrefixIndex {
    pub fn new() -> Self {
        Self::default()
    }

    /// Walk `hashes` (a chain, root first), acquiring one pool reference
    /// per block for the calling sequence.
    ///
    /// Resident blocks attach (a dedup hit: no new pool bytes); absent
    /// blocks are reserved and inserted, to be computed by the caller's
    /// prefill and written back. If the pool cannot hold a new block the
    /// index evicts cold leaves and retries once; if it is still full the
    /// walk stops there — acquiring a *partial* prefix is fine, the caller
    /// just computes more of the prompt itself.
    pub fn acquire(&self, hashes: &[u64], block_bytes: u64, pool: &PoolHandle) -> AcquireResult {
        self.acquire_tiered(hashes, block_bytes, &TieredLedger::single(pool.clone()))
    }

    /// [`acquire`](Self::acquire) against a tier stack: hits on demoted
    /// nodes attach on the node's *current* tier (and are summed into
    /// [`AcquireResult::cold_fetch`]); pool pressure on cold inserts is
    /// relieved demotion-first. With a single-tier ledger this is exactly
    /// the untiered walk.
    pub fn acquire_tiered(
        &self,
        hashes: &[u64],
        block_bytes: u64,
        ledger: &TieredLedger,
    ) -> AcquireResult {
        let pool = ledger.pool();
        let mut s = self.state.lock().unwrap();
        s.clock += 1;
        let now = s.clock;
        let mut out = AcquireResult::default();
        let mut parent: Option<u64> = None;
        for &h in hashes {
            if let Some(node) = s.nodes.get_mut(&h) {
                node.last_use = now;
                let tier = node.tier;
                let bytes = node.bytes;
                let handle = ledger.handle(tier).unwrap_or(pool);
                let r = handle.shared_acquire(h, block_bytes);
                debug_assert_eq!(r, SharedAcquire::Attached, "resident node must hold a ref");
                out.hit_blocks += 1;
                out.deduped_bytes += bytes;
                out.acquired.push(h);
                out.tiers.push(tier);
                if tier != Tier::Remote {
                    match out.cold_fetch.iter_mut().find(|(t, _)| *t == tier) {
                        Some(e) => e.1 += bytes,
                        None => out.cold_fetch.push((tier, bytes)),
                    }
                }
            } else {
                // Cold: reserve the sequence's reference, relieving pool
                // pressure once (demote-first, evict-second), then attach
                // the index's own reference.
                let mut r = pool.shared_acquire(h, block_bytes);
                if r == SharedAcquire::Exhausted {
                    Self::evict_locked(&mut s, ledger, block_bytes);
                    r = pool.shared_acquire(h, block_bytes);
                }
                match r {
                    SharedAcquire::Reserved => {}
                    SharedAcquire::Exhausted => break,
                    SharedAcquire::Attached => {
                        // Resident in the pool but unknown to the index
                        // (another clone raced us between map lookup and
                        // ledger call is impossible under one lock; this is
                        // a caller passing duplicate hashes). Count as hit.
                        out.hit_blocks += 1;
                        out.deduped_bytes += block_bytes;
                        out.acquired.push(h);
                        out.tiers.push(Tier::Remote);
                        parent = Some(h);
                        continue;
                    }
                }
                let index_ref = pool.shared_acquire(h, block_bytes);
                debug_assert_eq!(index_ref, SharedAcquire::Attached);
                let bytes = pool_quantized(pool, block_bytes);
                s.nodes.insert(
                    h,
                    Node { parent, children: 0, bytes, last_use: now, tier: Tier::Remote },
                );
                if let Some(p) = parent {
                    if let Some(pn) = s.nodes.get_mut(&p) {
                        pn.children += 1;
                    }
                }
                out.inserted.push(h);
                out.acquired.push(h);
                out.tiers.push(Tier::Remote);
            }
            parent = Some(h);
        }
        s.hits += out.hit_blocks as u64;
        s.misses += (hashes.len() - out.hit_blocks) as u64;
        out
    }

    /// Roll back an admission: drop the caller's references on `acquired`
    /// and remove the `inserted` nodes outright (their blocks were never
    /// computed, so leaving them resident would advertise KV that does not
    /// exist). `inserted` must be in chain order, as returned by
    /// [`acquire`](Self::acquire).
    pub fn abort(&self, acquired: &[u64], inserted: &[u64], pool: &PoolHandle) {
        self.abort_tiered(acquired, inserted, &TieredLedger::single(pool.clone()));
    }

    /// [`abort`](Self::abort) against a tier stack: each acquired hash is
    /// released on the tier its node's reservation currently lives at
    /// (pool for nodes already gone from the index).
    pub fn abort_tiered(&self, acquired: &[u64], inserted: &[u64], ledger: &TieredLedger) {
        let pool = ledger.pool();
        let mut s = self.state.lock().unwrap();
        for &h in acquired {
            let handle = s
                .nodes
                .get(&h)
                .and_then(|n| ledger.handle(n.tier))
                .unwrap_or(pool);
            handle.shared_release(h);
        }
        for &h in inserted.iter().rev() {
            let Some(node) = s.nodes.remove(&h) else { continue };
            debug_assert_eq!(node.children, 0, "aborted nodes are removed leaf-first");
            if let Some(p) = node.parent {
                if let Some(pn) = s.nodes.get_mut(&p) {
                    pn.children -= 1;
                }
            }
            // Inserted nodes are always fresh pool residents.
            pool.shared_release(h);
        }
    }

    /// Evict cold leaves (LRU first) until at least `want_bytes` have been
    /// freed or nothing more is evictable. Returns the bytes freed.
    pub fn evict(&self, pool: &PoolHandle, want_bytes: u64) -> u64 {
        self.evict_tiered(&TieredLedger::single(pool.clone()), want_bytes)
    }

    /// [`evict`](Self::evict) against a tier stack: pool pressure is
    /// relieved demotion-first (LRU unreferenced node moves one tier
    /// down, staying resident), eviction-second (only when no cold tier
    /// has room). Returns the *pool* bytes freed either way.
    pub fn evict_tiered(&self, ledger: &TieredLedger, want_bytes: u64) -> u64 {
        let mut s = self.state.lock().unwrap();
        Self::evict_locked(&mut s, ledger, want_bytes)
    }

    fn evict_locked(s: &mut IndexState, ledger: &TieredLedger, want_bytes: u64) -> u64 {
        let pool = ledger.pool();
        let mut freed = 0u64;
        while freed < want_bytes {
            // Demotion-first: the LRU pool-tier entry nobody reads — leaf
            // or not, demotion keeps it resident — moves its reservation
            // one tier down if any cold tier has room.
            if ledger.below(Tier::Remote).is_some() {
                let candidate = s
                    .nodes
                    .iter()
                    .filter(|(h, n)| n.tier == Tier::Remote && pool.shared_refs(**h) == 1)
                    .min_by_key(|(_, n)| n.last_use)
                    .map(|(h, n)| (*h, n.bytes));
                if let Some((h, bytes)) = candidate {
                    // Shallowest cold tier with room wins (Dram before
                    // Cxl before Ssd).
                    let dst = ledger
                        .tiers()
                        .skip(1)
                        .find(|&d| ledger.shared_move(h, Tier::Remote, d));
                    if let Some(d) = dst {
                        s.nodes.get_mut(&h).unwrap().tier = d;
                        s.demoted += 1;
                        freed += bytes;
                        continue;
                    }
                }
            }
            // Eviction: an entry is evictable iff it is a pool-tier leaf
            // and the index holds the last reference (no live sequence
            // reads it).
            let victim = s
                .nodes
                .iter()
                .filter(|(h, n)| {
                    n.children == 0 && n.tier == Tier::Remote && pool.shared_refs(**h) == 1
                })
                .min_by_key(|(_, n)| n.last_use)
                .map(|(h, _)| *h);
            let Some(h) = victim else { break };
            let node = s.nodes.remove(&h).unwrap();
            if let Some(p) = node.parent {
                if let Some(pn) = s.nodes.get_mut(&p) {
                    pn.children -= 1;
                }
            }
            let released = pool.shared_release(h);
            debug_assert!(released, "index held the last reference");
            freed += node.bytes;
            s.evicted += 1;
        }
        freed
    }

    /// Resident nodes.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Ledger bytes held by resident entries across all tiers (each
    /// counted once). Equals the pool's shared bytes on untiered setups.
    pub fn resident_bytes(&self) -> u64 {
        self.state.lock().unwrap().nodes.values().map(|n| n.bytes).sum()
    }

    /// Bytes of resident entries demoted below the pool, per cold tier.
    pub fn cold_resident_bytes(&self) -> Vec<(Tier, u64)> {
        let s = self.state.lock().unwrap();
        let mut out: Vec<(Tier, u64)> = Vec::new();
        for n in s.nodes.values() {
            if n.tier == Tier::Remote {
                continue;
            }
            match out.iter_mut().find(|(t, _)| *t == n.tier) {
                Some(e) => e.1 += n.bytes,
                None => out.push((n.tier, n.bytes)),
            }
        }
        out
    }

    /// Lifetime (hit blocks, missed blocks, evicted entries).
    pub fn stats(&self) -> (u64, u64, u64) {
        let s = self.state.lock().unwrap();
        (s.hits, s.misses, s.evicted)
    }

    /// Lifetime count of entries demoted below the pool instead of
    /// evicted.
    pub fn demoted(&self) -> u64 {
        self.state.lock().unwrap().demoted
    }
}

fn pool_quantized(pool: &PoolHandle, bytes: u64) -> u64 {
    let chunk = pool.chunk_bytes();
    if chunk <= 1 || bytes == 0 {
        bytes
    } else {
        bytes.div_ceil(chunk).saturating_mul(chunk)
    }
}

/// Chain-hash `block` token-block ids onto `prev` (FNV-1a style mix). The
/// workload generator uses this to stamp requests; stability across
/// replicas and runs is what makes the cache cluster-wide.
pub fn chain_hash(prev: u64, block_seed: u64) -> u64 {
    let mut h = prev ^ 0xcbf2_9ce4_8422_2325;
    for x in [block_seed, prev] {
        h ^= x;
        h = h.wrapping_mul(0x0000_0100_0000_01b3).rotate_left(29);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    const BLK: u64 = 64;

    fn chain(seed: u64, n: usize) -> Vec<u64> {
        let mut v = Vec::with_capacity(n);
        let mut h = seed;
        for i in 0..n {
            h = chain_hash(h, i as u64);
            v.push(h);
        }
        v
    }

    #[test]
    fn cold_then_hit() {
        let pool = PoolHandle::new_chunked(16 * BLK, BLK);
        let idx = PrefixIndex::new();
        let c = chain(1, 4);
        let a = idx.acquire(&c, BLK, &pool);
        assert_eq!(a.hit_blocks, 0);
        assert_eq!(a.acquired, c);
        assert_eq!(a.inserted, c);
        assert_eq!(idx.len(), 4);
        assert_eq!(pool.used(), 4 * BLK, "deduped: one reservation per block");
        // Same chain again: full hit, no new bytes.
        let b = idx.acquire(&c, BLK, &pool);
        assert_eq!(b.hit_blocks, 4);
        assert!(b.inserted.is_empty());
        assert_eq!(b.deduped_bytes, 4 * BLK);
        assert_eq!(pool.used(), 4 * BLK);
        // Divergent continuation shares the common prefix only.
        let mut c2 = chain(1, 2);
        c2.push(chain_hash(999, 0));
        let d = idx.acquire(&c2, BLK, &pool);
        assert_eq!(d.hit_blocks, 2);
        assert_eq!(d.inserted.len(), 1);
        assert_eq!(idx.len(), 5);
    }

    #[test]
    fn sequence_refs_block_eviction() {
        let pool = PoolHandle::new_chunked(4 * BLK, BLK);
        let idx = PrefixIndex::new();
        let c = chain(1, 4);
        let a = idx.acquire(&c, BLK, &pool);
        assert_eq!(a.acquired.len(), 4);
        // Live sequence holds refs: nothing evictable.
        assert_eq!(idx.evict(&pool, u64::MAX), 0);
        // Sequence retires (drops its refs): leaves become evictable,
        // leaf-first so resident prefixes stay chain-contiguous.
        for &h in &c {
            pool.shared_release(h);
        }
        assert_eq!(idx.evict(&pool, 1), BLK);
        assert_eq!(idx.len(), 3);
        assert_eq!(idx.evict(&pool, u64::MAX), 3 * BLK);
        assert!(idx.is_empty());
        assert_eq!(pool.used(), 0);
    }

    #[test]
    fn acquire_evicts_lru_under_pressure() {
        let pool = PoolHandle::new_chunked(4 * BLK, BLK);
        let idx = PrefixIndex::new();
        let old = chain(1, 2);
        let a = idx.acquire(&old, BLK, &pool);
        // Retire the old sequence: its entries are cold but cached.
        idx_release(&a.acquired, &pool);
        // A new 4-block chain needs the whole pool: the cold entries go.
        let newc = chain(2, 4);
        let b = idx.acquire(&newc, BLK, &pool);
        assert_eq!(b.acquired.len(), 4);
        assert_eq!(idx.len(), 4);
        assert_eq!(pool.used(), 4 * BLK);
        let (_, _, evicted) = idx.stats();
        assert_eq!(evicted, 2);
        // Pool full of *referenced* blocks: a further chain stops short.
        let c = idx.acquire(&chain(3, 2), BLK, &pool);
        assert!(c.acquired.is_empty(), "nothing evictable, nothing acquired");
    }

    #[test]
    fn abort_unwinds_inserted_nodes() {
        let pool = PoolHandle::new_chunked(16 * BLK, BLK);
        let idx = PrefixIndex::new();
        let c = chain(1, 3);
        let warm = idx.acquire(&c[..1], BLK, &pool);
        assert_eq!(warm.inserted.len(), 1);
        let a = idx.acquire(&c, BLK, &pool);
        assert_eq!(a.hit_blocks, 1);
        assert_eq!(a.inserted.len(), 2);
        idx.abort(&a.acquired, &a.inserted, &pool);
        // The pre-existing node survives (still referenced by `warm`'s
        // holder + the index); the aborted tail is gone entirely.
        assert_eq!(idx.len(), 1);
        assert_eq!(pool.used(), BLK);
        assert_eq!(pool.shared_refs(c[0]), 2);
        assert_eq!(pool.shared_refs(c[1]), 0);
    }

    #[test]
    fn resident_bytes_track_pool_ledger() {
        let pool = PoolHandle::new_chunked(1 << 20, 100);
        let idx = PrefixIndex::new();
        let c = chain(7, 5);
        // 64-byte blocks quantize to the 100-byte pool chunk.
        idx.acquire(&c, 64, &pool);
        assert_eq!(idx.resident_bytes(), 500);
        assert_eq!(pool.used(), 500);
        assert_eq!(pool.shared_bytes(), 500);
    }

    fn idx_release(hashes: &[u64], pool: &PoolHandle) {
        for &h in hashes {
            pool.shared_release(h);
        }
    }

    fn dram_ledger(pool_blocks: u64, dram_blocks: u64) -> TieredLedger {
        use crate::sim::{HwConfig, TierTopology};
        let hw = HwConfig::ascend910c_like();
        let topo = TierTopology::two_tier(&hw).with_cold_tier(
            Tier::Dram,
            10.0,
            10.0,
            5.0,
            dram_blocks * BLK,
        );
        let pool = PoolHandle::new_chunked(pool_blocks * BLK, BLK);
        TieredLedger::from_topology(pool, &topo, BLK)
    }

    #[test]
    fn pressure_demotes_before_evicting_and_hits_report_cold_fetch() {
        let ledger = dram_ledger(2, 2);
        let idx = PrefixIndex::new();
        let old = chain(1, 2);
        let a = idx.acquire_tiered(&old, BLK, &ledger);
        assert_eq!(a.inserted.len(), 2);
        assert_eq!(a.tiers, vec![Tier::Remote, Tier::Remote]);
        assert!(a.cold_fetch.is_empty());
        idx_release(&a.acquired, ledger.pool());
        // A new 2-block chain needs the whole pool: the cold entries are
        // demoted to DRAM, not evicted — they stay resident.
        let newc = chain(2, 2);
        let b = idx.acquire_tiered(&newc, BLK, &ledger);
        assert_eq!(b.acquired.len(), 2);
        assert_eq!(idx.len(), 4, "demotion keeps entries resident");
        assert_eq!(idx.demoted(), 2);
        let (_, _, evicted) = idx.stats();
        assert_eq!(evicted, 0);
        assert_eq!(idx.cold_resident_bytes(), vec![(Tier::Dram, 2 * BLK)]);
        assert_eq!(ledger.pool().used(), 2 * BLK);
        assert_eq!(ledger.handle(Tier::Dram).unwrap().used(), 2 * BLK);
        assert_eq!(ledger.total_used(), 4 * BLK);
        // Hitting the demoted chain attaches on DRAM and reports the
        // bytes as a cold fetch.
        let c = idx.acquire_tiered(&old, BLK, &ledger);
        assert_eq!(c.hit_blocks, 2);
        assert_eq!(c.tiers, vec![Tier::Dram, Tier::Dram]);
        assert_eq!(c.cold_fetch, vec![(Tier::Dram, 2 * BLK)]);
        assert_eq!(ledger.handle(Tier::Dram).unwrap().shared_refs(old[0]), 2);
        // Rollback releases on the tier actually holding the entry.
        idx.abort_tiered(&c.acquired, &c.inserted, &ledger);
        assert_eq!(ledger.handle(Tier::Dram).unwrap().shared_refs(old[0]), 1);
        assert_eq!(ledger.total_used(), 4 * BLK);
    }

    #[test]
    fn eviction_resumes_when_the_cold_tier_is_full() {
        let ledger = dram_ledger(2, 1);
        let idx = PrefixIndex::new();
        let x = chain(1, 1);
        let y = chain(2, 1);
        idx_release(&idx.acquire_tiered(&x, BLK, &ledger).acquired, ledger.pool());
        idx_release(&idx.acquire_tiered(&y, BLK, &ledger).acquired, ledger.pool());
        // Two fresh blocks: the first displacement demotes LRU `x` into
        // the one-block DRAM tier; the second finds DRAM full and falls
        // back to evicting `y`.
        let z = chain(3, 2);
        let b = idx.acquire_tiered(&z, BLK, &ledger);
        assert_eq!(b.acquired.len(), 2);
        assert_eq!(idx.demoted(), 1);
        let (_, _, evicted) = idx.stats();
        assert_eq!(evicted, 1);
        assert_eq!(idx.len(), 3, "x demoted, y evicted, z resident");
        assert_eq!(idx.cold_resident_bytes(), vec![(Tier::Dram, BLK)]);
        assert_eq!(ledger.pool().used(), 2 * BLK);
        assert_eq!(ledger.handle(Tier::Dram).unwrap().used(), BLK);
    }
}
