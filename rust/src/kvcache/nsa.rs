//! NSA-style sparse attention block selection (§7.3's "DeepSeek-V3 + NSA"
//! inference setting and the §7.4 block-granularity sensitivity).
//!
//! Native Sparse Attention reads only a subset of KV blocks per decode
//! step: a small set of *selected* (top-k) blocks plus a *sliding window*
//! of recent blocks. Under hierarchical memory this determines the per-step
//! transfer volume (which blocks must be device-resident) and the CPU-side
//! sparse-block processing cost — the term that produces the paper's
//! decode-latency regression (Table 5: 0.117 s → 0.146 s) when block
//! granularity grows.

use crate::util::rng::Rng;

/// NSA selection parameters.
#[derive(Debug, Clone)]
pub struct NsaConfig {
    /// Tokens per KV block (the "sparse block granularity" of §7.4).
    pub block_tokens: usize,
    /// Number of top-k selected blocks attended per step.
    pub num_selected: usize,
    /// Sliding window length in tokens (always-attended suffix).
    pub sliding_tokens: usize,
    /// CPU cost per processed block is `cpu_base_us + bytes *
    /// cpu_per_byte_us` — partial KV updates and block gather/scatter run
    /// on the host when blocks are remote (§7.3.3).
    pub cpu_base_us: f64,
    pub cpu_per_byte_us: f64,
}

impl Default for NsaConfig {
    fn default() -> Self {
        Self {
            block_tokens: 64,
            num_selected: 16,
            sliding_tokens: 512,
            cpu_base_us: 3.0,
            cpu_per_byte_us: 4.0e-6,
        }
    }
}

impl NsaConfig {
    /// Paper's "unfavourable" coarse-block setting (§7.3.3 / Table 5):
    /// larger selection/sliding blocks inflate CPU-side processing.
    pub fn coarse(mut self, factor: usize) -> Self {
        self.block_tokens *= factor.max(1);
        self
    }

    /// Blocks needed at `seq_len` tokens: ceil.
    pub fn blocks_for(&self, seq_len: usize) -> usize {
        seq_len.div_ceil(self.block_tokens)
    }

    /// Which block indices a decode step at `seq_len` touches.
    ///
    /// Deterministic given (seq_len, seed): top-k selection is
    /// content-dependent in the real algorithm; we model it as a seeded
    /// uniform draw over the prefix (excluding the sliding suffix), which
    /// preserves the *count* and *spread* that drive transfer volume.
    /// The draw is keyed on the BLOCK count, not the token count: real
    /// top-k selections are temporally stable and shift when the context
    /// grows by a block, not on every token.
    pub fn touched_blocks(&self, seq_len: usize, seed: u64) -> Vec<usize> {
        let total = self.blocks_for(seq_len.max(1));
        let sliding_blocks = self.sliding_tokens.div_ceil(self.block_tokens).min(total);
        let mut touched: Vec<usize> = ((total - sliding_blocks)..total).collect();

        let prefix = total - sliding_blocks;
        let k = self.num_selected.min(prefix);
        if k > 0 {
            let mut rng = Rng::new(seed ^ (total as u64).wrapping_mul(0x9E37));
            let mut pool: Vec<usize> = (0..prefix).collect();
            rng.shuffle(&mut pool);
            let mut sel = pool[..k].to_vec();
            sel.sort_unstable();
            touched.splice(0..0, sel);
        }
        touched.dedup();
        touched
    }

    /// Bytes of one KV block given per-token KV bytes.
    pub fn block_bytes(&self, kv_bytes_per_token: u64) -> u64 {
        self.block_tokens as u64 * kv_bytes_per_token
    }

    /// CPU-side sparse processing cost for one decode step (us): gathering
    /// and partially updating `n_blocks` of the given size on the host.
    pub fn cpu_step_cost_us(&self, n_blocks: usize, block_bytes: u64) -> f64 {
        n_blocks as f64 * (self.cpu_base_us + block_bytes as f64 * self.cpu_per_byte_us)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blocks_for_rounds_up() {
        let c = NsaConfig { block_tokens: 64, ..Default::default() };
        assert_eq!(c.blocks_for(1), 1);
        assert_eq!(c.blocks_for(64), 1);
        assert_eq!(c.blocks_for(65), 2);
        assert_eq!(c.blocks_for(6400), 100);
    }

    #[test]
    fn touched_includes_sliding_suffix() {
        let c = NsaConfig { block_tokens: 64, num_selected: 4, sliding_tokens: 256, ..Default::default() };
        let t = c.touched_blocks(64 * 100, 7);
        // Last 4 blocks (256/64) must be present.
        for b in 96..100 {
            assert!(t.contains(&b), "missing sliding block {b}");
        }
        // 4 selected + 4 sliding.
        assert_eq!(t.len(), 8);
    }

    #[test]
    fn touched_deterministic_per_seed() {
        let c = NsaConfig::default();
        assert_eq!(c.touched_blocks(10_000, 42), c.touched_blocks(10_000, 42));
        // Different seed, (almost surely) different selection.
        assert_ne!(c.touched_blocks(100_000, 1), c.touched_blocks(100_000, 2));
    }

    #[test]
    fn short_sequences_touch_everything_available() {
        let c = NsaConfig { block_tokens: 64, num_selected: 16, sliding_tokens: 512, ..Default::default() };
        // 300 tokens -> 5 blocks, all inside the sliding window.
        let t = c.touched_blocks(300, 3);
        assert_eq!(t, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn coarse_blocks_scale_cpu_cost() {
        let fine = NsaConfig::default();
        let coarse = NsaConfig::default().coarse(4);
        let kv_per_tok = 228 * 1024u64; // realistic per-token KV mass
        let fine_cost = fine.cpu_step_cost_us(8, fine.block_bytes(kv_per_tok));
        let coarse_cost = coarse.cpu_step_cost_us(8, coarse.block_bytes(kv_per_tok));
        assert!(coarse_cost > fine_cost * 1.5, "{coarse_cost} vs {fine_cost}");
    }

    #[test]
    fn touched_blocks_sorted_unique() {
        let c = NsaConfig::default();
        for seq in [1000usize, 5000, 20_000] {
            let t = c.touched_blocks(seq, 9);
            let mut s = t.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(t, s, "seq {seq}");
        }
    }
}
