//! Block-based KV-cache manager (§5.2).
//!
//! KV state is held in fixed-size blocks (paged, vLLM-style — the same
//! granularity the L1 Pallas kernel tiles attention over). Residency policy
//! decides where blocks live:
//!
//! * [`KvPolicy::AllDevice`] — the paper's inference baseline: every block
//!   in HBM, allocated through the fragmenting [`DeviceAllocator`], so long
//!   sequences near capacity trigger defragmentation (Table 4).
//! * [`KvPolicy::FullOffload`] — the hierarchical-memory configuration:
//!   blocks live in the remote pool; the decode scheduler prefetches the
//!   NSA-touched working set ahead of each step, and the graph-driven
//!   schedule hides the transfers behind the step's other compute.
//!
//! With a [`TieredLedger`] carrying cold tiers (DRAM/CXL/SSD below the
//! pool), shared prefix blocks can be *demoted* below the pool under
//! pressure instead of evicted; a block's [`BlockHome`] records which
//! tier holds its reservation and reads from cold homes are reported per
//! tier in [`StepCost::cold_fetch`] so the step graph lowers them as
//! cold-tier prefetches. The degenerate single-tier ledger reproduces the
//! pool-only manager bit-for-bit.

use std::collections::HashMap;
use std::fmt;

use anyhow::Result;

use crate::graph::Tier;
use crate::memory::{DeviceAllocator, PoolHandle, SharedAcquire, TieredLedger};
use crate::sim::HwConfig;

use super::nsa::NsaConfig;
use super::prefix::{AcquireResult, PrefixIndex};

/// Where KV blocks reside.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvPolicy {
    /// Baseline: all KV blocks in device HBM.
    AllDevice,
    /// Hierarchical memory: KV home is the remote pool; a bounded device
    /// working set holds the blocks the current step touches.
    FullOffload,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum BlockHome {
    Device(crate::memory::AllocId),
    Remote,
    /// Block shared through the prefix index; `hash` is its chain hash and
    /// `tier` the level whose ledger holds the reservation (the pool for
    /// fresh entries; a cold tier after demotion). The sequence holds one
    /// reference in that tier's shared ledger; the index holds another, so
    /// retiring the sequence leaves the block cached for future
    /// admissions.
    Shared { hash: u64, tier: Tier },
    /// Pool-resident block shared copy-on-write between forked sequences
    /// (manager-local refcount; one pool reservation backs all holders).
    /// Writing it forks a private copy.
    Cow(u64),
    /// Private block homed in `lender`'s spare HBM under a
    /// [`LeaseLedger`] lease: fetched over the device↔device peer edge
    /// instead of the pool link. Revocation rehomes it to `Remote`
    /// (never drops it); only private blocks borrow — shared prefix
    /// entries stay in the refcounted pool/cold ledgers.
    Peer { lender: u16 },
}

/// Structured failure modes of the KV-cache manager, carried through the
/// `anyhow` error chain (callers can `downcast_ref::<KvError>()` instead
/// of string-matching the message).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvError {
    /// Admission (or fork) targeted a sequence id that is already live.
    AlreadyAdmitted { seq: u64 },
    /// The sequence id is not (or no longer) managed here.
    UnknownSequence { seq: u64 },
    /// The remote pool could not hold `bytes` more, even after demoting /
    /// evicting cold prefix entries.
    PoolExhausted { bytes: u64, what: &'static str },
    /// Fork walked into a device-resident block (only pool-homed
    /// sequences fork).
    DeviceResidentFork { seq: u64 },
    /// A block referenced a copy-on-write entry that is not in the table
    /// — refcount corruption, not a recoverable condition for the block.
    CorruptCow { id: u64 },
    /// The operation is not defined under the manager's residency policy.
    PolicyMismatch { op: &'static str },
}

impl fmt::Display for KvError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            KvError::AlreadyAdmitted { seq } => write!(f, "sequence {seq} already admitted"),
            KvError::UnknownSequence { seq } => write!(f, "unknown sequence {seq}"),
            KvError::PoolExhausted { bytes, what } => {
                write!(f, "remote pool exhausted: {bytes} B for {what}")
            }
            KvError::DeviceResidentFork { seq } => {
                write!(f, "cannot fork device-resident blocks of sequence {seq}")
            }
            KvError::CorruptCow { id } => write!(f, "copy-on-write entry {id} is not live"),
            KvError::PolicyMismatch { op } => {
                write!(f, "{op} requires the FullOffload policy")
            }
        }
    }
}

impl std::error::Error for KvError {}

/// Refcount for one copy-on-write block (the reservation itself lives in
/// the pool ledger and is counted in `remote_kv_bytes` exactly once).
#[derive(Debug)]
struct CowBlock {
    refs: u64,
}

#[derive(Debug)]
struct Sequence {
    tokens: usize,
    blocks: Vec<BlockHome>,
    /// Baseline (AllDevice): the prompt KV is one contiguous variable-size
    /// allocation — the non-paged layout of the paper's MindSpore baseline
    /// and the reason long-sequence churn fragments HBM (§7.3.2).
    prompt_alloc: Option<crate::memory::AllocId>,
    /// Blocks of KV capacity already backed (prompt region + growth).
    capacity_blocks: usize,
    /// Blocks currently device-resident in the offload working set (the
    /// previous step's touched set). Only the delta transfers each step.
    cached: Vec<usize>,
}

/// Per-step accounting returned by [`KvCacheManager::decode_step`].
#[derive(Debug, Clone, Default)]
pub struct StepCost {
    /// Bytes moved Remote→Device for this step (prefetch volume).
    pub r2d_bytes: u64,
    /// Bytes written back Device→Remote (new token K/V persisted).
    pub d2r_bytes: u64,
    /// Bytes fetched from *below* the pool (demoted blocks the step
    /// touches), summed per cold tier. Empty on untiered setups.
    pub cold_fetch: Vec<(Tier, u64)>,
    /// Bytes fetched from borrowed peer-HBM homes, per lender replica —
    /// they ride the device↔device edge, not the pool link. Empty
    /// without an active lease.
    pub peer_fetch: Vec<(u16, u64)>,
    /// Bytes written back to borrowed peer-HBM homes, per lender replica.
    pub peer_store: Vec<(u16, u64)>,
    /// Host-side sparse block processing time (us).
    pub cpu_us: f64,
    /// Device-allocator defragmentation stall (us).
    pub defrag_us: f64,
    /// Defrag events triggered by this step.
    pub defrag_events: u64,
}

/// Fixed framework cost of one compaction pass (us). Calibrated from the
/// paper's §7.3.2: ~30 s of prefill degradation across 57 events.
pub const DEFRAG_FIXED_US: f64 = 1_000_000.0;

/// Result of a prefix-aware admission ([`KvCacheManager::admit_prefix`]).
#[derive(Debug, Clone, Default)]
pub struct PrefixAdmit {
    /// Transfer/stall cost of materialising the sequence.
    pub cost: StepCost,
    /// Prompt blocks served from the shared prefix cache (not recomputed).
    pub hit_blocks: usize,
    /// Prompt tokens those blocks cover (prefill skips computing them).
    pub hit_tokens: usize,
    /// Pool bytes this admission deduplicated: attached to resident shared
    /// blocks instead of reserving new capacity.
    pub deduped_bytes: u64,
    /// Shared-prefix bytes the device must fetch pool→device before the
    /// suffix prefill can attend over them. 0 when the whole prompt hit —
    /// then decode's working-set prefetches pull blocks on demand instead.
    pub prefix_fetch_bytes: u64,
    /// Shared-prefix bytes resident *below* the pool (demoted blocks),
    /// per cold tier — fetched over the deep fabric path instead of the
    /// pool link. Disjoint from
    /// [`prefix_fetch_bytes`](Self::prefix_fetch_bytes).
    pub cold_fetch: Vec<(Tier, u64)>,
}

/// The KV-cache manager for one device.
pub struct KvCacheManager {
    pub policy: KvPolicy,
    pub nsa: NsaConfig,
    /// KV bytes per token across all layers (k+v).
    pub kv_bytes_per_token: u64,
    pub allocator: DeviceAllocator,
    /// Device working set for offloaded blocks (bytes), bounding residency.
    pub working_set_bytes: u64,
    /// The memory stack below the device: the remote-pool capacity ledger
    /// (tier 0 — a private handle for a lone device; a clone of the
    /// node-wide handle when several engines share one SuperNode pool,
    /// where every `FullOffload` block competes with sibling devices for
    /// capacity) plus any cold DRAM/CXL/SSD ledgers beneath it that
    /// prefix entries demote into under pressure.
    ledger: TieredLedger,
    /// Opt-in pressure valve: when the pool (and its cold tiers) cannot
    /// hold a growth block, place it in device HBM instead of failing the
    /// step. Off by default — the untiered manager fails loudly, which is
    /// what the capacity tests pin.
    device_spill: bool,
    /// Prefix index consulted by [`admit_prefix`](Self::admit_prefix);
    /// cluster-wide when the handle is shared across managers.
    index: Option<PrefixIndex>,
    /// Peer-HBM lease broker (cluster-wide when shared) and this
    /// manager's replica id in it. `None` disables harvesting: every
    /// placement decision is bit-identical to the pool-only manager.
    lease: Option<crate::memory::LeaseLedger>,
    replica: u16,
    /// Borrower-side gate: the engine closes it when the tail budget
    /// has no headroom for revocation risk (the SLO veto).
    peer_enabled: bool,
    /// Bytes this manager currently holds in borrowed peer HBM.
    pub peer_kv_bytes: u64,
    /// Copy-on-write blocks shared between forked sequences.
    cow: HashMap<u64, CowBlock>,
    next_cow: u64,
    /// CoW blocks forked into private copies on divergence (writes).
    pub cow_forks: u64,
    seqs: HashMap<u64, Sequence>,
    /// Remote-pool bytes *privately* reserved by this device's KV (shared
    /// prefix blocks are accounted once, in the pool's shared ledger).
    pub remote_kv_bytes: u64,
    /// Peak device bytes used by KV (blocks + working set).
    pub peak_device_kv: u64,
    working_set_used: u64,
}

impl KvCacheManager {
    pub fn new(
        policy: KvPolicy,
        nsa: NsaConfig,
        kv_bytes_per_token: u64,
        device_kv_budget: u64,
    ) -> Self {
        Self::with_pool(policy, nsa, kv_bytes_per_token, device_kv_budget, PoolHandle::unbounded())
    }

    /// A manager whose offloaded blocks reserve capacity from `pool`
    /// (shared across devices when the handle is cloned).
    ///
    /// All of this manager's pool traffic is block-granular — admissions
    /// reserve whole blocks, growth reserves one block, retirement
    /// releases blocks — so a pool whose chunk size is the KV block
    /// ([`PoolHandle::new_chunked`], the cluster's setup) accounts it
    /// without any rounding.
    pub fn with_pool(
        policy: KvPolicy,
        nsa: NsaConfig,
        kv_bytes_per_token: u64,
        device_kv_budget: u64,
        pool: PoolHandle,
    ) -> Self {
        Self::with_pool_and_index(policy, nsa, kv_bytes_per_token, device_kv_budget, pool, None)
    }

    /// A manager that additionally consults `index` on admission
    /// ([`Self::admit_prefix`]): prompt blocks whose chain hashes are
    /// resident attach to the existing pool reservation instead of being
    /// recomputed. Share the index handle across managers (the cluster
    /// setup) and a prefix prefilled by one device is a pool hit for all.
    pub fn with_pool_and_index(
        policy: KvPolicy,
        nsa: NsaConfig,
        kv_bytes_per_token: u64,
        device_kv_budget: u64,
        pool: PoolHandle,
        index: Option<PrefixIndex>,
    ) -> Self {
        Self::with_ledger(
            policy,
            nsa,
            kv_bytes_per_token,
            device_kv_budget,
            TieredLedger::single(pool),
            index,
        )
    }

    /// A manager backed by a full tier stack: offloaded blocks reserve
    /// from the ledger's pool tier, and under pressure cold prefix
    /// entries demote into the ledger's deeper tiers instead of being
    /// evicted. `TieredLedger::single(pool)` reproduces
    /// [`with_pool_and_index`](Self::with_pool_and_index) exactly.
    pub fn with_ledger(
        policy: KvPolicy,
        nsa: NsaConfig,
        kv_bytes_per_token: u64,
        device_kv_budget: u64,
        ledger: TieredLedger,
        index: Option<PrefixIndex>,
    ) -> Self {
        debug_assert!(
            ledger.pool().chunk_bytes() <= 1
                || nsa.block_bytes(kv_bytes_per_token) % ledger.pool().chunk_bytes() == 0,
            "KV block size must be a multiple of the pool's chunk granularity"
        );
        Self {
            policy,
            nsa,
            kv_bytes_per_token,
            allocator: DeviceAllocator::new(device_kv_budget),
            working_set_bytes: device_kv_budget / 8,
            ledger,
            device_spill: false,
            index,
            lease: None,
            replica: 0,
            peer_enabled: true,
            peer_kv_bytes: 0,
            cow: HashMap::new(),
            next_cow: 1,
            cow_forks: 0,
            seqs: HashMap::new(),
            remote_kv_bytes: 0,
            peak_device_kv: 0,
            working_set_used: 0,
        }
    }

    /// Enable the device-spill pressure valve (see the `device_spill`
    /// field): growth blocks that fit nowhere in the pool stack land in
    /// HBM instead of failing the step.
    pub fn with_device_spill(mut self) -> Self {
        self.device_spill = true;
        self
    }

    /// Attach this manager (replica `replica`) to a peer-HBM lease
    /// broker: *private* block placements prefer borrowed peer HBM over
    /// the pool whenever the ledger has an open lender, and
    /// [`revoke_peer`](Self::revoke_peer) rehomes borrowed blocks when a
    /// lender reclaims. Never set → bit-identical pool-only behaviour.
    pub fn set_peer_lease(&mut self, lease: crate::memory::LeaseLedger, replica: u16) {
        self.lease = Some(lease);
        self.replica = replica;
    }

    /// Borrower-side SLO veto: while disabled, no *new* borrows happen
    /// (existing leases stay until retired or revoked).
    pub fn set_peer_enabled(&mut self, on: bool) {
        self.peer_enabled = on;
    }

    /// Try to borrow `bytes` of peer HBM for a private placement.
    fn try_borrow_peer(&self, bytes: u64) -> Option<u16> {
        if !self.peer_enabled {
            return None;
        }
        self.lease.as_ref()?.try_borrow(self.replica, bytes)
    }

    /// The remote pool this manager reserves offloaded KV from.
    pub fn pool(&self) -> &PoolHandle {
        self.ledger.pool()
    }

    /// The full tier stack below the device.
    pub fn ledger(&self) -> &TieredLedger {
        &self.ledger
    }

    /// The prefix index consulted on admission, if configured.
    pub fn prefix_index(&self) -> Option<&PrefixIndex> {
        self.index.as_ref()
    }

    /// Device KV bytes still allocatable (baseline headroom signal for
    /// online routing).
    pub fn device_headroom_bytes(&self) -> u64 {
        self.allocator.capacity().saturating_sub(self.allocator.used())
    }

    /// Conservative admission check used when re-admitting preempted
    /// sequences: the sequence footprint plus one growth block must fit
    /// (a vLLM-style watermark that avoids admit-then-preempt thrash on
    /// an exactly-full device).
    pub fn can_admit_tokens(&self, tokens: usize) -> bool {
        let blocks = self.nsa.blocks_for(tokens.max(1)) as u64 + 1;
        let bytes = blocks * self.block_bytes();
        match self.policy {
            KvPolicy::AllDevice => self.allocator.free_total() >= bytes,
            KvPolicy::FullOffload => {
                let pool = self.ledger.pool();
                pool.capacity().saturating_sub(pool.used()) >= bytes
            }
        }
    }

    pub fn block_bytes(&self) -> u64 {
        self.nsa.block_bytes(self.kv_bytes_per_token)
    }

    /// Admit a sequence after prefill: allocate blocks for `prompt_tokens`.
    /// Returns the step cost of materialising them (alloc stalls, transfer
    /// volume for offloaded prefill writeback). Equivalent to
    /// [`admit_prefix`](Self::admit_prefix) with no hashes (the cold path).
    pub fn admit(&mut self, seq_id: u64, prompt_tokens: usize, hw: &HwConfig) -> Result<StepCost> {
        self.admit_prefix(seq_id, prompt_tokens, &[], hw).map(|a| a.cost)
    }

    /// Admit a sequence whose leading full blocks carry chain hashes
    /// (`block_hashes[i]` commits to blocks `0..=i` of the prompt),
    /// consulting the prefix index: resident blocks attach to the shared
    /// pool reservation and are *not* recomputed by prefill; cold hashed
    /// blocks are inserted so the next request sharing the prefix hits;
    /// the unhashed suffix is privately reserved as before.
    pub fn admit_prefix(
        &mut self,
        seq_id: u64,
        prompt_tokens: usize,
        block_hashes: &[u64],
        hw: &HwConfig,
    ) -> Result<PrefixAdmit> {
        if self.seqs.contains_key(&seq_id) {
            return Err(KvError::AlreadyAdmitted { seq: seq_id }.into());
        }
        let nblocks = self.nsa.blocks_for(prompt_tokens.max(1));
        let block_bytes = self.block_bytes();
        let mut admit = PrefixAdmit::default();
        let mut blocks = Vec::with_capacity(nblocks);
        let mut prompt_alloc = None;
        match self.policy {
            KvPolicy::AllDevice => {
                // Sharing needs the pool tier; the device baseline ignores
                // hashes and allocates one contiguous variable-size region
                // for the prompt KV.
                let bytes = nblocks as u64 * block_bytes;
                let before = self.allocator.defrag_events;
                let (id, moved) = self.allocator.alloc(bytes)?;
                if moved > 0 {
                    admit.cost.defrag_us +=
                        2.0 * moved as f64 / (hw.hbm_gbps * 1e9) * 1e6 + DEFRAG_FIXED_US;
                }
                admit.cost.defrag_events += self.allocator.defrag_events - before;
                prompt_alloc = Some(id);
            }
            KvPolicy::FullOffload => {
                // Only *full* blocks can be shared: a partial tail block's
                // hash would cover tokens that are not there.
                let full_blocks = prompt_tokens / self.nsa.block_tokens;
                let usable = block_hashes.len().min(full_blocks);
                let acq = match (&self.index, usable) {
                    (Some(idx), 1..) => {
                        idx.acquire_tiered(&block_hashes[..usable], block_bytes, &self.ledger)
                    }
                    _ => AcquireResult::default(),
                };
                let shared_n = acq.acquired.len();
                let private = (nblocks - shared_n) as u64 * block_bytes;
                // The private suffix prefers borrowed peer HBM: faster
                // than the pool link and it sheds pool pressure. All or
                // nothing from one lender — a partial lease would
                // scatter one sequence's suffix across homes.
                let peer_lender = if private > 0 { self.try_borrow_peer(private) } else { None };
                match peer_lender {
                    Some(_) => self.peer_kv_bytes += private,
                    None => {
                        // Reserve the suffix atomically, so a mid-admit
                        // failure leaks nothing (the acquired prefix
                        // unwinds via abort).
                        if private > 0 && !self.try_reserve_evicting(private) {
                            if let Some(idx) = &self.index {
                                idx.abort_tiered(&acq.acquired, &acq.inserted, &self.ledger);
                            }
                            return Err(KvError::PoolExhausted {
                                bytes: private,
                                what: "prefill blocks",
                            }
                            .into());
                        }
                        self.remote_kv_bytes += private;
                    }
                }
                for (i, &h) in acq.acquired.iter().enumerate() {
                    let tier = acq.tiers.get(i).copied().unwrap_or(Tier::Remote);
                    blocks.push(BlockHome::Shared { hash: h, tier });
                }
                blocks.resize(
                    nblocks,
                    match peer_lender {
                        Some(lender) => BlockHome::Peer { lender },
                        None => BlockHome::Remote,
                    },
                );
                // Hit blocks are not recomputed; everything else — cold
                // shared blocks included, this prefill produces them —
                // streams back to its home as it is written: shared
                // blocks to the pool, a peer-homed suffix over the
                // device↔device edge.
                admit.hit_blocks = acq.hit_blocks;
                admit.hit_tokens = acq.hit_blocks * self.nsa.block_tokens;
                admit.deduped_bytes = acq.deduped_bytes;
                let computed = (nblocks - acq.hit_blocks) as u64 * block_bytes;
                match peer_lender {
                    Some(lender) => {
                        admit.cost.peer_store.push((lender, private));
                        admit.cost.d2r_bytes += computed.saturating_sub(private);
                    }
                    None => admit.cost.d2r_bytes += computed,
                }
                if admit.hit_tokens < prompt_tokens && acq.hit_blocks > 0 {
                    // The suffix prefill attends over the shared prefix,
                    // so the hit blocks transfer to the device first —
                    // pool-resident ones over the pool link, demoted ones
                    // over their cold tier's deeper path.
                    let cold_bytes: u64 = acq.cold_fetch.iter().map(|&(_, b)| b).sum();
                    admit.prefix_fetch_bytes =
                        (acq.hit_blocks as u64 * block_bytes).saturating_sub(cold_bytes);
                    admit.cold_fetch = acq.cold_fetch.clone();
                }
            }
        }
        self.seqs.insert(
            seq_id,
            Sequence {
                tokens: prompt_tokens,
                blocks,
                prompt_alloc,
                capacity_blocks: nblocks,
                cached: Vec::new(),
            },
        );
        self.note_peak();
        Ok(admit)
    }

    /// Fork `child` from `parent` (multi-turn divergence): the child
    /// shares every parent block copy-on-write. Shared-prefix blocks gain
    /// a pool reference; private blocks convert to refcounted CoW entries
    /// backed by the parent's single reservation (no new pool bytes).
    /// Writing a CoW tail later forks a private copy
    /// ([`Self::decode_step`]). `FullOffload` only.
    pub fn fork(&mut self, parent: u64, child: u64) -> Result<()> {
        if self.policy != KvPolicy::FullOffload {
            return Err(KvError::PolicyMismatch { op: "fork" }.into());
        }
        if self.seqs.contains_key(&child) {
            return Err(KvError::AlreadyAdmitted { seq: child }.into());
        }
        let block_bytes = self.block_bytes();
        let (tokens, capacity_blocks, parent_blocks) = {
            let Some(p) = self.seqs.get(&parent) else {
                return Err(KvError::UnknownSequence { seq: parent }.into());
            };
            (p.tokens, p.capacity_blocks, p.blocks.clone())
        };
        // Validate the whole walk up front so the conversions below are
        // infallible (attach / refcount only, no new capacity) and cannot
        // fail half-way with some parent blocks already converted.
        for b in &parent_blocks {
            match *b {
                // Peer homes are device-class memory (a sibling's HBM):
                // like spilled device blocks they cannot back a CoW
                // share, whose reservation lives in the pool ledger.
                BlockHome::Device(_) | BlockHome::Peer { .. } => {
                    return Err(KvError::DeviceResidentFork { seq: parent }.into());
                }
                BlockHome::Cow(id) if !self.cow.contains_key(&id) => {
                    return Err(KvError::CorruptCow { id }.into());
                }
                _ => {}
            }
        }
        let mut blocks = Vec::with_capacity(parent_blocks.len());
        for (i, b) in parent_blocks.iter().enumerate() {
            match *b {
                BlockHome::Shared { hash, tier } => {
                    let handle = self.ledger.handle(tier).unwrap_or(self.ledger.pool());
                    let r = handle.shared_acquire(hash, block_bytes);
                    debug_assert_eq!(r, SharedAcquire::Attached);
                    blocks.push(BlockHome::Shared { hash, tier });
                }
                BlockHome::Remote => {
                    let id = self.next_cow;
                    self.next_cow += 1;
                    self.cow.insert(id, CowBlock { refs: 2 });
                    self.seqs.get_mut(&parent).unwrap().blocks[i] = BlockHome::Cow(id);
                    blocks.push(BlockHome::Cow(id));
                }
                BlockHome::Cow(id) => {
                    // Presence pre-validated above.
                    self.cow.get_mut(&id).expect("validated above").refs += 1;
                    blocks.push(BlockHome::Cow(id));
                }
                BlockHome::Device(_) | BlockHome::Peer { .. } => {
                    return Err(KvError::DeviceResidentFork { seq: parent }.into());
                }
            }
        }
        self.seqs.insert(
            child,
            Sequence { tokens, blocks, prompt_alloc: None, capacity_blocks, cached: Vec::new() },
        );
        self.note_peak();
        Ok(())
    }

    /// One decode step for `seq_id`: appends a token, prefetches the NSA
    /// working set (offload policy), charges CPU sparse processing.
    pub fn decode_step(&mut self, seq_id: u64, hw: &HwConfig) -> Result<StepCost> {
        let block_bytes = self.block_bytes();
        let policy = self.policy;
        let nsa = self.nsa.clone();
        let seq = match self.seqs.get_mut(&seq_id) {
            Some(s) => s,
            None => return Err(KvError::UnknownSequence { seq: seq_id }.into()),
        };
        seq.tokens += 1;
        let tokens = seq.tokens;
        let need_new_block = nsa.blocks_for(tokens) > seq.capacity_blocks;

        let mut cost = StepCost::default();
        if need_new_block {
            let b = self.place_block(&mut cost, hw)?;
            let seq = self.seqs.get_mut(&seq_id).unwrap();
            seq.blocks.push(b);
            seq.capacity_blocks += 1;
        }

        match policy {
            KvPolicy::AllDevice => {
                // Everything resident: no transfers, no host gather.
            }
            KvPolicy::FullOffload => {
                let touched = nsa.touched_blocks(tokens, seq_id);
                // Only the delta vs the resident working set transfers:
                // sliding-window blocks stay cached across steps, selection
                // churn brings in new blocks (graph-scheduled prefetches).
                // Blocks whose home is below the pool arrive over their
                // cold tier's path and are reported separately.
                let seq = self.seqs.get_mut(&seq_id).unwrap();
                let mut new_blocks = 0u64;
                for &bi in touched.iter().filter(|b| !seq.cached.contains(b)) {
                    match seq.blocks.get(bi) {
                        Some(&BlockHome::Shared { tier, .. }) if tier.is_cold() => {
                            match cost.cold_fetch.iter_mut().find(|(t, _)| *t == tier) {
                                Some(e) => e.1 += block_bytes,
                                None => cost.cold_fetch.push((tier, block_bytes)),
                            }
                        }
                        Some(&BlockHome::Peer { lender }) => {
                            // Borrowed blocks arrive over the peer edge,
                            // not the pool link.
                            match cost.peer_fetch.iter_mut().find(|(r, _)| *r == lender) {
                                Some(e) => e.1 += block_bytes,
                                None => cost.peer_fetch.push((lender, block_bytes)),
                            }
                        }
                        _ => new_blocks += 1,
                    }
                }
                seq.cached = touched.clone();
                let tail = *seq.blocks.last().expect("offloaded sequences always have blocks");
                cost.r2d_bytes += new_blocks * block_bytes;
                // Persist the updated tail block — copy-on-write: a tail
                // still shared with a forked sibling forks a private copy
                // before the write lands.
                let mut tail_writeback = true;
                let mut tail_peer = None;
                match tail {
                    BlockHome::Cow(id) => {
                        let refs = match self.cow.get(&id) {
                            Some(e) => e.refs,
                            None => return Err(KvError::CorruptCow { id }.into()),
                        };
                        if refs > 1 {
                            if !self.try_reserve_evicting(block_bytes) {
                                return Err(KvError::PoolExhausted {
                                    bytes: block_bytes,
                                    what: "a CoW fork",
                                }
                                .into());
                            }
                            self.cow.get_mut(&id).unwrap().refs -= 1;
                            self.remote_kv_bytes += block_bytes;
                            self.cow_forks += 1;
                        } else {
                            // Last holder: collapse in place, the entry's
                            // reservation simply becomes private again.
                            self.cow.remove(&id);
                        }
                        *self.seqs.get_mut(&seq_id).unwrap().blocks.last_mut().unwrap() =
                            BlockHome::Remote;
                    }
                    BlockHome::Remote => {}
                    // A borrowed tail persists over the peer edge.
                    BlockHome::Peer { lender } => tail_peer = Some(lender),
                    // A spilled growth block decodes in place: the write
                    // lands in HBM, nothing transfers back to the pool.
                    BlockHome::Device(_) if self.device_spill => tail_writeback = false,
                    // A shared (immutable, full) block is never the tail of
                    // a decoding sequence: admission leaves the partial
                    // suffix private, and a fully-shared prompt grows a
                    // private block on its first decode step.
                    BlockHome::Shared { .. } | BlockHome::Device(_) => {
                        debug_assert!(false, "decode tail must be private");
                    }
                }
                if tail_writeback {
                    match tail_peer {
                        Some(lender) => {
                            match cost.peer_store.iter_mut().find(|(r, _)| *r == lender) {
                                Some(e) => e.1 += block_bytes,
                                None => cost.peer_store.push((lender, block_bytes)),
                            }
                        }
                        None => cost.d2r_bytes += block_bytes,
                    }
                }
                // Host-side sparse processing over every touched block
                // (partial KV updates, gather/scatter) — the term that
                // makes Table 5's decode latency grow with granularity.
                cost.cpu_us += nsa.cpu_step_cost_us(touched.len(), block_bytes);
                self.working_set_used =
                    (touched.len() as u64 * block_bytes).min(self.working_set_bytes);
            }
        }
        self.note_peak();
        Ok(cost)
    }

    /// Retire a finished (or preempted) sequence, freeing its blocks.
    ///
    /// Only *private* bytes return to the pool: a shared-prefix block just
    /// drops this sequence's reference — the index's own reference keeps
    /// it cached for future admissions — and a CoW block frees only when
    /// its last holder goes. This is what makes preemption/requeue safe on
    /// shared prefixes: the preempted sequence cannot double-free a block
    /// a sibling still reads, and its re-admission goes back through the
    /// index instead of re-prefilling.
    pub fn retire(&mut self, seq_id: u64) -> Result<()> {
        let Some(seq) = self.seqs.remove(&seq_id) else {
            return Err(KvError::UnknownSequence { seq: seq_id }.into());
        };
        if let Some(a) = seq.prompt_alloc {
            self.allocator.free(a)?;
        }
        for b in seq.blocks {
            match b {
                BlockHome::Device(a) => self.allocator.free(a)?,
                BlockHome::Remote => {
                    self.ledger.pool().release(self.block_bytes());
                    self.remote_kv_bytes -= self.block_bytes();
                }
                BlockHome::Shared { hash, tier } => {
                    // Drop this sequence's reference on whichever tier
                    // holds the block now.
                    let handle = self.ledger.handle(tier).unwrap_or(self.ledger.pool());
                    handle.shared_release(hash);
                }
                BlockHome::Cow(id) => {
                    let e = match self.cow.get_mut(&id) {
                        Some(e) => e,
                        None => return Err(KvError::CorruptCow { id }.into()),
                    };
                    e.refs -= 1;
                    if e.refs == 0 {
                        self.cow.remove(&id);
                        self.ledger.pool().release(self.block_bytes());
                        self.remote_kv_bytes -= self.block_bytes();
                    }
                }
                BlockHome::Peer { lender } => {
                    // Return the borrowed bytes to the lender's ledger —
                    // a retire/preempt ends the lease without touching
                    // the pool.
                    if let Some(lease) = &self.lease {
                        lease.release(lender, self.block_bytes());
                    }
                    self.peer_kv_bytes = self.peer_kv_bytes.saturating_sub(self.block_bytes());
                }
            }
        }
        if self.seqs.is_empty() {
            self.working_set_used = 0;
        }
        Ok(())
    }

    /// Total KV bytes currently on device (blocks + offload working set).
    pub fn device_kv_bytes(&self) -> u64 {
        self.allocator.used() + self.working_set_used
    }

    /// Total tokens currently cached for `seq_id`.
    pub fn seq_tokens(&self, seq_id: u64) -> Option<usize> {
        self.seqs.get(&seq_id).map(|s| s.tokens)
    }

    /// Can the manager hold a sequence of `tokens` under the current
    /// policy? (The Table 3 max-sequence-length question.)
    pub fn max_tokens_supported(&self, non_kv_reserved: u64, device_total: u64) -> u64 {
        let kv_budget = match self.policy {
            KvPolicy::AllDevice => device_total.saturating_sub(non_kv_reserved),
            KvPolicy::FullOffload => {
                // KV lives in the pool; device only needs the working set.
                return u64::MAX; // bounded by pool, not device
            }
        };
        kv_budget / self.kv_bytes_per_token
    }

    fn place_block(&mut self, cost: &mut StepCost, hw: &HwConfig) -> Result<BlockHome> {
        match self.policy {
            KvPolicy::AllDevice => {
                let before = self.allocator.defrag_events;
                let (id, moved) = self.allocator.alloc(self.block_bytes())?;
                if moved > 0 {
                    // Byte movement at HBM bandwidth + the framework-level
                    // fixed cost of a compaction pass (synchronise, rebuild
                    // tables — the dominant term the paper measures:
                    // ~30 s of prefill across 57 events, §7.3.2).
                    cost.defrag_us += 2.0 * moved as f64 / (hw.hbm_gbps * 1e9) * 1e6
                        + DEFRAG_FIXED_US;
                }
                cost.defrag_events += self.allocator.defrag_events - before;
                Ok(BlockHome::Device(id))
            }
            KvPolicy::FullOffload => {
                let bytes = self.block_bytes();
                // Growth blocks prefer borrowed peer HBM for the same
                // reason admission suffixes do: the peer edge beats the
                // pool link and borrowing sheds pool pressure.
                if let Some(lender) = self.try_borrow_peer(bytes) {
                    self.peer_kv_bytes += bytes;
                    return Ok(BlockHome::Peer { lender });
                }
                if !self.try_reserve_evicting(bytes) {
                    if self.device_spill {
                        // Pressure valve: the growth block lands in HBM.
                        // This raises peak device KV — exactly the cost
                        // the tier-hierarchy bench compares against
                        // demoting cold prefixes below the pool instead.
                        let before = self.allocator.defrag_events;
                        let (id, moved) = self.allocator.alloc(bytes)?;
                        if moved > 0 {
                            cost.defrag_us += 2.0 * moved as f64 / (hw.hbm_gbps * 1e9) * 1e6
                                + DEFRAG_FIXED_US;
                        }
                        cost.defrag_events += self.allocator.defrag_events - before;
                        return Ok(BlockHome::Device(id));
                    }
                    return Err(KvError::PoolExhausted { bytes, what: "one KV block" }.into());
                }
                self.remote_kv_bytes += bytes;
                Ok(BlockHome::Remote)
            }
        }
    }

    /// Reserve private pool bytes, relieving pressure through the prefix
    /// index once: cold entries demote below the pool when the ledger has
    /// cold tiers, and are evicted when it does not (live shared blocks
    /// are refcount-protected and never move from under a reader).
    fn try_reserve_evicting(&self, bytes: u64) -> bool {
        if self.ledger.pool().try_reserve(bytes) {
            return true;
        }
        let Some(idx) = &self.index else { return false };
        idx.evict_tiered(&self.ledger, bytes);
        self.ledger.pool().try_reserve(bytes)
    }

    fn note_peak(&mut self) {
        self.peak_device_kv = self.peak_device_kv.max(self.device_kv_bytes());
    }

    /// Lender `lender` revoked its lease: rehome every block this
    /// manager borrowed from it into the pool. Each block moves exactly
    /// once — [`LeaseLedger::demote`] reserves the pool destination
    /// first, so a full pool leaves the block parked at the peer (still
    /// on lease) for a later sweep instead of dropping it. Returns the
    /// bytes demoted (the Peer→Remote transfer volume the caller must
    /// charge to the fabric).
    pub fn revoke_peer(&mut self, lender: u16) -> u64 {
        let Some(lease) = self.lease.clone() else { return 0 };
        let block_bytes = self.block_bytes();
        let targets: Vec<(u64, usize)> = self
            .seqs
            .iter()
            .flat_map(|(&id, s)| {
                s.blocks.iter().enumerate().filter_map(move |(i, b)| {
                    matches!(*b, BlockHome::Peer { lender: l } if l == lender)
                        .then_some((id, i))
                })
            })
            .collect();
        let mut moved = 0u64;
        for (id, i) in targets {
            if !lease.demote(lender, block_bytes, self.ledger.pool()) {
                // Pool full: relieve pressure through the prefix index
                // once and retry; a second failure leaves the copy at
                // the peer — conservation over promptness.
                if let Some(idx) = &self.index {
                    idx.evict_tiered(&self.ledger, block_bytes);
                }
                if !lease.demote(lender, block_bytes, self.ledger.pool()) {
                    continue;
                }
            }
            self.seqs.get_mut(&id).unwrap().blocks[i] = BlockHome::Remote;
            self.remote_kv_bytes += block_bytes;
            self.peer_kv_bytes = self.peer_kv_bytes.saturating_sub(block_bytes);
            moved += block_bytes;
        }
        moved
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::GB;

    fn hw() -> HwConfig {
        let mut h = HwConfig::ascend910c_like();
        h.device_capacity = 8 * GB;
        h
    }

    fn mgr(policy: KvPolicy, budget: u64) -> KvCacheManager {
        KvCacheManager::new(policy, NsaConfig::default(), 64 * 1024, budget)
    }

    #[test]
    fn admit_allocates_blocks() {
        let mut m = mgr(KvPolicy::AllDevice, GB);
        m.admit(1, 1000, &hw()).unwrap();
        // 1000 tokens / 64 per block = 16 blocks of 4 MB.
        assert_eq!(m.allocator.used(), 16 * 64 * 64 * 1024);
        assert_eq!(m.seq_tokens(1), Some(1000));
    }

    #[test]
    fn double_admit_rejected() {
        let mut m = mgr(KvPolicy::AllDevice, GB);
        m.admit(1, 10, &hw()).unwrap();
        assert!(m.admit(1, 10, &hw()).is_err());
    }

    #[test]
    fn decode_grows_blocks_at_boundary() {
        let mut m = mgr(KvPolicy::AllDevice, GB);
        m.admit(1, 63, &hw()).unwrap();
        let used0 = m.allocator.used();
        m.decode_step(1, &hw()).unwrap(); // 64th token, same block
        assert_eq!(m.allocator.used(), used0);
        m.decode_step(1, &hw()).unwrap(); // 65th -> new block
        assert!(m.allocator.used() > used0);
    }

    #[test]
    fn offload_keeps_device_bounded() {
        let mut m = mgr(KvPolicy::FullOffload, GB);
        m.admit(1, 10_000, &hw()).unwrap();
        for _ in 0..500 {
            m.decode_step(1, &hw()).unwrap();
        }
        // Device KV never exceeds the working set bound.
        assert!(m.device_kv_bytes() <= m.working_set_bytes);
        assert!(m.remote_kv_bytes > 0);
    }

    #[test]
    fn offload_steps_report_transfer_and_cpu_cost() {
        let mut m = mgr(KvPolicy::FullOffload, GB);
        m.admit(1, 10_000, &hw()).unwrap();
        let c = m.decode_step(1, &hw()).unwrap();
        assert!(c.r2d_bytes > 0);
        assert!(c.d2r_bytes > 0);
        assert!(c.cpu_us > 0.0);
        assert_eq!(c.defrag_events, 0);
    }

    #[test]
    fn all_device_steps_are_free_of_transfers() {
        let mut m = mgr(KvPolicy::AllDevice, GB);
        m.admit(1, 1000, &hw()).unwrap();
        let c = m.decode_step(1, &hw()).unwrap();
        assert_eq!(c.r2d_bytes, 0);
        assert_eq!(c.cpu_us, 0.0);
    }

    #[test]
    fn retire_frees_everything() {
        let mut m = mgr(KvPolicy::AllDevice, GB);
        m.admit(1, 1000, &hw()).unwrap();
        m.admit(2, 500, &hw()).unwrap();
        m.retire(1).unwrap();
        m.retire(2).unwrap();
        assert_eq!(m.allocator.used(), 0);
        assert!(m.retire(1).is_err());
    }

    #[test]
    fn device_baseline_ooms_but_offload_does_not() {
        // Budget fits ~256 blocks of 4 MB = 1 GB.
        let mut dev = mgr(KvPolicy::AllDevice, GB);
        let r = dev.admit(1, 64 * 300, &hw()); // 300 blocks > budget
        assert!(r.is_err(), "baseline should OOM");
        let mut off = mgr(KvPolicy::FullOffload, GB);
        off.admit(1, 64 * 300, &hw()).unwrap();
        assert!(off.max_tokens_supported(0, GB) > 64 * 300);
    }

    #[test]
    fn shared_pool_bounds_offload_and_frees_on_retire() {
        // Pool fits exactly 4 blocks of 4 MiB (64 tok * 64 KiB).
        let block = 64 * 64 * 1024u64;
        let pool = PoolHandle::new(4 * block);
        let mut a = KvCacheManager::with_pool(
            KvPolicy::FullOffload,
            NsaConfig::default(),
            64 * 1024,
            GB,
            pool.clone(),
        );
        let mut b = KvCacheManager::with_pool(
            KvPolicy::FullOffload,
            NsaConfig::default(),
            64 * 1024,
            GB,
            pool.clone(),
        );
        a.admit(1, 64 * 3, &hw()).unwrap(); // 3 blocks
        // Sibling device sees the pressure: 2 blocks won't fit.
        assert!(b.admit(2, 64 * 2, &hw()).is_err());
        assert_eq!(pool.used(), 3 * block, "failed admit must not leak");
        b.admit(2, 32, &hw()).unwrap(); // the last block fits
        // Growth beyond the pool fails at the next block boundary.
        for _ in 0..32 {
            b.decode_step(2, &hw()).unwrap(); // fills block 1, no growth
        }
        assert!(b.decode_step(2, &hw()).is_err(), "pool is full");
        a.retire(1).unwrap();
        assert_eq!(pool.used(), block);
        assert_eq!(a.remote_kv_bytes, 0);
    }

    #[test]
    fn prefix_admission_dedups_across_managers() {
        use crate::kvcache::prefix::{chain_hash, PrefixIndex};
        let block = 64 * 64 * 1024u64; // 64 tok x 64 KiB
        let pool = PoolHandle::new_chunked(64 * block, block);
        let idx = PrefixIndex::new();
        let mk = || {
            KvCacheManager::with_pool_and_index(
                KvPolicy::FullOffload,
                NsaConfig::default(),
                64 * 1024,
                GB,
                pool.clone(),
                Some(idx.clone()),
            )
        };
        let mut a = mk();
        let mut b = mk();
        // 3 hashed full blocks + a partial private tail: 250 tokens.
        let mut hashes = Vec::new();
        let mut h = 42;
        for i in 0..3u64 {
            h = chain_hash(h, i);
            hashes.push(h);
        }
        let cold = a.admit_prefix(1, 250, &hashes, &hw()).unwrap();
        assert_eq!(cold.hit_blocks, 0);
        assert_eq!(cold.deduped_bytes, 0);
        assert_eq!(cold.cost.d2r_bytes, 4 * block, "all 4 blocks computed+written");
        assert_eq!(pool.used(), 4 * block);
        assert_eq!(a.remote_kv_bytes, block, "only the tail is private");

        // Replica B admits the same template: 3-block hit, 1 private tail.
        let warm = b.admit_prefix(2, 250, &hashes, &hw()).unwrap();
        assert_eq!(warm.hit_blocks, 3);
        assert_eq!(warm.hit_tokens, 192);
        assert_eq!(warm.deduped_bytes, 3 * block);
        assert_eq!(warm.cost.d2r_bytes, block, "only the suffix is computed");
        assert_eq!(warm.prefix_fetch_bytes, 3 * block);
        assert_eq!(pool.used(), 5 * block, "shared bytes counted once");

        // Retiring both leaves the cached prefix resident, index-owned.
        a.retire(1).unwrap();
        b.retire(2).unwrap();
        assert_eq!(a.remote_kv_bytes + b.remote_kv_bytes, 0);
        assert_eq!(pool.used(), 3 * block);
        assert_eq!(idx.resident_bytes(), 3 * block);
        assert_eq!(idx.evict(&pool, u64::MAX), 3 * block);
        assert_eq!(pool.used(), 0);
    }

    #[test]
    fn preempted_sequence_releases_only_private_blocks_and_readmits() {
        use crate::kvcache::prefix::{chain_hash, PrefixIndex};
        let block = 64 * 64 * 1024u64;
        let pool = PoolHandle::new_chunked(64 * block, block);
        let idx = PrefixIndex::new();
        let mut m = KvCacheManager::with_pool_and_index(
            KvPolicy::FullOffload,
            NsaConfig::default(),
            64 * 1024,
            GB,
            pool.clone(),
            Some(idx.clone()),
        );
        let hashes: Vec<u64> = {
            let mut v = Vec::new();
            let mut h = 7;
            for i in 0..2u64 {
                h = chain_hash(h, i);
                v.push(h);
            }
            v
        };
        m.admit_prefix(1, 200, &hashes, &hw()).unwrap(); // 2 shared + 2 private
        m.admit_prefix(2, 200, &hashes, &hw()).unwrap(); // attaches to both
        let used = pool.used();
        assert_eq!(used, 6 * block);
        // Preempt seq 1 (vLLM recompute-style: retire, requeue later).
        m.retire(1).unwrap();
        assert_eq!(pool.used(), used - 2 * block, "only private blocks freed");
        for &h in &hashes {
            assert_eq!(pool.shared_refs(h), 2, "seq 2 + index still hold refs");
        }
        // Re-admission goes through the index: full prefix hit, no
        // double-reservation, no re-prefill of the shared blocks.
        let re = m.admit_prefix(1, 200, &hashes, &hw()).unwrap();
        assert_eq!(re.hit_blocks, 2);
        assert_eq!(pool.used(), used);
        assert_eq!(re.cost.d2r_bytes, 2 * block, "only the private suffix recomputes");
        m.retire(1).unwrap();
        m.retire(2).unwrap();
        assert_eq!(pool.used(), idx.resident_bytes());
    }

    #[test]
    fn cow_fork_diverges_on_write() {
        let block = 64 * 64 * 1024u64;
        let pool = PoolHandle::new_chunked(64 * block, block);
        let mut m = KvCacheManager::with_pool(
            KvPolicy::FullOffload,
            NsaConfig::default(),
            64 * 1024,
            GB,
            pool.clone(),
        );
        // 100 tokens = 2 blocks, tail block half-full (no growth on the
        // next decode step, so the CoW tail is written in place).
        m.admit(1, 100, &hw()).unwrap();
        assert_eq!(pool.used(), 2 * block);
        m.fork(1, 2).unwrap();
        assert_eq!(pool.used(), 2 * block, "fork reserves nothing");
        assert_eq!(m.seq_tokens(2), Some(100));
        // Parent writes its tail: still shared with the child -> private
        // copy forked, one new block reserved.
        m.decode_step(1, &hw()).unwrap();
        assert_eq!(pool.used(), 3 * block);
        assert_eq!(m.cow_forks, 1);
        // Child writes its tail: it is the last holder now -> collapses in
        // place, no new bytes.
        m.decode_step(2, &hw()).unwrap();
        assert_eq!(pool.used(), 3 * block);
        assert_eq!(m.cow_forks, 1);
        m.retire(1).unwrap();
        m.retire(2).unwrap();
        assert_eq!(pool.used(), 0);
        assert_eq!(m.remote_kv_bytes, 0);
    }

    #[test]
    fn admission_under_pressure_evicts_cold_prefixes() {
        use crate::kvcache::prefix::{chain_hash, PrefixIndex};
        let block = 64 * 64 * 1024u64;
        let pool = PoolHandle::new_chunked(4 * block, block);
        let idx = PrefixIndex::new();
        let mut m = KvCacheManager::with_pool_and_index(
            KvPolicy::FullOffload,
            NsaConfig::default(),
            64 * 1024,
            GB,
            pool.clone(),
            Some(idx.clone()),
        );
        let mut hashes = Vec::new();
        let mut h = 9;
        for i in 0..2u64 {
            h = chain_hash(h, i);
            hashes.push(h);
        }
        m.admit_prefix(1, 128, &hashes, &hw()).unwrap(); // 2 shared blocks
        m.retire(1).unwrap(); // cached, cold
        assert_eq!(pool.used(), 2 * block);
        // A private 4-block admission needs the whole pool: the cold
        // cached prefix is evicted to make room.
        m.admit(2, 256, &hw()).unwrap();
        assert_eq!(pool.used(), 4 * block);
        assert!(idx.is_empty(), "cold entries evicted under pressure");
    }

    #[test]
    fn errors_downcast_to_structured_kv_errors() {
        let mut m = mgr(KvPolicy::AllDevice, GB);
        m.admit(1, 10, &hw()).unwrap();
        let e = m.admit(1, 10, &hw()).unwrap_err();
        assert_eq!(e.downcast_ref::<KvError>(), Some(&KvError::AlreadyAdmitted { seq: 1 }));
        let e = m.decode_step(9, &hw()).unwrap_err();
        assert_eq!(e.downcast_ref::<KvError>(), Some(&KvError::UnknownSequence { seq: 9 }));
        let e = m.fork(1, 2).unwrap_err();
        assert_eq!(e.downcast_ref::<KvError>(), Some(&KvError::PolicyMismatch { op: "fork" }));
        let e = m.retire(42).unwrap_err();
        assert_eq!(e.downcast_ref::<KvError>(), Some(&KvError::UnknownSequence { seq: 42 }));
        // Capacity failures carry the structured PoolExhausted variant.
        let block = 64 * 64 * 1024u64;
        let mut tight = KvCacheManager::with_pool(
            KvPolicy::FullOffload,
            NsaConfig::default(),
            64 * 1024,
            GB,
            PoolHandle::new_chunked(block, block),
        );
        let e = tight.admit(7, 64 * 2, &hw()).unwrap_err();
        assert!(matches!(
            e.downcast_ref::<KvError>(),
            Some(&KvError::PoolExhausted { what: "prefill blocks", .. })
        ));
    }

    #[test]
    fn tiered_ledger_demotes_prefixes_and_reports_cold_fetches() {
        use crate::kvcache::prefix::{chain_hash, PrefixIndex};
        use crate::sim::TierTopology;
        let block = 64 * 64 * 1024u64;
        let pool = PoolHandle::new_chunked(4 * block, block);
        let topo = TierTopology::two_tier(&hw()).with_cold_tier(
            Tier::Dram,
            10.0,
            10.0,
            5.0,
            8 * block,
        );
        let ledger = TieredLedger::from_topology(pool.clone(), &topo, block);
        let idx = PrefixIndex::new();
        let mut m = KvCacheManager::with_ledger(
            KvPolicy::FullOffload,
            NsaConfig::default(),
            64 * 1024,
            GB,
            ledger.clone(),
            Some(idx.clone()),
        );
        let mut hashes = Vec::new();
        let mut h = 9;
        for i in 0..2u64 {
            h = chain_hash(h, i);
            hashes.push(h);
        }
        m.admit_prefix(1, 128, &hashes, &hw()).unwrap(); // 2 shared blocks
        m.retire(1).unwrap(); // cached, cold
        assert_eq!(pool.used(), 2 * block);
        // A private 4-block admission needs the whole pool: the cold
        // cached prefix demotes to DRAM instead of being evicted.
        m.admit(2, 256, &hw()).unwrap();
        assert_eq!(pool.used(), 4 * block);
        assert_eq!(idx.len(), 2, "demotion keeps the prefix resident");
        assert_eq!(idx.demoted(), 2);
        assert_eq!(ledger.handle(Tier::Dram).unwrap().used(), 2 * block);
        m.retire(2).unwrap();
        // Re-admitting the template hits the demoted blocks: no prefill
        // recompute, and the bytes arrive over the DRAM path.
        let warm = m.admit_prefix(3, 250, &hashes, &hw()).unwrap();
        assert_eq!(warm.hit_blocks, 2);
        assert_eq!(warm.prefix_fetch_bytes, 0, "nothing comes over the pool link");
        assert_eq!(warm.cold_fetch, vec![(Tier::Dram, 2 * block)]);
        // 250 tokens = 4 blocks: a small sequence's decode touches every
        // block (all inside the sliding window), so the two demoted homes
        // show up as a per-step cold fetch, not pool prefetch volume.
        let c = m.decode_step(3, &hw()).unwrap();
        assert_eq!(c.cold_fetch, vec![(Tier::Dram, 2 * block)]);
        assert_eq!(c.r2d_bytes, 2 * block, "only the private blocks use the pool link");
        m.retire(3).unwrap();
        assert_eq!(ledger.handle(Tier::Dram).unwrap().shared_refs(hashes[0]), 1);
        assert_eq!(pool.used(), 0);
        assert_eq!(ledger.total_used(), 2 * block, "only the demoted prefix stays resident");
    }

    #[test]
    fn device_spill_places_growth_blocks_in_hbm_when_pool_full() {
        let block = 64 * 64 * 1024u64;
        let pool = PoolHandle::new_chunked(2 * block, block);
        let mut m = KvCacheManager::with_pool(
            KvPolicy::FullOffload,
            NsaConfig::default(),
            64 * 1024,
            GB,
            pool.clone(),
        )
        .with_device_spill();
        m.admit(1, 64 * 2, &hw()).unwrap(); // fills the pool
        assert_eq!(pool.used(), 2 * block);
        assert_eq!(m.allocator.used(), 0);
        // The next growth block fits nowhere in the pool: it spills into
        // HBM instead of failing the step, and decodes in place (no
        // writeback to the pool).
        let c = m.decode_step(1, &hw()).unwrap();
        assert_eq!(m.allocator.used(), block);
        assert_eq!(pool.used(), 2 * block);
        assert_eq!(c.d2r_bytes, 0, "spilled tail writes land in HBM");
        assert!(m.peak_device_kv >= block);
        m.retire(1).unwrap();
        assert_eq!(m.allocator.used(), 0);
        assert_eq!(pool.used(), 0);
    }

    #[test]
    fn peer_lease_places_private_blocks_and_revoke_rehomes_them() {
        use crate::memory::LeaseLedger;
        let block = 64 * 64 * 1024u64;
        let pool = PoolHandle::new_chunked(16 * block, block);
        let lease = LeaseLedger::new();
        lease.register_lender(1, 4 * block);
        let mut m = KvCacheManager::with_pool(
            KvPolicy::FullOffload,
            NsaConfig::default(),
            64 * 1024,
            GB,
            pool.clone(),
        );
        m.set_peer_lease(lease.clone(), 0);
        // 3-block private admission: the whole suffix borrows from the
        // idle lender, nothing touches the pool.
        let a = m.admit(1, 64 * 3, &hw()).unwrap();
        assert_eq!(pool.used(), 0);
        assert_eq!(lease.lent(1), 3 * block);
        assert_eq!(m.peer_kv_bytes, 3 * block);
        assert_eq!(a.d2r_bytes, 0, "prefill writes back over the peer edge");
        assert_eq!(a.peer_store, vec![(1, 3 * block)]);
        // Decode fetches the working set from the peer, not the pool,
        // and the tail writeback rides the peer edge too. The growth
        // block (193rd token) borrows the lender's last spare block.
        let c = m.decode_step(1, &hw()).unwrap();
        assert_eq!(c.r2d_bytes, 0);
        assert!(c.peer_fetch.iter().any(|&(r, b)| r == 1 && b > 0));
        assert_eq!(c.peer_store, vec![(1, block)]);
        assert_eq!(lease.lent(1), 4 * block);
        // Lender load spike: revoke demotes every borrowed block into
        // the pool — exactly once, never dropped.
        lease.begin_revoke(1);
        let moved = m.revoke_peer(1);
        assert_eq!(moved, 4 * block);
        assert_eq!(pool.used(), 4 * block);
        assert_eq!(lease.lent(1), 0);
        assert_eq!(m.peer_kv_bytes, 0);
        assert_eq!(m.remote_kv_bytes, 4 * block);
        // A second sweep finds nothing: no double-demote.
        assert_eq!(m.revoke_peer(1), 0);
        assert_eq!(pool.used(), 4 * block);
        m.retire(1).unwrap();
        assert_eq!(pool.used(), 0);
    }

    #[test]
    fn peer_retire_releases_the_lease_without_touching_the_pool() {
        use crate::memory::LeaseLedger;
        let block = 64 * 64 * 1024u64;
        let pool = PoolHandle::new_chunked(16 * block, block);
        let lease = LeaseLedger::new();
        lease.register_lender(2, 8 * block);
        let mut m = KvCacheManager::with_pool(
            KvPolicy::FullOffload,
            NsaConfig::default(),
            64 * 1024,
            GB,
            pool.clone(),
        );
        m.set_peer_lease(lease.clone(), 0);
        m.admit(1, 64 * 2, &hw()).unwrap();
        assert_eq!(lease.lent(2), 2 * block);
        m.retire(1).unwrap();
        assert_eq!(lease.lent(2), 0);
        assert_eq!(pool.used(), 0);
        assert_eq!(m.peer_kv_bytes, 0);
    }

    #[test]
    fn peer_disabled_or_exhausted_falls_back_to_the_pool() {
        use crate::memory::LeaseLedger;
        let block = 64 * 64 * 1024u64;
        let pool = PoolHandle::new_chunked(16 * block, block);
        let lease = LeaseLedger::new();
        lease.register_lender(1, block); // too small for a 2-block suffix
        let mut m = KvCacheManager::with_pool(
            KvPolicy::FullOffload,
            NsaConfig::default(),
            64 * 1024,
            GB,
            pool.clone(),
        );
        m.set_peer_lease(lease.clone(), 0);
        m.admit(1, 64 * 2, &hw()).unwrap();
        assert_eq!(pool.used(), 2 * block, "undersized lender: pool fallback");
        assert_eq!(lease.lent(1), 0);
        // SLO veto closes the borrower side entirely.
        m.set_peer_enabled(false);
        m.admit(2, 32, &hw()).unwrap();
        assert_eq!(pool.used(), 3 * block);
        assert_eq!(lease.lent(1), 0);
    }

    #[test]
    fn fragmentation_defrag_under_churn() {
        // Lots of admits/retires of uneven sizes near capacity fragments
        // the allocator and eventually triggers compaction (Table 4).
        let mut m = KvCacheManager::new(
            KvPolicy::AllDevice,
            NsaConfig { block_tokens: 64, ..Default::default() },
            64 * 1024,
            512 * 1024 * 1024,
        );
        let mut next = 0u64;
        let mut live: Vec<u64> = Vec::new();
        for round in 0..200 {
            let toks = 64 * (1 + (round % 13));
            if m.admit(next, toks, &hw()).is_ok() {
                live.push(next);
            }
            next += 1;
            if live.len() > 6 {
                // Retire from the middle to punch holes.
                let mid = live.remove(live.len() / 2);
                m.retire(mid).unwrap();
            }
        }
        assert!(
            m.allocator.defrag_events > 0 || m.allocator.fragmentation() > 0.0,
            "churn should fragment"
        );
    }
}
