//! Block-based KV-cache manager (§5.2).
//!
//! KV state is held in fixed-size blocks (paged, vLLM-style — the same
//! granularity the L1 Pallas kernel tiles attention over). Residency policy
//! decides where blocks live:
//!
//! * [`KvPolicy::AllDevice`] — the paper's inference baseline: every block
//!   in HBM, allocated through the fragmenting [`DeviceAllocator`], so long
//!   sequences near capacity trigger defragmentation (Table 4).
//! * [`KvPolicy::FullOffload`] — the hierarchical-memory configuration:
//!   blocks live in the remote pool; the decode scheduler prefetches the
//!   NSA-touched working set ahead of each step, and the graph-driven
//!   schedule hides the transfers behind the step's other compute.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::memory::{DeviceAllocator, PoolHandle};
use crate::sim::HwConfig;

use super::nsa::NsaConfig;

/// Where KV blocks reside.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvPolicy {
    /// Baseline: all KV blocks in device HBM.
    AllDevice,
    /// Hierarchical memory: KV home is the remote pool; a bounded device
    /// working set holds the blocks the current step touches.
    FullOffload,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum BlockHome {
    Device(crate::memory::AllocId),
    Remote,
}

#[derive(Debug)]
struct Sequence {
    tokens: usize,
    blocks: Vec<BlockHome>,
    /// Baseline (AllDevice): the prompt KV is one contiguous variable-size
    /// allocation — the non-paged layout of the paper's MindSpore baseline
    /// and the reason long-sequence churn fragments HBM (§7.3.2).
    prompt_alloc: Option<crate::memory::AllocId>,
    /// Blocks of KV capacity already backed (prompt region + growth).
    capacity_blocks: usize,
    /// Blocks currently device-resident in the offload working set (the
    /// previous step's touched set). Only the delta transfers each step.
    cached: Vec<usize>,
}

/// Per-step accounting returned by [`KvCacheManager::decode_step`].
#[derive(Debug, Clone, Default)]
pub struct StepCost {
    /// Bytes moved Remote→Device for this step (prefetch volume).
    pub r2d_bytes: u64,
    /// Bytes written back Device→Remote (new token K/V persisted).
    pub d2r_bytes: u64,
    /// Host-side sparse block processing time (us).
    pub cpu_us: f64,
    /// Device-allocator defragmentation stall (us).
    pub defrag_us: f64,
    /// Defrag events triggered by this step.
    pub defrag_events: u64,
}

/// Fixed framework cost of one compaction pass (us). Calibrated from the
/// paper's §7.3.2: ~30 s of prefill degradation across 57 events.
pub const DEFRAG_FIXED_US: f64 = 1_000_000.0;

/// The KV-cache manager for one device.
pub struct KvCacheManager {
    pub policy: KvPolicy,
    pub nsa: NsaConfig,
    /// KV bytes per token across all layers (k+v).
    pub kv_bytes_per_token: u64,
    pub allocator: DeviceAllocator,
    /// Device working set for offloaded blocks (bytes), bounding residency.
    pub working_set_bytes: u64,
    /// Remote-pool capacity ledger. A private handle for a lone device;
    /// a clone of the node-wide handle when several engines share one
    /// SuperNode pool (the cluster setup) — then every `FullOffload`
    /// block placed here competes with sibling devices for capacity.
    pool: PoolHandle,
    seqs: HashMap<u64, Sequence>,
    /// Remote-pool bytes used by *this device's* KV.
    pub remote_kv_bytes: u64,
    /// Peak device bytes used by KV (blocks + working set).
    pub peak_device_kv: u64,
    working_set_used: u64,
}

impl KvCacheManager {
    pub fn new(
        policy: KvPolicy,
        nsa: NsaConfig,
        kv_bytes_per_token: u64,
        device_kv_budget: u64,
    ) -> Self {
        Self::with_pool(policy, nsa, kv_bytes_per_token, device_kv_budget, PoolHandle::unbounded())
    }

    /// A manager whose offloaded blocks reserve capacity from `pool`
    /// (shared across devices when the handle is cloned).
    ///
    /// All of this manager's pool traffic is block-granular — admissions
    /// reserve whole blocks, growth reserves one block, retirement
    /// releases blocks — so a pool whose chunk size is the KV block
    /// ([`PoolHandle::new_chunked`], the cluster's setup) accounts it
    /// without any rounding.
    pub fn with_pool(
        policy: KvPolicy,
        nsa: NsaConfig,
        kv_bytes_per_token: u64,
        device_kv_budget: u64,
        pool: PoolHandle,
    ) -> Self {
        debug_assert!(
            pool.chunk_bytes() <= 1
                || nsa.block_bytes(kv_bytes_per_token) % pool.chunk_bytes() == 0,
            "KV block size must be a multiple of the pool's chunk granularity"
        );
        Self {
            policy,
            nsa,
            kv_bytes_per_token,
            allocator: DeviceAllocator::new(device_kv_budget),
            working_set_bytes: device_kv_budget / 8,
            pool,
            seqs: HashMap::new(),
            remote_kv_bytes: 0,
            peak_device_kv: 0,
            working_set_used: 0,
        }
    }

    /// The remote pool this manager reserves offloaded KV from.
    pub fn pool(&self) -> &PoolHandle {
        &self.pool
    }

    /// Device KV bytes still allocatable (baseline headroom signal for
    /// online routing).
    pub fn device_headroom_bytes(&self) -> u64 {
        self.allocator.capacity().saturating_sub(self.allocator.used())
    }

    /// Conservative admission check used when re-admitting preempted
    /// sequences: the sequence footprint plus one growth block must fit
    /// (a vLLM-style watermark that avoids admit-then-preempt thrash on
    /// an exactly-full device).
    pub fn can_admit_tokens(&self, tokens: usize) -> bool {
        let blocks = self.nsa.blocks_for(tokens.max(1)) as u64 + 1;
        let bytes = blocks * self.block_bytes();
        match self.policy {
            KvPolicy::AllDevice => self.allocator.free_total() >= bytes,
            KvPolicy::FullOffload => {
                self.pool.capacity().saturating_sub(self.pool.used()) >= bytes
            }
        }
    }

    pub fn block_bytes(&self) -> u64 {
        self.nsa.block_bytes(self.kv_bytes_per_token)
    }

    /// Admit a sequence after prefill: allocate blocks for `prompt_tokens`.
    /// Returns the step cost of materialising them (alloc stalls, transfer
    /// volume for offloaded prefill writeback).
    pub fn admit(&mut self, seq_id: u64, prompt_tokens: usize, hw: &HwConfig) -> Result<StepCost> {
        if self.seqs.contains_key(&seq_id) {
            bail!("sequence {seq_id} already admitted");
        }
        let nblocks = self.nsa.blocks_for(prompt_tokens.max(1));
        let mut cost = StepCost::default();
        let mut blocks = Vec::with_capacity(nblocks);
        let mut prompt_alloc = None;
        match self.policy {
            KvPolicy::AllDevice => {
                // One contiguous variable-size region for the prompt KV.
                let bytes = nblocks as u64 * self.block_bytes();
                let before = self.allocator.defrag_events;
                let (id, moved) = self.allocator.alloc(bytes)?;
                if moved > 0 {
                    cost.defrag_us += 2.0 * moved as f64 / (hw.hbm_gbps * 1e9) * 1e6
                        + DEFRAG_FIXED_US;
                }
                cost.defrag_events += self.allocator.defrag_events - before;
                prompt_alloc = Some(id);
            }
            KvPolicy::FullOffload => {
                // Reserve the whole prompt's KV from the (possibly shared)
                // pool atomically, so a mid-admit failure leaks nothing.
                let bytes = nblocks as u64 * self.block_bytes();
                if !self.pool.try_reserve(bytes) {
                    bail!("remote pool exhausted: {bytes} B for {nblocks} prefill blocks");
                }
                self.remote_kv_bytes += bytes;
                blocks.resize(nblocks, BlockHome::Remote);
                // Prefill KV streams to the pool as it is produced.
                cost.d2r_bytes += bytes;
            }
        }
        self.seqs.insert(
            seq_id,
            Sequence {
                tokens: prompt_tokens,
                blocks,
                prompt_alloc,
                capacity_blocks: nblocks,
                cached: Vec::new(),
            },
        );
        self.note_peak();
        Ok(cost)
    }

    /// One decode step for `seq_id`: appends a token, prefetches the NSA
    /// working set (offload policy), charges CPU sparse processing.
    pub fn decode_step(&mut self, seq_id: u64, hw: &HwConfig) -> Result<StepCost> {
        let block_bytes = self.block_bytes();
        let policy = self.policy;
        let nsa = self.nsa.clone();
        let seq = match self.seqs.get_mut(&seq_id) {
            Some(s) => s,
            None => bail!("unknown sequence {seq_id}"),
        };
        seq.tokens += 1;
        let tokens = seq.tokens;
        let need_new_block = nsa.blocks_for(tokens) > seq.capacity_blocks;

        let mut cost = StepCost::default();
        if need_new_block {
            let b = self.place_block(&mut cost, hw)?;
            let seq = self.seqs.get_mut(&seq_id).unwrap();
            seq.blocks.push(b);
            seq.capacity_blocks += 1;
        }

        match policy {
            KvPolicy::AllDevice => {
                // Everything resident: no transfers, no host gather.
            }
            KvPolicy::FullOffload => {
                let touched = nsa.touched_blocks(tokens, seq_id);
                // Only the delta vs the resident working set transfers:
                // sliding-window blocks stay cached across steps, selection
                // churn brings in new blocks (graph-scheduled prefetches).
                let seq = self.seqs.get_mut(&seq_id).unwrap();
                let new_blocks =
                    touched.iter().filter(|b| !seq.cached.contains(b)).count() as u64;
                seq.cached = touched.clone();
                cost.r2d_bytes += new_blocks * block_bytes;
                // Persist the updated tail block.
                cost.d2r_bytes += block_bytes;
                // Host-side sparse processing over every touched block
                // (partial KV updates, gather/scatter) — the term that
                // makes Table 5's decode latency grow with granularity.
                cost.cpu_us += nsa.cpu_step_cost_us(touched.len(), block_bytes);
                self.working_set_used =
                    (touched.len() as u64 * block_bytes).min(self.working_set_bytes);
            }
        }
        self.note_peak();
        Ok(cost)
    }

    /// Retire a finished sequence, freeing its blocks.
    pub fn retire(&mut self, seq_id: u64) -> Result<()> {
        let Some(seq) = self.seqs.remove(&seq_id) else {
            bail!("unknown sequence {seq_id}");
        };
        if let Some(a) = seq.prompt_alloc {
            self.allocator.free(a)?;
        }
        for b in seq.blocks {
            match b {
                BlockHome::Device(a) => self.allocator.free(a)?,
                BlockHome::Remote => {
                    self.pool.release(self.block_bytes());
                    self.remote_kv_bytes -= self.block_bytes();
                }
            }
        }
        if self.seqs.is_empty() {
            self.working_set_used = 0;
        }
        Ok(())
    }

    /// Total KV bytes currently on device (blocks + offload working set).
    pub fn device_kv_bytes(&self) -> u64 {
        self.allocator.used() + self.working_set_used
    }

    /// Total tokens currently cached for `seq_id`.
    pub fn seq_tokens(&self, seq_id: u64) -> Option<usize> {
        self.seqs.get(&seq_id).map(|s| s.tokens)
    }

    /// Can the manager hold a sequence of `tokens` under the current
    /// policy? (The Table 3 max-sequence-length question.)
    pub fn max_tokens_supported(&self, non_kv_reserved: u64, device_total: u64) -> u64 {
        let kv_budget = match self.policy {
            KvPolicy::AllDevice => device_total.saturating_sub(non_kv_reserved),
            KvPolicy::FullOffload => {
                // KV lives in the pool; device only needs the working set.
                return u64::MAX; // bounded by pool, not device
            }
        };
        kv_budget / self.kv_bytes_per_token
    }

    fn place_block(&mut self, cost: &mut StepCost, hw: &HwConfig) -> Result<BlockHome> {
        match self.policy {
            KvPolicy::AllDevice => {
                let before = self.allocator.defrag_events;
                let (id, moved) = self.allocator.alloc(self.block_bytes())?;
                if moved > 0 {
                    // Byte movement at HBM bandwidth + the framework-level
                    // fixed cost of a compaction pass (synchronise, rebuild
                    // tables — the dominant term the paper measures:
                    // ~30 s of prefill across 57 events, §7.3.2).
                    cost.defrag_us += 2.0 * moved as f64 / (hw.hbm_gbps * 1e9) * 1e6
                        + DEFRAG_FIXED_US;
                }
                cost.defrag_events += self.allocator.defrag_events - before;
                Ok(BlockHome::Device(id))
            }
            KvPolicy::FullOffload => {
                let bytes = self.block_bytes();
                if !self.pool.try_reserve(bytes) {
                    bail!("remote pool exhausted: {bytes} B for one KV block");
                }
                self.remote_kv_bytes += bytes;
                Ok(BlockHome::Remote)
            }
        }
    }

    fn note_peak(&mut self) {
        self.peak_device_kv = self.peak_device_kv.max(self.device_kv_bytes());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::GB;

    fn hw() -> HwConfig {
        let mut h = HwConfig::ascend910c_like();
        h.device_capacity = 8 * GB;
        h
    }

    fn mgr(policy: KvPolicy, budget: u64) -> KvCacheManager {
        KvCacheManager::new(policy, NsaConfig::default(), 64 * 1024, budget)
    }

    #[test]
    fn admit_allocates_blocks() {
        let mut m = mgr(KvPolicy::AllDevice, GB);
        m.admit(1, 1000, &hw()).unwrap();
        // 1000 tokens / 64 per block = 16 blocks of 4 MB.
        assert_eq!(m.allocator.used(), 16 * 64 * 64 * 1024);
        assert_eq!(m.seq_tokens(1), Some(1000));
    }

    #[test]
    fn double_admit_rejected() {
        let mut m = mgr(KvPolicy::AllDevice, GB);
        m.admit(1, 10, &hw()).unwrap();
        assert!(m.admit(1, 10, &hw()).is_err());
    }

    #[test]
    fn decode_grows_blocks_at_boundary() {
        let mut m = mgr(KvPolicy::AllDevice, GB);
        m.admit(1, 63, &hw()).unwrap();
        let used0 = m.allocator.used();
        m.decode_step(1, &hw()).unwrap(); // 64th token, same block
        assert_eq!(m.allocator.used(), used0);
        m.decode_step(1, &hw()).unwrap(); // 65th -> new block
        assert!(m.allocator.used() > used0);
    }

    #[test]
    fn offload_keeps_device_bounded() {
        let mut m = mgr(KvPolicy::FullOffload, GB);
        m.admit(1, 10_000, &hw()).unwrap();
        for _ in 0..500 {
            m.decode_step(1, &hw()).unwrap();
        }
        // Device KV never exceeds the working set bound.
        assert!(m.device_kv_bytes() <= m.working_set_bytes);
        assert!(m.remote_kv_bytes > 0);
    }

    #[test]
    fn offload_steps_report_transfer_and_cpu_cost() {
        let mut m = mgr(KvPolicy::FullOffload, GB);
        m.admit(1, 10_000, &hw()).unwrap();
        let c = m.decode_step(1, &hw()).unwrap();
        assert!(c.r2d_bytes > 0);
        assert!(c.d2r_bytes > 0);
        assert!(c.cpu_us > 0.0);
        assert_eq!(c.defrag_events, 0);
    }

    #[test]
    fn all_device_steps_are_free_of_transfers() {
        let mut m = mgr(KvPolicy::AllDevice, GB);
        m.admit(1, 1000, &hw()).unwrap();
        let c = m.decode_step(1, &hw()).unwrap();
        assert_eq!(c.r2d_bytes, 0);
        assert_eq!(c.cpu_us, 0.0);
    }

    #[test]
    fn retire_frees_everything() {
        let mut m = mgr(KvPolicy::AllDevice, GB);
        m.admit(1, 1000, &hw()).unwrap();
        m.admit(2, 500, &hw()).unwrap();
        m.retire(1).unwrap();
        m.retire(2).unwrap();
        assert_eq!(m.allocator.used(), 0);
        assert!(m.retire(1).is_err());
    }

    #[test]
    fn device_baseline_ooms_but_offload_does_not() {
        // Budget fits ~256 blocks of 4 MB = 1 GB.
        let mut dev = mgr(KvPolicy::AllDevice, GB);
        let r = dev.admit(1, 64 * 300, &hw()); // 300 blocks > budget
        assert!(r.is_err(), "baseline should OOM");
        let mut off = mgr(KvPolicy::FullOffload, GB);
        off.admit(1, 64 * 300, &hw()).unwrap();
        assert!(off.max_tokens_supported(0, GB) > 64 * 300);
    }

    #[test]
    fn shared_pool_bounds_offload_and_frees_on_retire() {
        // Pool fits exactly 4 blocks of 4 MiB (64 tok * 64 KiB).
        let block = 64 * 64 * 1024u64;
        let pool = PoolHandle::new(4 * block);
        let mut a = KvCacheManager::with_pool(
            KvPolicy::FullOffload,
            NsaConfig::default(),
            64 * 1024,
            GB,
            pool.clone(),
        );
        let mut b = KvCacheManager::with_pool(
            KvPolicy::FullOffload,
            NsaConfig::default(),
            64 * 1024,
            GB,
            pool.clone(),
        );
        a.admit(1, 64 * 3, &hw()).unwrap(); // 3 blocks
        // Sibling device sees the pressure: 2 blocks won't fit.
        assert!(b.admit(2, 64 * 2, &hw()).is_err());
        assert_eq!(pool.used(), 3 * block, "failed admit must not leak");
        b.admit(2, 32, &hw()).unwrap(); // the last block fits
        // Growth beyond the pool fails at the next block boundary.
        for _ in 0..32 {
            b.decode_step(2, &hw()).unwrap(); // fills block 1, no growth
        }
        assert!(b.decode_step(2, &hw()).is_err(), "pool is full");
        a.retire(1).unwrap();
        assert_eq!(pool.used(), block);
        assert_eq!(a.remote_kv_bytes, 0);
    }

    #[test]
    fn fragmentation_defrag_under_churn() {
        // Lots of admits/retires of uneven sizes near capacity fragments
        // the allocator and eventually triggers compaction (Table 4).
        let mut m = KvCacheManager::new(
            KvPolicy::AllDevice,
            NsaConfig { block_tokens: 64, ..Default::default() },
            64 * 1024,
            512 * 1024 * 1024,
        );
        let mut next = 0u64;
        let mut live: Vec<u64> = Vec::new();
        for round in 0..200 {
            let toks = 64 * (1 + (round % 13));
            if m.admit(next, toks, &hw()).is_ok() {
                live.push(next);
            }
            next += 1;
            if live.len() > 6 {
                // Retire from the middle to punch holes.
                let mid = live.remove(live.len() / 2);
                m.retire(mid).unwrap();
            }
        }
        assert!(
            m.allocator.defrag_events > 0 || m.allocator.fragmentation() > 0.0,
            "churn should fragment"
        );
    }
}
