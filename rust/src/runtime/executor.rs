//! One compiled HLO executable on the PJRT CPU client.

use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};
use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// A compiled artifact plus simple execution statistics.
pub struct Executor {
    exe: PjRtLoadedExecutable,
    pub name: String,
    /// Cumulative wall-clock spent inside `execute*` (perf accounting).
    pub total_exec_us: std::cell::Cell<u64>,
    pub exec_count: std::cell::Cell<u64>,
}

impl Executor {
    /// Load HLO text from `path` and compile it on `client`.
    ///
    /// HLO *text* is the interchange format — jax >= 0.5 serialized protos
    /// carry 64-bit instruction ids that xla_extension 0.5.1 rejects; the
    /// text parser reassigns ids (see /opt/xla-example/README.md).
    pub fn load(client: &PjRtClient, path: &Path) -> Result<Self> {
        let proto = HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Self {
            exe,
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
            total_exec_us: std::cell::Cell::new(0),
            exec_count: std::cell::Cell::new(0),
        })
    }

    /// Execute with host literals; returns the flattened tuple outputs.
    ///
    /// All artifacts are lowered with `return_tuple=True`, so the single
    /// device output is a tuple literal that we decompose.
    pub fn run(&self, args: &[Literal]) -> Result<Vec<Literal>> {
        let t0 = Instant::now();
        let result = self.exe.execute::<Literal>(args)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        self.bump(t0);
        Ok(parts)
    }

    /// Like [`run`](Self::run) but borrows the argument literals (avoids
    /// cloning multi-MB weights/caches into a temporary Vec).
    pub fn run_ref(&self, args: &[&Literal]) -> Result<Vec<Literal>> {
        let t0 = Instant::now();
        let result = self.exe.execute::<&Literal>(args)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        self.bump(t0);
        Ok(parts)
    }

    /// Execute buffer-to-buffer.
    ///
    /// NOTE: artifacts are lowered with `return_tuple=True` and the crate's
    /// ExecuteOptions do not untuple, so for multi-output computations this
    /// returns a single tuple buffer that cannot be fed back as separate
    /// inputs — use [`run`](Self::run)/[`run_ref`](Self::run_ref) for those.
    pub fn run_b(&self, args: &[PjRtBuffer]) -> Result<Vec<PjRtBuffer>> {
        let t0 = Instant::now();
        let mut outs = self.exe.execute_b(args)?;
        self.bump(t0);
        Ok(outs.swap_remove(0))
    }

    fn bump(&self, t0: Instant) {
        self.total_exec_us
            .set(self.total_exec_us.get() + t0.elapsed().as_micros() as u64);
        self.exec_count.set(self.exec_count.get() + 1);
    }

    /// Mean execution time in microseconds (0 if never run).
    pub fn mean_exec_us(&self) -> f64 {
        let n = self.exec_count.get();
        if n == 0 {
            0.0
        } else {
            self.total_exec_us.get() as f64 / n as f64
        }
    }
}
