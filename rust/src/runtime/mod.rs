//! Runtime layer: load AOT-compiled HLO-text artifacts and execute them on
//! the PJRT CPU client (the `xla` crate). This is the only place python
//! output crosses into rust; after `make artifacts` the binary is
//! self-contained.
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.

mod executor;
mod model;

pub use executor::Executor;
pub use model::{ModelRuntime, ModelSpec};
