//! Model runtime: the AOT-compiled transformer (prefill + decode step) plus
//! its weight literals, reconstructed from `artifacts/` per the manifest.

use std::path::Path;

use anyhow::{bail, Context, Result};
use xla::{Literal, PjRtClient};

use crate::util::meta::Meta;
use super::Executor;

/// Static model/artifact dimensions parsed from `meta.txt`.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub max_seq: usize,
    pub prefill_len: usize,
    pub batch: usize,
    pub kv_block: usize,
    pub head_dim: usize,
}

impl ModelSpec {
    pub fn from_meta(meta: &Meta) -> Result<Self> {
        Ok(Self {
            vocab: meta.get_usize("vocab")?,
            d_model: meta.get_usize("d_model")?,
            n_heads: meta.get_usize("n_heads")?,
            n_layers: meta.get_usize("n_layers")?,
            max_seq: meta.get_usize("max_seq")?,
            prefill_len: meta.get_usize("prefill_len")?,
            batch: meta.get_usize("batch")?,
            kv_block: meta.get_usize("kv_block")?,
            head_dim: meta.get_usize("head_dim")?,
        })
    }

    /// Bytes of one KV cache tensor (one of k/v): L*B*H*S*Dh*4.
    pub fn cache_bytes(&self) -> u64 {
        (self.n_layers * self.batch * self.n_heads * self.max_seq * self.head_dim * 4) as u64
    }

    /// Bytes of KV per token per sequence across all layers (k+v).
    pub fn kv_bytes_per_token(&self) -> u64 {
        (2 * self.n_layers * self.n_heads * self.head_dim * 4) as u64
    }
}

/// The served model: compiled prefill + decode executables and weights.
pub struct ModelRuntime {
    pub spec: ModelSpec,
    pub prefill: Executor,
    pub decode: Executor,
    weights: Vec<Literal>,
}

impl ModelRuntime {
    /// Load `meta.txt`, `weights.bin`, and both HLO artifacts from `dir`.
    pub fn load(client: &PjRtClient, dir: &Path) -> Result<Self> {
        let meta = Meta::load(&dir.join("meta.txt"))?;
        let spec = ModelSpec::from_meta(&meta)?;

        let raw = std::fs::read(dir.join("weights.bin"))
            .with_context(|| format!("reading {}/weights.bin", dir.display()))?;
        let total: usize = meta.weights.iter().map(|w| w.numel).sum();
        if raw.len() != total * 4 {
            bail!(
                "weights.bin is {} bytes, manifest expects {} f32 ({} bytes)",
                raw.len(), total, total * 4
            );
        }
        let mut weights = Vec::with_capacity(meta.weights.len());
        let mut off = 0usize;
        for w in &meta.weights {
            let n = w.numel;
            let mut vals = vec![0f32; n];
            // weights.bin is f32 little-endian, the native layout here.
            for (i, chunk) in raw[off..off + n * 4].chunks_exact(4).enumerate() {
                vals[i] = f32::from_le_bytes(chunk.try_into().unwrap());
            }
            off += n * 4;
            let lit = Literal::vec1(&vals);
            let lit = if w.shape.len() == 1 {
                lit
            } else {
                lit.reshape(&w.shape)
                    .with_context(|| format!("reshaping weight {}", w.name))?
            };
            weights.push(lit);
        }

        let prefill = Executor::load(client, &dir.join("prefill.hlo.txt"))?;
        let decode = Executor::load(client, &dir.join("decode.hlo.txt"))?;
        Ok(Self { spec, prefill, decode, weights })
    }

    /// Run prefill over a padded `batch x prefill_len` token matrix.
    ///
    /// Returns (last-position logits `[B*V]`, k_cache, v_cache).
    pub fn run_prefill(&self, tokens: &[i32]) -> Result<(Vec<f32>, Literal, Literal)> {
        let (b, p) = (self.spec.batch, self.spec.prefill_len);
        if tokens.len() != b * p {
            bail!("prefill expects {}x{} tokens, got {}", b, p, tokens.len());
        }
        let tok = Literal::vec1(tokens).reshape(&[b as i64, p as i64])?;
        let mut args: Vec<&Literal> = self.weights.iter().collect();
        args.push(&tok);
        let mut outs = self.prefill.run_ref(&args)?;
        if outs.len() != 3 {
            bail!("prefill returned {} outputs, expected 3", outs.len());
        }
        let vc = outs.pop().unwrap();
        let kc = outs.pop().unwrap();
        let logits = outs.pop().unwrap().to_vec::<f32>()?;
        Ok((logits, kc, vc))
    }

    /// Run one decode step: write position `pos`, batched `tokens` (`[B]`).
    ///
    /// Returns (logits `[B*V]`, new k_cache, new v_cache).
    pub fn run_decode(
        &self,
        tokens: &[i32],
        pos: i32,
        k_cache: &Literal,
        v_cache: &Literal,
    ) -> Result<(Vec<f32>, Literal, Literal)> {
        if tokens.len() != self.spec.batch {
            bail!("decode expects batch {}, got {}", self.spec.batch, tokens.len());
        }
        if pos < 0 || pos as usize >= self.spec.max_seq {
            bail!("decode pos {} out of range [0, {})", pos, self.spec.max_seq);
        }
        let tok = Literal::vec1(tokens);
        let posl = Literal::scalar(pos);
        let mut args: Vec<&Literal> = self.weights.iter().collect();
        args.push(&tok);
        args.push(&posl);
        args.push(k_cache);
        args.push(v_cache);
        let mut outs = self.decode.run_ref(&args)?;
        if outs.len() != 3 {
            bail!("decode returned {} outputs, expected 3", outs.len());
        }
        let vc = outs.pop().unwrap();
        let kc = outs.pop().unwrap();
        let logits = outs.pop().unwrap().to_vec::<f32>()?;
        Ok((logits, kc, vc))
    }

    /// Zero-initialised KV cache literal (shape `[L,B,H,S,Dh]` f32).
    pub fn empty_cache(&self) -> Result<Literal> {
        let s = &self.spec;
        let n = s.n_layers * s.batch * s.n_heads * s.max_seq * s.head_dim;
        Literal::vec1(&vec![0f32; n])
            .reshape(&[
                s.n_layers as i64,
                s.batch as i64,
                s.n_heads as i64,
                s.max_seq as i64,
                s.head_dim as i64,
            ])
            .map_err(Into::into)
    }

    /// Greedy argmax over per-sequence logits.
    pub fn argmax_tokens(&self, logits: &[f32]) -> Vec<i32> {
        let v = self.spec.vocab;
        logits
            .chunks_exact(v)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i as i32)
                    .unwrap_or(0)
            })
            .collect()
    }
}
