//! Minimal fixed-width table printer for the bench harnesses (replaces
//! criterion's reporting: every bench regenerates one of the paper's tables
//! or figure series as aligned text rows).

/// A simple left-aligned text table.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Self {
        Self {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rowv(&mut self, cells: Vec<String>) {
        self.row(&cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::new();
            for i in 0..ncol {
                s.push_str(&format!("{:<w$}", cells[i], w = widths[i] + 2));
            }
            s.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with the given precision (bench-row helper).
pub fn f(x: f64, prec: usize) -> String {
    format!("{x:.prec$}")
}

/// Format a percentage delta like the paper's tables ("-23.13%").
pub fn pct(new: f64, base: f64) -> String {
    format!("{:+.2}%", (new - base) / base * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["a", "bbbb"]);
        t.row(&["1".into(), "2".into()]);
        t.row(&["333".into(), "4".into()]);
        let r = t.render();
        assert!(r.contains("== T =="));
        assert!(r.contains("a"));
        assert!(r.lines().count() >= 5);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(&["1".into()]);
    }

    #[test]
    fn pct_formats() {
        assert_eq!(pct(77.0, 100.0), "-23.00%");
        assert_eq!(pct(105.0, 100.0), "+5.00%");
    }
}
