//! Small self-contained utilities replacing crates absent from the offline
//! mirror (see the note at the top of Cargo.toml).

pub mod meta;
pub mod rng;
pub mod table;
