//! Line-oriented parser for `artifacts/meta.txt` (replaces serde_json for
//! the rust side; `meta.json` is kept for humans).
//!
//! Format:
//! ```text
//! key=value
//! ...
//! weight <name> <numel> <d0,d1,...>
//! ```

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// One weight-manifest entry, in jax tree-flatten (== jit parameter) order.
#[derive(Debug, Clone)]
pub struct WeightEntry {
    pub name: String,
    pub numel: usize,
    pub shape: Vec<i64>,
}

/// Parsed `meta.txt`.
#[derive(Debug, Clone)]
pub struct Meta {
    pub keys: HashMap<String, i64>,
    pub weights: Vec<WeightEntry>,
}

impl Meta {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let mut keys = HashMap::new();
        let mut weights = Vec::new();
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if let Some(rest) = line.strip_prefix("weight ") {
                let parts: Vec<&str> = rest.split_whitespace().collect();
                if parts.len() != 3 {
                    bail!("meta.txt line {}: bad weight entry {line:?}", ln + 1);
                }
                let numel: usize = parts[1].parse()
                    .with_context(|| format!("line {}: numel", ln + 1))?;
                let shape: Vec<i64> = parts[2]
                    .split(',')
                    .filter(|s| !s.is_empty())
                    .map(|s| s.parse::<i64>())
                    .collect::<std::result::Result<_, _>>()
                    .with_context(|| format!("line {}: shape", ln + 1))?;
                let prod: i64 = shape.iter().product::<i64>().max(1);
                if prod as usize != numel {
                    bail!("line {}: shape {:?} product != numel {}", ln + 1, shape, numel);
                }
                weights.push(WeightEntry { name: parts[0].to_string(), numel, shape });
            } else if let Some((k, v)) = line.split_once('=') {
                keys.insert(k.trim().to_string(),
                            v.trim().parse::<i64>()
                                .with_context(|| format!("line {}: value for {k}", ln + 1))?);
            } else {
                bail!("meta.txt line {}: unparseable {line:?}", ln + 1);
            }
        }
        Ok(Self { keys, weights })
    }

    pub fn get(&self, key: &str) -> Result<i64> {
        self.keys.get(key).copied()
            .with_context(|| format!("meta.txt missing key {key:?}"))
    }

    pub fn get_usize(&self, key: &str) -> Result<usize> {
        Ok(self.get(key)? as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
vocab=512
n_layers=4
weight embed 65536 512,128
weight final_norm 128 128
";

    #[test]
    fn parses_keys_and_weights() {
        let m = Meta::parse(SAMPLE).unwrap();
        assert_eq!(m.get("vocab").unwrap(), 512);
        assert_eq!(m.weights.len(), 2);
        assert_eq!(m.weights[0].name, "embed");
        assert_eq!(m.weights[0].shape, vec![512, 128]);
        assert_eq!(m.weights[1].numel, 128);
    }

    #[test]
    fn rejects_shape_numel_mismatch() {
        assert!(Meta::parse("weight w 10 3,4\n").is_err());
    }

    #[test]
    fn rejects_garbage_line() {
        assert!(Meta::parse("not a meta line\n").is_err());
    }

    #[test]
    fn missing_key_errors() {
        let m = Meta::parse(SAMPLE).unwrap();
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn skips_comments_and_blanks() {
        let m = Meta::parse("# c\n\nvocab=1\n").unwrap();
        assert_eq!(m.get("vocab").unwrap(), 1);
    }
}
