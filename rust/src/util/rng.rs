//! SplitMix64 PRNG — deterministic, seedable, dependency-free.
//!
//! Replaces the `rand` crate (absent from the offline mirror) for workload
//! generation and the seeded-sweep property tests.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes (workload gen).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [lo, hi) — panics if lo >= hi.
    pub fn gen_range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo < hi, "gen_range: empty range [{lo}, {hi})");
        lo + self.next_u64() % (hi - lo)
    }

    /// Uniform usize in [lo, hi).
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.gen_range(lo as u64, hi as u64) as usize
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with the given mean (inter-arrival times).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * self.next_f64().max(1e-12).ln()
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize(0, i + 1);
            xs.swap(i, j);
        }
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize(0, xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.gen_range(5, 17);
            assert!((5..17).contains(&x));
        }
    }

    #[test]
    fn normal_roughly_standard() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let m = (0..n).map(|_| r.exponential(3.0)).sum::<f64>() / n as f64;
        assert!((m - 3.0).abs() < 0.1, "mean {m}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(17);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
