//! High availability at cluster level (the paper's third contribution:
//! "ensures high availability at the cluster level").
//!
//! With model states resident in the SuperNode shared pool, a failed
//! device's replacement re-attaches to pool-resident weights/optimizer
//! states over the Unified Bus, instead of re-reading a checkpoint from
//! cold storage and replaying lost steps. This module models both recovery
//! paths and the failure-injection comparison the `ha_recovery` example
//! runs.

use crate::sim::HwConfig;
use crate::util::rng::Rng;

/// Checkpoint-based recovery parameters (the §7.1 baseline: "traditional
/// checkpoint-based mechanisms").
#[derive(Debug, Clone)]
pub struct CheckpointCfg {
    /// Cold-storage read bandwidth (GB/s) — object store / parallel fs.
    pub storage_gbps: f64,
    /// Steps between checkpoints.
    pub interval_steps: u64,
    /// Seconds per training step (to cost replay).
    pub step_time_s: f64,
    /// Fixed orchestration overhead (restart, process group rebuild) (s).
    pub restart_overhead_s: f64,
}

impl Default for CheckpointCfg {
    fn default() -> Self {
        Self { storage_gbps: 5.0, interval_steps: 500, step_time_s: 5.2, restart_overhead_s: 60.0 }
    }
}

/// One device's state footprint (bytes) that recovery must restore.
#[derive(Debug, Clone, Copy)]
pub struct StateFootprint {
    pub weights: u64,
    pub optimizer: u64,
}

impl StateFootprint {
    pub fn total(&self) -> u64 {
        self.weights + self.optimizer
    }
}

/// Recovery time via checkpoint reload + replay of lost steps.
///
/// `steps_since_ckpt` ∈ [0, interval): how far past the last checkpoint the
/// failure struck.
pub fn checkpoint_recovery_s(
    state: StateFootprint,
    cfg: &CheckpointCfg,
    steps_since_ckpt: u64,
) -> f64 {
    let reload = state.total() as f64 / (cfg.storage_gbps * 1e9);
    let replay = steps_since_ckpt as f64 * cfg.step_time_s;
    cfg.restart_overhead_s + reload + replay
}

/// Recovery time via pool-resident states: re-attach over the UB link.
/// No replay — states are current as of the last completed step.
pub fn pool_recovery_s(state: StateFootprint, hw: &HwConfig, restart_overhead_s: f64) -> f64 {
    restart_overhead_s + state.total() as f64 / (hw.r2d_gbps * 1e9)
}

/// Summary of a failure-injection campaign.
#[derive(Debug, Clone, Default)]
pub struct HaReport {
    pub failures: u64,
    pub mean_ckpt_recovery_s: f64,
    pub mean_pool_recovery_s: f64,
    pub total_lost_steps_ckpt: u64,
    pub total_lost_steps_pool: u64,
}

/// Inject `n_failures` uniformly over the checkpoint interval and compare.
pub fn failure_campaign(
    state: StateFootprint,
    cfg: &CheckpointCfg,
    hw: &HwConfig,
    n_failures: u64,
    seed: u64,
) -> HaReport {
    let mut rng = Rng::new(seed);
    let mut ckpt_sum = 0.0;
    let mut pool_sum = 0.0;
    let mut lost_ckpt = 0u64;
    for _ in 0..n_failures {
        let since = rng.gen_range(0, cfg.interval_steps);
        ckpt_sum += checkpoint_recovery_s(state, cfg, since);
        pool_sum += pool_recovery_s(state, hw, cfg.restart_overhead_s);
        lost_ckpt += since;
    }
    HaReport {
        failures: n_failures,
        mean_ckpt_recovery_s: ckpt_sum / n_failures.max(1) as f64,
        mean_pool_recovery_s: pool_sum / n_failures.max(1) as f64,
        total_lost_steps_ckpt: lost_ckpt,
        total_lost_steps_pool: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::GB;

    fn state() -> StateFootprint {
        StateFootprint { weights: 16 * GB, optimizer: 8 * GB }
    }

    #[test]
    fn pool_recovery_much_faster_than_checkpoint() {
        let hw = HwConfig::ascend910c_like();
        let cfg = CheckpointCfg::default();
        let ck = checkpoint_recovery_s(state(), &cfg, 250);
        let pl = pool_recovery_s(state(), &hw, cfg.restart_overhead_s);
        assert!(pl < ck / 5.0, "pool {pl} vs ckpt {ck}");
    }

    #[test]
    fn replay_dominates_when_far_from_checkpoint() {
        let cfg = CheckpointCfg::default();
        let near = checkpoint_recovery_s(state(), &cfg, 1);
        let far = checkpoint_recovery_s(state(), &cfg, 499);
        assert!(far > near + 2000.0);
    }

    #[test]
    fn campaign_loses_no_steps_with_pool() {
        let hw = HwConfig::ascend910c_like();
        let r = failure_campaign(state(), &CheckpointCfg::default(), &hw, 50, 42);
        assert_eq!(r.failures, 50);
        assert_eq!(r.total_lost_steps_pool, 0);
        assert!(r.total_lost_steps_ckpt > 0);
        assert!(r.mean_pool_recovery_s < r.mean_ckpt_recovery_s);
    }

    #[test]
    fn campaign_deterministic() {
        let hw = HwConfig::ascend910c_like();
        let a = failure_campaign(state(), &CheckpointCfg::default(), &hw, 10, 7);
        let b = failure_campaign(state(), &CheckpointCfg::default(), &hw, 10, 7);
        assert_eq!(a.total_lost_steps_ckpt, b.total_lost_steps_ckpt);
    }
}
