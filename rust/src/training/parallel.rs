//! Parallelism configuration (DP / TP / PP / EP) and the per-device shares
//! and communication volumes it implies — the rows of Tables 1 and 2.

use super::presets::ModelPreset;

/// A DP/TP/PP(/EP) layout plus batching.
#[derive(Debug, Clone)]
pub struct ParallelCfg {
    pub dp: usize,
    pub tp: usize,
    pub pp: usize,
    pub ep: usize,
    /// Per-device micro-batch size (sequences).
    pub micro_batch: usize,
    /// Global batch size (sequences).
    pub gbs: usize,
    pub seq_len: usize,
    /// Activation recomputation on the backward pass.
    pub recompute: bool,
    /// ZeRO-1: shard optimizer states across DP replicas. The Table 1 No.1
    /// baseline runs without it (full states per replica — the memory
    /// pressure that makes it defragment); tuned layouts enable it.
    pub zero1: bool,
    /// Fraction of layer weights homed in the remote pool under
    /// hierarchical memory ("offloading activations and a subset of
    /// parameters", §7.2.1). 0 for baselines.
    pub param_offload_frac: f64,
}

impl ParallelCfg {
    /// Table 1 No.1: DP8, batch 2, GBS 16, recompute on. No ZeRO — full
    /// optimizer replicas blow past HBM, hence the paper's observation
    /// that this config "frequently triggers memory defragmentation".
    pub fn llama_no1() -> Self {
        Self {
            dp: 8, tp: 1, pp: 1, ep: 1, micro_batch: 2, gbs: 16, seq_len: 4096,
            recompute: true, zero1: false, param_offload_frac: 0.0,
        }
    }

    /// Table 1 No.2: 2/2/2, batch 1, GBS 16, recompute off (the stable
    /// baseline all §7.2.1 comparisons use).
    pub fn llama_no2() -> Self {
        Self {
            dp: 2, tp: 2, pp: 2, ep: 1, micro_batch: 1, gbs: 16, seq_len: 4096,
            recompute: false, zero1: true, param_offload_frac: 0.0,
        }
    }

    /// §7.2.1 hierarchical-memory run: 8/1/1, batch 2, GBS 16; activations
    /// and half of the layer weights eligible for pool residency (the
    /// fraction is calibrated so the 33.6 GB/s point sits at baseline
    /// parity, matching §7.2.1's measured crossover).
    pub fn llama_hier() -> Self {
        Self {
            recompute: false, zero1: true, param_offload_frac: 0.5,
            ..Self::llama_no1()
        }
    }

    /// Table 2 baseline: 2/2/2/4, batch 1, GBS 16.
    pub fn dsv3_baseline() -> Self {
        Self {
            dp: 2, tp: 2, pp: 2, ep: 4, micro_batch: 1, gbs: 16, seq_len: 4096,
            recompute: false, zero1: true, param_offload_frac: 0.0,
        }
    }

    /// §7.2.2 hierarchical run: the paper uses 8/1/1/4; with our scaled
    /// preset the feasible DP-pure layout shards experts across all 8
    /// NPUs (EP=8). 70% of layer weights are pool-resident — calibrated
    /// so the 33.6 GB/s point sits near baseline parity (§7.2.2's "+2%"
    /// low end).
    pub fn dsv3_hier() -> Self {
        Self {
            dp: 8, tp: 1, pp: 1, ep: 8, micro_batch: 2, gbs: 16, seq_len: 4096,
            recompute: false, zero1: true, param_offload_frac: 0.7,
        }
    }

    pub fn n_devices(&self) -> usize {
        self.dp * self.tp * self.pp
    }

    /// Micro-batches each pipeline pumps per step.
    pub fn microbatches(&self) -> usize {
        (self.gbs / self.dp / self.micro_batch).max(1)
    }

    /// 1F1B pipeline bubble factor: (m + p - 1) / m.
    pub fn pipeline_bubble(&self) -> f64 {
        let m = self.microbatches() as f64;
        (m + self.pp as f64 - 1.0) / m
    }

    /// Layers resident on one device.
    pub fn layers_per_device(&self, model: &ModelPreset) -> usize {
        model.n_layers.div_ceil(self.pp)
    }

    /// Weight bytes resident per device.
    pub fn weight_bytes_per_device(&self, model: &ModelPreset) -> f64 {
        let shard = self.tp as f64
            * match &model.moe {
                // EP shards expert params; dense part shards by TP only.
                Some(m) => 1.0 / (1.0 - m.expert_param_frac + m.expert_param_frac / self.ep as f64),
                None => 1.0,
            };
        model.params * model.weight_bytes_per_param / self.pp as f64 / shard
    }

    /// Optimizer-state bytes per device; ZeRO-1 shards across DP replicas
    /// when enabled.
    pub fn opt_bytes_per_device(&self, model: &ModelPreset) -> f64 {
        let full = self.weight_bytes_per_device(model) / model.weight_bytes_per_param
            * model.opt_bytes_per_param;
        if self.zero1 {
            full / self.dp as f64
        } else {
            full
        }
    }

    /// Gradient bytes per device (bf16 grads, fp32 accumulation lives in
    /// the optimizer states — Megatron-style mixed precision).
    pub fn grad_bytes_per_device(&self, model: &ModelPreset) -> f64 {
        self.weight_bytes_per_device(model)
    }

    /// Peak activation bytes per device for one micro-batch in flight
    /// (recompute keeps only layer-boundary tensors, ~1/8 of the full set).
    pub fn act_bytes_per_device(&self, model: &ModelPreset) -> f64 {
        let per_layer = model.act_bytes_per_token_layer() * self.seq_len as f64
            * self.micro_batch as f64
            / self.tp as f64;
        let layers = self.layers_per_device(model) as f64;
        // PP stages hold activations for up to `pp` in-flight microbatches.
        let inflight = self.pp.min(self.microbatches()) as f64;
        let full = per_layer * layers * inflight;
        if self.recompute {
            full / 8.0
        } else {
            full
        }
    }

    /// Tokens processed per device per step.
    pub fn tokens_per_device(&self) -> f64 {
        (self.gbs as f64 / self.dp as f64) * self.seq_len as f64
    }

    /// TP collective bytes per device per step: 2 all-reduces per layer in
    /// forward + 2 in backward, ring volume 2(n-1)/n per all-reduce.
    pub fn tp_comm_bytes(&self, model: &ModelPreset) -> f64 {
        if self.tp == 1 {
            return 0.0;
        }
        let ring = 2.0 * (self.tp as f64 - 1.0) / self.tp as f64;
        4.0 * self.hidden_act_bytes(model) * ring / 2.0
            * self.layers_per_device(model) as f64
            * self.microbatches() as f64
    }

    /// EP all-to-all bytes per device per step (dispatch + combine, fwd +
    /// bwd), (n-1)/n of the boundary activation leaves the device.
    pub fn ep_comm_bytes(&self, model: &ModelPreset) -> f64 {
        if self.ep == 1 || model.moe.is_none() {
            return 0.0;
        }
        let frac = (self.ep as f64 - 1.0) / self.ep as f64;
        4.0 * self.hidden_act_bytes(model) * frac
            * self.layers_per_device(model) as f64
            * self.microbatches() as f64
    }

    /// PP p2p bytes per device per step.
    pub fn pp_comm_bytes(&self, model: &ModelPreset) -> f64 {
        if self.pp == 1 {
            return 0.0;
        }
        2.0 * self.hidden_act_bytes(model) * self.microbatches() as f64
    }

    /// DP gradient all-reduce bytes per device per step (ring: 2(n-1)/n).
    pub fn dp_comm_bytes(&self, model: &ModelPreset) -> f64 {
        if self.dp == 1 {
            return 0.0;
        }
        let grads = self.grad_bytes_per_device(model);
        2.0 * grads * (self.dp as f64 - 1.0) / self.dp as f64
    }

    /// One microbatch's boundary activation (bf16 s·b·h).
    fn hidden_act_bytes(&self, model: &ModelPreset) -> f64 {
        2.0 * self.seq_len as f64 * self.micro_batch as f64 * model.hidden as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn device_counts() {
        assert_eq!(ParallelCfg::llama_no1().n_devices(), 8);
        assert_eq!(ParallelCfg::llama_no2().n_devices(), 8);
        assert_eq!(ParallelCfg::dsv3_baseline().n_devices(), 8);
    }

    #[test]
    fn dp8_holds_full_replica() {
        let m = ModelPreset::llama8b();
        let c = ParallelCfg::llama_no1();
        // Full 16 GB of weights per device; No.1 runs without ZeRO, so the
        // full 64 GB Adam state sits on every replica (the pressure story).
        assert!((c.weight_bytes_per_device(&m) - 16.06e9).abs() < 0.2e9);
        assert!((c.opt_bytes_per_device(&m) - 64.24e9).abs() < 0.7e9);
        // ZeRO-1 (the hierarchical layout) shards it 8x.
        let z = ParallelCfg::llama_hier();
        assert!((z.opt_bytes_per_device(&m) - 8.03e9).abs() < 0.1e9);
    }

    #[test]
    fn tp_pp_shard_weights() {
        let m = ModelPreset::llama8b();
        let c = ParallelCfg::llama_no2();
        let w = c.weight_bytes_per_device(&m);
        assert!((w - 16.06e9 / 4.0).abs() < 0.2e9, "w={w}");
    }

    #[test]
    fn recompute_cuts_activation_memory() {
        let m = ModelPreset::llama8b();
        let with = ParallelCfg::llama_no1();
        let without = ParallelCfg { recompute: false, ..ParallelCfg::llama_no1() };
        assert!(with.act_bytes_per_device(&m) < without.act_bytes_per_device(&m) / 4.0);
    }

    #[test]
    fn comm_volumes_zero_when_unsharded() {
        let m = ModelPreset::llama8b();
        let c = ParallelCfg::llama_hier();
        assert_eq!(c.tp_comm_bytes(&m), 0.0);
        assert_eq!(c.pp_comm_bytes(&m), 0.0);
        assert!(c.dp_comm_bytes(&m) > 0.0);
    }

    #[test]
    fn pipeline_bubble_shrinks_with_more_microbatches() {
        let few = ParallelCfg { gbs: 4, ..ParallelCfg::llama_no2() };
        let many = ParallelCfg { gbs: 64, ..ParallelCfg::llama_no2() };
        assert!(few.pipeline_bubble() > many.pipeline_bubble());
        assert_eq!(ParallelCfg::llama_hier().pipeline_bubble(), 1.0);
    }

    #[test]
    fn ep_shards_dsv3_weights() {
        let m = ModelPreset::deepseek_v3_like();
        let base = ParallelCfg::dsv3_baseline();
        let w = base.weight_bytes_per_device(&m);
        // 671B bf16 = 1342 GB total; pp2·tp2·ep4 on experts -> far smaller.
        assert!(w < 250e9, "w={w}");
    }
}
