//! Training substrate (§5.1, §7.2): model presets, DP/TP/PP/EP parallelism
//! cost model, per-device step-graph generation, and baseline vs
//! hierarchical step-time estimation (Tables 1–2, Fig. 6).

mod graph_gen;
mod parallel;
mod presets;
mod step;

pub use graph_gen::{build_step_graph, StepGraph};
pub use parallel::ParallelCfg;
pub use presets::{ModelPreset, MoeShape};
pub use step::{
    baseline_demand_bytes, baseline_step, hierarchical_step, hierarchical_step_with,
    StepBreakdown, StepOptions,
};
