//! Build the per-device training-step computation graph for a (pp = 1)
//! layout — the hierarchical-memory configurations of §7.2 (8/1/1 and
//! 8/1/1/4).
//!
//! Per layer: fwd (produces the layer activation), bwd (consumes it),
//! optimizer update (consumes the layer's optimizer state). Optimizer
//! states are **remote-home** graph inputs — the paper's §5.1 design keeps
//! them in the pool between iterations, prefetches them back under the
//! backward pass, and stores them out again after the update. Both edges
//! of that cycle are modeled here: each state gets an explicit `Prefetch`
//! (the reload for *this* step's update) and a `Store` (the writeback the
//! *next* step's prefetch will read). Earlier revisions emitted only the
//! `Store` — a sim shortcut that made the graph unverifiable (a release
//! with no device residency), so `Compiler::verify(true)` could not be
//! enabled on training compiles. Activations are device-home; the
//! prefetch-insertion pass decides which ones to offload.

use crate::graph::{Graph, GraphBuilder, OpId, Tier};

use super::parallel::ParallelCfg;
use super::presets::ModelPreset;

/// Handle to the interesting pieces of the generated graph.
pub struct StepGraph {
    pub graph: Graph,
    pub act_tensors: Vec<usize>,
    pub opt_tensors: Vec<usize>,
    pub fwd_ops: Vec<OpId>,
    pub bwd_ops: Vec<OpId>,
    pub update_ops: Vec<OpId>,
}

/// Generate the training-step graph for one device. Requires `pp == 1`
/// (the paper's hierarchical configs; pipelined baselines are costed
/// analytically by [`super::baseline_step`]).
pub fn build_step_graph(model: &ModelPreset, par: &ParallelCfg) -> StepGraph {
    assert_eq!(par.pp, 1, "graph generation models pp=1 layouts");
    let layers = model.n_layers;
    let tokens = par.tokens_per_device();

    let flops_fwd_layer = model.fwd_flops_per_token_layer() * tokens / par.tp as f64;
    let flops_bwd_layer = 2.0 * flops_fwd_layer;
    let act_bytes_layer =
        (model.act_bytes_per_token_layer() * tokens / par.tp as f64) as u64;
    let opt_bytes_layer =
        (par.opt_bytes_per_device(model) / layers as f64) as u64;
    // Update reads grads + states, writes weights + states: cheap flops,
    // heavy HBM traffic.
    let update_bytes = opt_bytes_layer + (par.weight_bytes_per_device(model) / layers as f64) as u64;

    // Pool-resident slice of each layer's weights ("subset of parameters
    // offloaded to remote memory", §7.2.1). Prefetched before first use and
    // released after the backward pass by the standard planner machinery.
    let w_remote_layer = (par.weight_bytes_per_device(model) / layers as f64
        * par.param_offload_frac) as u64;

    let mut b = GraphBuilder::new();
    let mut acts = Vec::with_capacity(layers);
    let mut opts = Vec::with_capacity(layers);
    let mut weights = Vec::with_capacity(layers);
    let mut fwd_ops = Vec::with_capacity(layers);
    let mut bwd_ops = Vec::with_capacity(layers);
    let mut update_ops = Vec::with_capacity(layers);

    // Forward chain.
    let mut prev_act = None;
    for l in 0..layers {
        let act = b.tensor(&format!("act.{l}"), act_bytes_layer, Tier::Device);
        let mut inputs = prev_act.map(|t| vec![t]).unwrap_or_default();
        if w_remote_layer > 0 {
            let w = b.tensor(&format!("w.{l}"), w_remote_layer, Tier::Remote);
            inputs.push(w);
            weights.push(w);
        }
        let f = b.compute(&format!("fwd.{l}"), flops_fwd_layer, act_bytes_layer, inputs, vec![act]);
        fwd_ops.push(f);
        acts.push(act);
        prev_act = Some(act);
    }

    // Optimizer states: remote-home inputs (pool-resident between steps).
    for l in 0..layers {
        opts.push(b.tensor(&format!("opt.{l}"), opt_bytes_layer, Tier::Remote));
    }

    // Backward chain (reverse order), each consuming its activation.
    let mut prev_bwd: Option<OpId> = None;
    let mut grads = Vec::with_capacity(layers);
    for l in (0..layers).rev() {
        let grad = b.tensor(&format!("grad.{l}"), 0, Tier::Device);
        let mut inputs = vec![acts[l]];
        if let Some(&w) = weights.get(l) {
            inputs.push(w); // weight reuse in backward
        }
        let bw = b.compute(
            &format!("bwd.{l}"),
            flops_bwd_layer,
            act_bytes_layer,
            inputs,
            vec![grad],
        );
        if let Some(p) = prev_bwd {
            b.dep(bw, p);
        } else if let Some(&last_fwd) = fwd_ops.last() {
            b.dep(bw, last_fwd);
        }
        prev_bwd = Some(bw);
        bwd_ops.push(bw);
        grads.push(grad);
    }
    bwd_ops.reverse();
    grads.reverse();

    // DP gradient all-reduce, bucketed per layer so each bucket launches
    // as soon as its backward completes and overlaps the remaining
    // backward compute on the network stream (standard gradient bucketing,
    // here simply expressed as graph structure).
    let dp_bytes_layer = (par.dp_comm_bytes(model) / layers as f64) as u64;

    // Per-layer optimizer update, consuming the (prefetched) state and the
    // all-reduced gradient, then storing the state back to the pool.
    // Emitted in BACKWARD order (layer L-1 first): gradient buckets become
    // ready in that order, so the network stream starts collectives as
    // soon as each backward completes instead of blocking on layer 0.
    for l in (0..layers).rev() {
        let mut upd_deps = vec![opts[l], grads[l]];
        let ar = if dp_bytes_layer > 0 {
            let ar = b.collective(&format!("allreduce.grad.{l}"), dp_bytes_layer, vec![grads[l]]);
            b.dep(ar, bwd_ops[l]);
            Some(ar)
        } else {
            None
        };
        // Reload edge: the state lives in the pool between steps; this
        // step's update reads it only after the prefetch completes. The
        // exec-order pass places the transfer under the backward compute.
        let pf = b.prefetch(&format!("prefetch.opt.{l}"), opts[l]);
        let upd = b.compute(
            &format!("update.{l}"),
            1e6, // negligible flops; HBM-bound
            update_bytes,
            std::mem::take(&mut upd_deps),
            vec![],
        );
        b.dep(upd, pf);
        if let Some(ar) = ar {
            b.dep(upd, ar);
        }
        // Writeback edge: the next step's prefetch reads this Store.
        let st = b.store(&format!("store.opt.{l}"), opts[l]);
        b.dep(st, upd);
        update_ops.push(upd);
    }
    update_ops.reverse(); // restore layer-index order for callers

    StepGraph { graph: b.build(), act_tensors: acts, opt_tensors: opts, fwd_ops, bwd_ops, update_ops }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama_hier_graph_shape() {
        let m = ModelPreset::llama8b();
        let p = ParallelCfg::llama_hier();
        let sg = build_step_graph(&m, &p);
        assert_eq!(sg.fwd_ops.len(), 32);
        assert_eq!(sg.bwd_ops.len(), 32);
        assert_eq!(sg.update_ops.len(), 32);
        assert!(sg.graph.validate().is_ok());
    }

    #[test]
    fn bwd_depends_on_matching_act() {
        let m = ModelPreset::llama8b();
        let p = ParallelCfg::llama_hier();
        let sg = build_step_graph(&m, &p);
        for l in 0..32 {
            let bw = sg.graph.op(sg.bwd_ops[l]);
            assert!(bw.inputs.contains(&sg.act_tensors[l]), "layer {l}");
        }
    }

    #[test]
    fn opt_states_are_remote_home() {
        let m = ModelPreset::llama8b();
        let p = ParallelCfg::llama_hier();
        let sg = build_step_graph(&m, &p);
        for &t in &sg.opt_tensors {
            assert_eq!(sg.graph.tensor(t).home, Tier::Remote);
        }
    }

    #[test]
    fn updates_come_after_backward_in_topo() {
        let m = ModelPreset::llama8b();
        let p = ParallelCfg::llama_hier();
        let sg = build_step_graph(&m, &p);
        let order = sg.graph.topo_order().unwrap();
        let pos = |o: OpId| order.iter().position(|&x| x == o).unwrap();
        let last_bwd = sg.bwd_ops.iter().map(|&o| pos(o)).max().unwrap();
        for &u in &sg.update_ops {
            assert!(pos(u) > last_bwd);
        }
    }

    #[test]
    #[should_panic(expected = "pp=1")]
    fn rejects_pipelined_layouts() {
        let m = ModelPreset::llama8b();
        let p = ParallelCfg::llama_no2();
        build_step_graph(&m, &p);
    }

    #[test]
    fn opt_state_stores_have_matching_reload() {
        // The headline bugfix: every optimizer-state Store is paired with
        // the reload Prefetch that puts the state on the device first —
        // without it the IR verifier (rightly) rejects the graph as
        // releasing residency it never had.
        let m = ModelPreset::llama8b();
        let p = ParallelCfg::llama_hier();
        let sg = build_step_graph(&m, &p);
        for &t in &sg.opt_tensors {
            let prefetches = sg
                .graph
                .ops
                .iter()
                .filter(|o| matches!(o.kind, crate::graph::OpKind::Prefetch { tensor, .. } if tensor == t))
                .count();
            let stores = sg
                .graph
                .ops
                .iter()
                .filter(|o| matches!(o.kind, crate::graph::OpKind::Store { tensor, .. } if tensor == t))
                .count();
            assert_eq!(prefetches, 1, "opt state {t} missing its reload");
            assert_eq!(stores, 1, "opt state {t} missing its writeback");
        }
    }

    #[test]
    fn generated_graph_passes_ir_verification() {
        // `verify(true)` on a raw training compile — impossible before the
        // reload edge was modeled.
        use crate::passes::Compiler;
        use crate::sim::HwConfig;
        let m = ModelPreset::llama8b();
        let p = ParallelCfg::llama_hier();
        let mut sg = build_step_graph(&m, &p);
        let report = Compiler::new(HwConfig::ascend910c_like())
            .verify(true)
            .compile(&mut sg.graph)
            .expect("training graph must verify end to end");
        assert!(sg.graph.is_valid_order(&report.order));
    }
}
