//! Training step-time estimation: analytic baseline (any DP/TP/PP/EP, the
//! Tables 1–2 configurations) and graph-driven hierarchical execution
//! (compile pipeline + simulator, the Fig. 6 curves).
//!
//! Hierarchical steps compile with `verify(true)` — the IR verifier runs
//! between every stage — and the full decision pipeline: capacity-aware
//! transfer elision (reserving the fixed weight/grad working set),
//! recompute-vs-offload when the layout trains with recomputation
//! ([`ParallelCfg::recompute`]), and SLO throttling when a step-time
//! target is set ([`StepOptions::step_slo_ms`]).

use crate::passes::{Compiler, ElideRedundantTransfers, OffloadPolicy};
use crate::sim::{simulate, HwConfig};

use super::graph_gen::build_step_graph;
use super::parallel::ParallelCfg;
use super::presets::ModelPreset;

/// Per-step time/memory breakdown (the Fig. 6 stacked bars).
#[derive(Debug, Clone, Default)]
pub struct StepBreakdown {
    pub compute_ms: f64,
    pub recompute_ms: f64,
    /// Collective communication (TP/EP/PP/DP), serial in the baseline.
    pub comm_ms: f64,
    /// D2H/H2D (pool) traffic that the schedule failed to hide.
    pub exposed_d2h_ms: f64,
    /// Pool traffic hidden under compute.
    pub overlapped_d2h_ms: f64,
    /// Memory-pressure stalls (defrag / runtime swapping).
    pub stall_ms: f64,
    pub total_ms: f64,
    /// Steady-state memory demand (bytes) before any offload.
    pub demand_bytes: f64,
    /// Peak device bytes after offload decisions.
    pub peak_bytes: f64,
}

/// Memory demand of a configuration without hierarchical memory.
pub fn baseline_demand_bytes(model: &ModelPreset, par: &ParallelCfg) -> f64 {
    par.weight_bytes_per_device(model)
        + par.grad_bytes_per_device(model)
        + par.opt_bytes_per_device(model)
        + par.act_bytes_per_device(model)
}

/// Analytic baseline step (native framework: no offload, no overlap).
pub fn baseline_step(model: &ModelPreset, par: &ParallelCfg, hw: &HwConfig) -> StepBreakdown {
    let tokens = par.tokens_per_device();
    let p_active = model.fwd_flops_per_token_layer() * model.n_layers as f64 / 2.0;
    // fwd 2P + bwd 4P per token, sharded over tp*pp, stretched by the
    // pipeline bubble.
    let flops = 6.0 * p_active * tokens / (par.tp as f64 * par.pp as f64);
    let compute_ms = flops / (hw.compute_tflops * 1e12) * 1e3 * par.pipeline_bubble();
    let recompute_ms = if par.recompute { compute_ms / 3.0 } else { 0.0 };

    let comm_bytes = par.tp_comm_bytes(model)
        + par.ep_comm_bytes(model)
        + par.pp_comm_bytes(model)
        + par.dp_comm_bytes(model);
    let comm_ms = comm_bytes / (hw.net_gbps * 1e9) * 1e3;

    // Memory pressure: near capacity the framework allocator defragments
    // (§7.2.1 "frequently triggers memory defragmentation"); beyond
    // capacity the runtime swaps reactively over the D2H link.
    let demand = baseline_demand_bytes(model, par);
    let cap = hw.device_capacity as f64;
    let mut stall_ms = 0.0;
    if demand > 0.9 * cap {
        let pressure = (demand - 0.9 * cap).min(0.1 * cap);
        // Compaction cost ~ moving the overflowing working set at HBM bw.
        stall_ms += 4.0 * pressure / (hw.hbm_gbps * 1e9) * 1e3;
    }
    if demand > cap {
        // Reactive swap of the overflow, twice per step, fully exposed.
        stall_ms += 2.0 * (demand - cap) / (hw.d2r_gbps * 1e9) * 1e3;
    }

    let total = compute_ms + recompute_ms + comm_ms + stall_ms;
    StepBreakdown {
        compute_ms,
        recompute_ms,
        comm_ms,
        stall_ms,
        total_ms: total,
        demand_bytes: demand,
        peak_bytes: demand.min(cap),
        ..Default::default()
    }
}

/// Options for the hierarchical-step compile pipeline (decision passes
/// layered over the default lifetime → insert → exec-order stages).
#[derive(Debug, Clone)]
pub struct StepOptions {
    /// Enable the recompute-vs-offload decision pass.
    pub recompute: bool,
    /// Capacity-aware transfer elision (reserves the fixed weight/grad
    /// working set before testing headroom). On by default.
    pub elide: bool,
    /// Step-time SLO (ms) fed to the SLO throttle; `None` = no throttling.
    pub step_slo_ms: Option<f64>,
    /// Fabric-contention slowdown assumed by the decision passes (≥ 1.0) —
    /// e.g. the `Fabric::slowdown` of sibling DP replicas sharing the
    /// SuperNode pool link.
    pub dma_contention: f64,
}

impl StepOptions {
    /// The preset a layout implies: recompute follows the parallel
    /// config's recompute flag, elision is on, no SLO, private link.
    pub fn for_par(par: &ParallelCfg) -> Self {
        Self { recompute: par.recompute, elide: true, step_slo_ms: None, dma_contention: 1.0 }
    }
}

/// Hierarchical-memory step: build the pp=1 step graph, run the
/// HyperOffload compile pipeline implied by `par` (see
/// [`StepOptions::for_par`]), simulate on `hw`.
pub fn hierarchical_step(model: &ModelPreset, par: &ParallelCfg, hw: &HwConfig) -> StepBreakdown {
    hierarchical_step_with(model, par, hw, &StepOptions::for_par(par))
}

/// [`hierarchical_step`] with an explicit pipeline configuration.
pub fn hierarchical_step_with(
    model: &ModelPreset,
    par: &ParallelCfg,
    hw: &HwConfig,
    opts: &StepOptions,
) -> StepBreakdown {
    let mut sg = build_step_graph(model, par);
    let policy = OffloadPolicy { min_bytes: 16 << 20, ..Default::default() };

    // Weights not homed in the pool stay resident; grads stay resident.
    let fixed = par.weight_bytes_per_device(model) * (1.0 - par.param_offload_frac)
        + par.grad_bytes_per_device(model);

    let mut compiler = Compiler::new(hw.clone())
        .policy(policy)
        .verify(true)
        .contention(opts.dma_contention);
    if opts.elide {
        compiler = compiler
            .elide_redundant_transfers_with(ElideRedundantTransfers::with_reserved(fixed as u64));
    }
    if opts.recompute {
        compiler = compiler.recompute_vs_offload();
    }
    if let Some(slo_ms) = opts.step_slo_ms {
        compiler = compiler.slo_us(slo_ms * 1e3).slo_throttle();
    }
    let report = compiler
        .compile(&mut sg.graph)
        .expect("hierarchical_step: generated step graph must compile and verify");
    let sim = simulate(&sg.graph, &report.order, hw);

    // EP all-to-all (MoE) is not in the generated graph; add serially like
    // the baseline (it is orthogonal to the offload machinery).
    let ep_ms = par.ep_comm_bytes(model) / (hw.net_gbps * 1e9) * 1e3;

    StepBreakdown {
        compute_ms: (sim.compute_busy_us - sim.recompute_us) / 1e3,
        recompute_ms: sim.recompute_us / 1e3,
        comm_ms: ep_ms,
        exposed_d2h_ms: sim.exposed_comm_us / 1e3,
        overlapped_d2h_ms: sim.overlapped_comm_us / 1e3,
        stall_ms: 0.0,
        total_ms: sim.makespan_us / 1e3 + ep_ms,
        demand_bytes: baseline_demand_bytes(model, par),
        peak_bytes: fixed + sim.peak_device_bytes as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hw() -> HwConfig {
        HwConfig::ascend910c_like()
    }

    #[test]
    fn table1_shape_no1_slower_than_no2() {
        let m = ModelPreset::llama8b();
        let no1 = baseline_step(&m, &ParallelCfg::llama_no1(), &hw());
        let no2 = baseline_step(&m, &ParallelCfg::llama_no2(), &hw());
        assert!(
            no1.total_ms > no2.total_ms * 1.2,
            "No.1 {} not clearly slower than No.2 {}",
            no1.total_ms,
            no2.total_ms
        );
        assert!(no1.recompute_ms > 0.0);
        assert_eq!(no2.recompute_ms, 0.0);
    }

    #[test]
    fn hierarchical_beats_baseline_at_high_bandwidth() {
        // Fig 6(a) right side: ample pool bandwidth -> 8/1/1 + offload is
        // faster than the 2/2/2 baseline.
        let m = ModelPreset::llama8b();
        let base = baseline_step(&m, &ParallelCfg::llama_no2(), &hw());
        let hier = hierarchical_step(&m, &ParallelCfg::llama_hier(), &hw().with_pool_bandwidth(70.0));
        assert!(
            hier.total_ms < base.total_ms,
            "hier {} !< base {}",
            hier.total_ms,
            base.total_ms
        );
    }

    #[test]
    fn exposure_shrinks_with_bandwidth() {
        // The Fig 6 mechanism: more pool bandwidth -> less exposed D2H.
        let m = ModelPreset::llama8b();
        let lo = hierarchical_step(&m, &ParallelCfg::llama_hier(), &hw().with_pool_bandwidth(20.0));
        let hi = hierarchical_step(&m, &ParallelCfg::llama_hier(), &hw().with_pool_bandwidth(70.0));
        assert!(
            lo.exposed_d2h_ms > hi.exposed_d2h_ms,
            "exposure did not shrink: {} vs {}",
            lo.exposed_d2h_ms,
            hi.exposed_d2h_ms
        );
        assert!(hi.total_ms <= lo.total_ms);
    }

    #[test]
    fn hierarchical_peak_fits_device() {
        // 8/1/1 demand is a large fraction of HBM; offload must reduce the
        // realised peak below the raw demand and under capacity.
        let m = ModelPreset::llama8b();
        let hier = hierarchical_step(&m, &ParallelCfg::llama_hier(), &hw());
        assert!(hier.demand_bytes > hw().device_capacity as f64 * 0.6);
        assert!(
            hier.peak_bytes < hier.demand_bytes,
            "offload did not reduce peak: {} vs demand {}",
            hier.peak_bytes,
            hier.demand_bytes
        );
        assert!(hier.peak_bytes < hw().device_capacity as f64);
    }

    #[test]
    fn dsv3_hierarchical_gains_are_moderate() {
        // Fig 6(b): higher compute density -> gains present but smaller in
        // relative terms; just assert both runs complete and hier >= parity
        // at high bandwidth.
        let m = ModelPreset::deepseek_v3_like();
        let base = baseline_step(&m, &ParallelCfg::dsv3_baseline(), &hw());
        let hier = hierarchical_step(&m, &ParallelCfg::dsv3_hier(), &hw().with_pool_bandwidth(70.0));
        assert!(hier.total_ms > 0.0 && base.total_ms > 0.0);
        assert!(hier.total_ms < base.total_ms * 1.1, "hier {} vs base {}", hier.total_ms, base.total_ms);
    }
}
