//! Model presets for the training evaluation (§7.2): LLaMA-8B and a
//! DeepSeek-V3-like MoE, described by the quantities the cost model needs.

/// Mixture-of-experts shape (DeepSeek-V3-like).
#[derive(Debug, Clone)]
pub struct MoeShape {
    pub n_experts: usize,
    /// Experts active per token (top-k).
    pub active_experts: usize,
    /// Fraction of a layer's parameters that are expert FFN weights.
    pub expert_param_frac: f64,
}

/// A transformer described at cost-model granularity.
#[derive(Debug, Clone)]
pub struct ModelPreset {
    pub name: &'static str,
    pub n_layers: usize,
    pub hidden: usize,
    pub ff: usize,
    pub vocab: usize,
    /// Total parameter count.
    pub params: f64,
    /// Bytes per parameter for weights (bf16 = 2).
    pub weight_bytes_per_param: f64,
    /// Optimizer state bytes per parameter (Adam fp32 m + v = 8).
    pub opt_bytes_per_param: f64,
    /// Activation bytes per token per layer = `act_coeff` × hidden.
    /// ~32 for a vanilla transformer; much lower under MLA/NSA compression.
    pub act_coeff: f64,
    pub moe: Option<MoeShape>,
}

impl ModelPreset {
    /// LLaMA-3-8B (§7.2.1 / Table 1).
    pub fn llama8b() -> Self {
        Self {
            name: "LLaMA-8B",
            n_layers: 32,
            hidden: 4096,
            ff: 14336,
            vocab: 128_256,
            params: 8.03e9,
            weight_bytes_per_param: 2.0,
            opt_bytes_per_param: 8.0,
            act_coeff: 32.0,
            moe: None,
        }
    }

    /// DeepSeek-V3-like MoE (§7.2.2 / Table 2): 61 layers, MoE with 1/32 of
    /// expert parameters active per token, experts sharded by EP.
    ///
    /// **Scaled substitution** (DESIGN.md §2): the real 671B model cannot
    /// exist on one 8-NPU 64 GB slice under any layout; we keep the layer
    /// count, MoE sparsity ratio and arithmetic-intensity profile but scale
    /// total parameters to 96B so the baseline layout is feasible — the
    /// paper's Table 2 config then exercises the same code paths. The
    /// `act_coeff` of 8 reflects MLA + NSA activation compression.
    pub fn deepseek_v3_like() -> Self {
        Self {
            name: "DeepSeek-V3",
            n_layers: 61,
            hidden: 7168,
            ff: 18432,
            vocab: 129_280,
            params: 96e9,
            weight_bytes_per_param: 2.0,
            opt_bytes_per_param: 8.0,
            act_coeff: 8.0,
            moe: Some(MoeShape { n_experts: 256, active_experts: 8, expert_param_frac: 0.97 }),
        }
    }

    /// Parameters per layer (uniform share; embeddings folded in).
    pub fn params_per_layer(&self) -> f64 {
        self.params / self.n_layers as f64
    }

    /// Parameters *active* per token per layer (MoE activates a subset).
    pub fn active_params_per_layer(&self) -> f64 {
        match &self.moe {
            None => self.params_per_layer(),
            Some(m) => {
                let layer = self.params_per_layer();
                let expert = layer * m.expert_param_frac;
                let dense = layer - expert;
                dense + expert * (m.active_experts as f64 / m.n_experts as f64)
            }
        }
    }

    /// Forward FLOPs per token per layer ≈ 2 × active params per layer.
    pub fn fwd_flops_per_token_layer(&self) -> f64 {
        2.0 * self.active_params_per_layer()
    }

    /// Activation bytes per token per layer (bf16; ~34·h for a vanilla
    /// transformer, lower under MLA/NSA — see `act_coeff`).
    pub fn act_bytes_per_token_layer(&self) -> f64 {
        self.act_coeff * self.hidden as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama8b_sizes() {
        let m = ModelPreset::llama8b();
        assert_eq!(m.n_layers, 32);
        // Weights ~16 GB bf16.
        let wb = m.params * m.weight_bytes_per_param;
        assert!((wb - 16.06e9).abs() < 0.2e9);
        // Dense: active == total per layer.
        assert_eq!(m.active_params_per_layer(), m.params_per_layer());
    }

    #[test]
    fn dsv3_active_params_much_smaller_than_total() {
        let m = ModelPreset::deepseek_v3_like();
        let active_total = m.active_params_per_layer() * m.n_layers as f64;
        // MoE sparsity: ~6% of parameters active per token (0.03 dense +
        // 0.97/32 expert share), matching DSv3's 37B/671B ratio.
        assert!(active_total < 0.07 * m.params, "active {active_total}");
        assert!(active_total > 0.045 * m.params, "active {active_total}");
    }

    #[test]
    fn flops_scale_with_active_params() {
        let m = ModelPreset::deepseek_v3_like();
        assert!(m.fwd_flops_per_token_layer() < 2.0 * m.params_per_layer());
        let d = ModelPreset::llama8b();
        assert_eq!(d.fwd_flops_per_token_layer(), 2.0 * d.params_per_layer());
    }
}
