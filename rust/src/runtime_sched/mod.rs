//! The reactive runtime baseline (§3.1) — what HyperOffload replaces.
//!
//! Runtime-driven systems see memory pressure, not the graph: transfers are
//! triggered reactively (on demand, or a fixed lookahead ahead of the
//! consumer), and every runtime intervention costs a CPU control-path
//! detour that *interrupts the device pipeline* (inspect state, issue DMA,
//! synchronise). Periodically the runtime also performs memory compaction /
//! system-level management (the 6.7 s component of the paper's 15 s
//! motivation measurement).
//!
//! Implemented as a graph transformation: the same workload graph gets
//! `Prefetch` ops wired the way a runtime would fire them, plus
//! compute-stream stall ops for the control overhead — then the shared
//! [`crate::sim`] engine measures the result, so baseline and HyperOffload
//! numbers come from identical machinery.
//!
//! Under the session API the baseline is *just another pipeline
//! configuration*: [`ReactivePass`] implements
//! [`Pass`](crate::passes::Pass), so
//! `Compiler::empty(hw).pass(ReactivePass::new(cfg))` compiles a workload
//! the way the reactive runtime would execute it.

use crate::graph::{CycleError, Graph, OpId, OpKind, TensorId, Tier};
use crate::passes::{AnalysisCache, CompileError, Compiler, Pass, PassCtx, PassReport};
use crate::sim::{HwConfig, SimResult};

/// How the runtime decides when to move data.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ReactiveMode {
    /// Transfer starts only when the consumer is reached (fully exposed).
    OnDemand,
    /// Runtime looks `lookahead` ops ahead and fires the transfer then —
    /// partial overlap, but every firing still pays the control path.
    Prefetch { lookahead: usize },
}

/// Reactive-runtime configuration.
#[derive(Debug, Clone)]
pub struct ReactiveConfig {
    pub mode: ReactiveMode,
    /// Insert a compaction/management stall after every N transfers
    /// (0 = never). Models §3.1's "memory compaction and system-level
    /// management" component.
    pub compaction_every: usize,
    /// Duration of one compaction stall (us).
    pub compaction_us: f64,
}

impl Default for ReactiveConfig {
    fn default() -> Self {
        Self { mode: ReactiveMode::OnDemand, compaction_every: 0, compaction_us: 0.0 }
    }
}

/// A compute-stream stall of fixed duration (the device sits idle while the
/// CPU walks the control path). Encoded as a zero-byte compute op whose
/// flops are back-computed from the duration.
fn stall_flops(us: f64, hw: &HwConfig) -> f64 {
    us * hw.compute_tflops * 1e6
}

/// Transform `graph` into its reactive-runtime execution: for every
/// remote-home tensor, wire a `Prefetch` the way the runtime would fire it,
/// plus the control-path stalls. Returns the transformed graph **and the
/// dispatch order that realises the runtime's firing points** — the stalls
/// and loads are interleaved into the device pipeline at the positions the
/// runtime would fire them (a plain topo sort would let them drift).
pub fn transform(graph: &Graph, cfg: &ReactiveConfig, hw: &HwConfig) -> (Graph, Vec<OpId>) {
    let mut g = graph.clone();
    let order = transform_into(&mut g, cfg, hw).expect("reactive transform: cyclic graph");
    (g, order)
}

/// In-place [`transform`]: rewrites `g` and returns the dispatch order.
/// This is the body [`ReactivePass`] drives inside a compile session.
fn transform_into(
    g: &mut Graph,
    cfg: &ReactiveConfig,
    hw: &HwConfig,
) -> Result<Vec<OpId>, CycleError> {
    let order = g.topo_order_detailed()?;
    // Compute ops in execution order (the "device pipeline").
    let compute_order: Vec<OpId> = order
        .iter()
        .copied()
        .filter(|&o| matches!(g.op(o).kind, OpKind::Compute { .. }))
        .collect();
    let non_compute: Vec<OpId> = order
        .iter()
        .copied()
        .filter(|&o| !matches!(g.op(o).kind, OpKind::Compute { .. }))
        .collect();

    // Remote tensors consumed by compute ops, keyed by their earliest
    // consumer (collected up front: the loop below mutates the graph).
    let pos_in_compute = |op: OpId| compute_order.iter().position(|&x| x == op);
    let mut targets: Vec<(TensorId, String, OpId, Vec<OpId>)> = Vec::new();
    for t in &g.tensors {
        if t.home != Tier::Remote {
            continue;
        }
        let users: Vec<OpId> = g
            .consumers_of(t.id)
            .iter()
            .copied()
            .filter(|&c| matches!(g.op(c).kind, OpKind::Compute { .. }))
            .collect();
        let Some(&u) = users
            .iter()
            .min_by_key(|&&c| pos_in_compute(c).unwrap_or(usize::MAX))
        else {
            continue;
        };
        targets.push((t.id, t.name.clone(), u, users));
    }
    targets.sort_by_key(|&(_, _, u, _)| pos_in_compute(u).unwrap_or(usize::MAX));

    // fire_at[j] = ops dispatched just before compute_order[j].
    let mut fire_at: Vec<Vec<OpId>> = vec![Vec::new(); compute_order.len() + 1];
    let mut transfers = 0usize;
    for (t, tname, u, users) in targets {
        let u_pos = pos_in_compute(u).unwrap_or(0);
        // Where does the runtime fire? OnDemand: at the consumer itself.
        // Prefetch{k}: k compute ops earlier.
        let fire_pos = match cfg.mode {
            ReactiveMode::OnDemand => u_pos,
            ReactiveMode::Prefetch { lookahead } => u_pos.saturating_sub(lookahead.max(1)),
        };

        // Control-path stall ON the compute stream at the firing point.
        let stall = g.add_op(
            format!("runtime.ctrl.{tname}"),
            OpKind::Compute { flops: stall_flops(hw.host_overhead_us, hw), bytes_accessed: 0 },
            vec![],
            vec![],
        );
        if fire_pos > 0 {
            g.add_control_dep(stall, compute_order[fire_pos - 1]);
        }
        let pf = g.add_op(
            format!("runtime.load.{tname}"),
            OpKind::prefetch(t),
            vec![t],
            vec![],
        );
        g.add_control_dep(pf, stall);
        // Every compute consumer waits on the load, not just the one that
        // fires it — the device cannot read bytes still in flight. `u` is
        // the earliest consumer, so the extra edges are forward edges and
        // the dispatch order assembled below stays valid (TransferSan:
        // residency::no_acquire).
        for &c in &users {
            g.add_control_dep(c, pf);
        }
        fire_at[fire_pos].push(stall);
        fire_at[fire_pos].push(pf);

        transfers += 1;
        if cfg.compaction_every > 0 && transfers % cfg.compaction_every == 0 {
            // Compaction bites when the allocation happens — at the consumer.
            let comp = g.add_op(
                format!("runtime.compact.{transfers}"),
                OpKind::Compute { flops: stall_flops(cfg.compaction_us, hw), bytes_accessed: 0 },
                vec![],
                vec![],
            );
            g.add_control_dep(comp, pf);
            g.add_control_dep(u, comp);
            fire_at[u_pos].push(comp);
        }
    }

    // Assemble the dispatch order: runtime ops at their firing points.
    let mut exec: Vec<OpId> = Vec::with_capacity(g.ops.len());
    exec.extend(&non_compute);
    for (j, &c) in compute_order.iter().enumerate() {
        exec.extend(fire_at[j].iter().copied());
        exec.push(c);
    }
    exec.extend(fire_at[compute_order.len()].iter().copied());
    debug_assert!(g.is_valid_order(&exec), "reactive dispatch order invalid");
    Ok(exec)
}

/// The reactive runtime as a compiler pass: under the session API the
/// paper's baseline is just another pipeline configuration —
/// `Compiler::empty(hw).pass(ReactivePass::new(cfg))`.
#[derive(Debug, Clone, Default)]
pub struct ReactivePass {
    pub cfg: ReactiveConfig,
}

impl ReactivePass {
    pub fn new(cfg: ReactiveConfig) -> Self {
        Self { cfg }
    }
}

impl Pass for ReactivePass {
    fn name(&self) -> &'static str {
        "reactive-runtime"
    }

    fn run(
        &mut self,
        g: &mut Graph,
        _cache: &mut AnalysisCache,
        ctx: &PassCtx,
    ) -> Result<PassReport, CompileError> {
        let before = g.ops.len();
        let order = transform_into(g, &self.cfg, &ctx.hw)?;
        let mut rep = PassReport::new(self.name());
        rep.diagnostics.push(crate::passes::Diagnostic::info(
            self.name(),
            format!("{} runtime ops (loads/stalls/compactions) wired", g.ops.len() - before),
        ));
        rep.order = Some(order);
        Ok(rep)
    }
}

/// Convenience: compile the reactive configuration and simulate with the
/// runtime's dispatch order.
pub fn simulate_reactive(graph: &Graph, cfg: &ReactiveConfig, hw: &HwConfig) -> SimResult {
    let mut g = graph.clone();
    let report = Compiler::empty(hw.clone())
        .pass(ReactivePass::new(cfg.clone()))
        .compile(&mut g)
        .expect("reactive transform: cyclic graph");
    crate::sim::simulate(&g, &report.order, hw)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::sim::simulate;

    fn hw() -> HwConfig {
        HwConfig::test_default().with_host_overhead(50.0)
    }

    /// 8 ops à 100us, each consuming a 50us-transfer remote weight.
    fn workload() -> Graph {
        GraphBuilder::chain_with_remote_weights(8, 100e6, 0, 50_000).0
    }

    #[test]
    fn on_demand_exposes_every_transfer() {
        let r = simulate_reactive(&workload(), &ReactiveConfig::default(), &hw());
        // 8 transfers à 50us fully exposed + 8 stalls à 50us on compute.
        assert!(r.exposed_comm_us > 350.0, "exposed {}", r.exposed_comm_us);
        assert!(r.makespan_us > 8.0 * 100.0 + 8.0 * 50.0, "makespan {}", r.makespan_us);
    }

    #[test]
    fn lookahead_prefetch_partially_overlaps() {
        let on_demand = simulate_reactive(&workload(), &ReactiveConfig::default(), &hw());
        let cfg = ReactiveConfig { mode: ReactiveMode::Prefetch { lookahead: 2 }, ..Default::default() };
        let pf = simulate_reactive(&workload(), &cfg, &hw());
        assert!(pf.makespan_us < on_demand.makespan_us, "{} !< {}", pf.makespan_us, on_demand.makespan_us);
        // But control stalls remain on the compute stream.
        assert!(pf.makespan_us > 8.0 * 100.0 + 7.0 * 50.0, "makespan {}", pf.makespan_us);
    }

    #[test]
    fn compaction_adds_bubbles() {
        let cfg = ReactiveConfig {
            mode: ReactiveMode::Prefetch { lookahead: 2 },
            compaction_every: 2,
            compaction_us: 200.0,
        };
        let without = simulate_reactive(
            &workload(),
            &ReactiveConfig { mode: ReactiveMode::Prefetch { lookahead: 2 }, ..Default::default() },
            &hw(),
        );
        let with = simulate_reactive(&workload(), &cfg, &hw());
        assert!(with.makespan_us > without.makespan_us + 700.0,
            "compaction too cheap: {} vs {}", with.makespan_us, without.makespan_us);
    }

    #[test]
    fn hyperoffload_beats_reactive_on_same_workload() {
        // The paper's core comparison (Fig. 3): compile-time scheduling vs
        // runtime-driven on the identical graph + hardware.
        let base = workload();
        let reactive = simulate_reactive(
            &base,
            &ReactiveConfig { mode: ReactiveMode::Prefetch { lookahead: 1 }, compaction_every: 3, compaction_us: 150.0 },
            &hw(),
        );
        let mut g = base.clone();
        let report = Compiler::new(hw()).compile(&mut g).unwrap();
        let ours = simulate(&g, &report.order, &hw());
        assert!(
            ours.makespan_us < reactive.makespan_us * 0.8,
            "HyperOffload {} not clearly faster than reactive {}",
            ours.makespan_us,
            reactive.makespan_us
        );
        // At most the pipeline-fill transfer is exposed (first weight has
        // no compute to hide under). Note the reactive baseline reports 0
        // *DMA* exposure — its slowdown is control-path bubbles on the
        // compute stream, exactly the paper's Fig. 3(b) story.
        let one_transfer = hw().r2d_us(50_000);
        assert!(ours.exposed_comm_us <= one_transfer + 1e-6);
    }

    #[test]
    fn transform_keeps_graph_acyclic_and_order_valid() {
        for lookahead in 1..5 {
            let cfg = ReactiveConfig { mode: ReactiveMode::Prefetch { lookahead }, ..Default::default() };
            let (g, order) = transform(&workload(), &cfg, &hw());
            assert!(g.validate().is_ok(), "lookahead {lookahead}");
            assert!(g.is_valid_order(&order), "lookahead {lookahead}");
        }
    }
}
