//! `ElideRedundantTransfers`: drop offload round trips that buy nothing.
//!
//! The insertion pass (§4.2.2) offloads any tensor whose idle window can
//! hide the transfer — it reasons about *time*, not about whether the
//! device actually needs the bytes back. On a machine with headroom, a
//! `Store` whose tensor is later re-`Prefetch`ed with no intervening
//! device-memory pressure is pure fabric traffic: the tensor could simply
//! have stayed resident. This pass detects such round trips and removes
//! both cache operators, collapsing the pair to plain (detach-free)
//! residency — measurably cutting device↔pool bytes without touching the
//! makespan.
//!
//! Enabled by opt-in (`Compiler::elide_redundant_transfers()` or
//! `.pass_before("exec-order", ElideRedundantTransfers::default())`); it
//! must run after insertion and before Algorithm 1 anchors the transfers.
//! This pass is the extensibility proof of the session API: it is built
//! entirely from `Pass` + `AnalysisCache` + `Graph::remove_ops`, with no
//! changes to the pipeline driver.

use std::collections::HashSet;

use crate::graph::{Graph, OpId, OpKind, TensorId, Tier};

use super::compiler::{AnalysisCache, CompileError, Diagnostic, Pass, PassCtx, PassReport};

/// Remove `Store`/`Prefetch` round trips whose tensor could have stayed
/// device-resident within the configured capacity headroom.
#[derive(Debug, Clone)]
pub struct ElideRedundantTransfers {
    /// Keep a round trip unless peak residency *without* it stays within
    /// `headroom` × the usable device capacity. Default 0.9: never trade
    /// the last 10% of HBM for saved fabric traffic.
    pub headroom: f64,
    /// Device bytes spoken for outside the compiled graph (resident
    /// weights, gradient buffers) — subtracted from capacity before the
    /// headroom test. The training preset feeds its fixed working set
    /// here so elision decisions are capacity-aware end to end.
    pub reserved_bytes: u64,
}

impl Default for ElideRedundantTransfers {
    fn default() -> Self {
        Self { headroom: 0.9, reserved_bytes: 0 }
    }
}

impl ElideRedundantTransfers {
    /// Elision with `reserved` bytes of device capacity considered already
    /// occupied outside the graph.
    pub fn with_reserved(reserved: u64) -> Self {
        Self { reserved_bytes: reserved, ..Default::default() }
    }
}

impl Pass for ElideRedundantTransfers {
    fn name(&self) -> &'static str {
        "elide-redundant-transfers"
    }

    fn run(
        &mut self,
        g: &mut Graph,
        cache: &mut AnalysisCache,
        ctx: &PassCtx,
    ) -> Result<PassReport, CompileError> {
        let mut rep = PassReport::new(self.name());
        let usable = ctx.hw.device_capacity.saturating_sub(self.reserved_bytes);
        let budget = (usable as f64 * self.headroom) as u64;
        let mut decided: HashSet<TensorId> = HashSet::new();
        let mut elided = 0usize;
        let mut saved_bytes = 0u64;

        // Greedy, one round trip at a time: op ids shift after each
        // removal, so candidates are re-discovered from the live graph.
        loop {
            let order = cache.topo_order(g)?;
            let mut pos = vec![usize::MAX; g.ops.len()];
            for (i, &o) in order.iter().enumerate() {
                pos[o] = i;
            }
            let mut candidate: Option<(TensorId, OpId, OpId)> = None;
            for t in &g.tensors {
                if t.home != Tier::Device || decided.contains(&t.id) {
                    continue;
                }
                let mut stores = Vec::new();
                let mut prefetches = Vec::new();
                let mut detaches = 0usize;
                for op in &g.ops {
                    match op.kind {
                        OpKind::Store { tensor, .. } if tensor == t.id => stores.push(op.id),
                        OpKind::Prefetch { tensor, .. } if tensor == t.id => prefetches.push(op.id),
                        OpKind::Detach { tensor } if tensor == t.id => detaches += 1,
                        _ => {}
                    }
                }
                // Exactly the inserted round-trip shape: one store, one
                // later prefetch, no detach.
                if detaches == 0
                    && stores.len() == 1
                    && prefetches.len() == 1
                    && pos[stores[0]] < pos[prefetches[0]]
                {
                    candidate = Some((t.id, stores[0], prefetches[0]));
                    break;
                }
            }
            let Some((t, st, pf)) = candidate else { break };
            decided.insert(t);

            // Pressure check on a trial copy: with the round trip removed,
            // the tensor stays resident across the window — the peak must
            // still fit the headroom budget.
            let mut trial = g.clone();
            trial.remove_ops(&[st, pf]);
            let trial_order = match trial.topo_order_detailed() {
                Ok(o) => o,
                Err(_) => continue,
            };
            let sim = crate::sim::simulate(&trial, &trial_order, &ctx.hw);
            if sim.peak_device_bytes <= budget {
                let bytes = g.tensor(t).bytes;
                let name = g.tensor(t).name.clone();
                g.remove_ops(&[st, pf]);
                elided += 1;
                saved_bytes += 2 * bytes;
                rep.diagnostics.push(Diagnostic::info(
                    self.name(),
                    format!(
                        "elided store/prefetch round trip for tensor '{name}' \
                         ({} bytes of fabric traffic)",
                        2 * bytes
                    ),
                ));
            }
        }

        rep.elided = elided;
        rep.diagnostics.push(Diagnostic::info(
            self.name(),
            format!("{elided} round trip(s) elided, {saved_bytes} device<->pool bytes saved"),
        ));
        Ok(rep)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::passes::Compiler;
    use crate::sim::{simulate, HwConfig};

    fn workload() -> Graph {
        // §5.1 miniature: 4 × 8 MB activations round-tripped through the
        // pool while the mid section computes.
        GraphBuilder::fwd_bwd_chain(4, 8 << 20, 10e9, 24, 1e9)
    }

    #[test]
    fn elides_round_trips_when_memory_is_ample() {
        let hw = HwConfig::test_default(); // 1 GiB device vs 32 MB of acts
        let mut base = workload();
        let rb = Compiler::new(hw.clone()).compile(&mut base).unwrap();
        let sb = simulate(&base, &rb.order, &hw);
        assert!(!rb.inserted.is_empty(), "fixture must offload something");

        let mut opt = workload();
        let ro = Compiler::new(hw.clone())
            .elide_redundant_transfers()
            .verify(true)
            .compile(&mut opt)
            .unwrap();
        let so = simulate(&opt, &ro.order, &hw);

        assert_eq!(ro.elided, rb.inserted.len(), "all round trips should elide");
        assert!(so.dma_bytes < sb.dma_bytes, "{} !< {}", so.dma_bytes, sb.dma_bytes);
        assert_eq!(so.dma_bytes, 0);
        assert!(
            so.makespan_us <= sb.makespan_us * 1.01,
            "elision slowed things down: {} vs {}",
            so.makespan_us,
            sb.makespan_us
        );
        assert!(opt.cache_ops().is_empty());
    }

    #[test]
    fn keeps_round_trips_under_memory_pressure() {
        // 24 MB device capacity vs 32 MB of activations: keeping them
        // resident would blow the 0.9 headroom, so nothing is elided.
        let hw = HwConfig::test_default().with_device_capacity(24 << 20);
        let mut g = workload();
        let r = Compiler::new(hw.clone())
            .elide_redundant_transfers()
            .compile(&mut g)
            .unwrap();
        assert!(!r.inserted.is_empty());
        assert_eq!(r.elided, 0, "elision under pressure");
        assert!(!g.cache_ops().is_empty());
    }

    #[test]
    fn remote_home_prefetches_are_never_elided() {
        // Weight-streaming graph: prefetches of remote-home tensors are
        // legalisation, not an optimisation — they must survive.
        let hw = HwConfig::test_default();
        let (mut g, _) = GraphBuilder::chain_with_remote_weights(8, 100e6, 0, 50_000);
        let r = Compiler::new(hw.clone())
            .elide_redundant_transfers()
            .verify(true)
            .compile(&mut g)
            .unwrap();
        assert_eq!(r.elided, 0);
        assert_eq!(g.cache_ops().len(), 8);
        let s = simulate(&g, &r.order, &hw);
        assert!(s.dma_bytes > 0);
    }
}
