//! Tier-placement decision pass: pick a *home tier* for each offloaded
//! round trip from its lifetime, and emit promotions ahead of reuse.
//!
//! The insertion pass always parks offloaded tensors in the shared pool
//! (tier 1). With a deeper [`TierTopology`](crate::sim::TierTopology)
//! installed, that wastes the stack: an activation idle for most of the
//! schedule can sit in DRAM/CXL/SSD and leave the pool's capacity (and
//! its fabric edge) to tenants that actually need the hot tier — the
//! paper's "graph-driven hierarchical" placement applied below the pool.
//!
//! For every single-Store/single-Prefetch round trip the pass asks, per
//! cold tier deepest-first: does the *deep* path — Store straight to the
//! cold tier, a `Promote` back up to the pool ahead of reuse, the
//! existing pool Prefetch — still hide inside the tensor's idle window
//! with [`hide_factor`](TierPlacement::hide_factor) headroom, and does
//! the tier have capacity for the bytes already routed there? The first
//! tier that passes wins:
//!
//! ```text
//! before:  Store(t → pool) ............................. Prefetch(t ← pool)
//! after:   Store(t → ssd) ......... Promote(t: ssd → pool) → Prefetch(t ← pool)
//!          deep(t) = evict(ssd) + promote(ssd → pool) + fetch(pool)
//!          commit when deep(t) ≤ hide_factor × window_compute(t)
//! ```
//!
//! The rewrite keeps the device-side schedule shape — the Prefetch still
//! reads the pool, so the reload hop the exec-order pass anchors is
//! unchanged — and the `Promote` rides the cold-DMA stream, invisible to
//! the device fabric. Control deps (`Promote` after the Store, the
//! Prefetch after the `Promote`) make the residency walk airtight:
//! verify_ir's `cold_at` tracking and TransferSan's `tier::cold_read`
//! lint both see the copy where each reader expects it.
//!
//! With no topology (or a degenerate two-tier one) the pass is a strict
//! no-op — the opt-in path that keeps two-tier compiles bit-identical.

use std::collections::HashMap;

use crate::graph::{Graph, OpId, OpKind, TensorId, Tier};

use super::compiler::{AnalysisCache, CompileError, Diagnostic, Pass, PassCtx, PassReport};

/// The tier-placement decision pass. Opt in with
/// [`Compiler::tier_placement`](super::Compiler::tier_placement); it runs
/// before exec-order so the promotions it emits get anchored like any
/// other cache op.
#[derive(Debug, Clone)]
pub struct TierPlacement {
    /// Fraction of the idle window's compute the deep round trip may
    /// consume. 0.5 leaves half the window as schedule slack; lower is
    /// more conservative (0.0 disables every rewrite).
    pub hide_factor: f64,
    /// Round trips below this size stay in the pool — per-hop latency
    /// dominates small transfers, and the pool bytes saved are noise.
    pub min_bytes: u64,
}

impl Default for TierPlacement {
    fn default() -> Self {
        Self { hide_factor: 0.5, min_bytes: 1 << 20 }
    }
}

impl Pass for TierPlacement {
    fn name(&self) -> &'static str {
        "tier-placement"
    }

    fn run(
        &mut self,
        g: &mut Graph,
        cache: &mut AnalysisCache,
        ctx: &PassCtx,
    ) -> Result<PassReport, CompileError> {
        let mut rep = PassReport::new(self.name());
        let chw = ctx.contended_hw();
        let Some(topo) = chw.tiers.clone() else {
            return Ok(rep);
        };
        if topo.cold_tiers().is_empty() || self.hide_factor <= 0.0 {
            return Ok(rep);
        }

        let order = cache.topo_order(g)?;
        let mut pos = vec![usize::MAX; g.ops.len()];
        for (i, &o) in order.iter().enumerate() {
            pos[o] = i;
        }
        let compute_us = |o: OpId| match g.op(o).kind {
            OpKind::Compute { flops, bytes_accessed } => chw.compute_us(flops, bytes_accessed),
            _ => 0.0,
        };
        // Compute prefix sums along the order: window compute in O(1).
        let mut pc = vec![0.0f64; order.len() + 1];
        for (i, &o) in order.iter().enumerate() {
            pc[i + 1] = pc[i] + compute_us(o);
        }

        // Per-tensor cache-op index; only untouched pool round trips
        // (exactly one Store and one Prefetch, both pool-homed, store
        // before prefetch) are candidates.
        let nt = g.tensors.len();
        let (mut stores, mut prefetches) = (vec![Vec::new(); nt], vec![Vec::new(); nt]);
        let mut promoted = vec![false; nt];
        for op in &g.ops {
            match op.kind {
                OpKind::Store { tensor, dst } => stores[tensor].push((op.id, dst)),
                OpKind::Prefetch { tensor, src } => prefetches[tensor].push((op.id, src)),
                OpKind::Promote { tensor, .. } => promoted[tensor] = true,
                _ => {}
            }
        }

        struct Candidate {
            tensor: TensorId,
            st: OpId,
            pf: OpId,
            bytes: u64,
            window_us: f64,
            /// Canonical position of the Store's latest dependency — where
            /// the Store *can* start, which is where exec-order parks it.
            /// The Store's own canonical position is meaningless here: the
            /// min-id tie-break drifts appended ops toward their consumers.
            st_anchor: usize,
            u_pos: usize,
        }
        let mut cands: Vec<Candidate> = Vec::new();
        for t in &g.tensors {
            if t.bytes < self.min_bytes || promoted[t.id] || t.alias_of.is_some() {
                continue;
            }
            if stores[t.id].len() != 1 || prefetches[t.id].len() != 1 {
                continue;
            }
            let (st, st_dst) = stores[t.id][0];
            let (pf, pf_src) = prefetches[t.id][0];
            if st_dst != Tier::Remote || pf_src != Tier::Remote || pos[st] >= pos[pf] {
                continue;
            }
            // The window that has to hide the deep path: store → first
            // real consumer after it (the prefetch's deadline).
            let Some(u_pos) = g
                .consumers_of(t.id)
                .iter()
                .filter(|&&c| !g.op(c).kind.is_cache_op() && pos[c] > pos[st])
                .map(|&c| pos[c])
                .min()
            else {
                continue;
            };
            let st_anchor = g.preds(st).iter().map(|&p| pos[p]).max().unwrap_or(0);
            let window_us = pc[u_pos] - pc[st_anchor + 1];
            cands.push(Candidate {
                tensor: t.id,
                st,
                pf,
                bytes: t.bytes,
                window_us,
                st_anchor,
                u_pos,
            });
        }
        // Biggest tensors first: each pool byte shed is worth the most,
        // and cold-tier capacity goes to the tensors that relieve the
        // pool hardest. Ties break on id for determinism.
        cands.sort_by(|a, b| b.bytes.cmp(&a.bytes).then(a.tensor.cmp(&b.tensor)));

        let mut routed: HashMap<Tier, u64> = HashMap::new();
        let mut per_tier: HashMap<Tier, usize> = HashMap::new();
        for c in cands {
            // Deepest tier first: the deepest level whose full path still
            // hides is the cheapest home the window can afford.
            let chosen = topo.cold_tiers().iter().rev().copied().find(|&tier| {
                let deep = chw.evict_us(tier, c.bytes)
                    + chw.promote_us(tier, Tier::Remote, c.bytes)
                    + chw.fetch_us(Tier::Remote, c.bytes);
                if deep > self.hide_factor * c.window_us {
                    return false;
                }
                let cap = chw.tier_capacity(tier).unwrap_or(u64::MAX);
                routed.get(&tier).copied().unwrap_or(0).saturating_add(c.bytes) <= cap
            });
            let Some(tier) = chosen else { continue };
            g.retarget_transfer_tier(c.st, tier);
            let pm = g.add_op(
                format!("promote.{}", g.tensor(c.tensor).name),
                OpKind::Promote { tensor: c.tensor, src: tier, dst: Tier::Remote },
                vec![c.tensor],
                vec![],
            );
            g.add_control_dep(pm, c.st);
            g.add_control_dep(c.pf, pm);
            // Promote *ahead of reuse*, not eagerly: anchored to the
            // latest op that still leaves 1/hide_factor × the up-path
            // time of compute before the consumer, the copy parks in the
            // cold tier for the bulk of its idle window. With no such
            // anchor the promote simply follows the store (still sound,
            // just colder for less of the window).
            let lead_us = (chw.promote_us(tier, Tier::Remote, c.bytes)
                + chw.fetch_us(Tier::Remote, c.bytes))
                / self.hide_factor;
            // Non-cache anchors only: exec-order refinement relocates
            // Store/Prefetch ops, so a cache-op anchor could drift and drag
            // the promote with it; compute ops keep their slots.
            let anchor = (c.st_anchor + 1..c.u_pos)
                .rev()
                .filter(|&p| !g.op(order[p]).kind.is_cache_op())
                .find(|&p| pc[c.u_pos] - pc[p + 1] >= lead_us)
                .map(|p| order[p]);
            if let Some(a) = anchor {
                g.add_control_dep(pm, a);
            }
            *routed.entry(tier).or_insert(0) += c.bytes;
            *per_tier.entry(tier).or_insert(0) += 1;
            rep.retiered += 1;
        }

        if rep.retiered > 0 {
            let mut parts: Vec<String> = topo
                .cold_tiers()
                .iter()
                .filter_map(|t| {
                    per_tier.get(t).map(|n| {
                        format!("{n} -> {t:?} ({} MiB)", routed[t] >> 20)
                    })
                })
                .collect();
            parts.sort();
            rep.diagnostics.push(Diagnostic::info(
                self.name(),
                format!(
                    "{} round trip(s) rehomed below the pool: {}",
                    rep.retiered,
                    parts.join(", ")
                ),
            ));
        }
        Ok(rep)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::passes::Compiler;
    use crate::sim::{simulate, HwConfig, TierTopology};

    fn hw() -> HwConfig {
        HwConfig::test_default()
    }

    /// The mod.rs pipeline fixture: long fwd ops producing big
    /// activations consumed in reverse by the bwd half — wide idle
    /// windows, so the default pipeline reliably inserts round trips.
    fn fixture() -> Graph {
        GraphBuilder::fwd_bwd_chain(4, 8 << 20, 10e9, 24, 1e9)
    }

    #[test]
    fn no_topology_means_bit_identical_to_the_default_pipeline() {
        let mut plain = fixture();
        let rp = Compiler::new(hw()).verify(true).compile(&mut plain).unwrap();
        let mut tiered = fixture();
        let rt = Compiler::new(hw()).tier_placement().verify(true).compile(&mut tiered).unwrap();
        assert_eq!(rt.retiered, 0);
        assert_eq!(rp.order, rt.order);
        assert_eq!(plain.ops.len(), tiered.ops.len());
        for (a, b) in plain.ops.iter().zip(&tiered.ops) {
            assert_eq!(a.kind, b.kind, "op {} diverged", a.id);
        }
        // Same for a mirrored two-tier topology: no cold tier, no rewrite.
        let hw2 = hw();
        let hw2 = hw2.clone().with_tiers(TierTopology::two_tier(&hw2));
        let mut two = fixture();
        let r2 = Compiler::new(hw2).tier_placement().verify(true).compile(&mut two).unwrap();
        assert_eq!(r2.retiered, 0);
        assert_eq!(rp.order, r2.order);
    }

    #[test]
    fn deep_stack_rehomes_round_trips_and_stays_clean() {
        let base = hw();
        let hw3 = base.clone().with_tiers(TierTopology::three_tier(&base));
        // Longer mid section than `fixture()`: on test_default hardware the
        // deep path for an 8 MiB block is ~41.9 ms (evict 16.8 + promote
        // 16.8 + fetch 8.4), so with hide_factor 0.5 the early activations
        // (windows 120/100 ms -> budgets 60/50 ms) rehome with wide margin
        // and the late ones (budgets 40/30 ms) robustly stay in the pool.
        let mut g = GraphBuilder::fwd_bwd_chain(4, 8 << 20, 10e9, 60, 1e9);
        let report = Compiler::new(hw3.clone())
            .tier_placement()
            .verify(true)
            .sanitize(true)
            .compile(&mut g)
            .unwrap();
        assert_eq!(report.retiered, 2, "expected exactly the two wide-window round trips");
        // Every rehomed trip: Store to Dram + a Promote back to the pool.
        let deep_stores = g
            .ops
            .iter()
            .filter(|o| matches!(o.kind, OpKind::Store { dst: Tier::Dram, .. }))
            .count();
        let promotes = g
            .ops
            .iter()
            .filter(|o| {
                matches!(
                    o.kind,
                    OpKind::Promote { src: Tier::Dram, dst: Tier::Remote, .. }
                )
            })
            .count();
        assert_eq!(deep_stores, report.retiered);
        assert_eq!(promotes, report.retiered);
        // The simulator sees the bytes park in DRAM and move back up.
        let sim = simulate(&g, &report.order, &hw3);
        let dram_peak = sim
            .tier_peaks
            .iter()
            .find(|(t, _)| *t == Tier::Dram)
            .map(|&(_, b)| b)
            .unwrap_or(0);
        assert!(dram_peak >= 8 << 20, "rehomed block never resident in DRAM");
        assert_eq!(sim.cold_dma_bytes, 2 * (8 << 20), "each rehomed trip promotes once");

        // Against the pool-only compile on the same deep hardware: the pool
        // is no worse off (the sim's copy accounting never releases a pool
        // copy, and the promote re-materialises one, so the *peak* can tie
        // — the byte-level relief shows up in the serving ledger, where
        // demotion really frees pool blocks) and the deep detour stays
        // hidden: makespan within schedule noise of the pool-only run.
        let mut pool_only = GraphBuilder::fwd_bwd_chain(4, 8 << 20, 10e9, 60, 1e9);
        let rp = Compiler::new(hw3.clone()).verify(true).compile(&mut pool_only).unwrap();
        let sp = simulate(&pool_only, &rp.order, &hw3);
        let pool_peak = |s: &crate::sim::SimResult| {
            s.tier_peaks
                .iter()
                .find(|(t, _)| *t == Tier::Remote)
                .map(|&(_, b)| b)
                .unwrap_or(0)
        };
        assert!(
            pool_peak(&sim) <= pool_peak(&sp),
            "pool peak regressed: {} vs {}",
            pool_peak(&sim),
            pool_peak(&sp)
        );
        assert!(
            sim.makespan_us <= sp.makespan_us * 1.05,
            "deep detour not hidden: {} vs {}",
            sim.makespan_us,
            sp.makespan_us
        );
    }

    #[test]
    fn zero_hide_factor_rewrites_nothing() {
        let base = hw();
        let hw3 = base.clone().with_tiers(TierTopology::three_tier(&base));
        let mut g = fixture();
        let report = Compiler::new(hw3)
            .pass_before("exec-order", TierPlacement { hide_factor: 0.0, min_bytes: 1 })
            .verify(true)
            .compile(&mut g)
            .unwrap();
        assert_eq!(report.retiered, 0);
        assert!(!g.ops.iter().any(|o| matches!(o.kind, OpKind::Promote { .. })));
    }
}
