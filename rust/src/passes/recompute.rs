//! `RecomputeVsOffload`: weigh regenerating a tensor against round-tripping
//! it through the pool — the first pass that *changes* an offload decision
//! instead of only placing transfers.
//!
//! The insertion pass (§4.2.2) decides "offload and prefetch" for every
//! profitable candidate. On a saturated device↔pool link that is not the
//! only option: a tensor whose producer's FLOPs are cheap relative to its
//! bytes can be *discarded* and replayed from still-resident inputs just
//! before its next use (SuperOffload's speculate-then-validate tradeoff,
//! dominant on superchips where compute outruns the offload fabric).
//!
//! ## Cost model
//!
//! For each inserted `Store`/`Prefetch` round trip over tensor `t`:
//!
//! * **exposed-transfer cost** — the round trip's wire time under the
//!   session's assumed fabric contention ([`PassCtx::contended_hw`]),
//!   minus the compute available inside `t`'s idle window (from the cached
//!   lifetimes) that could hide it, floored by the *global* DMA
//!   overcommit share: when ΣDMA > Σcompute the streams are the critical
//!   path and every round trip is at least proportionally exposed.
//! * **recompute cost** — Σ `compute_us(flops, bytes)` over the producer
//!   subgraph that regenerates `t` from still-resident tensors
//!   ([`Graph::recompute_plan`]); tensors whose inputs have left the
//!   device recursively extend the plan until `max_clone_ops` caps it.
//!
//! Recompute is *speculated* when its cost is within `margin` × the
//! exposed-transfer estimate, then **validated** by re-simulation:
//! decisions that fail to strictly improve makespan or peak residency —
//! or that regress either — are rolled back.
//!
//! ## Windowed validation
//!
//! A candidate rewrite only perturbs the schedule from its `Store`'s
//! position onward, so with `windowed` (the default) validation resumes a
//! recorded baseline [`SimTrace`] at that position instead of
//! re-simulating from t=0: the trial order is the baseline order with the
//! round trip spliced out (`Detach` in the `Store`'s slot, replay clones
//! just before the first post-window consumer) and the suffix re-walked
//! from the recorded stream state. Resumed simulation is bit-identical to
//! full simulation of the same trial (P13 pins this), so the
//! accept/reject criteria — and the never-regress guarantee — are exactly
//! as strong as under full re-simulation. With `windowed` off the pass
//! validates the pre-incremental way: full re-refinement (Algorithm 1)
//! plus full re-simulation per candidate — the A/B baseline
//! `benches/hot_path.rs` measures against.
//!
//! The pass runs *after* exec-order, so its baseline is the session's
//! pinned (refined) schedule — exactly what an offload-only pipeline would
//! emit. Because every commit is validated against that baseline and each
//! commit re-pins the validated trial order, the pipeline with this pass
//! never simulates worse than the same pipeline without it, and is
//! strictly better whenever at least one decision lands.

use std::collections::HashSet;

use crate::graph::{Graph, OpId, OpKind, RecomputePlan, TensorId, Tier};
use crate::sim::{simulate, SimTrace};

use super::compiler::{AnalysisCache, CompileError, Diagnostic, Pass, PassCtx, PassReport};

/// The recompute-vs-offload decision pass. See the module docs for the
/// cost model.
#[derive(Debug, Clone)]
pub struct RecomputeVsOffload {
    /// Speculate a recompute when its cost is ≤ `margin` × the exposed
    /// transfer estimate. 1.0 = only when the model says it outright wins.
    pub margin: f64,
    /// Upper bound on ops cloned per recompute subgraph (deep replay
    /// chains stop paying for themselves quickly).
    pub max_clone_ops: usize,
    /// Safety bound on committed decisions per compile.
    pub max_decisions: usize,
    /// Validate candidates by resuming a recorded baseline simulation at
    /// the rewrite's window start instead of re-refining and re-simulating
    /// the whole schedule (see module docs). Off = the pre-incremental
    /// full-validation path.
    pub windowed: bool,
}

impl Default for RecomputeVsOffload {
    fn default() -> Self {
        Self { margin: 1.0, max_clone_ops: 4, max_decisions: 64, windowed: true }
    }
}

/// One enumerated round-trip candidate.
struct Candidate {
    tensor: TensorId,
    store: OpId,
    prefetch: OpId,
    /// Position of the `Store` in the baseline order — the earliest
    /// schedule position the rewrite can affect.
    st_pos: usize,
    /// Position of the first post-window consumer.
    u_pos: usize,
    /// Model-estimated benefit (exposed transfer − recompute cost), us.
    benefit: f64,
    /// The replay subgraph the score was computed from — applied verbatim
    /// so scoring and rewrite can never diverge.
    plan: RecomputePlan,
}

/// A materialised trial rewrite, with everything the windowed path needs
/// to splice the baseline order.
struct TrialRewrite {
    trial: Graph,
    /// `old_id -> new_id` over the pre-removal id space (original ops +
    /// appended clones).
    map: Vec<Option<OpId>>,
    /// The replay clones, producers first (post-removal ids).
    clone_ops: Vec<OpId>,
    /// The `Detach` replacing the `Store`'s free (post-removal id).
    detach: OpId,
}

impl Pass for RecomputeVsOffload {
    fn name(&self) -> &'static str {
        "recompute-vs-offload"
    }

    fn run(
        &mut self,
        g: &mut Graph,
        cache: &mut AnalysisCache,
        ctx: &PassCtx,
    ) -> Result<PassReport, CompileError> {
        let mut rep = PassReport::new(self.name());
        let chw = ctx.contended_hw();
        let mut decided: HashSet<TensorId> = HashSet::new();
        let mut accepted = 0usize;
        let mut rejected = 0usize;
        let mut saved_dma_bytes = 0u64;
        let mut final_order: Option<Vec<OpId>> = None;

        // Baseline: the schedule the session would otherwise emit —
        // exec-order's pinned order (topo on custom pipelines). Both the
        // order and its simulation stay valid across rejected
        // speculations; only commits change the graph.
        let mut order: Vec<OpId> = (*cache.pinned_or_topo(g)?).clone();
        let mut trace =
            if self.windowed { Some(SimTrace::record(g, &order, &chw)) } else { None };
        let mut cur = match &trace {
            Some(t) => t.base.clone(),
            None => simulate(g, &order, &chw),
        };
        // One decision at a time: each commit renumbers ops, so candidates
        // are re-enumerated from the live graph (same protocol as elide).
        while accepted < self.max_decisions {
            let Some(c) = self.best_candidate(g, &order, &chw, &decided) else { break };
            decided.insert(c.tensor);

            // Speculate on a trial copy, then validate by re-simulation.
            let Some(tr) = apply_recompute(g, &order, &c) else {
                rejected += 1;
                continue;
            };
            let (sim, trial_order, trial_graph) = if let Some(trace) = &trace {
                // Windowed: splice the rewrite into the baseline order and
                // resume the recorded simulation at the window start.
                let trial_order = splice_order(&order, &c, &tr);
                let sim = trace.resume(c.st_pos, &tr.trial, &trial_order, &chw, &[]);
                (sim, trial_order, tr.trial)
            } else {
                // Full validation: re-run Algorithm 1 on the rewritten
                // graph (this also re-anchors cache ops), then simulate
                // from scratch.
                let mut trial = tr.trial;
                let Ok(topo) = trial.topo_order_detailed() else {
                    rejected += 1;
                    continue;
                };
                let refined =
                    crate::passes::exec_order::refine_from(&mut trial, topo, &ctx.hw, &ctx.exec);
                let sim = simulate(&trial, &refined.order, &chw);
                (sim, refined.order, trial)
            };
            let improves = sim.makespan_us < cur.makespan_us * (1.0 - 1e-9)
                || (sim.makespan_us <= cur.makespan_us * (1.0 + 1e-9)
                    && sim.peak_device_bytes < cur.peak_device_bytes);
            let regresses = sim.makespan_us > cur.makespan_us * (1.0 + 1e-9)
                || sim.peak_device_bytes > cur.peak_device_bytes;
            if improves && !regresses {
                let name = g.tensor(c.tensor).name.clone();
                let bytes = g.tensor(c.tensor).bytes;
                *g = trial_graph;
                cache.pin_order(g, trial_order.clone());
                rep.diagnostics.push(Diagnostic::info(
                    self.name(),
                    format!(
                        "recompute '{name}' instead of round-tripping it \
                         ({bytes} bytes each way): makespan {:.1} -> {:.1} us, \
                         peak {} -> {} bytes",
                        cur.makespan_us,
                        sim.makespan_us,
                        cur.peak_device_bytes,
                        sim.peak_device_bytes
                    ),
                ));
                order = trial_order.clone();
                final_order = Some(trial_order);
                cur = sim;
                accepted += 1;
                saved_dma_bytes += 2 * bytes;
                if trace.is_some() {
                    trace = Some(SimTrace::record(g, &order, &chw));
                }
            } else {
                rejected += 1;
                rep.diagnostics.push(Diagnostic::info(
                    self.name(),
                    format!(
                        "rolled back speculative recompute of '{}': simulated \
                         makespan {:.1} vs {:.1} us (validation failed)",
                        g.tensor(c.tensor).name,
                        sim.makespan_us,
                        cur.makespan_us
                    ),
                ));
            }
        }

        rep.recomputed = accepted;
        rep.order = final_order;
        rep.diagnostics.push(Diagnostic::info(
            self.name(),
            format!(
                "{accepted} round trip(s) replaced by recompute ({saved_dma_bytes} \
                 device<->pool bytes saved), {rejected} speculation(s) rolled back"
            ),
        ));
        Ok(rep)
    }
}

impl RecomputeVsOffload {
    /// Enumerate undecided round trips and return the one with the highest
    /// model-estimated benefit (exposed transfer − recompute cost), if any
    /// clears the speculation margin.
    ///
    /// One indexed O(ops + edges) sweep per round: per-tensor cache-op
    /// lists, a compute prefix-sum for window costs, and one shared
    /// [`Availability`] index — instead of rescanning every op per
    /// candidate tensor.
    fn best_candidate(
        &self,
        g: &Graph,
        order: &[OpId],
        chw: &crate::sim::HwConfig,
        decided: &HashSet<TensorId>,
    ) -> Option<Candidate> {
        let mut pos = vec![usize::MAX; g.ops.len()];
        for (i, &o) in order.iter().enumerate() {
            pos[o] = i;
        }
        let compute_us = |o: OpId| match g.op(o).kind {
            OpKind::Compute { flops, bytes_accessed } => chw.compute_us(flops, bytes_accessed),
            _ => 0.0,
        };
        // Global DMA overcommit: when the serial DMA streams carry more
        // time than the compute stream, the excess is exposed somewhere
        // regardless of placement.
        let total_compute: f64 = (0..g.ops.len()).map(|o| compute_us(o)).sum();
        let total_dma: f64 = g
            .ops
            .iter()
            .map(|o| match o.kind {
                OpKind::Prefetch { tensor, src } => chw.fetch_us(src, g.tensor(tensor).bytes),
                OpKind::Store { tensor, dst } => chw.evict_us(dst, g.tensor(tensor).bytes),
                _ => 0.0,
            })
            .sum();
        let overcommit = if total_dma > total_compute {
            (total_dma - total_compute) / total_dma
        } else {
            0.0
        };

        // Per-tensor cache-op index (one op sweep for all tensors).
        let nt = g.tensors.len();
        let (mut stores, mut prefetches, mut detaches) =
            (vec![Vec::new(); nt], vec![Vec::new(); nt], vec![0usize; nt]);
        for op in &g.ops {
            match op.kind {
                OpKind::Store { tensor, .. } => stores[tensor].push(op.id),
                OpKind::Prefetch { tensor, .. } => prefetches[tensor].push(op.id),
                OpKind::Detach { tensor } => detaches[tensor] += 1,
                _ => {}
            }
        }
        // Prefix sums of compute time along the order: the compute
        // available inside any window is one subtraction.
        let mut pc = vec![0.0f64; order.len() + 1];
        for (i, &o) in order.iter().enumerate() {
            pc[i + 1] = pc[i] + compute_us(o);
        }
        let availability = Availability::build(g, order);

        let mut best: Option<Candidate> = None;
        for t in &g.tensors {
            if t.home != Tier::Device || decided.contains(&t.id) {
                continue;
            }
            if detaches[t.id] != 0 || stores[t.id].len() != 1 || prefetches[t.id].len() != 1 {
                continue;
            }
            let (st, pf) = (stores[t.id][0], prefetches[t.id][0]);
            if pos[st] >= pos[pf] {
                continue;
            }
            // First consumer after the offload window opens.
            let Some(u_pos) = g
                .consumers_of(t.id)
                .iter()
                .filter(|&&c| !g.op(c).kind.is_cache_op() && pos[c] > pos[st])
                .map(|&c| pos[c])
                .min()
            else {
                continue;
            };

            let roundtrip = chw.d2r_us(t.bytes) + chw.r2d_us(t.bytes);
            let window_compute = pc[u_pos] - pc[pos[st] + 1];
            let exposed_est =
                (roundtrip - window_compute).max(roundtrip * overcommit).max(0.0);
            if exposed_est <= 0.0 {
                continue;
            }
            let tid = t.id;
            let avail = |_: &Graph, x: TensorId| x != tid && availability.usable(x, u_pos);
            let Some(plan) = g.recompute_plan(t.id, &avail, self.max_clone_ops) else {
                continue;
            };
            let rc_cost: f64 =
                plan.op_costs.iter().map(|&(f, b)| chw.compute_us(f, b)).sum();
            if rc_cost > self.margin * exposed_est {
                continue;
            }
            let benefit = exposed_est - rc_cost;
            if best.as_ref().map_or(true, |b| benefit > b.benefit) {
                best = Some(Candidate {
                    tensor: t.id,
                    store: st,
                    prefetch: pf,
                    st_pos: pos[st],
                    u_pos,
                    benefit,
                    plan,
                });
            }
        }
        best
    }
}

/// Usability of every tensor as a recompute input at any position:
/// device residency per the cache-operator walk the verifier uses
/// (device-home tensors are resident from their producer — or t=0 for
/// graph inputs — unless released by a `Store`/`Detach`; remote-home
/// tensors become resident at a `Prefetch`), minus any tensor with a
/// cache op at/after the query position: a clone reading a tensor whose
/// reload `Prefetch` lands later could not be dependency-ordered after the
/// transfer's completion, and one whose `Store`/`Detach` lands later has
/// no ordering against that release — both are rightly rejected by the IR
/// verifier. Refcount frees do not appear here — a new consumer at the
/// query position extends the refcount lifetime, so only cache-managed
/// absence makes an input unusable.
///
/// Built once per decision round (one order sweep); queries at arbitrary
/// positions are a binary search over that tensor's residency events.
struct Availability {
    /// Per tensor: `(position, becomes_resident)` events, ascending.
    events: Vec<Vec<(usize, bool)>>,
    /// Per tensor: last position with any cache op (usize::MAX = none).
    last_cache_pos: Vec<usize>,
    /// Residency before the first event (device-home graph inputs).
    initial: Vec<bool>,
}

impl Availability {
    fn build(g: &Graph, order: &[OpId]) -> Self {
        let nt = g.tensors.len();
        let initial: Vec<bool> = g
            .tensors
            .iter()
            .map(|t| t.home == Tier::Device && g.producer_of(t.id).is_none())
            .collect();
        let mut events: Vec<Vec<(usize, bool)>> = vec![Vec::new(); nt];
        let mut last_cache_pos = vec![usize::MAX; nt];
        for (i, &o) in order.iter().enumerate() {
            match g.op(o).kind {
                OpKind::Prefetch { tensor, .. } => {
                    events[tensor].push((i, true));
                    last_cache_pos[tensor] = i;
                }
                OpKind::Store { tensor, .. } | OpKind::Detach { tensor } => {
                    events[tensor].push((i, false));
                    last_cache_pos[tensor] = i;
                }
                _ => {
                    for &t in &g.op(o).outputs {
                        if g.tensor(t).home == Tier::Device {
                            events[t].push((i, true));
                        }
                    }
                }
            }
        }
        Self { events, last_cache_pos, initial }
    }

    /// Is `x` usable as a recompute input at position `u`?
    fn usable(&self, x: TensorId, u: usize) -> bool {
        if self.last_cache_pos[x] != usize::MAX && self.last_cache_pos[x] >= u {
            return false;
        }
        let ev = &self.events[x];
        match ev.partition_point(|&(p, _)| p < u) {
            0 => self.initial[x],
            k => ev[k - 1].1,
        }
    }
}

/// Apply one recompute decision to a trial clone of `g`: remove the round
/// trip, clone the candidate's planned producer subgraph (anchored just
/// before the first post-window consumer), rewire post-window consumers
/// to the regenerated tensor, and wire prefetch-completion deps for any
/// cache-managed inputs the clones read.
fn apply_recompute(g: &Graph, order: &[OpId], c: &Candidate) -> Option<TrialRewrite> {
    let mut pos = vec![usize::MAX; g.ops.len()];
    for (i, &o) in order.iter().enumerate() {
        pos[o] = i;
    }
    let tid = c.tensor;
    let plan = &c.plan;

    // Consumers inside/after the offload window read the clone instead.
    let st_pos = pos[c.store];
    let window_consumers: Vec<OpId> = g
        .consumers_of(tid)
        .iter()
        .copied()
        .filter(|&x| !g.op(x).kind.is_cache_op() && pos[x] > st_pos)
        .collect();
    // Anchor: the compute op immediately preceding the first post-window
    // consumer — "replay HERE", the just-in-time placement Algorithm 1
    // would pick for the prefetch this replaces.
    let anchor = order[..c.u_pos]
        .iter()
        .rev()
        .copied()
        .find(|&o| matches!(g.op(o).kind, OpKind::Compute { .. }));

    let mut trial = g.clone();
    let clone = trial.clone_recompute_subgraph(plan);
    let map = trial.remove_ops(&[c.store, c.prefetch]);
    let clone_ops: Vec<OpId> = clone.ops.iter().map(|&o| map[o].unwrap()).collect();

    for &w in &window_consumers {
        trial.replace_input(map[w]?, tid, clone.tensor);
    }
    // The original copy is now discarded, not transferred: release its
    // residency after every consumer still reading it (its producer if
    // none remain) — the Store used to perform this free.
    let keepers: Vec<OpId> = g
        .consumers_of(tid)
        .iter()
        .copied()
        .filter(|&x| !g.op(x).kind.is_cache_op() && pos[x] <= st_pos)
        .collect();
    let dt = trial.add_op(
        format!("detach.{}", g.tensor(tid).name),
        OpKind::Detach { tensor: tid },
        vec![tid],
        vec![],
    );
    if keepers.is_empty() {
        if let Some(p) = g.producer_of(tid) {
            trial.add_control_dep(dt, map[p]?);
        }
    } else {
        for &k in &keepers {
            trial.add_control_dep(dt, map[k]?);
        }
    }
    if let Some(a) = anchor {
        for &ro in &clone_ops {
            trial.add_control_dep(ro, map[a]?);
        }
    }
    // Clones consuming a prefetched tensor must be dependency-ordered
    // after that transfer's completion (verifier rule: placement after the
    // prefetch is not completion ordering).
    for &ro in &clone_ops {
        let inputs = trial.op(ro).inputs.clone();
        for x in inputs {
            for old in 0..g.ops.len() {
                if matches!(g.op(old).kind, OpKind::Prefetch { tensor, .. } if tensor == x)
                    && pos[old] < c.u_pos
                {
                    if let Some(new_pf) = map[old] {
                        trial.add_control_dep(ro, new_pf);
                    }
                }
            }
        }
    }
    Some(TrialRewrite { trial, map, clone_ops, detach: dt })
}

/// Splice one committed rewrite into the baseline order without
/// re-refining: the `Detach` takes the `Store`'s slot (its deps — the
/// pre-window keepers — all precede it), the `Prefetch` disappears (ops
/// that waited on it inherit its predecessors via `remove_ops`), and the
/// replay clones land producers-first immediately before the first
/// post-window consumer — the just-in-time placement Algorithm 1 would
/// pick for the prefetch they replace. Everything else keeps its baseline
/// position, so the first `st_pos` positions are untouched and a recorded
/// [`SimTrace`] can resume there.
fn splice_order(order: &[OpId], c: &Candidate, tr: &TrialRewrite) -> Vec<OpId> {
    let mut out = Vec::with_capacity(order.len() + tr.clone_ops.len());
    for (i, &o) in order.iter().enumerate() {
        if i == c.u_pos {
            out.extend(tr.clone_ops.iter().copied());
        }
        if o == c.store {
            out.push(tr.detach);
        } else if o != c.prefetch {
            out.push(tr.map[o].expect("surviving op must be mapped"));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::passes::{Compiler, OffloadPolicy};
    use crate::sim::HwConfig;

    /// A producer whose activation is cheap to replay: 1 ms of compute vs
    /// a 2 MB round trip. On a slow link the round trip is exposed and the
    /// decision pass should flip it to recompute.
    fn workload() -> Graph {
        let mut b = GraphBuilder::new();
        let act = b.tensor("act", 2 << 20, crate::graph::Tier::Device);
        let sink = b.tensor("sink", 0, crate::graph::Tier::Device);
        b.compute("fwd", 1e9, 0, vec![], vec![act]);
        let mut prev = None;
        for i in 0..6 {
            let t = b.tensor(&format!("m{i}"), 0, crate::graph::Tier::Device);
            let inputs = prev.map(|p| vec![p]).unwrap_or_default();
            let o = b.compute(&format!("mid{i}"), 1e9, 0, inputs, vec![t]);
            if i == 0 {
                b.dep(o, 0);
            }
            prev = Some(t);
        }
        b.compute("bwd", 1e9, 0, vec![act, prev.unwrap()], vec![sink]);
        b.build()
    }

    /// Slow link: the 2 MB round trip takes ~42 ms against 6 ms of window
    /// compute — thoroughly exposed.
    fn slow_link_hw() -> HwConfig {
        let mut hw = HwConfig::test_default();
        hw.d2r_gbps = 0.1;
        hw.r2d_gbps = 0.1;
        hw
    }

    /// Loose policy so insertion still offloads on the slow link.
    fn aggressive() -> OffloadPolicy {
        OffloadPolicy { coverage: 0.1, ..Default::default() }
    }

    #[test]
    fn recompute_beats_exposed_round_trip() {
        let mut a = workload();
        let ra = Compiler::new(slow_link_hw())
            .policy(aggressive())
            .compile(&mut a)
            .unwrap();
        let sa = simulate(&a, &ra.order, &slow_link_hw());
        assert!(!ra.inserted.is_empty(), "fixture must offload");

        let mut b = workload();
        let rb = Compiler::new(slow_link_hw())
            .policy(aggressive())
            .recompute_vs_offload()
            .verify(true)
            .compile(&mut b)
            .unwrap();
        let sb = simulate(&b, &rb.order, &slow_link_hw());

        assert_eq!(rb.recomputed, 1, "round trip must flip to recompute");
        assert!(
            sb.makespan_us < sa.makespan_us,
            "recompute did not beat offload: {} !< {}",
            sb.makespan_us,
            sa.makespan_us
        );
        assert!(sb.peak_device_bytes <= sa.peak_device_bytes);
        assert!(sb.recompute_us > 0.0, "recompute time must be accounted");
        assert!(sb.dma_bytes < sa.dma_bytes);
        assert!(b.ops.iter().any(|o| o.recompute), "clone must be marked");
    }

    #[test]
    fn windowed_and_full_validation_agree_on_the_fixture() {
        // Same workload through both validation paths: both must flip the
        // round trip, and neither may regress the other's baseline.
        let mut a = workload();
        let ra = Compiler::new(slow_link_hw())
            .policy(aggressive())
            .pass(RecomputeVsOffload { windowed: false, ..Default::default() })
            .verify(true)
            .compile(&mut a)
            .unwrap();
        let sa = simulate(&a, &ra.order, &slow_link_hw());

        let mut b = workload();
        let rb = Compiler::new(slow_link_hw())
            .policy(aggressive())
            .recompute_vs_offload() // windowed by default
            .verify(true)
            .compile(&mut b)
            .unwrap();
        let sb = simulate(&b, &rb.order, &slow_link_hw());

        assert_eq!(ra.recomputed, 1);
        assert_eq!(rb.recomputed, 1);
        // Both validated against the same pinned baseline, so both ended
        // strictly under it; windowed must be in the same ballpark.
        assert!(sb.makespan_us <= sa.makespan_us * 1.05,
            "windowed validation lost too much: {} vs {}", sb.makespan_us, sa.makespan_us);
    }

    #[test]
    fn hidden_round_trips_are_left_alone() {
        // Fast link: the round trip hides inside the window; recompute has
        // nothing to win and must not fire.
        let mut g = workload();
        let r = Compiler::new(HwConfig::test_default())
            .recompute_vs_offload()
            .verify(true)
            .compile(&mut g)
            .unwrap();
        assert!(!r.inserted.is_empty());
        assert_eq!(r.recomputed, 0, "hidden transfers must stay transfers");
    }

    #[test]
    fn expensive_producers_are_not_replayed() {
        // Producer flops dominate the transfer: the margin test rejects the
        // speculation before simulation.
        let mut b = GraphBuilder::new();
        let act = b.tensor("act", 2 << 20, crate::graph::Tier::Device);
        let sink = b.tensor("sink", 0, crate::graph::Tier::Device);
        b.compute("fwd", 200e9, 0, vec![], vec![act]); // 200 ms to replay
        let mut prev = None;
        for i in 0..6 {
            let t = b.tensor(&format!("m{i}"), 0, crate::graph::Tier::Device);
            let inputs = prev.map(|p| vec![p]).unwrap_or_default();
            let o = b.compute(&format!("mid{i}"), 1e9, 0, inputs, vec![t]);
            if i == 0 {
                b.dep(o, 0);
            }
            prev = Some(t);
        }
        b.compute("bwd", 1e9, 0, vec![act, prev.unwrap()], vec![sink]);
        let mut g = b.build();
        let r = Compiler::new(slow_link_hw())
            .policy(aggressive())
            .recompute_vs_offload()
            .compile(&mut g)
            .unwrap();
        assert_eq!(r.recomputed, 0);
    }

    #[test]
    fn contention_tips_the_decision() {
        // At moderate link speed the round trip just about hides; telling
        // the session the fabric is 8x contended makes recompute win.
        let mut hw = HwConfig::test_default();
        hw.d2r_gbps = 1.0;
        hw.r2d_gbps = 1.0;
        let mut a = workload();
        let ra = Compiler::new(hw.clone())
            .policy(aggressive())
            .recompute_vs_offload()
            .compile(&mut a)
            .unwrap();
        assert_eq!(ra.recomputed, 0, "uncontended: transfer hides");

        let mut b = workload();
        let rb = Compiler::new(hw)
            .policy(aggressive())
            .contention(8.0)
            .recompute_vs_offload()
            .verify(true)
            .compile(&mut b)
            .unwrap();
        assert_eq!(rb.recomputed, 1, "contended fabric must flip the decision");
    }
}
