//! `RecomputeVsOffload`: weigh regenerating a tensor against round-tripping
//! it through the pool — the first pass that *changes* an offload decision
//! instead of only placing transfers.
//!
//! The insertion pass (§4.2.2) decides "offload and prefetch" for every
//! profitable candidate. On a saturated device↔pool link that is not the
//! only option: a tensor whose producer's FLOPs are cheap relative to its
//! bytes can be *discarded* and replayed from still-resident inputs just
//! before its next use (SuperOffload's speculate-then-validate tradeoff,
//! dominant on superchips where compute outruns the offload fabric).
//!
//! ## Cost model
//!
//! For each inserted `Store`/`Prefetch` round trip over tensor `t`:
//!
//! * **exposed-transfer cost** — the round trip's wire time under the
//!   session's assumed fabric contention ([`PassCtx::contended_hw`]),
//!   minus the compute available inside `t`'s idle window (from the cached
//!   lifetimes) that could hide it, floored by the *global* DMA
//!   overcommit share: when ΣDMA > Σcompute the streams are the critical
//!   path and every round trip is at least proportionally exposed.
//! * **recompute cost** — Σ `compute_us(flops, bytes)` over the producer
//!   subgraph that regenerates `t` from still-resident tensors
//!   ([`Graph::recompute_plan`]); tensors whose inputs have left the
//!   device recursively extend the plan until `max_clone_ops` caps it.
//!
//! Recompute is *speculated* when its cost is within `margin` × the
//! exposed-transfer estimate, then **validated**: the rewrite (drop the
//! round trip, release the original copy with a `Detach`, clone the
//! producer subgraph anchored just before the first post-window consumer,
//! rewire those consumers to the clone) is applied to a trial graph,
//! re-refined with Algorithm 1, and re-simulated; decisions that fail to
//! strictly improve makespan or peak residency — or that regress either —
//! are rolled back.
//!
//! The pass runs *after* exec-order, so its baseline is the session's
//! pinned (refined) schedule — exactly what an offload-only pipeline would
//! emit. Because every commit is validated against that baseline and each
//! commit re-pins the refined trial order, the pipeline with this pass
//! never simulates worse than the same pipeline without it, and is
//! strictly better whenever at least one decision lands.

use std::collections::HashSet;

use crate::graph::{Graph, OpId, OpKind, RecomputePlan, TensorId, Tier};
use crate::sim::simulate;

use super::compiler::{AnalysisCache, CompileError, Diagnostic, Pass, PassCtx, PassReport};

/// The recompute-vs-offload decision pass. See the module docs for the
/// cost model.
#[derive(Debug, Clone)]
pub struct RecomputeVsOffload {
    /// Speculate a recompute when its cost is ≤ `margin` × the exposed
    /// transfer estimate. 1.0 = only when the model says it outright wins.
    pub margin: f64,
    /// Upper bound on ops cloned per recompute subgraph (deep replay
    /// chains stop paying for themselves quickly).
    pub max_clone_ops: usize,
    /// Safety bound on committed decisions per compile.
    pub max_decisions: usize,
}

impl Default for RecomputeVsOffload {
    fn default() -> Self {
        Self { margin: 1.0, max_clone_ops: 4, max_decisions: 64 }
    }
}

/// One enumerated round-trip candidate.
struct Candidate {
    tensor: TensorId,
    store: OpId,
    prefetch: OpId,
    /// Position of the first post-window consumer.
    u_pos: usize,
    /// Model-estimated benefit (exposed transfer − recompute cost), us.
    benefit: f64,
    /// The replay subgraph the score was computed from — applied verbatim
    /// so scoring and rewrite can never diverge.
    plan: RecomputePlan,
}

impl Pass for RecomputeVsOffload {
    fn name(&self) -> &'static str {
        "recompute-vs-offload"
    }

    fn run(
        &mut self,
        g: &mut Graph,
        cache: &mut AnalysisCache,
        ctx: &PassCtx,
    ) -> Result<PassReport, CompileError> {
        let mut rep = PassReport::new(self.name());
        let chw = ctx.contended_hw();
        let mut decided: HashSet<TensorId> = HashSet::new();
        let mut accepted = 0usize;
        let mut rejected = 0usize;
        let mut saved_dma_bytes = 0u64;
        let mut final_order: Option<Vec<OpId>> = None;

        // Baseline: the schedule the session would otherwise emit —
        // exec-order's pinned order (topo on custom pipelines). Both the
        // order and its simulation stay valid across rejected
        // speculations; only commits change the graph.
        let mut order = cache.pinned_or_topo(g)?;
        let mut cur = simulate(g, &order, &chw);
        // One decision at a time: each commit renumbers ops, so candidates
        // are re-enumerated from the live graph (same protocol as elide).
        while accepted < self.max_decisions {
            let Some(c) = self.best_candidate(g, &order, &chw, &decided) else { break };
            decided.insert(c.tensor);

            // Speculate on a trial copy: rewrite, re-run Algorithm 1 on
            // the rewritten graph, then validate by re-simulation.
            match apply_recompute(g, &order, &c) {
                Some(mut trial) => {
                    let Ok(topo) = trial.topo_order_detailed() else { continue };
                    let refined =
                        crate::passes::exec_order::refine_from(&mut trial, topo, &ctx.hw, &ctx.exec);
                    let sim = simulate(&trial, &refined.order, &chw);
                    let improves = sim.makespan_us < cur.makespan_us * (1.0 - 1e-9)
                        || (sim.makespan_us <= cur.makespan_us * (1.0 + 1e-9)
                            && sim.peak_device_bytes < cur.peak_device_bytes);
                    let regresses = sim.makespan_us > cur.makespan_us * (1.0 + 1e-9)
                        || sim.peak_device_bytes > cur.peak_device_bytes;
                    if improves && !regresses {
                        let name = g.tensor(c.tensor).name.clone();
                        let bytes = g.tensor(c.tensor).bytes;
                        *g = trial;
                        cache.pin_order(g, refined.order.clone());
                        rep.diagnostics.push(Diagnostic::info(
                            self.name(),
                            format!(
                                "recompute '{name}' instead of round-tripping it \
                                 ({bytes} bytes each way): makespan {:.1} -> {:.1} us, \
                                 peak {} -> {} bytes",
                                cur.makespan_us,
                                sim.makespan_us,
                                cur.peak_device_bytes,
                                sim.peak_device_bytes
                            ),
                        ));
                        order = refined.order.clone();
                        final_order = Some(refined.order);
                        cur = sim;
                        accepted += 1;
                        saved_dma_bytes += 2 * bytes;
                    } else {
                        rejected += 1;
                        rep.diagnostics.push(Diagnostic::info(
                            self.name(),
                            format!(
                                "rolled back speculative recompute of '{}': simulated \
                                 makespan {:.1} vs {:.1} us (validation failed)",
                                g.tensor(c.tensor).name,
                                sim.makespan_us,
                                cur.makespan_us
                            ),
                        ));
                    }
                }
                None => {
                    rejected += 1;
                }
            }
        }

        rep.recomputed = accepted;
        rep.order = final_order;
        rep.diagnostics.push(Diagnostic::info(
            self.name(),
            format!(
                "{accepted} round trip(s) replaced by recompute ({saved_dma_bytes} \
                 device<->pool bytes saved), {rejected} speculation(s) rolled back"
            ),
        ));
        Ok(rep)
    }
}

impl RecomputeVsOffload {
    /// Enumerate undecided round trips and return the one with the highest
    /// model-estimated benefit (exposed transfer − recompute cost), if any
    /// clears the speculation margin.
    fn best_candidate(
        &self,
        g: &Graph,
        order: &[OpId],
        chw: &crate::sim::HwConfig,
        decided: &HashSet<TensorId>,
    ) -> Option<Candidate> {
        let mut pos = vec![usize::MAX; g.ops.len()];
        for (i, &o) in order.iter().enumerate() {
            pos[o] = i;
        }
        let compute_us = |o: OpId| match g.op(o).kind {
            OpKind::Compute { flops, bytes_accessed } => chw.compute_us(flops, bytes_accessed),
            _ => 0.0,
        };
        // Global DMA overcommit: when the serial DMA streams carry more
        // time than the compute stream, the excess is exposed somewhere
        // regardless of placement.
        let total_compute: f64 = (0..g.ops.len()).map(|o| compute_us(o)).sum();
        let total_dma: f64 = g
            .ops
            .iter()
            .map(|o| match o.kind {
                OpKind::Prefetch { tensor } => chw.r2d_us(g.tensor(tensor).bytes),
                OpKind::Store { tensor } => chw.d2r_us(g.tensor(tensor).bytes),
                _ => 0.0,
            })
            .sum();
        let overcommit = if total_dma > total_compute {
            (total_dma - total_compute) / total_dma
        } else {
            0.0
        };

        let mut best: Option<Candidate> = None;
        for t in &g.tensors {
            if t.home != Tier::Device || decided.contains(&t.id) {
                continue;
            }
            let (mut stores, mut prefetches, mut detaches) = (Vec::new(), Vec::new(), 0usize);
            for op in &g.ops {
                match op.kind {
                    OpKind::Store { tensor } if tensor == t.id => stores.push(op.id),
                    OpKind::Prefetch { tensor } if tensor == t.id => prefetches.push(op.id),
                    OpKind::Detach { tensor } if tensor == t.id => detaches += 1,
                    _ => {}
                }
            }
            if detaches != 0 || stores.len() != 1 || prefetches.len() != 1 {
                continue;
            }
            let (st, pf) = (stores[0], prefetches[0]);
            if pos[st] >= pos[pf] {
                continue;
            }
            // First consumer after the offload window opens.
            let Some(u_pos) = g
                .consumers_of(t.id)
                .iter()
                .filter(|&&c| !g.op(c).kind.is_cache_op() && pos[c] > pos[st])
                .map(|&c| pos[c])
                .min()
            else {
                continue;
            };

            let roundtrip = chw.d2r_us(t.bytes) + chw.r2d_us(t.bytes);
            let window_compute: f64 =
                order[pos[st] + 1..u_pos].iter().map(|&o| compute_us(o)).sum();
            let exposed_est =
                (roundtrip - window_compute).max(roundtrip * overcommit).max(0.0);
            if exposed_est <= 0.0 {
                continue;
            }
            let usable = available_at(g, order, u_pos);
            let tid = t.id;
            let avail = |_: &Graph, x: TensorId| x != tid && usable[x];
            let Some(plan) = g.recompute_plan(t.id, &avail, self.max_clone_ops) else {
                continue;
            };
            let rc_cost: f64 =
                plan.op_costs.iter().map(|&(f, b)| chw.compute_us(f, b)).sum();
            if rc_cost > self.margin * exposed_est {
                continue;
            }
            let benefit = exposed_est - rc_cost;
            if best.as_ref().map_or(true, |b| benefit > b.benefit) {
                best = Some(Candidate {
                    tensor: t.id,
                    store: st,
                    prefetch: pf,
                    u_pos,
                    benefit,
                    plan,
                });
            }
        }
        best
    }
}

/// Usability of every tensor as a recompute input at position `u_pos`:
/// device residency per the cache-operator walk the verifier uses
/// (device-home tensors are resident from their producer — or t=0 for
/// graph inputs — unless released by a `Store`/`Detach`; remote-home
/// tensors become resident at a `Prefetch`), minus any tensor with a
/// cache op at/after `u_pos`: a clone reading a tensor whose reload
/// `Prefetch` lands later could not be dependency-ordered after the
/// transfer's completion, and one whose `Store`/`Detach` lands later has
/// no ordering against that release — both are rightly rejected by the IR
/// verifier. Refcount frees do not appear here — a new consumer at
/// `u_pos` extends the refcount lifetime, so only cache-managed absence
/// makes an input unusable.
fn available_at(g: &Graph, order: &[OpId], u_pos: usize) -> Vec<bool> {
    let mut avail: Vec<bool> = g
        .tensors
        .iter()
        .map(|t| t.home == Tier::Device && g.producer_of(t.id).is_none())
        .collect();
    for &o in &order[..u_pos] {
        match g.op(o).kind {
            OpKind::Prefetch { tensor } => avail[tensor] = true,
            OpKind::Store { tensor } | OpKind::Detach { tensor } => avail[tensor] = false,
            _ => {
                for &t in &g.op(o).outputs {
                    if g.tensor(t).home == Tier::Device {
                        avail[t] = true;
                    }
                }
            }
        }
    }
    for &o in &order[u_pos..] {
        if let Some(t) = g.op(o).kind.cache_tensor() {
            avail[t] = false;
        }
    }
    avail
}

/// Apply one recompute decision to a trial clone of `g`: remove the round
/// trip, clone the candidate's planned producer subgraph (anchored just
/// before the first post-window consumer), rewire post-window consumers
/// to the regenerated tensor, and wire prefetch-completion deps for any
/// cache-managed inputs the clones read.
fn apply_recompute(g: &Graph, order: &[OpId], c: &Candidate) -> Option<Graph> {
    let mut pos = vec![usize::MAX; g.ops.len()];
    for (i, &o) in order.iter().enumerate() {
        pos[o] = i;
    }
    let tid = c.tensor;
    let plan = &c.plan;

    // Consumers inside/after the offload window read the clone instead.
    let st_pos = pos[c.store];
    let window_consumers: Vec<OpId> = g
        .consumers_of(tid)
        .iter()
        .copied()
        .filter(|&x| !g.op(x).kind.is_cache_op() && pos[x] > st_pos)
        .collect();
    // Anchor: the compute op immediately preceding the first post-window
    // consumer — "replay HERE", the just-in-time placement Algorithm 1
    // would pick for the prefetch this replaces.
    let anchor = order[..c.u_pos]
        .iter()
        .rev()
        .copied()
        .find(|&o| matches!(g.op(o).kind, OpKind::Compute { .. }));

    let mut trial = g.clone();
    let clone = trial.clone_recompute_subgraph(plan);
    let map = trial.remove_ops(&[c.store, c.prefetch]);
    let clone_ops: Vec<OpId> = clone.ops.iter().map(|&o| map[o].unwrap()).collect();

    for &w in &window_consumers {
        trial.replace_input(map[w]?, tid, clone.tensor);
    }
    // The original copy is now discarded, not transferred: release its
    // residency after every consumer still reading it (its producer if
    // none remain) — the Store used to perform this free.
    let keepers: Vec<OpId> = g
        .consumers_of(tid)
        .iter()
        .copied()
        .filter(|&x| !g.op(x).kind.is_cache_op() && pos[x] <= st_pos)
        .collect();
    let dt = trial.add_op(
        format!("detach.{}", g.tensor(tid).name),
        OpKind::Detach { tensor: tid },
        vec![tid],
        vec![],
    );
    if keepers.is_empty() {
        if let Some(p) = g.producer_of(tid) {
            trial.add_control_dep(dt, map[p]?);
        }
    } else {
        for &k in &keepers {
            trial.add_control_dep(dt, map[k]?);
        }
    }
    if let Some(a) = anchor {
        for &ro in &clone_ops {
            trial.add_control_dep(ro, map[a]?);
        }
    }
    // Clones consuming a prefetched tensor must be dependency-ordered
    // after that transfer's completion (verifier rule: placement after the
    // prefetch is not completion ordering).
    for &ro in &clone_ops {
        let inputs = trial.op(ro).inputs.clone();
        for x in inputs {
            for old in 0..g.ops.len() {
                if matches!(g.op(old).kind, OpKind::Prefetch { tensor } if tensor == x)
                    && pos[old] < c.u_pos
                {
                    if let Some(new_pf) = map[old] {
                        trial.add_control_dep(ro, new_pf);
                    }
                }
            }
        }
    }
    Some(trial)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::passes::{Compiler, OffloadPolicy};
    use crate::sim::HwConfig;

    /// A producer whose activation is cheap to replay: 1 ms of compute vs
    /// a 2 MB round trip. On a slow link the round trip is exposed and the
    /// decision pass should flip it to recompute.
    fn workload() -> Graph {
        let mut b = GraphBuilder::new();
        let act = b.tensor("act", 2 << 20, crate::graph::Tier::Device);
        let sink = b.tensor("sink", 0, crate::graph::Tier::Device);
        b.compute("fwd", 1e9, 0, vec![], vec![act]);
        let mut prev = None;
        for i in 0..6 {
            let t = b.tensor(&format!("m{i}"), 0, crate::graph::Tier::Device);
            let inputs = prev.map(|p| vec![p]).unwrap_or_default();
            let o = b.compute(&format!("mid{i}"), 1e9, 0, inputs, vec![t]);
            if i == 0 {
                b.dep(o, 0);
            }
            prev = Some(t);
        }
        b.compute("bwd", 1e9, 0, vec![act, prev.unwrap()], vec![sink]);
        b.build()
    }

    /// Slow link: the 2 MB round trip takes ~42 ms against 6 ms of window
    /// compute — thoroughly exposed.
    fn slow_link_hw() -> HwConfig {
        let mut hw = HwConfig::test_default();
        hw.d2r_gbps = 0.1;
        hw.r2d_gbps = 0.1;
        hw
    }

    /// Loose policy so insertion still offloads on the slow link.
    fn aggressive() -> OffloadPolicy {
        OffloadPolicy { coverage: 0.1, ..Default::default() }
    }

    #[test]
    fn recompute_beats_exposed_round_trip() {
        let mut a = workload();
        let ra = Compiler::new(slow_link_hw())
            .policy(aggressive())
            .compile(&mut a)
            .unwrap();
        let sa = simulate(&a, &ra.order, &slow_link_hw());
        assert!(!ra.inserted.is_empty(), "fixture must offload");

        let mut b = workload();
        let rb = Compiler::new(slow_link_hw())
            .policy(aggressive())
            .recompute_vs_offload()
            .verify(true)
            .compile(&mut b)
            .unwrap();
        let sb = simulate(&b, &rb.order, &slow_link_hw());

        assert_eq!(rb.recomputed, 1, "round trip must flip to recompute");
        assert!(
            sb.makespan_us < sa.makespan_us,
            "recompute did not beat offload: {} !< {}",
            sb.makespan_us,
            sa.makespan_us
        );
        assert!(sb.peak_device_bytes <= sa.peak_device_bytes);
        assert!(sb.recompute_us > 0.0, "recompute time must be accounted");
        assert!(sb.dma_bytes < sa.dma_bytes);
        assert!(b.ops.iter().any(|o| o.recompute), "clone must be marked");
    }

    #[test]
    fn hidden_round_trips_are_left_alone() {
        // Fast link: the round trip hides inside the window; recompute has
        // nothing to win and must not fire.
        let mut g = workload();
        let r = Compiler::new(HwConfig::test_default())
            .recompute_vs_offload()
            .verify(true)
            .compile(&mut g)
            .unwrap();
        assert!(!r.inserted.is_empty());
        assert_eq!(r.recomputed, 0, "hidden transfers must stay transfers");
    }

    #[test]
    fn expensive_producers_are_not_replayed() {
        // Producer flops dominate the transfer: the margin test rejects the
        // speculation before simulation.
        let mut b = GraphBuilder::new();
        let act = b.tensor("act", 2 << 20, crate::graph::Tier::Device);
        let sink = b.tensor("sink", 0, crate::graph::Tier::Device);
        b.compute("fwd", 200e9, 0, vec![], vec![act]); // 200 ms to replay
        let mut prev = None;
        for i in 0..6 {
            let t = b.tensor(&format!("m{i}"), 0, crate::graph::Tier::Device);
            let inputs = prev.map(|p| vec![p]).unwrap_or_default();
            let o = b.compute(&format!("mid{i}"), 1e9, 0, inputs, vec![t]);
            if i == 0 {
                b.dep(o, 0);
            }
            prev = Some(t);
        }
        b.compute("bwd", 1e9, 0, vec![act, prev.unwrap()], vec![sink]);
        let mut g = b.build();
        let r = Compiler::new(slow_link_hw())
            .policy(aggressive())
            .recompute_vs_offload()
            .compile(&mut g)
            .unwrap();
        assert_eq!(r.recomputed, 0);
    }

    #[test]
    fn contention_tips_the_decision() {
        // At moderate link speed the round trip just about hides; telling
        // the session the fabric is 8x contended makes recompute win.
        let mut hw = HwConfig::test_default();
        hw.d2r_gbps = 1.0;
        hw.r2d_gbps = 1.0;
        let mut a = workload();
        let ra = Compiler::new(hw.clone())
            .policy(aggressive())
            .recompute_vs_offload()
            .compile(&mut a)
            .unwrap();
        assert_eq!(ra.recomputed, 0, "uncontended: transfer hides");

        let mut b = workload();
        let rb = Compiler::new(hw)
            .policy(aggressive())
            .contention(8.0)
            .recompute_vs_offload()
            .verify(true)
            .compile(&mut b)
            .unwrap();
        assert_eq!(rb.recomputed, 1, "contended fabric must flip the decision");
    }
}
