//! Tensor lifetime analysis (§3.2 "Global Visibility of Memory Lifecycles").
//!
//! With cache operations as graph nodes, the compiler can see exactly when
//! each tensor is produced, consumed, offloaded and reloaded. This pass
//! computes, per tensor and per execution order: producer position, first /
//! last consumer positions, the *idle window* between consecutive uses, and
//! residency byte-time — the quantities the offload-candidate selector and
//! Algorithm 1's cost model consume.

use std::collections::HashMap;

use crate::graph::{Graph, OpId, TensorId};

/// Lifetime facts for one tensor under one execution order.
#[derive(Debug, Clone)]
pub struct Lifetime {
    pub tensor: TensorId,
    /// Position of the producer in the order (None for graph inputs).
    pub def_pos: Option<usize>,
    /// Positions of consumers, ascending.
    pub use_pos: Vec<usize>,
    /// Largest gap (in positions) between consecutive uses (or def→first
    /// use). The paper's offload candidates are tensors with a large idle
    /// window between forward production and backward consumption.
    pub max_idle_gap: usize,
    /// Start index of that largest gap.
    pub idle_gap_start: usize,
}

impl Lifetime {
    /// Span from definition to last use (positions).
    pub fn span(&self) -> usize {
        let start = self.def_pos.unwrap_or(0);
        let end = self.use_pos.last().copied().unwrap_or(start);
        end.saturating_sub(start)
    }
}

/// Analysis over a whole graph + order.
#[derive(Debug, Clone)]
pub struct LifetimeAnalysis {
    pub lifetimes: HashMap<TensorId, Lifetime>,
    /// position of each op in the order.
    pub pos: Vec<usize>,
}

impl LifetimeAnalysis {
    pub fn run(graph: &Graph, order: &[OpId]) -> Self {
        let mut pos = vec![usize::MAX; graph.ops.len()];
        for (i, &o) in order.iter().enumerate() {
            pos[o] = i;
        }
        let mut lifetimes = HashMap::new();
        for t in &graph.tensors {
            lifetimes.insert(t.id, lifetime_of(graph, t.id, &pos));
        }
        Self { lifetimes, pos }
    }

    pub fn get(&self, t: TensorId) -> &Lifetime {
        &self.lifetimes[&t]
    }
}

/// Lifetime facts for one tensor, given `pos[op] = position in order`.
///
/// This is the per-tensor body of [`LifetimeAnalysis::run`], exposed so the
/// compiler's incremental `AnalysisCache` can recompute lifetimes for only
/// the tensors a journalled graph mutation touched.
pub fn lifetime_of(graph: &Graph, tensor: TensorId, pos: &[usize]) -> Lifetime {
    let def_pos = graph.producer_of(tensor).map(|p| pos[p]);
    let mut use_pos: Vec<usize> = graph
        .consumers_of(tensor)
        .iter()
        .filter(|&&c| !graph.op(c).kind.is_cache_op())
        .map(|&c| pos[c])
        .collect();
    use_pos.sort_unstable();

    // Largest idle gap between consecutive events (def, use...).
    let mut events: Vec<usize> = Vec::with_capacity(use_pos.len() + 1);
    if let Some(d) = def_pos {
        events.push(d);
    }
    events.extend(&use_pos);
    let (mut max_gap, mut gap_start) = (0usize, events.first().copied().unwrap_or(0));
    for w in events.windows(2) {
        let gap = w[1].saturating_sub(w[0]);
        if gap > max_gap {
            max_gap = gap;
            gap_start = w[0];
        }
    }
    Lifetime { tensor, def_pos, use_pos, max_idle_gap: max_gap, idle_gap_start: gap_start }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{GraphBuilder, Tier};

    #[test]
    fn chain_lifetimes() {
        let g = GraphBuilder::linear_chain(4, 1e6, 64);
        let order = g.topo_order().unwrap();
        let la = LifetimeAnalysis::run(&g, &order);
        // act.0 defined by op0, used by op1.
        let lt = la.get(0);
        assert_eq!(lt.def_pos, Some(0));
        assert_eq!(lt.use_pos, vec![1]);
        assert_eq!(lt.max_idle_gap, 1);
    }

    #[test]
    fn idle_gap_found_for_fwd_bwd_pattern() {
        // act produced at op0, consumed at op5 (bwd-like): gap = 5.
        let mut b = GraphBuilder::new();
        let act = b.tensor("act", 1 << 20, Tier::Device);
        let sink = b.tensor("sink", 0, Tier::Device);
        b.compute("fwd", 1e6, 0, vec![], vec![act]);
        let mut prev = None;
        for i in 0..4 {
            let t = b.tensor(&format!("m{i}"), 0, Tier::Device);
            let inputs = prev.map(|p| vec![p]).unwrap_or_default();
            let o = b.compute(&format!("mid{i}"), 1e6, 0, inputs, vec![t]);
            if i == 0 {
                b.dep(o, 0);
            }
            prev = Some(t);
        }
        b.compute("bwd", 1e6, 0, vec![act, prev.unwrap()], vec![sink]);
        let g = b.build();
        let order = g.topo_order().unwrap();
        let la = LifetimeAnalysis::run(&g, &order);
        let lt = la.get(act);
        assert_eq!(lt.def_pos, Some(0));
        assert_eq!(lt.max_idle_gap, 5);
        assert_eq!(lt.idle_gap_start, 0);
        assert_eq!(lt.span(), 5);
    }

    #[test]
    fn graph_input_has_no_def() {
        let mut b = GraphBuilder::new();
        let w = b.tensor("w", 64, Tier::Remote);
        let o = b.tensor("o", 0, Tier::Device);
        b.compute("c", 1e6, 0, vec![w], vec![o]);
        let g = b.build();
        let order = g.topo_order().unwrap();
        let la = LifetimeAnalysis::run(&g, &order);
        assert_eq!(la.get(w).def_pos, None);
        assert_eq!(la.get(w).use_pos, vec![0]);
    }

    #[test]
    fn cache_op_uses_excluded() {
        let mut b = GraphBuilder::new();
        let a = b.tensor("a", 64, Tier::Device);
        let o = b.tensor("o", 0, Tier::Device);
        let c0 = b.compute("p", 1e6, 0, vec![], vec![a]);
        let st = b.store("st.a", a);
        b.dep(st, c0);
        b.compute("q", 1e6, 0, vec![a], vec![o]);
        let g = b.build();
        let order = g.topo_order().unwrap();
        let la = LifetimeAnalysis::run(&g, &order);
        // The Store op is not a "use" for lifetime purposes.
        assert_eq!(la.get(a).use_pos.len(), 1);
    }
}
