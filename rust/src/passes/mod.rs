//! Compiler passes over the HyperOffload IR (§4 of the paper).
//!
//! Pipeline (what [`compile`] runs, in order):
//! 1. [`lifetime`]      — tensor lifetime / idle-window analysis (§3.2)
//! 2. [`prefetch_insert`] — offload-candidate selection + cache-operator
//!    insertion (§4.2.2)
//! 3. [`exec_order`]    — Algorithm 1 execution-order refinement (§4.3)

pub mod exec_order;
pub mod lifetime;
pub mod prefetch_insert;

use crate::graph::{Graph, OpId};
use crate::sim::HwConfig;

pub use exec_order::{refine, refine_from, ExecOrderConfig, Refinement};
pub use lifetime::{Lifetime, LifetimeAnalysis};
pub use prefetch_insert::{InsertionResult, OffloadPlan, OffloadPolicy};

/// End-to-end compilation report.
#[derive(Debug, Clone)]
pub struct CompileReport {
    /// Final, refined execution order.
    pub order: Vec<OpId>,
    /// Cache-op pairs inserted by the prefetch pass.
    pub inserted: Vec<(OpId, OpId)>,
    /// Offload candidates rejected (window too small — §5.1).
    pub rejected: usize,
    /// Cache ops moved by Algorithm 1.
    pub moved: usize,
}

/// The full HyperOffload compile pipeline: lifetimes → insertion →
/// Algorithm 1. Mutates `graph` (cache ops are inserted) and returns the
/// refined order to execute it with.
pub fn compile(
    graph: &mut Graph,
    hw: &HwConfig,
    policy: &OffloadPolicy,
    exec_cfg: &ExecOrderConfig,
) -> CompileReport {
    let order = graph.topo_order().expect("compile: cyclic graph");
    let ins = prefetch_insert::run(graph, &order, hw, policy);
    let refined = exec_order::refine(graph, hw, exec_cfg);
    CompileReport {
        order: refined.order,
        inserted: ins.inserted,
        rejected: ins.rejected,
        moved: refined.moved,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{GraphBuilder, Tier};
    use crate::sim::simulate;

    fn hw() -> HwConfig {
        HwConfig {
            compute_tflops: 1.0,
            hbm_gbps: 1e9,
            d2r_gbps: 1.0,
            r2d_gbps: 1.0,
            link_latency_us: 0.0,
            net_gbps: 1.0,
            host_overhead_us: 0.0,
            device_capacity: 1 << 30,
            remote_capacity: 1 << 40,
        }
    }

    #[test]
    fn full_pipeline_cuts_peak_without_slowdown() {
        // fwd producing 4 big activations, heavy mid section, bwd consuming
        // them in reverse — the §5.1 training case in miniature.
        // fwd ops are long (10 ms) relative to the 8 ms store of their 8 MB
        // activation, so offloaded activations leave the device while later
        // layers still compute — that is where the peak reduction comes from.
        let mut b = GraphBuilder::new();
        let mut acts = Vec::new();
        let mut prev = None;
        for i in 0..4 {
            let a = b.tensor(&format!("act{i}"), 8 << 20, Tier::Device);
            let o = b.compute(&format!("fwd{i}"), 10e9, 0, prev.map(|p| vec![p]).unwrap_or_default(), vec![a]);
            let _ = o;
            acts.push(a);
            prev = Some(a);
        }
        let mut mid_prev: Option<usize> = None;
        for i in 0..24 {
            let t = b.tensor(&format!("m{i}"), 0, Tier::Device);
            let o = b.compute(&format!("mid{i}"), 1e9, 0, vec![], vec![t]);
            if let Some(p) = mid_prev {
                b.dep(o, p);
            } else {
                b.dep(o, 3);
            }
            mid_prev = Some(o);
        }
        let mut bwd_prev = mid_prev;
        for (i, &a) in acts.iter().enumerate().rev() {
            let t = b.tensor(&format!("g{i}"), 0, Tier::Device);
            let o = b.compute(&format!("bwd{i}"), 10e9, 0, vec![a], vec![t]);
            if let Some(p) = bwd_prev {
                b.dep(o, p);
            }
            bwd_prev = Some(o);
        }
        let mut g = b.build();

        let base_order = g.topo_order().unwrap();
        let base = simulate(&g, &base_order, &hw());

        let report = compile(&mut g, &hw(), &OffloadPolicy::default(), &ExecOrderConfig::default());
        assert!(!report.inserted.is_empty(), "no cache ops inserted");
        let opt = simulate(&g, &report.order, &hw());

        assert!(
            opt.peak_device_bytes < base.peak_device_bytes,
            "peak not reduced: {} vs {}",
            opt.peak_device_bytes,
            base.peak_device_bytes
        );
        // End-to-end time within 5% of baseline (paper: "iteration time
        // stays the same").
        assert!(
            opt.makespan_us <= base.makespan_us * 1.05,
            "slowdown: {} vs {}",
            opt.makespan_us,
            base.makespan_us
        );
    }
}
