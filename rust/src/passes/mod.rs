//! Compiler passes over the HyperOffload IR (§4 of the paper), driven by
//! the [`Compiler`] session API.
//!
//! ## Pipeline
//!
//! A [`Compiler`] is a configured compile *session*: hardware + policy +
//! an ordered list of [`Pass`]es, sharing one [`AnalysisCache`]:
//!
//! ```text
//!          Compiler::new(hw).policy(p).exec(cfg).slo_us(t).verify(true)
//!             ┌──────────────────────────────────────────────────────┐
//!  Graph ───▶ │ LifetimePass          §3.2 lifetime / idle windows   │
//!             │ PrefetchInsertPass    §4.2.2 cache-op insertion      │
//!             │ (ElideRedundantTransfers   opt-in, capacity-aware    │
//!             │                            round-trip elision)       │
//!             │ (RecomputeVsOffload        opt-in: replay cheap      │
//!             │                            producers vs transfer)    │
//!             │ (TierPlacement             opt-in: rehome idle       │
//!             │                            round trips below pool)   │
//!             │ ExecOrderPass         §4.3 Algorithm 1 refinement    │
//!             │ (SloThrottle               opt-in: defer/split       │
//!             │                            prefetches under an SLO)  │
//!             └──────────────────────────────────────────────────────┘
//!                  │                    ▲
//!                  ▼                    │ memoised topo order, lifetimes,
//!        verify_ir + TransferSan   AnalysisCache  cache-op reachability +
//!        (between stages when          pinned order, keyed on
//!        enabled / --cfg strict_verify)    Graph::version()
//!
//!  ──▶ Result<CompileReport { order, per-pass reports, diagnostics }>
//! ```
//!
//! Cyclic graphs surface as [`CompileError::Cycle`] (with the culprit
//! ops), verifier findings as [`CompileError::Verify`] — no panics.
//!
//! ## Incremental analyses (production graph scale)
//!
//! The pipeline's analyses are shared through the session's
//! [`AnalysisCache`], built to stay cheap on 20k+-op graphs:
//!
//! * **Version-keyed sharing.** Topological order and lifetime tables are
//!   handed out as `Rc` views keyed on [`Graph::version`] — a pass that
//!   does not mutate the graph gets the previous pass's analysis for
//!   free (no clone, no recompute).
//! * **Journal-driven delta updates.** The graph keeps a bounded journal
//!   of [`Mutation`](crate::graph::Mutation) events. When a pass appends
//!   ops, tensors, or forward edges, the cache *patches* its cached topo
//!   order and re-analyses only the touched tensors' lifetimes instead of
//!   recomputing from scratch; any non-local mutation (removal, input
//!   rewiring) falls back to a full recompute. Patched results are
//!   bit-identical to fresh ones (property P13 in
//!   `rust/tests/proptest_invariants.rs`); `Compiler::incremental(false)`
//!   disables patching for A/B measurement.
//! * **Windowed re-simulation.** The decision passes validate each
//!   speculative rewrite against the simulator. Instead of re-simulating
//!   the whole schedule per speculation, they record one
//!   [`SimTrace`](crate::sim::SimTrace) of the baseline and *resume* it
//!   at the first position the rewrite can affect — exact, not an
//!   approximation (also P13). `RecomputeVsOffload::windowed` /
//!   `SloThrottle::windowed` fall back to the full path when off.
//! * **One-shot verification structures.** `verify_ir` checks every
//!   (prefetch, consumer) completion ordering against a single
//!   precomputed bitset reachability structure rather than one DFS per
//!   pair.
//!
//! Compile latency is observable end to end: `benches/hot_path.rs`
//! times the full pipeline at 20k ops with the machinery on vs off, and
//! the serving engine accounts every step-compile miss in
//! `ServingReport::compile_us_total` / `compile_us_max` (the compile
//! stall a first-of-its-shape decode step absorbs).
//!
//! ## TransferSan — the static cache-op sanitizer
//!
//! [`Compiler::sanitize`]`(true)` appends a static analysis stage (the
//! [`analysis`](crate::analysis) module) after the pipeline. Where
//! `verify_ir` walks *one* linearization, TransferSan proves properties
//! over **every** execution order the dependence graph admits, using the
//! session's cached [`Reach`](crate::graph::Reach) bitsets: readers whose
//! prefetch is not forced before them, store/consumer races, double
//! releases, use-after-release, pool-ledger leaks, chunk/parent aliasing
//! hazards, and a static antichain upper bound on peak residency — all
//! without running the simulator. Findings surface through the usual
//! [`Diagnostic`] stream under the `transfer-san` pass name, levelled by
//! a lint registry: [`Compiler::lint`]`("race::store_consumer", …)`
//! re-levels one lint, [`Compiler::deny_warnings`]`(true)` promotes every
//! surviving warning to a compile failure. Deny-level findings abort the
//! compile as [`CompileError::Verify`]. Under `--cfg strict_verify` (the
//! hardened CI job) the sanitizer additionally runs after *every* pass
//! with warnings denied, so a rewrite that corrupts the cache-op IR is
//! caught at the pass that introduced it. The mutation corpus in
//! `rust/tests/sanitizer_mutations.rs` pins each lint to the class of
//! pass bug it exists to catch.
//!
//! ## Decision passes and their cost model
//!
//! The insertion pass only ever decides "offload and prefetch"; two
//! opt-in *decision passes* change that decision when the cost model says
//! a transfer is the wrong tool. Both speculate a rewrite, re-simulate the
//! live graph under the session's assumed fabric contention
//! ([`PassCtx::contended_hw`]), and roll back anything that regresses —
//! so neither can make the compiled schedule worse than what it was fed.
//!
//! **[`RecomputeVsOffload`]** ([`Compiler::recompute_vs_offload`]) —
//! recompute wins when replaying a tensor's producer subgraph from
//! still-resident inputs costs less than the round trip's *exposed*
//! transfer time:
//!
//! ```text
//! exposed(t)  = max(roundtrip(t) − window_compute(t),   // lifetime window
//!                   roundtrip(t) × DMA-overcommit share) // ΣDMA > Σcompute
//! recompute(t) = Σ compute_us(flops, bytes) over the replay subgraph
//! speculate when recompute(t) ≤ margin × exposed(t)
//! ```
//!
//! On an idle fabric every inserted round trip hides inside its window, so
//! `exposed ≈ 0` and nothing flips; as the link saturates (low bandwidth,
//! or `Compiler::contention` > 1 for shared-fabric compiles), transfers
//! become the critical path and cheap producers are replayed instead.
//!
//! **[`SloThrottle`]** ([`Compiler::slo_throttle`] + [`Compiler::slo_us`])
//! — transfer *timing* shaped against a latency SLO. First it *spills*:
//! Stores of `deferrable` tensors (serving KV writebacks) are shrunk to
//! the largest chunk view that fits the budget, the shed bytes reported
//! for the caller to move in a later schedule. Then, against a global
//! budget of `max(slo, makespan)`, it greedily (latest consumers first)
//! defers prefetches to later anchors and splits oversized transfers —
//! pool-resident prefetches *and* full Store/Prefetch round trips — into
//! chunked partial-tensor transfers ([`Graph::add_chunk_tensor`]),
//! committing only rewrites that keep the re-simulated makespan within
//! budget, never raise peak residency above the entry schedule, and
//! strictly reduce peak or residency byte·time — spending SLO slack to
//! spill bytes into pool headroom rather than letting early transfers
//! camp in HBM. The serving engine compiles every step through this pass
//! (see `serving::step_graph`).
//!
//! ## Writing a custom pass
//!
//! The session API turns "add a scenario" into registering one [`Pass`]:
//!
//! ```no_run
//! use hyperoffload::graph::{Graph, GraphBuilder};
//! use hyperoffload::passes::{
//!     AnalysisCache, CompileError, Compiler, Pass, PassCtx, PassReport,
//! };
//! use hyperoffload::sim::HwConfig;
//!
//! /// Counts cache operators; a real pass would rewrite the graph.
//! struct CountCacheOps;
//!
//! impl Pass for CountCacheOps {
//!     fn name(&self) -> &'static str {
//!         "count-cache-ops"
//!     }
//!     fn run(
//!         &mut self,
//!         g: &mut Graph,
//!         cache: &mut AnalysisCache,
//!         _ctx: &PassCtx,
//!     ) -> Result<PassReport, CompileError> {
//!         let order = cache.topo_order(g)?; // memoised, auto-invalidated
//!         let _ = (order, g.cache_ops().len());
//!         Ok(PassReport::new("count-cache-ops"))
//!     }
//! }
//!
//! let mut g = GraphBuilder::linear_chain(8, 1e9, 1 << 20);
//! let report = Compiler::new(HwConfig::ascend910c_like())
//!     .pass(CountCacheOps) // appended after the default pipeline
//!     .compile(&mut g)
//!     .expect("compile");
//! assert!(g.is_valid_order(&report.order));
//! ```
//!
//! The underlying algorithms remain directly callable ([`lifetime`],
//! [`prefetch_insert`], [`exec_order`]) for tooling and benchmarks.

pub mod compiler;
pub mod elide;
pub mod exec_order;
pub mod lifetime;
pub mod prefetch_insert;
pub mod recompute;
pub mod slo_throttle;
pub mod tier_placement;

use crate::graph::Graph;
use crate::sim::HwConfig;

pub use compiler::{
    verify_ir, verify_ir_with, AnalysisCache, CompileError, CompileReport, Compiler, Diagnostic,
    ExecOrderPass, LifetimePass, Pass, PassCtx, PassReport, PrefetchInsertPass, Severity,
    VerifyPass,
};
pub use elide::ElideRedundantTransfers;
pub use exec_order::{refine, refine_from, ExecOrderConfig, Refinement};
pub use lifetime::{Lifetime, LifetimeAnalysis};
pub use prefetch_insert::{InsertionResult, OffloadPlan, OffloadPolicy};
pub use recompute::RecomputeVsOffload;
pub use slo_throttle::SloThrottle;
pub use tier_placement::TierPlacement;

/// The legacy positional-config entry point, kept as a thin shim over the
/// default [`Compiler`] pipeline with identical output.
///
/// Panics on cyclic graphs (the historical behaviour); the session API
/// returns [`CompileError::Cycle`] instead.
#[deprecated(
    since = "0.3.0",
    note = "use the Compiler session API: Compiler::new(hw).policy(p).exec(cfg).compile(&mut g)"
)]
pub fn compile(
    graph: &mut Graph,
    hw: &HwConfig,
    policy: &OffloadPolicy,
    exec_cfg: &ExecOrderConfig,
) -> CompileReport {
    Compiler::new(hw.clone())
        .policy(policy.clone())
        .exec(exec_cfg.clone())
        .compile(graph)
        .expect("compile: cyclic graph")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::sim::simulate;

    fn hw() -> HwConfig {
        HwConfig::test_default()
    }

    #[test]
    fn full_pipeline_cuts_peak_without_slowdown() {
        // fwd producing 4 big activations, heavy mid section, bwd consuming
        // them in reverse — the §5.1 training case in miniature.
        // fwd ops are long (10 ms) relative to the 8 ms store of their 8 MB
        // activation, so offloaded activations leave the device while later
        // layers still compute — that is where the peak reduction comes from.
        let mut g = GraphBuilder::fwd_bwd_chain(4, 8 << 20, 10e9, 24, 1e9);

        let base_order = g.topo_order().unwrap();
        let base = simulate(&g, &base_order, &hw());

        let report = Compiler::new(hw()).verify(true).compile(&mut g).unwrap();
        assert!(!report.inserted.is_empty(), "no cache ops inserted");
        let opt = simulate(&g, &report.order, &hw());

        assert!(
            opt.peak_device_bytes < base.peak_device_bytes,
            "peak not reduced: {} vs {}",
            opt.peak_device_bytes,
            base.peak_device_bytes
        );
        // End-to-end time within 5% of baseline (paper: "iteration time
        // stays the same").
        assert!(
            opt.makespan_us <= base.makespan_us * 1.05,
            "slowdown: {} vs {}",
            opt.makespan_us,
            base.makespan_us
        );
        // The session report carries one entry per default pass.
        assert_eq!(report.per_pass.len(), 3);
        assert!(report.cache_hits > 0, "analysis cache never hit");
    }
}
