//! Compile-time offload-candidate selection and cache-operator insertion
//! (§4.2.2 "Compile-Time Prefetch Insertion").
//!
//! Given a graph and an execution order, select tensors whose idle window
//! makes offloading profitable — transfer cost must fit inside the window's
//! compute (the paper: "activations with very short lifetimes or
//! fine-grained access patterns are not good candidates... Algorithm 1
//! detects such cases at compile time and avoids offloading them") — then
//! rewrite the graph: `Store` after the last use before the window,
//! `Prefetch` (control-dep'd on the Store) before the next use, and the
//! consumer control-dep'd on the Prefetch.

use crate::graph::{Graph, OpId, OpKind, TensorId};
use crate::sim::HwConfig;

use super::lifetime::LifetimeAnalysis;

/// Tuning knobs for candidate selection.
#[derive(Debug, Clone)]
pub struct OffloadPolicy {
    /// Ignore tensors smaller than this (transfer setup dominates).
    pub min_bytes: u64,
    /// Minimum idle window (in ops) for a tensor to be worth moving.
    pub min_idle_gap: usize,
    /// Require the window's compute time to cover `coverage` × the
    /// round-trip transfer time (store + prefetch).
    pub coverage: f64,
    /// Upper bound on how many tensors to offload (0 = unlimited).
    pub max_candidates: usize,
}

impl Default for OffloadPolicy {
    fn default() -> Self {
        Self { min_bytes: 1 << 20, min_idle_gap: 2, coverage: 0.8, max_candidates: 0 }
    }
}

/// One selected offload: tensor + the ops bracketing its idle window.
#[derive(Debug, Clone)]
pub struct OffloadPlan {
    pub tensor: TensorId,
    /// Op after which the Store is issued (producer or last pre-window use).
    /// `None` for remote-home tensors: they need no Store, only a Prefetch
    /// before their first device use.
    pub after_op: Option<OpId>,
    /// First op needing the tensor back (gets a dep on the Prefetch).
    pub before_op: OpId,
}

/// Result of the insertion pass.
#[derive(Debug, Clone)]
pub struct InsertionResult {
    pub plans: Vec<OffloadPlan>,
    /// (store_op, prefetch_op) pairs inserted, aligned with `plans`.
    pub inserted: Vec<(OpId, OpId)>,
    /// Candidates rejected because the window could not cover the transfer.
    pub rejected: usize,
}

/// Select offload candidates, running a fresh lifetime analysis.
pub fn select_candidates(
    graph: &Graph,
    order: &[OpId],
    hw: &HwConfig,
    policy: &OffloadPolicy,
) -> (Vec<OffloadPlan>, usize) {
    let la = LifetimeAnalysis::run(graph, order);
    select_candidates_with(graph, order, &la, hw, policy)
}

/// Select offload candidates from a precomputed (e.g. session-cached)
/// lifetime analysis. `la` must have been computed for `order`.
pub fn select_candidates_with(
    graph: &Graph,
    order: &[OpId],
    la: &LifetimeAnalysis,
    hw: &HwConfig,
    policy: &OffloadPolicy,
) -> (Vec<OffloadPlan>, usize) {
    let mut plans = Vec::new();
    let mut rejected = 0usize;

    // Compute time available inside a window of positions (sum of compute
    // op durations strictly inside the window).
    let window_compute_us = |a: usize, b: usize| -> f64 {
        order[a + 1..b]
            .iter()
            .map(|&o| match graph.op(o).kind {
                OpKind::Compute { flops, bytes_accessed } => hw.compute_us(flops, bytes_accessed),
                _ => 0.0,
            })
            .sum()
    };

    let mut scored: Vec<(u64, OffloadPlan)> = Vec::new();
    for t in &graph.tensors {
        // Already managed by a cache op? Skip.
        if graph
            .ops
            .iter()
            .any(|o| o.kind.cache_tensor() == Some(t.id))
        {
            continue;
        }
        // Remote-home tensors MUST be prefetched before first device use —
        // not an optimisation choice, a legalisation step. Always planned.
        if t.home == crate::graph::Tier::Remote {
            if let Some(&u) = graph
                .consumers_of(t.id)
                .iter()
                .find(|&&c| matches!(graph.op(c).kind, OpKind::Compute { .. }))
            {
                plans.push(OffloadPlan { tensor: t.id, after_op: None, before_op: u });
            }
            continue;
        }
        if t.bytes < policy.min_bytes {
            continue;
        }
        let lt = la.get(t.id);
        if lt.max_idle_gap < policy.min_idle_gap || lt.use_pos.is_empty() {
            continue;
        }
        let gap_start = lt.idle_gap_start;
        let gap_end = gap_start + lt.max_idle_gap;
        let transfer_us = hw.d2r_us(t.bytes) + hw.r2d_us(t.bytes);
        let cover = window_compute_us(gap_start, gap_end);
        if cover < policy.coverage * transfer_us {
            rejected += 1;
            continue;
        }
        scored.push((
            t.bytes,
            OffloadPlan {
                tensor: t.id,
                after_op: Some(order[gap_start]),
                before_op: order[gap_end],
            },
        ));
    }
    // Biggest tensors first — most memory relief per cache-op pair — and a
    // global DMA budget: total round-trip transfer time across accepted
    // candidates must stay within `coverage` × total compute time, or the
    // (serial) DMA streams become the critical path regardless of placement.
    scored.sort_by(|a, b| b.0.cmp(&a.0));
    let total_compute_us: f64 = order
        .iter()
        .map(|&o| match graph.op(o).kind {
            OpKind::Compute { flops, bytes_accessed } => hw.compute_us(flops, bytes_accessed),
            _ => 0.0,
        })
        .sum();
    // Same ratio as the per-window test: transfer <= compute / coverage.
    let mut dma_budget_us = total_compute_us / policy.coverage;
    for (bytes, p) in scored {
        if policy.max_candidates > 0 && plans.len() >= policy.max_candidates {
            break;
        }
        let round_trip = hw.d2r_us(bytes) + hw.r2d_us(bytes);
        if round_trip > dma_budget_us {
            rejected += 1;
            continue;
        }
        dma_budget_us -= round_trip;
        plans.push(p);
    }
    (plans, rejected)
}

/// Rewrite `graph` in place, inserting Store/Prefetch pairs (or lone
/// Prefetches for remote-home tensors) for `plans`. `order` is the
/// (pre-insertion) execution order the plans were selected against.
/// Returns `(store_or_prefetch, prefetch)` pairs — for store-less plans
/// both ids are the prefetch.
///
/// Every consumer at-or-after the idle window is control-dep'd on the
/// prefetch — not just `before_op`. With only the first consumer wired, a
/// later consumer with no path to the prefetch could be scheduled inside
/// the offload window and read a tensor that has left the device.
pub fn insert_cache_ops(
    graph: &mut Graph,
    plans: &[OffloadPlan],
    order: &[OpId],
) -> Vec<(OpId, OpId)> {
    let mut pos = vec![usize::MAX; graph.ops.len()];
    for (i, &o) in order.iter().enumerate() {
        pos[o] = i;
    }
    let mut inserted = Vec::with_capacity(plans.len());
    for p in plans {
        let tname = graph.tensor(p.tensor).name.clone();
        let st = p.after_op.map(|after| {
            let st = graph.add_op(
                format!("store.{tname}"),
                OpKind::store(p.tensor),
                vec![p.tensor],
                vec![],
            );
            graph.add_control_dep(st, after);
            st
        });
        let pf = graph.add_op(
            format!("prefetch.{tname}"),
            OpKind::prefetch(p.tensor),
            vec![p.tensor],
            vec![],
        );
        if let Some(st) = st {
            graph.add_control_dep(pf, st);
        }
        graph.add_control_dep(p.before_op, pf);
        // Consumers inside/after the window wait for the transfer too.
        // Remote-home tensors (no Store) have no pre-window resident copy,
        // so every consumer waits.
        let anchor_pos = if p.after_op.is_some() {
            pos.get(p.before_op).copied().unwrap_or(0)
        } else {
            0
        };
        let consumers: Vec<OpId> = graph.consumers_of(p.tensor).to_vec();
        for c in consumers {
            if c == pf || Some(c) == st || graph.op(c).kind.is_cache_op() {
                continue;
            }
            let cpos = pos.get(c).copied().unwrap_or(usize::MAX);
            if cpos != usize::MAX && cpos >= anchor_pos {
                graph.add_control_dep(c, pf);
            } else if cpos != usize::MAX {
                // Pre-window consumers read the pre-offload copy, so the
                // Store must wait for them. Anchoring it on `after` alone
                // orders it only against the *last* pre-window use: an
                // earlier consumer with no data path to `after` would be
                // free to land after the Store in another valid
                // linearization and read an offloaded tensor — benign in
                // the order the plans were selected against, a race
                // everywhere else (TransferSan: race::store_consumer).
                if let Some(st) = st {
                    if !graph.op(st).control_deps.contains(&c) {
                        graph.add_control_dep(st, c);
                    }
                }
            }
        }
        inserted.push((st.unwrap_or(pf), pf));
    }
    inserted
}

/// Full pass: select + insert. Returns the rewritten-graph bookkeeping.
pub fn run(
    graph: &mut Graph,
    order: &[OpId],
    hw: &HwConfig,
    policy: &OffloadPolicy,
) -> InsertionResult {
    let la = LifetimeAnalysis::run(graph, order);
    run_with(graph, order, &la, hw, policy)
}

/// Full pass with a caller-supplied (e.g. session-cached) lifetime
/// analysis — what `PrefetchInsertPass` drives.
pub fn run_with(
    graph: &mut Graph,
    order: &[OpId],
    la: &LifetimeAnalysis,
    hw: &HwConfig,
    policy: &OffloadPolicy,
) -> InsertionResult {
    let (plans, rejected) = select_candidates_with(graph, order, la, hw, policy);
    let inserted = insert_cache_ops(graph, &plans, order);
    InsertionResult { plans, inserted, rejected }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{GraphBuilder, Tier};

    /// fwd produces a big activation, 6 heavy mid ops, bwd consumes it.
    fn fwd_bwd_graph(act_bytes: u64, mid_flops: f64) -> Graph {
        let mut b = GraphBuilder::new();
        let act = b.tensor("act", act_bytes, Tier::Device);
        let sink = b.tensor("sink", 0, Tier::Device);
        b.compute("fwd", 1e6, 0, vec![], vec![act]);
        let mut prev = None;
        for i in 0..6 {
            let t = b.tensor(&format!("m{i}"), 0, Tier::Device);
            let inputs = prev.map(|p| vec![p]).unwrap_or_default();
            let o = b.compute(&format!("mid{i}"), mid_flops, 0, inputs, vec![t]);
            if i == 0 {
                b.dep(o, 0);
            }
            prev = Some(t);
        }
        b.compute("bwd", 1e6, 0, vec![act, prev.unwrap()], vec![sink]);
        b.build()
    }

    fn hw() -> HwConfig {
        HwConfig::test_default()
    }

    #[test]
    fn selects_big_long_lived_tensor() {
        // 2 MB activation, round trip 4000 us; 6 mids à 1000 us = 6000 us cover.
        let g = fwd_bwd_graph(2 << 20, 1e9);
        let order = g.topo_order().unwrap();
        let (plans, rejected) =
            select_candidates(&g, &order, &hw(), &OffloadPolicy::default());
        assert_eq!(plans.len(), 1);
        assert_eq!(rejected, 0);
        assert_eq!(g.tensor(plans[0].tensor).name, "act");
    }

    #[test]
    fn rejects_when_window_cannot_cover_transfer() {
        // Tiny mid compute: window can't hide the 4000us round trip.
        let g = fwd_bwd_graph(2 << 20, 1e3);
        let order = g.topo_order().unwrap();
        let (plans, rejected) =
            select_candidates(&g, &order, &hw(), &OffloadPolicy::default());
        assert!(plans.is_empty());
        assert_eq!(rejected, 1);
    }

    #[test]
    fn rejects_small_tensors() {
        let g = fwd_bwd_graph(1024, 1e9); // 1 KB < min_bytes
        let order = g.topo_order().unwrap();
        let (plans, _) = select_candidates(&g, &order, &hw(), &OffloadPolicy::default());
        assert!(plans.is_empty());
    }

    #[test]
    fn insertion_preserves_validity_and_wires_deps() {
        let mut g = fwd_bwd_graph(2 << 20, 1e9);
        let order = g.topo_order().unwrap();
        let res = run(&mut g, &order, &hw(), &OffloadPolicy::default());
        assert_eq!(res.inserted.len(), 1);
        let (st, pf) = res.inserted[0];
        assert!(g.validate().is_ok());
        let new_order = g.topo_order().unwrap();
        let pos = |o: OpId| new_order.iter().position(|&x| x == o).unwrap();
        // store after fwd, prefetch after store, bwd after prefetch.
        assert!(pos(st) > pos(0));
        assert!(pos(pf) > pos(st));
        let bwd = g.ops.iter().find(|o| o.name == "bwd").unwrap().id;
        assert!(pos(bwd) > pos(pf));
    }

    #[test]
    fn offload_reduces_residency_byte_time_after_refinement() {
        // A single offloaded activation cannot lower the instantaneous peak
        // (it is alone in memory), but its residency byte-time must drop.
        // Insertion ALONE does not achieve this: with the default topo
        // order the prefetch starts the moment the store completes (the
        // DMA streams are idle), so the bytes never leave. Only Algorithm 1
        // placing the prefetch just-in-time opens the gap — the paper's
        // §3.3 argument in miniature.
        use crate::passes::exec_order::{refine, ExecOrderConfig};
        use crate::sim::simulate;
        // mids at 3e9 flops = 3 ms each so the 4.2 ms round trip of the
        // 2 MB activation fits well inside the 18 ms window, leaving a
        // long absence gap (the byte-time saving).
        let mut g = fwd_bwd_graph(2 << 20, 3e9);
        let base_order = g.topo_order().unwrap();
        let base = simulate(&g, &base_order, &hw());
        run(&mut g, &base_order, &hw(), &OffloadPolicy::default());

        // Insertion only: byte-time unchanged (prefetch chases the store).
        let mid_order = g.topo_order().unwrap();
        let mid = simulate(&g, &mid_order, &hw());
        assert!(
            (mid.residency_byte_time() - base.residency_byte_time()).abs()
                < base.residency_byte_time() * 0.05,
            "insertion alone should not change byte-time materially"
        );

        // Insertion + Algorithm 1: byte-time drops.
        let r = refine(&mut g, &hw(), &ExecOrderConfig::default());
        let opt = simulate(&g, &r.order, &hw());
        assert!(
            opt.residency_byte_time() < base.residency_byte_time() * 0.8,
            "byte-time not reduced: {} vs {}",
            opt.residency_byte_time(),
            base.residency_byte_time()
        );
    }

    #[test]
    fn all_post_window_consumers_wait_for_the_prefetch() {
        // act consumed by bwd1 AND bwd2 after the idle window; both must be
        // ordered after the prefetch, or one could read inside the window.
        let mut b = GraphBuilder::new();
        let act = b.tensor("act", 2 << 20, Tier::Device);
        let s1 = b.tensor("s1", 0, Tier::Device);
        let s2 = b.tensor("s2", 0, Tier::Device);
        b.compute("fwd", 1e6, 0, vec![], vec![act]);
        let mut prev = None;
        for i in 0..6 {
            let t = b.tensor(&format!("m{i}"), 0, Tier::Device);
            let inputs = prev.map(|p| vec![p]).unwrap_or_default();
            let o = b.compute(&format!("mid{i}"), 1e9, 0, inputs, vec![t]);
            if i == 0 {
                b.dep(o, 0);
            }
            prev = Some(t);
        }
        let bwd1 = b.compute("bwd1", 1e6, 0, vec![act, prev.unwrap()], vec![s1]);
        let bwd2 = b.compute("bwd2", 1e6, 0, vec![act], vec![s2]);
        b.dep(bwd2, bwd1);
        let mut g = b.build();
        let order = g.topo_order().unwrap();
        let res = run(&mut g, &order, &hw(), &OffloadPolicy::default());
        assert_eq!(res.inserted.len(), 1);
        let (_, pf) = res.inserted[0];
        assert!(g.op(bwd1).control_deps.contains(&pf), "bwd1 not wired to prefetch");
        assert!(g.op(bwd2).control_deps.contains(&pf), "bwd2 not wired to prefetch");
        assert!(g.validate().is_ok());
    }

    #[test]
    fn max_candidates_caps_selection() {
        // Two offloadable tensors, cap at 1.
        let mut b = GraphBuilder::new();
        let a1 = b.tensor("a1", 4 << 20, Tier::Device);
        let a2 = b.tensor("a2", 2 << 20, Tier::Device);
        let sink = b.tensor("sink", 0, Tier::Device);
        b.compute("f1", 1e6, 0, vec![], vec![a1]);
        let f2 = b.compute("f2", 1e6, 0, vec![], vec![a2]);
        b.dep(f2, 0);
        let mut prev: Option<usize> = Some(f2);
        for i in 0..30 {
            let t = b.tensor(&format!("m{i}"), 0, Tier::Device);
            let o = b.compute(&format!("mid{i}"), 2e9, 0, vec![], vec![t]);
            if let Some(p) = prev {
                b.dep(o, p);
            }
            prev = Some(o);
        }
        let bwd = b.compute("bwd", 1e6, 0, vec![a1, a2], vec![sink]);
        b.dep(bwd, prev.unwrap());
        let g0 = b.build();
        let order = g0.topo_order().unwrap();
        let policy = OffloadPolicy { max_candidates: 1, ..Default::default() };
        let (plans, _) = select_candidates(&g0, &order, &hw(), &policy);
        assert_eq!(plans.len(), 1);
        // Biggest first.
        assert_eq!(g0.tensor(plans[0].tensor).name, "a1");
    }
}
