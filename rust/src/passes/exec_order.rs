//! Graph-Driven Execution-Order Optimization — Algorithm 1 of the paper.
//!
//! The relative order of independent operators is unspecified in the IR;
//! this pass pins it. Starting from a valid topological order, each cache
//! operator `c` is moved to the feasible position `p*` minimising
//!
//! ```text
//! C(p) = alpha * exposed_latency(c, p) + beta * residency_byte_time(c, p)
//! ```
//!
//! exposed latency = how long c's first consumer `u` stalls waiting for the
//! transfer; residency byte-time = tensor bytes × how long the prefetched
//! data sits idle in device memory before `u` (too-early prefetch, Fig. 4b).
//! Both terms are evaluated against the compute-time prefix sums of the
//! current order, with DMA-stream serialisation among already-placed cache
//! operators taken into account.

use crate::graph::{Graph, OpId, OpKind};
use crate::sim::{duration_us, stream_of, HwConfig, Stream};

/// Cost-model weights / ablation switches.
#[derive(Debug, Clone)]
pub struct ExecOrderConfig {
    /// Weight of exposed transfer latency (us).
    pub alpha: f64,
    /// Weight of residency byte-time (byte·us, scaled by 1e-9 to keep the
    /// two terms comparable).
    pub beta: f64,
    /// Ablation: disable the latency term (prefetch placed latest).
    pub latency_term: bool,
    /// Ablation: disable the residency term (prefetch placed earliest).
    pub residency_term: bool,
}

impl Default for ExecOrderConfig {
    /// `beta` is deliberately small: exposed latency is pure slowdown,
    /// residency is a soft memory cost. 0.01 means "1 GB idling 100 us
    /// hurts as much as 1 us of stall" -- residency decides among the
    /// zero-exposure placements rather than trading stalls for memory
    /// (Fig. 4(c): no stalls AND no needless residency).
    fn default() -> Self {
        Self { alpha: 1.0, beta: 0.01, latency_term: true, residency_term: true }
    }
}

/// Outcome of the refinement pass.
#[derive(Debug, Clone)]
pub struct Refinement {
    pub order: Vec<OpId>,
    /// Number of cache operators moved from their initial position.
    pub moved: usize,
    /// Number of positions evaluated (perf counter for §Perf).
    pub evaluated: usize,
}

/// Algorithm 1: refine the execution order of cache operators.
///
/// Mutates `graph`: each placed cache operator is *anchored* with a control
/// dependency on the compute op immediately preceding its chosen position —
/// this is how the compiler materialises "issue the transfer HERE" in an IR
/// whose streams otherwise launch independent ops as early as possible
/// (Fig. 3(c)'s statically-orchestrated DMA).
pub fn refine(graph: &mut Graph, hw: &HwConfig, cfg: &ExecOrderConfig) -> Refinement {
    let init = graph.topo_order().expect("refine: graph must be acyclic");
    refine_from(graph, init, hw, cfg)
}

/// Algorithm 1 starting from a caller-supplied topological order.
pub fn refine_from(
    graph: &mut Graph,
    mut order: Vec<OpId>,
    hw: &HwConfig,
    cfg: &ExecOrderConfig,
) -> Refinement {
    debug_assert!(graph.is_valid_order(&order));
    let cache_ops: Vec<OpId> = order
        .iter()
        .copied()
        .filter(|&o| matches!(graph.op(o).kind, OpKind::Prefetch { .. } | OpKind::Store { .. }))
        .collect();

    let mut moved = 0usize;
    let mut evaluated = 0usize;

    // Hoisted invariants (§Perf): durations and stream assignments never
    // change during refinement; computing them once removes ~2M redundant
    // cost-model evaluations on 2000-op graphs. Dependency sets of the
    // not-yet-placed cache ops are invariant too — the only edges
    // refinement adds are anchor deps, which always point cache op →
    // compute op, so they never enter another cache op's pred/succ or
    // control-dependent sets before that op is placed. Hoisting them
    // removes the per-cache-op O(ops·deps) succ/dependent rescans.
    let dur: Vec<f64> = graph
        .ops
        .iter()
        .map(|o| duration_us(&o.kind, graph, hw))
        .collect();
    let streams: Vec<Stream> = graph.ops.iter().map(|o| stream_of(&o.kind)).collect();
    let is_cache = |o: OpId| matches!(graph.op(o).kind, OpKind::Prefetch { .. } | OpKind::Store { .. });
    let preds_of: Vec<Vec<OpId>> = cache_ops.iter().map(|&c| graph.preds(c)).collect();
    let succs_of: Vec<Vec<OpId>> = cache_ops.iter().map(|&c| graph.succs(c)).collect();
    // Non-cache ops control-depending on each cache op, in op-id order.
    let mut dependents: Vec<Vec<OpId>> = vec![Vec::new(); graph.ops.len()];
    for op in &graph.ops {
        if op.kind.is_cache_op() {
            continue;
        }
        for &d in &op.control_deps {
            if is_cache(d) {
                dependents[d].push(op.id);
            }
        }
    }

    // Position of every op in the live order, maintained across moves
    // instead of re-scanned per cache op.
    let mut pos = vec![usize::MAX; graph.ops.len()];
    for (i, &o) in order.iter().enumerate() {
        pos[o] = i;
    }

    for (ci, &c) in cache_ops.iter().enumerate() {
        let cur = pos[c];
        // Work on the order *as if* c were removed: insertion index p in
        // that c-less order equals c's final position. Rather than
        // materialising the c-less order (a clone per cache op), positions
        // are mapped through `rp` — an op past c shifts down by one. All
        // per-position quantities are O(1) lookups into prefix sums built
        // once per cache op (§Perf: this replaced an O(n) re-scan per
        // candidate position).
        let rp = |o: OpId| {
            let p = pos[o];
            if p == usize::MAX || p < cur {
                p
            } else {
                p - 1
            }
        };
        let lo = preds_of[ci].iter().map(|&q| rp(q) + 1).max().unwrap_or(0);
        let n = order.len() - 1;
        let hi = succs_of[ci].iter().map(|&s| rp(s)).min().unwrap_or(n);
        if lo > hi {
            continue;
        }

        // Prefix sums over the c-less order: compute time and
        // same-DMA-stream time.
        let my_stream = streams[c];
        let mut pre_compute = vec![0.0f64; n + 1];
        let mut pre_stream = vec![0.0f64; n + 1];
        let mut i = 0usize;
        for &o in order.iter() {
            if o == c {
                continue;
            }
            let d = dur[o];
            let s = streams[o];
            pre_compute[i + 1] = pre_compute[i] + if s == Stream::Compute { d } else { 0.0 };
            pre_stream[i + 1] = pre_stream[i] + if s == my_stream { d } else { 0.0 };
            i += 1;
        }

        // First non-cache consumer of c's tensor (or control-dependent op)
        // within/after the feasible window -- consumers before `lo` (e.g.
        // forward-pass uses preceding the Store) are not this cache op's
        // target.
        let u_pos = first_consumer_pos(graph, c, &dependents[c], &rp, lo);
        let u_ready = u_pos.map(|p| pre_compute[p]).unwrap_or(pre_compute[n]);

        let dur_c = dur[c];
        let bytes = graph.op(c).kind.cache_tensor().map(|t| graph.tensor(t).bytes).unwrap_or(0);
        let is_prefetch = matches!(graph.op(c).kind, OpKind::Prefetch { .. });

        let mut best_pos = cur.min(n);
        let mut best_cost = f64::INFINITY;
        for p in lo..=hi.min(n) {
            evaluated += 1;
            let issue = pre_compute[p].max(pre_stream[p]);
            let done = issue + dur_c;
            let mut cost = 0.0;
            if is_prefetch {
                if cfg.latency_term {
                    cost += cfg.alpha * (done - u_ready).max(0.0);
                }
                if cfg.residency_term {
                    cost += cfg.beta * (u_ready - done).max(0.0) * bytes as f64 * 1e-9;
                }
                cost -= 1e-9 * p as f64; // tie-break: later = less residency
            } else {
                if cfg.residency_term {
                    cost += cfg.beta * done * bytes as f64 * 1e-9;
                }
                cost += 1e-9 * p as f64; // tie-break: earlier frees sooner
            }
            if cost < best_cost - 1e-12 {
                best_cost = cost;
                best_pos = p;
            }
        }
        let final_pos = if best_pos != cur {
            order.remove(cur);
            order.insert(best_pos, c);
            // Only positions between the two endpoints shifted.
            for i in best_pos.min(cur)..=best_pos.max(cur) {
                pos[order[i]] = i;
            }
            moved += 1;
            best_pos
        } else {
            cur
        };
        // Anchor: issue the transfer after the op now preceding it.
        if let Some(&anchor) = order[..final_pos]
            .iter()
            .rev()
            .find(|&&o| matches!(graph.op(o).kind, OpKind::Compute { .. }))
        {
            graph.add_control_dep(c, anchor);
        }
        debug_assert!(graph.is_valid_order(&order), "Algorithm 1 broke topology");
    }
    Refinement { order, moved, evaluated }
}

/// Position (in a c-less order, via the `rp` position map) of the first
/// non-cache consumer of c's tensor, including ops control-dependent on c
/// (precomputed by the caller).
fn first_consumer_pos(
    graph: &Graph,
    c: OpId,
    ctrl_dependents: &[OpId],
    rp: &dyn Fn(OpId) -> usize,
    lo: usize,
) -> Option<usize> {
    let mut best: Option<usize> = None;
    let mut consider = |id: OpId| {
        let p = rp(id);
        if p != usize::MAX && p >= lo {
            best = Some(best.map_or(p, |b| b.min(p)));
        }
    };
    if let Some(t) = graph.op(c).kind.cache_tensor() {
        for &u in graph.consumers_of(t) {
            if u != c && !graph.op(u).kind.is_cache_op() {
                consider(u);
            }
        }
    }
    for &id in ctrl_dependents {
        consider(id);
    }
    best
}

/// Feasible insertion positions for op `c` in `order`: after its last
/// predecessor, before its first successor ("Pos_c" in Algorithm 1).
/// Returned as inclusive position bounds for c itself.
pub fn feasible_range(graph: &Graph, order: &[OpId], c: OpId) -> (usize, usize) {
    let mut pos = vec![usize::MAX; graph.ops.len()];
    for (i, &o) in order.iter().enumerate() {
        pos[o] = i;
    }
    let lo = graph
        .preds(c)
        .iter()
        .map(|&p| pos[p] + 1)
        .max()
        .unwrap_or(0);
    let hi = graph
        .succs(c)
        .iter()
        .map(|&s| pos[s].saturating_sub(1))
        .min()
        .unwrap_or(order.len() - 1);
    (lo, hi.min(order.len() - 1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{GraphBuilder, Tier};
    use crate::sim::simulate;

    fn hw() -> HwConfig {
        HwConfig::test_default()
    }

    /// n compute ops à `op_us`, op k consumes a remote weight (w_bytes).
    fn weighted_chain(n: usize, k: usize, op_us: f64, w_bytes: u64) -> (crate::graph::Graph, OpId) {
        let mut b = GraphBuilder::new();
        let w = b.tensor("w", w_bytes, Tier::Remote);
        let pf = b.prefetch("pf.w", w);
        let mut prev = None;
        for i in 0..n {
            let t = b.tensor(&format!("a{i}"), 0, Tier::Device);
            let mut inputs = prev.map(|p| vec![p]).unwrap_or_default();
            if i == k {
                inputs.push(w);
            }
            let o = b.compute(&format!("c{i}"), op_us * 1e6, 0, inputs, vec![t]);
            if i == k {
                b.dep(o, pf);
            }
            prev = Some(t);
        }
        (b.build(), pf)
    }

    #[test]
    fn prefetch_moved_to_hide_latency_without_early_residency() {
        // 10 ops à 10us; op 8 needs a 30us transfer. JIT position: issue
        // ~at op 5 (30us before use). Default topo puts pf first (id 0).
        let (mut g, pf) = weighted_chain(10, 8, 10.0, 30_000);
        let r = refine(&mut g, &hw(), &ExecOrderConfig::default());
        assert!(g.is_valid_order(&r.order));
        let sim = simulate(&g, &r.order, &hw());
        // No exposure...
        assert!(sim.exposed_comm_us < 1e-6, "exposed {}", sim.exposed_comm_us);
        // ...and prefetch did not stay at the very front.
        let pf_pos = r.order.iter().position(|&x| x == pf).unwrap();
        assert!(pf_pos >= 4, "prefetch at {pf_pos}, want just-in-time");
    }

    #[test]
    fn latency_only_ablation_prefetches_early() {
        let (mut g, pf) = weighted_chain(10, 8, 10.0, 30_000);
        let cfg = ExecOrderConfig { residency_term: false, ..Default::default() };
        let r = refine(&mut g, &hw(), &cfg);
        let pf_pos = r.order.iter().position(|&x| x == pf).unwrap();
        // Without the residency penalty the earliest no-stall position wins
        // (ties break toward later, but any position <= JIT point is
        // zero-cost only at/before the earliest... latency-only keeps all
        // zero-exposure placements equal; tie-break picks the latest).
        let sim = simulate(&g, &r.order, &hw());
        assert!(sim.exposed_comm_us < 1e-6);
        let _ = pf_pos;
    }

    #[test]
    fn residency_only_ablation_exposes_latency() {
        let (mut g, _pf) = weighted_chain(10, 8, 10.0, 30_000);
        let cfg = ExecOrderConfig { latency_term: false, ..Default::default() };
        let r = refine(&mut g, &hw(), &cfg);
        let sim = simulate(&g, &r.order, &hw());
        // Prefetch pushed as late as possible -> transfer exposed.
        assert!(sim.exposed_comm_us > 1.0, "exposed {}", sim.exposed_comm_us);
    }

    #[test]
    fn refinement_never_breaks_topology() {
        for n in [3usize, 6, 12] {
            for k in 0..n {
                let (mut g, _) = weighted_chain(n, k, 5.0, 10_000);
                let r = refine(&mut g, &hw(), &ExecOrderConfig::default());
                assert!(g.is_valid_order(&r.order), "n={n} k={k}");
            }
        }
    }

    #[test]
    fn refined_no_worse_than_program_order() {
        // Makespan under refined order must not regress vs the initial
        // topological order, across several shapes.
        for (n, k, op_us, bytes) in
            [(8, 6, 10.0, 40_000u64), (12, 3, 4.0, 8_000), (5, 4, 20.0, 100_000)]
        {
            let (mut g, _) = weighted_chain(n, k, op_us, bytes);
            let base_order = g.topo_order().unwrap();
            let base = simulate(&g, &base_order, &hw());
            let r = refine(&mut g, &hw(), &ExecOrderConfig::default());
            let opt = simulate(&g, &r.order, &hw());
            assert!(
                opt.makespan_us <= base.makespan_us + 1e-6,
                "regressed: {} > {} (n={n} k={k})",
                opt.makespan_us,
                base.makespan_us
            );
        }
    }

    #[test]
    fn multiple_prefetches_serialise_on_dma_stream() {
        // Two weights consumed by ops 6 and 8; transfers 25us each.
        let mut b = GraphBuilder::new();
        let w1 = b.tensor("w1", 25_000, Tier::Remote);
        let w2 = b.tensor("w2", 25_000, Tier::Remote);
        let pf1 = b.prefetch("pf1", w1);
        let pf2 = b.prefetch("pf2", w2);
        let mut prev = None;
        for i in 0..10 {
            let t = b.tensor(&format!("a{i}"), 0, Tier::Device);
            let mut inputs = prev.map(|p| vec![p]).unwrap_or_default();
            if i == 6 {
                inputs.push(w1);
            }
            if i == 8 {
                inputs.push(w2);
            }
            let o = b.compute(&format!("c{i}"), 10e6, 0, inputs, vec![t]);
            if i == 6 {
                b.dep(o, pf1);
            }
            if i == 8 {
                b.dep(o, pf2);
            }
            prev = Some(t);
        }
        let mut g = b.build();
        let r = refine(&mut g, &hw(), &ExecOrderConfig::default());
        let sim = simulate(&g, &r.order, &hw());
        assert!(sim.exposed_comm_us < 1e-6, "exposed {}", sim.exposed_comm_us);
        assert!(g.is_valid_order(&r.order));
    }

    #[test]
    fn evaluated_counter_counts_positions() {
        let (mut g, _) = weighted_chain(10, 8, 10.0, 30_000);
        let r = refine(&mut g, &hw(), &ExecOrderConfig::default());
        assert!(r.evaluated > 0);
    }
}
