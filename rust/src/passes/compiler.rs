//! The `Compiler` session API: a trait-based pass pipeline with a
//! memoising analysis cache, an IR verifier, and fallible, diagnostic-rich
//! compilation.
//!
//! The paper's thesis is that memory management belongs in the compiler's
//! optimisation framework; this module is that framework. A [`Compiler`]
//! is a configured *session*: hardware, policy, and an ordered list of
//! [`Pass`]es that each see the mutable [`Graph`], a shared
//! [`AnalysisCache`], and the immutable [`PassCtx`]. New optimisations
//! (recompute-vs-offload, SLO-aware transfer throttling, transfer elision)
//! register a `Pass` instead of forking the pipeline entry point.
//!
//! ```no_run
//! use hyperoffload::graph::GraphBuilder;
//! use hyperoffload::passes::Compiler;
//! use hyperoffload::sim::HwConfig;
//!
//! let mut g = GraphBuilder::linear_chain(8, 1e9, 1 << 20);
//! let report = Compiler::new(HwConfig::ascend910c_like())
//!     .verify(true)
//!     .compile(&mut g)
//!     .expect("compile");
//! assert!(g.is_valid_order(&report.order));
//! ```

use std::fmt;
use std::rc::Rc;

use crate::analysis::{self, LintConfig, LintLevel};
use crate::graph::{CycleError, Graph, Mutation, OpId, OpKind, Reach, Tier, TrackedSet};
use crate::sim::HwConfig;

use super::exec_order::{self, ExecOrderConfig};
use super::lifetime::LifetimeAnalysis;
use super::prefetch_insert::{self, OffloadPolicy};

/// How serious a [`Diagnostic`] is. Only `Error` fails a verified compile.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    Info,
    Warning,
    Error,
}

/// One structured message from a pass or the verifier.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    pub severity: Severity,
    /// Name of the pass that produced it.
    pub pass: String,
    /// The op the message is anchored to, when there is one.
    pub op: Option<OpId>,
    pub message: String,
}

impl Diagnostic {
    pub fn new(severity: Severity, pass: &str, message: impl Into<String>) -> Self {
        Self { severity, pass: pass.to_string(), op: None, message: message.into() }
    }

    pub fn info(pass: &str, message: impl Into<String>) -> Self {
        Self::new(Severity::Info, pass, message)
    }

    pub fn warning(pass: &str, message: impl Into<String>) -> Self {
        Self::new(Severity::Warning, pass, message)
    }

    pub fn error(pass: &str, message: impl Into<String>) -> Self {
        Self::new(Severity::Error, pass, message)
    }

    pub fn with_op(mut self, op: OpId) -> Self {
        self.op = Some(op);
        self
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let sev = match self.severity {
            Severity::Info => "info",
            Severity::Warning => "warning",
            Severity::Error => "error",
        };
        match self.op {
            Some(op) => write!(f, "[{sev}] {}: op {op}: {}", self.pass, self.message),
            None => write!(f, "[{sev}] {}: {}", self.pass, self.message),
        }
    }
}

/// Why a compile session failed. Replaces the old panic paths
/// (`expect("compile: cyclic graph")`) with a typed, recoverable error.
#[derive(Debug, Clone)]
pub enum CompileError {
    /// The graph has a dependency cycle; `culprit_ops` are the ops Kahn's
    /// algorithm could not order.
    Cycle { culprit_ops: Vec<OpId> },
    /// The IR verifier found invariant violations after `pass` ran.
    Verify { pass: String, violations: Vec<Diagnostic> },
    /// A pass failed for a pass-specific reason.
    Pass { pass: String, message: String },
}

impl fmt::Display for CompileError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CompileError::Cycle { culprit_ops } => write!(
                f,
                "graph has a dependency cycle through {} op(s): {:?}",
                culprit_ops.len(),
                &culprit_ops[..culprit_ops.len().min(8)]
            ),
            CompileError::Verify { pass, violations } => {
                write!(
                    f,
                    "IR verification failed after pass '{pass}': {} violation(s)",
                    violations.len()
                )?;
                for d in violations.iter().take(4) {
                    write!(f, "; {}", d.message)?;
                }
                Ok(())
            }
            CompileError::Pass { pass, message } => write!(f, "pass '{pass}' failed: {message}"),
        }
    }
}

impl std::error::Error for CompileError {}

impl From<CycleError> for CompileError {
    fn from(e: CycleError) -> Self {
        CompileError::Cycle { culprit_ops: e.culprit_ops }
    }
}

/// How a cache query was served (internal; drives the per-analysis
/// counters).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Served {
    /// Version matched: the cached value was returned as-is.
    Hit,
    /// The cached value was patched forward from the graph's mutation
    /// journal (no full recomputation).
    Delta,
    /// Full recomputation.
    Miss,
}

/// Memoised analyses shared by all passes of one session.
///
/// Results are keyed on [`Graph::version`] and handed out as shared
/// [`Rc`] views — a cache hit is a pointer bump, never a clone of the
/// order / lifetime tables. When the graph *has* mutated, the cache first
/// replays the graph's bounded mutation journal
/// ([`Graph::mutations_since`]): purely local mutations (op appends,
/// forward control-dep / input wiring) *delta-update* the cached topo
/// order and lifetime table instead of recomputing them; anything
/// non-local (op removal, input replacement, journal truncation) falls
/// back to full recomputation. Delta results are bit-identical to full
/// recomputation — property-tested (P13) in rust/tests/.
#[derive(Debug)]
pub struct AnalysisCache {
    topo: Option<(u64, Rc<Vec<OpId>>)>,
    lifetime: Option<(u64, Rc<LifetimeAnalysis>)>,
    /// Execution order pinned by an order-producing pass (exec-order),
    /// version-keyed like the analyses. Later decision passes (the SLO
    /// throttle) start from this instead of a raw topological order, so
    /// their speculate/validate baseline is the schedule the session would
    /// otherwise emit.
    pinned: Option<(u64, Rc<Vec<OpId>>)>,
    /// Cache-op ancestor reachability ([`Reach`] over
    /// [`TrackedSet::CacheOps`]), shared by `verify_ir` and TransferSan.
    /// Version-keyed like the analyses; journal-patched on local
    /// mutations.
    reach: Option<(u64, Rc<Reach>)>,
    /// Journal-driven delta updates enabled (default). Off = every
    /// version bump forces full recomputation, the pre-incremental
    /// behaviour (kept togglable for A/B measurement — see
    /// `benches/hot_path.rs`).
    incremental: bool,
    /// Topo-order queries served from the cache unchanged.
    pub topo_hits: usize,
    /// Topo-order queries served by patching the cached order forward
    /// from the mutation journal.
    pub topo_deltas: usize,
    /// Topo-order queries requiring full recomputation.
    pub topo_misses: usize,
    /// Lifetime queries served from the cache unchanged.
    pub lifetime_hits: usize,
    /// Lifetime queries served by per-tensor delta update.
    pub lifetime_deltas: usize,
    /// Lifetime queries requiring full recomputation.
    pub lifetime_misses: usize,
    /// Reachability queries served from the cache unchanged.
    pub reach_hits: usize,
    /// Reachability queries served by journal-driven matrix patching.
    pub reach_deltas: usize,
    /// Reachability queries requiring a full matrix rebuild.
    pub reach_misses: usize,
}

impl Default for AnalysisCache {
    fn default() -> Self {
        Self {
            topo: None,
            lifetime: None,
            pinned: None,
            reach: None,
            incremental: true,
            topo_hits: 0,
            topo_deltas: 0,
            topo_misses: 0,
            lifetime_hits: 0,
            lifetime_deltas: 0,
            lifetime_misses: 0,
            reach_hits: 0,
            reach_deltas: 0,
            reach_misses: 0,
        }
    }
}

impl AnalysisCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Enable/disable journal-driven delta updates (on by default).
    pub fn set_incremental(&mut self, on: bool) {
        self.incremental = on;
    }

    /// Queries served without full recomputation (version hits + journal
    /// delta updates), across both analyses.
    pub fn hits(&self) -> usize {
        self.topo_hits + self.topo_deltas + self.lifetime_hits + self.lifetime_deltas
    }

    /// Queries that fell back to full recomputation, across both analyses.
    pub fn misses(&self) -> usize {
        self.topo_misses + self.lifetime_misses
    }

    /// The deterministic topological order of `g`: a shared view of the
    /// cached order on a version hit, a journal-patched extension of it on
    /// local mutations, a full recomputation otherwise.
    pub fn topo_order(&mut self, g: &Graph) -> Result<Rc<Vec<OpId>>, CompileError> {
        let (order, served) = self.topo_inner(g)?;
        match served {
            Served::Hit => self.topo_hits += 1,
            Served::Delta => self.topo_deltas += 1,
            Served::Miss => self.topo_misses += 1,
        }
        Ok(order)
    }

    /// [`topo_order`](Self::topo_order) without touching the topo
    /// counters — used internally by `lifetimes()` so a cold lifetime
    /// query counts once (as a lifetime miss), not once per analysis it
    /// happens to warm.
    fn topo_inner(&mut self, g: &Graph) -> Result<(Rc<Vec<OpId>>, Served), CompileError> {
        let v = g.version();
        if let Some((cv, o)) = &self.topo {
            if *cv == v {
                return Ok((Rc::clone(o), Served::Hit));
            }
            if self.incremental {
                if let Some(patched) = Self::patch_topo(g, *cv, o) {
                    let patched = Rc::new(patched);
                    self.topo = Some((v, Rc::clone(&patched)));
                    return Ok((patched, Served::Delta));
                }
            }
        }
        let order = Rc::new(g.topo_order_detailed()?);
        self.topo = Some((v, Rc::clone(&order)));
        Ok((order, Served::Miss))
    }

    /// Replay the mutation journal since `cached_v` over the cached
    /// canonical order. Returns the patched canonical order of the current
    /// graph, or `None` when any mutation is non-local (or the journal
    /// window was truncated) and the caller must recompute.
    ///
    /// Why patching is exact (Kahn, min-id tie-break = insertion order):
    /// an appended op has the maximum id and — checked per event — nothing
    /// already placed depends on it, so the canonical order is the old
    /// order with the op appended; a new edge `d → o` with `d` placed
    /// before `o` removes candidates from Kahn's ready set without ever
    /// changing its minimum, so the canonical order is unchanged. Any
    /// backward edge bails out to full recomputation.
    fn patch_topo(g: &Graph, cached_v: u64, cached: &Rc<Vec<OpId>>) -> Option<Vec<OpId>> {
        let muts = g.mutations_since(cached_v)?;
        // A removal/rewire anywhere in the window may have renumbered ops:
        // ids in earlier events (and the cached order) are then meaningless
        // against the current graph, so bail before touching them.
        if muts.iter().any(|m| matches!(m, Mutation::NonLocal)) {
            return None;
        }
        let n = g.ops.len();
        let mut order: Vec<OpId> = (**cached).clone();
        let mut pos = vec![usize::MAX; n];
        for (i, &o) in order.iter().enumerate() {
            if o >= n {
                return None; // cached order predates an (unjournalled) removal
            }
            pos[o] = i;
        }
        for m in muts {
            match m {
                Mutation::TensorAdded { .. }
                | Mutation::TensorMeta
                | Mutation::OpRetargeted { .. } => {}
                Mutation::OpAdded { op } => {
                    // Safe to append only if nothing already placed
                    // consumes one of the new op's outputs (a consumer can
                    // be registered before its producer exists).
                    for &t in &g.op(op).outputs {
                        for &c in g.consumers_of(t) {
                            if c != op && pos[c] != usize::MAX {
                                return None;
                            }
                        }
                    }
                    pos[op] = order.len();
                    order.push(op);
                }
                Mutation::ControlDepAdded { op, dep } => {
                    if pos[dep] == usize::MAX || pos[op] == usize::MAX || pos[dep] >= pos[op] {
                        return None;
                    }
                }
                Mutation::InputAdded { op, tensor } => {
                    // Edge producer(tensor) → op, if the producer existed
                    // at event time; a producer appended later is caught by
                    // its own OpAdded consumer check above.
                    if let Some(p) = g.producer_of(tensor) {
                        if p != op && pos[p] != usize::MAX && pos[p] >= pos[op] {
                            return None;
                        }
                    }
                }
                Mutation::NonLocal => unreachable!("filtered above"),
            }
        }
        if order.len() != n {
            return None;
        }
        debug_assert!(g.is_valid_order(&order), "patched topo order invalid");
        Some(order)
    }

    /// Lifetime analysis of `g` under its current topological order: a
    /// shared view on a version hit; on purely local mutations only the
    /// tensors the mutations touched are re-analysed.
    pub fn lifetimes(&mut self, g: &Graph) -> Result<Rc<LifetimeAnalysis>, CompileError> {
        let v = g.version();
        if let Some((cv, la)) = &self.lifetime {
            if *cv == v {
                self.lifetime_hits += 1;
                return Ok(Rc::clone(la));
            }
        }
        let (order, _) = self.topo_inner(g)?;
        if self.incremental {
            if let Some((cv, la)) = self.lifetime.take() {
                if let Some(patched) = Self::patch_lifetimes(g, cv, &la, &order) {
                    let patched = Rc::new(patched);
                    self.lifetime = Some((v, Rc::clone(&patched)));
                    self.lifetime_deltas += 1;
                    return Ok(patched);
                }
                self.lifetime = Some((cv, la));
            }
        }
        self.lifetime_misses += 1;
        let la = Rc::new(LifetimeAnalysis::run(g, &order));
        self.lifetime = Some((v, Rc::clone(&la)));
        Ok(la)
    }

    /// Re-analyse only the tensors touched by the journalled mutations
    /// since `cached_v`, under the (already current) `order`. `None` when
    /// a mutation is non-local or the positions of pre-existing ops moved.
    fn patch_lifetimes(
        g: &Graph,
        cached_v: u64,
        cached: &Rc<LifetimeAnalysis>,
        order: &[OpId],
    ) -> Option<LifetimeAnalysis> {
        let muts = g.mutations_since(cached_v)?;
        if muts.iter().any(|m| matches!(m, Mutation::NonLocal)) {
            return None;
        }
        let mut pos = vec![usize::MAX; g.ops.len()];
        for (i, &o) in order.iter().enumerate() {
            pos[o] = i;
        }
        // Per-tensor results are valid only while every pre-existing op
        // kept its position (appends only extend the order).
        let old_n = cached.pos.len();
        if old_n > pos.len() || pos[..old_n] != cached.pos[..] {
            return None;
        }
        let mut la = LifetimeAnalysis {
            lifetimes: cached.lifetimes.clone(),
            pos: pos.clone(),
        };
        for m in muts {
            match m {
                Mutation::TensorAdded { tensor } => {
                    la.lifetimes.insert(tensor, super::lifetime::lifetime_of(g, tensor, &pos));
                }
                Mutation::OpAdded { op } => {
                    let o = g.op(op);
                    for &t in o.inputs.iter().chain(o.outputs.iter()) {
                        la.lifetimes.insert(t, super::lifetime::lifetime_of(g, t, &pos));
                    }
                }
                Mutation::InputAdded { tensor, .. } => {
                    la.lifetimes.insert(tensor, super::lifetime::lifetime_of(g, tensor, &pos));
                }
                Mutation::ControlDepAdded { .. }
                | Mutation::TensorMeta
                | Mutation::OpRetargeted { .. } => {}
                Mutation::NonLocal => unreachable!("filtered above"),
            }
        }
        Some(la)
    }

    /// Cache-op ancestor reachability of `g` — the [`Reach`] matrix over
    /// [`TrackedSet::CacheOps`] shared by the verifier and the TransferSan
    /// analyzer: a shared view on a version hit, a journal-patched matrix
    /// on purely local mutations, a full rebuild otherwise.
    ///
    /// Counted by the `reach_*` counters, deliberately *outside*
    /// [`hits`](Self::hits)/[`misses`](Self::misses) (whose exact values
    /// predate this analysis and are pinned by tests).
    pub fn reach(&mut self, g: &Graph) -> Result<Rc<Reach>, CompileError> {
        let v = g.version();
        if let Some((cv, r)) = &self.reach {
            if *cv == v {
                self.reach_hits += 1;
                return Ok(Rc::clone(r));
            }
        }
        let (order, _) = self.topo_inner(g)?;
        if self.incremental {
            if let Some((cv, mut r)) = self.reach.take() {
                if let Some(muts) = g.mutations_since(cv) {
                    // A failed update may leave the (uniquely-owned) clone
                    // half-patched; it is discarded either way.
                    if Rc::make_mut(&mut r).update(g, &order, &muts) {
                        self.reach = Some((v, Rc::clone(&r)));
                        self.reach_deltas += 1;
                        return Ok(r);
                    }
                }
            }
        }
        self.reach_misses += 1;
        let r = Rc::new(Reach::ancestors(g, &order, TrackedSet::CacheOps));
        self.reach = Some((v, Rc::clone(&r)));
        Ok(r)
    }

    /// Pin `order` as the session's current execution order for `g` (valid
    /// until the next structural mutation).
    pub fn pin_order(&mut self, g: &Graph, order: Vec<OpId>) {
        debug_assert!(g.is_valid_order(&order), "pin_order: invalid order");
        self.pinned = Some((g.version(), Rc::new(order)));
    }

    /// The pinned execution order if one is fresh for `g`, else the plain
    /// topological order.
    pub fn pinned_or_topo(&mut self, g: &Graph) -> Result<Rc<Vec<OpId>>, CompileError> {
        if let Some((v, o)) = &self.pinned {
            if *v == g.version() {
                return Ok(Rc::clone(o));
            }
        }
        self.topo_order(g)
    }

    /// Drop all cached analyses (they would also lapse naturally on the
    /// next version mismatch).
    pub fn invalidate(&mut self) {
        self.topo = None;
        self.lifetime = None;
        self.pinned = None;
        self.reach = None;
    }
}

/// Immutable session context handed to every pass.
#[derive(Debug, Clone)]
pub struct PassCtx {
    pub hw: HwConfig,
    pub policy: OffloadPolicy,
    pub exec: ExecOrderConfig,
    /// Latency SLO for the compiled schedule (us): the training step-time
    /// target or the serving decode/step budget. Consumed by the SLO
    /// throttle pass; `None` disables SLO shaping.
    pub slo_us: Option<f64>,
    /// Fabric-contention slowdown (≥ 1.0) the decision passes assume on
    /// the device↔pool link — the compile-time counterpart of
    /// [`Fabric::slowdown`](crate::sim::Fabric::slowdown) when sibling
    /// devices share the SuperNode fabric. 1.0 = private link.
    pub dma_contention: f64,
}

impl PassCtx {
    /// The session hardware with the assumed fabric contention folded into
    /// the device↔pool link rates — what decision passes cost transfers
    /// (and speculate/validate simulations) against.
    pub fn contended_hw(&self) -> HwConfig {
        let mut hw = self.hw.clone();
        if self.dma_contention > 1.0 {
            hw.d2r_gbps /= self.dma_contention;
            hw.r2d_gbps /= self.dma_contention;
        }
        hw
    }
}

/// What one pass did: structured counters + diagnostics, plus the
/// execution order for order-producing passes.
#[derive(Debug, Clone, Default)]
pub struct PassReport {
    /// Name of the pass this report came from.
    pub pass: String,
    /// Cache-op pairs inserted (store/prefetch; both ids equal for
    /// store-less prefetches).
    pub inserted: Vec<(OpId, OpId)>,
    /// Offload candidates rejected.
    pub rejected: usize,
    /// Cache ops moved by order refinement.
    pub moved: usize,
    /// Transfer round trips elided.
    pub elided: usize,
    /// Offload round trips replaced by recompute subgraphs.
    pub recomputed: usize,
    /// Round trips rehomed to a deeper tier (Store retargeted + a Promote
    /// emitted ahead of reuse) by the tier-placement decision pass.
    pub retiered: usize,
    /// Rewrites committed by SLO throttling (vetoes + spills + splits +
    /// deferrals).
    pub throttled: usize,
    /// Placement detours (deep/peer Store + Promote round trips) unwound
    /// by SLO throttling — a subset of `throttled`.
    pub vetoed: usize,
    /// Transfers split into chunked (partial-tensor) transfers by SLO
    /// throttling — a subset of `throttled`.
    pub chunked: usize,
    /// Deferrable Store bytes spilled out of the schedule by SLO
    /// throttling (they stay resident; the caller moves them later).
    pub deferred_bytes: u64,
    /// Execution order produced by this pass, if it pins one.
    pub order: Option<Vec<OpId>>,
    pub diagnostics: Vec<Diagnostic>,
}

impl PassReport {
    pub fn new(pass: &str) -> Self {
        Self { pass: pass.to_string(), ..Default::default() }
    }
}

/// A compiler pass over the HyperOffload IR.
///
/// Passes mutate the graph, read/derive analyses through the shared
/// [`AnalysisCache`], and report what they did. Returning an error aborts
/// the session. See the `passes` module docs for a worked custom-pass
/// example.
pub trait Pass {
    /// Stable, kebab-case pass name (used in diagnostics and for pipeline
    /// positioning).
    fn name(&self) -> &'static str;

    /// Run the pass over `g`.
    fn run(
        &mut self,
        g: &mut Graph,
        cache: &mut AnalysisCache,
        ctx: &PassCtx,
    ) -> Result<PassReport, CompileError>;
}

/// §3.2 tensor lifetime analysis: warms the [`AnalysisCache`] and reports
/// how many tensors expose an offloadable idle window.
#[derive(Debug, Clone, Copy, Default)]
pub struct LifetimePass;

impl Pass for LifetimePass {
    fn name(&self) -> &'static str {
        "lifetime"
    }

    fn run(
        &mut self,
        g: &mut Graph,
        cache: &mut AnalysisCache,
        _ctx: &PassCtx,
    ) -> Result<PassReport, CompileError> {
        let la = cache.lifetimes(g)?;
        let windowed = la.lifetimes.values().filter(|l| l.max_idle_gap >= 2).count();
        let mut rep = PassReport::new(self.name());
        rep.diagnostics.push(Diagnostic::info(
            self.name(),
            format!(
                "{} tensors analysed, {windowed} with an idle window of >= 2 ops",
                g.tensors.len()
            ),
        ));
        Ok(rep)
    }
}

/// §4.2.2 offload-candidate selection + cache-operator insertion, using
/// the cached lifetime analysis.
#[derive(Debug, Clone, Copy, Default)]
pub struct PrefetchInsertPass;

impl Pass for PrefetchInsertPass {
    fn name(&self) -> &'static str {
        "prefetch-insert"
    }

    fn run(
        &mut self,
        g: &mut Graph,
        cache: &mut AnalysisCache,
        ctx: &PassCtx,
    ) -> Result<PassReport, CompileError> {
        let order = cache.topo_order(g)?;
        let la = cache.lifetimes(g)?;
        let res = prefetch_insert::run_with(g, &order, &la, &ctx.hw, &ctx.policy);
        let mut rep = PassReport::new(self.name());
        rep.diagnostics.push(Diagnostic::info(
            self.name(),
            format!(
                "{} cache-op pairs inserted, {} candidates rejected as unprofitable",
                res.inserted.len(),
                res.rejected
            ),
        ));
        rep.inserted = res.inserted;
        rep.rejected = res.rejected;
        Ok(rep)
    }
}

/// §4.3 Algorithm 1 execution-order refinement; pins the session's final
/// order.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExecOrderPass;

impl Pass for ExecOrderPass {
    fn name(&self) -> &'static str {
        "exec-order"
    }

    fn run(
        &mut self,
        g: &mut Graph,
        cache: &mut AnalysisCache,
        ctx: &PassCtx,
    ) -> Result<PassReport, CompileError> {
        let init = cache.topo_order(g)?;
        let r = exec_order::refine_from(g, (*init).clone(), &ctx.hw, &ctx.exec);
        let mut rep = PassReport::new(self.name());
        rep.diagnostics.push(Diagnostic::info(
            self.name(),
            format!("{} cache ops moved ({} positions evaluated)", r.moved, r.evaluated),
        ));
        rep.moved = r.moved;
        cache.pin_order(g, r.order.clone());
        rep.order = Some(r.order);
        Ok(rep)
    }
}

/// Check the IR invariants the pipeline relies on, against a concrete
/// execution order:
///
/// 1. every op references only known tensors/ops, and cache ops list their
///    managed tensor as an input;
/// 2. `order` is a valid topological order of the whole graph;
/// 3. every consumer placed after a `Prefetch` is dependency-reachable
///    from it (streams run concurrently — mere placement after the
///    prefetch does not order *completion* before the consume, §4.2.1);
/// 4. walking `order`, no `Store`/`Detach` releases a tensor that has no
///    device residency (double release), and no op consumes a
///    cache-managed tensor while it is offloaded.
///
/// Returns all findings; callers decide whether `Error`s are fatal.
///
/// Builds the cache-op reachability matrix ad hoc; inside a compile
/// session prefer [`verify_ir_with`] and the [`AnalysisCache::reach`]
/// matrix, which is journal-patched across passes instead of rebuilt.
pub fn verify_ir(g: &Graph, order: &[OpId]) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    if !verify_structure_and_order(g, order, &mut diags) {
        return diags;
    }
    let reach = Reach::ancestors(g, order, TrackedSet::CacheOps);
    verify_semantics(g, order, &reach, &mut diags);
    diags
}

/// [`verify_ir`] against a prebuilt cache-op *ancestor* matrix (see
/// [`Reach::ancestors`] over [`TrackedSet::CacheOps`]). The matrix encodes
/// dep reachability, which is a property of the graph, not of any one
/// linearization — so a matrix built under the canonical topological order
/// is equally valid for verifying a pinned execution order.
pub fn verify_ir_with(g: &Graph, order: &[OpId], reach: &Reach) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    if !verify_structure_and_order(g, order, &mut diags) {
        return diags;
    }
    verify_semantics(g, order, reach, &mut diags);
    diags
}

/// Steps 1–2: structural checks + order validity. `false` = later stages
/// must not run (they index tensors/ops freely and trust `order`).
fn verify_structure_and_order(g: &Graph, order: &[OpId], diags: &mut Vec<Diagnostic>) -> bool {
    const PASS: &str = "verify";
    let nt = g.tensors.len();
    let n = g.ops.len();

    // 1. Structural checks; everything below indexes tensors/ops freely.
    let mut structural_ok = true;
    for op in &g.ops {
        for &t in op.inputs.iter().chain(op.outputs.iter()) {
            if t >= nt {
                diags.push(
                    Diagnostic::error(
                        PASS,
                        format!("op '{}' references dangling tensor {t}", op.name),
                    )
                    .with_op(op.id),
                );
                structural_ok = false;
            }
        }
        if let Some(t) = op.kind.cache_tensor() {
            if t >= nt {
                diags.push(
                    Diagnostic::error(
                        PASS,
                        format!("cache op '{}' manages dangling tensor {t}", op.name),
                    )
                    .with_op(op.id),
                );
                structural_ok = false;
            } else if !op.inputs.contains(&t) {
                diags.push(
                    Diagnostic::error(
                        PASS,
                        format!("cache op '{}' must list its tensor {t} as an input", op.name),
                    )
                    .with_op(op.id),
                );
            }
        }
        for &d in &op.control_deps {
            if d >= n {
                diags.push(
                    Diagnostic::error(
                        PASS,
                        format!("op '{}' control-depends on unknown op {d}", op.name),
                    )
                    .with_op(op.id),
                );
                structural_ok = false;
            }
        }
    }
    if !structural_ok {
        return false;
    }

    // 2. The order itself.
    if !g.is_valid_order(order) {
        diags.push(Diagnostic::error(
            PASS,
            "execution order is not a valid topological order of the graph",
        ));
        return false;
    }
    true
}

/// Steps 3–4: the semantic checks, against a cache-op ancestor `reach`
/// matrix (historically rebuilt here on every call; now built once per
/// graph version by [`AnalysisCache::reach`] and shared).
fn verify_semantics(g: &Graph, order: &[OpId], reach: &Reach, diags: &mut Vec<Diagnostic>) {
    const PASS: &str = "verify";
    let nt = g.tensors.len();
    let n = g.ops.len();
    let mut pos = vec![usize::MAX; n];
    for (i, &o) in order.iter().enumerate() {
        pos[o] = i;
    }

    // 3. Prefetch completion precedes EVERY later consumer — not just the
    // first. A later consumer on a parallel branch with no path from the
    // prefetch can start before the DMA completes even though it sits
    // after the prefetch in the order (streams run concurrently).
    // Consumers placed before the prefetch read the pre-offload copy and
    // are exempt (the residency walk below polices them).
    for &pf in reach.tracked() {
        let OpKind::Prefetch { tensor, .. } = g.op(pf).kind else { continue };
        for &c in g.consumers_of(tensor) {
            if c == pf || g.op(c).kind.is_cache_op() || pos[c] < pos[pf] {
                continue;
            }
            if !reach.contains(c, pf) {
                diags.push(
                    Diagnostic::error(
                        PASS,
                        format!(
                            "consumer '{}' of prefetch '{}' is not dependency-ordered \
                             after transfer completion",
                            g.op(c).name,
                            g.op(pf).name
                        ),
                    )
                    .with_op(c),
                );
            }
        }
    }

    // 4. Residency walk over cache-managed tensors. Alongside the device
    // residency bit, track *where* each offloaded copy lives (`cold_at`):
    // a Store parks the copy at its destination tier, a Promote moves it,
    // and a Prefetch must read it from where it actually is. Mismatches
    // are only reported when a cold tier (DRAM/CXL/SSD) is involved — the
    // legacy pipelines conflate Host and the pool, and that conflation
    // stays diagnostic-free.
    let mut managed = vec![false; nt];
    for op in &g.ops {
        if let Some(t) = op.kind.cache_tensor() {
            managed[t] = true;
        }
    }
    let mut resident: Vec<bool> = g
        .tensors
        .iter()
        .map(|t| t.home == Tier::Device && g.producer_of(t.id).is_none())
        .collect();
    let mut cold_at: Vec<Option<Tier>> = g
        .tensors
        .iter()
        .map(|t| (t.home != Tier::Device).then_some(t.home))
        .collect();
    // Peer (harvested-HBM) copies get the same where-is-the-copy
    // discipline as the cold tiers: a fetch from a peer the copy provably
    // left (a revocation demoted it) is an error, not a conflation.
    let cold_involved = |src: Tier, at: Option<Tier>| {
        src.is_cold() || src.is_peer() || at.is_some_and(|t| t.is_cold() || t.is_peer())
    };
    for &o in order {
        let op = g.op(o);
        match op.kind {
            OpKind::Prefetch { tensor, src } => {
                if resident[tensor] {
                    diags.push(
                        Diagnostic::warning(
                            PASS,
                            format!(
                                "prefetch '{}' re-loads already-resident tensor '{}'",
                                op.name,
                                g.tensor(tensor).name
                            ),
                        )
                        .with_op(op.id),
                    );
                }
                if cold_involved(src, cold_at[tensor]) && cold_at[tensor] != Some(src) {
                    diags.push(
                        Diagnostic::error(
                            PASS,
                            format!(
                                "prefetch '{}' reads tensor '{}' from tier {:?}, but its \
                                 offloaded copy is at {} (promotion missing?)",
                                op.name,
                                g.tensor(tensor).name,
                                src,
                                cold_at[tensor]
                                    .map_or("no tier".to_string(), |t| format!("{t:?}")),
                            ),
                        )
                        .with_op(op.id),
                    );
                }
                resident[tensor] = true;
            }
            OpKind::Store { tensor, dst } => {
                if !resident[tensor] {
                    diags.push(
                        Diagnostic::error(
                            PASS,
                            format!(
                                "'{}' releases tensor '{}' which has no device residency at \
                                 that point (double release?)",
                                op.name,
                                g.tensor(tensor).name
                            ),
                        )
                        .with_op(op.id),
                    );
                }
                resident[tensor] = false;
                cold_at[tensor] = Some(dst);
            }
            OpKind::Detach { tensor } => {
                if !resident[tensor] {
                    diags.push(
                        Diagnostic::error(
                            PASS,
                            format!(
                                "'{}' releases tensor '{}' which has no device residency at \
                                 that point (double release?)",
                                op.name,
                                g.tensor(tensor).name
                            ),
                        )
                        .with_op(op.id),
                    );
                }
                resident[tensor] = false;
            }
            OpKind::Promote { tensor, src, dst } => {
                // Moves the non-device copy; device residency is untouched.
                if cold_involved(src, cold_at[tensor]) && cold_at[tensor] != Some(src) {
                    diags.push(
                        Diagnostic::error(
                            PASS,
                            format!(
                                "promote '{}' moves tensor '{}' from tier {:?}, but its \
                                 offloaded copy is at {}",
                                op.name,
                                g.tensor(tensor).name,
                                src,
                                cold_at[tensor]
                                    .map_or("no tier".to_string(), |t| format!("{t:?}")),
                            ),
                        )
                        .with_op(op.id),
                    );
                }
                cold_at[tensor] = Some(dst);
            }
            _ => {
                for &t in &op.inputs {
                    if managed[t] && !resident[t] {
                        diags.push(
                            Diagnostic::error(
                                PASS,
                                format!(
                                    "op '{}' consumes tensor '{}' while it is offloaded \
                                     (released before use, or prefetch missing)",
                                    op.name,
                                    g.tensor(t).name
                                ),
                            )
                            .with_op(op.id),
                        );
                    }
                }
            }
        }
        for &t in &op.outputs {
            if g.tensor(t).home == Tier::Device {
                resident[t] = true;
            }
        }
    }
}

/// [`verify_ir`] as a pipeline stage: verifies against the cached topo
/// order and fails the session on any `Error`-severity finding. Prefer
/// `Compiler::verify(true)`, which runs the same checks between *every*
/// stage; use this to place one explicit checkpoint in a custom pipeline.
#[derive(Debug, Clone, Copy, Default)]
pub struct VerifyPass;

impl Pass for VerifyPass {
    fn name(&self) -> &'static str {
        "verify"
    }

    fn run(
        &mut self,
        g: &mut Graph,
        cache: &mut AnalysisCache,
        _ctx: &PassCtx,
    ) -> Result<PassReport, CompileError> {
        let order = cache.topo_order(g)?;
        let reach = cache.reach(g)?;
        let diags = check_verdict(self.name(), verify_ir_with(g, &order, &reach))?;
        let mut rep = PassReport::new(self.name());
        rep.diagnostics = diags;
        Ok(rep)
    }
}

/// Run the TransferSan analyzer over the session's current order and the
/// cached reachability matrix, route findings through the lint config, and
/// fail on `deny`-level findings like any verifier error.
fn run_sanitizer(
    stage: &str,
    graph: &Graph,
    cache: &mut AnalysisCache,
    ctx: &PassCtx,
    lints: &LintConfig,
    diagnostics: &mut Vec<Diagnostic>,
) -> Result<(), CompileError> {
    let order = cache.pinned_or_topo(graph)?;
    let reach = cache.reach(graph)?;
    let report = analysis::analyze(graph, &order, &reach, &ctx.hw);
    diagnostics.extend(check_verdict(stage, analysis::to_diagnostics(&report, lints))?);
    Ok(())
}

/// Split verifier findings: `Err` with the violations if any are
/// `Error`-severity, `Ok` with everything otherwise.
fn check_verdict(stage: &str, diags: Vec<Diagnostic>) -> Result<Vec<Diagnostic>, CompileError> {
    if diags.iter().any(|d| d.severity == Severity::Error) {
        Err(CompileError::Verify {
            pass: stage.to_string(),
            violations: diags.into_iter().filter(|d| d.severity == Severity::Error).collect(),
        })
    } else {
        Ok(diags)
    }
}

/// End-to-end compilation report: final order, aggregate counters (the old
/// two bare counters, kept for compatibility), and the structured per-pass
/// reports + diagnostics of the session API.
#[derive(Debug, Clone)]
pub struct CompileReport {
    /// Final, refined execution order.
    pub order: Vec<OpId>,
    /// Cache-op pairs inserted by insertion passes.
    pub inserted: Vec<(OpId, OpId)>,
    /// Offload candidates rejected (window too small — §5.1).
    pub rejected: usize,
    /// Cache ops moved by Algorithm 1.
    pub moved: usize,
    /// Transfer round trips elided (see `ElideRedundantTransfers`).
    pub elided: usize,
    /// Offload round trips replaced by recompute (see `RecomputeVsOffload`).
    pub recomputed: usize,
    /// Round trips rehomed to a deeper tier (see `TierPlacement`).
    pub retiered: usize,
    /// Prefetches deferred or split by SLO throttling (see `SloThrottle`).
    pub throttled: usize,
    /// Placement detours unwound by SLO throttling (see
    /// `SloThrottle::veto_promotions`).
    pub vetoed: usize,
    /// Transfers split into chunked (partial-tensor) transfers.
    pub chunked: usize,
    /// Deferrable Store bytes spilled past the schedule by SLO throttling.
    pub deferred_bytes: u64,
    /// One report per pipeline stage, in execution order.
    pub per_pass: Vec<PassReport>,
    /// All diagnostics emitted across the session.
    pub diagnostics: Vec<Diagnostic>,
    /// Analysis-cache hit/miss counters for the session.
    pub cache_hits: usize,
    pub cache_misses: usize,
}

/// A compile *session* builder: configure hardware, policy and the pass
/// pipeline, then drive it over a graph.
///
/// ```no_run
/// # use hyperoffload::graph::GraphBuilder;
/// # use hyperoffload::passes::{Compiler, OffloadPolicy};
/// # use hyperoffload::sim::HwConfig;
/// let mut g = GraphBuilder::linear_chain(8, 1e9, 1 << 20);
/// let report = Compiler::new(HwConfig::ascend910c_like())
///     .policy(OffloadPolicy { min_bytes: 16 << 20, ..Default::default() })
///     .verify(true)
///     .compile(&mut g)
///     .expect("compile");
/// ```
pub struct Compiler {
    hw: HwConfig,
    policy: OffloadPolicy,
    exec: ExecOrderConfig,
    slo_us: Option<f64>,
    dma_contention: f64,
    passes: Vec<Box<dyn Pass>>,
    verify: bool,
    sanitize: bool,
    deny_warnings: bool,
    lints: LintConfig,
    incremental: bool,
    /// Diagnostics raised while *building* the session (e.g. a
    /// `pass_before` anchor that is not scheduled); surfaced at the head
    /// of the compile report's diagnostics.
    pending_diags: Vec<Diagnostic>,
}

impl Compiler {
    /// A session with the default HyperOffload pipeline:
    /// lifetime → prefetch-insert → exec-order.
    pub fn new(hw: HwConfig) -> Self {
        Self {
            hw,
            policy: OffloadPolicy::default(),
            exec: ExecOrderConfig::default(),
            slo_us: None,
            dma_contention: 1.0,
            passes: vec![
                Box::new(LifetimePass),
                Box::new(PrefetchInsertPass),
                Box::new(ExecOrderPass),
            ],
            verify: false,
            sanitize: false,
            deny_warnings: false,
            lints: LintConfig::default(),
            incremental: true,
            pending_diags: Vec::new(),
        }
    }

    /// A session with no passes — add them with [`pass`](Self::pass).
    pub fn empty(hw: HwConfig) -> Self {
        Self {
            hw,
            policy: OffloadPolicy::default(),
            exec: ExecOrderConfig::default(),
            slo_us: None,
            dma_contention: 1.0,
            passes: Vec::new(),
            verify: false,
            sanitize: false,
            deny_warnings: false,
            lints: LintConfig::default(),
            incremental: true,
            pending_diags: Vec::new(),
        }
    }

    /// Set the offload-candidate selection policy.
    pub fn policy(mut self, p: OffloadPolicy) -> Self {
        self.policy = p;
        self
    }

    /// Set the Algorithm 1 cost-model configuration.
    pub fn exec(mut self, cfg: ExecOrderConfig) -> Self {
        self.exec = cfg;
        self
    }

    /// Set the latency SLO (us) the schedule must respect — the budget the
    /// [`SloThrottle`](super::SloThrottle) pass shapes transfers against.
    pub fn slo_us(mut self, us: f64) -> Self {
        self.slo_us = Some(us);
        self
    }

    /// Assume a fabric-contention slowdown (≥ 1.0) on the device↔pool link
    /// for all decision-pass cost models and validation simulations.
    pub fn contention(mut self, slowdown: f64) -> Self {
        self.dma_contention = slowdown.max(1.0);
        self
    }

    /// Run [`verify_ir`] on the input graph and after every pass; any
    /// `Error`-severity finding aborts with [`CompileError::Verify`].
    pub fn verify(mut self, on: bool) -> Self {
        self.verify = on;
        self
    }

    /// Run the TransferSan static analyzer (the [`analysis`] module) as a
    /// final pipeline stage: residency safety under **all** dep-consistent
    /// linearizations, transfer-race / double-release / ledger-balance
    /// lints, and a static peak-residency bound — no simulation involved.
    /// Findings flow through the lint registry into the compile
    /// diagnostics; `deny`-level findings abort the session like verifier
    /// errors. Under `--cfg strict_verify` the analyzer additionally runs
    /// after every pass regardless of this setting.
    pub fn sanitize(mut self, on: bool) -> Self {
        self.sanitize = on;
        self
    }

    /// Fail the compile if any `Warning`-severity diagnostic was emitted
    /// (surfaced as [`CompileError::Verify`] from stage `deny-warnings`).
    /// The CI mode: a droppable warning today is a silent regression
    /// tomorrow. Implied by `--cfg strict_verify`.
    pub fn deny_warnings(mut self, on: bool) -> Self {
        self.deny_warnings = on;
        self
    }

    /// Override the level of one TransferSan lint for this session (see
    /// [`analysis::LINTS`] for the registry). Unknown names are ignored —
    /// registry membership is asserted in the analysis module's tests.
    pub fn lint(mut self, name: &str, level: LintLevel) -> Self {
        self.lints.set(name, level);
        self
    }

    /// Enable/disable the session cache's journal-driven incremental
    /// analysis updates (on by default). Off restores the pre-incremental
    /// recompute-on-every-mutation behaviour — the A/B baseline
    /// `benches/hot_path.rs` measures against; results are identical
    /// either way.
    pub fn incremental(mut self, on: bool) -> Self {
        self.incremental = on;
        self
    }

    /// Append a pass to the pipeline.
    pub fn pass(mut self, p: impl Pass + 'static) -> Self {
        self.passes.push(Box::new(p));
        self
    }

    /// Insert a pass immediately before the pass named `name`.
    ///
    /// When no such pass is scheduled the new pass is appended instead,
    /// and the session records a `Warning` diagnostic (surfaced in the
    /// compile report): a pass positioned relative to an absent anchor is
    /// almost always a pipeline-construction mistake — e.g. transfer
    /// elision ordered "before exec-order" on an [`empty`](Self::empty)
    /// pipeline lands where nothing anchors its rewrites.
    pub fn pass_before(mut self, name: &str, p: impl Pass + 'static) -> Self {
        match self.passes.iter().position(|q| q.name() == name) {
            Some(idx) => self.passes.insert(idx, Box::new(p)),
            None => {
                self.pending_diags.push(Diagnostic::warning(
                    "compiler",
                    format!(
                        "pass '{}' was scheduled before '{name}', but no pass named \
                         '{name}' is in the pipeline; appending it at the end instead",
                        p.name()
                    ),
                ));
                self.passes.push(Box::new(p));
            }
        }
        self
    }

    /// Enable [`ElideRedundantTransfers`](super::ElideRedundantTransfers)
    /// (inserted before exec-order, where the round trips are visible but
    /// not yet anchored).
    pub fn elide_redundant_transfers(self) -> Self {
        self.elide_redundant_transfers_with(super::elide::ElideRedundantTransfers::default())
    }

    /// [`elide_redundant_transfers`](Self::elide_redundant_transfers) with
    /// an explicit capacity policy (headroom / reserved bytes).
    pub fn elide_redundant_transfers_with(
        self,
        pass: super::elide::ElideRedundantTransfers,
    ) -> Self {
        self.pass_before("exec-order", pass)
    }

    /// Enable the [`TierPlacement`](super::TierPlacement) decision pass
    /// (inserted before exec-order so the promotions it emits are anchored
    /// with everything else). A strict no-op unless the session hardware
    /// carries a [`TierTopology`](crate::sim::TierTopology) with at least
    /// one cold level below the pool.
    pub fn tier_placement(self) -> Self {
        self.pass_before("exec-order", super::tier_placement::TierPlacement::default())
    }

    /// Enable the [`RecomputeVsOffload`](super::RecomputeVsOffload)
    /// decision pass (appended after exec-order so it speculates against
    /// the refined schedule the session would otherwise emit).
    pub fn recompute_vs_offload(self) -> Self {
        self.pass(super::recompute::RecomputeVsOffload::default())
    }

    /// Enable the [`SloThrottle`](super::SloThrottle) pass (appended after
    /// exec-order, where it shapes the otherwise-final schedule against the
    /// session SLO). A no-op unless [`slo_us`](Self::slo_us) is set.
    pub fn slo_throttle(self) -> Self {
        self.pass(super::slo_throttle::SloThrottle::default())
    }

    /// Drive the pipeline over `graph`.
    ///
    /// The graph is mutated in place (cache operators inserted/removed,
    /// anchoring control deps wired); the report carries the final
    /// execution order plus per-pass details. Cyclic inputs surface as
    /// [`CompileError::Cycle`] instead of the old panic.
    pub fn compile(mut self, graph: &mut Graph) -> Result<CompileReport, CompileError> {
        let ctx = PassCtx {
            hw: self.hw.clone(),
            policy: self.policy.clone(),
            exec: self.exec.clone(),
            slo_us: self.slo_us,
            dma_contention: self.dma_contention,
        };
        let mut cache = AnalysisCache::new();
        cache.set_incremental(self.incremental);
        let mut diagnostics: Vec<Diagnostic> = std::mem::take(&mut self.pending_diags);
        let mut per_pass: Vec<PassReport> = Vec::new();
        let mut order: Option<Vec<OpId>> = None;
        // The strict-verify build (CI: RUSTFLAGS=--cfg strict_verify) hardens
        // every session: verifier + TransferSan after every pass, warnings
        // fatal — regardless of the per-session settings.
        let strict = cfg!(strict_verify);
        let mut sanitized_at: Option<u64> = None;

        // Early cycle check (and input verification when enabled).
        let input_order = cache.topo_order(graph)?;
        if self.verify || strict {
            let reach = cache.reach(graph)?;
            diagnostics.extend(check_verdict("input", verify_ir_with(graph, &input_order, &reach))?);
        }

        for p in self.passes.iter_mut() {
            let rep = p.run(graph, &mut cache, &ctx)?;
            if rep.order.is_some() {
                order = rep.order.clone();
            }
            diagnostics.extend(rep.diagnostics.iter().cloned());
            per_pass.push(rep);
            if self.verify || strict {
                let vorder: Rc<Vec<OpId>> = match &order {
                    Some(o) if graph.is_valid_order(o) => Rc::new(o.clone()),
                    _ => cache.topo_order(graph)?,
                };
                let name = per_pass.last().map(|r| r.pass.clone()).unwrap_or_default();
                let reach = cache.reach(graph)?;
                diagnostics.extend(check_verdict(&name, verify_ir_with(graph, &vorder, &reach))?);
            }
            if strict {
                run_sanitizer("transfer-san", graph, &mut cache, &ctx, &self.lints, &mut diagnostics)?;
                sanitized_at = Some(graph.version());
            }
        }

        if (self.sanitize || strict) && sanitized_at != Some(graph.version()) {
            run_sanitizer("transfer-san", graph, &mut cache, &ctx, &self.lints, &mut diagnostics)?;
        }

        let mut final_order = match order {
            Some(o) if graph.is_valid_order(&o) => o,
            Some(_) => {
                diagnostics.push(Diagnostic::warning(
                    "compiler",
                    "pinned execution order went stale after a later graph mutation; \
                     falling back to the topological order",
                ));
                (*cache.topo_order(graph)?).clone()
            }
            None => (*cache.topo_order(graph)?).clone(),
        };
        // The cached topo can go stale WITHOUT a version bump if a pass
        // mutated the public `Graph::ops`/`tensors` fields directly instead
        // of using the mutation methods — never trust it blindly.
        if !graph.is_valid_order(&final_order) {
            cache.invalidate();
            final_order = (*cache.topo_order(graph)?).clone();
        }

        if self.deny_warnings || strict {
            let warns: Vec<Diagnostic> = diagnostics
                .iter()
                .filter(|d| d.severity == Severity::Warning)
                .cloned()
                .collect();
            if !warns.is_empty() {
                return Err(CompileError::Verify {
                    pass: "deny-warnings".to_string(),
                    violations: warns,
                });
            }
        }

        let inserted: Vec<(OpId, OpId)> =
            per_pass.iter().flat_map(|r| r.inserted.iter().copied()).collect();
        let rejected = per_pass.iter().map(|r| r.rejected).sum();
        let moved = per_pass.iter().map(|r| r.moved).sum();
        let elided = per_pass.iter().map(|r| r.elided).sum();
        let recomputed = per_pass.iter().map(|r| r.recomputed).sum();
        let retiered = per_pass.iter().map(|r| r.retiered).sum();
        let throttled = per_pass.iter().map(|r| r.throttled).sum();
        let vetoed = per_pass.iter().map(|r| r.vetoed).sum();
        let chunked = per_pass.iter().map(|r| r.chunked).sum();
        let deferred_bytes = per_pass.iter().map(|r| r.deferred_bytes).sum();
        Ok(CompileReport {
            order: final_order,
            inserted,
            rejected,
            moved,
            elided,
            recomputed,
            retiered,
            throttled,
            vetoed,
            chunked,
            deferred_bytes,
            per_pass,
            diagnostics,
            cache_hits: cache.hits(),
            cache_misses: cache.misses(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{GraphBuilder, Tier};
    use crate::sim::simulate;

    fn hw() -> HwConfig {
        HwConfig::test_default()
    }

    #[test]
    fn default_pipeline_matches_legacy_compile() {
        let g0 = GraphBuilder::fwd_bwd_chain(4, 8 << 20, 10e9, 24, 1e9);
        let mut a = g0.clone();
        #[allow(deprecated)]
        let old = crate::passes::compile(
            &mut a,
            &hw(),
            &OffloadPolicy::default(),
            &ExecOrderConfig::default(),
        );
        let mut b = g0;
        let new = Compiler::new(hw()).compile(&mut b).unwrap();
        assert_eq!(old.order, new.order);
        assert_eq!(old.inserted, new.inserted);
        assert_eq!(old.rejected, new.rejected);
        assert_eq!(old.moved, new.moved);
        let sa = simulate(&a, &old.order, &hw());
        let sb = simulate(&b, &new.order, &hw());
        assert_eq!(sa.peak_device_bytes, sb.peak_device_bytes);
        assert_eq!(sa.makespan_us.to_bits(), sb.makespan_us.to_bits());
        assert_eq!(sa.dma_bytes, sb.dma_bytes);
    }

    #[test]
    fn cycle_surfaces_as_compile_error() {
        let mut b = GraphBuilder::new();
        let t0 = b.tensor("t0", 8, Tier::Device);
        let t1 = b.tensor("t1", 8, Tier::Device);
        let x = b.compute("x", 1e6, 0, vec![], vec![t0]);
        let y = b.compute("y", 1e6, 0, vec![t0], vec![t1]);
        b.dep(x, y);
        let mut g = b.build();
        match Compiler::new(hw()).compile(&mut g) {
            Err(CompileError::Cycle { culprit_ops }) => {
                assert!(culprit_ops.contains(&x) && culprit_ops.contains(&y));
            }
            other => panic!("expected CompileError::Cycle, got {other:?}"),
        }
    }

    #[test]
    fn analysis_cache_invalidates_on_mutation() {
        let mut g = GraphBuilder::linear_chain(4, 1e6, 64);
        let mut cache = AnalysisCache::new();
        let o1 = cache.topo_order(&g).unwrap();
        let _ = cache.topo_order(&g).unwrap();
        assert_eq!(cache.topo_misses, 1);
        assert_eq!(cache.topo_hits, 1);
        // A local mutation (append) is served by a journal delta update,
        // bit-identical to full recomputation.
        let t = g.add_tensor("x", 1, Tier::Device);
        let c = g.add_op("c", crate::graph::OpKind::HostWork { us: 1.0 }, vec![], vec![t]);
        let o2 = cache.topo_order(&g).unwrap();
        assert_eq!(cache.topo_deltas, 1);
        assert_eq!(cache.topo_misses, 1);
        assert_eq!(o2.len(), o1.len() + 1);
        assert_eq!(*o2, g.topo_order_detailed().unwrap());
        // A non-local mutation (removal) forces full recomputation.
        g.remove_ops(&[c]);
        let o3 = cache.topo_order(&g).unwrap();
        assert_eq!(cache.topo_misses, 2);
        assert_eq!(*o3, *o1);
        // With incremental updates off, even an append is a miss.
        cache.set_incremental(false);
        let t2 = g.add_tensor("y", 1, Tier::Device);
        g.add_op("d", crate::graph::OpKind::HostWork { us: 1.0 }, vec![], vec![t2]);
        let _ = cache.topo_order(&g).unwrap();
        assert_eq!(cache.topo_misses, 3);
    }

    /// Regression test for the hit/miss double count: a cold `lifetimes()`
    /// call used to record a topo miss *and* a lifetime miss, overstating
    /// recomputation in `CompileReport::cache_misses`. Counters are now
    /// per analysis: a cold lifetime query is exactly one lifetime miss.
    #[test]
    fn analysis_cache_counts_per_analysis() {
        let mut g = GraphBuilder::linear_chain(4, 1e6, 64);
        let mut cache = AnalysisCache::new();
        let _ = cache.lifetimes(&g).unwrap();
        assert_eq!(cache.lifetime_misses, 1);
        assert_eq!(cache.topo_misses, 0, "cold lifetimes() must not count a topo miss");
        assert_eq!(cache.misses(), 1);
        let _ = cache.lifetimes(&g).unwrap();
        assert_eq!(cache.lifetime_hits, 1);
        // The topo order warmed as a side effect: a hit, counted only now.
        let _ = cache.topo_order(&g).unwrap();
        assert_eq!((cache.topo_hits, cache.topo_misses), (1, 0));
        // A local mutation delta-updates the lifetime table too.
        let t = g.add_tensor("x", 1, Tier::Device);
        g.add_op("c", crate::graph::OpKind::HostWork { us: 1.0 }, vec![], vec![t]);
        let la = cache.lifetimes(&g).unwrap();
        assert_eq!(cache.lifetime_deltas, 1);
        assert_eq!(cache.lifetime_misses, 1);
        let full = crate::passes::lifetime::LifetimeAnalysis::run(&g, &g.topo_order().unwrap());
        assert_eq!(la.pos, full.pos);
        assert_eq!(la.lifetimes.len(), full.lifetimes.len());
        for (tid, lt) in &full.lifetimes {
            let got = la.get(*tid);
            assert_eq!((got.def_pos, &got.use_pos), (lt.def_pos, &lt.use_pos));
            assert_eq!(got.max_idle_gap, lt.max_idle_gap);
            assert_eq!(got.idle_gap_start, lt.idle_gap_start);
        }
    }

    // Under --cfg strict_verify warnings are fatal, so the "compiles with a
    // warning" half of this test cannot run; the strict-mode behaviour is
    // covered by `deny_warnings_surfaces_warning_as_failure` below.
    #[cfg(not(strict_verify))]
    #[test]
    fn pass_before_missing_anchor_warns() {
        let mut g = GraphBuilder::fwd_bwd_chain(4, 8 << 20, 10e9, 24, 1e9);
        // elide is ordered "before exec-order", but an empty pipeline has
        // no exec-order pass: appended, with a Warning on the report.
        let report = Compiler::empty(hw())
            .elide_redundant_transfers()
            .compile(&mut g)
            .unwrap();
        assert!(
            report
                .diagnostics
                .iter()
                .any(|d| d.severity == Severity::Warning && d.message.contains("exec-order")),
            "missing-anchor warning not surfaced: {:?}",
            report.diagnostics
        );
        // With the anchor present there is nothing to warn about.
        let mut g2 = GraphBuilder::fwd_bwd_chain(4, 8 << 20, 10e9, 24, 1e9);
        let report = Compiler::new(hw()).elide_redundant_transfers().compile(&mut g2).unwrap();
        assert!(!report
            .diagnostics
            .iter()
            .any(|d| d.severity == Severity::Warning && d.message.contains("no pass named")));
    }

    #[test]
    fn deny_warnings_surfaces_warning_as_failure() {
        let mut g = GraphBuilder::fwd_bwd_chain(4, 8 << 20, 10e9, 24, 1e9);
        // elide ordered "before exec-order" on an empty pipeline: appended
        // with a Warning — which deny_warnings upgrades to a failure.
        let res = Compiler::empty(hw())
            .elide_redundant_transfers()
            .deny_warnings(true)
            .compile(&mut g);
        match res {
            Err(CompileError::Verify { pass, violations }) => {
                assert_eq!(pass, "deny-warnings");
                assert!(!violations.is_empty());
                assert!(violations.iter().all(|d| d.severity == Severity::Warning));
            }
            other => panic!("expected deny-warnings failure, got {other:?}"),
        }
        // A warning-free session is unaffected.
        let mut g2 = GraphBuilder::fwd_bwd_chain(4, 8 << 20, 10e9, 24, 1e9);
        Compiler::new(hw()).deny_warnings(true).compile(&mut g2).unwrap();
    }

    #[test]
    fn sanitize_accepts_default_pipeline_output() {
        let mut g = GraphBuilder::fwd_bwd_chain(4, 8 << 20, 10e9, 24, 1e9);
        let report =
            Compiler::new(hw()).verify(true).sanitize(true).compile(&mut g).unwrap();
        assert!(!report.inserted.is_empty());
        assert!(
            report.diagnostics.iter().any(|d| d.pass == "transfer-san"),
            "sanitizer stage left no trace in the diagnostics"
        );
        assert!(!report.diagnostics.iter().any(|d| d.severity == Severity::Error));
    }

    #[test]
    fn reach_cache_patches_and_matches_rebuild() {
        let mut g = GraphBuilder::fwd_bwd_chain(4, 8 << 20, 10e9, 24, 1e9);
        let mut cache = AnalysisCache::new();
        let r1 = cache.reach(&g).unwrap();
        let _ = cache.reach(&g).unwrap();
        assert_eq!((cache.reach_hits, cache.reach_misses), (1, 1));
        // Append a round trip on a fresh tensor: journal-patched, not rebuilt.
        let t = g.add_tensor("x", 8 << 20, Tier::Remote);
        let pf = g.add_op("pfx", crate::graph::OpKind::prefetch(t), vec![t], vec![]);
        let c = g.add_op(
            "cx",
            crate::graph::OpKind::Compute { flops: 1e9, bytes_accessed: 0 },
            vec![t],
            vec![],
        );
        g.add_control_dep(c, pf);
        let r2 = cache.reach(&g).unwrap();
        assert_eq!(cache.reach_deltas, 1);
        assert_eq!(cache.reach_misses, 1);
        let order = g.topo_order().unwrap();
        let fresh = crate::graph::Reach::ancestors(&g, &order, crate::graph::TrackedSet::CacheOps);
        assert_eq!(r2.tracked_len(), fresh.tracked_len());
        for op in 0..g.ops.len() {
            for &tr in fresh.tracked() {
                assert_eq!(r2.contains(op, tr), fresh.contains(op, tr), "op {op} vs {tr}");
            }
        }
        assert!(r2.contains(c, pf));
        drop(r1);
        // A removal is non-local: full rebuild.
        g.remove_ops(&[c]);
        let _ = cache.reach(&g).unwrap();
        assert_eq!(cache.reach_misses, 2);
    }

    #[test]
    fn verify_catches_double_release() {
        let mut b = GraphBuilder::new();
        let a = b.tensor("a", 1024, Tier::Device);
        let p = b.compute("p", 1e6, 0, vec![], vec![a]);
        let s1 = b.store("st1", a);
        b.dep(s1, p);
        let s2 = b.store("st2", a);
        b.dep(s2, s1);
        let g = b.build();
        let order = g.topo_order().unwrap();
        let diags = verify_ir(&g, &order);
        assert!(
            diags.iter().any(|d| d.severity == Severity::Error),
            "double release not caught: {diags:?}"
        );
    }

    #[test]
    fn verify_accepts_inserted_round_trip() {
        let mut g = GraphBuilder::fwd_bwd_chain(4, 8 << 20, 10e9, 24, 1e9);
        let report = Compiler::new(hw()).verify(true).compile(&mut g).unwrap();
        assert!(!report.inserted.is_empty());
        assert!(g.is_valid_order(&report.order));
    }

    #[test]
    fn custom_pass_extends_pipeline() {
        struct MarkerPass;
        impl Pass for MarkerPass {
            fn name(&self) -> &'static str {
                "marker"
            }
            fn run(
                &mut self,
                g: &mut Graph,
                _cache: &mut AnalysisCache,
                _ctx: &PassCtx,
            ) -> Result<PassReport, CompileError> {
                let mut rep = PassReport::new(self.name());
                rep.diagnostics.push(Diagnostic::info("marker", format!("{} ops", g.ops.len())));
                Ok(rep)
            }
        }
        let mut g = GraphBuilder::linear_chain(3, 1e6, 0);
        let report = Compiler::new(hw()).pass(MarkerPass).compile(&mut g).unwrap();
        assert!(report.per_pass.iter().any(|p| p.pass == "marker"));
        assert_eq!(report.per_pass.len(), 4);
    }

    #[test]
    fn explicit_verify_pass_usable_in_custom_pipeline() {
        let mut g = GraphBuilder::linear_chain(3, 1e6, 0);
        let report = Compiler::empty(hw()).pass(VerifyPass).compile(&mut g).unwrap();
        assert_eq!(report.per_pass.len(), 1);
        assert_eq!(report.order, vec![0, 1, 2]);
    }
}
