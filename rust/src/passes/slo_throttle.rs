//! `SloThrottle`: shape transfer timing against a latency SLO — spill,
//! defer or split transfers whose bandwidth demand crowds the schedule,
//! preferring to spill pool headroom (bytes stay remote longer) or SLO
//! slack over early residency.
//!
//! Modeled on "Memory Offloading for LLM Inference with Latency SLO
//! Guarantees": offload traffic must not push the serving/step latency past
//! its budget, and transfer *timing* — not just placement — is a resource
//! to allocate. This pass runs after exec-order on the session's pinned
//! schedule and applies four rewrites, each speculated and validated by
//! re-simulation under the session's assumed fabric contention:
//!
//! * **veto** — a pass-inserted placement detour (the
//!   [`TierPlacement`](super::TierPlacement) shape: Store retargeted to a
//!   cold or [`Tier::Peer`] tier plus a `Promote` back to the pool ahead
//!   of the pool Prefetch) is *unwound* when the schedule blows the SLO:
//!   the Store goes back to the pool and the `Promote` is removed. The
//!   placement passes reason about hiding transfers under idle windows;
//!   the throttle is the tail-budget authority, so a detour the budget
//!   can no longer afford is vetoed before any traffic is shed.
//! * **spill** — a Store of a [`deferrable`](crate::graph::TensorInfo::deferrable)
//!   tensor whose transfer pushes the schedule past the SLO is shrunk to
//!   the largest chunk that fits the budget (a `.keep` chunk view aliasing
//!   the tensor's storage); the shed bytes stay device-resident and are
//!   reported as [`PassReport::deferred_bytes`] for the caller to move in a
//!   later schedule. This is how the serving engine's per-step KV
//!   writeback throttling is expressed in the IR.
//! * **split** — a monolithic transfer becomes `k` chunked transfers over
//!   `.chunk` tensors aliasing the same storage
//!   ([`Graph::add_chunk_tensor`]): either a pool-resident prefetch
//!   (chunks arrive staggered instead of as one bandwidth spike) or a full
//!   Store/Prefetch *round trip* (each chunk leaves and returns
//!   independently — partial-tensor residency, so the release curve steps
//!   down per chunk store instead of waiting for the whole transfer).
//!   Either way the transfer-window residency byte·time drops and the
//!   scheduler gains preemption points between chunks.
//! * **defer** — a prefetch is re-anchored later (control dep on a later
//!   compute op, the same mechanism Algorithm 1 uses to pin issue time),
//!   trading latency slack for memory: the bytes spill into pool headroom
//!   until closer to their use.
//!
//! ## How the SLO budget is apportioned
//!
//! The budget is global, not per-transfer: `budget = max(slo_us, entry
//! makespan)` (an already-over-SLO schedule is never made worse; spills
//! run first and can only *shrink* the entry makespan toward the SLO).
//! Rewrites are committed greedily — latest-consumer prefetches first —
//! and every commit must keep the *re-simulated* makespan within the
//! budget and the peak at-or-below the entry schedule's peak; splits and
//! deferrals must additionally strictly improve peak residency or
//! residency byte·time, spills must strictly improve makespan. Whatever
//! slack one decision consumes is gone for the next (each speculation
//! re-simulates the live graph), so the pass never overdraws the SLO.
//! Consequently the throttled schedule's peak device bytes never exceed
//! the no-throttle schedule's — the P11/P12 invariant.

use crate::graph::{Graph, OpId, OpKind, TensorId, Tier};
use crate::sim::{simulate, SimTrace};

use super::compiler::{AnalysisCache, CompileError, Diagnostic, Pass, PassCtx, PassReport};

/// The SLO-aware transfer throttle. A no-op unless the session sets an SLO
/// ([`Compiler::slo_us`](super::Compiler::slo_us)).
#[derive(Debug, Clone)]
pub struct SloThrottle {
    /// Split transfers of at least `2 × split_min_bytes` into chunks of
    /// roughly this size (pool-resident prefetches and Store/Prefetch
    /// round trips).
    pub split_min_bytes: u64,
    /// Upper bound on chunks per split.
    pub max_chunks: usize,
    /// Safety bound on committed rewrites (vetoes + spills + splits +
    /// deferrals) per compile — each commit re-simulates, so this bounds
    /// compile time.
    pub max_decisions: usize,
    /// Shed Store traffic of `deferrable` tensors past the schedule when
    /// the SLO demands it (the spill rewrite). Inert on graphs without
    /// deferrable tensors.
    pub spill_deferrable_stores: bool,
    /// Allow re-anchoring prefetches later (the defer rewrite). The
    /// serving step compiler disables this: decode needs its fetched KV
    /// blocks now, so only spills and splits apply.
    pub defer_prefetches: bool,
    /// Throughput mode (the default): split rewrites are validated in
    /// *batches* (one topo + one simulation per batch, bisecting on
    /// failure instead of one full validation per split), and deferral
    /// probes resume a recorded baseline [`SimTrace`] at the prefetch's
    /// position — with the probed anchor dep passed as a virtual edge —
    /// instead of cloning the graph and re-simulating from t=0 per probe.
    /// Resumed simulation is bit-identical to full simulation of the same
    /// candidate (P13), so accept/reject decisions match the off path;
    /// off = the pre-incremental per-rewrite validation `benches/
    /// hot_path.rs` uses as its A/B baseline.
    pub windowed: bool,
    /// Unwind pass-inserted deep/peer placement detours
    /// ([`TierPlacement`](super::TierPlacement)'s Store→cold +
    /// Promote→pool rewrite and its `Tier::Peer` analog) while the
    /// schedule is over the SLO (the veto rewrite). Each veto must
    /// strictly improve the re-simulated makespan and hold the peak cap.
    pub veto_promotions: bool,
}

impl Default for SloThrottle {
    fn default() -> Self {
        Self {
            split_min_bytes: 64 << 20,
            max_chunks: 4,
            max_decisions: 64,
            spill_deferrable_stores: true,
            defer_prefetches: true,
            windowed: true,
            veto_promotions: true,
        }
    }
}

impl Pass for SloThrottle {
    fn name(&self) -> &'static str {
        "slo-throttle"
    }

    fn run(
        &mut self,
        g: &mut Graph,
        cache: &mut AnalysisCache,
        ctx: &PassCtx,
    ) -> Result<PassReport, CompileError> {
        let mut rep = PassReport::new(self.name());
        let Some(slo) = ctx.slo_us else {
            rep.diagnostics
                .push(Diagnostic::info(self.name(), "no SLO configured; pass skipped"));
            return Ok(rep);
        };
        let chw = ctx.contended_hw();
        let entry_order: Vec<OpId> = (*cache.pinned_or_topo(g)?).clone();
        let base = simulate(g, &entry_order, &chw);
        let peak_cap = base.peak_device_bytes;

        let mut order = entry_order;
        let mut split_count = 0usize;
        let mut deferred = 0usize;
        let mut cur = base.clone();

        // ---- phase 0: veto placement detours the budget can't afford ----
        // A TierPlacement-shaped detour (Store to a cold or peer tier +
        // Promote back to the pool ahead of the pool Prefetch) was
        // committed on hiding grounds; under a blown SLO the throttle is
        // the tail-budget authority and unwinds it — the Store retargets
        // back to the pool and the Promote is removed. Removal renumbers
        // op ids, so the pinned order is remapped through the removal map
        // (splice semantics keep it a valid linear extension).
        let mut vetoes = 0usize;
        if self.veto_promotions {
            let mut decided_veto: Vec<TensorId> = Vec::new();
            while vetoes < self.max_decisions && cur.makespan_us > slo * (1.0 + 1e-12) {
                let Some((t, st, pm)) = next_detour(g, &decided_veto) else { break };
                decided_veto.push(t);
                let mut trial = g.clone();
                trial.retarget_transfer_tier(st, Tier::Remote);
                let map = trial.remove_ops(&[pm]);
                let torder: Vec<OpId> = order.iter().filter_map(|&o| map[o]).collect();
                let sim = simulate(&trial, &torder, &chw);
                if sim.makespan_us < cur.makespan_us * (1.0 - 1e-12)
                    && sim.peak_device_bytes <= peak_cap
                {
                    let name = g.tensor(t).name.clone();
                    rep.diagnostics.push(Diagnostic::info(
                        self.name(),
                        format!(
                            "vetoed placement detour of '{name}': makespan {:.1} -> {:.1} us \
                             (slo {slo:.1})",
                            cur.makespan_us, sim.makespan_us
                        ),
                    ));
                    *g = trial;
                    order = torder;
                    cur = sim;
                    vetoes += 1;
                }
            }
            rep.vetoed = vetoes;
        }

        // ---- phase 1: spill deferrable Store traffic past the SLO -------
        // Unlike the later phases this one *reduces* an over-SLO entry
        // makespan instead of accepting it: a writeback the caller marked
        // deferrable need not complete inside this schedule at all, so its
        // Store is shrunk to the largest chunk that fits the budget and
        // the rest is reported as `deferred_bytes`.
        let mut spills = 0usize;
        if self.spill_deferrable_stores {
            let mut decided_spill: Vec<TensorId> = Vec::new();
            while vetoes + spills + split_count + deferred < self.max_decisions
                && cur.makespan_us > slo * (1.0 + 1e-12)
            {
                let Some((s, t)) = next_deferrable_store(g, &decided_spill) else { break };
                decided_spill.push(t);
                let Some(sp) = spill_store(g, s, t, slo, peak_cap, &chw, &cur) else { continue };
                let name = g.tensor(t).name.clone();
                rep.diagnostics.push(Diagnostic::info(
                    self.name(),
                    format!(
                        "spilled {} of {} deferrable bytes of '{name}': makespan \
                         {:.1} -> {:.1} us (slo {slo:.1})",
                        sp.deferred_bytes,
                        g.tensor(t).bytes,
                        cur.makespan_us,
                        sp.sim.makespan_us
                    ),
                ));
                *g = sp.graph;
                order = sp.order;
                cur = sp.sim;
                rep.deferred_bytes += sp.deferred_bytes;
                spills += 1;
            }
        }

        // Global budget: never regress an already-over-SLO schedule (after
        // spills have pulled the makespan as close to the SLO as they can).
        let budget = slo.max(cur.makespan_us);

        // ---- phase 2: split oversized transfers into chunks -------------
        // Pool-resident prefetches arrive staggered; Store/Prefetch round
        // trips leave and return per chunk (partial-tensor residency).
        let mut decided: Vec<TensorId> = Vec::new();
        if self.windowed {
            // Batched validation: apply every enumerated split to one
            // trial, validate with a single topo + simulation, and bisect
            // on failure (each split is independent tensor-wise, so a bad
            // batch member is isolated in O(log) extra simulations instead
            // of paying one full validation per split). Re-enumerate after
            // each round — committed splits can expose further candidates
            // (over-sized chunks of a split prefetch).
            loop {
                let remaining = self
                    .max_decisions
                    .saturating_sub(vetoes + spills + split_count + deferred);
                if remaining == 0 {
                    break;
                }
                let mut batch = self.split_candidates(g, &decided);
                batch.truncate(remaining);
                if batch.is_empty() {
                    break;
                }
                for &(t, _, _) in &batch {
                    decided.push(t);
                }
                let committed = commit_split_batch(
                    self.name(),
                    g,
                    &mut order,
                    &mut cur,
                    &batch,
                    &chw,
                    budget,
                    peak_cap,
                    &mut rep,
                );
                split_count += committed;
                rep.chunked += committed;
            }
        } else {
            while vetoes + spills + split_count + deferred < self.max_decisions {
                let Some(&(t, kind, k)) = self.split_candidates(g, &decided).first() else {
                    break;
                };
                decided.push(t);
                let trial = match kind {
                    SplitKind::PoolResident { pf } => split_prefetch(g, t, pf, k),
                    SplitKind::RoundTrip { st, pf } => split_round_trip(g, t, st, pf, k),
                };
                let Some(trial) = trial else { continue };
                let Ok(torder) = trial.topo_order_detailed() else { continue };
                let sim = simulate(&trial, &torder, &chw);
                // Same contract as deferrals: stay within budget and peak
                // cap, and strictly improve peak or residency byte·time.
                let improves = sim.peak_device_bytes < cur.peak_device_bytes
                    || (sim.peak_device_bytes == cur.peak_device_bytes
                        && sim.residency_byte_time()
                            < cur.residency_byte_time() * (1.0 - 1e-9));
                if sim.makespan_us <= budget && sim.peak_device_bytes <= peak_cap && improves {
                    let name = g.tensor(t).name.clone();
                    let what = match kind {
                        SplitKind::PoolResident { .. } => "prefetch",
                        SplitKind::RoundTrip { .. } => "store/prefetch round trip",
                    };
                    *g = trial;
                    order = torder;
                    cur = sim;
                    split_count += 1;
                    rep.chunked += 1;
                    rep.diagnostics.push(Diagnostic::info(
                        self.name(),
                        format!("split {what} of '{name}' into {k} chunked transfers"),
                    ));
                }
            }
        }

        // ---- phase 3: defer prefetches into the SLO slack ----------------
        // Latest-consumer prefetches first: their windows close last, so
        // they have the most slack to spend. `cur` stays valid across
        // rejected speculations — only commits change the graph. In
        // windowed mode the anchor probes resume a recorded trace at the
        // prefetch's position (the earliest point a deferral can move)
        // instead of fully re-simulating; the trace is re-recorded after
        // each commit.
        let mut trace = if self.windowed && self.defer_prefetches {
            Some(SimTrace::record(g, &order, &chw))
        } else {
            None
        };
        while self.defer_prefetches
            && vetoes + spills + split_count + deferred < self.max_decisions
        {
            let mut committed = false;
            let prefetches: Vec<OpId> = order
                .iter()
                .rev()
                .copied()
                .filter(|&o| matches!(g.op(o).kind, OpKind::Prefetch { .. }))
                .collect();
            for c in prefetches {
                let Some((trial, cand_order, sim)) =
                    best_deferral(g, &order, c, &chw, budget, peak_cap, &cur, trace.as_ref())
                else {
                    continue;
                };
                let name = g.op(c).name.clone();
                *g = trial;
                order = cand_order;
                if trace.is_some() {
                    trace = Some(SimTrace::record(g, &order, &chw));
                }
                deferred += 1;
                committed = true;
                rep.diagnostics.push(Diagnostic::info(
                    self.name(),
                    format!(
                        "deferred '{name}': peak {} -> {} bytes, makespan {:.1} -> {:.1} us \
                         (budget {budget:.1})",
                        cur.peak_device_bytes,
                        sim.peak_device_bytes,
                        cur.makespan_us,
                        sim.makespan_us
                    ),
                ));
                cur = sim;
                break; // rescan against the committed graph
            }
            if !committed {
                break;
            }
        }

        let final_sim = cur;
        rep.throttled = vetoes + spills + split_count + deferred;
        rep.diagnostics.push(Diagnostic::info(
            self.name(),
            format!(
                "{vetoes} veto(es), {spills} spill(s) ({} bytes), {split_count} split(s), \
                 {deferred} deferral(s); makespan {:.1} us against a {budget:.1} us budget, \
                 peak {} bytes (entry {})",
                rep.deferred_bytes, final_sim.makespan_us, final_sim.peak_device_bytes, peak_cap
            ),
        ));
        cache.pin_order(g, order.clone());
        rep.order = Some(order);
        Ok(rep)
    }
}

/// Which transfer shape a split rewrite targets.
#[derive(Debug, Clone, Copy)]
enum SplitKind {
    /// A lone prefetch of a pool-resident tensor (no Store).
    PoolResident { pf: OpId },
    /// A full Store → Prefetch round trip of one tensor.
    RoundTrip { st: OpId, pf: OpId },
}

impl SloThrottle {
    /// All splittable transfers, in tensor-id order: each is either a
    /// pool-resident tensor with exactly one cache op (its lone prefetch)
    /// or a tensor with exactly one Store + one Prefetch (a full round
    /// trip); big enough for ≥ 2 chunks either way. Chunk views themselves
    /// are never re-split.
    fn split_candidates(
        &self,
        g: &Graph,
        decided: &[TensorId],
    ) -> Vec<(TensorId, SplitKind, usize)> {
        let mut out = Vec::new();
        if self.split_min_bytes == 0 {
            return out;
        }
        for t in &g.tensors {
            if t.bytes < 2 * self.split_min_bytes
                || t.alias_of.is_some()
                || decided.contains(&t.id)
            {
                continue;
            }
            let cache_ops: Vec<OpId> = g
                .ops
                .iter()
                .filter(|o| o.kind.cache_tensor() == Some(t.id))
                .map(|o| o.id)
                .collect();
            let kind = match cache_ops.as_slice() {
                [pf]
                    if t.home == Tier::Remote
                        && matches!(g.op(*pf).kind, OpKind::Prefetch { .. })
                        && g.consumers_of(t.id).iter().any(|&c| !g.op(c).kind.is_cache_op()) =>
                {
                    SplitKind::PoolResident { pf: *pf }
                }
                [a, b] => {
                    // A round trip in either op-id order; require the
                    // insertion-pass wiring (prefetch control-deps its
                    // store) and at least one window consumer waiting on
                    // the prefetch so chunk arrivals have somewhere to
                    // anchor.
                    let (st, pf) = match (&g.op(*a).kind, &g.op(*b).kind) {
                        (OpKind::Store { .. }, OpKind::Prefetch { .. }) => (*a, *b),
                        (OpKind::Prefetch { .. }, OpKind::Store { .. }) => (*b, *a),
                        _ => continue,
                    };
                    if !g.op(pf).control_deps.contains(&st)
                        || window_consumers(g, pf).is_empty()
                    {
                        continue;
                    }
                    SplitKind::RoundTrip { st, pf }
                }
                _ => continue,
            };
            let k = ((t.bytes / self.split_min_bytes) as usize).clamp(2, self.max_chunks.max(2));
            out.push((t.id, kind, k));
        }
        out
    }
}

/// The next vetoable placement detour on the live graph: a non-alias
/// tensor not homed at the detour tier with exactly one Store to a cold
/// or peer tier, exactly one Promote from that tier back to the pool,
/// and exactly one pool Prefetch — the shape `TierPlacement` (and its
/// peer analog) leaves behind. Returns `(tensor, store, promote)`; op
/// ids are re-derived per call because committed vetoes renumber them.
fn next_detour(g: &Graph, decided: &[TensorId]) -> Option<(TensorId, OpId, OpId)> {
    for t in &g.tensors {
        if t.alias_of.is_some() || decided.contains(&t.id) {
            continue;
        }
        let mut stores = Vec::new();
        let mut promotes = Vec::new();
        let mut prefetches = Vec::new();
        for op in &g.ops {
            match op.kind {
                OpKind::Store { tensor, dst } if tensor == t.id => stores.push((op.id, dst)),
                OpKind::Promote { tensor, src, dst } if tensor == t.id => {
                    promotes.push((op.id, src, dst))
                }
                OpKind::Prefetch { tensor, src } if tensor == t.id => {
                    prefetches.push((op.id, src))
                }
                _ => {}
            }
        }
        if stores.len() != 1 || promotes.len() != 1 || prefetches.len() != 1 {
            continue;
        }
        let (st, st_dst) = stores[0];
        let (pm, pm_src, pm_dst) = promotes[0];
        let (_, pf_src) = prefetches[0];
        if (st_dst.is_cold() || st_dst.is_peer())
            && pm_src == st_dst
            && pm_dst == Tier::Remote
            && pf_src == Tier::Remote
            && t.home != st_dst
        {
            return Some((t.id, st, pm));
        }
    }
    None
}

/// Re-locate `t`'s cache ops on (a possibly already-rewritten) `g` and
/// apply its split. Batch application renumbers op ids per member
/// (`remove_ops`), so splits are keyed by tensor id — stable across
/// rewrites — and the op wiring is re-derived here per application.
fn apply_split(g: &Graph, t: TensorId, k: usize) -> Option<Graph> {
    let cache_ops: Vec<OpId> =
        g.ops.iter().filter(|o| o.kind.cache_tensor() == Some(t)).map(|o| o.id).collect();
    match cache_ops.as_slice() {
        [pf] if matches!(g.op(*pf).kind, OpKind::Prefetch { .. }) => split_prefetch(g, t, *pf, k),
        [a, b] => {
            let (st, pf) = match (&g.op(*a).kind, &g.op(*b).kind) {
                (OpKind::Store { .. }, OpKind::Prefetch { .. }) => (*a, *b),
                (OpKind::Prefetch { .. }, OpKind::Store { .. }) => (*b, *a),
                _ => return None,
            };
            split_round_trip(g, t, st, pf, k)
        }
        _ => None,
    }
}

/// Validate a batch of splits with one topo + one simulation; on failure
/// bisect so one regressive member cannot veto the rest. Commits mutate
/// `g`/`order`/`cur` in place (the right half of a bisection re-validates
/// against the left half's committed state, like the sequential path).
/// Returns the number of splits committed.
#[allow(clippy::too_many_arguments)]
fn commit_split_batch(
    pass: &'static str,
    g: &mut Graph,
    order: &mut Vec<OpId>,
    cur: &mut crate::sim::SimResult,
    batch: &[(TensorId, SplitKind, usize)],
    chw: &crate::sim::HwConfig,
    budget: f64,
    peak_cap: u64,
    rep: &mut PassReport,
) -> usize {
    if batch.is_empty() {
        return 0;
    }
    let mut trial = g.clone();
    let mut applied: Vec<(TensorId, SplitKind, usize)> = Vec::new();
    for &(t, kind, k) in batch {
        if let Some(next) = apply_split(&trial, t, k) {
            trial = next;
            applied.push((t, kind, k));
        }
    }
    let bisect = |g: &mut Graph,
                  order: &mut Vec<OpId>,
                  cur: &mut crate::sim::SimResult,
                  rep: &mut PassReport| {
        if batch.len() == 1 {
            return 0;
        }
        let mid = batch.len() / 2;
        let left =
            commit_split_batch(pass, g, order, cur, &batch[..mid], chw, budget, peak_cap, rep);
        let right =
            commit_split_batch(pass, g, order, cur, &batch[mid..], chw, budget, peak_cap, rep);
        left + right
    };
    if applied.is_empty() {
        return 0;
    }
    let Ok(torder) = trial.topo_order_detailed() else {
        return bisect(g, order, cur, rep);
    };
    let sim = simulate(&trial, &torder, chw);
    // Same contract as the sequential path: stay within budget and peak
    // cap, and strictly improve peak or residency byte·time.
    let improves = sim.peak_device_bytes < cur.peak_device_bytes
        || (sim.peak_device_bytes == cur.peak_device_bytes
            && sim.residency_byte_time() < cur.residency_byte_time() * (1.0 - 1e-9));
    if sim.makespan_us <= budget && sim.peak_device_bytes <= peak_cap && improves {
        for &(t, kind, k) in &applied {
            let name = g.tensor(t).name.clone();
            let what = match kind {
                SplitKind::PoolResident { .. } => "prefetch",
                SplitKind::RoundTrip { .. } => "store/prefetch round trip",
            };
            rep.diagnostics.push(Diagnostic::info(
                pass,
                format!("split {what} of '{name}' into {k} chunked transfers"),
            ));
        }
        *g = trial;
        *order = torder;
        *cur = sim;
        return applied.len();
    }
    bisect(g, order, cur, rep)
}

/// Non-cache ops control-depending on `pf` — the consumers the insertion
/// pass ordered after transfer completion (§4.2.1's "at/after-window"
/// set).
fn window_consumers(g: &Graph, pf: OpId) -> Vec<OpId> {
    g.ops
        .iter()
        .filter(|o| o.control_deps.contains(&pf) && !o.kind.is_cache_op())
        .map(|o| o.id)
        .collect()
}

/// First Store of a deferrable, not-yet-decided tensor.
fn next_deferrable_store(g: &Graph, decided: &[TensorId]) -> Option<(OpId, TensorId)> {
    g.ops.iter().find_map(|o| match o.kind {
        OpKind::Store { tensor, .. }
            if g.tensor(tensor).deferrable
                && g.tensor(tensor).alias_of.is_none()
                && !decided.contains(&tensor) =>
        {
            Some((o.id, tensor))
        }
        _ => None,
    })
}

/// A committed spill rewrite.
struct Spill {
    graph: Graph,
    order: Vec<OpId>,
    sim: crate::sim::SimResult,
    deferred_bytes: u64,
}

/// Shrink the Store `s` of deferrable tensor `t` to the largest `.keep`
/// chunk whose schedule fits `max(slo, floor)` — `floor` being the
/// makespan with the store fully shed (an SLO below the floor cannot be
/// bought with this store). The shed bytes stay device-resident (no chunk
/// releases them); the caller is responsible for moving them in a later
/// schedule. Returns `None` when spilling cannot strictly improve the
/// makespan or would raise the peak above `peak_cap`.
fn spill_store(
    g: &Graph,
    s: OpId,
    t: TensorId,
    slo: f64,
    peak_cap: u64,
    chw: &crate::sim::HwConfig,
    cur: &crate::sim::SimResult,
) -> Option<Spill> {
    let bytes = g.tensor(t).bytes;
    if bytes == 0 {
        return None;
    }
    let name = g.tensor(t).name.clone();
    let s_deps = g.op(s).control_deps.clone();
    let dependents: Vec<OpId> = g
        .ops
        .iter()
        .filter(|o| o.control_deps.contains(&s))
        .map(|o| o.id)
        .collect();

    // Build the keep-k trial: replace Store(t) by Store(t.keep) of `keep`
    // bytes with the same wiring (or drop it entirely at keep == 0). The
    // keep-store inherits the original store's destination tier.
    let st_dst = match g.op(s).kind {
        OpKind::Store { dst, .. } => dst,
        _ => Tier::Remote,
    };
    let build = |keep: u64| -> Option<(Graph, Vec<OpId>)> {
        let mut trial = g.clone();
        if keep > 0 {
            let kc = trial.add_chunk_tensor(t, format!("{name}.keep"), keep);
            let st2 = trial.add_op(
                format!("store.{name}.keep"),
                OpKind::Store { tensor: kc, dst: st_dst },
                vec![kc],
                vec![],
            );
            for &d in &s_deps {
                trial.add_control_dep(st2, d);
            }
            for &o in &dependents {
                trial.add_control_dep(o, st2);
            }
        }
        trial.remove_ops(&[s]);
        let order = trial.topo_order_detailed().ok()?;
        Some((trial, order))
    };

    // Floor: the store fully shed. If that does not beat the current
    // schedule, the store is not what crowds the budget.
    let (fg, forder) = build(0)?;
    let fsim = simulate(&fg, &forder, chw);
    if fsim.makespan_us >= cur.makespan_us * (1.0 - 1e-12) || fsim.peak_device_bytes > peak_cap {
        return None;
    }
    let target = slo.max(fsim.makespan_us);

    // Largest keep whose makespan fits the target: makespan is monotone
    // non-decreasing in keep, so binary-search the byte count (the graphs
    // are a handful of ops; ~30 re-simulations are cheap and exact).
    let fits = |keep: u64| -> Option<(Graph, Vec<OpId>, crate::sim::SimResult)> {
        let (tg, torder) = build(keep)?;
        let sim = simulate(&tg, &torder, chw);
        (sim.makespan_us <= target * (1.0 + 1e-12) && sim.peak_device_bytes <= peak_cap)
            .then_some((tg, torder, sim))
    };
    let (mut lo, mut hi) = (0u64, bytes);
    while hi - lo > 1 {
        let mid = lo + (hi - lo) / 2;
        if fits(mid).is_some() {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    let keep = lo;
    let (graph, order, sim) = fits(keep)?;
    if sim.makespan_us >= cur.makespan_us * (1.0 - 1e-12) {
        return None;
    }
    Some(Spill { graph, order, sim, deferred_bytes: bytes - keep })
}

/// Rewrite `t`'s lone prefetch into `k` chunked prefetches on a trial
/// clone. The chunk tensors alias `t`'s pool storage; `t` itself stays a
/// (pool-resident, never-transferred) input of its consumers, so the data
/// dependency on its logical value is preserved while the bytes arrive
/// through the chunks.
fn split_prefetch(g: &Graph, t: TensorId, pf: OpId, k: usize) -> Option<Graph> {
    let consumers: Vec<OpId> = g
        .consumers_of(t)
        .iter()
        .copied()
        .filter(|&c| !g.op(c).kind.is_cache_op())
        .collect();
    let bytes = g.tensor(t).bytes;
    let name = g.tensor(t).name.clone();
    let pf_src = match g.op(pf).kind {
        OpKind::Prefetch { src, .. } => src,
        _ => Tier::Remote,
    };
    let mut trial = g.clone();
    let map = trial.remove_ops(&[pf]);
    let chunk = bytes / k as u64;
    for j in 0..k {
        let sz = if j + 1 == k { bytes - chunk * (k as u64 - 1) } else { chunk };
        let tc = trial.add_tensor(format!("{name}.chunk{j}"), sz, Tier::Remote);
        let pfc = trial.add_op(
            format!("prefetch.{name}.chunk{j}"),
            OpKind::Prefetch { tensor: tc, src: pf_src },
            vec![tc],
            vec![],
        );
        for &cns in &consumers {
            // A Prefetch produces nothing, so listing the chunk as a
            // consumer input creates no dependency edge by itself; the
            // control dep is what orders the consumer after transfer
            // completion (same wiring as the insertion pass). The input
            // additionally ends the chunk's refcount lifetime at its last
            // consumer.
            trial.add_input(map[cns]?, tc);
            trial.add_control_dep(map[cns]?, pfc);
        }
    }
    Some(trial)
}

/// Rewrite the Store/Prefetch round trip of `t` into `k` chunked round
/// trips on a trial clone: each `.chunk` tensor is a chunk view of `t`'s
/// device storage ([`Graph::add_chunk_tensor`]), stored out and prefetched
/// back independently — the release curve steps down per chunk store and
/// back up per chunk arrival (partial-tensor residency), instead of the
/// whole tensor waiting for one monolithic transfer. `t` itself stays an
/// input of its consumers (the logical value), while the bytes move
/// through the chunks. Wiring mirrors the insertion pass: chunk stores
/// inherit the store's anchors, each chunk prefetch waits on its own store
/// (plus the prefetch's non-store anchors), and every window consumer
/// waits on every chunk prefetch.
fn split_round_trip(g: &Graph, t: TensorId, st: OpId, pf: OpId, k: usize) -> Option<Graph> {
    let bytes = g.tensor(t).bytes;
    let name = g.tensor(t).name.clone();
    let st_dst = match g.op(st).kind {
        OpKind::Store { dst, .. } => dst,
        _ => Tier::Remote,
    };
    let pf_src = match g.op(pf).kind {
        OpKind::Prefetch { src, .. } => src,
        _ => Tier::Remote,
    };
    let st_deps = g.op(st).control_deps.clone();
    let pf_deps: Vec<OpId> =
        g.op(pf).control_deps.iter().copied().filter(|&d| d != st).collect();
    let consumers = window_consumers(g, pf);
    if consumers.is_empty() {
        return None;
    }
    let mut trial = g.clone();
    let map = trial.remove_ops(&[st, pf]);
    let chunk = bytes / k as u64;
    for j in 0..k {
        let sz = if j + 1 == k { bytes - chunk * (k as u64 - 1) } else { chunk };
        let tc = trial.add_chunk_tensor(t, format!("{name}.chunk{j}"), sz);
        let stc = trial.add_op(
            format!("store.{name}.chunk{j}"),
            OpKind::Store { tensor: tc, dst: st_dst },
            vec![tc],
            vec![],
        );
        for &d in &st_deps {
            trial.add_control_dep(stc, map[d]?);
        }
        let pfc = trial.add_op(
            format!("prefetch.{name}.chunk{j}"),
            OpKind::Prefetch { tensor: tc, src: pf_src },
            vec![tc],
            vec![],
        );
        trial.add_control_dep(pfc, stc);
        for &d in &pf_deps {
            trial.add_control_dep(pfc, map[d]?);
        }
        for &c in &consumers {
            let cm = map[c]?;
            trial.add_input(cm, tc);
            trial.add_control_dep(cm, pfc);
        }
    }
    Some(trial)
}

/// Scan anchors for prefetch `c` latest-first and return the first
/// validated deferral: within budget and peak cap, strictly improving peak
/// residency (or byte·time at equal peak). Latest-first means maximal pool
/// spill per commit; later scans can still defer further. Returns the
/// trial graph (anchor dep added), its order, and the validating
/// simulation.
///
/// With a recorded `trace` (windowed mode) each probe resumes the
/// baseline simulation at `c`'s position — the earliest point the
/// deferral can perturb — passing the probed anchor dep as a virtual
/// edge, so no per-probe graph clone or full re-simulation happens; the
/// graph is only cloned and mutated for the one probe that commits.
/// Resumed results are bit-identical to the full simulations the
/// trace-less path runs, so both paths pick the same anchor.
#[allow(clippy::too_many_arguments)]
fn best_deferral(
    g: &Graph,
    order: &[OpId],
    c: OpId,
    chw: &crate::sim::HwConfig,
    budget: f64,
    peak_cap: u64,
    cur: &crate::sim::SimResult,
    trace: Option<&SimTrace>,
) -> Option<(Graph, Vec<OpId>, crate::sim::SimResult)> {
    let n = order.len();
    let mut pos = vec![usize::MAX; g.ops.len()];
    for (i, &o) in order.iter().enumerate() {
        pos[o] = i;
    }
    let cur_pos = pos[c];
    let hi = g.succs(c).iter().map(|&s| pos[s]).min().unwrap_or(n);
    let cur_byte_time = cur.residency_byte_time();

    let best_key = (cur.peak_device_bytes, cur_byte_time);
    // Candidate anchors: any compute op ordered before c's first
    // successor. Order position alone does not defer a dep-free prefetch
    // (streams issue as early as they can) — the control dep on the
    // anchor is what pins the issue time, exactly as Algorithm 1 anchors
    // placements. Every op at a position < hi is a non-dependent of c
    // (all dependents sit at/after the first successor), so the dep
    // cannot create a cycle. Scanned latest-first so ties keep the
    // latest anchor — maximal deferral; the scan is capped because each
    // probe costs a simulation (a windowed resume, or a clone + full
    // re-simulation) and deep anchors only get less attractive.
    const MAX_ANCHOR_PROBES: usize = 48;
    let mut probes = 0usize;
    for a_idx in (0..hi).rev() {
        if probes >= MAX_ANCHOR_PROBES {
            break;
        }
        let a = order[a_idx];
        if a == c || !matches!(g.op(a).kind, OpKind::Compute { .. }) {
            continue;
        }
        probes += 1;
        let mut cand: Vec<OpId> = order.to_vec();
        if a_idx > cur_pos {
            // Move c just after its new anchor; after removing c (which
            // was before a), a sits at a_idx - 1.
            cand.remove(cur_pos);
            cand.insert(a_idx, c);
        }
        // `cand` differs from the baseline order only at/after `cur_pos`
        // (c either stays put with a new dep or moves later), and c's
        // preds all precede `cur_pos`, so the candidate is valid by
        // construction and a recorded trace can resume at `cur_pos`.
        let sim = match trace {
            Some(trace) => trace.resume(cur_pos, g, &cand, chw, &[(c, a)]),
            None => {
                let mut trial = g.clone();
                trial.add_control_dep(c, a);
                if !trial.is_valid_order(&cand) {
                    continue;
                }
                simulate(&trial, &cand, chw)
            }
        };
        if sim.makespan_us > budget || sim.peak_device_bytes > peak_cap {
            continue;
        }
        let improves = sim.peak_device_bytes < best_key.0
            || (sim.peak_device_bytes == best_key.0
                && sim.residency_byte_time() < best_key.1 * (1.0 - 1e-9));
        if improves {
            let mut trial = g.clone();
            trial.add_control_dep(c, a);
            debug_assert!(trial.is_valid_order(&cand));
            return Some((trial, cand, sim));
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::passes::Compiler;
    use crate::sim::HwConfig;

    fn hw() -> HwConfig {
        HwConfig::test_default()
    }

    /// 10 ops à 10 ms; op 8 consumes a 10 MB remote weight (10 ms
    /// transfer). Exec-order hides the transfer by prefetching early — at
    /// the cost of the weight idling in HBM.
    fn workload() -> Graph {
        let mut b = GraphBuilder::new();
        let w = b.tensor("w", 10 << 20, crate::graph::Tier::Remote);
        let mut prev = None;
        for i in 0..10 {
            let t = b.tensor(&format!("a{i}"), 0, crate::graph::Tier::Device);
            let mut inputs = prev.map(|p| vec![p]).unwrap_or_default();
            if i == 8 {
                inputs.push(w);
            }
            let o = b.compute(&format!("c{i}"), 10e9, 0, inputs, vec![t]);
            let _ = o;
            prev = Some(t);
        }
        b.build()
    }

    #[test]
    fn no_slo_means_no_op() {
        let mut a = workload();
        let ra = Compiler::new(hw()).compile(&mut a).unwrap();
        let mut b = workload();
        let rb = Compiler::new(hw()).slo_throttle().compile(&mut b).unwrap();
        assert_eq!(rb.throttled, 0);
        assert_eq!(ra.order, rb.order, "throttle without SLO must be inert");
    }

    /// Two streamed weights: a 40 MB one used late and a 5 MB one used
    /// early. The program-order schedule front-loads the big transfer, so
    /// it idles in HBM for half the run and head-of-line blocks the small
    /// one. (No exec-order stage: this exercises the throttle as the
    /// placement authority over a runtime-ish entry schedule.)
    fn two_weight_workload() -> Graph {
        let mut b = GraphBuilder::new();
        let wa = b.tensor("wa", 40 << 20, crate::graph::Tier::Remote);
        let wb = b.tensor("wb", 5 << 20, crate::graph::Tier::Remote);
        let mut prev = None;
        for i in 0..10 {
            let t = b.tensor(&format!("a{i}"), 0, crate::graph::Tier::Device);
            let mut inputs = prev.map(|p| vec![p]).unwrap_or_default();
            if i == 9 {
                inputs.push(wa);
            }
            if i == 2 {
                inputs.push(wb);
            }
            b.compute(&format!("c{i}"), 10e9, 0, inputs, vec![t]);
            prev = Some(t);
        }
        b.build()
    }

    fn no_exec_pipeline(hw: HwConfig) -> Compiler {
        Compiler::empty(hw)
            .pass(crate::passes::LifetimePass)
            .pass(crate::passes::PrefetchInsertPass)
    }

    #[test]
    fn slack_is_spent_on_residency_not_past_the_budget() {
        let mut a = two_weight_workload();
        let ra = no_exec_pipeline(hw()).compile(&mut a).unwrap();
        let sa = simulate(&a, &ra.order, &hw());

        let slo = sa.makespan_us; // zero slack beyond the entry schedule
        let mut b = two_weight_workload();
        let rb = no_exec_pipeline(hw())
            .slo_us(slo)
            .slo_throttle()
            .verify(true)
            .compile(&mut b)
            .unwrap();
        let sb = simulate(&b, &rb.order, &hw());

        assert!(rb.throttled > 0, "deferral opportunity missed");
        assert!(sb.makespan_us <= slo * (1.0 + 1e-9), "budget violated");
        assert!(
            sb.peak_device_bytes <= sa.peak_device_bytes,
            "throttle raised the peak: {} > {}",
            sb.peak_device_bytes,
            sa.peak_device_bytes
        );
        assert!(
            sb.residency_byte_time() < sa.residency_byte_time() * 0.8,
            "deferral must cut idle residency: {} !< {}",
            sb.residency_byte_time(),
            sa.residency_byte_time()
        );
    }

    #[test]
    fn zero_slack_never_regresses() {
        let mut a = workload();
        let ra = Compiler::new(hw()).compile(&mut a).unwrap();
        let sa = simulate(&a, &ra.order, &hw());

        // SLO below what the schedule can do: budget clamps to the entry
        // makespan; only free improvements may land.
        let mut b = workload();
        let rb = Compiler::new(hw())
            .slo_us(sa.makespan_us * 0.5)
            .slo_throttle()
            .verify(true)
            .compile(&mut b)
            .unwrap();
        let sb = simulate(&b, &rb.order, &hw());
        assert!(sb.makespan_us <= sa.makespan_us * (1.0 + 1e-9));
        assert!(sb.peak_device_bytes <= sa.peak_device_bytes);
    }

    /// A decode-step-shaped graph: a deferrable 32 MiB KV writeback whose
    /// Store dwarfs the 40 us of compute it could hide under, with 5 us of
    /// host work waiting on both.
    fn writeback_step() -> Graph {
        let mut g = Graph::new();
        let w = g.add_tensor("kv.wb", 32 << 20, crate::graph::Tier::Device);
        g.set_deferrable(w, true);
        let st = g.add_op("store.kv.wb", OpKind::store(w), vec![w], vec![]);
        let t0 = g.add_tensor("out", 0, crate::graph::Tier::Device);
        let c = g.add_op(
            "decode",
            OpKind::Compute { flops: 40e6, bytes_accessed: 0 },
            vec![],
            vec![t0],
        );
        let h = g.add_op("host", OpKind::HostWork { us: 5.0 }, vec![], vec![]);
        g.add_control_dep(h, c);
        g.add_control_dep(h, st);
        g
    }

    #[test]
    fn spill_sheds_deferrable_writeback_down_to_the_slo() {
        // Entry makespan ~33.6 ms (the 32 MiB store at 1 GB/s); a 50 us
        // SLO forces the spill to keep only what fits: store_end + 5 us of
        // host work <= 50 us -> ~45 KB kept, the rest deferred.
        let mut g = writeback_step();
        let r = Compiler::empty(hw())
            .slo_us(50.0)
            .slo_throttle()
            .verify(true)
            .compile(&mut g)
            .unwrap();
        assert!(r.throttled >= 1, "spill never engaged");
        assert!(
            r.deferred_bytes > 30 << 20,
            "almost everything should spill: {}",
            r.deferred_bytes
        );
        let s = simulate(&g, &r.order, &hw());
        assert!(s.makespan_us <= 50.0 * (1.0 + 1e-9), "SLO missed: {}", s.makespan_us);
        // The kept chunk is a Store of a `.keep` view of the writeback.
        let kept: Vec<_> = g
            .ops
            .iter()
            .filter(|o| matches!(o.kind, OpKind::Store { .. }))
            .collect();
        assert_eq!(kept.len(), 1);
        let OpKind::Store { tensor, .. } = kept[0].kind else { unreachable!() };
        assert_eq!(g.tensor(tensor).alias_of, Some(0));
        assert_eq!(g.tensor(tensor).bytes + r.deferred_bytes, 32 << 20, "byte conservation");
    }

    #[test]
    fn generous_slo_spills_nothing() {
        let mut g = writeback_step();
        let r = Compiler::empty(hw())
            .slo_us(1e9)
            .slo_throttle()
            .verify(true)
            .compile(&mut g)
            .unwrap();
        assert_eq!(r.deferred_bytes, 0);
        assert_eq!(r.throttled, 0);
    }

    /// fwd produces a 256 MB activation, a long mid section opens the idle
    /// window, bwd consumes it — the default pipeline inserts the
    /// Store/Prefetch round trip the throttle then chunks.
    fn big_round_trip_workload() -> Graph {
        let mut b = GraphBuilder::new();
        let act = b.tensor("act", 256 << 20, crate::graph::Tier::Device);
        let sink = b.tensor("sink", 0, crate::graph::Tier::Device);
        b.compute("fwd", 1e6, 0, vec![], vec![act]);
        let mut prev = None;
        for i in 0..8 {
            let t = b.tensor(&format!("m{i}"), 0, crate::graph::Tier::Device);
            let inputs = prev.map(|p| vec![p]).unwrap_or_default();
            let o = b.compute(&format!("mid{i}"), 1e11, 0, inputs, vec![t]);
            if i == 0 {
                b.dep(o, 0);
            }
            prev = Some(t);
        }
        b.compute("bwd", 1e6, 0, vec![act, prev.unwrap()], vec![sink]);
        b.build()
    }

    #[test]
    fn oversized_round_trip_is_split_into_chunked_transfers() {
        let mut a = big_round_trip_workload();
        let ra = Compiler::new(hw()).verify(true).compile(&mut a).unwrap();
        assert_eq!(ra.inserted.len(), 1, "round trip must be inserted");
        let sa = simulate(&a, &ra.order, &hw());

        let mut g = big_round_trip_workload();
        let r = Compiler::new(hw())
            .slo_us(sa.makespan_us * 1.1)
            .slo_throttle()
            .verify(true)
            .compile(&mut g)
            .unwrap();
        let s = simulate(&g, &r.order, &hw());

        assert!(r.chunked >= 1, "round trip never chunked");
        let chunk_stores = g
            .ops
            .iter()
            .filter(|o| matches!(o.kind, OpKind::Store { .. }) && o.name.contains(".chunk"))
            .count();
        let chunk_pfs = g
            .ops
            .iter()
            .filter(|o| matches!(o.kind, OpKind::Prefetch { .. }) && o.name.contains(".chunk"))
            .count();
        assert_eq!(chunk_stores, 4, "256 MB must split into 4 chunk stores");
        assert_eq!(chunk_pfs, 4);
        // Chunk tensors are views of the activation's storage.
        assert!(g
            .tensors
            .iter()
            .filter(|t| t.name.starts_with("act.chunk"))
            .all(|t| t.alias_of == Some(0)));
        assert!(s.makespan_us <= sa.makespan_us * 1.1 * (1.0 + 1e-9));
        assert!(
            s.peak_device_bytes <= sa.peak_device_bytes,
            "chunking raised the peak: {} > {}",
            s.peak_device_bytes,
            sa.peak_device_bytes
        );
        assert!(
            s.residency_byte_time() < sa.residency_byte_time(),
            "partial residency must cut byte-time: {} !< {}",
            s.residency_byte_time(),
            sa.residency_byte_time()
        );
        // Conservation: the four chunk round trips move exactly the
        // activation's bytes twice, like the unsplit round trip did.
        assert_eq!(s.dma_bytes, sa.dma_bytes);
    }

    #[test]
    fn oversized_prefetch_is_split_into_chunks() {
        // One 256 MB weight: the throttle splits it into 4 chunks whose
        // staggered arrival cuts transfer-window residency byte-time.
        let mut b = GraphBuilder::new();
        let w = b.tensor("w", 256 << 20, crate::graph::Tier::Remote);
        let mut prev = None;
        for i in 0..10 {
            let t = b.tensor(&format!("a{i}"), 0, crate::graph::Tier::Device);
            let mut inputs = prev.map(|p| vec![p]).unwrap_or_default();
            if i == 9 {
                inputs.push(w);
            }
            b.compute(&format!("c{i}"), 40e9, 0, inputs, vec![t]);
            prev = Some(t);
        }
        let g0 = b.build();

        let mut a = g0.clone();
        let ra = Compiler::new(hw()).compile(&mut a).unwrap();
        let sa = simulate(&a, &ra.order, &hw());

        let mut g = g0;
        let r = Compiler::new(hw())
            .slo_us(sa.makespan_us * 1.1)
            .slo_throttle()
            .verify(true)
            .compile(&mut g)
            .unwrap();
        let s = simulate(&g, &r.order, &hw());

        let chunks = g
            .ops
            .iter()
            .filter(|o| matches!(o.kind, OpKind::Prefetch { .. }) && o.name.contains(".chunk"))
            .count();
        assert_eq!(chunks, 4, "256 MB must split into 4 chunks");
        assert!(s.makespan_us <= sa.makespan_us * 1.1 * (1.0 + 1e-9));
        assert!(s.peak_device_bytes <= sa.peak_device_bytes);
        assert!(
            s.residency_byte_time() < sa.residency_byte_time(),
            "chunked arrival must cut byte-time: {} !< {}",
            s.residency_byte_time(),
            sa.residency_byte_time()
        );
    }

    /// The detour shape hand-built on the peer edge: w round-trips
    /// through a neighbor's HBM (Store → Peer, Promote Peer → pool, pool
    /// Prefetch). Over a slow device↔device link the detour costs ~270 ms
    /// of transfers where the direct pool round trip costs ~67 ms.
    fn peer_detour_graph() -> Graph {
        let mut b = GraphBuilder::new();
        let w = b.tensor("w", 32 << 20, crate::graph::Tier::Device);
        let out = b.tensor("out", 0, crate::graph::Tier::Device);
        let p = b.compute("produce", 10e9, 0, vec![], vec![w]);
        let st = b.store_to("store.w", w, crate::graph::Tier::Peer(1));
        let pm =
            b.promote("promote.w", w, crate::graph::Tier::Peer(1), crate::graph::Tier::Remote);
        let pf = b.prefetch("fetch.w", w);
        let c = b.compute("consume", 10e9, 0, vec![w], vec![out]);
        b.dep(st, p);
        b.dep(pm, st);
        b.dep(pf, pm);
        b.dep(c, pf);
        b.build()
    }

    #[test]
    fn over_budget_peer_detour_is_vetoed_back_to_the_pool() {
        let phw = hw().with_peer_link(0.25, 10.0);
        let mut a = peer_detour_graph();
        let ra = Compiler::empty(phw.clone()).verify(true).compile(&mut a).unwrap();
        let sa = simulate(&a, &ra.order, &phw);

        // An SLO far under the detoured makespan but above the pool-only
        // round trip: the veto must fire and land inside the budget.
        let slo = 100_000.0;
        assert!(sa.makespan_us > slo, "fixture detour must blow the SLO: {}", sa.makespan_us);
        let mut g = peer_detour_graph();
        let r = Compiler::empty(phw.clone())
            .slo_us(slo)
            .slo_throttle()
            .verify(true)
            .compile(&mut g)
            .unwrap();
        let s = simulate(&g, &r.order, &phw);

        assert_eq!(r.vetoed, 1, "the peer detour must be vetoed");
        assert!(r.throttled >= 1);
        assert!(!g.ops.iter().any(|o| matches!(o.kind, OpKind::Promote { .. })));
        assert!(g
            .ops
            .iter()
            .any(|o| matches!(o.kind, OpKind::Store { dst: Tier::Remote, .. })));
        assert!(s.makespan_us <= slo * (1.0 + 1e-9), "SLO missed: {}", s.makespan_us);
        assert!(s.peak_device_bytes <= sa.peak_device_bytes);

        // A generous SLO leaves the (affordable) detour alone.
        let mut k = peer_detour_graph();
        let rk = Compiler::empty(phw)
            .slo_us(1e9)
            .slo_throttle()
            .verify(true)
            .compile(&mut k)
            .unwrap();
        assert_eq!(rk.vetoed, 0);
        assert!(k.ops.iter().any(|o| matches!(o.kind, OpKind::Promote { .. })));
    }

    #[test]
    fn tier_placement_detours_are_vetoed_under_a_tight_slo() {
        use crate::passes::TierPlacement;
        use crate::sim::TierTopology;
        let base = hw();
        let hw3 = base.clone().with_tiers(TierTopology::three_tier(&base));
        // hide_factor 10: placement optimistically rehomes round trips
        // whose ~42 ms deep paths the ~24 ms windows cannot actually hide
        // — the throttle is the tail-budget backstop.
        let aggressive = TierPlacement { hide_factor: 10.0, min_bytes: 1 };

        let mk = || GraphBuilder::fwd_bwd_chain(4, 8 << 20, 10e9, 24, 1e9);
        let mut a = mk();
        let ra = Compiler::new(hw3.clone())
            .pass_before("exec-order", aggressive.clone())
            .verify(true)
            .compile(&mut a)
            .unwrap();
        assert!(ra.retiered >= 1, "fixture must rehome something");
        let sa = simulate(&a, &ra.order, &hw3);

        let mut p = mk();
        let rp = Compiler::new(hw3.clone()).verify(true).compile(&mut p).unwrap();
        let sp = simulate(&p, &rp.order, &hw3);
        assert!(
            sa.makespan_us > sp.makespan_us,
            "detours must be exposed for this test: {} !> {}",
            sa.makespan_us,
            sp.makespan_us
        );

        let mut g = mk();
        let r = Compiler::new(hw3)
            .pass_before("exec-order", aggressive)
            .slo_us(sp.makespan_us * 1.02)
            .slo_throttle()
            .verify(true)
            .compile(&mut g)
            .unwrap();
        let s = simulate(&g, &r.order, &hw3);
        assert!(r.vetoed >= 1, "no detour vetoed");
        assert!(r.vetoed <= r.retiered);
        assert!(
            s.makespan_us < sa.makespan_us,
            "veto must claw back makespan: {} !< {}",
            s.makespan_us,
            sa.makespan_us
        );
        assert!(s.peak_device_bytes <= sa.peak_device_bytes);
    }
}
