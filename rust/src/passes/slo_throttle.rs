//! `SloThrottle`: shape transfer timing against a latency SLO — defer or
//! split prefetches whose bandwidth demand crowds the schedule, preferring
//! to spill pool headroom (bytes stay remote longer) over early residency.
//!
//! Modeled on "Memory Offloading for LLM Inference with Latency SLO
//! Guarantees": offload traffic must not push the serving/step latency past
//! its budget, and transfer *timing* — not just placement — is a resource
//! to allocate. This pass runs after exec-order on the session's pinned
//! schedule and applies two rewrites, each speculated and validated by
//! re-simulation under the session's assumed fabric contention:
//!
//! * **split** — a monolithic prefetch of a pool-resident tensor becomes
//!   `k` chunked prefetches (fresh `.chunk` tensors aliasing the same pool
//!   storage, every consumer waiting on all chunks). Chunks arrive
//!   staggered instead of as one bandwidth spike, roughly halving the
//!   transfer-window residency byte·time and giving the scheduler
//!   preemption points between chunks.
//! * **defer** — a prefetch is re-anchored later (control dep on a later
//!   compute op, the same mechanism Algorithm 1 uses to pin issue time),
//!   trading latency slack for memory: the bytes spill into pool headroom
//!   until closer to their use.
//!
//! ## How the SLO budget is apportioned
//!
//! The budget is global, not per-transfer: `budget = max(slo_us, entry
//! makespan)` (an already-over-SLO schedule is never made worse). Rewrites
//! are committed greedily — latest-consumer prefetches first — and every
//! commit must keep the *re-simulated* makespan within the budget and the
//! peak at-or-below the entry schedule's peak, and must strictly improve
//! peak residency or residency byte·time. Whatever slack one decision
//! consumes is gone for the next (each speculation re-simulates the live
//! graph), so the pass never overdraws the SLO. Consequently the throttled
//! schedule's peak device bytes never exceed the no-throttle schedule's —
//! the P11 invariant.

use crate::graph::{Graph, OpId, OpKind, TensorId, Tier};
use crate::sim::simulate;

use super::compiler::{AnalysisCache, CompileError, Diagnostic, Pass, PassCtx, PassReport};

/// The SLO-aware transfer throttle. A no-op unless the session sets an SLO
/// ([`Compiler::slo_us`](super::Compiler::slo_us)).
#[derive(Debug, Clone)]
pub struct SloThrottle {
    /// Split pool-resident prefetches of at least `2 × split_min_bytes`
    /// into chunks of roughly this size.
    pub split_min_bytes: u64,
    /// Upper bound on chunks per split.
    pub max_chunks: usize,
    /// Safety bound on committed rewrites (splits + deferrals) per
    /// compile — each commit re-simulates, so this bounds compile time.
    pub max_decisions: usize,
}

impl Default for SloThrottle {
    fn default() -> Self {
        Self { split_min_bytes: 64 << 20, max_chunks: 4, max_decisions: 64 }
    }
}

impl Pass for SloThrottle {
    fn name(&self) -> &'static str {
        "slo-throttle"
    }

    fn run(
        &mut self,
        g: &mut Graph,
        cache: &mut AnalysisCache,
        ctx: &PassCtx,
    ) -> Result<PassReport, CompileError> {
        let mut rep = PassReport::new(self.name());
        let Some(slo) = ctx.slo_us else {
            rep.diagnostics
                .push(Diagnostic::info(self.name(), "no SLO configured; pass skipped"));
            return Ok(rep);
        };
        let chw = ctx.contended_hw();
        let entry_order = cache.pinned_or_topo(g)?;
        let base = simulate(g, &entry_order, &chw);
        // Global budget: never regress an already-over-SLO schedule.
        let budget = slo.max(base.makespan_us);
        let peak_cap = base.peak_device_bytes;

        let mut order = entry_order;
        let mut split_count = 0usize;
        let mut deferred = 0usize;

        // ---- phase 1: split oversized pool-resident prefetches ----------
        let mut decided: Vec<TensorId> = Vec::new();
        let mut cur = base.clone();
        while split_count + deferred < self.max_decisions {
            let Some((t, pf, k)) = self.split_candidate(g, &decided) else { break };
            decided.push(t);
            let Some(trial) = split_prefetch(g, t, pf, k) else { continue };
            let Ok(torder) = trial.topo_order_detailed() else { continue };
            let sim = simulate(&trial, &torder, &chw);
            // Same contract as deferrals: stay within budget and peak cap,
            // and strictly improve peak or residency byte·time.
            let improves = sim.peak_device_bytes < cur.peak_device_bytes
                || (sim.peak_device_bytes == cur.peak_device_bytes
                    && sim.residency_byte_time()
                        < cur.residency_byte_time() * (1.0 - 1e-9));
            if sim.makespan_us <= budget && sim.peak_device_bytes <= peak_cap && improves {
                let name = g.tensor(t).name.clone();
                *g = trial;
                order = torder;
                cur = sim;
                split_count += 1;
                rep.diagnostics.push(Diagnostic::info(
                    self.name(),
                    format!("split prefetch of '{name}' into {k} chunked transfers"),
                ));
            }
        }

        // ---- phase 2: defer prefetches into the SLO slack ----------------
        // Latest-consumer prefetches first: their windows close last, so
        // they have the most slack to spend. `cur` stays valid across
        // rejected speculations — only commits change the graph.
        while split_count + deferred < self.max_decisions {
            let mut committed = false;
            let prefetches: Vec<OpId> = order
                .iter()
                .rev()
                .copied()
                .filter(|&o| matches!(g.op(o).kind, OpKind::Prefetch { .. }))
                .collect();
            for c in prefetches {
                let Some((trial, cand_order, sim)) =
                    best_deferral(g, &order, c, &chw, budget, peak_cap, &cur)
                else {
                    continue;
                };
                let name = g.op(c).name.clone();
                *g = trial;
                order = cand_order;
                deferred += 1;
                committed = true;
                rep.diagnostics.push(Diagnostic::info(
                    self.name(),
                    format!(
                        "deferred '{name}': peak {} -> {} bytes, makespan {:.1} -> {:.1} us \
                         (budget {budget:.1})",
                        cur.peak_device_bytes,
                        sim.peak_device_bytes,
                        cur.makespan_us,
                        sim.makespan_us
                    ),
                ));
                cur = sim;
                break; // rescan against the committed graph
            }
            if !committed {
                break;
            }
        }

        let final_sim = cur;
        rep.throttled = split_count + deferred;
        rep.diagnostics.push(Diagnostic::info(
            self.name(),
            format!(
                "{split_count} split(s), {deferred} deferral(s); makespan {:.1} us against a \
                 {budget:.1} us budget, peak {} bytes (entry {})",
                final_sim.makespan_us, final_sim.peak_device_bytes, peak_cap
            ),
        ));
        cache.pin_order(g, order.clone());
        rep.order = Some(order);
        Ok(rep)
    }
}

impl SloThrottle {
    /// Next splittable prefetch: pool-resident tensor, exactly one cache
    /// op (its lone prefetch), big enough for ≥ 2 chunks.
    fn split_candidate(&self, g: &Graph, decided: &[TensorId]) -> Option<(TensorId, OpId, usize)> {
        if self.split_min_bytes == 0 {
            return None;
        }
        for t in &g.tensors {
            if t.home != Tier::Remote
                || t.bytes < 2 * self.split_min_bytes
                || decided.contains(&t.id)
            {
                continue;
            }
            let cache_ops: Vec<OpId> = g
                .ops
                .iter()
                .filter(|o| o.kind.cache_tensor() == Some(t.id))
                .map(|o| o.id)
                .collect();
            if cache_ops.len() != 1 {
                continue;
            }
            let pf = cache_ops[0];
            if !matches!(g.op(pf).kind, OpKind::Prefetch { .. }) {
                continue;
            }
            if !g.consumers_of(t.id).iter().any(|&c| !g.op(c).kind.is_cache_op()) {
                continue;
            }
            let k = ((t.bytes / self.split_min_bytes) as usize).clamp(2, self.max_chunks.max(2));
            return Some((t.id, pf, k));
        }
        None
    }
}

/// Rewrite `t`'s lone prefetch into `k` chunked prefetches on a trial
/// clone. The chunk tensors alias `t`'s pool storage; `t` itself stays a
/// (pool-resident, never-transferred) input of its consumers, so the data
/// dependency on its logical value is preserved while the bytes arrive
/// through the chunks.
fn split_prefetch(g: &Graph, t: TensorId, pf: OpId, k: usize) -> Option<Graph> {
    let consumers: Vec<OpId> = g
        .consumers_of(t)
        .iter()
        .copied()
        .filter(|&c| !g.op(c).kind.is_cache_op())
        .collect();
    let bytes = g.tensor(t).bytes;
    let name = g.tensor(t).name.clone();
    let mut trial = g.clone();
    let map = trial.remove_ops(&[pf]);
    let chunk = bytes / k as u64;
    for j in 0..k {
        let sz = if j + 1 == k { bytes - chunk * (k as u64 - 1) } else { chunk };
        let tc = trial.add_tensor(format!("{name}.chunk{j}"), sz, Tier::Remote);
        let pfc = trial.add_op(
            format!("prefetch.{name}.chunk{j}"),
            OpKind::Prefetch { tensor: tc },
            vec![tc],
            vec![],
        );
        for &cns in &consumers {
            // A Prefetch produces nothing, so listing the chunk as a
            // consumer input creates no dependency edge by itself; the
            // control dep is what orders the consumer after transfer
            // completion (same wiring as the insertion pass). The input
            // additionally ends the chunk's refcount lifetime at its last
            // consumer.
            trial.add_input(map[cns]?, tc);
            trial.add_control_dep(map[cns]?, pfc);
        }
    }
    Some(trial)
}

/// Scan anchors for prefetch `c` latest-first and return the first
/// validated deferral: within budget and peak cap, strictly improving peak
/// residency (or byte·time at equal peak). Latest-first means maximal pool
/// spill per commit; later scans can still defer further. Returns the
/// trial graph (anchor dep added), its order, and the validating
/// simulation.
#[allow(clippy::too_many_arguments)]
fn best_deferral(
    g: &Graph,
    order: &[OpId],
    c: OpId,
    chw: &crate::sim::HwConfig,
    budget: f64,
    peak_cap: u64,
    cur: &crate::sim::SimResult,
) -> Option<(Graph, Vec<OpId>, crate::sim::SimResult)> {
    let n = order.len();
    let mut pos = vec![usize::MAX; g.ops.len()];
    for (i, &o) in order.iter().enumerate() {
        pos[o] = i;
    }
    let cur_pos = pos[c];
    let hi = g.succs(c).iter().map(|&s| pos[s]).min().unwrap_or(n);
    let cur_byte_time = cur.residency_byte_time();

    let best_key = (cur.peak_device_bytes, cur_byte_time);
    // Candidate anchors: any compute op ordered before c's first
    // successor. Order position alone does not defer a dep-free prefetch
    // (streams issue as early as they can) — the control dep on the
    // anchor is what pins the issue time, exactly as Algorithm 1 anchors
    // placements. Every op at a position < hi is a non-dependent of c
    // (all dependents sit at/after the first successor), so the dep
    // cannot create a cycle. Scanned latest-first so ties keep the
    // latest anchor — maximal deferral; the scan is capped because each
    // probe costs a clone + simulation and deep anchors only get less
    // attractive.
    const MAX_ANCHOR_PROBES: usize = 48;
    let mut probes = 0usize;
    for a_idx in (0..hi).rev() {
        if probes >= MAX_ANCHOR_PROBES {
            break;
        }
        let a = order[a_idx];
        if a == c || !matches!(g.op(a).kind, OpKind::Compute { .. }) {
            continue;
        }
        probes += 1;
        let mut cand: Vec<OpId> = order.to_vec();
        if a_idx > cur_pos {
            // Move c just after its new anchor; after removing c (which
            // was before a), a sits at a_idx - 1.
            cand.remove(cur_pos);
            cand.insert(a_idx, c);
        }
        let mut trial = g.clone();
        trial.add_control_dep(c, a);
        if !trial.is_valid_order(&cand) {
            continue;
        }
        let sim = simulate(&trial, &cand, chw);
        if sim.makespan_us > budget || sim.peak_device_bytes > peak_cap {
            continue;
        }
        let improves = sim.peak_device_bytes < best_key.0
            || (sim.peak_device_bytes == best_key.0
                && sim.residency_byte_time() < best_key.1 * (1.0 - 1e-9));
        if improves {
            return Some((trial, cand, sim));
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphBuilder;
    use crate::passes::Compiler;
    use crate::sim::HwConfig;

    fn hw() -> HwConfig {
        HwConfig::test_default()
    }

    /// 10 ops à 10 ms; op 8 consumes a 10 MB remote weight (10 ms
    /// transfer). Exec-order hides the transfer by prefetching early — at
    /// the cost of the weight idling in HBM.
    fn workload() -> Graph {
        let mut b = GraphBuilder::new();
        let w = b.tensor("w", 10 << 20, crate::graph::Tier::Remote);
        let mut prev = None;
        for i in 0..10 {
            let t = b.tensor(&format!("a{i}"), 0, crate::graph::Tier::Device);
            let mut inputs = prev.map(|p| vec![p]).unwrap_or_default();
            if i == 8 {
                inputs.push(w);
            }
            let o = b.compute(&format!("c{i}"), 10e9, 0, inputs, vec![t]);
            let _ = o;
            prev = Some(t);
        }
        b.build()
    }

    #[test]
    fn no_slo_means_no_op() {
        let mut a = workload();
        let ra = Compiler::new(hw()).compile(&mut a).unwrap();
        let mut b = workload();
        let rb = Compiler::new(hw()).slo_throttle().compile(&mut b).unwrap();
        assert_eq!(rb.throttled, 0);
        assert_eq!(ra.order, rb.order, "throttle without SLO must be inert");
    }

    /// Two streamed weights: a 40 MB one used late and a 5 MB one used
    /// early. The program-order schedule front-loads the big transfer, so
    /// it idles in HBM for half the run and head-of-line blocks the small
    /// one. (No exec-order stage: this exercises the throttle as the
    /// placement authority over a runtime-ish entry schedule.)
    fn two_weight_workload() -> Graph {
        let mut b = GraphBuilder::new();
        let wa = b.tensor("wa", 40 << 20, crate::graph::Tier::Remote);
        let wb = b.tensor("wb", 5 << 20, crate::graph::Tier::Remote);
        let mut prev = None;
        for i in 0..10 {
            let t = b.tensor(&format!("a{i}"), 0, crate::graph::Tier::Device);
            let mut inputs = prev.map(|p| vec![p]).unwrap_or_default();
            if i == 9 {
                inputs.push(wa);
            }
            if i == 2 {
                inputs.push(wb);
            }
            b.compute(&format!("c{i}"), 10e9, 0, inputs, vec![t]);
            prev = Some(t);
        }
        b.build()
    }

    fn no_exec_pipeline(hw: HwConfig) -> Compiler {
        Compiler::empty(hw)
            .pass(crate::passes::LifetimePass)
            .pass(crate::passes::PrefetchInsertPass)
    }

    #[test]
    fn slack_is_spent_on_residency_not_past_the_budget() {
        let mut a = two_weight_workload();
        let ra = no_exec_pipeline(hw()).compile(&mut a).unwrap();
        let sa = simulate(&a, &ra.order, &hw());

        let slo = sa.makespan_us; // zero slack beyond the entry schedule
        let mut b = two_weight_workload();
        let rb = no_exec_pipeline(hw())
            .slo_us(slo)
            .slo_throttle()
            .verify(true)
            .compile(&mut b)
            .unwrap();
        let sb = simulate(&b, &rb.order, &hw());

        assert!(rb.throttled > 0, "deferral opportunity missed");
        assert!(sb.makespan_us <= slo * (1.0 + 1e-9), "budget violated");
        assert!(
            sb.peak_device_bytes <= sa.peak_device_bytes,
            "throttle raised the peak: {} > {}",
            sb.peak_device_bytes,
            sa.peak_device_bytes
        );
        assert!(
            sb.residency_byte_time() < sa.residency_byte_time() * 0.8,
            "deferral must cut idle residency: {} !< {}",
            sb.residency_byte_time(),
            sa.residency_byte_time()
        );
    }

    #[test]
    fn zero_slack_never_regresses() {
        let mut a = workload();
        let ra = Compiler::new(hw()).compile(&mut a).unwrap();
        let sa = simulate(&a, &ra.order, &hw());

        // SLO below what the schedule can do: budget clamps to the entry
        // makespan; only free improvements may land.
        let mut b = workload();
        let rb = Compiler::new(hw())
            .slo_us(sa.makespan_us * 0.5)
            .slo_throttle()
            .verify(true)
            .compile(&mut b)
            .unwrap();
        let sb = simulate(&b, &rb.order, &hw());
        assert!(sb.makespan_us <= sa.makespan_us * (1.0 + 1e-9));
        assert!(sb.peak_device_bytes <= sa.peak_device_bytes);
    }

    #[test]
    fn oversized_prefetch_is_split_into_chunks() {
        // One 256 MB weight: the throttle splits it into 4 chunks whose
        // staggered arrival cuts transfer-window residency byte-time.
        let mut b = GraphBuilder::new();
        let w = b.tensor("w", 256 << 20, crate::graph::Tier::Remote);
        let mut prev = None;
        for i in 0..10 {
            let t = b.tensor(&format!("a{i}"), 0, crate::graph::Tier::Device);
            let mut inputs = prev.map(|p| vec![p]).unwrap_or_default();
            if i == 9 {
                inputs.push(w);
            }
            b.compute(&format!("c{i}"), 40e9, 0, inputs, vec![t]);
            prev = Some(t);
        }
        let g0 = b.build();

        let mut a = g0.clone();
        let ra = Compiler::new(hw()).compile(&mut a).unwrap();
        let sa = simulate(&a, &ra.order, &hw());

        let mut g = g0;
        let r = Compiler::new(hw())
            .slo_us(sa.makespan_us * 1.1)
            .slo_throttle()
            .verify(true)
            .compile(&mut g)
            .unwrap();
        let s = simulate(&g, &r.order, &hw());

        let chunks = g
            .ops
            .iter()
            .filter(|o| matches!(o.kind, OpKind::Prefetch { .. }) && o.name.contains(".chunk"))
            .count();
        assert_eq!(chunks, 4, "256 MB must split into 4 chunks");
        assert!(s.makespan_us <= sa.makespan_us * 1.1 * (1.0 + 1e-9));
        assert!(s.peak_device_bytes <= sa.peak_device_bytes);
        assert!(
            s.residency_byte_time() < sa.residency_byte_time(),
            "chunked arrival must cut byte-time: {} !< {}",
            s.residency_byte_time(),
            sa.residency_byte_time()
        );
    }
}
