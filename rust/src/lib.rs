//! # HyperOffload — reproduction
//!
//! Graph-driven hierarchical memory management for LLMs on SuperNode
//! architectures (Liu et al., CS.DC 2026), rebuilt as a three-layer
//! rust + JAX + Pallas stack (see DESIGN.md).
//!
//! The paper's contribution — cache operators (`Prefetch`/`Store`/`Detach`)
//! as first-class computation-graph nodes plus a graph-driven execution-order
//! refinement (Algorithm 1) — lives in [`graph`] and [`passes`]. Everything
//! the paper's evaluation depends on (SuperNode memory tiers, a reactive
//! runtime baseline, a KV-cache manager, a serving stack, training-step
//! simulation, high availability) is built as substrates in the sibling
//! modules. Real model execution (the end-to-end serving example) goes
//! through [`runtime`], which loads AOT-compiled HLO-text artifacts.

pub mod coordinator;
pub mod graph;
pub mod ha;
pub mod kvcache;
pub mod memory;
pub mod passes;
pub mod runtime;
pub mod serving;
pub mod runtime_sched;
pub mod sim;
pub mod training;
pub mod util;
