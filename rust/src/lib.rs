//! # HyperOffload — reproduction
//!
//! Graph-driven hierarchical memory management for LLMs on SuperNode
//! architectures (Liu et al., CS.DC 2026), rebuilt as a three-layer
//! rust + JAX + Pallas stack (see DESIGN.md).
//!
//! The paper's contribution — cache operators (`Prefetch`/`Store`/`Detach`)
//! as first-class computation-graph nodes plus a graph-driven execution-order
//! refinement (Algorithm 1) — lives in [`graph`] and [`passes`]. Everything
//! the paper's evaluation depends on (SuperNode memory tiers, a reactive
//! runtime baseline, a KV-cache manager, a serving stack, training-step
//! simulation, high availability) is built as substrates in the sibling
//! modules. Real model execution (the end-to-end serving example) goes
//! through `runtime` (xla-gated), which loads AOT-compiled HLO-text artifacts
//! (requires the `xla` feature and a vendored `xla` crate).
//!
//! ## The compiler session API
//!
//! Compilation is driven by [`passes::Compiler`], a *session* builder over
//! a trait-based pass pipeline:
//!
//! ```no_run
//! use hyperoffload::graph::GraphBuilder;
//! use hyperoffload::passes::Compiler;
//! use hyperoffload::sim::{simulate, HwConfig};
//!
//! let hw = HwConfig::ascend910c_like();
//! let (mut g, _) = GraphBuilder::chain_with_remote_weights(12, 2e12, 1 << 20, 100 << 20);
//! let report = Compiler::new(hw.clone())
//!     .verify(true) // IR verifier between stages
//!     .compile(&mut g)
//!     .expect("compile");
//! let sim = simulate(&g, &report.order, &hw);
//! assert!(sim.makespan_us > 0.0);
//! ```
//!
//! Each stage is a [`passes::Pass`] sharing one memoised
//! [`passes::AnalysisCache`]; failures are structured
//! ([`passes::CompileError`] — cycles carry their culprit ops, verifier
//! findings their diagnostics). Adding an optimisation means registering a
//! pass, not forking the pipeline: [`passes::ElideRedundantTransfers`]
//! (capacity-aware round-trip elision),
//! [`passes::RecomputeVsOffload`] (speculate-then-validate recompute vs
//! transfer), [`passes::SloThrottle`] (SLO-bounded transfer deferral /
//! splitting) and [`runtime_sched::ReactivePass`] (the paper's reactive
//! baseline as a pipeline configuration) are all expressed this way. See
//! the [`passes`] module docs for the pipeline diagram, the decision-pass
//! cost model, and a custom-pass walkthrough.
//!
//! ## Cluster-scale serving
//!
//! The serving stack simulates the paper's §7 multi-NPU setting as a
//! first-class object: [`serving::SimServingEngine`] is a *steppable*
//! engine (`enqueue` / `step` / `step_until`) that does not own the
//! global clock, and [`serving::SimCluster`] advances N replicas through
//! one event loop while they share
//!
//! * one capacity-accounted remote pool ([`memory::PoolHandle`] — every
//!   offloaded KV block reserves real bytes, so siblings can starve each
//!   other), and
//! * one bandwidth-contended device↔pool fabric ([`sim::Fabric`] —
//!   per-link rates degrade to `aggregate / k` once `k` concurrent
//!   transferrers saturate the node's provisioning).
//!
//! Requests are dispatched online at arrival time from live replica state
//! (outstanding tokens, KV headroom, pool pressure) with completion
//! feedback ([`serving::Router::route_live`]); the static
//! `Router::partition` path remains as the blind baseline. A cluster of
//! N=1 reproduces the single-engine timings bit-for-bit.

pub mod analysis;
#[cfg(feature = "xla")]
pub mod coordinator;
pub mod graph;
pub mod ha;
pub mod kvcache;
pub mod memory;
pub mod passes;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod serving;
pub mod runtime_sched;
pub mod sim;
pub mod training;
pub mod util;
