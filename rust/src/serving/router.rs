//! Request router over multiple engine replicas (the L3 leader's front
//! door, vLLM-router-shaped).
//!
//! Two dispatch modes:
//! * static — [`Router::partition`] splits a whole workload up front from
//!   the router's own cumulative token counters (the closed-loop bench
//!   path; no completion feedback);
//! * online — [`Router::route_live`] decides per arrival from *live*
//!   replica state ([`ReplicaView`]: outstanding tokens, KV headroom,
//!   pool pressure) with completions fed back via [`Router::complete`],
//!   so a replica that drained early takes new work immediately.
//!
//! Online routing is additionally *prefix-affine*: requests carrying
//! [`Request::block_hashes`] remember which replica last served their
//! template (keyed by the prefix root hash), and among replicas whose
//! load is within one [`AFFINITY_SLACK`]-token bucket the affine replica
//! wins — its device working set is warm even though the pool-resident
//! prefix itself is shared cluster-wide. Requests without hashes rank
//! exactly as before (the bucket is a monotone function of the load, so
//! the tiebreak chain degenerates to plain least-loaded).

use std::collections::HashMap;

use super::request::Request;

/// Load difference (tokens) within which prefix affinity may override
/// least-loaded placement: replicas are ranked by `outstanding_tokens /
/// AFFINITY_SLACK` first, affinity second, exact load last.
pub const AFFINITY_SLACK: u64 = 4096;

/// Live state of one engine replica, sampled at dispatch time by the
/// cluster orchestrator.
#[derive(Debug, Clone, Default)]
pub struct ReplicaView {
    /// Token work queued + in flight on the replica right now.
    pub outstanding_tokens: u64,
    /// Tokens of KV the replica could still admit (device headroom for
    /// the baseline policy, pool headroom under offload).
    pub kv_headroom_tokens: u64,
    /// Occupancy of the replica's (possibly shared) remote pool, [0, 1].
    pub pool_pressure: f64,
    /// Bytes of HBM this replica is currently lending to peers under the
    /// harvest protocol (0 when harvesting is off or it lends nothing).
    /// Loading an active lender forces a revocation — every borrowed
    /// block demotes to the pool — so the router steers work away from
    /// live lenders when an equally-loaded non-lender exists.
    pub lending_bytes: u64,
    /// The replica's local clock (us).
    pub now_us: f64,
}

/// Routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    /// Pick the replica with the least outstanding token work.
    LeastLoaded,
}

/// Router state over `n` replicas.
#[derive(Debug)]
pub struct Router {
    policy: RoutePolicy,
    /// Outstanding work (tokens) per replica.
    load: Vec<u64>,
    next_rr: usize,
    /// Replica that last served each shared-prefix template, keyed by the
    /// prefix root (first chain hash). Online dispatch only.
    affinity: HashMap<u64, usize>,
}

impl Router {
    pub fn new(n_replicas: usize, policy: RoutePolicy) -> Self {
        assert!(n_replicas > 0);
        Self { policy, load: vec![0; n_replicas], next_rr: 0, affinity: HashMap::new() }
    }

    pub fn n_replicas(&self) -> usize {
        self.load.len()
    }

    /// Route one request; returns the replica index.
    pub fn route(&mut self, req: &Request) -> usize {
        let idx = match self.policy {
            RoutePolicy::RoundRobin => {
                let i = self.next_rr;
                self.next_rr = (self.next_rr + 1) % self.load.len();
                i
            }
            RoutePolicy::LeastLoaded => self
                .load
                .iter()
                .enumerate()
                .min_by_key(|(_, &l)| l)
                .map(|(i, _)| i)
                .unwrap(),
        };
        self.load[idx] += (req.prompt_tokens + req.gen_tokens) as u64;
        idx
    }

    /// Route one request using live replica state (online dispatch).
    /// Returns the replica index. `views[i]` must describe replica `i`
    /// at the request's arrival time.
    pub fn route_live(&mut self, req: &Request, views: &[ReplicaView]) -> usize {
        assert_eq!(views.len(), self.load.len(), "one view per replica");
        let idx = match self.policy {
            RoutePolicy::RoundRobin => {
                let i = self.next_rr;
                self.next_rr = (self.next_rr + 1) % self.load.len();
                i
            }
            RoutePolicy::LeastLoaded => {
                // Outstanding work dominates; a replica that lacks the KV
                // headroom for this request (it would defrag or preempt
                // to take it) is pushed to the back of the ranking. Among
                // replicas in the same load bucket, active lenders lose —
                // loading one revokes its leases and demotes every
                // borrowed block to the pool — then the one that last
                // served this request's prefix template wins the tie.
                // With harvesting off, `lending_bytes` is 0 everywhere
                // and the ordering is exactly the pre-harvest chain.
                let need = (req.prompt_tokens + req.gen_tokens) as u64;
                let root = req.block_hashes.first().copied();
                views
                    .iter()
                    .enumerate()
                    .min_by_key(|(i, v)| {
                        let starved = v.kv_headroom_tokens < need;
                        let miss =
                            root.map_or(false, |h| self.affinity.get(&h) != Some(i));
                        (
                            starved,
                            v.outstanding_tokens / AFFINITY_SLACK,
                            v.lending_bytes > 0,
                            miss,
                            v.outstanding_tokens,
                        )
                    })
                    .map(|(i, _)| i)
                    .unwrap()
            }
        };
        if let Some(&h) = req.block_hashes.first() {
            self.affinity.insert(h, idx);
        }
        self.load[idx] += (req.prompt_tokens + req.gen_tokens) as u64;
        idx
    }

    /// Mark a request complete on its replica.
    pub fn complete(&mut self, replica: usize, req: &Request) {
        let w = (req.prompt_tokens + req.gen_tokens) as u64;
        self.load[replica] = self.load[replica].saturating_sub(w);
    }

    pub fn load_of(&self, replica: usize) -> u64 {
        self.load[replica]
    }

    /// Partition a workload across replicas (static dispatch for the
    /// closed-loop benches).
    pub fn partition(&mut self, requests: &[Request]) -> Vec<Vec<Request>> {
        let mut out = vec![Vec::new(); self.load.len()];
        for r in requests {
            let i = self.route(r);
            out[i].push(r.clone());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, p: usize, g: usize) -> Request {
        Request { id, arrival_us: 0.0, prompt_tokens: p, gen_tokens: g, block_hashes: vec![] }
    }

    fn shared_req(id: u64, root: u64) -> Request {
        Request { block_hashes: vec![root, root ^ 1], ..req(id, 100, 50) }
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(3, RoutePolicy::RoundRobin);
        let targets: Vec<usize> = (0..6).map(|i| r.route(&req(i, 10, 10))).collect();
        assert_eq!(targets, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_balances_uneven_work() {
        let mut r = Router::new(2, RoutePolicy::LeastLoaded);
        // Big request to 0, small ones should then prefer 1.
        assert_eq!(r.route(&req(0, 10_000, 1000)), 0);
        assert_eq!(r.route(&req(1, 10, 10)), 1);
        assert_eq!(r.route(&req(2, 10, 10)), 1);
    }

    #[test]
    fn complete_releases_load() {
        let mut r = Router::new(2, RoutePolicy::LeastLoaded);
        let big = req(0, 10_000, 0);
        let i = r.route(&big);
        assert!(r.load_of(i) > 0);
        r.complete(i, &big);
        assert_eq!(r.load_of(i), 0);
    }

    #[test]
    fn route_live_prefers_drained_replica() {
        let mut r = Router::new(2, RoutePolicy::LeastLoaded);
        // Replica 0 has a fat *cumulative* history but is idle now;
        // replica 1 is still grinding. Live routing must pick 0.
        let views = vec![
            ReplicaView { outstanding_tokens: 0, kv_headroom_tokens: 1 << 30, ..Default::default() },
            ReplicaView { outstanding_tokens: 900, kv_headroom_tokens: 1 << 30, ..Default::default() },
        ];
        assert_eq!(r.route_live(&req(0, 100, 50), &views), 0);
    }

    #[test]
    fn route_live_avoids_kv_starved_replica() {
        let mut r = Router::new(2, RoutePolicy::LeastLoaded);
        // Replica 0 is less loaded but cannot hold the request's KV.
        let views = vec![
            ReplicaView { outstanding_tokens: 10, kv_headroom_tokens: 50, ..Default::default() },
            ReplicaView { outstanding_tokens: 500, kv_headroom_tokens: 1 << 30, ..Default::default() },
        ];
        assert_eq!(r.route_live(&req(0, 100, 50), &views), 1);
    }

    #[test]
    fn route_live_prefix_affinity_breaks_near_ties() {
        let mut r = Router::new(2, RoutePolicy::LeastLoaded);
        let views = |a, b| {
            vec![
                ReplicaView {
                    outstanding_tokens: a,
                    kv_headroom_tokens: 1 << 30,
                    ..Default::default()
                },
                ReplicaView {
                    outstanding_tokens: b,
                    kv_headroom_tokens: 1 << 30,
                    ..Default::default()
                },
            ]
        };
        // First placement of the template: plain least-loaded (replica 1).
        assert_eq!(r.route_live(&shared_req(0, 0xABC), &views(500, 0)), 1);
        // Same template again: replica 0 is now lighter, but within one
        // affinity bucket — stick with replica 1's warm working set.
        assert_eq!(r.route_live(&shared_req(1, 0xABC), &views(0, 500)), 1);
        // Gross imbalance (more than one bucket) overrides affinity.
        assert_eq!(r.route_live(&shared_req(2, 0xABC), &views(0, 50_000)), 0);
        // Hashless requests keep the exact least-loaded ordering: the
        // lighter replica wins even against an affinity-free near-tie.
        assert_eq!(r.route_live(&req(3, 100, 50), &views(500, 0)), 1);
        assert_eq!(r.route_live(&req(4, 100, 50), &views(0, 500)), 0);
    }

    #[test]
    fn route_live_avoids_active_lenders_within_a_bucket() {
        let mut r = Router::new(2, RoutePolicy::LeastLoaded);
        // Replica 0 is marginally lighter but lending HBM to a peer;
        // loading it would revoke the lease. Same bucket → pick 1.
        let views = vec![
            ReplicaView {
                outstanding_tokens: 10,
                kv_headroom_tokens: 1 << 30,
                lending_bytes: 4 << 20,
                ..Default::default()
            },
            ReplicaView {
                outstanding_tokens: 20,
                kv_headroom_tokens: 1 << 30,
                ..Default::default()
            },
        ];
        assert_eq!(r.route_live(&req(0, 100, 50), &views), 1);
        // A full bucket of extra load overrides lender avoidance.
        let views2 = vec![
            ReplicaView {
                outstanding_tokens: 10,
                kv_headroom_tokens: 1 << 30,
                lending_bytes: 4 << 20,
                ..Default::default()
            },
            ReplicaView {
                outstanding_tokens: 10 + AFFINITY_SLACK,
                kv_headroom_tokens: 1 << 30,
                ..Default::default()
            },
        ];
        assert_eq!(r.route_live(&req(1, 100, 50), &views2), 0);
    }

    #[test]
    fn route_live_round_robin_ignores_views() {
        let mut r = Router::new(3, RoutePolicy::RoundRobin);
        let views = vec![ReplicaView::default(); 3];
        let targets: Vec<usize> =
            (0..6).map(|i| r.route_live(&req(i, 10, 10), &views)).collect();
        assert_eq!(targets, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn partition_covers_all_requests() {
        let mut r = Router::new(4, RoutePolicy::RoundRobin);
        let reqs: Vec<Request> = (0..10).map(|i| req(i, 100, 10)).collect();
        let parts = r.partition(&reqs);
        assert_eq!(parts.iter().map(|p| p.len()).sum::<usize>(), 10);
        assert!(parts.iter().all(|p| !p.is_empty()));
    }
}
