//! Request router over multiple engine replicas (the L3 leader's front
//! door, vLLM-router-shaped). Routing is static-state-aware: least-loaded
//! by outstanding tokens, or round-robin.

use super::request::Request;

/// Routing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    /// Pick the replica with the least outstanding token work.
    LeastLoaded,
}

/// Router state over `n` replicas.
#[derive(Debug)]
pub struct Router {
    policy: RoutePolicy,
    /// Outstanding work (tokens) per replica.
    load: Vec<u64>,
    next_rr: usize,
}

impl Router {
    pub fn new(n_replicas: usize, policy: RoutePolicy) -> Self {
        assert!(n_replicas > 0);
        Self { policy, load: vec![0; n_replicas], next_rr: 0 }
    }

    pub fn n_replicas(&self) -> usize {
        self.load.len()
    }

    /// Route one request; returns the replica index.
    pub fn route(&mut self, req: &Request) -> usize {
        let idx = match self.policy {
            RoutePolicy::RoundRobin => {
                let i = self.next_rr;
                self.next_rr = (self.next_rr + 1) % self.load.len();
                i
            }
            RoutePolicy::LeastLoaded => self
                .load
                .iter()
                .enumerate()
                .min_by_key(|(_, &l)| l)
                .map(|(i, _)| i)
                .unwrap(),
        };
        self.load[idx] += (req.prompt_tokens + req.gen_tokens) as u64;
        idx
    }

    /// Mark a request complete on its replica.
    pub fn complete(&mut self, replica: usize, req: &Request) {
        let w = (req.prompt_tokens + req.gen_tokens) as u64;
        self.load[replica] = self.load[replica].saturating_sub(w);
    }

    pub fn load_of(&self, replica: usize) -> u64 {
        self.load[replica]
    }

    /// Partition a workload across replicas (static dispatch for the
    /// closed-loop benches).
    pub fn partition(&mut self, requests: &[Request]) -> Vec<Vec<Request>> {
        let mut out = vec![Vec::new(); self.load.len()];
        for r in requests {
            let i = self.route(r);
            out[i].push(r.clone());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, p: usize, g: usize) -> Request {
        Request { id, arrival_us: 0.0, prompt_tokens: p, gen_tokens: g }
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(3, RoutePolicy::RoundRobin);
        let targets: Vec<usize> = (0..6).map(|i| r.route(&req(i, 10, 10))).collect();
        assert_eq!(targets, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_balances_uneven_work() {
        let mut r = Router::new(2, RoutePolicy::LeastLoaded);
        // Big request to 0, small ones should then prefer 1.
        assert_eq!(r.route(&req(0, 10_000, 1000)), 0);
        assert_eq!(r.route(&req(1, 10, 10)), 1);
        assert_eq!(r.route(&req(2, 10, 10)), 1);
    }

    #[test]
    fn complete_releases_load() {
        let mut r = Router::new(2, RoutePolicy::LeastLoaded);
        let big = req(0, 10_000, 0);
        let i = r.route(&big);
        assert!(r.load_of(i) > 0);
        r.complete(i, &big);
        assert_eq!(r.load_of(i), 0);
    }

    #[test]
    fn partition_covers_all_requests() {
        let mut r = Router::new(4, RoutePolicy::RoundRobin);
        let reqs: Vec<Request> = (0..10).map(|i| req(i, 100, 10)).collect();
        let parts = r.partition(&reqs);
        assert_eq!(parts.iter().map(|p| p.len()).sum::<usize>(), 10);
        assert!(parts.iter().all(|p| !p.is_empty()));
    }
}
