//! Cluster-scale serving: N engine replicas contending for one SuperNode
//! pool (the paper's §7 multi-NPU setting, where the terabyte-scale pool
//! is shared by *many* devices rather than private to one).
//!
//! # Who owns the clock
//!
//! Engines are resumable steppers with private local clocks; **the
//! cluster owns global time**. Its event loop repeatedly picks the
//! laggard replica (minimum local clock) that can still make progress and
//! advances it by exactly one scheduler iteration. Because the laggard
//! always moves first, replica clocks stay within one iteration of each
//! other, which is what makes window-based fabric contention meaningful.
//!
//! # What `step_until` guarantees
//!
//! [`SimServingEngine::step_until`]`(t)` catches an engine up to an event
//! horizon `t`: it steps while the engine can make progress without
//! *starting* past `t`. Iterations are atomic, so the last one may finish
//! beyond `t` — exactly like the pre-refactor monolith, where a request
//! arriving mid-decode-step waited for the step boundary. An idle engine
//! never advances its clock past `t` on its own; time only moves when
//! work (or an admissible arrival) exists. The cluster dispatches each
//! request at its arrival time, after advancing every replica to that
//! horizon, so routing always sees live state.
//!
//! # Fabric contention model
//!
//! All device↔pool links funnel into one [`Fabric`] with finite aggregate
//! bandwidth. Before each engine step the cluster counts the replicas
//! with transfer traffic in flight (`k`) and hands the stepped engine a
//! [`FabricPressure`] of `per_link / min(per_link, aggregate / k)` per
//! direction. With one replica (or a generously provisioned fabric) the
//! slowdown is exactly 1.0 and the single-engine timing is reproduced
//! bit-for-bit; past the provisioning knee, transfers stretch and the
//! extra exposed time is reported as `fabric_stall_us`.
//!
//! # Shared-pool accounting
//!
//! One [`PoolHandle`] of `hw.remote_capacity` bytes is cloned into every
//! replica's KV manager: offloaded blocks reserve real capacity, so a
//! replica can be preempted because a *sibling* filled the pool.
//!
//! The same sharing makes the pool a **cluster-wide prefix cache**: one
//! [`PrefixIndex`] handle is cloned into every replica alongside the
//! pool, so a prompt prefix prefilled by any replica is pool-resident
//! and every sibling's admission hits it (refcounted, copy-on-write —
//! see the [`crate::kvcache`] module docs). The report surfaces the
//! effect as `prefix_hit_blocks` / `prefill_flops_saved` /
//! `pool_bytes_deduped` sums.

use anyhow::Result;

use crate::kvcache::PrefixIndex;
use crate::memory::{LeaseLedger, PoolHandle};
use crate::sim::Fabric;

use super::engine::{EngineConfig, FabricPressure, SimServingEngine};
use super::metrics::{stats, ServingReport, Stats};
use super::request::Request;
use super::router::{ReplicaView, RoutePolicy, Router};

/// Configuration of a simulated serving cluster.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Per-replica engine configuration (each replica gets a clone; the
    /// remote pool inside it is replaced by the shared cluster pool).
    pub engine: EngineConfig,
    pub n_replicas: usize,
    pub route: RoutePolicy,
    /// The shared device↔pool interconnect.
    pub fabric: Fabric,
    /// If true, requests are assigned to replicas by the static
    /// [`Router::partition`]-style pre-pass (cumulative token counters,
    /// no completion feedback) instead of live-state online routing.
    /// Arrival times are still honoured — only the placement is blind.
    pub static_partition: bool,
    /// Peer-HBM harvesting: idle replicas lend spare HBM as a revocable
    /// middle tier between local HBM and the pool. `None` (the default)
    /// reproduces the lease-free cluster bit-for-bit.
    pub peer_harvest: Option<PeerHarvestConfig>,
}

/// Lender-side policy for the peer-HBM harvest protocol.
#[derive(Debug, Clone, Copy)]
pub struct PeerHarvestConfig {
    /// Spare HBM each replica exposes for borrowing when idle (bytes).
    pub spare_bytes: u64,
    /// A replica stays open for new borrows while its outstanding token
    /// work is at or below this.
    pub lend_below_tokens: u64,
    /// A lender whose outstanding work rises above this revokes: its
    /// borrowed-out blocks demote to the pool (never dropped). Loads in
    /// the band between the two thresholds close the lender to *new*
    /// borrows without disturbing live leases (hysteresis).
    pub revoke_above_tokens: u64,
}

impl Default for PeerHarvestConfig {
    /// Lend only when fully idle; any assigned work revokes.
    fn default() -> Self {
        Self { spare_bytes: 0, lend_below_tokens: 0, revoke_above_tokens: 0 }
    }
}

impl ClusterConfig {
    pub fn new(engine: EngineConfig, n_replicas: usize) -> Self {
        assert!(n_replicas > 0);
        let fabric = Fabric::for_hw(&engine.hw);
        Self {
            engine,
            n_replicas,
            route: RoutePolicy::LeastLoaded,
            fabric,
            static_partition: false,
            peer_harvest: None,
        }
    }

    pub fn with_route(mut self, route: RoutePolicy) -> Self {
        self.route = route;
        self
    }

    pub fn with_fabric(mut self, fabric: Fabric) -> Self {
        self.fabric = fabric;
        self
    }

    pub fn with_static_partition(mut self, on: bool) -> Self {
        self.static_partition = on;
        self
    }

    pub fn with_peer_harvest(mut self, ph: PeerHarvestConfig) -> Self {
        self.peer_harvest = Some(ph);
        self
    }
}

/// Aggregate + per-replica outcome of a cluster run.
#[derive(Debug, Clone)]
pub struct ClusterReport {
    pub per_replica: Vec<ServingReport>,
    /// Requests handed to engines (== completed + rejected afterwards).
    pub dispatched: u64,
    pub completed: u64,
    pub rejected: u64,
    pub preempted_events: u64,
    /// Wall time of the run: the latest replica clock (us).
    pub total_time_us: f64,
    pub tokens_generated: u64,
    pub throughput_tok_per_s: f64,
    /// End-to-end latency across *all* replicas' completions.
    pub e2e_latency_us: Stats,
    /// Prefill execution latency across all replicas.
    pub prefill_latency_us: Stats,
    /// Summed exposed transfer time across replicas (us).
    pub exposed_transfer_us: f64,
    /// Summed fabric-contention stall across replicas (us).
    pub fabric_stall_us: f64,
    pub kv_transfer_bytes: u64,
    /// Max device-memory peak across replicas (bytes).
    pub peak_device_bytes: u64,
    /// High-water mark of the shared remote pool (bytes).
    pub pool_peak_bytes: u64,
    /// Capacity of the shared remote pool (bytes).
    pub pool_capacity_bytes: u64,
    /// Summed step-graph compile-cache hits across replicas.
    pub compile_cache_hits: u64,
    /// Summed step-graph compile-cache misses across replicas.
    pub compile_cache_misses: u64,
    /// Summed step-graph compile wall-clock across replicas (us).
    pub compile_us_total: f64,
    /// Longest single step-graph compile across replicas (us).
    pub compile_us_max: f64,
    /// Summed first-time SLO-deferred writeback bytes across replicas.
    pub slo_deferred_bytes: u64,
    /// Summed admission-time prefix-cache hits across replicas (blocks
    /// served from the shared pool instead of recomputed by prefill).
    pub prefix_hit_blocks: u64,
    /// Summed prefill FLOPs those hits avoided across replicas.
    pub prefill_flops_saved: f64,
    /// Summed pool bytes deduplicated by shared-prefix admissions.
    pub pool_bytes_deduped: u64,
    /// Summed bytes fetched from tiers below the pool across replicas
    /// (demoted prefix blocks). 0 on untiered setups.
    pub cold_fetch_bytes: u64,
    /// Summed bytes read from borrowed peer HBM across replicas — KV
    /// traffic the harvested middle tier absorbed instead of the pool.
    pub peer_fetch_bytes: u64,
    /// Summed bytes written into borrowed peer HBM across replicas.
    pub peer_store_bytes: u64,
    /// High-water mark of Σ borrowed bytes across all lenders.
    pub borrowed_bytes_peak: u64,
    /// Lease revocation events (lender load spikes that found live
    /// leases).
    pub peer_revocations: u64,
    /// Bytes revocations demoted from peer HBM into the pool.
    pub peer_revoked_bytes: u64,
}

impl ClusterReport {
    /// Cluster-wide step-graph compile-cache hit rate in [0, 1].
    pub fn compile_cache_hit_rate(&self) -> f64 {
        super::metrics::hit_rate(self.compile_cache_hits, self.compile_cache_misses)
    }
}

/// N engine replicas advanced through one event loop, sharing a
/// capacity-accounted remote pool and a bandwidth-contended fabric.
pub struct SimCluster {
    cfg: ClusterConfig,
    engines: Vec<SimServingEngine>,
    router: Router,
    pool: PoolHandle,
    /// Per-replica cursor into `completed()` for feedback already
    /// reported to the router.
    seen: Vec<usize>,
    dispatched: u64,
    /// Shared peer-HBM lease broker; `Some` iff harvesting is configured.
    lease: Option<LeaseLedger>,
}

impl SimCluster {
    pub fn new(cfg: ClusterConfig) -> Self {
        // The shared pool hands out KV-block-sized chunks: every replica's
        // reservation — prompt admission, per-step block growth — is
        // chunk-granular, so sibling devices cannot fragment the ledger
        // with partial blocks.
        let chunk = cfg.engine.nsa.block_bytes(cfg.engine.model.kv_bytes_per_token);
        let pool = PoolHandle::new_chunked(cfg.engine.hw.remote_capacity, chunk);
        // One prefix index across all replicas: with the pool shared too,
        // a prefix prefilled anywhere is an admission hit everywhere.
        let index = PrefixIndex::new();
        let mut engines: Vec<SimServingEngine> = (0..cfg.n_replicas)
            .map(|_| {
                SimServingEngine::with_pool_and_index(
                    cfg.engine.clone(),
                    pool.clone(),
                    index.clone(),
                )
            })
            .collect();
        // Peer harvesting: one shared lease ledger; every replica is both
        // a registered lender (its spare HBM) and a potential borrower.
        let lease = cfg.peer_harvest.map(|ph| {
            let lease = LeaseLedger::new();
            for (i, e) in engines.iter_mut().enumerate() {
                lease.register_lender(i as u16, ph.spare_bytes);
                e.set_peer_lease(lease.clone(), i as u16);
            }
            lease
        });
        let router = Router::new(cfg.n_replicas, cfg.route);
        let seen = vec![0; cfg.n_replicas];
        Self { cfg, engines, router, pool, seen, dispatched: 0, lease }
    }

    /// The shared remote pool (cloneable handle).
    pub fn pool(&self) -> &PoolHandle {
        &self.pool
    }

    /// Serve a workload to completion: dispatch each request at its
    /// arrival time (advancing all replicas to that horizon first, so
    /// routing sees live state), then drain.
    pub fn run(mut self, mut requests: Vec<Request>) -> Result<ClusterReport> {
        requests.sort_by(|a, b| a.arrival_us.total_cmp(&b.arrival_us));
        // Static mode: decide placement up front from cumulative token
        // counters only (the old Router::partition behaviour).
        let static_plan: Option<Vec<usize>> = if self.cfg.static_partition {
            Some(requests.iter().map(|r| self.router.route(r)).collect())
        } else {
            None
        };
        for (i, req) in requests.into_iter().enumerate() {
            self.advance_until(req.arrival_us)?;
            let idx = match &static_plan {
                Some(plan) => plan[i],
                None => {
                    let views = self.views();
                    self.router.route_live(&req, &views)
                }
            };
            self.dispatched += 1;
            self.engines[idx].enqueue(req);
        }
        self.advance_until(f64::INFINITY)?;
        Ok(self.finish())
    }

    fn views(&self) -> Vec<ReplicaView> {
        self.engines
            .iter()
            .enumerate()
            .map(|(i, e)| ReplicaView {
                outstanding_tokens: e.outstanding_tokens(),
                kv_headroom_tokens: e.kv_headroom_tokens(),
                pool_pressure: e.pool_pressure(),
                lending_bytes: self.lease.as_ref().map_or(0, |l| l.lent(i as u16)),
                now_us: e.now_us(),
            })
            .collect()
    }

    /// Advance every replica to the event horizon `t`, laggard first, with
    /// per-step fabric pressure from the replicas transferring in the
    /// same window, feeding completions back to the router as they land.
    fn advance_until(&mut self, t: f64) -> Result<()> {
        loop {
            let mut laggard: Option<usize> = None;
            for (i, e) in self.engines.iter().enumerate() {
                if !e.can_progress(t) {
                    continue;
                }
                match laggard {
                    Some(l) if self.engines[l].now_us() <= e.now_us() => {}
                    _ => laggard = Some(i),
                }
            }
            let Some(i) = laggard else { return Ok(()) };
            let k = self.engines.iter().filter(|e| e.has_transfer_traffic()).count();
            // The peer edge is contended separately, by the replicas with
            // KV actually homed at peers in this window.
            let peer_k = self.engines.iter().filter(|e| e.kv.peer_kv_bytes > 0).count();
            let peer_slowdown = match (&self.lease, &self.cfg.engine.hw.peer) {
                (Some(_), Some(link)) => self.cfg.fabric.slowdown(link.gbps, peer_k),
                _ => 1.0,
            };
            let pressure = FabricPressure {
                d2r_slowdown: self.cfg.fabric.slowdown(self.cfg.engine.hw.d2r_gbps, k),
                r2d_slowdown: self.cfg.fabric.slowdown(self.cfg.engine.hw.r2d_gbps, k),
                peer_slowdown,
            };
            self.broker_peer_leases(&pressure);
            self.engines[i].step(&pressure)?;
            self.feed_completions(i);
        }
    }

    /// One brokering pass of the harvest protocol: open/close lenders by
    /// their live load and revoke leases whose lender spiked. Revocation
    /// is conservative — `begin_revoke` closes the lender and each
    /// borrower demotes its borrowed blocks peer→pool (reserve-first,
    /// exactly once); a full pool parks the blocks at the peer and a
    /// later pass retries. No-op without a configured lease.
    fn broker_peer_leases(&mut self, pressure: &FabricPressure) {
        let Some(lease) = self.lease.clone() else { return };
        let ph = self.cfg.peer_harvest.expect("lease implies harvest config");
        for r in 0..self.engines.len() {
            let load = self.engines[r].outstanding_tokens();
            let id = r as u16;
            if load > ph.revoke_above_tokens {
                // First pass closes the lease and counts the revocation;
                // later passes only retry demotions that failed on a full
                // pool (is_open is already false, nothing double-counts).
                if lease.is_open(id) {
                    lease.begin_revoke(id);
                }
                if lease.lent(id) > 0 {
                    for j in 0..self.engines.len() {
                        if j != r {
                            self.engines[j].revoke_peer(id, pressure);
                        }
                    }
                }
            } else {
                lease.set_open(id, load <= ph.lend_below_tokens);
            }
        }
    }

    fn feed_completions(&mut self, i: usize) {
        let done = self.engines[i].completed();
        while self.seen[i] < done.len() {
            let (req, _) = &done[self.seen[i]];
            self.router.complete(i, req);
            self.seen[i] += 1;
        }
    }

    fn finish(self) -> ClusterReport {
        let mut e2e = Vec::new();
        let mut prefill = Vec::new();
        for e in &self.engines {
            for (r, t) in e.completed() {
                e2e.push(t.e2e_latency_us(r.arrival_us));
                prefill.push(t.prefill_end_us - t.prefill_start_us);
            }
        }
        let total_time_us = self.engines.iter().map(|e| e.now_us()).fold(0.0, f64::max);
        let per_replica: Vec<ServingReport> =
            self.engines.into_iter().map(|e| e.report()).collect();
        let completed: u64 = per_replica.iter().map(|r| r.e2e_latency_us.n as u64).sum();
        let rejected: u64 = per_replica.iter().map(|r| r.rejected_requests).sum();
        let preempted: u64 = per_replica.iter().map(|r| r.preempted_events).sum();
        let tokens: u64 = per_replica.iter().map(|r| r.tokens_generated).sum();
        let exposed: f64 = per_replica.iter().map(|r| r.exposed_transfer_us).sum();
        let fabric_stall: f64 = per_replica.iter().map(|r| r.fabric_stall_us).sum();
        let kv_bytes: u64 = per_replica.iter().map(|r| r.kv_transfer_bytes).sum();
        let peak_device = per_replica.iter().map(|r| r.peak_device_bytes).max().unwrap_or(0);
        let cache_hits: u64 = per_replica.iter().map(|r| r.compile_cache_hits).sum();
        let cache_misses: u64 = per_replica.iter().map(|r| r.compile_cache_misses).sum();
        let compile_us: f64 = per_replica.iter().map(|r| r.compile_us_total).sum();
        let compile_us_max =
            per_replica.iter().map(|r| r.compile_us_max).fold(0.0, f64::max);
        let deferred: u64 = per_replica.iter().map(|r| r.slo_deferred_bytes).sum();
        let prefix_hits: u64 = per_replica.iter().map(|r| r.prefix_hit_blocks).sum();
        let flops_saved: f64 = per_replica.iter().map(|r| r.prefill_flops_saved).sum();
        let deduped: u64 = per_replica.iter().map(|r| r.pool_bytes_deduped).sum();
        let cold_fetch: u64 = per_replica.iter().map(|r| r.cold_fetch_bytes).sum();
        let peer_fetch: u64 = per_replica.iter().map(|r| r.peer_fetch_bytes).sum();
        let peer_store: u64 = per_replica.iter().map(|r| r.peer_store_bytes).sum();
        ClusterReport {
            dispatched: self.dispatched,
            completed,
            rejected,
            preempted_events: preempted,
            total_time_us,
            tokens_generated: tokens,
            throughput_tok_per_s: if total_time_us > 0.0 {
                tokens as f64 / (total_time_us / 1e6)
            } else {
                0.0
            },
            e2e_latency_us: stats(&e2e),
            prefill_latency_us: stats(&prefill),
            exposed_transfer_us: exposed,
            fabric_stall_us: fabric_stall,
            kv_transfer_bytes: kv_bytes,
            peak_device_bytes: peak_device,
            pool_peak_bytes: self.pool.peak(),
            pool_capacity_bytes: self.pool.capacity(),
            compile_cache_hits: cache_hits,
            compile_cache_misses: cache_misses,
            compile_us_total: compile_us,
            compile_us_max,
            slo_deferred_bytes: deferred,
            prefix_hit_blocks: prefix_hits,
            prefill_flops_saved: flops_saved,
            pool_bytes_deduped: deduped,
            cold_fetch_bytes: cold_fetch,
            peer_fetch_bytes: peer_fetch,
            peer_store_bytes: peer_store,
            borrowed_bytes_peak: self.lease.as_ref().map_or(0, |l| l.borrowed_peak()),
            peer_revocations: self.lease.as_ref().map_or(0, |l| l.revocations()),
            peer_revoked_bytes: self.lease.as_ref().map_or(0, |l| l.revoked_bytes()),
            per_replica,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::{ModelCost, WorkloadConfig};
    use crate::sim::{HwConfig, GB};

    fn hw() -> HwConfig {
        HwConfig::ascend910c_like().with_device_capacity(64 * GB)
    }

    fn small_model() -> ModelCost {
        ModelCost {
            weights_bytes: 8 * GB,
            act_bytes: GB,
            prefill_flops_per_token: 16e9,
            decode_flops_per_token: 16e9,
            kv_bytes_per_token: 64 * 1024,
        }
    }

    /// N=1 must reproduce the single-engine `run()` reports exactly (the
    /// refactor is behavior-preserving at the fixpoint).
    #[test]
    fn n1_cluster_reproduces_single_engine_run() {
        type CfgFn = fn(HwConfig, ModelCost) -> EngineConfig;
        for cfg_of in [EngineConfig::baseline as CfgFn, EngineConfig::hierarchical as CfgFn] {
            let wl = WorkloadConfig {
                mean_interarrival_us: 30_000.0,
                ..WorkloadConfig::short_sequence(16, 13)
            }
            .generate();
            let solo = SimServingEngine::new(cfg_of(hw(), small_model()))
                .run(wl.clone())
                .unwrap();
            let cluster = SimCluster::new(ClusterConfig::new(cfg_of(hw(), small_model()), 1))
                .run(wl)
                .unwrap();
            let r = &cluster.per_replica[0];
            let rel = |a: f64, b: f64| (a - b).abs() / b.abs().max(1e-12);
            assert!(
                rel(r.throughput_tok_per_s, solo.throughput_tok_per_s) < 1e-6,
                "throughput {} vs {}",
                r.throughput_tok_per_s,
                solo.throughput_tok_per_s
            );
            assert_eq!(r.peak_device_bytes, solo.peak_device_bytes);
            assert!(
                (r.exposed_transfer_us - solo.exposed_transfer_us).abs()
                    <= 1e-6 * solo.exposed_transfer_us.abs().max(1.0),
                "exposed {} vs {}",
                r.exposed_transfer_us,
                solo.exposed_transfer_us
            );
            assert!(rel(r.total_time_us, solo.total_time_us) < 1e-6);
            assert_eq!(r.e2e_latency_us.n, solo.e2e_latency_us.n);
            assert_eq!(cluster.fabric_stall_us, 0.0, "N=1 must be uncontended");
        }
    }

    /// Four replicas sharing the fabric expose more transfer time than one
    /// replica serving the same workload uncontended.
    #[test]
    fn n4_fabric_contention_exposes_transfers() {
        // Chunky prompts: prefill writeback dominates and cannot hide.
        let wl = WorkloadConfig::long_sequence(8, 8000, 40, 5).generate();
        let n1 = SimCluster::new(ClusterConfig::new(
            EngineConfig::hierarchical(hw(), small_model()),
            1,
        ))
        .run(wl.clone())
        .unwrap();
        let n4 = SimCluster::new(ClusterConfig::new(
            EngineConfig::hierarchical(hw(), small_model()),
            4,
        ))
        .run(wl)
        .unwrap();
        assert_eq!(n1.fabric_stall_us, 0.0);
        assert!(n4.fabric_stall_us > 0.0, "4 sharers must contend");
        assert!(
            n4.exposed_transfer_us > n1.exposed_transfer_us,
            "aggregate-bandwidth limit must grow exposure: {} <= {}",
            n4.exposed_transfer_us,
            n1.exposed_transfer_us
        );
        assert_eq!(n4.dispatched, n4.completed + n4.rejected);
    }

    /// Online least-loaded routing (live outstanding tokens + completion
    /// feedback) beats the static token-count partition on tail latency
    /// for a bursty trace where token totals mislead.
    #[test]
    fn online_routing_beats_static_partition_on_p99() {
        // max_batch 1 serialises replicas, so placement mistakes queue.
        let engine = EngineConfig {
            max_batch: 1,
            ..EngineConfig::baseline(hw(), small_model())
        };
        let mk = |id, t, p, g| Request {
            id,
            arrival_us: t,
            prompt_tokens: p,
            gen_tokens: g,
            block_hashes: vec![],
        };
        // M0: decode monster (1000 steps ~ 5.4 s). S0: token-fat but
        // cheap (prefill-only). At t=150 ms S0 is long done; static
        // placement still sees replica 1 as "heavier" (6002 > 3000
        // tokens) and stacks M1 behind M0, while online routing sees
        // replica 1 idle.
        let wl = vec![
            mk(0, 0.0, 2000, 1000),     // M0 -> replica 0 (both modes)
            mk(1, 0.0, 6000, 2),        // S0 -> replica 1 (both modes)
            mk(2, 150_000.0, 2000, 1000), // M1: the discriminating request
        ];
        let online = SimCluster::new(ClusterConfig::new(engine.clone(), 2))
            .run(wl.clone())
            .unwrap();
        let static_ = SimCluster::new(
            ClusterConfig::new(engine, 2).with_static_partition(true),
        )
        .run(wl)
        .unwrap();
        assert_eq!(online.completed, 3);
        assert_eq!(static_.completed, 3);
        assert!(
            online.e2e_latency_us.p99 < static_.e2e_latency_us.p99,
            "online p99 {} must beat static p99 {}",
            online.e2e_latency_us.p99,
            static_.e2e_latency_us.p99
        );
    }

    /// The prefix cache is cluster-wide: a prefix prefilled by one replica
    /// is an admission hit on a *different* replica, because both share
    /// the pool and the index.
    #[test]
    fn prefix_cache_is_cluster_wide() {
        use crate::serving::request::template_prefix_hashes;
        let engine = EngineConfig::hierarchical(hw(), small_model());
        // 1024-token template = 16 full 64-token blocks of 4 MiB each.
        let hashes = template_prefix_hashes(3, 1024, 64);
        assert_eq!(hashes.len(), 16);
        let mk = |id, t: f64| Request {
            id,
            arrival_us: t,
            prompt_tokens: 1024 + 256,
            gen_tokens: 8,
            block_hashes: hashes.clone(),
        };
        // Round-robin pins the requests to different replicas; the second
        // arrives long after the first finished, so its admission hits the
        // prefix the sibling replica prefilled into the shared pool.
        let wl = vec![mk(0, 0.0), mk(1, 1e9)];
        let report = SimCluster::new(
            ClusterConfig::new(engine, 2).with_route(RoutePolicy::RoundRobin),
        )
        .run(wl)
        .unwrap();
        let block = 64 * 64 * 1024u64;
        assert_eq!(report.completed, 2);
        assert_eq!(report.rejected, 0);
        assert_eq!(report.per_replica[0].prefix_hit_blocks, 0, "first admission is cold");
        assert_eq!(
            report.per_replica[1].prefix_hit_blocks, 16,
            "replica 1 must hit replica 0's prefix"
        );
        assert_eq!(report.prefix_hit_blocks, 16);
        assert_eq!(report.pool_bytes_deduped, 16 * block);
        assert!(report.prefill_flops_saved > 0.0);
    }

    /// End-to-end harvest protocol: a loaded replica borrows the idle
    /// sibling's HBM, decode fetches ride the peer edge, and routing work
    /// onto the lender revokes the lease — every borrowed byte demotes to
    /// the pool, never dropped.
    #[test]
    fn peer_harvest_borrows_then_revokes_on_lender_load() {
        let h = hw().with_peer_link(400.0, 5.0);
        let engine = EngineConfig::hierarchical(h, small_model());
        let mk = |id, t, p, g| Request {
            id,
            arrival_us: t,
            prompt_tokens: p,
            gen_tokens: g,
            block_hashes: vec![],
        };
        // A keeps replica 0 busy long past B's arrival; B lands on the
        // idle lender (replica 1) and triggers the revocation.
        let wl = vec![mk(0, 0.0, 1024, 100), mk(1, 100_000.0, 512, 50)];
        let report = SimCluster::new(
            ClusterConfig::new(engine, 2)
                .with_peer_harvest(PeerHarvestConfig {
                    spare_bytes: GB,
                    ..PeerHarvestConfig::default()
                }),
        )
        .run(wl)
        .unwrap();
        assert_eq!(report.completed, 2);
        assert_eq!(report.rejected, 0);
        assert!(report.borrowed_bytes_peak > 0, "replica 0 must borrow: {report:?}");
        assert!(report.peer_fetch_bytes > 0, "decode must fetch over the peer edge");
        assert!(report.peer_revocations >= 1, "loading the lender must revoke");
        assert!(report.peer_revoked_bytes > 0);
        // Conservation: everything demoted landed in the pool ledger.
        assert!(report.pool_peak_bytes <= report.pool_capacity_bytes);
    }

    /// Harvesting with zero spare capacity is the protocol's fixpoint:
    /// all the wiring engages (lease registered, broker runs, router
    /// sees lending bytes of 0) but no borrow can ever match, so the run
    /// must reproduce the lease-free cluster exactly.
    #[test]
    fn zero_spare_harvest_is_bit_identical_to_disabled() {
        let h = hw().with_peer_link(400.0, 5.0);
        let wl = WorkloadConfig {
            mean_interarrival_us: 30_000.0,
            ..WorkloadConfig::short_sequence(12, 23)
        }
        .generate();
        let off = SimCluster::new(ClusterConfig::new(
            EngineConfig::hierarchical(h.clone(), small_model()),
            2,
        ))
        .run(wl.clone())
        .unwrap();
        let on = SimCluster::new(
            ClusterConfig::new(EngineConfig::hierarchical(h, small_model()), 2)
                .with_peer_harvest(PeerHarvestConfig::default()),
        )
        .run(wl)
        .unwrap();
        assert_eq!(on.peer_fetch_bytes, 0);
        assert_eq!(on.peer_store_bytes, 0);
        assert_eq!(on.borrowed_bytes_peak, 0);
        assert_eq!(on.peer_revocations, 0);
        assert_eq!(on.total_time_us, off.total_time_us, "zero-spare must be a fixpoint");
        assert_eq!(on.kv_transfer_bytes, off.kv_transfer_bytes);
        assert_eq!(on.exposed_transfer_us, off.exposed_transfer_us);
        assert_eq!(on.peak_device_bytes, off.peak_device_bytes);
        assert_eq!(on.throughput_tok_per_s, off.throughput_tok_per_s);
    }

    /// The shared pool is a real constraint: one replica's residency can
    /// reject a sibling's request that a private pool would have taken.
    #[test]
    fn shared_pool_is_capacity_accounted() {
        // Pool sized for ~one replica's worth of KV: 2 x 8000-token
        // prompts of 64 KiB/tok ~= 1 GiB; give the pool 1.2 GiB.
        let mut h = hw();
        h.remote_capacity = (12 * GB) / 10;
        let engine = EngineConfig::hierarchical(h, small_model());
        let wl = WorkloadConfig::long_sequence(6, 8000, 20, 9).generate();
        let report = SimCluster::new(ClusterConfig::new(engine, 2)).run(wl).unwrap();
        assert!(report.pool_peak_bytes <= report.pool_capacity_bytes);
        assert_eq!(report.dispatched, report.completed + report.rejected);
        assert!(
            report.rejected > 0 || report.preempted_events > 0,
            "pool pressure must bite: {report:?}"
        );
    }
}
