//! The serving engine: continuous-batching inference over the simulated
//! SuperNode device, with KV residency managed by [`KvCacheManager`].
//!
//! Two scheduling modes mirror the paper's comparison:
//! * baseline — KV `AllDevice`, no remote pool, fragmenting allocator
//!   (defrag stalls land on the prefill path, §7.3.2);
//! * hierarchical — KV `FullOffload` with graph-driven scheduling: per-step
//!   prefetch volume overlaps the step's compute (exposed only when the
//!   transfer outruns it), CPU sparse-block processing serialises (§7.3.3).

use anyhow::Result;

use crate::kvcache::{KvCacheManager, KvPolicy, NsaConfig};
use crate::sim::HwConfig;

use super::metrics::{stats, ServingReport};
use super::request::{Request, RequestTiming};

/// Analytic model-cost parameters for the served LLM (per device).
#[derive(Debug, Clone)]
pub struct ModelCost {
    /// Static weights resident in HBM (bytes).
    pub weights_bytes: u64,
    /// Peak transient activation bytes during prefill of one request.
    pub act_bytes: u64,
    /// FLOPs per prompt token during prefill (per device).
    pub prefill_flops_per_token: f64,
    /// FLOPs per generated token during decode (per device, per sequence).
    pub decode_flops_per_token: f64,
    /// KV bytes per token (all layers, k+v, per device).
    pub kv_bytes_per_token: u64,
}

impl ModelCost {
    /// DeepSeek-V3-like per-device share on an 8-NPU node with NSA
    /// (Table 3's setting, see DESIGN.md §2 for the calibration).
    pub fn dsv3_nsa_like() -> Self {
        Self {
            weights_bytes: 42 * crate::sim::GB,
            act_bytes: 3 * crate::sim::GB,
            prefill_flops_per_token: 90e9,
            decode_flops_per_token: 90e9,
            kv_bytes_per_token: 228 * 1024,
        }
    }
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub hw: HwConfig,
    pub model: ModelCost,
    pub kv_policy: KvPolicy,
    pub nsa: NsaConfig,
    /// Max concurrent decode sequences.
    pub max_batch: usize,
    /// If false (baseline runtime-style), per-step KV transfers are fully
    /// exposed instead of overlapping decode compute.
    pub overlap_transfers: bool,
}

impl EngineConfig {
    pub fn baseline(hw: HwConfig, model: ModelCost) -> Self {
        Self {
            hw,
            model,
            kv_policy: KvPolicy::AllDevice,
            nsa: NsaConfig::default(),
            max_batch: 8,
            overlap_transfers: false,
        }
    }

    pub fn hierarchical(hw: HwConfig, model: ModelCost) -> Self {
        Self {
            hw,
            model,
            kv_policy: KvPolicy::FullOffload,
            nsa: NsaConfig::default(),
            max_batch: 8,
            overlap_transfers: true,
        }
    }
}

struct Active {
    req: Request,
    timing: RequestTiming,
    remaining: usize,
}

/// Continuous-batching simulated serving engine for one device.
pub struct SimServingEngine {
    pub cfg: EngineConfig,
    pub kv: KvCacheManager,
    clock_us: f64,
    active: Vec<Active>,
    done: Vec<(Request, RequestTiming)>,
    exposed_transfer_us: f64,
    kv_transfer_bytes: u64,
    peak_device_bytes: u64,
    rejected: u64,
}

impl SimServingEngine {
    pub fn new(cfg: EngineConfig) -> Self {
        let kv_budget = cfg
            .hw
            .device_capacity
            .saturating_sub(cfg.model.weights_bytes + cfg.model.act_bytes);
        let kv = KvCacheManager::new(
            cfg.kv_policy,
            cfg.nsa.clone(),
            cfg.model.kv_bytes_per_token,
            kv_budget,
        );
        Self {
            cfg,
            kv,
            clock_us: 0.0,
            active: Vec::new(),
            done: Vec::new(),
            exposed_transfer_us: 0.0,
            kv_transfer_bytes: 0,
            peak_device_bytes: 0,
            rejected: 0,
        }
    }

    /// Run the whole workload to completion and report.
    pub fn run(mut self, mut requests: Vec<Request>) -> Result<ServingReport> {
        requests.sort_by(|a, b| a.arrival_us.total_cmp(&b.arrival_us));
        let mut pending: std::collections::VecDeque<Request> = requests.into();

        while !pending.is_empty() || !self.active.is_empty() {
            // Admit arrivals while there is batch room.
            while self.active.len() < self.cfg.max_batch {
                let Some(next) = pending.front() else { break };
                if next.arrival_us > self.clock_us && !self.active.is_empty() {
                    break; // keep decoding until it arrives
                }
                let req = pending.pop_front().unwrap();
                self.clock_us = self.clock_us.max(req.arrival_us);
                match self.prefill(req) {
                    Ok(()) => {}
                    Err(_) => {
                        self.rejected += 1;
                    }
                }
            }
            if self.active.is_empty() {
                if let Some(next) = pending.front() {
                    self.clock_us = self.clock_us.max(next.arrival_us);
                }
                continue;
            }
            self.decode_iteration()?;
            // Retire finished sequences.
            let mut i = 0;
            while i < self.active.len() {
                if self.active[i].remaining == 0 {
                    let mut a = self.active.swap_remove(i);
                    a.timing.done_us = self.clock_us;
                    self.kv.retire(a.req.id)?;
                    self.done.push((a.req, a.timing));
                } else {
                    i += 1;
                }
            }
        }
        Ok(self.report())
    }

    /// Prefill one request (serial, as in chunked-prefill-off serving).
    fn prefill(&mut self, req: Request) -> Result<()> {
        let mut timing = RequestTiming { prefill_start_us: self.clock_us, ..Default::default() };

        let compute_us = self
            .cfg
            .hw
            .compute_us(self.cfg.model.prefill_flops_per_token * req.prompt_tokens as f64, 0);
        let admit = self.kv.admit(req.id, req.prompt_tokens, &self.cfg.hw)?;

        // Baseline: defrag stalls serialise into prefill (§7.3.2).
        let mut t = compute_us + admit.defrag_us + admit.cpu_us;
        // Hierarchical: prefill KV writeback streams to the pool; exposed
        // only if it outruns prefill compute.
        let d2r_us = self.cfg.hw.d2r_us(admit.d2r_bytes);
        if admit.d2r_bytes > 0 {
            if self.cfg.overlap_transfers {
                let exposed = (d2r_us - compute_us).max(0.0);
                t += exposed;
                self.exposed_transfer_us += exposed;
            } else {
                t += d2r_us;
                self.exposed_transfer_us += d2r_us;
            }
        }
        self.kv_transfer_bytes += admit.d2r_bytes + admit.r2d_bytes;

        self.clock_us += t;
        timing.prefill_end_us = self.clock_us;
        timing.first_token_us = self.clock_us;
        self.note_peak();
        self.active.push(Active { remaining: req.gen_tokens, req, timing });
        Ok(())
    }

    /// One batched decode step over all active sequences.
    fn decode_iteration(&mut self) -> Result<()> {
        let batch = self.active.len();
        let compute_us = self.cfg.hw.compute_us(
            self.cfg.model.decode_flops_per_token * batch as f64,
            // decode is bandwidth-bound: weights are re-read every step.
            self.cfg.model.weights_bytes,
        );

        let mut r2d = 0u64;
        let mut d2r = 0u64;
        let mut cpu_us = 0.0;
        let mut defrag_us = 0.0;
        let mut preempted: Vec<usize> = Vec::new();
        for (i, a) in self.active.iter_mut().enumerate() {
            match self.kv.decode_step(a.req.id, &self.cfg.hw) {
                Ok(c) => {
                    r2d += c.r2d_bytes;
                    d2r += c.d2r_bytes;
                    cpu_us += c.cpu_us;
                    defrag_us += c.defrag_us;
                    a.remaining -= 1;
                }
                Err(_) => {
                    // Device KV exhausted mid-decode (baseline without a
                    // pool has nowhere to grow): preempt the sequence.
                    preempted.push(i);
                }
            }
        }
        for &i in preempted.iter().rev() {
            let a = self.active.swap_remove(i);
            let _ = self.kv.retire(a.req.id);
            self.rejected += 1;
        }
        self.kv_transfer_bytes += r2d + d2r;

        let transfer_us = self.cfg.hw.r2d_us(r2d).max(self.cfg.hw.d2r_us(d2r));
        let step_us = if self.cfg.overlap_transfers {
            // Graph-driven: transfers hide under the step's compute.
            let exposed = (transfer_us - compute_us).max(0.0);
            self.exposed_transfer_us += exposed;
            compute_us + exposed + cpu_us + defrag_us
        } else if r2d + d2r > 0 {
            self.exposed_transfer_us += transfer_us;
            compute_us + transfer_us + cpu_us + defrag_us
        } else {
            compute_us + cpu_us + defrag_us
        };
        self.clock_us += step_us;
        self.note_peak();
        Ok(())
    }

    fn note_peak(&mut self) {
        let total = self.cfg.model.weights_bytes
            + self.cfg.model.act_bytes
            + self.kv.device_kv_bytes();
        self.peak_device_bytes = self.peak_device_bytes.max(total);
    }

    fn report(self) -> ServingReport {
        // Prefill = execution time (start→end), as the paper measures it;
        // queueing shows up in e2e latency instead.
        let prefill: Vec<f64> = self
            .done
            .iter()
            .map(|(_, t)| t.prefill_end_us - t.prefill_start_us)
            .collect();
        let decode_pt: Vec<f64> = self
            .done
            .iter()
            .filter(|(r, _)| r.gen_tokens > 0)
            .map(|(r, t)| t.decode_time_us() / r.gen_tokens as f64)
            .collect();
        let e2e: Vec<f64> = self
            .done
            .iter()
            .map(|(r, t)| t.e2e_latency_us(r.arrival_us))
            .collect();
        let tokens: u64 = self.done.iter().map(|(r, _)| r.gen_tokens as u64).sum();
        ServingReport {
            prefill_latency_us: stats(&prefill),
            decode_per_token_us: stats(&decode_pt),
            e2e_latency_us: stats(&e2e),
            total_time_us: self.clock_us,
            tokens_generated: tokens,
            throughput_tok_per_s: if self.clock_us > 0.0 {
                tokens as f64 / (self.clock_us / 1e6)
            } else {
                0.0
            },
            peak_device_bytes: self.peak_device_bytes,
            defrag_events: self.kv.allocator.defrag_events,
            defrag_stall_us: 0.0,
            exposed_transfer_us: self.exposed_transfer_us,
            kv_transfer_bytes: self.kv_transfer_bytes,
            rejected_requests: self.rejected,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::request::WorkloadConfig;
    use crate::sim::GB;

    fn hw() -> HwConfig {
        HwConfig::ascend910c_like().with_device_capacity(64 * GB)
    }

    fn small_model() -> ModelCost {
        ModelCost {
            weights_bytes: 8 * GB,
            act_bytes: GB,
            prefill_flops_per_token: 16e9,
            decode_flops_per_token: 16e9,
            kv_bytes_per_token: 64 * 1024,
        }
    }

    #[test]
    fn completes_all_requests() {
        let cfg = EngineConfig::baseline(hw(), small_model());
        let eng = SimServingEngine::new(cfg);
        let r = eng.run(WorkloadConfig::short_sequence(12, 5).generate()).unwrap();
        assert_eq!(r.prefill_latency_us.n, 12);
        assert!(r.tokens_generated > 0);
        assert!(r.throughput_tok_per_s > 0.0);
        assert_eq!(r.rejected_requests, 0);
    }

    #[test]
    fn hierarchical_lowers_peak_memory() {
        let wl = WorkloadConfig::long_sequence(4, 20_000, 200, 3).generate();
        let base = SimServingEngine::new(EngineConfig::baseline(hw(), small_model()))
            .run(wl.clone())
            .unwrap();
        let hier = SimServingEngine::new(EngineConfig::hierarchical(hw(), small_model()))
            .run(wl)
            .unwrap();
        assert!(
            hier.peak_device_bytes < base.peak_device_bytes,
            "hier {} >= base {}",
            hier.peak_device_bytes,
            base.peak_device_bytes
        );
    }

    #[test]
    fn hierarchical_decode_carries_cpu_overhead() {
        // Short sequences, low pressure: prefill comparable, decode slower
        // under offload (Table 5's shape).
        let wl = WorkloadConfig::short_sequence(8, 11).generate();
        let base = SimServingEngine::new(EngineConfig::baseline(hw(), small_model()))
            .run(wl.clone())
            .unwrap();
        let hier = SimServingEngine::new(EngineConfig::hierarchical(hw(), small_model()))
            .run(wl)
            .unwrap();
        assert!(
            hier.decode_per_token_us.mean > base.decode_per_token_us.mean,
            "decode overhead missing: {} <= {}",
            hier.decode_per_token_us.mean,
            base.decode_per_token_us.mean
        );
        // Prefill within a few percent.
        let rel = (hier.prefill_latency_us.mean - base.prefill_latency_us.mean).abs()
            / base.prefill_latency_us.mean;
        assert!(rel < 0.25, "prefill diverged {rel}");
    }

    #[test]
    fn baseline_rejects_what_offload_serves() {
        // Sequence too big for device KV budget: 900k tokens * 64 KiB/tok
        // = 65.5e9 B > the 55 GiB (59.1e9 B) KV budget.
        let wl = WorkloadConfig::long_sequence(1, 1_000_000, 10, 1).generate();
        let base = SimServingEngine::new(EngineConfig::baseline(hw(), small_model()))
            .run(wl.clone())
            .unwrap();
        assert_eq!(base.rejected_requests, 1);
        let hier = SimServingEngine::new(EngineConfig::hierarchical(hw(), small_model()))
            .run(wl)
            .unwrap();
        assert_eq!(hier.rejected_requests, 0);
    }

    #[test]
    fn offload_moves_bytes_baseline_does_not() {
        let wl = WorkloadConfig::short_sequence(4, 2).generate();
        let base = SimServingEngine::new(EngineConfig::baseline(hw(), small_model()))
            .run(wl.clone())
            .unwrap();
        let hier = SimServingEngine::new(EngineConfig::hierarchical(hw(), small_model()))
            .run(wl)
            .unwrap();
        assert_eq!(base.kv_transfer_bytes, 0);
        assert!(hier.kv_transfer_bytes > 0);
    }
}
