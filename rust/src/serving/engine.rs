//! The serving engine: continuous-batching inference over the simulated
//! SuperNode device, with KV residency managed by [`KvCacheManager`].
//!
//! Two scheduling modes mirror the paper's comparison:
//! * baseline — KV `AllDevice`, no remote pool, fragmenting allocator
//!   (defrag stalls land on the prefill path, §7.3.2); nothing crosses the
//!   device boundary, so steps are costed directly.
//! * hierarchical — KV `FullOffload` with *compiled* graph-driven
//!   scheduling: every step (prefill, batched decode, backlog drain) is
//!   lowered into a small KV transfer graph and compiled through the
//!   [`Compiler`](crate::passes::Compiler) session
//!   ([`StepCompiler`](super::step_graph::StepCompiler), pipeline
//!   `ExecOrder` → `SloThrottle` → elide) — step time is the compiled
//!   schedule's makespan, exposed transfer is what it could not hide, and
//!   under a decode SLO the throttle's spill rewrite decides which
//!   writeback bytes defer into the backlog. A shape-keyed compile cache
//!   amortises steady-state decode to a hash lookup. The retired analytic
//!   cost model survives only as a conservation oracle
//!   ([`EngineConfig::analytic_oracle`], exercised by tests and the
//!   `compiled_serving` bench).
//!
//! # Steppable core
//!
//! The engine is a *resumable stepper*, not a closed loop: it holds a
//! request queue ([`SimServingEngine::enqueue`]) and advances in discrete
//! scheduler iterations ([`SimServingEngine::step`] /
//! [`SimServingEngine::step_until`]). Its `clock_us` is a private, local
//! notion of time — the engine never assumes it owns the global clock, so
//! an external orchestrator ([`super::SimCluster`]) can interleave N
//! engines through one event loop, injecting per-step fabric contention
//! ([`FabricPressure`]) and observing live state (outstanding tokens, KV
//! headroom, pool pressure) for online routing. The legacy
//! [`SimServingEngine::run`] entry point is a thin wrapper — enqueue
//! everything, step to idle, report — and reproduces the pre-refactor
//! monolith bit-for-bit.
//!
//! Preempted sequences (device KV exhausted mid-decode) are no longer
//! dropped: they are requeued at the head of the queue for vLLM-style
//! recompute re-prefill (prompt + generated-so-far), up to
//! [`EngineConfig::max_preemptions`] attempts, and reported separately
//! from hard rejections.

use std::collections::VecDeque;

use anyhow::Result;

use crate::graph::Tier;
use crate::kvcache::{KvCacheManager, KvPolicy, NsaConfig, PrefixIndex};
use crate::memory::{LeaseLedger, PoolHandle, TieredLedger};
use crate::sim::HwConfig;

use super::metrics::{stats, ServingReport};
use super::request::{Request, RequestTiming};
use super::step_graph::{StepCompiler, StepPhase, StepSpec};

/// Analytic model-cost parameters for the served LLM (per device).
#[derive(Debug, Clone)]
pub struct ModelCost {
    /// Static weights resident in HBM (bytes).
    pub weights_bytes: u64,
    /// Peak transient activation bytes during prefill of one request.
    pub act_bytes: u64,
    /// FLOPs per prompt token during prefill (per device).
    pub prefill_flops_per_token: f64,
    /// FLOPs per generated token during decode (per device, per sequence).
    pub decode_flops_per_token: f64,
    /// KV bytes per token (all layers, k+v, per device).
    pub kv_bytes_per_token: u64,
}

impl ModelCost {
    /// DeepSeek-V3-like per-device share on an 8-NPU node with NSA
    /// (Table 3's setting, see DESIGN.md §2 for the calibration).
    pub fn dsv3_nsa_like() -> Self {
        Self {
            weights_bytes: 42 * crate::sim::GB,
            act_bytes: 3 * crate::sim::GB,
            prefill_flops_per_token: 90e9,
            decode_flops_per_token: 90e9,
            kv_bytes_per_token: 228 * 1024,
        }
    }
}

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub hw: HwConfig,
    pub model: ModelCost,
    pub kv_policy: KvPolicy,
    pub nsa: NsaConfig,
    /// Max concurrent decode sequences.
    pub max_batch: usize,
    /// If false (baseline runtime-style), per-step KV transfers are fully
    /// exposed instead of overlapping decode compute.
    pub overlap_transfers: bool,
    /// How many times one sequence may be preempted (and requeued for
    /// recompute re-prefill) before it is rejected outright.
    pub max_preemptions: u32,
    /// Per-decode-step latency SLO (us). When set (hierarchical engines
    /// only), KV *writebacks* — the deferrable direction — are throttled:
    /// the step graph's writeback tensor is flagged deferrable and the
    /// `SloThrottle` spill rewrite sheds whatever d2r bytes would push the
    /// compiled schedule past the budget into a backlog, drained by later
    /// steps with slack (flushed exposed at drain-out). Prefetches are
    /// never deferred: decode needs them now.
    pub decode_slo_us: Option<f64>,
    /// Retired analytic cost model, kept as a conservation oracle: when
    /// true, hierarchical steps are costed with the pre-compiler backlog
    /// arithmetic instead of compiling per-step KV transfer graphs. Used
    /// by the P12 conservation proptest and the `compiled_serving` bench;
    /// production configurations leave it false.
    pub analytic_oracle: bool,
    /// Opt-in pressure valve forwarded to the KV manager
    /// ([`KvCacheManager::with_device_spill`]): growth blocks that fit
    /// nowhere in the pool stack land in device HBM instead of preempting
    /// the sequence. Off in every preset — the tier-hierarchy bench turns
    /// it on to price pool exhaustion in peak HBM instead of preemptions.
    pub device_spill: bool,
}

impl EngineConfig {
    pub fn baseline(hw: HwConfig, model: ModelCost) -> Self {
        Self {
            hw,
            model,
            kv_policy: KvPolicy::AllDevice,
            nsa: NsaConfig::default(),
            max_batch: 8,
            overlap_transfers: false,
            max_preemptions: 3,
            decode_slo_us: None,
            analytic_oracle: false,
            device_spill: false,
        }
    }

    pub fn hierarchical(hw: HwConfig, model: ModelCost) -> Self {
        Self {
            hw,
            model,
            kv_policy: KvPolicy::FullOffload,
            nsa: NsaConfig::default(),
            max_batch: 8,
            overlap_transfers: true,
            max_preemptions: 3,
            decode_slo_us: None,
            analytic_oracle: false,
            device_spill: false,
        }
    }

    /// The hierarchical preset with a per-decode-step latency SLO: KV
    /// writebacks are shaped so offload traffic does not push step latency
    /// past `slo_us` (SelectiveOffload-style SLO guarantees).
    pub fn hierarchical_slo(hw: HwConfig, model: ModelCost, slo_us: f64) -> Self {
        Self { decode_slo_us: Some(slo_us), ..Self::hierarchical(hw, model) }
    }
}

/// Per-step fabric contention applied to this engine's pool transfers,
/// computed by the cluster orchestrator from how many sibling devices are
/// moving bytes in the same window. `1.0` on both directions (the
/// [`FabricPressure::NONE`] constant) reproduces the uncontended
/// single-device timing exactly.
#[derive(Debug, Clone, Copy)]
pub struct FabricPressure {
    /// Multiplier (≥ 1.0) on the D2R bandwidth term.
    pub d2r_slowdown: f64,
    /// Multiplier (≥ 1.0) on the R2D bandwidth term.
    pub r2d_slowdown: f64,
    /// Multiplier (≥ 1.0) on the device↔device peer edge — contention on
    /// the harvested-HBM link, counted separately from the pool fabric.
    pub peer_slowdown: f64,
}

impl FabricPressure {
    /// No contention: private, fully-provisioned link.
    pub const NONE: Self =
        Self { d2r_slowdown: 1.0, r2d_slowdown: 1.0, peer_slowdown: 1.0 };
}

/// Stack order of a tier for canonical sorting (device first, then down
/// the pyramid).
fn tier_rank(t: Tier) -> u8 {
    match t {
        Tier::Device => 0,
        // Borrowed peer HBM sits between local HBM and the pool.
        Tier::Peer(_) => 1,
        Tier::Remote | Tier::Host => 2,
        Tier::Dram => 3,
        Tier::Cxl => 4,
        Tier::Ssd => 5,
    }
}

struct Active {
    req: Request,
    timing: RequestTiming,
    remaining: usize,
    preempts: u32,
}

/// A queued sequence: either a fresh request or a preempted one waiting
/// for recompute re-prefill.
struct PendingSeq {
    req: Request,
    /// Tokens to prefill on admission: the prompt, or prompt + generated
    /// so far after a preemption (vLLM recompute semantics).
    prefill_tokens: usize,
    /// Generation tokens still to produce.
    remaining: usize,
    preempts: u32,
    /// `Some` iff this entry is a requeued preemption — the original
    /// timing is kept so reported prefill/first-token stats describe the
    /// first execution. Everything after that first prefill (including
    /// the requeue wait and the recompute pass itself) lands in the
    /// decode interval, so `decode_per_token_us` and e2e both absorb
    /// preemption stalls — matching how serving systems measure
    /// inter-token latency, where preemption shows up as ITL spikes.
    timing: Option<RequestTiming>,
}

/// Continuous-batching simulated serving engine for one device.
pub struct SimServingEngine {
    pub cfg: EngineConfig,
    pub kv: KvCacheManager,
    clock_us: f64,
    pending: VecDeque<PendingSeq>,
    active: Vec<Active>,
    done: Vec<(Request, RequestTiming)>,
    exposed_transfer_us: f64,
    fabric_stall_us: f64,
    kv_transfer_bytes: u64,
    peak_device_bytes: u64,
    defrag_stall_us: f64,
    rejected: u64,
    preempted_events: u64,
    residency: Vec<(f64, u64)>,
    /// Writeback bytes waiting for a decode step with SLO slack.
    slo_backlog_d2r: u64,
    /// Writeback bytes the decode SLO throttle deferred at least once
    /// (each byte counts once, on first deferral).
    slo_deferred_bytes: u64,
    /// Time-weighted counterpart: a byte deferred across k steps counts k
    /// times (the metric `slo_deferred_bytes` used to conflate).
    slo_deferred_byte_steps: u64,
    /// Longest single decode iteration (us) — the quantity a decode SLO
    /// bounds.
    decode_step_us_max: f64,
    /// Compiles per-step KV transfer graphs through the `Compiler`
    /// session. `Some` for hierarchical engines unless the analytic
    /// oracle is requested; `None` for the all-device baseline (nothing
    /// crosses the device boundary).
    step_compiler: Option<StepCompiler>,
    /// Transfers the step compiler split into chunked (partial-tensor)
    /// transfers across all compiled steps.
    chunk_splits: u64,
    /// Prompt KV blocks served from the shared prefix cache at admission
    /// (never recomputed by prefill).
    prefix_hit_blocks: u64,
    /// Prefill FLOPs those hits avoided.
    prefill_flops_saved: f64,
    /// Pool bytes admissions deduplicated by attaching to resident shared
    /// blocks instead of reserving new capacity.
    pool_bytes_deduped: u64,
    /// Bytes read from tiers *below* the pool (demoted prefix blocks the
    /// prefill and decode steps touched). 0 on untiered setups.
    cold_fetch_bytes: u64,
    /// Bytes fetched from borrowed peer HBM (reads that would otherwise
    /// have crossed the pool fabric — the peer-hit byte count).
    peer_fetch_bytes: u64,
    /// Bytes written back into borrowed peer HBM.
    peer_store_bytes: u64,
    /// Peak bytes of this engine's KV homed at peers at any instant.
    peer_kv_bytes_peak: u64,
    /// Bytes this engine demoted peer→pool when lenders revoked.
    peer_revoked_bytes: u64,
}

impl SimServingEngine {
    /// An engine with a private remote pool of `hw.remote_capacity` bytes,
    /// reserved at KV-block (chunk) granularity.
    pub fn new(cfg: EngineConfig) -> Self {
        let chunk = cfg.nsa.block_bytes(cfg.model.kv_bytes_per_token);
        let pool = PoolHandle::new_chunked(cfg.hw.remote_capacity, chunk);
        Self::with_pool(cfg, pool)
    }

    /// An engine whose offloaded KV reserves capacity from `pool` — clone
    /// one handle across N engines to model them sharing one SuperNode
    /// pool (the cluster setup). The prefix index is private; share one
    /// with [`Self::with_pool_and_index`] for a cluster-wide cache.
    pub fn with_pool(cfg: EngineConfig, pool: PoolHandle) -> Self {
        Self::with_pool_and_index(cfg, pool, PrefixIndex::new())
    }

    /// An engine sharing both the pool *and* the prefix `index`: a prompt
    /// prefix prefilled by any sibling engine is pool-resident and becomes
    /// an admission hit here — the pool doubles as a cluster-wide prefix
    /// cache with copy-on-write semantics.
    pub fn with_pool_and_index(cfg: EngineConfig, pool: PoolHandle, index: PrefixIndex) -> Self {
        let kv_budget = cfg
            .hw
            .device_capacity
            .saturating_sub(cfg.model.weights_bytes + cfg.model.act_bytes);
        // With a tier topology on the hardware, the manager's ledger
        // grows one cold handle per tier below the pool (demotion
        // targets); without one, the degenerate single-tier ledger
        // reproduces the pool-only manager bit-for-bit.
        let chunk = cfg.nsa.block_bytes(cfg.model.kv_bytes_per_token);
        let ledger = match &cfg.hw.tiers {
            Some(topo) => TieredLedger::from_topology(pool, topo, chunk),
            None => TieredLedger::single(pool),
        };
        let mut kv = KvCacheManager::with_ledger(
            cfg.kv_policy,
            cfg.nsa.clone(),
            cfg.model.kv_bytes_per_token,
            kv_budget,
            ledger,
            Some(index),
        );
        if cfg.device_spill {
            kv = kv.with_device_spill();
        }
        let step_compiler = (cfg.kv_policy == KvPolicy::FullOffload && !cfg.analytic_oracle)
            .then(|| StepCompiler::new(cfg.hw.clone(), cfg.overlap_transfers));
        Self {
            cfg,
            kv,
            step_compiler,
            clock_us: 0.0,
            pending: VecDeque::new(),
            active: Vec::new(),
            done: Vec::new(),
            exposed_transfer_us: 0.0,
            fabric_stall_us: 0.0,
            kv_transfer_bytes: 0,
            peak_device_bytes: 0,
            defrag_stall_us: 0.0,
            rejected: 0,
            preempted_events: 0,
            residency: Vec::new(),
            slo_backlog_d2r: 0,
            slo_deferred_bytes: 0,
            slo_deferred_byte_steps: 0,
            decode_step_us_max: 0.0,
            chunk_splits: 0,
            prefix_hit_blocks: 0,
            prefill_flops_saved: 0.0,
            pool_bytes_deduped: 0,
            cold_fetch_bytes: 0,
            peer_fetch_bytes: 0,
            peer_store_bytes: 0,
            peer_kv_bytes_peak: 0,
            peer_revoked_bytes: 0,
        }
    }

    /// Join the cluster's peer-HBM lease protocol: the KV manager may home
    /// private blocks at idle sibling replicas through `lease`, and this
    /// engine is addressed as `replica` (its own spare HBM is registered
    /// by the orchestrator, not here). Without this call the engine never
    /// touches peer HBM — the disabled configuration is bit-identical to
    /// the lease-free engine.
    pub fn set_peer_lease(&mut self, lease: LeaseLedger, replica: u16) {
        self.kv.set_peer_lease(lease, replica);
    }

    /// Borrower-side valve: stop (or resume) placing *new* blocks at
    /// peers. Existing leases are untouched.
    pub fn set_peer_enabled(&mut self, on: bool) {
        self.kv.set_peer_enabled(on);
    }

    /// A lender revoked: demote every block this engine borrowed from
    /// `lender` into the pool. The copies move over the pool fabric's
    /// write direction, exposed (revocation is not hidden under compute).
    /// Returns the bytes demoted.
    pub fn revoke_peer(&mut self, lender: u16, fabric: &FabricPressure) -> u64 {
        let moved = self.kv.revoke_peer(lender);
        if moved > 0 {
            let t = self.cfg.hw.d2r_us_slowed(moved, fabric.d2r_slowdown);
            self.clock_us += t;
            self.exposed_transfer_us += t;
            self.fabric_stall_us += t - self.cfg.hw.d2r_us(moved);
            self.kv_transfer_bytes += moved;
            self.peer_revoked_bytes += moved;
            self.note_peak();
        }
        moved
    }

    /// Run the whole workload to completion and report (the pre-refactor
    /// closed-loop entry point, now a wrapper over the stepper).
    pub fn run(mut self, mut requests: Vec<Request>) -> Result<ServingReport> {
        requests.sort_by(|a, b| a.arrival_us.total_cmp(&b.arrival_us));
        for req in requests {
            self.enqueue(req);
        }
        while self.step(&FabricPressure::NONE)? {}
        Ok(self.report())
    }

    /// Queue a request for admission. The caller dispatches in arrival
    /// order; the engine admits once its local clock reaches the arrival.
    pub fn enqueue(&mut self, req: Request) {
        self.pending.push_back(PendingSeq {
            prefill_tokens: req.prompt_tokens,
            remaining: req.gen_tokens,
            preempts: 0,
            timing: None,
            req,
        });
    }

    /// The engine's local clock (us). Meaningful only relative to the
    /// orchestrator's event horizon — the engine never advances siblings.
    pub fn now_us(&self) -> f64 {
        self.clock_us
    }

    /// True when there is nothing queued and nothing in flight.
    pub fn is_idle(&self) -> bool {
        self.pending.is_empty() && self.active.is_empty()
    }

    /// Whether a `step()` would make progress without running past
    /// `horizon_us`: the clock is behind the horizon and there is either
    /// in-flight work or an admissible arrival at/before the horizon.
    pub fn can_progress(&self, horizon_us: f64) -> bool {
        if self.clock_us >= horizon_us {
            return false;
        }
        if !self.active.is_empty() {
            return true;
        }
        match self.pending.front() {
            Some(p) => p.req.arrival_us <= horizon_us,
            None => false,
        }
    }

    /// Total token work not yet finished (queued prefill + queued and
    /// in-flight generation) — the live load signal for online routing.
    pub fn outstanding_tokens(&self) -> u64 {
        let queued: u64 = self
            .pending
            .iter()
            .map(|p| (p.prefill_tokens + p.remaining) as u64)
            .sum();
        let in_flight: u64 = self.active.iter().map(|a| a.remaining as u64).sum();
        queued + in_flight
    }

    /// Tokens of KV the engine could still admit (device headroom for the
    /// baseline policy, pool headroom under offload).
    pub fn kv_headroom_tokens(&self) -> u64 {
        let bytes = match self.cfg.kv_policy {
            KvPolicy::AllDevice => self.kv.device_headroom_bytes(),
            KvPolicy::FullOffload => {
                let pool = self.kv.pool();
                pool.capacity().saturating_sub(pool.used())
            }
        };
        bytes / self.cfg.model.kv_bytes_per_token.max(1)
    }

    /// Occupancy of the (possibly shared) remote pool in [0, 1].
    pub fn pool_pressure(&self) -> f64 {
        self.kv.pool().pressure()
    }

    /// Whether this engine is currently (or imminently) moving KV bytes
    /// over the device↔pool fabric — the cluster counts these to compute
    /// fabric contention for a window.
    pub fn has_transfer_traffic(&self) -> bool {
        self.cfg.kv_policy == KvPolicy::FullOffload && !self.is_idle()
    }

    /// Requests finished so far, in completion order. The cluster reads a
    /// suffix of this after each step to feed completions back to the
    /// router; the list also backs the final report.
    pub fn completed(&self) -> &[(Request, RequestTiming)] {
        &self.done
    }

    pub fn rejected_count(&self) -> u64 {
        self.rejected
    }

    /// One scheduler iteration: admit what is admissible, then run one
    /// batched decode step (or jump the clock to the next arrival when
    /// idle). Returns false when there is no work at all.
    ///
    /// The SLO writeback backlog has exactly one drain site: whatever path
    /// a step takes — decode-to-empty, or every pending request rejected
    /// at prefill — the backlog is flushed the moment nothing is queued
    /// and nothing is in flight, so deferred bytes are never dropped.
    pub fn step(&mut self, fabric: &FabricPressure) -> Result<bool> {
        let progressed = self.step_inner(fabric)?;
        if self.pending.is_empty() && self.active.is_empty() {
            self.flush_slo_backlog(fabric)?;
        }
        Ok(progressed)
    }

    fn step_inner(&mut self, fabric: &FabricPressure) -> Result<bool> {
        if self.pending.is_empty() && self.active.is_empty() {
            return Ok(false);
        }
        // Admit arrivals while there is batch room.
        while self.active.len() < self.cfg.max_batch {
            let Some(next) = self.pending.front() else { break };
            if next.req.arrival_us > self.clock_us && !self.active.is_empty() {
                break; // keep decoding until it arrives
            }
            // A requeued preemption waits for residency to free up while
            // other sequences are still draining, instead of being
            // rejected on a transient capacity miss.
            if next.timing.is_some()
                && !self.kv.can_admit_tokens(next.prefill_tokens)
                && !self.active.is_empty()
            {
                break;
            }
            let p = self.pending.pop_front().unwrap();
            self.clock_us = self.clock_us.max(p.req.arrival_us);
            if !self.prefill(p, fabric)? {
                self.rejected += 1;
            }
        }
        if self.active.is_empty() {
            if let Some(next) = self.pending.front() {
                self.clock_us = self.clock_us.max(next.req.arrival_us);
            }
            return Ok(true);
        }
        self.decode_iteration(fabric)?;
        // Retire finished sequences.
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].remaining == 0 {
                let mut a = self.active.swap_remove(i);
                a.timing.done_us = self.clock_us;
                self.kv.retire(a.req.id)?;
                self.done.push((a.req, a.timing));
            } else {
                i += 1;
            }
        }
        Ok(true)
    }

    /// Step until the local clock reaches `t_us` (the last step may
    /// overshoot — iterations are atomic) or no progress is possible
    /// without new arrivals. The *caller* owns the global clock; this
    /// merely catches the engine up to an event horizon.
    pub fn step_until(&mut self, t_us: f64, fabric: &FabricPressure) -> Result<()> {
        while self.can_progress(t_us) {
            if !self.step(fabric)? {
                break;
            }
        }
        Ok(())
    }

    /// Prefill one queued sequence (serial, as in chunked-prefill-off
    /// serving). For a requeued preemption this is the recompute pass.
    ///
    /// Hierarchical engines lower the prefill — compute plus the KV
    /// writeback streaming to the pool — into a step graph and run the
    /// compiled schedule; the baseline (no transfers) and the analytic
    /// oracle cost the step directly.
    ///
    /// Returns `Ok(false)` when admission fails for capacity (an ordinary
    /// rejection). A step-compiler error is an engine bug, not a capacity
    /// signal: the admission is unwound and the error propagates.
    fn prefill(&mut self, p: PendingSeq, fabric: &FabricPressure) -> Result<bool> {
        let start_us = self.clock_us;

        let Ok(admit) =
            self.kv.admit_prefix(p.req.id, p.prefill_tokens, &p.req.block_hashes, &self.cfg.hw)
        else {
            return Ok(false); // device/pool capacity rejection
        };
        self.defrag_stall_us += admit.cost.defrag_us;
        // Prefix hits are not recomputed: only the un-shared suffix runs
        // through prefill compute. The shared blocks instead transfer
        // pool→device (`prefix_fetch_bytes`) so the suffix can attend over
        // them — a bandwidth trade the compiled schedule hides under the
        // suffix compute.
        let suffix_tokens = p.prefill_tokens - admit.hit_tokens;
        let compute_flops = self.cfg.model.prefill_flops_per_token * suffix_tokens as f64;
        let compute_us = self.cfg.hw.compute_us(compute_flops, 0);
        self.prefix_hit_blocks += admit.hit_blocks as u64;
        self.prefill_flops_saved +=
            self.cfg.model.prefill_flops_per_token * admit.hit_tokens as f64;
        self.pool_bytes_deduped += admit.deduped_bytes;
        self.cold_fetch_bytes += admit.cold_fetch.iter().map(|&(_, b)| b).sum::<u64>();
        self.peer_store_bytes += admit.cost.peer_store.iter().map(|&(_, b)| b).sum::<u64>();

        let t = if let Some(sc) = self.step_compiler.as_mut() {
            let spec = StepSpec {
                phase: StepPhase::Prefill,
                batch: p.prefill_tokens,
                compute_flops,
                compute_bytes: 0,
                kv_fetch_bytes: admit.cost.r2d_bytes,
                prefix_fetch_bytes: admit.prefix_fetch_bytes,
                kv_writeback_bytes: admit.cost.d2r_bytes,
                cold_fetch: admit.cold_fetch.clone(),
                peer_fetch: admit.cost.peer_fetch.clone(),
                peer_store: admit.cost.peer_store.clone(),
                cpu_us: admit.cost.cpu_us,
                defrag_us: admit.cost.defrag_us,
                slo_us: None, // the SLO bounds decode steps, not prefill
            };
            let cs = match sc.compile(&spec, fabric) {
                Ok(cs) => cs,
                Err(e) => {
                    // Unwind the already-admitted sequence so its pool
                    // reservation and KV state do not leak, then surface
                    // the compiler failure (distinct from rejection).
                    let _ = self.kv.retire(p.req.id);
                    return Err(e.into());
                }
            };
            self.exposed_transfer_us += cs.exposed_us;
            self.fabric_stall_us += cs.exposed_us - cs.exposed_free_us;
            self.kv_transfer_bytes += cs.moved_r2d + cs.moved_d2r;
            self.chunk_splits += cs.chunk_splits as u64;
            cs.step_us
        } else {
            // Baseline/oracle: defrag stalls serialise into prefill
            // (§7.3.2); the hierarchical oracle exposes transfers — the
            // writeback stream and the shared-prefix fetch run on opposite
            // link directions, so they overlap each other — only where
            // they outrun the suffix prefill compute.
            let mut t = compute_us + admit.cost.defrag_us + admit.cost.cpu_us;
            let d2r_us = self.cfg.hw.d2r_us_slowed(admit.cost.d2r_bytes, fabric.d2r_slowdown);
            let d2r_free_us = self.cfg.hw.d2r_us(admit.cost.d2r_bytes);
            let pf_us =
                self.cfg.hw.r2d_us_slowed(admit.prefix_fetch_bytes, fabric.r2d_slowdown);
            let pf_free_us = self.cfg.hw.r2d_us(admit.prefix_fetch_bytes);
            // Demoted prefix blocks arrive over their cold tier's deeper
            // path (the node-local fabric pressure does not contend it).
            let cold_us: f64 =
                admit.cold_fetch.iter().map(|&(t, b)| self.cfg.hw.fetch_us(t, b)).sum();
            let cold_bytes: u64 = admit.cold_fetch.iter().map(|&(_, b)| b).sum();
            // Harvested-HBM writebacks ride the peer edge, which overlaps
            // the pool directions (a separate physical link).
            let peer_us: f64 = admit
                .cost
                .peer_store
                .iter()
                .map(|&(l, b)| {
                    self.cfg.hw.evict_us_slowed(Tier::Peer(l), b, fabric.peer_slowdown)
                })
                .sum();
            let peer_free_us: f64 = admit
                .cost
                .peer_store
                .iter()
                .map(|&(l, b)| self.cfg.hw.evict_us(Tier::Peer(l), b))
                .sum();
            let peer_bytes: u64 = admit.cost.peer_store.iter().map(|&(_, b)| b).sum();
            let transfer_us = d2r_us.max(pf_us).max(cold_us).max(peer_us);
            let transfer_free_us = d2r_free_us.max(pf_free_us).max(cold_us).max(peer_free_us);
            if admit.cost.d2r_bytes + admit.prefix_fetch_bytes + cold_bytes + peer_bytes > 0 {
                if self.cfg.overlap_transfers {
                    let exposed = (transfer_us - compute_us).max(0.0);
                    let exposed_free = (transfer_free_us - compute_us).max(0.0);
                    t += exposed;
                    self.exposed_transfer_us += exposed;
                    self.fabric_stall_us += exposed - exposed_free;
                } else {
                    t += transfer_us;
                    self.exposed_transfer_us += transfer_us;
                    self.fabric_stall_us += transfer_us - transfer_free_us;
                }
            }
            self.kv_transfer_bytes +=
                admit.cost.d2r_bytes + admit.cost.r2d_bytes + admit.prefix_fetch_bytes
                    + cold_bytes
                    + peer_bytes;
            t
        };

        self.clock_us += t;
        let timing = match p.timing {
            // Recompute pass: keep the first execution's prefill stamps.
            Some(orig) => orig,
            None => RequestTiming {
                prefill_start_us: start_us,
                prefill_end_us: self.clock_us,
                first_token_us: self.clock_us,
                ..Default::default()
            },
        };
        self.note_peak();
        self.active.push(Active {
            remaining: p.remaining,
            preempts: p.preempts,
            req: p.req,
            timing,
        });
        Ok(true)
    }

    /// One batched decode step over all active sequences.
    fn decode_iteration(&mut self, fabric: &FabricPressure) -> Result<()> {
        let batch = self.active.len();
        let compute_us = self.cfg.hw.compute_us(
            self.cfg.model.decode_flops_per_token * batch as f64,
            // decode is bandwidth-bound: weights are re-read every step.
            self.cfg.model.weights_bytes,
        );

        let mut r2d = 0u64;
        let mut d2r = 0u64;
        let mut cold: Vec<(Tier, u64)> = Vec::new();
        let mut peer_fetch: Vec<(u16, u64)> = Vec::new();
        let mut peer_store: Vec<(u16, u64)> = Vec::new();
        let mut cpu_us = 0.0;
        let mut defrag_us = 0.0;
        let mut preempted: Vec<usize> = Vec::new();
        fn merge_peer(acc: &mut Vec<(u16, u64)>, add: &[(u16, u64)]) {
            for &(l, b) in add {
                match acc.iter_mut().find(|(al, _)| *al == l) {
                    Some(e) => e.1 += b,
                    None => acc.push((l, b)),
                }
            }
        }
        for (i, a) in self.active.iter_mut().enumerate() {
            match self.kv.decode_step(a.req.id, &self.cfg.hw) {
                Ok(c) => {
                    r2d += c.r2d_bytes;
                    d2r += c.d2r_bytes;
                    for &(t, b) in &c.cold_fetch {
                        match cold.iter_mut().find(|(ct, _)| *ct == t) {
                            Some(e) => e.1 += b,
                            None => cold.push((t, b)),
                        }
                    }
                    merge_peer(&mut peer_fetch, &c.peer_fetch);
                    merge_peer(&mut peer_store, &c.peer_store);
                    cpu_us += c.cpu_us;
                    defrag_us += c.defrag_us;
                    a.remaining = a.remaining.saturating_sub(1);
                }
                Err(_) => {
                    // Device KV (or shared pool) exhausted mid-decode:
                    // preempt the sequence.
                    preempted.push(i);
                }
            }
        }
        // Canonical tier order keeps the compile-cache key stable across
        // steps with the same cold-fetch shape.
        cold.sort_by_key(|&(t, _)| tier_rank(t));
        self.cold_fetch_bytes += cold.iter().map(|&(_, b)| b).sum::<u64>();
        // Same canonicalisation for the per-lender peer traffic.
        peer_fetch.sort_by_key(|&(l, _)| l);
        peer_store.sort_by_key(|&(l, _)| l);
        self.peer_fetch_bytes += peer_fetch.iter().map(|&(_, b)| b).sum::<u64>();
        self.peer_store_bytes += peer_store.iter().map(|&(_, b)| b).sum::<u64>();
        for &i in preempted.iter().rev() {
            let a = self.active.swap_remove(i);
            let _ = self.kv.retire(a.req.id);
            if a.preempts >= self.cfg.max_preemptions {
                self.rejected += 1;
            } else {
                // vLLM-style recompute preemption: discard KV, requeue at
                // the head for re-prefill of prompt + generated tokens.
                self.preempted_events += 1;
                let generated = a.req.gen_tokens - a.remaining;
                self.pending.push_front(PendingSeq {
                    prefill_tokens: a.req.prompt_tokens + generated,
                    remaining: a.remaining,
                    preempts: a.preempts + 1,
                    timing: Some(a.timing),
                    req: a.req,
                });
            }
        }
        // Compiled path (hierarchical): lower the step into a KV transfer
        // graph — compute, fetch, writeback (plus a bounded backlog drain
        // attempt) and the host tail — and run the compiled schedule. The
        // SLO reaches the graph as `Compiler::slo_us`; the throttle's
        // spill rewrite decides which writeback bytes defer.
        if let Some(sc) = self.step_compiler.as_mut() {
            let slo = self.cfg.decode_slo_us.filter(|_| self.cfg.overlap_transfers);
            let mut drain = 0u64;
            if slo.is_some() {
                // Attempt to drain a bounded quantum per step: twice the
                // step's own writeback inflow, so backlog shrinks whenever
                // slack exists while the step *shape* — and therefore the
                // compile-cache key — stays fixed during steady draining.
                // The drain is rounded DOWN to whole KV blocks: the spill
                // rewrite defers arbitrary byte counts, and without the
                // rounding a sub-quantum backlog would put a fresh
                // remainder in every step's key, turning steady drain-down
                // into a compile-cache miss per step. Any sub-block
                // residue rides to the final flush.
                let block = self.kv.block_bytes().max(1);
                let quantum = 2 * (batch.max(1) as u64) * block;
                drain = (self.slo_backlog_d2r.min(quantum) / block) * block;
            }
            let spec = StepSpec {
                phase: StepPhase::Decode,
                batch,
                compute_flops: self.cfg.model.decode_flops_per_token * batch as f64,
                compute_bytes: self.cfg.model.weights_bytes,
                kv_fetch_bytes: r2d,
                prefix_fetch_bytes: 0,
                kv_writeback_bytes: d2r + drain,
                cold_fetch: cold.clone(),
                peer_fetch: peer_fetch.clone(),
                peer_store: peer_store.clone(),
                cpu_us,
                defrag_us,
                slo_us: slo,
            };
            let cs = sc.compile(&spec, fabric)?;
            // Deferral applies to the re-attempted backlog bytes first, so
            // `slo_deferred_bytes` counts each byte once (on its first
            // deferral) while the byte·steps metric counts every carry.
            let re_deferred = cs.deferred_d2r.min(drain);
            self.slo_deferred_bytes += cs.deferred_d2r - re_deferred;
            self.slo_deferred_byte_steps += cs.deferred_d2r;
            self.slo_backlog_d2r = self.slo_backlog_d2r - drain + cs.deferred_d2r;
            self.kv_transfer_bytes += cs.moved_r2d + cs.moved_d2r;
            self.defrag_stall_us += defrag_us;
            self.exposed_transfer_us += cs.exposed_us;
            self.fabric_stall_us += cs.exposed_us - cs.exposed_free_us;
            self.chunk_splits += cs.chunk_splits as u64;
            self.clock_us += cs.step_us;
            self.decode_step_us_max = self.decode_step_us_max.max(cs.step_us);
            self.note_peak();
            return Ok(());
        }

        // Analytic oracle / baseline path. SLO throttle (hierarchical
        // oracle only): writebacks are the deferrable direction. Keep only
        // the d2r bytes whose transfer fits this step's budget —
        // max(slo − cpu − defrag, compute); transfers up to the compute
        // time are free under overlap — and carry the rest in a backlog
        // that drains through later steps' slack.
        if self.cfg.overlap_transfers {
            if let Some(slo) = self.cfg.decode_slo_us {
                let carried = std::mem::take(&mut self.slo_backlog_d2r);
                d2r += carried;
                let budget_us = (slo - cpu_us - defrag_us).max(compute_us);
                if d2r > 0
                    && self.cfg.hw.d2r_us_slowed(d2r, fabric.d2r_slowdown) > budget_us
                {
                    let us_per_byte =
                        fabric.d2r_slowdown / (self.cfg.hw.d2r_gbps * 1e9) * 1e6;
                    let bw_budget = (budget_us - self.cfg.hw.link_latency_us).max(0.0);
                    let keep = ((bw_budget / us_per_byte) as u64).min(d2r);
                    let defer = d2r - keep;
                    self.slo_backlog_d2r = defer;
                    let re_deferred = defer.min(carried);
                    self.slo_deferred_bytes += defer - re_deferred;
                    self.slo_deferred_byte_steps += defer;
                    d2r = keep;
                }
            }
        }

        let cold_bytes: u64 = cold.iter().map(|&(_, b)| b).sum();
        let cold_us: f64 = cold.iter().map(|&(t, b)| self.cfg.hw.fetch_us(t, b)).sum();
        // Peer fetches and stores share one device↔device edge, so they
        // serialise with each other but overlap the pool directions.
        let peer_us: f64 = peer_fetch
            .iter()
            .map(|&(l, b)| self.cfg.hw.fetch_us_slowed(Tier::Peer(l), b, fabric.peer_slowdown))
            .sum::<f64>()
            + peer_store
                .iter()
                .map(|&(l, b)| {
                    self.cfg.hw.evict_us_slowed(Tier::Peer(l), b, fabric.peer_slowdown)
                })
                .sum::<f64>();
        let peer_free_us: f64 = peer_fetch
            .iter()
            .map(|&(l, b)| self.cfg.hw.fetch_us(Tier::Peer(l), b))
            .sum::<f64>()
            + peer_store.iter().map(|&(l, b)| self.cfg.hw.evict_us(Tier::Peer(l), b)).sum::<f64>();
        let peer_bytes: u64 = peer_fetch.iter().map(|&(_, b)| b).sum::<u64>()
            + peer_store.iter().map(|&(_, b)| b).sum::<u64>();
        self.kv_transfer_bytes += r2d + d2r + cold_bytes + peer_bytes;
        self.defrag_stall_us += defrag_us;

        let transfer_us = self
            .cfg
            .hw
            .r2d_us_slowed(r2d, fabric.r2d_slowdown)
            .max(self.cfg.hw.d2r_us_slowed(d2r, fabric.d2r_slowdown))
            .max(cold_us)
            .max(peer_us);
        let transfer_free_us = self
            .cfg
            .hw
            .r2d_us(r2d)
            .max(self.cfg.hw.d2r_us(d2r))
            .max(cold_us)
            .max(peer_free_us);
        let step_us = if self.cfg.overlap_transfers {
            // Graph-driven: transfers hide under the step's compute.
            let exposed = (transfer_us - compute_us).max(0.0);
            let exposed_free = (transfer_free_us - compute_us).max(0.0);
            self.exposed_transfer_us += exposed;
            self.fabric_stall_us += exposed - exposed_free;
            compute_us + exposed + cpu_us + defrag_us
        } else if r2d + d2r + cold_bytes + peer_bytes > 0 {
            self.exposed_transfer_us += transfer_us;
            self.fabric_stall_us += transfer_us - transfer_free_us;
            compute_us + transfer_us + cpu_us + defrag_us
        } else {
            compute_us + cpu_us + defrag_us
        };
        self.clock_us += step_us;
        self.decode_step_us_max = self.decode_step_us_max.max(step_us);
        self.note_peak();
        Ok(())
    }

    /// Flush the SLO writeback backlog once nothing is decoding: the
    /// remaining bytes transfer exposed (no compute to hide under), so
    /// conservation holds — every deferred byte still reaches the pool.
    /// On the compiled path the drain is itself a compiled step (a lone
    /// Store, no SLO — everything must move).
    fn flush_slo_backlog(&mut self, fabric: &FabricPressure) -> Result<()> {
        if self.slo_backlog_d2r == 0 {
            return Ok(());
        }
        let bytes = std::mem::take(&mut self.slo_backlog_d2r);
        if let Some(sc) = self.step_compiler.as_mut() {
            let spec = StepSpec {
                phase: StepPhase::Drain,
                batch: 0,
                compute_flops: 0.0,
                compute_bytes: 0,
                kv_fetch_bytes: 0,
                prefix_fetch_bytes: 0,
                kv_writeback_bytes: bytes,
                cold_fetch: vec![],
                peer_fetch: vec![],
                peer_store: vec![],
                cpu_us: 0.0,
                defrag_us: 0.0,
                slo_us: None,
            };
            let cs = sc.compile(&spec, fabric)?;
            self.exposed_transfer_us += cs.exposed_us;
            self.fabric_stall_us += cs.exposed_us - cs.exposed_free_us;
            self.kv_transfer_bytes += cs.moved_d2r;
            self.clock_us += cs.step_us;
        } else {
            let t = self.cfg.hw.d2r_us_slowed(bytes, fabric.d2r_slowdown);
            let t_free = self.cfg.hw.d2r_us(bytes);
            self.exposed_transfer_us += t;
            self.fabric_stall_us += t - t_free;
            self.kv_transfer_bytes += bytes;
            self.clock_us += t;
        }
        self.note_peak();
        Ok(())
    }

    fn note_peak(&mut self) {
        let total = self.cfg.model.weights_bytes
            + self.cfg.model.act_bytes
            + self.kv.device_kv_bytes();
        self.peak_device_bytes = self.peak_device_bytes.max(total);
        self.peer_kv_bytes_peak = self.peer_kv_bytes_peak.max(self.kv.peer_kv_bytes);
        self.residency.push((self.clock_us, total));
    }

    /// Consume the engine and summarise everything it served.
    pub fn report(self) -> ServingReport {
        // Prefill = execution time (start→end), as the paper measures it;
        // queueing shows up in e2e latency instead.
        let prefill: Vec<f64> = self
            .done
            .iter()
            .map(|(_, t)| t.prefill_end_us - t.prefill_start_us)
            .collect();
        let decode_pt: Vec<f64> = self
            .done
            .iter()
            .filter(|(r, _)| r.gen_tokens > 0)
            .map(|(r, t)| t.decode_time_us() / r.gen_tokens as f64)
            .collect();
        let e2e: Vec<f64> = self
            .done
            .iter()
            .map(|(r, t)| t.e2e_latency_us(r.arrival_us))
            .collect();
        let tokens: u64 = self.done.iter().map(|(r, _)| r.gen_tokens as u64).sum();
        ServingReport {
            prefill_latency_us: stats(&prefill),
            decode_per_token_us: stats(&decode_pt),
            e2e_latency_us: stats(&e2e),
            total_time_us: self.clock_us,
            tokens_generated: tokens,
            throughput_tok_per_s: if self.clock_us > 0.0 {
                tokens as f64 / (self.clock_us / 1e6)
            } else {
                0.0
            },
            peak_device_bytes: self.peak_device_bytes,
            defrag_events: self.kv.allocator.defrag_events,
            defrag_stall_us: self.defrag_stall_us,
            exposed_transfer_us: self.exposed_transfer_us,
            fabric_stall_us: self.fabric_stall_us,
            kv_transfer_bytes: self.kv_transfer_bytes,
            rejected_requests: self.rejected,
            preempted_events: self.preempted_events,
            slo_deferred_bytes: self.slo_deferred_bytes,
            slo_deferred_byte_steps: self.slo_deferred_byte_steps,
            decode_step_us_max: self.decode_step_us_max,
            compile_cache_hits: self.step_compiler.as_ref().map_or(0, |sc| sc.hits),
            compile_cache_misses: self.step_compiler.as_ref().map_or(0, |sc| sc.misses),
            compile_us_total: self.step_compiler.as_ref().map_or(0.0, |sc| sc.compile_us_total),
            compile_us_max: self.step_compiler.as_ref().map_or(0.0, |sc| sc.compile_us_max),
            chunk_splits: self.chunk_splits,
            prefix_hit_blocks: self.prefix_hit_blocks,
            prefill_flops_saved: self.prefill_flops_saved,
            pool_bytes_deduped: self.pool_bytes_deduped,
            cold_fetch_bytes: self.cold_fetch_bytes,
            peer_fetch_bytes: self.peer_fetch_bytes,
            peer_store_bytes: self.peer_store_bytes,
            peer_kv_bytes_peak: self.peer_kv_bytes_peak,
            peer_revoked_bytes: self.peer_revoked_bytes,
            residency: self.residency,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::request::WorkloadConfig;
    use crate::sim::{GB, MB};

    fn hw() -> HwConfig {
        HwConfig::ascend910c_like().with_device_capacity(64 * GB)
    }

    fn small_model() -> ModelCost {
        ModelCost {
            weights_bytes: 8 * GB,
            act_bytes: GB,
            prefill_flops_per_token: 16e9,
            decode_flops_per_token: 16e9,
            kv_bytes_per_token: 64 * 1024,
        }
    }

    #[test]
    fn completes_all_requests() {
        let cfg = EngineConfig::baseline(hw(), small_model());
        let eng = SimServingEngine::new(cfg);
        let r = eng.run(WorkloadConfig::short_sequence(12, 5).generate()).unwrap();
        assert_eq!(r.prefill_latency_us.n, 12);
        assert!(r.tokens_generated > 0);
        assert!(r.throughput_tok_per_s > 0.0);
        assert_eq!(r.rejected_requests, 0);
    }

    #[test]
    fn hierarchical_lowers_peak_memory() {
        let wl = WorkloadConfig::long_sequence(4, 20_000, 200, 3).generate();
        let base = SimServingEngine::new(EngineConfig::baseline(hw(), small_model()))
            .run(wl.clone())
            .unwrap();
        let hier = SimServingEngine::new(EngineConfig::hierarchical(hw(), small_model()))
            .run(wl)
            .unwrap();
        assert!(
            hier.peak_device_bytes < base.peak_device_bytes,
            "hier {} >= base {}",
            hier.peak_device_bytes,
            base.peak_device_bytes
        );
    }

    #[test]
    fn hierarchical_decode_carries_cpu_overhead() {
        // Short sequences, low pressure: prefill comparable, decode slower
        // under offload (Table 5's shape).
        let wl = WorkloadConfig::short_sequence(8, 11).generate();
        let base = SimServingEngine::new(EngineConfig::baseline(hw(), small_model()))
            .run(wl.clone())
            .unwrap();
        let hier = SimServingEngine::new(EngineConfig::hierarchical(hw(), small_model()))
            .run(wl)
            .unwrap();
        assert!(
            hier.decode_per_token_us.mean > base.decode_per_token_us.mean,
            "decode overhead missing: {} <= {}",
            hier.decode_per_token_us.mean,
            base.decode_per_token_us.mean
        );
        // Prefill within a few percent.
        let rel = (hier.prefill_latency_us.mean - base.prefill_latency_us.mean).abs()
            / base.prefill_latency_us.mean;
        assert!(rel < 0.25, "prefill diverged {rel}");
    }

    #[test]
    fn baseline_rejects_what_offload_serves() {
        // Sequence too big for device KV budget: 900k tokens * 64 KiB/tok
        // = 65.5e9 B > the 55 GiB (59.1e9 B) KV budget.
        let wl = WorkloadConfig::long_sequence(1, 1_000_000, 10, 1).generate();
        let base = SimServingEngine::new(EngineConfig::baseline(hw(), small_model()))
            .run(wl.clone())
            .unwrap();
        assert_eq!(base.rejected_requests, 1);
        let hier = SimServingEngine::new(EngineConfig::hierarchical(hw(), small_model()))
            .run(wl)
            .unwrap();
        assert_eq!(hier.rejected_requests, 0);
    }

    #[test]
    fn offload_moves_bytes_baseline_does_not() {
        let wl = WorkloadConfig::short_sequence(4, 2).generate();
        let base = SimServingEngine::new(EngineConfig::baseline(hw(), small_model()))
            .run(wl.clone())
            .unwrap();
        let hier = SimServingEngine::new(EngineConfig::hierarchical(hw(), small_model()))
            .run(wl)
            .unwrap();
        assert_eq!(base.kv_transfer_bytes, 0);
        assert!(hier.kv_transfer_bytes > 0);
    }

    // ---- steppable-core and satellite behaviours ----

    fn req(id: u64, arrival_us: f64, prompt: usize, gen: usize) -> Request {
        Request { id, arrival_us, prompt_tokens: prompt, gen_tokens: gen, block_hashes: vec![] }
    }

    /// A model whose KV blocks are 1 MiB (block_tokens 16 × 64 KiB/tok),
    /// with `budget_mb` MiB of device KV budget.
    fn tight_cfg(budget_mb: u64) -> EngineConfig {
        let model = ModelCost {
            weights_bytes: GB,
            act_bytes: GB / 2,
            prefill_flops_per_token: 16e9,
            decode_flops_per_token: 16e9,
            kv_bytes_per_token: 64 * 1024,
        };
        let hw = HwConfig::ascend910c_like()
            .with_device_capacity(GB + GB / 2 + budget_mb * MB);
        EngineConfig {
            nsa: NsaConfig { block_tokens: 16, ..Default::default() },
            ..EngineConfig::baseline(hw, model)
        }
    }

    #[test]
    fn stepper_matches_closed_loop_run() {
        // Driving the public step() API by hand must reproduce run().
        let wl = WorkloadConfig {
            mean_interarrival_us: 50_000.0,
            ..WorkloadConfig::short_sequence(10, 9)
        }
        .generate();
        let via_run = SimServingEngine::new(EngineConfig::hierarchical(hw(), small_model()))
            .run(wl.clone())
            .unwrap();
        let mut eng = SimServingEngine::new(EngineConfig::hierarchical(hw(), small_model()));
        let mut sorted = wl;
        sorted.sort_by(|a, b| a.arrival_us.total_cmp(&b.arrival_us));
        for r in sorted {
            // Dispatch at arrival time, as the cluster does.
            eng.step_until(r.arrival_us, &FabricPressure::NONE).unwrap();
            eng.enqueue(r);
        }
        while eng.step(&FabricPressure::NONE).unwrap() {}
        let via_step = eng.report();
        assert_eq!(via_step.prefill_latency_us.n, via_run.prefill_latency_us.n);
        assert!((via_step.total_time_us - via_run.total_time_us).abs() < 1e-9);
        assert!(
            (via_step.throughput_tok_per_s - via_run.throughput_tok_per_s).abs() < 1e-9
        );
        assert_eq!(via_step.peak_device_bytes, via_run.peak_device_bytes);
        assert!((via_step.exposed_transfer_us - via_run.exposed_transfer_us).abs() < 1e-9);
    }

    #[test]
    fn baseline_fragmentation_charges_defrag_stall() {
        // Deterministic compaction: R0 (400 MiB) and R1 (200 MiB) admitted
        // first; R1 retires early, leaving a 200 MiB hole that is too
        // small for R2 (300 MiB) while the tail is too short — free bytes
        // suffice only after compaction, which must stall prefill.
        let cfg = EngineConfig {
            max_batch: 2,
            nsa: NsaConfig { block_tokens: 64, ..Default::default() },
            ..tight_cfg(800)
        };
        let wl = vec![
            req(0, 0.0, 6400, 100), // 100 blocks of 4 MiB = 400 MiB
            req(1, 0.0, 3200, 10),  // 200 MiB, retires first
            req(2, 0.0, 4800, 10),  // 300 MiB, forces compaction
        ];
        let r = SimServingEngine::new(cfg).run(wl).unwrap();
        assert_eq!(r.prefill_latency_us.n, 3, "all three must complete");
        assert_eq!(r.rejected_requests, 0);
        assert!(r.defrag_events > 0, "churn must trigger compaction");
        assert!(
            r.defrag_stall_us > 0.0,
            "defrag stall must be accounted, got {}",
            r.defrag_stall_us
        );
    }

    #[test]
    fn preempted_sequence_requeues_and_completes() {
        // Budget 634 MiB = 634 one-MiB blocks. R0 (600 blocks + 1 growth)
        // and R1 (33 blocks) fill the device exactly after one decode
        // step; R1's next block growth OOMs -> preemption. R1 then waits
        // (its recompute needs 34 blocks, only 33 free) until R0 retires,
        // re-prefills and completes. Nothing is rejected.
        let cfg = EngineConfig { max_batch: 2, ..tight_cfg(634) };
        let wl = vec![
            req(0, 0.0, 9600, 16), // 600 blocks, one growth at step 1
            req(1, 0.0, 527, 1000), // 33 blocks, grows at step 2 -> OOM
        ];
        let r = SimServingEngine::new(cfg).run(wl).unwrap();
        assert_eq!(r.preempted_events, 1, "R1 must be preempted once");
        assert_eq!(r.rejected_requests, 0, "preemption is not rejection");
        assert_eq!(r.prefill_latency_us.n, 2, "both requests complete");
        assert_eq!(r.tokens_generated, 16 + 1000);
    }

    #[test]
    fn preemption_gives_up_after_max_attempts() {
        // A single sequence whose growth can never fit: 511 prompt blocks
        // + 1 growth block fill the 512 MiB budget; the next growth OOMs,
        // and every recompute re-prefill (512 blocks exactly) OOMs again
        // on its first decode step. After max_preemptions requeues it is
        // rejected, not looped forever.
        let cfg = EngineConfig { max_batch: 2, ..tight_cfg(512) };
        let wl = vec![req(0, 0.0, 8176, 100)];
        let r = SimServingEngine::new(cfg).run(wl).unwrap();
        assert_eq!(r.preempted_events, 3);
        assert_eq!(r.rejected_requests, 1);
        assert_eq!(r.prefill_latency_us.n, 0);
    }

    #[test]
    fn preemption_on_shared_prefix_trace_reuses_cache_without_double_free() {
        // FullOffload with 1 MiB blocks (16 tok x 64 KiB) and a 40-block
        // pool. R0 (34 blocks private) and R1 (2 shared + 2 private) fill
        // the pool after their first growth; the next growth OOMs and
        // preempts both. The shared prefix must survive preemption — the
        // retire drops only the sequences' own references, the index's
        // reference keeps the blocks cached — and R1's recompute
        // re-admission must *hit* the cache instead of re-prefilling it.
        let model = ModelCost {
            weights_bytes: GB,
            act_bytes: GB / 2,
            prefill_flops_per_token: 16e9,
            decode_flops_per_token: 16e9,
            kv_bytes_per_token: 64 * 1024,
        };
        let mut hw = HwConfig::ascend910c_like().with_device_capacity(64 * GB);
        hw.remote_capacity = 40 * MB;
        let cfg = EngineConfig {
            nsa: NsaConfig { block_tokens: 16, ..Default::default() },
            max_batch: 2,
            ..EngineConfig::hierarchical(hw, model)
        };
        let block = MB;
        let hashes = crate::serving::request::template_prefix_hashes(0, 32, 16);
        assert_eq!(hashes.len(), 2);
        let wl = vec![
            req(0, 0.0, 544, 32),
            Request { block_hashes: hashes.clone(), ..req(1, 0.0, 64, 100) },
        ];
        let mut eng = SimServingEngine::new(cfg);
        for r in wl {
            eng.enqueue(r);
        }
        while eng.step(&FabricPressure::NONE).unwrap() {}
        // Everything retired: the pool holds exactly the cached prefix —
        // a double-free (or a leaked sequence reference) breaks this.
        let idx = eng.kv.prefix_index().unwrap();
        assert_eq!(eng.kv.pool().used(), idx.resident_bytes());
        assert_eq!(idx.resident_bytes(), 2 * block, "prefix must survive preemption");
        for &h in &hashes {
            assert_eq!(eng.kv.pool().shared_refs(h), 1, "only the index ref remains");
        }
        let r = eng.report();
        assert!(r.preempted_events >= 1, "the trace must force preemption");
        assert_eq!(r.rejected_requests, 0);
        assert_eq!(r.prefill_latency_us.n, 2, "both requests complete");
        assert_eq!(r.tokens_generated, 32 + 100);
        // R1's first admission inserts the prefix cold; its post-preemption
        // recompute re-admission hits both blocks instead of re-prefilling.
        assert_eq!(r.prefix_hit_blocks, 2);
        assert_eq!(r.pool_bytes_deduped, 2 * block);
        assert!(r.prefill_flops_saved > 0.0);
    }

    #[test]
    fn residency_curve_is_time_ordered() {
        let wl = WorkloadConfig::short_sequence(6, 21).generate();
        let r = SimServingEngine::new(EngineConfig::hierarchical(hw(), small_model()))
            .run(wl)
            .unwrap();
        assert!(!r.residency.is_empty());
        for w in r.residency.windows(2) {
            assert!(w[1].0 >= w[0].0, "residency timestamps must not decrease");
        }
        assert!(r.residency.iter().all(|&(_, b)| b <= r.peak_device_bytes));
    }

    /// Writeback-heavy decode: 16 MiB KV blocks against 40 us of decode
    /// compute — the per-step tail-block persist dwarfs the compute it
    /// could hide under.
    fn writeback_heavy_cfg(slo_us: Option<f64>) -> EngineConfig {
        let model = ModelCost {
            weights_bytes: 64 * MB,
            act_bytes: GB,
            prefill_flops_per_token: 16e9,
            decode_flops_per_token: 1e9,
            kv_bytes_per_token: 64 * 1024,
        };
        EngineConfig {
            nsa: NsaConfig { block_tokens: 256, ..Default::default() },
            decode_slo_us: slo_us,
            ..EngineConfig::hierarchical(hw(), model)
        }
    }

    #[test]
    fn generous_decode_slo_is_inert() {
        let wl = WorkloadConfig::long_sequence(2, 8000, 50, 7).generate();
        let free = SimServingEngine::new(writeback_heavy_cfg(None)).run(wl.clone()).unwrap();
        let slo = SimServingEngine::new(writeback_heavy_cfg(Some(1e12))).run(wl).unwrap();
        assert_eq!(slo.slo_deferred_bytes, 0);
        assert_eq!(slo.kv_transfer_bytes, free.kv_transfer_bytes);
        assert!((slo.total_time_us - free.total_time_us).abs() < 1e-9);
        assert!((slo.decode_step_us_max - free.decode_step_us_max).abs() < 1e-9);
    }

    #[test]
    fn tight_decode_slo_defers_writebacks_and_conserves_bytes() {
        let wl = WorkloadConfig::long_sequence(2, 8000, 50, 7).generate();
        let free = SimServingEngine::new(writeback_heavy_cfg(None)).run(wl.clone()).unwrap();
        // A 1 us budget clamps to the compute floor: every step sheds the
        // writeback bytes it cannot hide under decode compute.
        let slo = SimServingEngine::new(writeback_heavy_cfg(Some(1.0))).run(wl).unwrap();

        assert!(slo.slo_deferred_bytes > 0, "throttle never engaged");
        assert!(
            slo.decode_step_us_max <= free.decode_step_us_max * (1.0 + 1e-9),
            "shaped steps must not be longer: {} > {}",
            slo.decode_step_us_max,
            free.decode_step_us_max
        );
        // Every deferred byte still reaches the pool (backlog + flush).
        assert_eq!(slo.kv_transfer_bytes, free.kv_transfer_bytes);
        assert_eq!(slo.tokens_generated, free.tokens_generated);
        assert_eq!(slo.rejected_requests, free.rejected_requests);
    }

    #[test]
    fn run_ending_in_rejection_still_flushes_slo_backlog() {
        // A decodes under a 1 us SLO (every step sheds writeback into the
        // backlog); B's prompt cannot fit the pool and is rejected at
        // prefill long after A finished, so the run ends through the
        // admission path — the single flush exit must still conserve every
        // deferred byte against the SLO-free run.
        let mk = |slo| {
            let mut cfg = writeback_heavy_cfg(slo);
            cfg.hw.remote_capacity = 700 * MB;
            cfg
        };
        let wl = vec![
            req(0, 0.0, 8000, 50),        // 32 blocks of 16 MiB = 512 MiB
            req(1, 1e12, 100_000, 10),    // ~6.1 GiB -> rejected at prefill
        ];
        let free = SimServingEngine::new(mk(None)).run(wl.clone()).unwrap();
        let slo = SimServingEngine::new(mk(Some(1.0))).run(wl).unwrap();
        assert_eq!(free.rejected_requests, 1);
        assert_eq!(slo.rejected_requests, 1);
        assert!(slo.slo_deferred_bytes > 0, "backlog never formed");
        assert_eq!(
            slo.kv_transfer_bytes, free.kv_transfer_bytes,
            "deferred writeback bytes were dropped on the admission-path exit"
        );
    }

    #[test]
    fn deferred_bytes_and_byte_steps_are_distinct_metrics() {
        // Bytes counts each deferred byte once; byte·steps counts every
        // carry, so a multi-step backlog makes it strictly larger.
        let wl = WorkloadConfig::long_sequence(2, 8000, 50, 7).generate();
        let r = SimServingEngine::new(writeback_heavy_cfg(Some(1.0))).run(wl).unwrap();
        assert!(r.slo_deferred_bytes > 0);
        assert!(
            r.slo_deferred_byte_steps > r.slo_deferred_bytes,
            "carried bytes must be re-counted per step: {} <= {}",
            r.slo_deferred_byte_steps,
            r.slo_deferred_bytes
        );
    }

    #[test]
    fn steady_state_decode_amortises_compilation() {
        // One long decode: the NSA selection shifts only at block
        // boundaries, so after warmup almost every step hits the
        // shape-keyed compile cache.
        let mut eng = SimServingEngine::new(EngineConfig::hierarchical(hw(), small_model()));
        eng.enqueue(req(0, 0.0, 8192, 600));
        while eng.step(&FabricPressure::NONE).unwrap() {}
        let r = eng.report();
        assert!(r.compile_cache_misses > 0, "nothing compiled");
        let rate = r.compile_cache_hit_rate();
        assert!(rate >= 0.9, "steady-state decode hit rate {rate} < 0.9");
    }

    #[test]
    fn compiled_path_matches_analytic_oracle_byte_totals() {
        // The compiled step-graph path and the retired analytic oracle
        // must agree on every byte that crosses the device boundary.
        let wl = WorkloadConfig::long_sequence(3, 6000, 40, 11).generate();
        for slo in [None, Some(1.0), Some(5_000.0)] {
            let compiled = SimServingEngine::new(EngineConfig {
                decode_slo_us: slo,
                ..EngineConfig::hierarchical(hw(), small_model())
            })
            .run(wl.clone())
            .unwrap();
            let oracle = SimServingEngine::new(EngineConfig {
                decode_slo_us: slo,
                analytic_oracle: true,
                ..EngineConfig::hierarchical(hw(), small_model())
            })
            .run(wl.clone())
            .unwrap();
            assert_eq!(compiled.kv_transfer_bytes, oracle.kv_transfer_bytes, "slo {slo:?}");
            assert_eq!(compiled.tokens_generated, oracle.tokens_generated);
            assert_eq!(compiled.rejected_requests, oracle.rejected_requests);
            assert!(compiled.compile_cache_misses > 0);
            assert_eq!(oracle.compile_cache_misses, 0, "oracle must not compile");
        }
    }

    #[test]
    fn fabric_pressure_stretches_exposed_transfers() {
        // The same offload workload under 2x fabric contention must show
        // more exposed transfer time and attribute the delta to the
        // fabric, while NONE reports zero fabric stall.
        let wl = WorkloadConfig::long_sequence(2, 8000, 50, 7).generate();
        let free = SimServingEngine::new(EngineConfig::hierarchical(hw(), small_model()))
            .run(wl.clone())
            .unwrap();
        assert_eq!(free.fabric_stall_us, 0.0);
        let mut eng = SimServingEngine::new(EngineConfig::hierarchical(hw(), small_model()));
        for r in wl {
            eng.enqueue(r);
        }
        let contended =
            FabricPressure { d2r_slowdown: 2.0, r2d_slowdown: 2.0, peer_slowdown: 1.0 };
        while eng.step(&contended).unwrap() {}
        let slow = eng.report();
        assert!(
            slow.exposed_transfer_us > free.exposed_transfer_us,
            "contention must expose more transfer time: {} <= {}",
            slow.exposed_transfer_us,
            free.exposed_transfer_us
        );
        assert!(slow.fabric_stall_us > 0.0);
    }
}
