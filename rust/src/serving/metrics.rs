//! Latency/throughput metrics for serving runs (the rows of Tables 3–6).

/// Aggregate statistics over a set of samples.
#[derive(Debug, Clone, Default)]
pub struct Stats {
    pub mean: f64,
    pub p50: f64,
    pub p99: f64,
    pub max: f64,
    pub n: usize,
}

pub fn stats(samples: &[f64]) -> Stats {
    if samples.is_empty() {
        return Stats::default();
    }
    let mut s = samples.to_vec();
    s.sort_by(|a, b| a.total_cmp(b));
    let pct = |q: f64| s[((s.len() as f64 - 1.0) * q).round() as usize];
    Stats {
        mean: s.iter().sum::<f64>() / s.len() as f64,
        p50: pct(0.5),
        p99: pct(0.99),
        max: *s.last().unwrap(),
        n: s.len(),
    }
}

/// Full report from a serving run: everything the paper's inference tables
/// print.
#[derive(Debug, Clone, Default)]
pub struct ServingReport {
    pub prefill_latency_us: Stats,
    /// Per-token decode latency.
    pub decode_per_token_us: Stats,
    pub e2e_latency_us: Stats,
    pub total_time_us: f64,
    pub tokens_generated: u64,
    pub throughput_tok_per_s: f64,
    /// Peak device memory across weights + activations + KV (bytes).
    pub peak_device_bytes: u64,
    pub defrag_events: u64,
    pub defrag_stall_us: f64,
    /// Exposed (non-overlapped) KV transfer time (us).
    pub exposed_transfer_us: f64,
    /// Extra exposed time attributable to fabric contention alone: the
    /// gap between contended and free-fabric exposure (us).
    pub fabric_stall_us: f64,
    /// Total KV transfer volume (bytes).
    pub kv_transfer_bytes: u64,
    pub rejected_requests: u64,
    /// Preemption events (sequences evicted mid-decode and requeued for
    /// recompute re-prefill; a request may contribute several).
    pub preempted_events: u64,
    /// Writeback bytes the decode SLO throttle deferred at least once —
    /// each byte counts exactly once, on its first deferral (0 when no
    /// `decode_slo_us` is configured).
    pub slo_deferred_bytes: u64,
    /// Time-weighted deferral: a byte carried in the backlog across k
    /// decode steps counts k times (the metric `slo_deferred_bytes`
    /// conflated before it was split in two).
    pub slo_deferred_byte_steps: u64,
    /// Longest single decode iteration (us) — what a decode SLO bounds.
    pub decode_step_us_max: f64,
    /// Step-graph compile-cache hits (compiled engines; 0 for the
    /// baseline and the analytic oracle).
    pub compile_cache_hits: u64,
    /// Step-graph compile-cache misses (actual compiles).
    pub compile_cache_misses: u64,
    /// Wall-clock spent compiling step graphs on cache misses (us). Hits
    /// are free; this is where session-pipeline throughput regressions
    /// surface in serving runs.
    pub compile_us_total: f64,
    /// Longest single step-graph compile (us).
    pub compile_us_max: f64,
    /// Transfers the step compiler split into chunked (partial-tensor)
    /// transfers.
    pub chunk_splits: u64,
    /// Prompt KV blocks served from the shared prefix cache instead of
    /// being recomputed by prefill (admission-time hits on the
    /// cluster-wide prefix index).
    pub prefix_hit_blocks: u64,
    /// Prefill FLOPs the prefix hits avoided (the tokens those blocks
    /// cover, times the model's per-token prefill cost).
    pub prefill_flops_saved: f64,
    /// Pool bytes deduplicated by prefix sharing: admissions that attached
    /// to a resident shared block instead of reserving new capacity.
    pub pool_bytes_deduped: u64,
    /// Bytes fetched from tiers *below* the pool (demoted prefix blocks
    /// touched by prefill or decode). 0 on untiered setups.
    pub cold_fetch_bytes: u64,
    /// Bytes read from borrowed peer HBM — KV traffic the harvested
    /// middle tier served instead of the pool fabric (peer hits).
    pub peer_fetch_bytes: u64,
    /// Bytes written into borrowed peer HBM (admission writebacks and
    /// decode-tail stores that skipped the pool).
    pub peer_store_bytes: u64,
    /// Peak bytes of this engine's KV homed at peers at any instant.
    pub peer_kv_bytes_peak: u64,
    /// Bytes this engine demoted peer→pool when lenders revoked.
    pub peer_revoked_bytes: u64,
    /// Device-residency curve: (time us, device bytes) samples taken at
    /// every admission/decode boundary, non-decreasing in time.
    pub residency: Vec<(f64, u64)>,
}

/// Hit rate in [0, 1]; 0 when nothing was looked up. Shared by the
/// engine- and cluster-level compile-cache reports.
pub(crate) fn hit_rate(hits: u64, misses: u64) -> f64 {
    let total = hits + misses;
    if total == 0 {
        0.0
    } else {
        hits as f64 / total as f64
    }
}

impl ServingReport {
    /// Step-graph compile-cache hit rate in [0, 1] (0 when nothing
    /// compiled — baseline or oracle engines).
    pub fn compile_cache_hit_rate(&self) -> f64 {
        hit_rate(self.compile_cache_hits, self.compile_cache_misses)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_of_known_samples() {
        let s = stats(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.n, 5);
    }

    #[test]
    fn stats_empty_is_zero() {
        let s = stats(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn p99_tracks_tail() {
        // 10% of samples are slow: p99 must land in the slow mass.
        let mut v = vec![1.0; 90];
        v.extend(vec![100.0; 10]);
        let s = stats(&v);
        assert_eq!(s.p99, 100.0);
        assert!(s.p50 < 2.0);
        assert_eq!(s.max, 100.0);
    }
}
