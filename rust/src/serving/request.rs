//! Requests and workload generation for the serving evaluation.

use crate::util::rng::Rng;

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Arrival time (us since epoch of the run).
    pub arrival_us: f64,
    pub prompt_tokens: usize,
    pub gen_tokens: usize,
}

/// Lifecycle timestamps filled in by the engine.
#[derive(Debug, Clone, Default)]
pub struct RequestTiming {
    pub prefill_start_us: f64,
    pub prefill_end_us: f64,
    /// First generated token time (== prefill_end in this engine).
    pub first_token_us: f64,
    pub done_us: f64,
}

impl RequestTiming {
    pub fn prefill_latency_us(&self, arrival: f64) -> f64 {
        self.prefill_end_us - arrival
    }
    pub fn e2e_latency_us(&self, arrival: f64) -> f64 {
        self.done_us - arrival
    }
    pub fn decode_time_us(&self) -> f64 {
        self.done_us - self.prefill_end_us
    }
}

/// Workload shapes used by the paper's inference experiments.
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    pub n_requests: usize,
    /// Mean inter-arrival time (us). 0 = all at t=0 (closed batch).
    pub mean_interarrival_us: f64,
    pub prompt_min: usize,
    pub prompt_max: usize,
    pub gen_min: usize,
    pub gen_max: usize,
    pub seed: u64,
}

impl WorkloadConfig {
    /// Long-sequence near-capacity workload (§7.3.2 / Table 4).
    pub fn long_sequence(n: usize, prompt: usize, gen: usize, seed: u64) -> Self {
        Self {
            n_requests: n,
            mean_interarrival_us: 0.0,
            prompt_min: prompt,
            prompt_max: prompt,
            gen_min: gen,
            gen_max: gen,
            seed,
        }
    }

    /// Typical short-sequence workload (§7.3.3 / Table 5).
    pub fn short_sequence(n: usize, seed: u64) -> Self {
        Self {
            n_requests: n,
            mean_interarrival_us: 0.0,
            prompt_min: 512,
            prompt_max: 2048,
            gen_min: 64,
            gen_max: 256,
            seed,
        }
    }

    pub fn generate(&self) -> Vec<Request> {
        let mut rng = Rng::new(self.seed);
        let mut t = 0.0f64;
        (0..self.n_requests)
            .map(|i| {
                if self.mean_interarrival_us > 0.0 {
                    t += rng.exponential(self.mean_interarrival_us);
                }
                Request {
                    id: i as u64,
                    arrival_us: t,
                    prompt_tokens: if self.prompt_min == self.prompt_max {
                        self.prompt_min
                    } else {
                        rng.usize(self.prompt_min, self.prompt_max + 1)
                    },
                    gen_tokens: if self.gen_min == self.gen_max {
                        self.gen_min
                    } else {
                        rng.usize(self.gen_min, self.gen_max + 1)
                    },
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let cfg = WorkloadConfig::short_sequence(20, 42);
        let a = cfg.generate();
        let b = cfg.generate();
        assert_eq!(a.len(), 20);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt_tokens, y.prompt_tokens);
            assert_eq!(x.gen_tokens, y.gen_tokens);
        }
    }

    #[test]
    fn bounds_respected() {
        let cfg = WorkloadConfig::short_sequence(200, 7);
        for r in cfg.generate() {
            assert!((512..=2048).contains(&r.prompt_tokens));
            assert!((64..=256).contains(&r.gen_tokens));
        }
    }

    #[test]
    fn long_sequence_is_fixed_shape() {
        let cfg = WorkloadConfig::long_sequence(4, 60_000, 1000, 1);
        for r in cfg.generate() {
            assert_eq!(r.prompt_tokens, 60_000);
            assert_eq!(r.gen_tokens, 1000);
            assert_eq!(r.arrival_us, 0.0);
        }
    }

    #[test]
    fn arrivals_monotone_with_poisson() {
        let cfg = WorkloadConfig {
            mean_interarrival_us: 1000.0,
            ..WorkloadConfig::short_sequence(50, 3)
        };
        let reqs = cfg.generate();
        for w in reqs.windows(2) {
            assert!(w[1].arrival_us >= w[0].arrival_us);
        }
    }
}
