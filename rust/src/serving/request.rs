//! Requests and workload generation for the serving evaluation.

use crate::kvcache::prefix::chain_hash;
use crate::util::rng::Rng;

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Arrival time (us since epoch of the run).
    pub arrival_us: f64,
    pub prompt_tokens: usize,
    pub gen_tokens: usize,
    /// Chain hashes of the prompt's leading *full* KV blocks (`hashes[i]`
    /// commits to blocks `0..=i`). Empty = no shareable prefix, the
    /// admission path stays cold. Stamped by the workload generator from
    /// the request's template; stable across runs and replicas, which is
    /// what makes the prefix cache cluster-wide.
    pub block_hashes: Vec<u64>,
}

/// Lifecycle timestamps filled in by the engine.
#[derive(Debug, Clone, Default)]
pub struct RequestTiming {
    pub prefill_start_us: f64,
    pub prefill_end_us: f64,
    /// First generated token time (== prefill_end in this engine).
    pub first_token_us: f64,
    pub done_us: f64,
}

impl RequestTiming {
    pub fn prefill_latency_us(&self, arrival: f64) -> f64 {
        self.prefill_end_us - arrival
    }
    pub fn e2e_latency_us(&self, arrival: f64) -> f64 {
        self.done_us - arrival
    }
    pub fn decode_time_us(&self) -> f64 {
        self.done_us - self.prefill_end_us
    }
}

/// Workload shapes used by the paper's inference experiments.
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    pub n_requests: usize,
    /// Mean inter-arrival time (us). 0 = all at t=0 (closed batch).
    pub mean_interarrival_us: f64,
    pub prompt_min: usize,
    pub prompt_max: usize,
    pub gen_min: usize,
    pub gen_max: usize,
    pub seed: u64,
    /// Fraction of requests that open with a shared template prefix
    /// (system prompt / few-shot scaffold / multi-turn history). 0 = the
    /// legacy unique-prompt trace, bit-identical to before this knob.
    pub prefix_share_ratio: f64,
    /// Distinct templates the shared requests draw from uniformly.
    pub prefix_templates: usize,
    /// Tokens in each shared template prefix (prepended to the drawn
    /// prompt length).
    pub prefix_tokens: usize,
    /// Tokens per KV block used to hash the prefix. Must match the
    /// serving engine's `NsaConfig::block_tokens` for hits to land.
    pub prefix_block_tokens: usize,
    /// Zipf exponent for the template draw. 0 = uniform (legacy,
    /// bit-identical trace); s > 0 skews reuse toward low-numbered
    /// templates (template `k` drawn with weight `1/(k+1)^s`), the
    /// access pattern that makes demotion-first tiering pay off: hot
    /// templates stay in the pool while the long zipf tail cools into
    /// DRAM/CXL/SSD.
    pub prefix_zipf_s: f64,
    /// Number of burst phases in the trace. The arrival timeline is cut
    /// into `2 × burst_phases` equal request segments alternating
    /// calm/burst; burst segments draw their inter-arrival gaps with the
    /// mean shrunk by [`burst_factor`](Self::burst_factor). 0 = the
    /// stationary Poisson process (legacy trace, bit-identical — the rng
    /// draw stream is unchanged, only the exponential's mean parameter
    /// moves).
    pub burst_phases: usize,
    /// Inter-arrival compression during a burst phase (≥ 1; 1 = no-op).
    pub burst_factor: f64,
}

impl WorkloadConfig {
    /// Long-sequence near-capacity workload (§7.3.2 / Table 4).
    pub fn long_sequence(n: usize, prompt: usize, gen: usize, seed: u64) -> Self {
        Self {
            n_requests: n,
            mean_interarrival_us: 0.0,
            prompt_min: prompt,
            prompt_max: prompt,
            gen_min: gen,
            gen_max: gen,
            seed,
            prefix_share_ratio: 0.0,
            prefix_templates: 0,
            prefix_tokens: 0,
            prefix_block_tokens: 64,
            prefix_zipf_s: 0.0,
            burst_phases: 0,
            burst_factor: 1.0,
        }
    }

    /// Typical short-sequence workload (§7.3.3 / Table 5).
    pub fn short_sequence(n: usize, seed: u64) -> Self {
        Self {
            n_requests: n,
            mean_interarrival_us: 0.0,
            prompt_min: 512,
            prompt_max: 2048,
            gen_min: 64,
            gen_max: 256,
            seed,
            prefix_share_ratio: 0.0,
            prefix_templates: 0,
            prefix_tokens: 0,
            prefix_block_tokens: 64,
            prefix_zipf_s: 0.0,
            burst_phases: 0,
            burst_factor: 1.0,
        }
    }

    /// Long-context agentic trace for the tier-hierarchy evaluation:
    /// 512k–1M-token prompts whose first 64k tokens come from a shared
    /// template pool reused with zipfian skew (`s = 1.1`). A handful of
    /// hot templates dominate while the tail is touched rarely — exactly
    /// the distribution where the prefix cache wants to *demote* cold
    /// chains below the pool instead of evicting them.
    pub fn long_context(n: usize, seed: u64) -> Self {
        Self {
            prompt_min: 512 * 1024,
            prompt_max: 1024 * 1024,
            gen_min: 128,
            gen_max: 512,
            prefix_share_ratio: 0.9,
            prefix_templates: 16,
            prefix_tokens: 64 * 1024,
            prefix_block_tokens: 64,
            prefix_zipf_s: 1.1,
            ..Self::short_sequence(n, seed)
        }
    }

    /// Shared-system-prompt / multi-turn trace (the prefix-cache
    /// workload): `share` of the requests open with one of `templates`
    /// fixed prefixes of `prefix_tokens` tokens, hashed per
    /// `block_tokens`-token block and stamped into
    /// [`Request::block_hashes`].
    pub fn shared_prefix(
        n: usize,
        share: f64,
        templates: usize,
        prefix_tokens: usize,
        block_tokens: usize,
        seed: u64,
    ) -> Self {
        Self {
            prefix_share_ratio: share.clamp(0.0, 1.0),
            prefix_templates: templates.max(1),
            prefix_tokens,
            prefix_block_tokens: block_tokens.max(1),
            ..Self::short_sequence(n, seed)
        }
    }

    /// Skewed + bursty open-loop trace for the peer-harvest evaluation:
    /// shared templates drawn with zipfian skew (prefix affinity
    /// concentrates the hot templates on a few replicas) and arrivals
    /// alternating calm and burst phases (`factor`× compressed gaps).
    /// The load asymmetry this produces is what opens lender windows on
    /// the cold replicas and spikes the hot ones into revocation.
    pub fn skewed_bursty(
        n: usize,
        mean_interarrival_us: f64,
        phases: usize,
        factor: f64,
        seed: u64,
    ) -> Self {
        Self {
            mean_interarrival_us,
            burst_phases: phases,
            burst_factor: factor.max(1.0),
            prefix_share_ratio: 0.8,
            prefix_templates: 8,
            prefix_tokens: 512,
            prefix_block_tokens: 64,
            prefix_zipf_s: 1.2,
            ..Self::short_sequence(n, seed)
        }
    }

    /// True iff request index `i` of `n` falls in a burst segment of the
    /// alternating calm/burst timeline.
    fn in_burst(&self, i: usize) -> bool {
        if self.burst_phases == 0 || self.burst_factor <= 1.0 {
            return false;
        }
        let seg = (self.n_requests / (2 * self.burst_phases)).max(1);
        (i / seg) % 2 == 1
    }

    pub fn generate(&self) -> Vec<Request> {
        let mut rng = Rng::new(self.seed);
        let mut t = 0.0f64;
        (0..self.n_requests)
            .map(|i| {
                if self.mean_interarrival_us > 0.0 {
                    let mean = if self.in_burst(i) {
                        self.mean_interarrival_us / self.burst_factor
                    } else {
                        self.mean_interarrival_us
                    };
                    t += rng.exponential(mean);
                }
                let mut prompt_tokens = if self.prompt_min == self.prompt_max {
                    self.prompt_min
                } else {
                    rng.usize(self.prompt_min, self.prompt_max + 1)
                };
                let gen_tokens = if self.gen_min == self.gen_max {
                    self.gen_min
                } else {
                    rng.usize(self.gen_min, self.gen_max + 1)
                };
                // Shared-prefix draws come *after* the legacy draws so a
                // zero share ratio leaves the trace bit-identical to the
                // pre-prefix generator.
                let mut block_hashes = Vec::new();
                if self.prefix_share_ratio > 0.0
                    && self.prefix_tokens >= self.prefix_block_tokens
                    && rng.next_f64() < self.prefix_share_ratio
                {
                    let templates = self.prefix_templates.max(1);
                    let template = if self.prefix_zipf_s > 0.0 {
                        zipf_draw(&mut rng, templates, self.prefix_zipf_s)
                    } else {
                        rng.gen_range(0, templates as u64)
                    };
                    block_hashes = template_prefix_hashes(
                        template,
                        self.prefix_tokens,
                        self.prefix_block_tokens,
                    );
                    prompt_tokens += self.prefix_tokens;
                }
                Request {
                    id: i as u64,
                    arrival_us: t,
                    prompt_tokens,
                    gen_tokens,
                    block_hashes,
                }
            })
            .collect()
    }
}

/// One zipfian draw over `n` templates: template `k` with probability
/// proportional to `1/(k+1)^s`, by inverse CDF. `n` is small (template
/// pools are tens, not millions), so the O(n) walk is fine.
fn zipf_draw(rng: &mut Rng, n: usize, s: f64) -> u64 {
    let norm: f64 = (0..n).map(|k| 1.0 / ((k + 1) as f64).powf(s)).sum();
    let mut u = rng.next_f64() * norm;
    for k in 0..n {
        u -= 1.0 / ((k + 1) as f64).powf(s);
        if u <= 0.0 {
            return k as u64;
        }
    }
    (n - 1) as u64
}

/// Chain hashes of template `template`'s prefix: one per *full*
/// `block_tokens`-token block of its `prefix_tokens` tokens. Pure in its
/// arguments, so every generator (and every cluster replica) derives the
/// same hashes for the same template.
pub fn template_prefix_hashes(
    template: u64,
    prefix_tokens: usize,
    block_tokens: usize,
) -> Vec<u64> {
    let full = prefix_tokens / block_tokens.max(1);
    let mut h = 0xC0FF_EE00u64 ^ template.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    let mut v = Vec::with_capacity(full);
    for i in 0..full {
        h = chain_hash(h, i as u64);
        v.push(h);
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let cfg = WorkloadConfig::short_sequence(20, 42);
        let a = cfg.generate();
        let b = cfg.generate();
        assert_eq!(a.len(), 20);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt_tokens, y.prompt_tokens);
            assert_eq!(x.gen_tokens, y.gen_tokens);
        }
    }

    #[test]
    fn bounds_respected() {
        let cfg = WorkloadConfig::short_sequence(200, 7);
        for r in cfg.generate() {
            assert!((512..=2048).contains(&r.prompt_tokens));
            assert!((64..=256).contains(&r.gen_tokens));
        }
    }

    #[test]
    fn long_sequence_is_fixed_shape() {
        let cfg = WorkloadConfig::long_sequence(4, 60_000, 1000, 1);
        for r in cfg.generate() {
            assert_eq!(r.prompt_tokens, 60_000);
            assert_eq!(r.gen_tokens, 1000);
            assert_eq!(r.arrival_us, 0.0);
        }
    }

    #[test]
    fn unique_prompt_traces_carry_no_hashes() {
        for r in WorkloadConfig::short_sequence(50, 11).generate() {
            assert!(r.block_hashes.is_empty());
        }
    }

    #[test]
    fn zero_share_ratio_is_bit_identical_to_legacy_trace() {
        let legacy = WorkloadConfig::short_sequence(60, 21).generate();
        let zeroed = WorkloadConfig::shared_prefix(60, 0.0, 4, 1024, 64, 21).generate();
        for (a, b) in legacy.iter().zip(&zeroed) {
            assert_eq!(a.prompt_tokens, b.prompt_tokens);
            assert_eq!(a.gen_tokens, b.gen_tokens);
            assert_eq!(a.arrival_us, b.arrival_us);
            assert!(b.block_hashes.is_empty());
        }
    }

    #[test]
    fn shared_prefix_trace_shape() {
        let cfg = WorkloadConfig::shared_prefix(200, 0.75, 4, 1024, 64, 5);
        let reqs = cfg.generate();
        let shared: Vec<&Request> =
            reqs.iter().filter(|r| !r.block_hashes.is_empty()).collect();
        // ~75% of 200 share a template (deterministic for the seed).
        assert!(
            (120..=180).contains(&shared.len()),
            "share count {} off the 0.75 ratio",
            shared.len()
        );
        for r in &shared {
            assert_eq!(r.block_hashes.len(), 1024 / 64);
            // Template prefix is prepended to the drawn prompt.
            assert!(r.prompt_tokens >= 1024 + 512);
        }
        // Exactly `templates` distinct chains, and requests of the same
        // template carry the identical chain (the cache-hit condition).
        let mut roots: Vec<u64> = shared.iter().map(|r| r.block_hashes[0]).collect();
        roots.sort_unstable();
        roots.dedup();
        assert_eq!(roots.len(), 4);
        for a in &shared {
            for b in &shared {
                if a.block_hashes[0] == b.block_hashes[0] {
                    assert_eq!(a.block_hashes, b.block_hashes);
                }
            }
        }
        // And the chains are reproducible from the template id alone.
        assert!(shared
            .iter()
            .any(|r| r.block_hashes == template_prefix_hashes(0, 1024, 64)));
    }

    #[test]
    fn long_context_trace_is_zipf_skewed() {
        let cfg = WorkloadConfig::long_context(300, 19);
        let reqs = cfg.generate();
        let mut counts = vec![0usize; cfg.prefix_templates];
        let mut shared = 0usize;
        for r in &reqs {
            if r.block_hashes.is_empty() {
                continue;
            }
            shared += 1;
            assert_eq!(r.block_hashes.len(), 64 * 1024 / 64);
            assert!(r.prompt_tokens >= 512 * 1024 + 64 * 1024);
            assert!(r.prompt_tokens <= 1024 * 1024 + 64 * 1024);
            // Map the chain back to its template id via the pure hash fn.
            let t = (0..cfg.prefix_templates)
                .find(|&t| {
                    template_prefix_hashes(t as u64, 64 * 1024, 64)[0] == r.block_hashes[0]
                })
                .expect("chain must come from a known template");
            counts[t] += 1;
        }
        // ~90% share ratio.
        assert!(shared > 240, "share count {shared} off the 0.9 ratio");
        // Zipf head dominates: template 0 beats the tail's average by a
        // wide margin (uniform would give each ~shared/16).
        let tail_avg = counts[8..].iter().sum::<usize>() as f64 / 8.0;
        assert!(
            counts[0] as f64 > 3.0 * tail_avg.max(1.0),
            "head {} vs tail avg {tail_avg}",
            counts[0]
        );
    }

    #[test]
    fn zipf_draw_zero_config_matches_uniform_path() {
        // prefix_zipf_s == 0.0 must take the legacy uniform branch so the
        // shared_prefix trace stays bit-identical to earlier releases.
        let a = WorkloadConfig::shared_prefix(40, 0.5, 4, 512, 64, 33).generate();
        let b = WorkloadConfig {
            prefix_zipf_s: 0.0,
            ..WorkloadConfig::shared_prefix(40, 0.5, 4, 512, 64, 33)
        }
        .generate();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.block_hashes, y.block_hashes);
            assert_eq!(x.prompt_tokens, y.prompt_tokens);
        }
    }

    #[test]
    fn arrivals_monotone_with_poisson() {
        let cfg = WorkloadConfig {
            mean_interarrival_us: 1000.0,
            ..WorkloadConfig::short_sequence(50, 3)
        };
        let reqs = cfg.generate();
        for w in reqs.windows(2) {
            assert!(w[1].arrival_us >= w[0].arrival_us);
        }
    }

    #[test]
    fn zero_burst_phases_is_bit_identical_to_stationary_trace() {
        let calm = WorkloadConfig {
            mean_interarrival_us: 1000.0,
            ..WorkloadConfig::short_sequence(80, 9)
        };
        let zeroed = WorkloadConfig { burst_phases: 0, burst_factor: 4.0, ..calm.clone() };
        for (a, b) in calm.generate().iter().zip(&zeroed.generate()) {
            assert_eq!(a.arrival_us, b.arrival_us);
            assert_eq!(a.prompt_tokens, b.prompt_tokens);
            assert_eq!(a.gen_tokens, b.gen_tokens);
        }
    }

    #[test]
    fn burst_phases_compress_arrivals_without_touching_shapes() {
        let calm = WorkloadConfig {
            mean_interarrival_us: 1000.0,
            ..WorkloadConfig::short_sequence(120, 9)
        };
        let bursty = WorkloadConfig { burst_phases: 2, burst_factor: 8.0, ..calm.clone() };
        let a = calm.generate();
        let b = bursty.generate();
        // Same rng stream: request shapes identical, only spacing moves.
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt_tokens, y.prompt_tokens);
            assert_eq!(x.gen_tokens, y.gen_tokens);
        }
        assert!(b.last().unwrap().arrival_us < a.last().unwrap().arrival_us);
        // Segment layout: 120 requests / (2 phases × 2) = 30 per segment,
        // odd segments bursting. Mean gap inside a burst segment must sit
        // far below the calm segments' (8× compression vs ~1.9× sampling
        // noise at n=30).
        let mean_gap = |r: &[Request], lo: usize, hi: usize| {
            (lo + 1..hi).map(|i| r[i].arrival_us - r[i - 1].arrival_us).sum::<f64>()
                / (hi - lo - 1) as f64
        };
        let calm_gap = mean_gap(&b, 0, 30);
        let burst_gap = mean_gap(&b, 30, 60);
        assert!(
            burst_gap < calm_gap / 2.0,
            "burst gap {burst_gap} !< half the calm gap {calm_gap}"
        );
    }

    #[test]
    fn skewed_bursty_trace_is_skewed_and_bursty() {
        let cfg = WorkloadConfig::skewed_bursty(240, 500.0, 2, 8.0, 77);
        let reqs = cfg.generate();
        assert_eq!(reqs.len(), 240);
        // Zipf-skewed template reuse: template 0's chain dominates.
        let hot = template_prefix_hashes(0, cfg.prefix_tokens, cfg.prefix_block_tokens);
        let shared = reqs.iter().filter(|r| !r.block_hashes.is_empty()).count();
        let on_hot = reqs.iter().filter(|r| r.block_hashes == hot).count();
        assert!(shared > 150, "share count {shared} off the 0.8 ratio");
        assert!(
            on_hot as f64 > 2.0 * shared as f64 / cfg.prefix_templates as f64,
            "hot template {} not dominant over uniform share {}",
            on_hot,
            shared / cfg.prefix_templates
        );
        // Bursts present: arrivals monotone but not stationary.
        for w in reqs.windows(2) {
            assert!(w[1].arrival_us >= w[0].arrival_us);
        }
        let stationary =
            WorkloadConfig { burst_phases: 0, ..cfg.clone() }.generate();
        assert!(reqs.last().unwrap().arrival_us < stationary.last().unwrap().arrival_us);
    }
}
