//! Serving stack (the paper's inference case study, §5.2 / §7.3): request
//! router, workload generation, continuous-batching engine with KV-cache
//! residency policies, and the metrics the inference tables report.

mod engine;
mod metrics;
mod request;
mod router;

pub use engine::{EngineConfig, ModelCost, SimServingEngine};
pub use metrics::{stats, ServingReport, Stats};
pub use request::{Request, RequestTiming, WorkloadConfig};
pub use router::{RoutePolicy, Router};
