//! Serving stack (the paper's inference case study, §5.2 / §7.3): request
//! router, workload generation, continuous-batching engine with KV-cache
//! residency policies, and the metrics the inference tables report.
//!
//! The unit of simulation is the *cluster*: [`SimServingEngine`] is a
//! resumable stepper (it never owns global time), and [`SimCluster`]
//! advances N replicas through one event loop while they share a
//! capacity-accounted remote pool and a bandwidth-contended device↔pool
//! fabric — see the [`cluster`] module docs for the contract.

pub mod cluster;
mod engine;
mod metrics;
mod request;
mod router;

pub use cluster::{ClusterConfig, ClusterReport, SimCluster};
pub use engine::{EngineConfig, FabricPressure, ModelCost, SimServingEngine};
pub use metrics::{stats, ServingReport, Stats};
pub use request::{Request, RequestTiming, WorkloadConfig};
pub use router::{ReplicaView, RoutePolicy, Router};
