//! Serving stack (the paper's inference case study, §5.2 / §7.3): request
//! router, workload generation, continuous-batching engine with KV-cache
//! residency policies, and the metrics the inference tables report.
//!
//! # The compiled step-graph flow
//!
//! Hierarchical engines do not *estimate* what the compiler would do with
//! their KV traffic — they run it. Every engine step flows through three
//! stages:
//!
//! ```text
//!  lowering            session pipeline                  SimResult feedback
//!  ────────            ────────────────                  ─────────────────
//!  prefill/decode/     Compiler::empty(hw)               step time   = makespan
//!  drain  ──────────▶    .pass(ExecOrderPass)      ───▶  exposed     = makespan
//!  (compute, KV          .pass(SloThrottle)               − compute − host
//!  fetch Prefetch,       .pass(Elide…)                   deferred d2r = spill
//!  KV writeback          .slo_us(decode_slo)              bytes → backlog
//!  Store, host tail)     .verify(true)                   → ServingReport
//! ```
//!
//! The lowering lives in [`step_graph`]: the step's compute, the NSA
//! working-set fetch (`Prefetch`), the writeback (`Store`, flagged
//! deferrable under a decode SLO) and the serialising host tail become IR
//! nodes, and the same pass pipeline the training path uses schedules
//! them. Under `EngineConfig::decode_slo_us` the throttle's spill rewrite
//! sheds writeback bytes that would break the budget; the engine carries
//! them in a backlog that later steps (and a final compiled drain step)
//! conserve to the pool.
//!
//! Compilation is memoised on the step *shape* —
//! `(phase, batch_bucket, kv_bytes_bucket)` plus cost-model inputs
//! ([`step_graph::StepKey`]) — so steady-state decode, whose NSA selection
//! only shifts at block boundaries, amortises to a hash lookup
//! (`ServingReport::compile_cache_hit_rate`, ≥ 90 % in the
//! `compiled_serving` bench). The retired analytic cost model survives
//! only as a conservation oracle (`EngineConfig::analytic_oracle`) that
//! the P12 proptest cross-checks byte totals against.
//!
//! # Cluster simulation
//!
//! The unit of simulation is the *cluster*: [`SimServingEngine`] is a
//! resumable stepper (it never owns global time), and [`SimCluster`]
//! advances N replicas through one event loop while they share a
//! chunk-granular, capacity-accounted remote pool and a
//! bandwidth-contended device↔pool fabric — see the [`cluster`] module
//! docs for the contract. Fabric pressure reaches the step compiler as
//! per-direction bandwidth derating and is part of the compile-cache key.
//!
//! # Cluster-wide prefix cache
//!
//! Requests may carry [`Request::block_hashes`] (stamped by the workload
//! generator's shared-template trace, `WorkloadConfig::shared_prefix`).
//! Admission then consults the shared [`crate::kvcache::PrefixIndex`]:
//! resident prompt blocks attach to the pool's refcounted shared ledger
//! instead of being recomputed, prefill runs over the un-shared suffix
//! only, and the hit blocks are lowered as compiled pool→device
//! `Prefetch` chunks the schedule hides under the suffix compute. The
//! router keeps hot templates on their warm replica when load allows
//! (prefix affinity). `ServingReport::prefix_hit_blocks`,
//! `prefill_flops_saved` and `pool_bytes_deduped` quantify the win.
//!
//! # Peer-HBM harvesting
//!
//! With [`cluster::PeerHarvestConfig`] set, idle replicas *lend* spare
//! HBM as a revocable middle tier between local HBM and the pool
//! (brokered by [`crate::memory::LeaseLedger`], costed on the
//! [`crate::sim::PeerLink`] device↔device edge). A loaded borrower homes
//! its private KV blocks at `Tier::Peer(lender)`; the compiled step graph
//! lowers their fetches and writebacks as first-class `Prefetch`/`Store`
//! cache ops on that edge, visible to the verifier and TransferSan. The
//! lender/borrower contract is: lenders open and close with their own
//! live load (hysteresis between the two token thresholds); a lender
//! load spike **revokes** — every borrowed block demotes to the pool,
//! reserve-destination-first and exactly once, so conservation holds
//! through revocation and nothing is ever dropped. The router avoids
//! live lenders within a load bucket so leases survive when an
//! equally-good placement exists. `ServingReport::peer_fetch_bytes` /
//! `ClusterReport::borrowed_bytes_peak` / `peer_revocations` quantify
//! the protocol.

pub mod cluster;
mod engine;
mod metrics;
mod request;
mod router;
pub mod step_graph;

pub use cluster::{ClusterConfig, ClusterReport, PeerHarvestConfig, SimCluster};
pub use engine::{EngineConfig, FabricPressure, ModelCost, SimServingEngine};
pub use metrics::{stats, ServingReport, Stats};
pub use request::{template_prefix_hashes, Request, RequestTiming, WorkloadConfig};
pub use router::{AFFINITY_SLACK, ReplicaView, RoutePolicy, Router};
pub use step_graph::{CompiledStep, StepCompiler, StepKey, StepPhase, StepSpec};
