//! Per-step KV transfer graphs: the serving engine's lowering into the
//! compiler session (the HyperOffload thesis applied to *serving*).
//!
//! Each engine step — a prefill, a batched decode iteration, or the final
//! backlog drain — is lowered into a small [`Graph`] whose nodes are the
//! step's compute, its KV fetch (`Prefetch` of the NSA-touched working-set
//! delta), its KV writeback (`Store` of the persisted tail blocks plus any
//! backlog the step attempts to drain), and the host-side sparse-block
//! processing (`HostWork` gated on everything else, §7.3.3's serialising
//! CPU term). The graph is compiled through the same [`Compiler`] session
//! the training path uses — `ExecOrder` → [`SloThrottle`] → elide, with
//! the IR verifier on — and the resulting simulation (`SimResult`) is what
//! the engine *runs*: step time is the schedule's makespan, exposed
//! transfer is what the schedule could not hide, and deferred writeback
//! bytes are whatever the throttle's spill rewrite shed past the decode
//! SLO. The engine stops estimating what the compiler would do and starts
//! running it.
//!
//! The serving throttle configuration is spill-only: prefetches are never
//! deferred (decode needs its fetched blocks now) and never split (the KV
//! manager's paged layout already moves block-granular chunks); what the
//! SLO shapes is the deferrable writeback direction, exactly as
//! SelectiveOffload prescribes. Round-trip chunking — the throttle
//! splitting a ≥128 MB Store/Prefetch round trip into partial-tensor
//! transfers — applies to compile-side graphs that *have* round trips
//! (training activations, optimizer state), see
//! [`SloThrottle`](crate::passes::SloThrottle).
//!
//! # The compile cache
//!
//! Steady-state decode repeats the same step shape over and over: the NSA
//! selection is keyed on the *block* count, so for `block_tokens − 1` out
//! of every `block_tokens` steps the fetch delta, writeback volume, batch
//! and host cost are all identical. The compiler memoises on exactly that
//! shape — a [`StepKey`] of `(phase, batch_bucket, kv_bytes_bucket)` plus
//! the cost-model inputs, where the KV buckets are the step's
//! block-granular byte totals — so a steady-state decode step compiles
//! once and afterwards amortises to one hash lookup (hit rates well above
//! 90%, asserted by the `compiled_serving` bench and the engine tests).

use std::collections::HashMap;

use crate::graph::{Graph, OpKind, Tier};
use crate::passes::{
    CompileError, Compiler, ElideRedundantTransfers, ExecOrderPass, SloThrottle,
};
use crate::sim::{simulate, HwConfig};

use super::engine::FabricPressure;

/// Which kind of engine step a graph lowers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StepPhase {
    /// Serial prefill of one request (compute + prefill-KV writeback).
    Prefill,
    /// One batched decode iteration (compute + fetch + writeback + host).
    Decode,
    /// Final drain of the SLO writeback backlog (a lone Store; nothing to
    /// hide under).
    Drain,
}

/// Everything one engine step asks the compiler to schedule.
#[derive(Debug, Clone)]
pub struct StepSpec {
    pub phase: StepPhase,
    /// Decode batch size, or prefill token count — whatever the compute
    /// cost scales with.
    pub batch: usize,
    /// Device FLOPs of the step's compute.
    pub compute_flops: f64,
    /// HBM traffic of the step's compute (weights re-read each decode).
    pub compute_bytes: u64,
    /// Remote→Device KV bytes the step must fetch (NSA working-set delta).
    pub kv_fetch_bytes: u64,
    /// Remote→Device bytes of *shared prefix* blocks a prefix-hit prefill
    /// pulls from the pool instead of recomputing (0 for decode/drain and
    /// cold prefills). Lowered as chunked pool→device `Prefetch`es the
    /// schedule overlaps with the suffix compute.
    pub prefix_fetch_bytes: u64,
    /// Device→Remote KV bytes the step wants to persist (tail blocks +
    /// any backlog drain attempt). Deferrable under a decode SLO.
    pub kv_writeback_bytes: u64,
    /// KV bytes the step must pull from *cold* tiers below the pool
    /// (DRAM/CXL/SSD-demoted prefix blocks), one entry per source tier.
    /// Empty on 2-tier configurations — the lowering is then byte-for-byte
    /// the legacy step graph. Each entry lowers as a `Prefetch` whose
    /// `src` is the cold tier, so the simulator charges the full
    /// multi-hop fabric path and TransferSan can prove the read sound.
    pub cold_fetch: Vec<(Tier, u64)>,
    /// KV bytes the step fetches from borrowed peer HBM, one entry per
    /// lender replica. Lowered as `Prefetch { src: Tier::Peer(r) }`, so
    /// the simulator costs the device↔device edge and TransferSan's
    /// `peer::revoked_read` lint guards the read. Empty without a lease.
    pub peer_fetch: Vec<(u16, u64)>,
    /// KV bytes the step persists *to* borrowed peer HBM (peer-homed
    /// tail writebacks), per lender. Lowered as
    /// `Store { dst: Tier::Peer(r) }`; never deferrable — the peer edge
    /// is the fast path, deferring it would be backwards.
    pub peer_store: Vec<(u16, u64)>,
    /// Host-side sparse-block processing (us).
    pub cpu_us: f64,
    /// Allocator defragmentation stall (us).
    pub defrag_us: f64,
    /// Per-step latency SLO handed to the throttle (decode only).
    pub slo_us: Option<f64>,
}

/// The shape-key steady-state decode amortises compilation on:
/// `(phase, batch_bucket, kv_bytes_bucket)` per the compile-cache design,
/// plus the remaining cost-model inputs (host time, compute cost, SLO,
/// fabric pressure) so a hit is guaranteed to reproduce the miss exactly.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct StepKey {
    phase: StepPhase,
    /// Decode batch (or prefill tokens) — compute cost scales with it.
    batch_bucket: u32,
    /// `(fetch, writeback)` byte totals. KV traffic is block-granular
    /// (every value is a multiple of the KV block size), so the raw totals
    /// *are* the block-quantized buckets.
    kv_bytes_bucket: (u64, u64),
    /// Shared-prefix fetch bytes (block-granular, like the KV buckets).
    prefix_bucket: u64,
    /// Per-cold-tier fetch bytes (block-granular; empty on 2-tier).
    cold_bucket: Vec<(Tier, u64)>,
    /// Per-lender peer fetch/store bytes (block-granular; empty without
    /// a lease).
    peer_bucket: (Vec<(u16, u64)>, Vec<(u16, u64)>),
    flops_bits: u64,
    compute_bytes: u64,
    host_us_bits: u64,
    slo_bits: u64,
    fabric_bits: (u64, u64, u64),
}

impl StepKey {
    fn of(spec: &StepSpec, fabric: &FabricPressure) -> Self {
        Self {
            phase: spec.phase,
            batch_bucket: spec.batch.min(u32::MAX as usize) as u32,
            kv_bytes_bucket: (spec.kv_fetch_bytes, spec.kv_writeback_bytes),
            prefix_bucket: spec.prefix_fetch_bytes,
            cold_bucket: spec.cold_fetch.clone(),
            peer_bucket: (spec.peer_fetch.clone(), spec.peer_store.clone()),
            flops_bits: spec.compute_flops.to_bits(),
            compute_bytes: spec.compute_bytes,
            host_us_bits: (spec.cpu_us + spec.defrag_us).to_bits(),
            slo_bits: spec.slo_us.map(f64::to_bits).unwrap_or(u64::MAX),
            fabric_bits: (
                fabric.d2r_slowdown.to_bits(),
                fabric.r2d_slowdown.to_bits(),
                fabric.peer_slowdown.to_bits(),
            ),
        }
    }
}

/// What a compiled step schedule tells the engine (cached per [`StepKey`];
/// identical spec → identical outcome, so a hit is a pure memoisation).
#[derive(Debug, Clone)]
pub struct CompiledStep {
    /// Makespan of the compiled schedule — the step's wall time (us).
    pub step_us: f64,
    /// Transfer time the schedule could not hide under compute/host work.
    pub exposed_us: f64,
    /// The same exposure on an uncontended fabric (`fabric_stall` =
    /// `exposed_us − exposed_free_us`).
    pub exposed_free_us: f64,
    /// Remote→Device bytes the schedule moves.
    pub moved_r2d: u64,
    /// Device→Remote bytes the schedule moves (writeback minus deferred).
    pub moved_d2r: u64,
    /// Writeback bytes the throttle's spill shed past this step's SLO —
    /// the engine carries them in its backlog.
    pub deferred_d2r: u64,
    /// Throttle rewrites committed (spills + splits + deferrals).
    pub throttled: usize,
    /// Transfers split into chunked (partial-tensor) transfers.
    pub chunk_splits: usize,
    /// True iff `SloThrottle` appeared in the step's `CompileReport`.
    pub throttle_in_report: bool,
    /// True iff TransferSan ran on the step (its peak-bound audit line is
    /// in the diagnostics) and found nothing fatal — a failed sanitize is
    /// a `CompileError`, so a cached step is always a sanitized step.
    pub sanitized: bool,
}

/// Compiles engine steps through the `Compiler` session, memoising on
/// [`StepKey`]. One per engine; `hits`/`misses` feed the serving report's
/// compile-cache hit rate.
pub struct StepCompiler {
    hw: HwConfig,
    /// If false, transfers serialise with compute (runtime-style engines):
    /// the lowering gates the step's compute on both transfers.
    overlap: bool,
    cache: HashMap<StepKey, CompiledStep>,
    pub hits: u64,
    pub misses: u64,
    /// Wall-clock spent in `compile_uncached` across all misses (us).
    /// Cache hits cost nothing; this is the compile latency the serving
    /// report surfaces so regressions in session-pipeline throughput show
    /// up in `ServingReport` rather than only in the benches.
    pub compile_us_total: f64,
    /// Longest single `compile_uncached` call (us) — the compile stall an
    /// unlucky first-of-its-shape step absorbs.
    pub compile_us_max: f64,
}

impl StepCompiler {
    pub fn new(hw: HwConfig, overlap: bool) -> Self {
        Self {
            hw,
            overlap,
            cache: HashMap::new(),
            hits: 0,
            misses: 0,
            compile_us_total: 0.0,
            compile_us_max: 0.0,
        }
    }

    /// Compile `spec` under `fabric` pressure, reusing the cached schedule
    /// when the step shape repeats (steady-state decode).
    pub fn compile(
        &mut self,
        spec: &StepSpec,
        fabric: &FabricPressure,
    ) -> Result<CompiledStep, CompileError> {
        let key = StepKey::of(spec, fabric);
        if let Some(cs) = self.cache.get(&key) {
            self.hits += 1;
            return Ok(cs.clone());
        }
        self.misses += 1;
        let t0 = std::time::Instant::now();
        let cs = self.compile_uncached(spec, fabric)?;
        let us = t0.elapsed().as_secs_f64() * 1e6;
        self.compile_us_total += us;
        self.compile_us_max = self.compile_us_max.max(us);
        self.cache.insert(key, cs.clone());
        Ok(cs)
    }

    fn compile_uncached(
        &self,
        spec: &StepSpec,
        fabric: &FabricPressure,
    ) -> Result<CompiledStep, CompileError> {
        // Fold the cluster's per-window fabric pressure into the session
        // hardware, per direction (the compile-time view of contention).
        let contended = fabric.d2r_slowdown > 1.0
            || fabric.r2d_slowdown > 1.0
            || fabric.peer_slowdown > 1.0;
        let mut chw = self.hw.clone();
        chw.d2r_gbps /= fabric.d2r_slowdown.max(1.0);
        chw.r2d_gbps /= fabric.r2d_slowdown.max(1.0);
        if let Some(p) = &mut chw.peer {
            p.gbps /= fabric.peer_slowdown.max(1.0);
        }

        let mut g = lower(spec, self.overlap);
        // The serving throttle is spill-only: no prefetch deferral (decode
        // needs its blocks now) and no splitting (KV transfers are already
        // block-granular) — the SLO shapes the deferrable writeback.
        let throttle = SloThrottle {
            split_min_bytes: 0,
            defer_prefetches: false,
            ..Default::default()
        };
        // `sanitize` is free on cache hits (the compiled step is memoised)
        // and proves the step schedule residency-safe under *any* dispatch
        // order, not just the pinned one the engine replays.
        let mut session = Compiler::empty(chw.clone())
            .pass(ExecOrderPass)
            .pass(throttle)
            .pass(ElideRedundantTransfers::default())
            .verify(true)
            .sanitize(true);
        if let Some(slo) = spec.slo_us {
            session = session.slo_us(slo);
        }
        let report = session.compile(&mut g)?;
        let sim = simulate(&g, &report.order, &chw);

        let host_us = spec.cpu_us + spec.defrag_us;
        let compute_us = chw.compute_us(spec.compute_flops, spec.compute_bytes);
        let serial_us = compute_us + host_us;
        let exposed = (sim.makespan_us - serial_us).max(0.0);
        let exposed_free = if contended {
            let free = simulate(&g, &report.order, &self.hw);
            (free.makespan_us - serial_us).max(0.0)
        } else {
            exposed
        };
        Ok(CompiledStep {
            step_us: sim.makespan_us,
            exposed_us: exposed,
            exposed_free_us: exposed_free,
            moved_r2d: spec.kv_fetch_bytes
                + spec.prefix_fetch_bytes
                + spec.cold_fetch.iter().map(|&(_, b)| b).sum::<u64>()
                + spec.peer_fetch.iter().map(|&(_, b)| b).sum::<u64>(),
            moved_d2r: spec.kv_writeback_bytes - report.deferred_bytes
                + spec.peer_store.iter().map(|&(_, b)| b).sum::<u64>(),
            deferred_d2r: report.deferred_bytes,
            throttled: report.throttled,
            chunk_splits: report.chunked,
            throttle_in_report: report.per_pass.iter().any(|p| p.pass == "slo-throttle"),
            sanitized: report
                .diagnostics
                .iter()
                .any(|d| d.pass == crate::analysis::lints::PASS),
        })
    }
}

/// Chunk size for lowering a shared-prefix fetch: one `Prefetch` per
/// ≤128 MB chunk, so a long prefix pipelines instead of arriving as one
/// monolithic transfer (mirrors the throttle's round-trip chunk size).
const PREFIX_CHUNK_BYTES: u64 = 128 << 20;

/// Lower one step into the IR:
///
/// ```text
///   Prefetch(kv.fetch)     ──┐               (Remote-home working-set delta)
///   Prefetch(kv.prefix.i)* ──┤               (shared-prefix blocks, chunked)
///   Store(kv.writeback)    ──┼──▶ HostWork(cpu + defrag)
///   Compute(step)          ──┘               (gates the host tail, §7.3.3)
/// ```
///
/// Overlap mode leaves the transfers independent of the compute (the
/// compiler scheduled them a step ahead, Fig. 4(c)); runtime mode gates
/// the compute on every transfer instead, exposing them serially. A
/// prefix-hit prefill additionally prefetches the shared blocks from the
/// pool (`kv.prefix.*`, one per [`PREFIX_CHUNK_BYTES`] chunk) — under
/// overlap they hide beneath the suffix compute, which is where the
/// prefix cache's latency win comes from. The writeback tensor is
/// producer-less and Device-home — the KV bytes are on device until
/// persisted — and is flagged
/// [`deferrable`](crate::graph::TensorInfo::deferrable) when the step has
/// an SLO, which is what arms the throttle's spill rewrite.
fn lower(spec: &StepSpec, overlap: bool) -> Graph {
    let mut g = Graph::new();
    let fetch = (spec.kv_fetch_bytes > 0)
        .then(|| g.add_tensor("kv.fetch", spec.kv_fetch_bytes, Tier::Remote));
    let wb = (spec.kv_writeback_bytes > 0)
        .then(|| g.add_tensor("kv.writeback", spec.kv_writeback_bytes, Tier::Device));
    if let (Some(w), true) = (wb, spec.slo_us.is_some()) {
        g.set_deferrable(w, true);
    }

    let mut prefix_tensors = Vec::new();
    let mut prefix_pf = Vec::new();
    if spec.prefix_fetch_bytes > 0 {
        let n = spec.prefix_fetch_bytes.div_ceil(PREFIX_CHUNK_BYTES).max(1);
        let base = spec.prefix_fetch_bytes / n;
        let rem = spec.prefix_fetch_bytes - base * n;
        for i in 0..n {
            let bytes = base + u64::from(i < rem);
            let t = g.add_tensor(format!("kv.prefix.{i}"), bytes, Tier::Remote);
            prefix_tensors.push(t);
            prefix_pf.push(g.add_op(
                format!("prefetch.kv.prefix.{i}"),
                OpKind::prefetch(t),
                vec![t],
                vec![],
            ));
        }
    }

    // Cold-tier fetches: blocks demoted below the pool arrive over the
    // deep fabric path. Their tensors are *home* at the cold tier, so the
    // sanitizer's tier lints see a consistent source and the simulator
    // charges every hop of the DRAM/CXL/SSD edge.
    let mut cold_tensors = Vec::new();
    let mut cold_pf = Vec::new();
    for (i, &(tier, bytes)) in spec.cold_fetch.iter().enumerate() {
        if bytes == 0 {
            continue;
        }
        let t = g.add_tensor(format!("kv.cold.{i}"), bytes, tier);
        cold_tensors.push(t);
        cold_pf.push(g.add_op(
            format!("prefetch.kv.cold.{i}"),
            OpKind::Prefetch { tensor: t, src: tier },
            vec![t],
            vec![],
        ));
    }

    // Peer-edge traffic: borrowed blocks fetched from (and persisted to)
    // a lender replica's HBM. Tensors are home at the `Peer` tier so the
    // verifier, TransferSan's `peer::revoked_read` lint, and the
    // simulator all see the device↔device edge as a first-class source.
    let mut peer_tensors = Vec::new();
    let mut peer_pf = Vec::new();
    for (i, &(lender, bytes)) in spec.peer_fetch.iter().enumerate() {
        if bytes == 0 {
            continue;
        }
        let tier = Tier::Peer(lender);
        let t = g.add_tensor(format!("kv.peer.{i}"), bytes, tier);
        peer_tensors.push(t);
        peer_pf.push(g.add_op(
            format!("prefetch.kv.peer.{i}"),
            OpKind::Prefetch { tensor: t, src: tier },
            vec![t],
            vec![],
        ));
    }
    let mut peer_st = Vec::new();
    for (i, &(lender, bytes)) in spec.peer_store.iter().enumerate() {
        if bytes == 0 {
            continue;
        }
        let t = g.add_tensor(format!("kv.peerwb.{i}"), bytes, Tier::Device);
        peer_st.push(g.add_op(
            format!("store.kv.peerwb.{i}"),
            OpKind::Store { tensor: t, dst: Tier::Peer(lender) },
            vec![t],
            vec![],
        ));
    }

    let pf = fetch.map(|t| g.add_op("prefetch.kv.fetch", OpKind::prefetch(t), vec![t], vec![]));
    let st = wb.map(|t| g.add_op("store.kv.writeback", OpKind::store(t), vec![t], vec![]));

    let compute = (spec.compute_flops > 0.0 || spec.compute_bytes > 0).then(|| {
        let out = g.add_tensor("step.out", 0, Tier::Device);
        let c = g.add_op(
            "step.compute",
            OpKind::Compute {
                flops: spec.compute_flops,
                bytes_accessed: spec.compute_bytes,
            },
            vec![],
            vec![out],
        );
        if !overlap {
            // Runtime-style: the step's compute waits for every transfer.
            for dep in [pf, st]
                .into_iter()
                .flatten()
                .chain(prefix_pf.iter().copied())
                .chain(cold_pf.iter().copied())
                .chain(peer_pf.iter().copied())
                .chain(peer_st.iter().copied())
            {
                g.add_control_dep(c, dep);
            }
        }
        c
    });

    let host_us = spec.cpu_us + spec.defrag_us;
    if host_us > 0.0
        || fetch.is_some()
        || !prefix_tensors.is_empty()
        || !cold_tensors.is_empty()
        || !peer_tensors.is_empty()
    {
        // The host tail consumes the fetched blocks (sparse gather over
        // the touched set, prefix, cold-tier and peer blocks included)
        // and runs after everything else in the step — CPU sparse-block
        // processing serialises (§7.3.3).
        let inputs: Vec<_> = fetch
            .into_iter()
            .chain(prefix_tensors.iter().copied())
            .chain(cold_tensors.iter().copied())
            .chain(peer_tensors.iter().copied())
            .collect();
        let h = g.add_op("step.host", OpKind::HostWork { us: host_us }, inputs, vec![]);
        for dep in [compute, pf, st]
            .into_iter()
            .flatten()
            .chain(prefix_pf.iter().copied())
            .chain(cold_pf.iter().copied())
            .chain(peer_pf.iter().copied())
            .chain(peer_st.iter().copied())
        {
            g.add_control_dep(h, dep);
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::MB;

    fn hw() -> HwConfig {
        HwConfig::test_default()
    }

    fn decode_spec(wb_mb: u64, slo: Option<f64>) -> StepSpec {
        StepSpec {
            phase: StepPhase::Decode,
            batch: 4,
            compute_flops: 40e6, // 40 us on the 1 TFLOP/s test device
            compute_bytes: 0,
            kv_fetch_bytes: 16 * 1024, // 16.4 us at 1 GB/s — hides under compute
            prefix_fetch_bytes: 0,
            kv_writeback_bytes: wb_mb * MB,
            cold_fetch: vec![],
            peer_fetch: vec![],
            peer_store: vec![],
            cpu_us: 5.0,
            defrag_us: 0.0,
            slo_us: slo,
        }
    }

    #[test]
    fn unthrottled_step_matches_the_analytic_formula() {
        // Overlap: max(compute, fetch, writeback) + host.
        let mut sc = StepCompiler::new(hw(), true);
        let cs = sc.compile(&decode_spec(8, None), &FabricPressure::NONE).unwrap();
        // 8 MB at 1 GB/s = 8388.6 us dominates the 40 us compute.
        let st_us = (8 * MB) as f64 / 1e9 * 1e6;
        assert!((cs.step_us - (st_us + 5.0)).abs() < 1e-6, "step {}", cs.step_us);
        assert!((cs.exposed_us - (st_us - 40.0)).abs() < 1e-6);
        assert_eq!(cs.moved_d2r, 8 * MB);
        assert_eq!(cs.deferred_d2r, 0);
        assert!(cs.throttle_in_report, "SloThrottle missing from the step pipeline");
    }

    #[test]
    fn runtime_mode_exposes_transfers_serially() {
        let mut sc = StepCompiler::new(hw(), false);
        let cs = sc.compile(&decode_spec(8, None), &FabricPressure::NONE).unwrap();
        let st_us = (8 * MB) as f64 / 1e9 * 1e6;
        // Serial: transfer + compute + host.
        assert!((cs.step_us - (st_us + 40.0 + 5.0)).abs() < 1e-6, "step {}", cs.step_us);
    }

    #[test]
    fn slo_spills_writeback_and_cache_hits_on_repeat() {
        let mut sc = StepCompiler::new(hw(), true);
        let spec = decode_spec(8, Some(60.0));
        let a = sc.compile(&spec, &FabricPressure::NONE).unwrap();
        assert!(a.deferred_d2r > 0, "tight SLO must defer writeback");
        assert_eq!(a.moved_d2r + a.deferred_d2r, 8 * MB, "byte conservation");
        assert!(a.step_us <= 60.0 * (1.0 + 1e-9), "SLO missed: {}", a.step_us);
        assert_eq!(sc.misses, 1);
        // The same shape compiles to a hash lookup.
        let b = sc.compile(&spec, &FabricPressure::NONE).unwrap();
        assert_eq!(sc.hits, 1);
        assert_eq!(a.moved_d2r, b.moved_d2r);
        assert_eq!(a.step_us.to_bits(), b.step_us.to_bits());
    }

    #[test]
    fn fabric_pressure_is_part_of_the_key_and_stretches_exposure() {
        let mut sc = StepCompiler::new(hw(), true);
        let free = sc.compile(&decode_spec(8, None), &FabricPressure::NONE).unwrap();
        let slow = sc
            .compile(
                &decode_spec(8, None),
                &FabricPressure { d2r_slowdown: 2.0, r2d_slowdown: 2.0, peer_slowdown: 1.0 },
            )
            .unwrap();
        assert_eq!(sc.misses, 2, "pressure must key separately");
        assert!(slow.exposed_us > free.exposed_us);
        assert!(slow.exposed_us - slow.exposed_free_us > 0.0, "fabric stall missing");
        assert_eq!(free.exposed_us, free.exposed_free_us);
    }

    #[test]
    fn drain_step_is_a_lone_store() {
        let mut sc = StepCompiler::new(hw(), true);
        let spec = StepSpec {
            phase: StepPhase::Drain,
            batch: 0,
            compute_flops: 0.0,
            compute_bytes: 0,
            kv_fetch_bytes: 0,
            prefix_fetch_bytes: 0,
            kv_writeback_bytes: 4 * MB,
            cold_fetch: vec![],
            peer_fetch: vec![],
            peer_store: vec![],
            cpu_us: 0.0,
            defrag_us: 0.0,
            slo_us: None,
        };
        let cs = sc.compile(&spec, &FabricPressure::NONE).unwrap();
        let st_us = (4 * MB) as f64 / 1e9 * 1e6;
        assert!((cs.step_us - st_us).abs() < 1e-6);
        assert!((cs.exposed_us - st_us).abs() < 1e-6, "nothing to hide under");
        assert_eq!(cs.moved_d2r, 4 * MB);
    }

    fn prefix_prefill_spec(prefix_bytes: u64) -> StepSpec {
        StepSpec {
            phase: StepPhase::Prefill,
            batch: 256,
            compute_flops: 40e6, // 40 us of suffix compute
            compute_bytes: 0,
            kv_fetch_bytes: 0,
            prefix_fetch_bytes: prefix_bytes,
            kv_writeback_bytes: 0,
            cold_fetch: vec![],
            peer_fetch: vec![],
            peer_store: vec![],
            cpu_us: 0.0,
            defrag_us: 0.0,
            slo_us: None,
        }
    }

    #[test]
    fn prefix_fetch_hides_under_suffix_compute() {
        let mut sc = StepCompiler::new(hw(), true);
        let cs = sc.compile(&prefix_prefill_spec(16 * 1024), &FabricPressure::NONE).unwrap();
        assert_eq!(cs.moved_r2d, 16 * 1024, "prefix bytes count as fetched");
        assert!(
            (cs.step_us - 40.0).abs() < 1e-6,
            "prefix fetch must hide under the suffix compute: {}",
            cs.step_us
        );
        // Runtime mode gates the compute on the prefix prefetch: serial.
        let mut rt = StepCompiler::new(hw(), false);
        let serial = rt.compile(&prefix_prefill_spec(16 * 1024), &FabricPressure::NONE).unwrap();
        assert!(serial.step_us > cs.step_us);
        // And the prefix volume is part of the cache key.
        sc.compile(&prefix_prefill_spec(32 * 1024), &FabricPressure::NONE).unwrap();
        assert_eq!(sc.misses, 2, "prefix bytes must key separately");
        sc.compile(&prefix_prefill_spec(16 * 1024), &FabricPressure::NONE).unwrap();
        assert_eq!(sc.hits, 1);
    }

    #[test]
    fn large_prefix_fetch_lowers_chunked() {
        let g = lower(&prefix_prefill_spec(300 * MB), true);
        let chunks = g
            .ops
            .iter()
            .filter(|o| o.name.starts_with("prefetch.kv.prefix."))
            .count();
        assert_eq!(chunks, 3, "300 MB at a 128 MB chunk size");
        let total: u64 = g
            .tensors
            .iter()
            .filter(|t| t.name.starts_with("kv.prefix."))
            .map(|t| t.bytes)
            .sum();
        assert_eq!(total, 300 * MB, "chunking conserves bytes");
        // A small prefix stays a single prefetch.
        let g1 = lower(&prefix_prefill_spec(MB), true);
        assert_eq!(
            g1.ops.iter().filter(|o| o.name.starts_with("prefetch.kv.prefix.")).count(),
            1
        );
    }

    #[test]
    fn cold_fetch_lowers_from_the_cold_tier_and_keys_separately() {
        use crate::sim::TierTopology;
        let base = hw();
        let tiered = base.clone().with_tiers(TierTopology::three_tier(&base));
        let mut sc = StepCompiler::new(tiered, true);

        let mut spec = decode_spec(8, None);
        spec.cold_fetch = vec![(Tier::Dram, 2 * MB)];
        let cs = sc.compile(&spec, &FabricPressure::NONE).unwrap();
        // The cold fetch counts as moved bytes and hides under the 8 MB
        // writeback (2 MB over the 0.5 GB/s DRAM edge ≈ 4.2 ms < 8.4 ms).
        assert_eq!(cs.moved_r2d, 16 * 1024 + 2 * MB);
        let wb_us = (8 * MB) as f64 / 1e9 * 1e6;
        assert!((cs.step_us - (wb_us + 5.0)).abs() < 1e-6, "step {}", cs.step_us);
        assert!(cs.sanitized, "cold-fetch step must pass TransferSan");

        // The cold volume is part of the compile-cache key.
        let warm = decode_spec(8, None);
        sc.compile(&warm, &FabricPressure::NONE).unwrap();
        assert_eq!(sc.misses, 2, "cold fetch must key separately");
        sc.compile(&spec, &FabricPressure::NONE).unwrap();
        assert_eq!(sc.hits, 1);

        // And the lowering is structurally what the sim costs: one
        // Prefetch whose src is the cold tier, tensor home at that tier.
        let g = lower(&spec, true);
        let cold: Vec<_> =
            g.ops.iter().filter(|o| o.name.starts_with("prefetch.kv.cold.")).collect();
        assert_eq!(cold.len(), 1);
        assert!(matches!(cold[0].kind, OpKind::Prefetch { src: Tier::Dram, .. }));
        let t = g.tensors.iter().find(|t| t.name == "kv.cold.0").unwrap();
        assert_eq!(t.home, Tier::Dram);
    }

    #[test]
    fn every_step_shape_compiles_sanitized() {
        // TransferSan is wired unconditionally into the step pipeline, so
        // each shape compiling at all proves its schedule residency-safe
        // under every dispatch order — overlap and runtime lowerings,
        // SLO-spilled writeback, drain, and the chunked prefix fetch.
        for overlap in [true, false] {
            let mut sc = StepCompiler::new(hw(), overlap);
            let drain = StepSpec {
                phase: StepPhase::Drain,
                batch: 0,
                compute_flops: 0.0,
                compute_bytes: 0,
                kv_fetch_bytes: 0,
                prefix_fetch_bytes: 0,
                kv_writeback_bytes: 4 * MB,
                cold_fetch: vec![],
            peer_fetch: vec![],
            peer_store: vec![],
                cpu_us: 0.0,
                defrag_us: 0.0,
                slo_us: None,
            };
            for spec in [
                decode_spec(8, None),
                decode_spec(8, Some(60.0)),
                prefix_prefill_spec(300 * MB),
                drain,
            ] {
                let cs = sc.compile(&spec, &FabricPressure::NONE).unwrap();
                assert!(cs.sanitized, "transfer-san audit line missing from step compile");
            }
        }
    }
}
