//! HyperOffload computation-graph IR (the paper's "MindIR" analogue).
//!
//! Cache operators — `Prefetch`, `Store`, `Detach` — are first-class nodes
//! (§4.2.1): they participate in dependency inference and topological
//! ordering, and the execution-order pass (Algorithm 1) schedules them like
//! any other op. See DESIGN.md §3.

mod builder;
#[allow(clippy::module_inception)]
mod graph;
mod op;
pub mod reach;
mod tensor;

pub use builder::GraphBuilder;
pub use graph::{CycleError, Graph, Mutation, RecomputeClone, RecomputePlan};
pub use op::{Op, OpId, OpKind};
pub use reach::{Reach, TrackedSet};
pub use tensor::{TensorId, TensorInfo, Tier};
