//! Tensors in the HyperOffload computation-graph IR.
//!
//! A tensor is a logical value with a size and a *home tier*: where it lives
//! when no cache operator has moved it. Cache operators (`Prefetch`, `Store`,
//! `Detach`) change its *residency* at execution time; the home tier only
//! decides the initial placement the memory planner assumes.

/// Index of a tensor inside its [`Graph`](super::Graph).
pub type TensorId = usize;

/// Memory tier in the SuperNode hierarchy (DESIGN.md §2).
///
/// The hot end (`Device`, `Remote`) is the paper's two-home model; the
/// cold end (`Dram`, `Cxl`, `Ssd`) is the N-level extension
/// (`sim::TierTopology`): optional levels below the pool with
/// order-of-magnitude bandwidth/latency spreads. Cache operators carry
/// explicit source/destination tiers, and `Promote` moves a cold copy
/// between non-device tiers without touching device residency.
///
/// `Peer(replica)` is the harvested middle tier: spare HBM on an idle
/// sibling replica, reached over the device↔device fabric link — faster
/// than the pool, but *revocable* (the lender can reclaim it, demoting
/// the borrowed copy to the pool). Peer homes only appear when a lease
/// is active; no lease, no `Peer` tiers anywhere in the IR.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Tier {
    /// On-device HBM — fast, scarce.
    Device,
    /// SuperNode shared memory pool reached over the Unified-Bus-like link.
    Remote,
    /// Host DRAM (staging tier; the paper's H2R/R2H primitives touch it).
    Host,
    /// Borrowed HBM on sibling replica `.0`, reached device↔device.
    /// Hotter than the pool, revocable by the lender.
    Peer(u16),
    /// Node-local cold DRAM below the pool (first cold level).
    Dram,
    /// Disaggregated CXL-attached memory below DRAM.
    Cxl,
    /// NVMe/SSD — the coldest, highest-capacity level.
    Ssd,
}

impl Tier {
    /// True for the cold levels below the pool (`Dram`/`Cxl`/`Ssd`).
    /// The legacy two-home paths treat every non-device tier alike; only
    /// cold tiers activate the N-level cost model and residency checks.
    pub fn is_cold(self) -> bool {
        matches!(self, Tier::Dram | Tier::Cxl | Tier::Ssd)
    }

    /// True for harvested peer-HBM homes ([`Tier::Peer`]).
    pub fn is_peer(self) -> bool {
        matches!(self, Tier::Peer(_))
    }
}

/// Static description of a tensor in the graph.
#[derive(Debug, Clone)]
pub struct TensorInfo {
    pub id: TensorId,
    pub name: String,
    /// Payload size in bytes; drives transfer cost and residency accounting.
    pub bytes: u64,
    /// Tier the tensor materialises in when produced.
    pub home: Tier,
    /// `Some(parent)` for a *chunk view*: this tensor names a byte range of
    /// the parent's storage rather than fresh memory. Cache operators on a
    /// chunk move only the chunk's bytes — this is what lets the SLO
    /// throttle split one tensor's Store/Prefetch round trip into staggered
    /// partial transfers (partial-tensor residency). For a `Device`-home
    /// chunk the *parent's* lifetime owns the allocation: the simulator
    /// charges no initial residency and no refcount free for the chunk
    /// itself, only its Store/Prefetch events (partial release/restore of
    /// the parent's bytes).
    pub alias_of: Option<TensorId>,
    /// True when the transfer persisting this tensor may be deferred past
    /// the current schedule (serving KV writebacks: the bytes can stay on
    /// device and move later). The SLO throttle's spill phase only sheds
    /// Store traffic of tensors carrying this flag.
    pub deferrable: bool,
}

impl TensorInfo {
    pub fn new(id: TensorId, name: impl Into<String>, bytes: u64, home: Tier) -> Self {
        Self { id, name: name.into(), bytes, home, alias_of: None, deferrable: false }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_info_fields() {
        let t = TensorInfo::new(3, "act.7", 4096, Tier::Device);
        assert_eq!(t.id, 3);
        assert_eq!(t.bytes, 4096);
        assert_eq!(t.home, Tier::Device);
        assert_eq!(t.name, "act.7");
    }

    #[test]
    fn tier_equality() {
        assert_ne!(Tier::Device, Tier::Remote);
        assert_eq!(Tier::Host, Tier::Host);
    }
}
