//! Operators in the HyperOffload IR.
//!
//! The paper's key move (§4.2): cache operations are *first-class graph
//! nodes*, peers of compute operators — not runtime side effects. `Prefetch`,
//! `Store`, `Detach` and `Promote` therefore appear here next to `Compute`,
//! participate in dependency inference and topological ordering, and are
//! scheduled by the same execution-order machinery.
//!
//! Transfers carry their non-device endpoint explicitly: a `Prefetch` names
//! the tier it reads from, a `Store` the tier it evicts to. The two-home
//! legacy graphs use [`OpKind::prefetch`]/[`OpKind::store`], which default
//! the endpoint to the shared pool ([`Tier::Remote`]) — cost- and
//! semantics-identical to the pre-tier IR. `Promote` moves a cold copy
//! between two non-device tiers (promotion up or demotion down the stack)
//! and never changes device residency.

use super::tensor::{TensorId, Tier};

/// Index of an op inside its [`Graph`](super::Graph).
pub type OpId = usize;

/// What an operator does, and which execution stream it occupies.
#[derive(Debug, Clone, PartialEq)]
pub enum OpKind {
    /// Device computation (MXU/vector work). Runs on the compute stream.
    Compute {
        /// Floating-point work, drives the roofline cost model.
        flops: f64,
        /// HBM traffic (bytes read+written), the other roofline axis.
        bytes_accessed: u64,
    },
    /// `src` tier → Device transfer of `tensor` (asynchronous DMA-in).
    /// Correctness: completion must precede the first consumer (§4.2.1).
    Prefetch { tensor: TensorId, src: Tier },
    /// Device → `dst` tier transfer of `tensor` (asynchronous DMA-out);
    /// device residency is released at completion (§4.2.1).
    Store { tensor: TensorId, dst: Tier },
    /// Release device residency of `tensor` without a transfer (§4.2.1).
    Detach { tensor: TensorId },
    /// Move the non-device copy of `tensor` from `src` to `dst` — promotion
    /// (colder → hotter) ahead of reuse, or demotion (hotter → colder)
    /// under pressure. Runs on the cold-DMA stream and leaves device
    /// residency untouched; a later `Prefetch` must read from `dst`.
    Promote { tensor: TensorId, src: Tier, dst: Tier },
    /// Inter-device collective (TP/PP/EP traffic). Runs on the network
    /// stream.
    Collective { bytes: u64 },
    /// CPU-side control work (runtime-driven scheduling overhead, sparse
    /// block processing). Runs on the host stream.
    HostWork { us: f64 },
}

impl OpKind {
    /// A pool-endpoint `Prefetch` — the two-home legacy shape.
    pub fn prefetch(tensor: TensorId) -> Self {
        OpKind::Prefetch { tensor, src: Tier::Remote }
    }

    /// A pool-endpoint `Store` — the two-home legacy shape.
    pub fn store(tensor: TensorId) -> Self {
        OpKind::Store { tensor, dst: Tier::Remote }
    }

    /// True for the paper's cache operators
    /// (`Prefetch`/`Store`/`Detach`/`Promote`).
    pub fn is_cache_op(&self) -> bool {
        matches!(
            self,
            OpKind::Prefetch { .. }
                | OpKind::Store { .. }
                | OpKind::Detach { .. }
                | OpKind::Promote { .. }
        )
    }

    /// True for transfer ops that move bytes across the *device* boundary.
    /// `Promote` moves bytes between non-device tiers only, so it is a
    /// cache op but not a device transfer.
    pub fn is_transfer(&self) -> bool {
        matches!(self, OpKind::Prefetch { .. } | OpKind::Store { .. })
    }

    /// The tensor a cache operator manages, if any.
    pub fn cache_tensor(&self) -> Option<TensorId> {
        match self {
            OpKind::Prefetch { tensor, .. }
            | OpKind::Store { tensor, .. }
            | OpKind::Detach { tensor }
            | OpKind::Promote { tensor, .. } => Some(*tensor),
            _ => None,
        }
    }
}

/// A node in the computation graph.
#[derive(Debug, Clone)]
pub struct Op {
    pub id: OpId,
    pub name: String,
    pub kind: OpKind,
    /// Tensors read. For cache ops this is the managed tensor.
    pub inputs: Vec<TensorId>,
    /// Tensors produced. Compute outputs materialise in their home tier.
    pub outputs: Vec<TensorId>,
    /// Explicit ordering edges beyond data dependencies (what the prefetch
    /// insertion pass wires between cache ops and consumers).
    pub control_deps: Vec<OpId>,
    /// True for ops cloned by the recompute-vs-offload decision pass: the
    /// op replays its original's FLOPs to regenerate a discarded tensor
    /// instead of transferring it back. The simulator accounts their busy
    /// time separately (`SimResult::recompute_us`, the paper's Fig. 6
    /// "recompute" bar).
    pub recompute: bool,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_op_classification() {
        assert!(OpKind::prefetch(0).is_cache_op());
        assert!(OpKind::store(0).is_cache_op());
        assert!(OpKind::Detach { tensor: 0 }.is_cache_op());
        assert!(OpKind::Promote { tensor: 0, src: Tier::Ssd, dst: Tier::Remote }.is_cache_op());
        assert!(!OpKind::Compute { flops: 1.0, bytes_accessed: 1 }.is_cache_op());
        assert!(!OpKind::Collective { bytes: 8 }.is_cache_op());
    }

    #[test]
    fn transfer_classification() {
        assert!(OpKind::prefetch(1).is_transfer());
        assert!(OpKind::store(1).is_transfer());
        assert!(!OpKind::Detach { tensor: 1 }.is_transfer());
        // Promote never crosses the device boundary.
        assert!(!OpKind::Promote { tensor: 1, src: Tier::Dram, dst: Tier::Remote }.is_transfer());
    }

    #[test]
    fn cache_tensor_extraction() {
        assert_eq!(OpKind::prefetch(7).cache_tensor(), Some(7));
        assert_eq!(
            OpKind::Promote { tensor: 9, src: Tier::Cxl, dst: Tier::Remote }.cache_tensor(),
            Some(9)
        );
        assert_eq!(OpKind::HostWork { us: 1.0 }.cache_tensor(), None);
    }

    #[test]
    fn legacy_constructors_default_to_the_pool() {
        assert_eq!(OpKind::prefetch(3), OpKind::Prefetch { tensor: 3, src: Tier::Remote });
        assert_eq!(OpKind::store(3), OpKind::Store { tensor: 3, dst: Tier::Remote });
    }
}
