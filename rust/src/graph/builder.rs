//! Convenience builders for common graph shapes (tests, benches, examples).

use super::graph::{CycleError, Graph};
use super::op::{OpId, OpKind};
use super::tensor::{TensorId, Tier};

/// Fluent builder over [`Graph`] for synthetic workloads.
pub struct GraphBuilder {
    pub graph: Graph,
}

impl GraphBuilder {
    pub fn new() -> Self {
        Self { graph: Graph::new() }
    }

    pub fn tensor(&mut self, name: &str, bytes: u64, home: Tier) -> TensorId {
        self.graph.add_tensor(name, bytes, home)
    }

    pub fn compute(
        &mut self,
        name: &str,
        flops: f64,
        bytes_accessed: u64,
        inputs: Vec<TensorId>,
        outputs: Vec<TensorId>,
    ) -> OpId {
        self.graph.add_op(name, OpKind::Compute { flops, bytes_accessed }, inputs, outputs)
    }

    /// Pool → device prefetch (the two-home legacy shape).
    pub fn prefetch(&mut self, name: &str, t: TensorId) -> OpId {
        self.prefetch_from(name, t, Tier::Remote)
    }

    /// `src`-tier → device prefetch.
    pub fn prefetch_from(&mut self, name: &str, t: TensorId, src: Tier) -> OpId {
        self.graph.add_op(name, OpKind::Prefetch { tensor: t, src }, vec![t], vec![])
    }

    /// Device → pool store (the two-home legacy shape).
    pub fn store(&mut self, name: &str, t: TensorId) -> OpId {
        self.store_to(name, t, Tier::Remote)
    }

    /// Device → `dst`-tier store.
    pub fn store_to(&mut self, name: &str, t: TensorId, dst: Tier) -> OpId {
        self.graph.add_op(name, OpKind::Store { tensor: t, dst }, vec![t], vec![])
    }

    pub fn detach(&mut self, name: &str, t: TensorId) -> OpId {
        self.graph.add_op(name, OpKind::Detach { tensor: t }, vec![t], vec![])
    }

    /// Non-device `src` → `dst` move (promotion/demotion on the cold side).
    pub fn promote(&mut self, name: &str, t: TensorId, src: Tier, dst: Tier) -> OpId {
        self.graph.add_op(name, OpKind::Promote { tensor: t, src, dst }, vec![t], vec![])
    }

    pub fn collective(&mut self, name: &str, bytes: u64, deps: Vec<TensorId>) -> OpId {
        self.graph.add_op(name, OpKind::Collective { bytes }, deps, vec![])
    }

    pub fn host(&mut self, name: &str, us: f64) -> OpId {
        self.graph.add_op(name, OpKind::HostWork { us }, vec![], vec![])
    }

    pub fn dep(&mut self, op: OpId, dep: OpId) {
        self.graph.add_control_dep(op, dep);
    }

    pub fn build(self) -> Graph {
        self.graph
    }

    /// Like [`build`](Self::build), but checks acyclicity up front and
    /// reports the cycle's culprit ops instead of deferring the failure to
    /// the first `topo_order` call.
    pub fn try_build(self) -> Result<Graph, CycleError> {
        self.graph.topo_order_detailed()?;
        Ok(self.graph)
    }

    /// A linear chain of `n` compute ops (`op_i` consumes `t_{i-1}`,
    /// produces `t_i`), each with the given cost — the simplest pipeline
    /// for overlap experiments.
    pub fn linear_chain(n: usize, flops: f64, act_bytes: u64) -> Graph {
        let mut b = GraphBuilder::new();
        let mut prev: Option<TensorId> = None;
        for i in 0..n {
            let out = b.tensor(&format!("act.{i}"), act_bytes, Tier::Device);
            let inputs = prev.map(|t| vec![t]).unwrap_or_default();
            b.compute(&format!("op.{i}"), flops, act_bytes, inputs, vec![out]);
            prev = Some(out);
        }
        b.build()
    }

    /// The §5.1 training case in miniature: `n_acts` forward ops each
    /// producing a large activation, a heavy mid-section of `n_mid` chained
    /// ops, then a backward chain consuming the activations in reverse.
    /// The canonical offload-round-trip workload (tests, Fig. 4, golden
    /// comparisons); backward ops reuse `fwd_flops`.
    pub fn fwd_bwd_chain(
        n_acts: usize,
        act_bytes: u64,
        fwd_flops: f64,
        n_mid: usize,
        mid_flops: f64,
    ) -> Graph {
        let mut b = GraphBuilder::new();
        let mut acts = Vec::with_capacity(n_acts);
        let mut prev: Option<TensorId> = None;
        let mut last_fwd: Option<OpId> = None;
        for i in 0..n_acts {
            let a = b.tensor(&format!("act{i}"), act_bytes, Tier::Device);
            let o = b.compute(
                &format!("fwd{i}"),
                fwd_flops,
                0,
                prev.map(|p| vec![p]).unwrap_or_default(),
                vec![a],
            );
            acts.push(a);
            prev = Some(a);
            last_fwd = Some(o);
        }
        let mut mid_prev: Option<OpId> = None;
        for i in 0..n_mid {
            let t = b.tensor(&format!("m{i}"), 0, Tier::Device);
            let o = b.compute(&format!("mid{i}"), mid_flops, 0, vec![], vec![t]);
            match mid_prev {
                Some(p) => b.dep(o, p),
                None => {
                    if let Some(fw) = last_fwd {
                        b.dep(o, fw);
                    }
                }
            }
            mid_prev = Some(o);
        }
        let mut bwd_prev = mid_prev.or(last_fwd);
        for (i, &a) in acts.iter().enumerate().rev() {
            let t = b.tensor(&format!("g{i}"), 0, Tier::Device);
            let o = b.compute(&format!("bwd{i}"), fwd_flops, 0, vec![a], vec![t]);
            if let Some(p) = bwd_prev {
                b.dep(o, p);
            }
            bwd_prev = Some(o);
        }
        b.build()
    }

    /// A chain where every op additionally consumes one remote-resident
    /// weight tensor — the canonical "weights streamed from the memory
    /// pool" workload of Figure 4. Returns (graph, weight tensor ids).
    pub fn chain_with_remote_weights(
        n: usize,
        flops: f64,
        act_bytes: u64,
        weight_bytes: u64,
    ) -> (Graph, Vec<TensorId>) {
        let mut b = GraphBuilder::new();
        let mut prev: Option<TensorId> = None;
        let mut weights = Vec::with_capacity(n);
        for i in 0..n {
            let w = b.tensor(&format!("w.{i}"), weight_bytes, Tier::Remote);
            weights.push(w);
            let out = b.tensor(&format!("act.{i}"), act_bytes, Tier::Device);
            let mut inputs = vec![w];
            if let Some(t) = prev {
                inputs.push(t);
            }
            b.compute(&format!("op.{i}"), flops, act_bytes, inputs, vec![out]);
            prev = Some(out);
        }
        (b.build(), weights)
    }
}

impl Default for GraphBuilder {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_chain_shape() {
        let g = GraphBuilder::linear_chain(5, 1e9, 1024);
        assert_eq!(g.ops.len(), 5);
        assert_eq!(g.tensors.len(), 5);
        let order = g.topo_order().unwrap();
        assert_eq!(order, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn chain_with_remote_weights_shape() {
        let (g, ws) = GraphBuilder::chain_with_remote_weights(3, 1e9, 64, 4096);
        assert_eq!(ws.len(), 3);
        assert_eq!(g.ops.len(), 3);
        for &w in &ws {
            assert_eq!(g.tensor(w).home, Tier::Remote);
            assert_eq!(g.consumers_of(w).len(), 1);
        }
        assert!(g.validate().is_ok());
    }

    #[test]
    fn try_build_reports_cycles() {
        let mut b = GraphBuilder::new();
        let t0 = b.tensor("t0", 8, Tier::Device);
        let t1 = b.tensor("t1", 8, Tier::Device);
        let a = b.compute("a", 1.0, 0, vec![], vec![t0]);
        let c = b.compute("c", 1.0, 0, vec![t0], vec![t1]);
        b.dep(a, c); // back edge: cycle a <-> c
        let err = b.try_build().unwrap_err();
        assert!(err.culprit_ops.contains(&a));
        assert!(err.culprit_ops.contains(&c));

        let mut ok = GraphBuilder::new();
        let t = ok.tensor("t", 8, Tier::Device);
        ok.compute("x", 1.0, 0, vec![], vec![t]);
        assert!(ok.try_build().is_ok());
    }

    #[test]
    fn fwd_bwd_chain_shape() {
        let g = GraphBuilder::fwd_bwd_chain(4, 8 << 20, 10e9, 24, 1e9);
        assert_eq!(g.ops.len(), 4 + 24 + 4);
        assert!(g.validate().is_ok());
        // bwd0 consumes act0, produced by fwd0.
        let bwd0 = g.ops.iter().find(|o| o.name == "bwd0").unwrap();
        let act0 = bwd0.inputs[0];
        assert_eq!(g.producer_of(act0), Some(0));
        // Backward runs after the mid section.
        let order = g.topo_order().unwrap();
        let pos = |name: &str| {
            let id = g.ops.iter().find(|o| o.name == name).unwrap().id;
            order.iter().position(|&x| x == id).unwrap()
        };
        assert!(pos("mid23") < pos("bwd3"));
        assert!(pos("bwd3") < pos("bwd0"));
    }

    #[test]
    fn builder_cache_ops_validate() {
        let mut b = GraphBuilder::new();
        let w = b.tensor("w", 1 << 20, Tier::Remote);
        let x = b.tensor("x", 64, Tier::Device);
        let pf = b.prefetch("pf.w", w);
        let c = b.compute("mm", 1e6, 64, vec![w], vec![x]);
        b.dep(c, pf);
        let st = b.store("st.x", x);
        b.dep(st, c);
        let g = b.build();
        assert!(g.validate().is_ok());
        assert_eq!(g.cache_ops().len(), 2);
    }
}
