//! The computation graph: ops + tensors + dependency structure.
//!
//! Dependency edges come from two sources: data (producer → consumer through
//! a tensor) and explicit control deps (wired by compiler passes around
//! cache operators). The *relative order of independent operators is
//! unspecified* — exactly the freedom Algorithm 1 exploits (§4.3).

use std::collections::{HashMap, VecDeque};

use anyhow::{bail, Result};

use super::op::{Op, OpId, OpKind};
use super::tensor::{TensorId, TensorInfo, Tier};

/// What one structural mutation did, recorded in the graph's bounded
/// journal so the compiler's `AnalysisCache` can *delta-update* cached
/// analyses (topological order, lifetimes) instead of recomputing them
/// from scratch after every version bump.
///
/// Every version increment pushes exactly one event; a consumer holding
/// the version its analysis was computed at replays
/// [`Graph::mutations_since`] to patch the analysis forward, falling back
/// to full recomputation when the journal was truncated or a
/// [`Mutation::NonLocal`] event appears.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mutation {
    /// A tensor was registered. No op-ordering effect; lifetime tables
    /// gain one (empty) entry.
    TensorAdded { tensor: TensorId },
    /// Tensor metadata changed (deferrable flag). No analysis effect.
    TensorMeta,
    /// A transfer op's tier endpoint was retargeted in place
    /// ([`Graph::retarget_transfer_tier`]). No structural effect: the op's
    /// edges, inputs and cache-op classification are unchanged — only its
    /// simulated duration (a per-query quantity, never cached) moves.
    OpRetargeted { op: OpId },
    /// An op was appended. Its id is the current maximum and nothing can
    /// depend on it yet, so any cached canonical topological order stays
    /// canonical with the new op appended at the end.
    OpAdded { op: OpId },
    /// `op` gained a data input `tensor` (edge producer(tensor) → op).
    InputAdded { op: OpId, tensor: TensorId },
    /// `op` gained an explicit ordering edge `dep → op`.
    ControlDepAdded { op: OpId, dep: OpId },
    /// A change cached analyses cannot patch locally (op removal, input
    /// replacement): consumers must recompute from scratch.
    NonLocal,
}

/// Journal capacity. Generous enough for the burst of local mutations a
/// decision pass makes between analysis queries; a compile that mutates
/// more than this between queries simply falls back to full recompute.
const JOURNAL_CAP: usize = 256;

/// A dependency cycle, reported with the ops that could not be ordered.
///
/// Produced by [`Graph::topo_order_detailed`] and
/// [`GraphBuilder::try_build`](super::GraphBuilder::try_build); the
/// compiler session surfaces it as `CompileError::Cycle`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CycleError {
    /// Ops left unorderable by Kahn's algorithm — every op on (or
    /// downstream of) a cycle.
    pub culprit_ops: Vec<OpId>,
}

impl std::fmt::Display for CycleError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "dependency cycle through {} op(s): {:?}",
            self.culprit_ops.len(),
            &self.culprit_ops[..self.culprit_ops.len().min(8)]
        )
    }
}

impl std::error::Error for CycleError {}

/// A computation graph with first-class cache operators.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub ops: Vec<Op>,
    pub tensors: Vec<TensorInfo>,
    /// producer[t] = op producing tensor t (graph inputs have none).
    producer: HashMap<TensorId, OpId>,
    /// consumers[t] = ops reading tensor t, in insertion order.
    consumers: HashMap<TensorId, Vec<OpId>>,
    /// Bumped on every structural mutation; the compiler's `AnalysisCache`
    /// keys cached analyses against it.
    version: u64,
    /// Sliding window of the most recent mutations, one entry per version
    /// bump. `journal_start` is the version at the front of the window.
    journal: VecDeque<Mutation>,
    journal_start: u64,
}

impl Graph {
    pub fn new() -> Self {
        Self::default()
    }

    /// Structural revision of this graph: incremented by every mutation
    /// (tensor/op insertion, control-dep wiring, op removal). Analyses
    /// cached against a version are valid exactly while it is unchanged.
    ///
    /// Caveat: direct writes to the public `ops`/`tensors` fields bypass
    /// this counter (and the producer/consumer indices) — prefer the
    /// mutation methods; the compiler session re-validates cached orders
    /// before trusting them as a backstop.
    pub fn version(&self) -> u64 {
        self.version
    }

    /// Bump the version and journal what changed (exactly one event per
    /// bump — the invariant `mutations_since` relies on).
    fn bump(&mut self, m: Mutation) {
        self.version += 1;
        if self.journal.len() == JOURNAL_CAP {
            self.journal.pop_front();
            self.journal_start += 1;
        }
        self.journal.push_back(m);
    }

    /// The mutations applied since version `since`, oldest first, or
    /// `None` when `since` predates the journal window (or lies in the
    /// future) — in which case callers must recompute from scratch.
    pub fn mutations_since(&self, since: u64) -> Option<Vec<Mutation>> {
        if since > self.version || since < self.journal_start {
            return None;
        }
        let skip = (since - self.journal_start) as usize;
        Some(self.journal.iter().skip(skip).copied().collect())
    }

    /// Register a tensor; returns its id.
    pub fn add_tensor(&mut self, name: impl Into<String>, bytes: u64, home: Tier) -> TensorId {
        let id = self.tensors.len();
        self.tensors.push(TensorInfo::new(id, name, bytes, home));
        self.bump(Mutation::TensorAdded { tensor: id });
        id
    }

    /// Register a *chunk view* of `parent`: a tensor naming `bytes` of the
    /// parent's storage (same home tier, [`TensorInfo::alias_of`] set).
    /// Cache operators on the chunk transfer only its bytes — the
    /// partial-tensor-residency primitive the SLO throttle's round-trip
    /// chunking builds on.
    pub fn add_chunk_tensor(
        &mut self,
        parent: TensorId,
        name: impl Into<String>,
        bytes: u64,
    ) -> TensorId {
        debug_assert!(parent < self.tensors.len(), "chunk parent {parent} unknown");
        debug_assert!(
            self.tensors[parent].alias_of.is_none(),
            "chunks of chunks are not supported"
        );
        let home = self.tensors[parent].home;
        let id = self.add_tensor(name, bytes, home);
        self.tensors[id].alias_of = Some(parent);
        id
    }

    /// Mark `t` as deferrable: its persisting Store may be shed from the
    /// schedule by the SLO throttle's spill phase (the bytes stay resident
    /// and move later). See [`TensorInfo::deferrable`].
    pub fn set_deferrable(&mut self, t: TensorId, on: bool) {
        debug_assert!(t < self.tensors.len(), "tensor {t} unknown");
        if self.tensors[t].deferrable != on {
            self.tensors[t].deferrable = on;
            self.bump(Mutation::TensorMeta);
        }
    }

    /// Append an op; data edges are derived from `inputs`/`outputs`.
    pub fn add_op(
        &mut self,
        name: impl Into<String>,
        kind: OpKind,
        inputs: Vec<TensorId>,
        outputs: Vec<TensorId>,
    ) -> OpId {
        let id = self.ops.len();
        for &t in &inputs {
            debug_assert!(t < self.tensors.len(), "input tensor {t} unknown");
            self.consumers.entry(t).or_default().push(id);
        }
        for &t in &outputs {
            debug_assert!(t < self.tensors.len(), "output tensor {t} unknown");
            let prev = self.producer.insert(t, id);
            debug_assert!(prev.is_none(), "tensor {t} produced twice");
        }
        self.ops.push(Op {
            id,
            name: name.into(),
            kind,
            inputs,
            outputs,
            control_deps: vec![],
            recompute: false,
        });
        self.bump(Mutation::OpAdded { op: id });
        id
    }

    /// Replace every occurrence of `old` in `op`'s inputs with `new`,
    /// keeping the consumer index consistent. No-op when `op` does not read
    /// `old`. Used by the recompute pass to point offload-window consumers
    /// at the regenerated clone of a discarded tensor.
    pub fn replace_input(&mut self, op: OpId, old: TensorId, new: TensorId) {
        debug_assert!(new < self.tensors.len(), "replacement tensor {new} unknown");
        let mut changed = false;
        for t in self.ops[op].inputs.iter_mut() {
            if *t == old {
                *t = new;
                changed = true;
            }
        }
        if !changed {
            return;
        }
        if let Some(v) = self.consumers.get_mut(&old) {
            v.retain(|&c| c != op);
        }
        let v = self.consumers.entry(new).or_default();
        if !v.contains(&op) {
            v.push(op);
        }
        // Rewiring can *remove* the edge producer(old) → op, which cached
        // orders cannot patch locally.
        self.bump(Mutation::NonLocal);
    }

    /// Append `t` to `op`'s inputs (creating the data edge producer(t) →
    /// op). No-op if the op already reads `t`. Used by the SLO throttle to
    /// make consumers wait on chunked prefetches.
    pub fn add_input(&mut self, op: OpId, t: TensorId) {
        debug_assert!(t < self.tensors.len(), "input tensor {t} unknown");
        if self.ops[op].inputs.contains(&t) {
            return;
        }
        self.ops[op].inputs.push(t);
        self.consumers.entry(t).or_default().push(op);
        self.bump(Mutation::InputAdded { op, tensor: t });
    }

    /// Point a transfer op at a different non-device tier: a `Store`'s
    /// destination or a `Prefetch`'s source. A structural no-op (edges and
    /// cache-op classification are untouched) journalled as
    /// [`Mutation::OpRetargeted`], so cached analyses patch through it.
    /// Ignores same-tier retargets; panics (debug) on non-transfer ops.
    pub fn retarget_transfer_tier(&mut self, op: OpId, tier: Tier) {
        debug_assert!(op < self.ops.len(), "op {op} unknown");
        match &mut self.ops[op].kind {
            OpKind::Store { dst, .. } if *dst != tier => {
                *dst = tier;
                self.bump(Mutation::OpRetargeted { op });
            }
            OpKind::Prefetch { src, .. } if *src != tier => {
                *src = tier;
                self.bump(Mutation::OpRetargeted { op });
            }
            OpKind::Store { .. } | OpKind::Prefetch { .. } => {}
            other => {
                debug_assert!(false, "retarget_transfer_tier on non-transfer op {op}: {other:?}");
            }
        }
    }

    /// Add an explicit ordering edge `dep → op`.
    pub fn add_control_dep(&mut self, op: OpId, dep: OpId) {
        if !self.ops[op].control_deps.contains(&dep) {
            self.ops[op].control_deps.push(dep);
            self.bump(Mutation::ControlDepAdded { op, dep });
        }
    }

    /// Remove `remove` from the graph, renumbering the surviving ops.
    ///
    /// Ordering constraints that flowed *through* a removed op are
    /// preserved: any op that control-depended on a removed op inherits the
    /// removed op's predecessors (data and control), spliced transitively
    /// through chains of removed ops. Tensors are untouched; a tensor whose
    /// producer is removed becomes a graph input.
    ///
    /// Returns `old_id -> Some(new_id)` for kept ops, `None` for removed.
    pub fn remove_ops(&mut self, remove: &[OpId]) -> Vec<Option<OpId>> {
        let n = self.ops.len();
        let mut removed = vec![false; n];
        for &r in remove {
            removed[r] = true;
        }
        // Replacement deps for removed ops (computed before any mutation).
        let mut repl: Vec<Vec<OpId>> = vec![Vec::new(); n];
        for r in 0..n {
            if removed[r] {
                repl[r] = self.preds(r);
            }
        }
        // Splice chains of removed ops (graph is acyclic, so this settles).
        let mut changed = true;
        while changed {
            changed = false;
            for r in 0..n {
                if !removed[r] || !repl[r].iter().any(|&p| removed[p]) {
                    continue;
                }
                let mut out = Vec::new();
                for &p in &repl[r] {
                    if removed[p] {
                        out.extend(repl[p].iter().copied());
                    } else {
                        out.push(p);
                    }
                }
                out.sort_unstable();
                out.dedup();
                repl[r] = out;
                changed = true;
            }
        }
        let mut new_id: Vec<Option<OpId>> = vec![None; n];
        let mut next = 0usize;
        for (i, slot) in new_id.iter_mut().enumerate() {
            if !removed[i] {
                *slot = Some(next);
                next += 1;
            }
        }
        let mut ops = std::mem::take(&mut self.ops);
        ops.retain(|o| !removed[o.id]);
        for o in &mut ops {
            let mut deps = Vec::new();
            for &d in &o.control_deps {
                if removed[d] {
                    deps.extend(repl[d].iter().copied());
                } else {
                    deps.push(d);
                }
            }
            deps.sort_unstable();
            deps.dedup();
            deps.retain(|&d| d != o.id && !removed[d]);
            o.control_deps = deps.into_iter().map(|d| new_id[d].unwrap()).collect();
            o.id = new_id[o.id].unwrap();
        }
        self.ops = ops;
        self.producer.clear();
        self.consumers.clear();
        for op in &self.ops {
            for &t in &op.inputs {
                self.consumers.entry(t).or_default().push(op.id);
            }
            for &t in &op.outputs {
                self.producer.insert(t, op.id);
            }
        }
        self.bump(Mutation::NonLocal);
        new_id
    }

    pub fn op(&self, id: OpId) -> &Op {
        &self.ops[id]
    }

    pub fn tensor(&self, id: TensorId) -> &TensorInfo {
        &self.tensors[id]
    }

    pub fn producer_of(&self, t: TensorId) -> Option<OpId> {
        self.producer.get(&t).copied()
    }

    pub fn consumers_of(&self, t: TensorId) -> &[OpId] {
        self.consumers.get(&t).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// All dependency predecessors of `op` (data producers + control deps).
    pub fn preds(&self, op: OpId) -> Vec<OpId> {
        let o = &self.ops[op];
        let mut out: Vec<OpId> = o
            .inputs
            .iter()
            .filter_map(|t| self.producer_of(*t))
            .collect();
        out.extend(o.control_deps.iter().copied());
        out.sort_unstable();
        out.dedup();
        out.retain(|&p| p != op);
        out
    }

    /// All dependency successors of `op`.
    pub fn succs(&self, op: OpId) -> Vec<OpId> {
        let mut out = Vec::new();
        for t in &self.ops[op].outputs {
            out.extend(self.consumers_of(*t));
        }
        for other in &self.ops {
            if other.control_deps.contains(&op) {
                out.push(other.id);
            }
        }
        out.sort_unstable();
        out.dedup();
        out.retain(|&s| s != op);
        out
    }

    /// Deterministic topological order (Kahn; ties broken by smallest id,
    /// i.e. insertion order — the "program order" a framework would emit).
    /// On a cyclic graph, reports exactly which ops could not be ordered.
    pub fn topo_order_detailed(&self) -> std::result::Result<Vec<OpId>, CycleError> {
        let n = self.ops.len();
        let mut indeg = vec![0usize; n];
        let mut succs: Vec<Vec<OpId>> = vec![Vec::new(); n];
        for op in &self.ops {
            for p in self.preds(op.id) {
                indeg[op.id] += 1;
                succs[p].push(op.id);
            }
        }
        let mut heap = std::collections::BinaryHeap::new();
        for (i, &d) in indeg.iter().enumerate() {
            if d == 0 {
                heap.push(std::cmp::Reverse(i));
            }
        }
        let mut order = Vec::with_capacity(n);
        while let Some(std::cmp::Reverse(u)) = heap.pop() {
            order.push(u);
            for &v in &succs[u] {
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    heap.push(std::cmp::Reverse(v));
                }
            }
        }
        if order.len() != n {
            let culprit_ops: Vec<OpId> = indeg
                .iter()
                .enumerate()
                .filter(|&(_, &d)| d > 0)
                .map(|(i, _)| i)
                .collect();
            return Err(CycleError { culprit_ops });
        }
        Ok(order)
    }

    /// A *random* valid topological order: Kahn with the ready set sampled
    /// uniformly by a seeded PRNG. Deterministic per seed. This is how the
    /// order-robustness tests exercise linearizations the canonical order
    /// (and the pinned schedule) never visit — TransferSan's verdicts must
    /// hold on every one of them.
    pub fn topo_order_seeded(&self, seed: u64) -> std::result::Result<Vec<OpId>, CycleError> {
        let n = self.ops.len();
        let mut indeg = vec![0usize; n];
        let mut succs: Vec<Vec<OpId>> = vec![Vec::new(); n];
        for op in &self.ops {
            for p in self.preds(op.id) {
                indeg[op.id] += 1;
                succs[p].push(op.id);
            }
        }
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut ready: Vec<OpId> =
            indeg.iter().enumerate().filter(|&(_, &d)| d == 0).map(|(i, _)| i).collect();
        let mut order = Vec::with_capacity(n);
        while !ready.is_empty() {
            let pick = rng.usize(0, ready.len());
            let u = ready.swap_remove(pick);
            order.push(u);
            for &v in &succs[u] {
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    ready.push(v);
                }
            }
        }
        if order.len() != n {
            let culprit_ops: Vec<OpId> = indeg
                .iter()
                .enumerate()
                .filter(|&(_, &d)| d > 0)
                .map(|(i, _)| i)
                .collect();
            return Err(CycleError { culprit_ops });
        }
        Ok(order)
    }

    /// [`topo_order_detailed`](Self::topo_order_detailed) with the legacy
    /// `anyhow` error type.
    pub fn topo_order(&self) -> Result<Vec<OpId>> {
        self.topo_order_detailed().map_err(|e| {
            anyhow::anyhow!(
                "graph has a dependency cycle ({} of {} ops ordered)",
                self.ops.len() - e.culprit_ops.len(),
                self.ops.len()
            )
        })
    }

    /// Check that `order` is a permutation of all ops respecting every
    /// dependency edge. This is the invariant Algorithm 1 must preserve —
    /// property-tested in rust/tests/.
    pub fn is_valid_order(&self, order: &[OpId]) -> bool {
        if order.len() != self.ops.len() {
            return false;
        }
        let mut pos = vec![usize::MAX; self.ops.len()];
        for (i, &o) in order.iter().enumerate() {
            if o >= self.ops.len() || pos[o] != usize::MAX {
                return false; // out of range or duplicate
            }
            pos[o] = i;
        }
        for op in &self.ops {
            for p in self.preds(op.id) {
                if pos[p] >= pos[op.id] {
                    return false;
                }
            }
        }
        true
    }

    /// Structural sanity checks (used by tests and the pass manager).
    pub fn validate(&self) -> Result<()> {
        for op in &self.ops {
            for &t in op.inputs.iter().chain(op.outputs.iter()) {
                if t >= self.tensors.len() {
                    bail!("op {} ({}) references unknown tensor {t}", op.id, op.name);
                }
            }
            if let Some(t) = op.kind.cache_tensor() {
                if !op.inputs.contains(&t) {
                    bail!("cache op {} ({}) must list its tensor {t} as input", op.id, op.name);
                }
            }
            for &d in &op.control_deps {
                if d >= self.ops.len() {
                    bail!("op {} control-dep on unknown op {d}", op.id);
                }
            }
        }
        self.topo_order().map(|_| ())
    }

    /// Ids of all cache operators.
    pub fn cache_ops(&self) -> Vec<OpId> {
        self.ops.iter().filter(|o| o.kind.is_cache_op()).map(|o| o.id).collect()
    }

    /// First consumer of a cache op's tensor *after* the cache op in
    /// `order` — "u ← first consumer of c" in Algorithm 1.
    pub fn first_consumer_after(&self, cache_op: OpId, order: &[OpId]) -> Option<OpId> {
        let t = self.ops[cache_op].kind.cache_tensor()?;
        let mut pos = vec![usize::MAX; self.ops.len()];
        for (i, &o) in order.iter().enumerate() {
            pos[o] = i;
        }
        // Consumers via data edge, or via control dep on the cache op.
        let mut candidates: Vec<OpId> = self
            .consumers_of(t)
            .iter()
            .copied()
            .filter(|&c| c != cache_op && !self.ops[c].kind.is_cache_op())
            .collect();
        for other in &self.ops {
            if other.control_deps.contains(&cache_op) && !other.kind.is_cache_op() {
                candidates.push(other.id);
            }
        }
        candidates.retain(|&c| pos[c] > pos[cache_op]);
        candidates.into_iter().min_by_key(|&c| pos[c])
    }

    /// Total bytes of all tensors whose home tier is `tier`.
    pub fn bytes_in_tier(&self, tier: Tier) -> u64 {
        self.tensors.iter().filter(|t| t.home == tier).map(|t| t.bytes).sum()
    }

    /// Plan (without mutating) the producer subgraph that would regenerate
    /// `target` from tensors the `available` predicate accepts: walk
    /// producers transitively, stopping at available inputs. Fails
    /// (`None`) when the walk hits a tensor with no producer, a non-compute
    /// producer, or needs more than `max_ops` ops — those tensors cannot be
    /// recomputed, only transferred.
    ///
    /// The plan is the cost side of the recompute-vs-offload decision: the
    /// pass compares `Σ compute_us(flops, bytes)` over `op_costs` against
    /// the tensor's exposed transfer cost before committing to a clone.
    pub fn recompute_plan(
        &self,
        target: TensorId,
        available: &dyn Fn(&Graph, TensorId) -> bool,
        max_ops: usize,
    ) -> Option<RecomputePlan> {
        let mut planned_ops: Vec<OpId> = Vec::new(); // producers before consumers
        let mut planned_set: Vec<bool> = vec![false; self.ops.len()];
        // Recursive expand-then-emit DFS so the emitted op order is
        // producers-first. `depth` prunes the descent: every recursion
        // level corresponds to at least one op the plan would have to
        // clone, so a chain deeper than `max_ops` can never fit the cap —
        // bail before recursing instead of after walking the whole chain.
        fn visit(
            g: &Graph,
            t: TensorId,
            available: &dyn Fn(&Graph, TensorId) -> bool,
            max_ops: usize,
            depth: usize,
            planned_ops: &mut Vec<OpId>,
            planned_set: &mut Vec<bool>,
        ) -> bool {
            if depth >= max_ops {
                return false;
            }
            let Some(p) = g.producer_of(t) else { return false };
            if planned_set[p] {
                return true;
            }
            if !matches!(g.op(p).kind, OpKind::Compute { .. }) {
                return false;
            }
            for &i in &g.op(p).inputs {
                if available(g, i) {
                    continue;
                }
                if !visit(g, i, available, max_ops, depth + 1, planned_ops, planned_set) {
                    return false;
                }
            }
            if planned_ops.len() >= max_ops {
                return false;
            }
            planned_set[p] = true;
            planned_ops.push(p);
            true
        }
        if !visit(self, target, available, max_ops, 0, &mut planned_ops, &mut planned_set) {
            return None;
        }
        let op_costs = planned_ops
            .iter()
            .map(|&o| match self.op(o).kind {
                OpKind::Compute { flops, bytes_accessed } => (flops, bytes_accessed),
                _ => unreachable!("plan admits compute ops only"),
            })
            .collect();
        Some(RecomputePlan { target, ops: planned_ops, op_costs })
    }

    /// Materialise a [`recompute_plan`](Self::recompute_plan): clone the
    /// planned producer ops (marked [`Op::recompute`], fresh `.rc` output
    /// tensors) so the graph regenerates `plan.target` instead of holding /
    /// reloading it. Returns the clone of `plan.target` plus the new op
    /// ids; the caller rewires consumers ([`replace_input`](Self::replace_input))
    /// and anchors the clones where the recompute should issue.
    pub fn clone_recompute_subgraph(&mut self, plan: &RecomputePlan) -> RecomputeClone {
        let mut tensor_map: HashMap<TensorId, TensorId> = HashMap::new();
        let mut new_ops = Vec::with_capacity(plan.ops.len());
        for &p in &plan.ops {
            let (name, kind, inputs, outputs) = {
                let op = self.op(p);
                (op.name.clone(), op.kind.clone(), op.inputs.clone(), op.outputs.clone())
            };
            let mut new_outputs: Vec<TensorId> = Vec::with_capacity(outputs.len());
            for &o in &outputs {
                let (tname, tbytes, thome) = {
                    let t = self.tensor(o);
                    (t.name.clone(), t.bytes, t.home)
                };
                let nt = self.add_tensor(format!("{tname}.rc"), tbytes, thome);
                tensor_map.insert(o, nt);
                new_outputs.push(nt);
            }
            let new_inputs: Vec<TensorId> =
                inputs.iter().map(|&i| tensor_map.get(&i).copied().unwrap_or(i)).collect();
            let id = self.add_op(format!("recompute.{name}"), kind, new_inputs, new_outputs);
            self.ops[id].recompute = true;
            new_ops.push(id);
        }
        RecomputeClone { tensor: tensor_map[&plan.target], ops: new_ops }
    }
}

/// A planned (not yet materialised) recompute subgraph: which ops must be
/// replayed to regenerate one tensor, and what each replay costs.
#[derive(Debug, Clone)]
pub struct RecomputePlan {
    /// The tensor the plan regenerates.
    pub target: TensorId,
    /// Original ops to clone, producers before consumers.
    pub ops: Vec<OpId>,
    /// `(flops, bytes_accessed)` of each planned op, aligned with `ops`.
    pub op_costs: Vec<(f64, u64)>,
}

/// Result of materialising a [`RecomputePlan`].
#[derive(Debug, Clone)]
pub struct RecomputeClone {
    /// The freshly produced clone of the plan's target tensor.
    pub tensor: TensorId,
    /// The cloned ops (all marked [`Op::recompute`]), producers first.
    pub ops: Vec<OpId>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Graph {
        // a -> (b, c) -> d
        let mut g = Graph::new();
        let t0 = g.add_tensor("t0", 8, Tier::Device);
        let t1 = g.add_tensor("t1", 8, Tier::Device);
        let t2 = g.add_tensor("t2", 8, Tier::Device);
        let t3 = g.add_tensor("t3", 8, Tier::Device);
        g.add_op("a", OpKind::Compute { flops: 1.0, bytes_accessed: 8 }, vec![], vec![t0]);
        g.add_op("b", OpKind::Compute { flops: 1.0, bytes_accessed: 8 }, vec![t0], vec![t1]);
        g.add_op("c", OpKind::Compute { flops: 1.0, bytes_accessed: 8 }, vec![t0], vec![t2]);
        g.add_op("d", OpKind::Compute { flops: 1.0, bytes_accessed: 8 }, vec![t1, t2], vec![t3]);
        g
    }

    #[test]
    fn topo_order_respects_deps() {
        let g = diamond();
        let order = g.topo_order().unwrap();
        assert!(g.is_valid_order(&order));
        assert_eq!(order[0], 0);
        assert_eq!(order[3], 3);
    }

    #[test]
    fn invalid_orders_rejected() {
        let g = diamond();
        assert!(!g.is_valid_order(&[3, 1, 2, 0])); // d before a
        assert!(!g.is_valid_order(&[0, 1, 2]));    // missing op
        assert!(!g.is_valid_order(&[0, 1, 1, 3])); // duplicate
    }

    #[test]
    fn control_deps_enter_ordering() {
        let mut g = diamond();
        // force c before b
        g.add_control_dep(1, 2);
        let order = g.topo_order().unwrap();
        let pos = |o: OpId| order.iter().position(|&x| x == o).unwrap();
        assert!(pos(2) < pos(1));
        assert!(g.is_valid_order(&order));
        assert!(!g.is_valid_order(&[0, 1, 2, 3]));
    }

    #[test]
    fn cycle_detected() {
        let mut g = diamond();
        g.add_control_dep(0, 3); // a after d -> cycle
        assert!(g.topo_order().is_err());
        assert!(g.validate().is_err());
    }

    #[test]
    fn preds_and_succs() {
        let g = diamond();
        assert_eq!(g.preds(3), vec![1, 2]);
        assert_eq!(g.succs(0), vec![1, 2]);
        assert!(g.preds(0).is_empty());
    }

    #[test]
    fn cache_ops_listed_and_first_consumer_found() {
        let mut g = Graph::new();
        let w = g.add_tensor("w", 1024, Tier::Remote);
        let x = g.add_tensor("x", 64, Tier::Device);
        let y = g.add_tensor("y", 64, Tier::Device);
        let pf = g.add_op("pf.w", OpKind::prefetch(w), vec![w], vec![]);
        let c0 = g.add_op("mm0", OpKind::Compute { flops: 1.0, bytes_accessed: 64 }, vec![], vec![x]);
        let c1 = g.add_op("mm1", OpKind::Compute { flops: 1.0, bytes_accessed: 64 }, vec![x, w], vec![y]);
        g.add_control_dep(c1, pf);
        let order = g.topo_order().unwrap();
        assert_eq!(g.cache_ops(), vec![pf]);
        assert_eq!(g.first_consumer_after(pf, &order), Some(c1));
        assert!(g.validate().is_ok());
        let _ = c0;
    }

    #[test]
    fn validate_rejects_cache_op_without_tensor_input() {
        let mut g = Graph::new();
        let w = g.add_tensor("w", 1024, Tier::Remote);
        g.add_op("pf.bad", OpKind::prefetch(w), vec![], vec![]);
        assert!(g.validate().is_err());
    }

    #[test]
    fn bytes_in_tier_sums() {
        let g = diamond();
        assert_eq!(g.bytes_in_tier(Tier::Device), 32);
        assert_eq!(g.bytes_in_tier(Tier::Remote), 0);
    }

    #[test]
    fn version_bumps_on_mutation() {
        let mut g = diamond();
        let v0 = g.version();
        g.add_control_dep(3, 0);
        assert!(g.version() > v0);
        let v1 = g.version();
        g.add_control_dep(3, 0); // duplicate: no structural change
        assert_eq!(g.version(), v1);
        let t = g.add_tensor("extra", 8, Tier::Device);
        g.add_op("e", OpKind::Compute { flops: 1.0, bytes_accessed: 8 }, vec![t], vec![]);
        assert!(g.version() > v1);
    }

    #[test]
    fn cycle_culprits_reported() {
        let mut g = diamond();
        g.add_control_dep(0, 3); // a after d -> cycle through all four
        let err = g.topo_order_detailed().unwrap_err();
        assert_eq!(err.culprit_ops, vec![0, 1, 2, 3]);
    }

    #[test]
    fn replace_and_add_input_keep_consumer_index_consistent() {
        let mut g = diamond();
        let t4 = g.add_tensor("t4", 8, Tier::Device);
        g.add_op("e", OpKind::Compute { flops: 1.0, bytes_accessed: 8 }, vec![], vec![t4]);
        // d now reads t4 instead of t1.
        g.replace_input(3, 1, t4);
        assert!(!g.consumers_of(1).contains(&3));
        assert!(g.consumers_of(t4).contains(&3));
        assert!(g.op(3).inputs.contains(&t4) && !g.op(3).inputs.contains(&1));
        // b additionally waits on t4.
        let v = g.version();
        g.add_input(1, t4);
        assert!(g.consumers_of(t4).contains(&1));
        assert!(g.version() > v);
        g.add_input(1, t4); // idempotent
        assert_eq!(g.consumers_of(t4).iter().filter(|&&c| c == 1).count(), 1);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn recompute_plan_walks_until_available_inputs() {
        let g = diamond();
        // Everything available: regenerating t3 replays only d.
        let all = |_: &Graph, _: TensorId| true;
        let p = g.recompute_plan(3, &all, 8).unwrap();
        assert_eq!(p.ops, vec![3]);
        // t1/t2 unavailable: the plan recursively pulls in b and c (t0
        // still available), producers before consumers.
        let only_t0 = |_: &Graph, x: TensorId| x == 0;
        let p = g.recompute_plan(3, &only_t0, 8).unwrap();
        assert_eq!(*p.ops.last().unwrap(), 3);
        assert!(p.ops.contains(&1) && p.ops.contains(&2));
        assert_eq!(p.op_costs.len(), 3);
        // Nothing available: t0 has no producer below it -> a is cloned
        // too; with a cap of 2 ops the plan must fail instead.
        let none = |_: &Graph, _: TensorId| false;
        assert!(g.recompute_plan(3, &none, 8).is_some());
        assert!(g.recompute_plan(3, &none, 2).is_none());
    }

    #[test]
    fn clone_recompute_subgraph_marks_and_rewires() {
        let mut g = diamond();
        let only_t0 = |_: &Graph, x: TensorId| x == 0;
        let plan = g.recompute_plan(3, &only_t0, 8).unwrap();
        let n_ops = g.ops.len();
        let clone = g.clone_recompute_subgraph(&plan);
        assert_eq!(g.ops.len(), n_ops + 3);
        assert!(clone.ops.iter().all(|&o| g.op(o).recompute));
        assert!(g.tensor(clone.tensor).name.ends_with(".rc"));
        // The cloned chain reads the available t0, not clones of it.
        let first = g.op(clone.ops[0]);
        assert!(first.inputs.contains(&0));
        assert!(g.validate().is_ok());
        assert_eq!(g.producer_of(clone.tensor), Some(*clone.ops.last().unwrap()));
    }

    #[test]
    fn mutation_journal_tracks_every_bump() {
        let mut g = diamond();
        let v = g.version();
        assert_eq!(g.mutations_since(v), Some(vec![]));
        let t = g.add_tensor("x", 8, Tier::Device);
        let e = g.add_op("e", OpKind::Compute { flops: 1.0, bytes_accessed: 8 }, vec![t], vec![]);
        g.add_control_dep(e, 0);
        g.add_control_dep(e, 0); // duplicate: no bump, no event
        let muts = g.mutations_since(v).unwrap();
        assert_eq!(
            muts,
            vec![
                Mutation::TensorAdded { tensor: t },
                Mutation::OpAdded { op: e },
                Mutation::ControlDepAdded { op: e, dep: 0 },
            ]
        );
        assert_eq!(g.version(), v + muts.len() as u64);
        g.remove_ops(&[e]);
        assert_eq!(g.mutations_since(g.version() - 1), Some(vec![Mutation::NonLocal]));
        // Future versions and truncated windows both report None.
        assert!(g.mutations_since(g.version() + 1).is_none());
        let mut big = diamond();
        let v0 = big.version();
        for _ in 0..(super::JOURNAL_CAP + 4) {
            big.set_deferrable(0, !big.tensor(0).deferrable);
        }
        assert!(big.mutations_since(v0).is_none());
        assert!(big.mutations_since(big.version()).is_some());
    }

    #[test]
    fn remove_ops_renumbers_and_keeps_ordering_through_removed() {
        // a -> st -> pf -> d (control chain); removing st+pf must leave
        // d ordered after a via the spliced control dep.
        let mut g = Graph::new();
        let t0 = g.add_tensor("t0", 8, Tier::Device);
        let a = g.add_op("a", OpKind::Compute { flops: 1.0, bytes_accessed: 0 }, vec![], vec![t0]);
        let st = g.add_op("st", OpKind::store(t0), vec![t0], vec![]);
        g.add_control_dep(st, a);
        let pf = g.add_op("pf", OpKind::prefetch(t0), vec![t0], vec![]);
        g.add_control_dep(pf, st);
        let t1 = g.add_tensor("t1", 8, Tier::Device);
        let d = g.add_op("d", OpKind::Compute { flops: 1.0, bytes_accessed: 0 }, vec![], vec![t1]);
        g.add_control_dep(d, pf);

        let map = g.remove_ops(&[st, pf]);
        assert_eq!(map[a], Some(0));
        assert_eq!(map[st], None);
        assert_eq!(map[pf], None);
        assert_eq!(map[d], Some(1));
        assert_eq!(g.ops.len(), 2);
        assert!(g.validate().is_ok());
        // d (new id 1) inherits an ordering edge on a (new id 0).
        assert_eq!(g.preds(1), vec![0]);
        assert!(g.cache_ops().is_empty());
        // Consumers of t0 no longer include the removed cache ops.
        assert!(g.consumers_of(t0).is_empty());
        assert_eq!(g.producer_of(t0), Some(0));
    }
}
