//! The computation graph: ops + tensors + dependency structure.
//!
//! Dependency edges come from two sources: data (producer → consumer through
//! a tensor) and explicit control deps (wired by compiler passes around
//! cache operators). The *relative order of independent operators is
//! unspecified* — exactly the freedom Algorithm 1 exploits (§4.3).

use std::collections::HashMap;

use anyhow::{bail, Result};

use super::op::{Op, OpId, OpKind};
use super::tensor::{TensorId, TensorInfo, Tier};

/// A computation graph with first-class cache operators.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub ops: Vec<Op>,
    pub tensors: Vec<TensorInfo>,
    /// producer[t] = op producing tensor t (graph inputs have none).
    producer: HashMap<TensorId, OpId>,
    /// consumers[t] = ops reading tensor t, in insertion order.
    consumers: HashMap<TensorId, Vec<OpId>>,
}

impl Graph {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a tensor; returns its id.
    pub fn add_tensor(&mut self, name: impl Into<String>, bytes: u64, home: Tier) -> TensorId {
        let id = self.tensors.len();
        self.tensors.push(TensorInfo::new(id, name, bytes, home));
        id
    }

    /// Append an op; data edges are derived from `inputs`/`outputs`.
    pub fn add_op(
        &mut self,
        name: impl Into<String>,
        kind: OpKind,
        inputs: Vec<TensorId>,
        outputs: Vec<TensorId>,
    ) -> OpId {
        let id = self.ops.len();
        for &t in &inputs {
            debug_assert!(t < self.tensors.len(), "input tensor {t} unknown");
            self.consumers.entry(t).or_default().push(id);
        }
        for &t in &outputs {
            debug_assert!(t < self.tensors.len(), "output tensor {t} unknown");
            let prev = self.producer.insert(t, id);
            debug_assert!(prev.is_none(), "tensor {t} produced twice");
        }
        self.ops.push(Op { id, name: name.into(), kind, inputs, outputs, control_deps: vec![] });
        id
    }

    /// Add an explicit ordering edge `dep → op`.
    pub fn add_control_dep(&mut self, op: OpId, dep: OpId) {
        if !self.ops[op].control_deps.contains(&dep) {
            self.ops[op].control_deps.push(dep);
        }
    }

    pub fn op(&self, id: OpId) -> &Op {
        &self.ops[id]
    }

    pub fn tensor(&self, id: TensorId) -> &TensorInfo {
        &self.tensors[id]
    }

    pub fn producer_of(&self, t: TensorId) -> Option<OpId> {
        self.producer.get(&t).copied()
    }

    pub fn consumers_of(&self, t: TensorId) -> &[OpId] {
        self.consumers.get(&t).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// All dependency predecessors of `op` (data producers + control deps).
    pub fn preds(&self, op: OpId) -> Vec<OpId> {
        let o = &self.ops[op];
        let mut out: Vec<OpId> = o
            .inputs
            .iter()
            .filter_map(|t| self.producer_of(*t))
            .collect();
        out.extend(o.control_deps.iter().copied());
        out.sort_unstable();
        out.dedup();
        out.retain(|&p| p != op);
        out
    }

    /// All dependency successors of `op`.
    pub fn succs(&self, op: OpId) -> Vec<OpId> {
        let mut out = Vec::new();
        for t in &self.ops[op].outputs {
            out.extend(self.consumers_of(*t));
        }
        for other in &self.ops {
            if other.control_deps.contains(&op) {
                out.push(other.id);
            }
        }
        out.sort_unstable();
        out.dedup();
        out.retain(|&s| s != op);
        out
    }

    /// Deterministic topological order (Kahn; ties broken by smallest id,
    /// i.e. insertion order — the "program order" a framework would emit).
    pub fn topo_order(&self) -> Result<Vec<OpId>> {
        let n = self.ops.len();
        let mut indeg = vec![0usize; n];
        let mut succs: Vec<Vec<OpId>> = vec![Vec::new(); n];
        for op in &self.ops {
            for p in self.preds(op.id) {
                indeg[op.id] += 1;
                succs[p].push(op.id);
            }
        }
        let mut heap = std::collections::BinaryHeap::new();
        for (i, &d) in indeg.iter().enumerate() {
            if d == 0 {
                heap.push(std::cmp::Reverse(i));
            }
        }
        let mut order = Vec::with_capacity(n);
        while let Some(std::cmp::Reverse(u)) = heap.pop() {
            order.push(u);
            for &v in &succs[u] {
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    heap.push(std::cmp::Reverse(v));
                }
            }
        }
        if order.len() != n {
            bail!("graph has a dependency cycle ({} of {} ops ordered)", order.len(), n);
        }
        Ok(order)
    }

    /// Check that `order` is a permutation of all ops respecting every
    /// dependency edge. This is the invariant Algorithm 1 must preserve —
    /// property-tested in rust/tests/.
    pub fn is_valid_order(&self, order: &[OpId]) -> bool {
        if order.len() != self.ops.len() {
            return false;
        }
        let mut pos = vec![usize::MAX; self.ops.len()];
        for (i, &o) in order.iter().enumerate() {
            if o >= self.ops.len() || pos[o] != usize::MAX {
                return false; // out of range or duplicate
            }
            pos[o] = i;
        }
        for op in &self.ops {
            for p in self.preds(op.id) {
                if pos[p] >= pos[op.id] {
                    return false;
                }
            }
        }
        true
    }

    /// Structural sanity checks (used by tests and the pass manager).
    pub fn validate(&self) -> Result<()> {
        for op in &self.ops {
            for &t in op.inputs.iter().chain(op.outputs.iter()) {
                if t >= self.tensors.len() {
                    bail!("op {} ({}) references unknown tensor {t}", op.id, op.name);
                }
            }
            if let Some(t) = op.kind.cache_tensor() {
                if !op.inputs.contains(&t) {
                    bail!("cache op {} ({}) must list its tensor {t} as input", op.id, op.name);
                }
            }
            for &d in &op.control_deps {
                if d >= self.ops.len() {
                    bail!("op {} control-dep on unknown op {d}", op.id);
                }
            }
        }
        self.topo_order().map(|_| ())
    }

    /// Ids of all cache operators.
    pub fn cache_ops(&self) -> Vec<OpId> {
        self.ops.iter().filter(|o| o.kind.is_cache_op()).map(|o| o.id).collect()
    }

    /// First consumer of a cache op's tensor *after* the cache op in
    /// `order` — "u ← first consumer of c" in Algorithm 1.
    pub fn first_consumer_after(&self, cache_op: OpId, order: &[OpId]) -> Option<OpId> {
        let t = self.ops[cache_op].kind.cache_tensor()?;
        let mut pos = vec![usize::MAX; self.ops.len()];
        for (i, &o) in order.iter().enumerate() {
            pos[o] = i;
        }
        // Consumers via data edge, or via control dep on the cache op.
        let mut candidates: Vec<OpId> = self
            .consumers_of(t)
            .iter()
            .copied()
            .filter(|&c| c != cache_op && !self.ops[c].kind.is_cache_op())
            .collect();
        for other in &self.ops {
            if other.control_deps.contains(&cache_op) && !other.kind.is_cache_op() {
                candidates.push(other.id);
            }
        }
        candidates.retain(|&c| pos[c] > pos[cache_op]);
        candidates.into_iter().min_by_key(|&c| pos[c])
    }

    /// Total bytes of all tensors whose home tier is `tier`.
    pub fn bytes_in_tier(&self, tier: Tier) -> u64 {
        self.tensors.iter().filter(|t| t.home == tier).map(|t| t.bytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Graph {
        // a -> (b, c) -> d
        let mut g = Graph::new();
        let t0 = g.add_tensor("t0", 8, Tier::Device);
        let t1 = g.add_tensor("t1", 8, Tier::Device);
        let t2 = g.add_tensor("t2", 8, Tier::Device);
        let t3 = g.add_tensor("t3", 8, Tier::Device);
        g.add_op("a", OpKind::Compute { flops: 1.0, bytes_accessed: 8 }, vec![], vec![t0]);
        g.add_op("b", OpKind::Compute { flops: 1.0, bytes_accessed: 8 }, vec![t0], vec![t1]);
        g.add_op("c", OpKind::Compute { flops: 1.0, bytes_accessed: 8 }, vec![t0], vec![t2]);
        g.add_op("d", OpKind::Compute { flops: 1.0, bytes_accessed: 8 }, vec![t1, t2], vec![t3]);
        g
    }

    #[test]
    fn topo_order_respects_deps() {
        let g = diamond();
        let order = g.topo_order().unwrap();
        assert!(g.is_valid_order(&order));
        assert_eq!(order[0], 0);
        assert_eq!(order[3], 3);
    }

    #[test]
    fn invalid_orders_rejected() {
        let g = diamond();
        assert!(!g.is_valid_order(&[3, 1, 2, 0])); // d before a
        assert!(!g.is_valid_order(&[0, 1, 2]));    // missing op
        assert!(!g.is_valid_order(&[0, 1, 1, 3])); // duplicate
    }

    #[test]
    fn control_deps_enter_ordering() {
        let mut g = diamond();
        // force c before b
        g.add_control_dep(1, 2);
        let order = g.topo_order().unwrap();
        let pos = |o: OpId| order.iter().position(|&x| x == o).unwrap();
        assert!(pos(2) < pos(1));
        assert!(g.is_valid_order(&order));
        assert!(!g.is_valid_order(&[0, 1, 2, 3]));
    }

    #[test]
    fn cycle_detected() {
        let mut g = diamond();
        g.add_control_dep(0, 3); // a after d -> cycle
        assert!(g.topo_order().is_err());
        assert!(g.validate().is_err());
    }

    #[test]
    fn preds_and_succs() {
        let g = diamond();
        assert_eq!(g.preds(3), vec![1, 2]);
        assert_eq!(g.succs(0), vec![1, 2]);
        assert!(g.preds(0).is_empty());
    }

    #[test]
    fn cache_ops_listed_and_first_consumer_found() {
        let mut g = Graph::new();
        let w = g.add_tensor("w", 1024, Tier::Remote);
        let x = g.add_tensor("x", 64, Tier::Device);
        let y = g.add_tensor("y", 64, Tier::Device);
        let pf = g.add_op("pf.w", OpKind::Prefetch { tensor: w }, vec![w], vec![]);
        let c0 = g.add_op("mm0", OpKind::Compute { flops: 1.0, bytes_accessed: 64 }, vec![], vec![x]);
        let c1 = g.add_op("mm1", OpKind::Compute { flops: 1.0, bytes_accessed: 64 }, vec![x, w], vec![y]);
        g.add_control_dep(c1, pf);
        let order = g.topo_order().unwrap();
        assert_eq!(g.cache_ops(), vec![pf]);
        assert_eq!(g.first_consumer_after(pf, &order), Some(c1));
        assert!(g.validate().is_ok());
        let _ = c0;
    }

    #[test]
    fn validate_rejects_cache_op_without_tensor_input() {
        let mut g = Graph::new();
        let w = g.add_tensor("w", 1024, Tier::Remote);
        g.add_op("pf.bad", OpKind::Prefetch { tensor: w }, vec![], vec![]);
        assert!(g.validate().is_err());
    }

    #[test]
    fn bytes_in_tier_sums() {
        let g = diamond();
        assert_eq!(g.bytes_in_tier(Tier::Device), 32);
        assert_eq!(g.bytes_in_tier(Tier::Remote), 0);
    }
}
