//! Bitset reachability over the op DAG, shared by the IR verifier and the
//! TransferSan static analyzer.
//!
//! Both clients ask the same class of question: *which of a small tracked
//! set of ops (cache operators, mostly) happen-before / happen-after a
//! given op on **every** dep-consistent linearization?* The answer is the
//! transitive closure restricted to tracked columns, stored as one bitset
//! row per op:
//!
//! * [`Reach::ancestors`] — `row(o)` holds tracked op `t` iff `t ⇝ o`
//!   (or `t == o`): `t` completes before `o` starts in every valid order.
//! * [`Reach::descendants`] — `row(o)` holds `t` iff `o ⇝ t` (or
//!   `t == o`).
//!
//! Rows are reflexive (a tracked op appears in its own row) so "at or
//! before" queries are one bit test; callers that need strict ordering
//! exclude equality themselves (tracked/untracked kind splits usually make
//! the cases disjoint anyway).
//!
//! Historically the verifier rebuilt this matrix from scratch inside every
//! `verify_ir` call — once per pipeline stage. The matrix now lives here,
//! is cached by the compiler's `AnalysisCache` keyed on the graph version,
//! and is **patched forward** from the graph's mutation journal
//! ([`Reach::update`]) when the interim mutations are local (op appends,
//! forward-edge insertions). A `NonLocal` event or a tracked-bit overflow
//! falls back to a full rebuild.

use super::graph::{Graph, Mutation};
use super::op::OpId;

/// Which ops get a bit column.
#[derive(Debug, Clone)]
pub enum TrackedSet {
    /// All cache operators (`Prefetch` / `Store` / `Detach`), in op-id
    /// order. The only variant that supports journal-driven
    /// [`Reach::update`] (membership of an appended op is decidable from
    /// the op alone).
    CacheOps,
    /// An explicit op set (kept in the given order, duplicates dropped).
    Ops(Vec<OpId>),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Dir {
    /// `row(o)` = tracked ops that happen at-or-before `o`.
    Ancestors,
    /// `row(o)` = tracked ops that happen at-or-after `o`.
    Descendants,
}

/// Per-op bitsets over a tracked op set. See module docs.
#[derive(Debug, Clone)]
pub struct Reach {
    dir: Dir,
    cache_ops_tracked: bool,
    n_ops: usize,
    /// Tracked ops in bit order.
    tracked: Vec<OpId>,
    /// `bit_of[op]` = bit index, or `usize::MAX` when untracked.
    bit_of: Vec<usize>,
    /// Words per row, sized with slack so appending tracked ops does not
    /// immediately force a rebuild.
    words: usize,
    /// Row-major `[op][word]`.
    rows: Vec<u64>,
}

/// Word capacity for `n` tracked bits, with headroom for incremental
/// appends (one spare word ≈ 64 more cache ops before a forced rebuild).
fn words_for(n: usize) -> usize {
    n / 64 + 2
}

impl Reach {
    /// Build the ancestor matrix: one forward sweep along `order`.
    ///
    /// `order` must be a valid topological order of `g` (every pred before
    /// its successor). Out-of-range preds (structurally broken graphs) are
    /// skipped so the verifier can still run its structural checks first.
    pub fn ancestors(g: &Graph, order: &[OpId], tracked: TrackedSet) -> Self {
        let mut r = Self::empty(g, Dir::Ancestors, tracked);
        r.sweep_forward(g, order, 0, None);
        r
    }

    /// Build the descendant matrix: one reverse sweep along `order`.
    pub fn descendants(g: &Graph, order: &[OpId], tracked: TrackedSet) -> Self {
        let mut r = Self::empty(g, Dir::Descendants, tracked);
        let n = g.ops.len();
        let w = r.words;
        // Invert preds once; `Graph::succs` is O(n) per call.
        let mut succs: Vec<Vec<OpId>> = vec![Vec::new(); n];
        for op in &g.ops {
            for p in g.preds(op.id) {
                if p < n {
                    succs[p].push(op.id);
                }
            }
        }
        for &o in order.iter().rev() {
            if o >= n {
                continue;
            }
            for &s in &succs[o] {
                for k in 0..w {
                    let m = r.rows[s * w + k];
                    r.rows[o * w + k] |= m;
                }
            }
            if r.bit_of[o] != usize::MAX {
                r.rows[o * w + r.bit_of[o] / 64] |= 1u64 << (r.bit_of[o] % 64);
            }
        }
        r
    }

    fn empty(g: &Graph, dir: Dir, tracked: TrackedSet) -> Self {
        let n = g.ops.len();
        let (tracked, cache_ops_tracked) = match tracked {
            TrackedSet::CacheOps => (g.cache_ops(), true),
            TrackedSet::Ops(v) => (v, false),
        };
        let mut bit_of = vec![usize::MAX; n];
        let mut kept = Vec::with_capacity(tracked.len());
        for &t in &tracked {
            if t < n && bit_of[t] == usize::MAX {
                bit_of[t] = kept.len();
                kept.push(t);
            }
        }
        let words = words_for(kept.len());
        Self { dir, cache_ops_tracked, n_ops: n, tracked: kept, bit_of, words, rows: vec![0; n * words] }
    }

    /// Forward sweep recomputing rows from position `start` in `order`.
    /// With `only` set, rows are recomputed only for flagged ops or ops
    /// with a flagged pred (the incremental path); newly changed rows flag
    /// their op in turn.
    fn sweep_forward(&mut self, g: &Graph, order: &[OpId], start: usize, only: Option<&mut Vec<bool>>) {
        let n = self.n_ops;
        let w = self.words;
        let mut scratch: Vec<u64> = vec![0; w];
        let mut flags = only;
        for &o in order.iter().skip(start) {
            if o >= n {
                continue;
            }
            let preds = g.preds(o);
            if let Some(flagged) = flags.as_deref_mut() {
                if !flagged[o] && !preds.iter().any(|&p| p < n && flagged[p]) {
                    continue;
                }
            }
            scratch.fill(0);
            for &p in &preds {
                if p >= n {
                    continue;
                }
                for k in 0..w {
                    scratch[k] |= self.rows[p * w + k];
                }
                if self.bit_of[p] != usize::MAX {
                    scratch[self.bit_of[p] / 64] |= 1u64 << (self.bit_of[p] % 64);
                }
            }
            if self.bit_of[o] != usize::MAX {
                scratch[self.bit_of[o] / 64] |= 1u64 << (self.bit_of[o] % 64);
            }
            let start_w = o * w;
            if self.rows[start_w..start_w + w] != scratch[..] {
                self.rows[start_w..start_w + w].copy_from_slice(&scratch);
                if let Some(flagged) = flags.as_deref_mut() {
                    flagged[o] = true;
                }
            }
        }
    }

    /// Patch the matrix forward across journalled `muts`, given a valid
    /// topological `order` of the *current* graph. Returns `false` when the
    /// batch cannot be patched (non-local mutation, tracked-bit overflow,
    /// stale order) — the caller rebuilds.
    ///
    /// Only ancestor matrices over [`TrackedSet::CacheOps`] are patchable:
    /// appends and forward edges only ever extend rows at-or-after the
    /// mutated op, so one suffix sweep restores the fixpoint. (Descendant
    /// rows would have to propagate *backwards* through the whole prefix.)
    pub fn update(&mut self, g: &Graph, order: &[OpId], muts: &[Mutation]) -> bool {
        if self.dir != Dir::Ancestors || !self.cache_ops_tracked {
            return false;
        }
        let n = g.ops.len();
        if order.len() != n || n < self.n_ops {
            return false;
        }
        let mut dirty: Vec<OpId> = Vec::new();
        for m in muts {
            match *m {
                // A retarget changes no edges and cannot alter cache-op
                // membership (Store stays Store, Prefetch stays Prefetch).
                Mutation::TensorAdded { .. }
                | Mutation::TensorMeta
                | Mutation::OpRetargeted { .. } => {}
                Mutation::OpAdded { op }
                | Mutation::InputAdded { op, .. }
                | Mutation::ControlDepAdded { op, .. } => dirty.push(op),
                Mutation::NonLocal => return false,
            }
        }
        if dirty.iter().any(|&o| o >= n) {
            return false;
        }
        // Grow rows / assign bits for appended ops.
        if n > self.n_ops {
            self.bit_of.resize(n, usize::MAX);
            self.rows.resize(n * self.words, 0);
            for op in &g.ops[self.n_ops..] {
                if op.kind.is_cache_op() {
                    let bit = self.tracked.len();
                    if bit >= self.words * 64 {
                        return false; // layout overflow — rebuild with fresh slack
                    }
                    self.tracked.push(op.id);
                    self.bit_of[op.id] = bit;
                }
            }
            self.n_ops = n;
        }
        if dirty.is_empty() {
            return true;
        }
        // Validate `order` is a permutation placing every pred of a
        // to-be-recomputed row before it, then run one suffix sweep from
        // the earliest dirty position.
        let mut pos = vec![usize::MAX; n];
        for (i, &o) in order.iter().enumerate() {
            if o >= n || pos[o] != usize::MAX {
                return false;
            }
            pos[o] = i;
        }
        let start = dirty.iter().map(|&o| pos[o]).min().unwrap_or(n);
        for &o in order.iter().skip(start) {
            if g.preds(o).iter().any(|&p| p >= n || pos[p] >= pos[o]) {
                return false; // order is stale w.r.t. the new edges
            }
        }
        let mut flagged = vec![false; n];
        for &o in &dirty {
            flagged[o] = true;
        }
        self.sweep_forward(g, order, start, Some(&mut flagged));
        true
    }

    /// Number of tracked ops (bit columns).
    pub fn tracked_len(&self) -> usize {
        self.tracked.len()
    }

    /// The tracked ops, in bit order.
    pub fn tracked(&self) -> &[OpId] {
        &self.tracked
    }

    /// Bit index of `op`, if tracked.
    pub fn bit(&self, op: OpId) -> Option<usize> {
        match self.bit_of.get(op) {
            Some(&b) if b != usize::MAX => Some(b),
            _ => None,
        }
    }

    /// Does `op`'s row contain tracked op `t`? For an ancestor matrix this
    /// is "`t ⇝ op` or `t == op`"; for descendants, "`op ⇝ t` or `t == op`".
    /// `false` when `t` is untracked or out of range.
    pub fn contains(&self, op: OpId, t: OpId) -> bool {
        let Some(bit) = self.bit(t) else { return false };
        if op >= self.n_ops {
            return false;
        }
        self.rows[op * self.words + bit / 64] & (1u64 << (bit % 64)) != 0
    }

    /// Build a bitmask (in tracked-bit space) over the given ops; untracked
    /// ops are ignored.
    pub fn mask<I: IntoIterator<Item = OpId>>(&self, ops: I) -> Vec<u64> {
        let mut m = vec![0u64; self.words];
        for op in ops {
            if let Some(bit) = self.bit(op) {
                m[bit / 64] |= 1u64 << (bit % 64);
            }
        }
        m
    }

    /// Does `row(op) ∩ mask` have any bit set?
    pub fn row_intersects(&self, op: OpId, mask: &[u64]) -> bool {
        if op >= self.n_ops {
            return false;
        }
        let row = &self.rows[op * self.words..(op + 1) * self.words];
        row.iter().zip(mask).any(|(a, b)| a & b != 0)
    }

    /// Does `row_self(a) ∩ row_other(b) ∩ mask` have any bit set? Both
    /// matrices must share one tracked layout (e.g. the ancestor and
    /// descendant matrices over `TrackedSet::CacheOps` of one graph); this
    /// answers "∃ tracked op in `mask` forced between `b` and `a`".
    pub fn rows_intersect(&self, a: OpId, other: &Reach, b: OpId, mask: &[u64]) -> bool {
        debug_assert_eq!(self.tracked.len(), other.tracked.len(), "tracked layouts differ");
        if a >= self.n_ops || b >= other.n_ops {
            return false;
        }
        let ra = &self.rows[a * self.words..(a + 1) * self.words];
        let rb = &other.rows[b * other.words..(b + 1) * other.words];
        let w = self.words.min(other.words).min(mask.len());
        (0..w).any(|i| ra[i] & rb[i] & mask[i] != 0)
    }
}

#[cfg(test)]
mod tests {
    use super::super::GraphBuilder;
    use super::*;

    /// p ── c1 ── st ── pf ── c2   (round trip on w)
    fn round_trip() -> (Graph, OpId, OpId, OpId, OpId) {
        let mut b = GraphBuilder::new();
        let w = b.tensor("w", 64 << 20, crate::graph::Tier::Device);
        let x = b.tensor("x", 1 << 20, crate::graph::Tier::Device);
        let p = b.compute("p", 1e9, 0, vec![], vec![w, x]);
        let c1 = b.compute("c1", 1e9, 0, vec![w, x], vec![]);
        let st = b.store("st", w);
        b.dep(st, c1);
        let pf = b.prefetch("pf", w);
        b.dep(pf, st);
        let c2 = b.compute("c2", 1e9, 0, vec![w], vec![]);
        b.dep(c2, pf);
        let _ = p;
        (b.build(), c1, st, pf, c2)
    }

    #[test]
    fn ancestors_and_descendants_agree() {
        let (g, c1, st, pf, c2) = round_trip();
        let order = g.topo_order().unwrap();
        let anc = Reach::ancestors(&g, &order, TrackedSet::CacheOps);
        let desc = Reach::descendants(&g, &order, TrackedSet::CacheOps);
        assert_eq!(anc.tracked_len(), 2);
        // st ⇝ pf ⇝ c2; c1 before both.
        assert!(anc.contains(c2, pf));
        assert!(anc.contains(c2, st));
        assert!(anc.contains(pf, st));
        assert!(!anc.contains(c1, st));
        assert!(desc.contains(c1, st));
        assert!(desc.contains(c1, pf));
        assert!(!desc.contains(c2, st));
        // reflexive
        assert!(anc.contains(st, st));
        assert!(desc.contains(pf, pf));
        // "a prefetch forced between st and c2"
        let acq = anc.mask([pf]);
        assert!(anc.rows_intersect(c2, &desc, st, &acq));
        // …but nothing tracked is forced between pf and c2 except pf itself.
        assert!(!anc.rows_intersect(c2, &desc, pf, &anc.mask([st])));
    }

    #[test]
    fn update_patches_appends_and_forward_edges() {
        let (mut g, _c1, st, pf, c2) = round_trip();
        let order = g.topo_order().unwrap();
        let mut anc = Reach::ancestors(&g, &order, TrackedSet::CacheOps);
        let v0 = g.version();
        // Append a prefetch + consumer, then wire forward edges.
        let t = g.add_tensor("y", 8 << 20, crate::graph::Tier::Remote);
        let pf2 = g.add_op("pf2", crate::graph::OpKind::prefetch(t), vec![t], vec![]);
        let c3 = g.add_op(
            "c3",
            crate::graph::OpKind::Compute { flops: 1e9, bytes_accessed: 0 },
            vec![t],
            vec![],
        );
        g.add_control_dep(c3, pf2);
        g.add_control_dep(pf2, c2);
        let muts = g.mutations_since(v0).unwrap();
        let order2 = g.topo_order().unwrap();
        assert!(anc.update(&g, &order2, &muts));
        let fresh = Reach::ancestors(&g, &order2, TrackedSet::CacheOps);
        for &o in &order2 {
            for &t in fresh.tracked() {
                assert_eq!(anc.contains(o, t), fresh.contains(o, t), "op {o} tracked {t}");
            }
        }
        assert!(anc.contains(c3, pf2));
        assert!(anc.contains(pf2, st));
        assert!(anc.contains(pf2, pf));
    }
}
