#![cfg(feature = "xla")]
//! Integration: the AOT bridge preserves numerics end-to-end.
//!
//! aot.py computed prefill + one decode step in python (jax) for seeded
//! inputs and dumped the logits; here the rust runtime loads the same
//! artifacts, replays the same inputs through PJRT, and must match.
//! This is the contract that makes the three-layer architecture sound.

use std::path::Path;

use hyperoffload::runtime::ModelRuntime;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn read_f32(path: &Path) -> Vec<f32> {
    let raw = std::fs::read(path).unwrap_or_else(|e| panic!("{}: {e}", path.display()));
    raw.chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

fn read_i32(path: &Path) -> Vec<i32> {
    let raw = std::fs::read(path).unwrap_or_else(|e| panic!("{}: {e}", path.display()));
    raw.chunks_exact(4)
        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
        .collect()
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "length mismatch");
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn prefill_and_decode_match_python_golden() {
    let dir = artifacts_dir();
    if !dir.join("meta.txt").exists() {
        panic!("artifacts missing — run `make artifacts` first");
    }
    let client = xla::PjRtClient::cpu().expect("pjrt cpu client");
    let model = ModelRuntime::load(&client, &dir).expect("load artifacts");

    let tokens = read_i32(&dir.join("golden_tokens.bin"));
    let want_prefill = read_f32(&dir.join("golden_prefill_logits.bin"));
    let want_next = read_i32(&dir.join("golden_next_token.bin"));
    let want_decode = read_f32(&dir.join("golden_decode_logits.bin"));

    // Prefill must reproduce python logits bit-close.
    let (logits, kc, vc) = model.run_prefill(&tokens).expect("prefill");
    let d = max_abs_diff(&logits, &want_prefill);
    assert!(d < 1e-4, "prefill logits diverged: max abs diff {d}");

    // Greedy next token must agree exactly.
    let next = model.argmax_tokens(&logits);
    assert_eq!(next, want_next, "greedy tokens diverged");

    // One decode step over the produced caches must match too.
    let (dlogits, _, _) = model
        .run_decode(&next, model.spec.prefill_len as i32, &kc, &vc)
        .expect("decode");
    let d = max_abs_diff(&dlogits, &want_decode);
    assert!(d < 1e-4, "decode logits diverged: max abs diff {d}");
}

#[test]
fn decode_positions_advance_cache_consistently() {
    // Decoding the same token at successive positions must change logits
    // (the cache grows) and stay finite.
    let dir = artifacts_dir();
    let client = xla::PjRtClient::cpu().unwrap();
    let model = ModelRuntime::load(&client, &dir).unwrap();
    let b = model.spec.batch;
    let p = model.spec.prefill_len as i32;

    let tokens: Vec<i32> = (0..b * model.spec.prefill_len).map(|i| (i % 100 + 1) as i32).collect();
    let (logits, mut kc, mut vc) = model.run_prefill(&tokens).unwrap();
    let mut next = model.argmax_tokens(&logits);

    let mut prev: Option<Vec<f32>> = None;
    for step in 0..4 {
        let (lo, kc2, vc2) = model.run_decode(&next, p + step, &kc, &vc).unwrap();
        assert!(lo.iter().all(|x| x.is_finite()), "non-finite logits at step {step}");
        if let Some(pv) = &prev {
            assert_ne!(&lo, pv, "logits identical across steps {step}");
        }
        prev = Some(lo.clone());
        next = model.argmax_tokens(&lo);
        kc = kc2;
        vc = vc2;
    }
}

#[test]
fn rejects_malformed_inputs() {
    let dir = artifacts_dir();
    let client = xla::PjRtClient::cpu().unwrap();
    let model = ModelRuntime::load(&client, &dir).unwrap();
    // Wrong token count.
    assert!(model.run_prefill(&[1, 2, 3]).is_err());
    // Out-of-range decode position.
    let cache = model.empty_cache().unwrap();
    let toks = vec![1; model.spec.batch];
    assert!(model.run_decode(&toks, model.spec.max_seq as i32, &cache, &cache).is_err());
    assert!(model.run_decode(&toks, -1, &cache, &cache).is_err());
}
